"""Quantizer semantics — the bit-exactness contract with the Rust model."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import quant


# NOTE: xla's CPU backend enables FTZ/fast-math globally, which breaks
# hypothesis' st.floats() sanity checks — derive floats from integers instead.
@settings(max_examples=100, deadline=None)
@given(
    xi=st.integers(-16_000, 16_000),
    bits=st.integers(1, 8),
    si=st.integers(1, 800),
)
def test_unsigned_code_value_consistency(xi, bits, si):
    x, scale = xi / 1000.0, si / 100.0
    xs = jnp.float32(x)
    s = jnp.float32(scale)
    code = int(quant.unsigned_code(xs, bits, s))
    levels = (1 << bits) - 1
    assert 0 <= code <= levels
    val = float(quant.quant_unsigned(xs, bits, s))
    np.testing.assert_allclose(val, code * scale / levels, rtol=1e-6, atol=1e-7)


@settings(max_examples=100, deadline=None)
@given(
    xi=st.integers(-16_000, 16_000),
    bits=st.integers(2, 8),
    si=st.integers(1, 800),
)
def test_signed_code_range_and_value(xi, bits, si):
    x, scale = xi / 1000.0, si / 100.0
    xs = jnp.float32(x)
    s = jnp.float32(scale)
    code = int(quant.signed_code(xs, bits, s))
    assert -(1 << (bits - 1)) <= code <= (1 << (bits - 1)) - 1
    val = float(quant.quant_signed(xs, bits, s))
    pos = (1 << (bits - 1)) - 1
    np.testing.assert_allclose(val, code * scale / pos, rtol=1e-6, atol=1e-7)


def test_quantization_is_idempotent():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=100).astype(np.float32))
    q1 = quant.quant_unsigned(x, 3, jnp.float32(1.0))
    q2 = quant.quant_unsigned(q1, 3, jnp.float32(1.0))
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))


def test_ste_gradient_passes_through():
    import jax

    g = jax.grad(lambda x: quant.quant_unsigned(x, 3, jnp.float32(1.0)).sum())(
        jnp.asarray([0.4, 0.7], jnp.float32)
    )
    np.testing.assert_allclose(np.asarray(g), [1.0, 1.0])


def test_round_half_even_semantics():
    # jnp.round ties-to-even: the rust side mirrors this exactly.
    vals = jnp.asarray([0.5, 1.5, 2.5, -0.5, -1.5], jnp.float32)
    np.testing.assert_array_equal(np.asarray(jnp.round(vals)), [0.0, 2.0, 2.0, -0.0, -2.0])
