"""L1 kernel correctness: Pallas vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes-relevant parameters (batch, neurons, fan-in,
degree, tile sizes) and asserts allclose — the core correctness signal for
the kernels that end up on the Rust serving path.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import lut_eval, lut_eval_ref, poly_neuron, poly_neuron_ref
from compile.monomials import monomial_count


@settings(max_examples=40, deadline=None)
@given(
    b=st.integers(1, 64),
    n=st.integers(1, 48),
    f=st.integers(1, 6),
    d=st.integers(1, 3),
    bt=st.sampled_from([1, 4, 16, 1 << 30]),
    nt=st.sampled_from([1, 8, 1 << 30]),
    seed=st.integers(0, 2**31 - 1),
)
def test_poly_neuron_matches_ref(b, n, f, d, bt, nt, seed):
    rng = np.random.default_rng(seed)
    xs = jnp.asarray(rng.normal(size=(b, n, f)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(n, monomial_count(f, d))).astype(np.float32))
    out = poly_neuron(xs, w, d, batch_tile=bt, neuron_tile=nt)
    ref = poly_neuron_ref(xs, w, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-5, atol=3e-5)


@settings(max_examples=40, deadline=None)
@given(
    b=st.integers(1, 64),
    n=st.integers(1, 32),
    tbits=st.integers(1, 10),
    bt=st.sampled_from([1, 8, 1 << 30]),
    nt=st.sampled_from([1, 4, 1 << 30]),
    seed=st.integers(0, 2**31 - 1),
)
def test_lut_eval_matches_ref(b, n, tbits, bt, nt, seed):
    rng = np.random.default_rng(seed)
    t = 1 << tbits
    addr = jnp.asarray(rng.integers(0, t, size=(b, n)).astype(np.int32))
    tables = jnp.asarray(rng.integers(-8, 8, size=(n, t)).astype(np.int32))
    out = lut_eval(addr, tables, batch_tile=bt, neuron_tile=nt)
    ref = lut_eval_ref(addr, tables)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_poly_neuron_degree_zero_weights():
    # Only the constant monomial active -> output equals w[:, 0].
    b, n, f, d = 4, 5, 3, 2
    w = np.zeros((n, monomial_count(f, d)), np.float32)
    w[:, 0] = np.arange(n)
    xs = np.random.default_rng(0).normal(size=(b, n, f)).astype(np.float32)
    out = np.asarray(poly_neuron(jnp.asarray(xs), jnp.asarray(w), d))
    np.testing.assert_allclose(out, np.tile(np.arange(n, dtype=np.float32), (b, 1)))


def test_poly_neuron_rejects_bad_weight_shape():
    xs = jnp.zeros((2, 3, 4))
    w = jnp.zeros((3, 7))  # wrong M for F=4, D=1 (should be 5)
    with pytest.raises(AssertionError):
        poly_neuron(xs, w, 1)


def test_lut_eval_identity_tables():
    # tables[n, a] = a -> output equals the address.
    b, n, t = 8, 6, 16
    rng = np.random.default_rng(1)
    addr = rng.integers(0, t, size=(b, n)).astype(np.int32)
    tables = np.tile(np.arange(t, dtype=np.int32), (n, 1))
    out = np.asarray(lut_eval(jnp.asarray(addr), jnp.asarray(tables)))
    np.testing.assert_array_equal(out, addr)
