"""AOT lowering: HLO text artifacts + manifest schema."""

import json
import os

import jax
import pytest

from compile import aot, configs as C, train as T
from compile.model import make_indices
from compile.optim import AdamWConfig


def test_to_hlo_text_prints_large_constants():
    """Regression for the xla_extension 0.5.1 gotcha: the default printer
    elides big constants as `constant({...})`, which the old text parser
    silently zeroes.  (See aot.to_hlo_text and rust/tests/cross_check.rs.)"""
    import numpy as np

    w = np.random.default_rng(0).normal(size=(64, 32)).astype(np.float32)
    lowered = jax.jit(lambda x: (x @ w,)).lower(
        jax.ShapeDtypeStruct((4, 64), "float32")
    )
    text = aot.to_hlo_text(lowered)
    assert "constant({...})" not in text
    assert "f32[64,32]" in text


def test_config_sets_unique_and_complete():
    all_cfgs = aot.config_set("all")
    ids = [aot.artifact_id(c) for c in all_cfgs]
    assert len(ids) == len(set(ids))
    fig6 = {aot.artifact_id(c) for c in aot.config_set("fig6")}
    # Base + deeper + wider + add variants for each model/degree.
    assert "hdr-d1-a1" in fig6
    assert "hdr-deep2-d1-a1" in fig6
    assert "hdr-wide2-d1-a1" in fig6
    assert "hdr-d1-a3" in fig6
    assert "nid-lite-d1-a2" in fig6
    t4 = {aot.artifact_id(c) for c in aot.config_set("table4")}
    assert t4 == {"hdr-t4-d3-a2", "jsc-xl-t4-d3-a2", "jsc-m-lite-t4-d3-a2", "nid-t4-d1-a2"}
    with pytest.raises(SystemExit):
        aot.config_set("nope")


def test_emit_config_writes_valid_manifest(tmp_path):
    cfg = C.jsc_m_lite(degree=1, a=2)
    aot.emit_config(cfg, str(tmp_path), eval_batch=32)
    aid = aot.artifact_id(cfg)
    meta = json.load(open(tmp_path / f"{aid}.meta.json"))
    # Schema the Rust loader (meta.rs) depends on.
    assert meta["id"] == aid
    assert meta["dataset"] == "jsc"
    assert meta["config"]["widths"] == [16, 64, 32, 5]
    assert len(meta["indices"]) == 3
    assert len(meta["indices"][0]) == 2  # A
    assert len(meta["monomials"]) == 3
    assert meta["monomials"][0][0] == []  # constant term first
    manifest = T.state_manifest(cfg, AdamWConfig())
    assert len(meta["state"]) == len(manifest) == len(meta["init"])
    for spec, (name, shape, role) in zip(meta["state"], manifest):
        assert spec["name"] == name
        assert tuple(spec["shape"]) == tuple(shape)
        assert spec["role"] == role
    for spec, init in zip(meta["state"], meta["init"]):
        want = 1
        for s in spec["shape"]:
            want *= s
        assert len(init) == want
    # HLO files exist and are text.
    for k in ("train", "eval"):
        p = tmp_path / meta["artifacts"][k]
        assert p.exists()
        head = open(p).read(200)
        assert head.startswith("HloModule")


def test_emit_config_is_incremental(tmp_path):
    cfg = C.jsc_m_lite(degree=1, a=1)
    aot.emit_config(cfg, str(tmp_path))
    aid = aot.artifact_id(cfg)
    path = tmp_path / f"{aid}.meta.json"
    mtime = os.path.getmtime(path)
    aot.emit_config(cfg, str(tmp_path))  # second call: up-to-date no-op
    assert os.path.getmtime(path) == mtime
