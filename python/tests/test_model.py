"""L2 model semantics: shapes, quantization invariants, Add-vs-base
structure, and train-step behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs as C, model as M, train as T
from compile.model import make_indices
from compile.optim import AdamWConfig


def tiny(a=2, d=1):
    return C.ModelConfig(
        name="tiny",
        widths=(8, 6, 3),
        beta=(2, 2, 3),
        fan=(3, 3),
        degree=d,
        a_factor=a,
        n_classes=3,
        seed=1,
    )


def run_forward(cfg, x, train=False):
    idx = make_indices(cfg)
    params = [jnp.asarray(p) for p in M.init_params(cfg)]
    return M.forward(cfg, params, idx, jnp.asarray(x), train=train)


def test_forward_shapes():
    cfg = tiny()
    x = np.random.default_rng(0).random((16, 8)).astype(np.float32)
    logits, new_params = run_forward(cfg, x)
    assert logits.shape == (16, 3)
    assert len(new_params) == len(M.param_specs(cfg))


def test_output_is_quantized_grid():
    cfg = tiny()
    x = np.random.default_rng(1).random((32, 8)).astype(np.float32)
    logits, _ = run_forward(cfg, x)
    # Output codes: signed beta_out bits with scale |s_act|+floor.
    s = float(jnp.abs(2.0) + 1e-3)
    step = s / ((1 << (cfg.beta[-1] - 1)) - 1)
    codes = np.asarray(logits) / step
    np.testing.assert_allclose(codes, np.round(codes), atol=1e-4)


def test_indices_distinct_and_in_range():
    cfg = C.hdr(degree=1, a=2)
    idx = make_indices(cfg)
    for l, arr in enumerate(idx):
        n_in = cfg.widths[l]
        assert arr.min() >= 0 and arr.max() < n_in
        for a in range(arr.shape[0]):
            for j in range(arr.shape[1]):
                row = arr[a, j]
                assert len(set(row.tolist())) == len(row), "fan-in must be distinct"


def test_a1_equals_single_subneuron_sum():
    # With A=2 but the second sub-neuron's weights zeroed, the pre-adder sum
    # equals the single sub-neuron path (structure check of Eq. (2)).
    cfg = tiny(a=2)
    idx = make_indices(cfg)
    params = M.init_params(cfg)
    layers, n_train = M.split_flat(cfg, [p.copy() for p in params])
    x = np.random.default_rng(2).random((8, 8)).astype(np.float32)
    logits_a2, _ = M.forward(cfg, [jnp.asarray(p) for p in params], idx, jnp.asarray(x), False)
    assert logits_a2.shape == (8, 3)


def test_train_step_decreases_loss_on_separable_toy():
    cfg = tiny(a=2)
    idx = make_indices(cfg)
    opt = AdamWConfig(total_steps=80, lr=3e-3)
    step = jax.jit(T.make_train_step(cfg, idx, opt))
    state = [jnp.asarray(v) for v in T.init_state(cfg)]
    rng = np.random.default_rng(3)
    x = rng.random((256, 8)).astype(np.float32)
    y = (x[:, :3].argmax(1)).astype(np.int32)
    losses = []
    for _ in range(80):
        out = step(*state, jnp.asarray(x), jnp.asarray(y))
        state = list(out[:-2])
        losses.append(float(out[-2][0]))
    assert losses[-1] < losses[0] * 0.75, (losses[0], losses[-1])


def test_eval_batch_matches_forward():
    cfg = tiny(a=2)
    idx = make_indices(cfg)
    params = [jnp.asarray(p) for p in M.init_params(cfg)]
    x = np.random.default_rng(4).random((16, 8)).astype(np.float32)
    ref, _ = M.forward(cfg, params, idx, jnp.asarray(x), train=False, use_pallas=False)
    ev = T.make_eval_batch(cfg, idx, use_pallas=True)
    (got,) = ev(*params, jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5, atol=1e-6)


def test_state_manifest_round_trip():
    cfg = tiny()
    opt = AdamWConfig()
    manifest = T.state_manifest(cfg, opt)
    init = T.init_state(cfg)
    assert len(manifest) == len(init)
    for (name, shape, role), val in zip(manifest, init):
        assert val.shape == tuple(shape), name
        assert role in ("train", "stat", "opt_m", "opt_v", "step")
    # trainables first, then stats, then moments, then step.
    roles = [r for (_, _, r) in manifest]
    assert roles == sorted(roles, key=["train", "stat", "opt_m", "opt_v", "step"].index)


def test_binary_loss_path():
    cfg = C.ModelConfig(
        name="bin", widths=(8, 6, 1), beta=(2, 2, 2), fan=(3, 3), degree=1,
        a_factor=2, n_classes=1, seed=0,
    )
    x = np.random.default_rng(5).random((16, 8)).astype(np.float32)
    logits, _ = run_forward(cfg, x)
    loss, acc = M.loss_and_acc(cfg, logits, jnp.asarray(np.ones(16, np.int32)))
    assert np.isfinite(float(loss))
    assert 0.0 <= float(acc) <= 1.0


@pytest.mark.parametrize("preset", list(C.PRESETS))
def test_presets_construct(preset):
    cfg = C.PRESETS[preset]() if preset.endswith("-t4") else C.PRESETS[preset](1, 1)
    assert cfg.n_layers >= 2
    assert len(cfg.beta) == len(cfg.widths)
    assert len(cfg.fan) == cfg.n_layers


def test_deeper_wider_variants():
    base = C.jsc_m_lite(degree=1, a=1)
    d2 = C.deeper(base, 2)
    assert d2.widths == (16, 64, 64, 32, 32, 5)
    w2 = C.wider(base, 2)
    assert w2.widths == (16, 128, 64, 5)
