"""Monomial enumeration — the cross-language weight-layout contract."""

import math

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import monomials as mono


def test_counts_formula():
    for f in range(1, 8):
        for d in range(1, 4):
            assert mono.monomial_count(f, d) == math.comb(f + d, d)
            assert len(mono.monomial_index_lists(f, d)) == mono.monomial_count(f, d)


def test_canonical_order_f2_d2():
    assert mono.monomial_index_lists(2, 2) == ((), (0,), (1,), (0, 0), (0, 1), (1, 1))


def test_exponent_matrix_consistent():
    e = mono.exponent_matrix(3, 2)
    assert e.shape == (10, 3)
    assert e[0].tolist() == [0, 0, 0]
    # Every row's degree <= 2 and ordering is degree-major.
    degs = e.sum(1)
    assert (np.diff(degs) >= 0).all()


@settings(max_examples=30, deadline=None)
@given(f=st.integers(1, 6), d=st.integers(1, 3), seed=st.integers(0, 10**6))
def test_expand_matches_manual_product(f, d, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(5, f)).astype(np.float64)
    ex = mono.expand(x, d)
    lists = mono.monomial_index_lists(f, d)
    assert ex.shape == (5, len(lists))
    for m, combo in enumerate(lists):
        want = np.ones(5)
        for i in combo:
            want = want * x[:, i]
        np.testing.assert_allclose(ex[:, m], want, rtol=1e-12)


def test_first_layer_artifact_contract(tmp_path):
    # The aot manifest exports monomials so Rust never guesses the order.
    from compile.configs import jsc_m_lite

    cfg = jsc_m_lite(degree=2, a=2)
    lists = mono.monomial_index_lists(cfg.fan[0], cfg.degree)
    assert lists[0] == ()
    assert lists[1] == (0,)
    # combinations_with_replacement ordering: last entry is the top-degree
    # power of the last variable.
    assert lists[-1] == tuple([cfg.fan[0] - 1] * cfg.degree)
