"""Pytest bootstrap: make `compile.*` importable whether pytest runs from
the repo root (`pytest python/tests/`) or from `python/`."""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
