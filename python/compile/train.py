"""Train/eval step builders for AOT lowering.

The Rust driver owns the loop; these functions define ONE step as a pure
function over a flat state list so the whole optimizer state lives in PJRT
device buffers between steps (no host round-trips):

    state = trainables(T) ++ bn_stats(S) ++ adam_m(T) ++ adam_v(T) ++ [step]
    train_step(state..., x, y) -> state'... ++ [loss, acc]
    eval_batch(trainables..., bn_stats..., x) -> logits   (Pallas fast path)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from .configs import ModelConfig
from .optim import AdamWConfig, adamw_update


def state_manifest(cfg: ModelConfig, opt: AdamWConfig):
    """Ordered (name, shape, role) manifest for the full training state."""
    specs = M.param_specs(cfg)
    train = [s for s in specs if s.role == "train"]
    stats = [s for s in specs if s.role == "stat"]
    out = [(s.name, s.shape, "train") for s in train]
    out += [(s.name, s.shape, "stat") for s in stats]
    out += [(f"m.{s.name}", s.shape, "opt_m") for s in train]
    out += [(f"v.{s.name}", s.shape, "opt_v") for s in train]
    out += [("step", (1,), "step")]
    return out


def init_state(cfg: ModelConfig) -> list[np.ndarray]:
    """Initial state values in manifest order."""
    params = M.init_params(cfg)
    n_train = sum(1 for s in M.param_specs(cfg) if s.role == "train")
    trainables = params[:n_train]
    stats = params[n_train:]
    zeros = [np.zeros_like(p) for p in trainables]
    return (
        trainables
        + stats
        + zeros
        + [np.zeros_like(p) for p in trainables]
        + [np.zeros((1,), np.float32)]
    )


def make_train_step(cfg: ModelConfig, indices: list[np.ndarray], opt: AdamWConfig):
    specs = M.param_specs(cfg)
    n_train = sum(1 for s in specs if s.role == "train")
    n_stat = len(specs) - n_train

    def train_step(*args):
        t, s = n_train, n_stat
        trainables = list(args[0:t])
        stats = list(args[t : t + s])
        adam_m = list(args[t + s : 2 * t + s])
        adam_v = list(args[2 * t + s : 3 * t + s])
        step = args[3 * t + s]
        x = args[3 * t + s + 1]
        y = args[3 * t + s + 2]

        def loss_fn(trainables_):
            flat = trainables_ + stats
            logits, new_flat = M.forward(cfg, flat, indices, x, train=True)
            loss, acc = M.loss_and_acc(cfg, logits, y)
            return loss, (acc, new_flat[n_train:])

        (loss, (acc, new_stats)), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            trainables
        )
        new_train, new_m, new_v = adamw_update(
            opt, trainables, grads, adam_m, adam_v, step[0]
        )
        new_step = step + 1.0
        return tuple(
            new_train
            + new_stats
            + new_m
            + new_v
            + [new_step, loss.reshape(1), acc.reshape(1)]
        )

    return train_step


def make_eval_batch(cfg: ModelConfig, indices: list[np.ndarray], use_pallas=True):
    specs = M.param_specs(cfg)
    n_params = len(specs)

    def eval_batch(*args):
        flat = list(args[0:n_params])
        x = args[n_params]
        logits, _ = M.forward(cfg, flat, indices, x, train=False, use_pallas=use_pallas)
        return (logits,)

    return eval_batch


def arg_specs_train(cfg: ModelConfig, opt: AdamWConfig, batch: int):
    """ShapeDtypeStructs for lowering train_step."""
    specs = [
        jax.ShapeDtypeStruct(shape, jnp.float32)
        for (_, shape, _) in state_manifest(cfg, opt)
    ]
    specs.append(jax.ShapeDtypeStruct((batch, cfg.widths[0]), jnp.float32))
    specs.append(jax.ShapeDtypeStruct((batch,), jnp.int32))
    return specs


def arg_specs_eval(cfg: ModelConfig, batch: int):
    specs = [
        jax.ShapeDtypeStruct(s.shape, jnp.float32) for s in M.param_specs(cfg)
    ]
    specs.append(jax.ShapeDtypeStruct((batch, cfg.widths[0]), jnp.float32))
    return specs
