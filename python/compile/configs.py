"""Model configurations — paper Table I and Table IV presets.

A config fully determines the network geometry: layer widths, per-layer
fan-in ``F`` and input bit-width ``beta``, polynomial degree ``D`` and the
PolyLUT-Add replication factor ``A`` (``A = 1`` is plain PolyLUT;
``A = 1, D = 1`` is LogicNets).

``deeper`` / ``wider`` build the paper's Section IV-C comparison variants.
The ``*_sweep`` presets are reduced-scale twins used for the Fig. 6 accuracy
sweep on CPU (documented in DESIGN.md §4); the full-geometry presets drive
the Table II/III area and timing experiments.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class ModelConfig:
    name: str
    # widths[0] is the input feature count; widths[-1] the output neurons.
    widths: tuple[int, ...]
    # beta[l]: bit width of layer l's *input* codes (len == len(widths) - 1 + 1):
    # beta[0] = beta_in, beta[1..n_layers-1] = hidden activation bits,
    # beta[n_layers] = beta_out (output code width, signed).
    beta: tuple[int, ...]
    # fan[l]: fan-in F of layer l (len == n_layers).
    fan: tuple[int, ...]
    degree: int
    a_factor: int  # A: sub-neurons per neuron
    n_classes: int  # 1 => binary (single output neuron, threshold at 0)
    seed: int = 0

    @property
    def n_layers(self) -> int:
        return len(self.widths) - 1

    def layer_dims(self) -> list[tuple[int, int]]:
        return [(self.widths[i], self.widths[i + 1]) for i in range(self.n_layers)]

    def table_bits_poly(self, layer: int) -> int:
        """Address bits of one Poly-layer sub-neuron table: beta * F."""
        return self.beta[layer] * self.fan[layer]

    def sub_bits(self, layer: int) -> int:
        """Signed word width of a sub-neuron output feeding the Adder-layer.

        One bit wider than the layer's *output* activation width (paper
        Sec. III-A: widen to beta+1 to avoid adder overflow).
        """
        return self.beta[layer + 1] + 1

    def table_bits_adder(self, layer: int) -> int:
        """Address bits of the Adder-layer table: A * (beta + 1)."""
        return self.a_factor * self.sub_bits(layer)


def _uniform(name, widths, beta_in, beta, beta_out, fan_in, fan, degree, a, n_classes, seed=0):
    n_layers = len(widths) - 1
    betas = (beta_in,) + (beta,) * (n_layers - 1) + (beta_out,)
    fans = (fan_in,) + (fan,) * (n_layers - 1)
    return ModelConfig(
        name=name, widths=tuple(widths), beta=betas, fan=fans,
        degree=degree, a_factor=a, n_classes=n_classes, seed=seed,
    )


def deeper(cfg: ModelConfig, factor: int) -> ModelConfig:
    """PolyLUT-Deeper: replicate each hidden layer `factor` times."""
    hidden = list(cfg.widths[1:-1])
    new_hidden = [w for w in hidden for _ in range(factor)]
    widths = (cfg.widths[0], *new_hidden, cfg.widths[-1])
    n_layers = len(widths) - 1
    beta = (cfg.beta[0],) + (cfg.beta[1],) * (n_layers - 1) + (cfg.beta[-1],)
    fan = (cfg.fan[0],) + (cfg.fan[1] if cfg.n_layers > 1 else cfg.fan[0],) * (n_layers - 1)
    return replace(cfg, name=f"{cfg.name}-deep{factor}", widths=widths, beta=beta, fan=fan)


def wider(cfg: ModelConfig, factor: int) -> ModelConfig:
    """PolyLUT-Wider: multiply each hidden width by `factor`."""
    widths = (cfg.widths[0], *[w * factor for w in cfg.widths[1:-1]], cfg.widths[-1])
    return replace(cfg, name=f"{cfg.name}-wide{factor}", widths=widths)


def with_a(cfg: ModelConfig, a: int) -> ModelConfig:
    return replace(cfg, name=f"{cfg.name}-add{a}" if a > 1 else cfg.name, a_factor=a)


def with_degree(cfg: ModelConfig, d: int) -> ModelConfig:
    return replace(cfg, name=f"{cfg.name}-d{d}", degree=d)


# ---------------------------------------------------------------------------
# Paper Table I presets (full geometry; A/D varied per experiment)
# ---------------------------------------------------------------------------

def hdr(degree=1, a=1, seed=0):
    """MNIST HDR: 784 -> 256,100,100,100,100,10; beta=2, F=6."""
    return _uniform("hdr", (784, 256, 100, 100, 100, 100, 10),
                    beta_in=2, beta=2, beta_out=4, fan_in=6, fan=6,
                    degree=degree, a=a, n_classes=10, seed=seed)


def jsc_xl(degree=1, a=1, seed=0):
    """JSC-XL: 16 -> 128,64,64,64,5; beta=5, F=3 (beta_i=7, F_i=2)."""
    return _uniform("jsc-xl", (16, 128, 64, 64, 64, 5),
                    beta_in=7, beta=5, beta_out=5, fan_in=2, fan=3,
                    degree=degree, a=a, n_classes=5, seed=seed)


def jsc_m_lite(degree=1, a=1, seed=0):
    """JSC-M Lite: 16 -> 64,32,5; beta=3, F=4."""
    return _uniform("jsc-m-lite", (16, 64, 32, 5),
                    beta_in=3, beta=3, beta_out=4, fan_in=4, fan=4,
                    degree=degree, a=a, n_classes=5, seed=seed)


def nid_lite(degree=1, a=1, seed=0):
    """NID Lite: 49 -> 686,147,98,49,1; beta=3, F=5 (beta_i=1, F_i=7)."""
    return _uniform("nid-lite", (49, 686, 147, 98, 49, 1),
                    beta_in=1, beta=3, beta_out=2, fan_in=7, fan=5,
                    degree=degree, a=a, n_classes=1, seed=seed)


# ---------------------------------------------------------------------------
# Paper Table IV presets (smaller F, D=3 except NID; A=2)
# ---------------------------------------------------------------------------

def hdr_add2(seed=0):
    return _uniform("hdr-t4", (784, 256, 100, 100, 100, 100, 10),
                    beta_in=2, beta=2, beta_out=4, fan_in=4, fan=4,
                    degree=3, a=2, n_classes=10, seed=seed)


def jsc_xl_add2(seed=0):
    return _uniform("jsc-xl-t4", (16, 128, 64, 64, 64, 5),
                    beta_in=7, beta=5, beta_out=5, fan_in=1, fan=2,
                    degree=3, a=2, n_classes=5, seed=seed)


def jsc_m_lite_add2(seed=0):
    return _uniform("jsc-m-lite-t4", (16, 64, 32, 5),
                    beta_in=3, beta=3, beta_out=4, fan_in=2, fan=2,
                    degree=3, a=2, n_classes=5, seed=seed)


def nid_add2(seed=0):
    return _uniform("nid-t4", (49, 100, 100, 50, 50, 1),
                    beta_in=1, beta=2, beta_out=2, fan_in=6, fan=3,
                    degree=1, a=2, n_classes=1, seed=seed)


# ---------------------------------------------------------------------------
# Reduced-scale sweep twins (Fig. 6 accuracy runs on CPU; DESIGN.md §4)
# ---------------------------------------------------------------------------

def hdr_sweep(degree=1, a=1, seed=0):
    """HDR at 14x14 synthetic digits, thinner trunk: CPU-trainable."""
    return _uniform("hdr-sweep", (196, 128, 64, 64, 10),
                    beta_in=2, beta=2, beta_out=4, fan_in=6, fan=6,
                    degree=degree, a=a, n_classes=10, seed=seed)


def jsc_xl_sweep(degree=1, a=1, seed=0):
    return _uniform("jsc-xl-sweep", (16, 64, 32, 32, 5),
                    beta_in=7, beta=5, beta_out=5, fan_in=2, fan=3,
                    degree=degree, a=a, n_classes=5, seed=seed)


def nid_sweep(degree=1, a=1, seed=0):
    return _uniform("nid-sweep", (49, 128, 64, 32, 1),
                    beta_in=1, beta=3, beta_out=2, fan_in=7, fan=5,
                    degree=degree, a=a, n_classes=1, seed=seed)


PRESETS = {
    "hdr": hdr, "jsc-xl": jsc_xl, "jsc-m-lite": jsc_m_lite, "nid-lite": nid_lite,
    "hdr-t4": hdr_add2, "jsc-xl-t4": jsc_xl_add2, "jsc-m-lite-t4": jsc_m_lite_add2,
    "nid-t4": nid_add2,
    "hdr-sweep": hdr_sweep, "jsc-xl-sweep": jsc_xl_sweep, "nid-sweep": nid_sweep,
}

DATASET_OF = {
    "hdr": "mnist", "hdr-t4": "mnist", "hdr-sweep": "mnist14",
    "jsc-xl": "jsc", "jsc-xl-t4": "jsc", "jsc-xl-sweep": "jsc",
    "jsc-m-lite": "jsc", "jsc-m-lite-t4": "jsc",
    "nid-lite": "nid", "nid-t4": "nid", "nid-sweep": "nid",
}
