"""Pallas kernel: LUT-network layer evaluation (deployed-semantics emulation).

After the LUT compiler freezes a layer into per-neuron lookup tables, a
software evaluation of the deployed network is a pure gather:
``out[b, n] = tables[n, addr[b, n]]`` where ``addr`` packs the F input codes
into a ``beta*F``-bit address.  This is the software analogue of the FPGA LUT
fabric — tables live in VMEM (the scratchpad analogue of distributed LUT
RAM); dynamic per-element indexing replaces physical routing.

The grid tiles (batch × neurons); each program holds a ``[tn, T]`` tile of
table contents resident in VMEM and streams ``[tb, tn]`` address tiles
through it.  interpret=True as for all kernels in this repo.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .poly_neuron import AOT_FULL_BLOCK, _largest_tile


def _kernel(addr_ref, tbl_ref, out_ref):
    addr = addr_ref[...]  # [tb, tn] int32
    tbl = tbl_ref[...]  # [tn, T]
    # out[b, j] = tbl[j, addr[b, j]]  ==  take_along_axis(tbl.T, addr, axis=0)
    out_ref[...] = jnp.take_along_axis(tbl.T, addr, axis=0)


@functools.partial(jax.jit, static_argnames=("batch_tile", "neuron_tile"))
def lut_eval(
    addr: jnp.ndarray,
    tables: jnp.ndarray,
    batch_tile: int = AOT_FULL_BLOCK,
    neuron_tile: int = AOT_FULL_BLOCK,
) -> jnp.ndarray:
    """Evaluate one LUT layer: addr [B, N] int32, tables [N, T] -> [B, N]."""
    b, n = addr.shape
    n2, t = tables.shape
    assert n == n2, (addr.shape, tables.shape)
    tb = _largest_tile(b, batch_tile)
    tn = _largest_tile(n, neuron_tile)
    if (tb, tn) == (b, n):
        # grid=() — no grid loop (xla_extension 0.5.1 compatibility; see
        # poly_neuron.AOT_FULL_BLOCK).
        return pl.pallas_call(
            _kernel,
            out_shape=jax.ShapeDtypeStruct((b, n), tables.dtype),
            interpret=True,
        )(addr, tables)
    grid = (b // tb, n // tn)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, tn), lambda i, j: (i, j)),
            pl.BlockSpec((tn, t), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tb, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), tables.dtype),
        interpret=True,
    )(addr, tables)
