"""L1 — Pallas kernels for the PolyLUT-Add compute hot-spots.

``poly_neuron`` is the QAT/enumeration hot-spot (monomial expansion fused
with the weighted reduction); ``lut_eval`` is the deployed-network emulation
hot-spot (per-neuron table gather).  ``ref`` holds the pure-jnp oracles.
All kernels run ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls; see DESIGN.md §7).
"""

from .poly_neuron import poly_neuron
from .lut_eval import lut_eval
from .ref import lut_eval_ref, poly_neuron_ref

__all__ = ["poly_neuron", "lut_eval", "poly_neuron_ref", "lut_eval_ref"]
