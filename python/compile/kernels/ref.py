"""Pure-jnp oracles for the Pallas kernels.

These define the semantics the kernels must match (pytest/hypothesis compare
them under ``assert_allclose``) and are also the differentiable path used by
the training graph (Pallas interpret-mode calls are forward-only; the QAT
backward pass runs through these, which XLA fuses on its own).
"""

from __future__ import annotations

import jax.numpy as jnp

from ..monomials import monomial_index_lists


def poly_neuron_ref(xs: jnp.ndarray, w: jnp.ndarray, degree: int) -> jnp.ndarray:
    """Polynomial sub-neuron pre-activations.

    xs: [..., N, F] gathered inputs; w: [N, M] weights in canonical monomial
    order (monomials.py).  Returns [..., N] pre-activations
    ``sum_m w[n, m] * monomial_m(xs[..., n, :])``.
    """
    fan_in = xs.shape[-1]
    combos = monomial_index_lists(fan_in, degree)
    assert w.shape[-1] == len(combos), (w.shape, len(combos), fan_in, degree)
    acc = jnp.zeros(xs.shape[:-1], dtype=xs.dtype)
    for m, combo in enumerate(combos):
        term = jnp.ones(xs.shape[:-1], dtype=xs.dtype)
        for i in combo:
            term = term * xs[..., i]
        acc = acc + term * w[..., :, m]
    return acc


def lut_eval_ref(addr: jnp.ndarray, tables: jnp.ndarray) -> jnp.ndarray:
    """LUT-network layer evaluation (the software analogue of the FPGA fabric).

    addr: [B, N] int32 table addresses; tables: [N, T] per-neuron contents.
    Returns [B, N] with out[b, n] = tables[n, addr[b, n]].
    """
    return jnp.take_along_axis(tables.T, addr, axis=0)
