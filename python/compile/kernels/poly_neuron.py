"""Pallas kernel: fused monomial expansion + weighted reduction.

The PolyLUT transfer function evaluates ``M = C(F+D, D)`` monomials per
sub-neuron and reduces them against the weight vector (paper Eq. (1)).  A
naive XLA graph materializes the [B, N, M] monomial tensor in HBM; this
kernel builds each monomial in VMEM registers and accumulates in place, so
HBM traffic is just ``xs`` in / pre-activations out.

TPU mapping (DESIGN.md §7): the grid tiles (batch × neurons); each program
holds an ``[tb, tn, F]`` slab of gathered inputs and a ``[tn, M]`` weight tile
in VMEM — the BlockSpec expresses the HBM↔VMEM schedule that the FPGA
implements spatially.  The M-loop is unrolled at trace time (M ≤ 84 for every
paper config), keeping the inner body pure VPU elementwise FMA work.

interpret=True always: real-TPU lowering emits a Mosaic custom-call the CPU
PJRT plugin cannot run.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..monomials import monomial_count, monomial_index_lists


def _largest_tile(n: int, cap: int) -> int:
    """Largest divisor of n that is <= cap (keeps the grid exact)."""
    best = 1
    for t in range(1, min(n, cap) + 1):
        if n % t == 0:
            best = t
    return best


def _kernel(xs_ref, w_ref, out_ref, *, combos):
    xs = xs_ref[...]  # [tb, tn, F]
    w = w_ref[...]  # [tn, M]
    acc = jnp.zeros(xs.shape[:-1], dtype=xs.dtype)
    for m, combo in enumerate(combos):
        term = jnp.ones(xs.shape[:-1], dtype=xs.dtype)
        for i in combo:
            term = term * xs[..., i]
        acc = acc + term * w[None, :, m]
    out_ref[...] = acc


#: Default tile caps. AOT artifacts destined for the Rust PJRT runtime use
#: full-array blocks (grid 1×1): xla_extension 0.5.1 (the version the `xla`
#: crate binds) mis-executes the interpret-mode grid while-loop after the HLO
#: text round-trip — verified by the cross_check integration test.  TPU-style
#: tiling stays available through the explicit arguments and is exercised by
#: pytest/hypothesis.
AOT_FULL_BLOCK = 1 << 30


@functools.partial(jax.jit, static_argnames=("degree", "batch_tile", "neuron_tile"))
def poly_neuron(
    xs: jnp.ndarray,
    w: jnp.ndarray,
    degree: int,
    batch_tile: int = AOT_FULL_BLOCK,
    neuron_tile: int = AOT_FULL_BLOCK,
) -> jnp.ndarray:
    """Pre-activations for a layer of polynomial sub-neurons.

    xs: [B, N, F] gathered (already dequantized) inputs.
    w:  [N, M] weights, canonical monomial order.
    Returns [B, N] f32.
    """
    b, n, fan_in = xs.shape
    combos = monomial_index_lists(fan_in, degree)
    m = monomial_count(fan_in, degree)
    assert w.shape == (n, m), (w.shape, (n, m))
    tb = _largest_tile(b, batch_tile)
    tn = _largest_tile(n, neuron_tile)
    if (tb, tn) == (b, n):
        # Single full-array block: lower with grid=() so no grid while-loop
        # is emitted (required for the xla_extension 0.5.1 runtime; see
        # AOT_FULL_BLOCK above).
        return pl.pallas_call(
            functools.partial(_kernel, combos=combos),
            out_shape=jax.ShapeDtypeStruct((b, n), xs.dtype),
            interpret=True,
        )(xs, w)
    grid = (b // tb, n // tn)
    return pl.pallas_call(
        functools.partial(_kernel, combos=combos),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, tn, fan_in), lambda i, j: (i, j, 0)),
            pl.BlockSpec((tn, m), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tb, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), xs.dtype),
        interpret=True,
    )(xs, w)
