"""Monomial enumeration shared by the JAX model and (by contract) the Rust LUT
compiler.

The PolyLUT transfer function (paper Eq. (1)) is a degree-``D`` polynomial in
the ``F`` neuron inputs; its terms are the ``M = C(F + D, D)`` monomials of
degree at most ``D``.  The *order* in which monomials are enumerated defines
the layout of every weight tensor, so Python and Rust must agree exactly.

Canonical order (mirrored in ``rust/src/nn/poly.rs``):

    for d in 0..=D:
        for combo in combinations_with_replacement(0..F, d)   # lexicographic
            monomial = prod(x[i] for i in combo)

``d = 0`` yields the constant monomial ``1`` (the bias is absorbed into the
weight vector, as in the PolyLUT toolflow).
"""

from __future__ import annotations

import itertools
import math
from functools import lru_cache

import numpy as np


def monomial_count(fan_in: int, degree: int) -> int:
    """Number of monomials of degree <= `degree` in `fan_in` variables."""
    return math.comb(fan_in + degree, degree)


@lru_cache(maxsize=None)
def monomial_exponents(fan_in: int, degree: int) -> tuple[tuple[int, ...], ...]:
    """Exponent vectors, one per monomial, in the canonical order.

    Each entry is a length-``fan_in`` tuple of exponents; entry 0 is all-zero
    (the constant term).  ``len(result) == monomial_count(fan_in, degree)``.
    """
    out: list[tuple[int, ...]] = []
    for d in range(degree + 1):
        for combo in itertools.combinations_with_replacement(range(fan_in), d):
            exp = [0] * fan_in
            for i in combo:
                exp[i] += 1
            out.append(tuple(exp))
    return tuple(out)


@lru_cache(maxsize=None)
def monomial_index_lists(fan_in: int, degree: int) -> tuple[tuple[int, ...], ...]:
    """Same enumeration as index multisets (factor lists), e.g. (0, 0, 2)."""
    out: list[tuple[int, ...]] = []
    for d in range(degree + 1):
        out.extend(itertools.combinations_with_replacement(range(fan_in), d))
    return tuple(out)


def exponent_matrix(fan_in: int, degree: int) -> np.ndarray:
    """[M, F] int32 exponent matrix in canonical order."""
    return np.asarray(monomial_exponents(fan_in, degree), dtype=np.int32).reshape(
        monomial_count(fan_in, degree), fan_in
    )


def expand(x: np.ndarray, degree: int) -> np.ndarray:
    """Reference (numpy) monomial expansion.

    x: [..., F]  ->  [..., M] in canonical order.  Used only by tests and the
    pure-numpy oracle; the JAX/Pallas paths build the same expansion.
    """
    fan_in = x.shape[-1]
    cols = []
    for combo in monomial_index_lists(fan_in, degree):
        term = np.ones(x.shape[:-1], dtype=x.dtype)
        for i in combo:
            term = term * x[..., i]
        cols.append(term)
    return np.stack(cols, axis=-1)
