"""AdamW with cosine-decay warmup, hand-rolled (optax is not installed).

Operates on flat lists of arrays so the whole optimizer state round-trips
through the AOT boundary as plain device buffers (see aot.py / the Rust
training driver).  Hyperparameters are baked into the lowered train_step.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 2e-3
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 1e-4
    warmup_steps: int = 20
    total_steps: int = 1000
    min_lr_frac: float = 0.05


def schedule(opt: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup then cosine decay to min_lr_frac * lr."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(opt.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - opt.warmup_steps) / jnp.maximum(opt.total_steps - opt.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    frac = opt.min_lr_frac + (1.0 - opt.min_lr_frac) * cos
    return opt.lr * warm * frac


def adamw_update(
    opt: AdamWConfig,
    params: list,
    grads: list,
    m: list,
    v: list,
    step: jnp.ndarray,
):
    """One AdamW step over flat lists. Returns (params', m', v')."""
    lr = schedule(opt, step)
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - opt.beta1**t
    bc2 = 1.0 - opt.beta2**t
    new_p, new_m, new_v = [], [], []
    for p, g, mi, vi in zip(params, grads, m, v):
        mi2 = opt.beta1 * mi + (1.0 - opt.beta1) * g
        vi2 = opt.beta2 * vi + (1.0 - opt.beta2) * (g * g)
        mhat = mi2 / bc1
        vhat = vi2 / bc2
        upd = mhat / (jnp.sqrt(vhat) + opt.eps) + opt.weight_decay * p
        new_p.append(p - lr * upd)
        new_m.append(mi2)
        new_v.append(vi2)
    return new_p, new_m, new_v
