"""L2 — the PolyLUT / PolyLUT-Add model in JAX (build-time only).

Datapath per layer (paper Fig. 1(b) / Fig. 3):

    codes(beta) --gather F per sub-neuron--> poly transfer (degree D)
      --> signed quant to beta+1 bits (shared per-layer scale)   [Poly-layer]
      --> sum over the A sub-neurons --> batch-norm --> ReLU
      --> unsigned quant to beta bits                            [Adder-layer]

``A = 1`` degenerates to PolyLUT (BN folded before the activation, same
math); ``A = 1, D = 1`` is LogicNets.  All quantizers are STE
(quant.py) and every constant of the deployed datapath (indices, scales, BN
affine) is exported so the Rust LUT compiler can enumerate bit-exact tables.

Parameters are kept as a *flat ordered list* of named arrays — the AOT
contract with the Rust training driver (aot.py writes the name/shape/role
manifest; Rust treats the list as opaque device buffers between steps).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from . import quant
from .configs import ModelConfig
from .kernels import poly_neuron, poly_neuron_ref
from .monomials import monomial_count

BN_EPS = 1e-5
BN_MOMENTUM = 0.9  # running = mom * running + (1 - mom) * batch
SCALE_FLOOR = 1e-3  # scale params pass through |.| + floor (rust mirrors this)


@dataclasses.dataclass
class ParamSpec:
    name: str
    shape: tuple[int, ...]
    role: str  # "train" | "stat"


def scale_of(p: jnp.ndarray) -> jnp.ndarray:
    """Positive scale from an unconstrained parameter (mirrored in Rust)."""
    return jnp.abs(p) + SCALE_FLOOR


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def make_indices(cfg: ModelConfig) -> list[np.ndarray]:
    """Random sparse connectivity: per layer an int32 [A, n_out, F] array.

    Each sub-neuron draws F *distinct* inputs from the previous layer
    (uniform, without replacement), as in LogicNets/PolyLUT.  Deterministic
    in cfg.seed; exported to the meta manifest for the Rust side.
    """
    rng = np.random.default_rng(cfg.seed + 0xC0FFEE)
    out = []
    for li, (n_in, n_out) in enumerate(cfg.layer_dims()):
        fan = cfg.fan[li]
        idx = np.empty((cfg.a_factor, n_out, fan), dtype=np.int32)
        for a in range(cfg.a_factor):
            for j in range(n_out):
                idx[a, j] = rng.choice(n_in, size=fan, replace=False)
        out.append(idx)
    return out


def param_specs(cfg: ModelConfig) -> list[ParamSpec]:
    """Flat parameter manifest: trainables first, then BN running stats."""
    train: list[ParamSpec] = []
    stats: list[ParamSpec] = []
    for li, (_, n_out) in enumerate(cfg.layer_dims()):
        m = monomial_count(cfg.fan[li], cfg.degree)
        train += [
            ParamSpec(f"l{li}.w", (cfg.a_factor, n_out, m), "train"),
            ParamSpec(f"l{li}.s_pre", (1,), "train"),
            ParamSpec(f"l{li}.s_act", (1,), "train"),
            ParamSpec(f"l{li}.bn_g", (n_out,), "train"),
            ParamSpec(f"l{li}.bn_b", (n_out,), "train"),
        ]
        stats += [
            ParamSpec(f"l{li}.bn_m", (n_out,), "stat"),
            ParamSpec(f"l{li}.bn_v", (n_out,), "stat"),
        ]
    return train + stats


def init_params(cfg: ModelConfig) -> list[np.ndarray]:
    """Initial values in manifest order (numpy, f32)."""
    rng = np.random.default_rng(cfg.seed + 0x5EED)
    vals: list[np.ndarray] = []
    for spec in param_specs(cfg):
        kind = spec.name.split(".")[1]
        if kind == "w":
            a, n, m = spec.shape
            w = rng.normal(0.0, 1.0 / np.sqrt(m), size=spec.shape)
            vals.append(w.astype(np.float32))
        elif kind == "s_pre":
            vals.append(np.full(spec.shape, 2.0, dtype=np.float32))
        elif kind == "s_act":
            vals.append(np.full(spec.shape, 2.0, dtype=np.float32))
        elif kind in ("bn_g",):
            vals.append(np.ones(spec.shape, dtype=np.float32))
        elif kind in ("bn_b", "bn_m"):
            vals.append(np.zeros(spec.shape, dtype=np.float32))
        elif kind == "bn_v":
            vals.append(np.ones(spec.shape, dtype=np.float32))
        else:  # pragma: no cover
            raise ValueError(spec.name)
    return vals


def split_flat(cfg: ModelConfig, flat: list) -> tuple[list[dict], int]:
    """Flat list -> per-layer dicts. Returns (layers, n_train_tensors)."""
    n_layers = cfg.n_layers
    layers = [dict() for _ in range(n_layers)]
    i = 0
    for li in range(n_layers):
        for k in ("w", "s_pre", "s_act", "bn_g", "bn_b"):
            layers[li][k] = flat[i]
            i += 1
    n_train = i
    for li in range(n_layers):
        for k in ("bn_m", "bn_v"):
            layers[li][k] = flat[i]
            i += 1
    assert i == len(flat), (i, len(flat))
    return layers, n_train


def join_flat(cfg: ModelConfig, layers: list[dict]) -> list:
    flat = []
    for li in range(cfg.n_layers):
        for k in ("w", "s_pre", "s_act", "bn_g", "bn_b"):
            flat.append(layers[li][k])
    for li in range(cfg.n_layers):
        for k in ("bn_m", "bn_v"):
            flat.append(layers[li][k])
    return flat


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def forward(
    cfg: ModelConfig,
    flat_params: list,
    indices: list[np.ndarray],
    x: jnp.ndarray,
    train: bool,
    use_pallas: bool = False,
):
    """Run the network.

    x: [B, n_in] raw features in [0, 1].
    Returns (logits [B, n_out] dequantized, new_flat_params) — in eval mode
    the params pass through unchanged.
    """
    layers, _ = split_flat(cfg, flat_params)
    vals = quant.quantize_input(x.astype(jnp.float32), cfg.beta[0])
    new_layers = []
    for li, p in enumerate(layers):
        idx = jnp.asarray(indices[li])  # [A, n_out, F]
        a, n_out, fan = idx.shape
        # Gather sub-neuron inputs: [B, A, n_out, F]
        xs = vals[:, idx]
        if use_pallas:
            pre = poly_neuron(
                xs.reshape(xs.shape[0], a * n_out, fan),
                p["w"].reshape(a * n_out, -1),
                cfg.degree,
            ).reshape(xs.shape[0], a, n_out)
        else:
            pre = poly_neuron_ref(xs, p["w"], cfg.degree)  # [B, A, n_out]
        # Poly-layer output: signed (beta+1)-bit quant, shared scale.
        preq = quant.quant_signed(pre, cfg.sub_bits(li), scale_of(p["s_pre"]))
        z = preq.sum(axis=1)  # Adder: [B, n_out]
        # Batch norm (after the adder — paper Fig. 1(b)).
        if train:
            mu = z.mean(axis=0)
            var = z.var(axis=0)
            new_m = BN_MOMENTUM * p["bn_m"] + (1.0 - BN_MOMENTUM) * mu
            new_v = BN_MOMENTUM * p["bn_v"] + (1.0 - BN_MOMENTUM) * var
        else:
            mu, var = p["bn_m"], p["bn_v"]
            new_m, new_v = p["bn_m"], p["bn_v"]
        zn = (z - mu) / jnp.sqrt(var + BN_EPS) * p["bn_g"] + p["bn_b"]
        last = li == cfg.n_layers - 1
        if last:
            # Output codes: signed beta_out-bit quant of the BN output.
            vals = quant.quant_signed(zn, cfg.beta[li + 1], scale_of(p["s_act"]))
        else:
            act = jax.nn.relu(zn)
            vals = quant.quant_unsigned(act, cfg.beta[li + 1], scale_of(p["s_act"]))
        q = dict(p)
        q["bn_m"], q["bn_v"] = new_m, new_v
        new_layers.append(q)
    return vals, join_flat(cfg, new_layers)


# ---------------------------------------------------------------------------
# Loss / metrics
# ---------------------------------------------------------------------------

def loss_and_acc(cfg: ModelConfig, logits: jnp.ndarray, y: jnp.ndarray):
    """Cross-entropy (softmax or sigmoid for single-output binary) + accuracy.

    Quantized logits have few discrete levels; a fixed temperature sharpens
    the softmax so gradients stay informative (STE passes them to the weights).
    """
    temp = 4.0
    if cfg.n_classes == 1:
        logit = logits[:, 0] * temp
        yf = y.astype(jnp.float32)
        loss = jnp.mean(
            jnp.maximum(logit, 0.0) - logit * yf + jnp.log1p(jnp.exp(-jnp.abs(logit)))
        )
        acc = jnp.mean((logit > 0.0) == (yf > 0.5))
    else:
        lg = logits * temp
        lse = jax.scipy.special.logsumexp(lg, axis=-1)
        nll = lse - jnp.take_along_axis(lg, y[:, None].astype(jnp.int32), axis=-1)[:, 0]
        loss = jnp.mean(nll)
        acc = jnp.mean(jnp.argmax(lg, axis=-1) == y)
    return loss, acc
