"""AOT lowering: JAX -> HLO text artifacts + metadata manifests.

Python runs exactly once (``make artifacts``); the Rust binary is then
self-contained.  Interchange is HLO **text**, not serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version the published ``xla`` crate binds) rejects; the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Per configuration we emit:

    artifacts/<id>.train.hlo.txt   one optimizer step (state in, state out)
    artifacts/<id>.eval.hlo.txt    batched inference (Pallas fast path)
    artifacts/<id>.meta.json       config, connectivity, monomial order,
                                   state manifest + init values, opt config

Usage: python -m compile.aot --out-dir ../artifacts [--set all|fig6|table4|quickstart]
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from . import configs as C
from . import train as T
from .configs import ModelConfig
from .model import make_indices
from .monomials import monomial_index_lists
from .optim import AdamWConfig


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (the interchange format).

    ``as_hlo_text(True)`` == print_large_constants: the default printer
    elides big dense literals as ``constant({...})``, which xla_extension
    0.5.1's text parser silently turns into garbage (all-zero f32 /
    saturated s32) — the model's frozen connectivity tables then gather
    nonsense.  Found the hard way; exercised by rust/tests/cross_check.rs.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(True)


def artifact_id(cfg: ModelConfig) -> str:
    return f"{cfg.name}-d{cfg.degree}-a{cfg.a_factor}"


def dataset_of(cfg: ModelConfig) -> str:
    base = cfg.name.split("-")[0]
    if base == "hdr":
        return "mnist"
    if base == "jsc":
        return "jsc"
    return "nid"


def batch_of(cfg: ModelConfig) -> int:
    return 128 if dataset_of(cfg) == "mnist" else 512


def fig6_set() -> list[ModelConfig]:
    """Full-geometry configs behind Fig. 6 / Table II (paper Sec. IV-C/D)."""
    out: list[ModelConfig] = []
    for mk, a_values in ((C.hdr, (2, 3)), (C.jsc_xl, (2,)), (C.jsc_m_lite, (2, 3))):
        for d in (1, 2):
            base = mk(degree=d, a=1)
            out.append(base)
            out.append(C.deeper(base, 2))
            out.append(C.wider(base, 2))
            out.extend(mk(degree=d, a=a) for a in a_values)
    nid = C.nid_lite(degree=1, a=1)
    out += [nid, C.deeper(nid, 2), C.wider(nid, 2), C.nid_lite(degree=1, a=2)]
    return out


def table4_set() -> list[ModelConfig]:
    return [C.hdr_add2(), C.jsc_xl_add2(), C.jsc_m_lite_add2(), C.nid_add2()]


def quickstart_set() -> list[ModelConfig]:
    return [C.jsc_m_lite(degree=1, a=1), C.jsc_m_lite(degree=1, a=2)]


def config_set(name: str) -> list[ModelConfig]:
    if name == "quickstart":
        sets = quickstart_set()
    elif name == "fig6":
        sets = fig6_set()
    elif name == "table4":
        sets = table4_set()
    elif name == "all":
        sets = fig6_set() + table4_set() + quickstart_set()
    else:
        raise SystemExit(f"unknown --set {name!r}")
    seen, out = set(), []
    for cfg in sets:
        aid = artifact_id(cfg)
        if aid not in seen:
            seen.add(aid)
            out.append(cfg)
    return out


def emit_config(cfg: ModelConfig, out_dir: str, eval_batch: int = 256, force=False):
    aid = artifact_id(cfg)
    meta_path = os.path.join(out_dir, f"{aid}.meta.json")
    train_path = os.path.join(out_dir, f"{aid}.train.hlo.txt")
    eval_path = os.path.join(out_dir, f"{aid}.eval.hlo.txt")
    if (
        not force
        and all(os.path.exists(p) for p in (meta_path, train_path, eval_path))
    ):
        print(f"[aot] {aid}: up to date")
        return

    opt = AdamWConfig()
    batch = batch_of(cfg)
    indices = make_indices(cfg)

    step_fn = T.make_train_step(cfg, indices, opt)
    lowered = jax.jit(step_fn).lower(*T.arg_specs_train(cfg, opt, batch))
    with open(train_path, "w") as f:
        f.write(to_hlo_text(lowered))

    eval_fn = T.make_eval_batch(cfg, indices, use_pallas=True)
    lowered_e = jax.jit(eval_fn).lower(*T.arg_specs_eval(cfg, eval_batch))
    with open(eval_path, "w") as f:
        f.write(to_hlo_text(lowered_e))

    manifest = T.state_manifest(cfg, opt)
    init = T.init_state(cfg)
    meta = {
        "id": aid,
        "dataset": dataset_of(cfg),
        "batch": batch,
        "eval_batch": eval_batch,
        "config": {
            "name": cfg.name,
            "widths": list(cfg.widths),
            "beta": list(cfg.beta),
            "fan": list(cfg.fan),
            "degree": cfg.degree,
            "a_factor": cfg.a_factor,
            "n_classes": cfg.n_classes,
            "seed": cfg.seed,
        },
        "indices": [idx.tolist() for idx in indices],
        "monomials": [
            [list(c) for c in monomial_index_lists(cfg.fan[li], cfg.degree)]
            for li in range(cfg.n_layers)
        ],
        "state": [
            {"name": n, "shape": list(s), "role": r} for (n, s, r) in manifest
        ],
        "init": [np.asarray(v).reshape(-1).astype(float).tolist() for v in init],
        "opt": {
            "lr": opt.lr,
            "beta1": opt.beta1,
            "beta2": opt.beta2,
            "eps": opt.eps,
            "weight_decay": opt.weight_decay,
            "warmup_steps": opt.warmup_steps,
            "total_steps": opt.total_steps,
            "min_lr_frac": opt.min_lr_frac,
        },
        "artifacts": {
            "train": os.path.basename(train_path),
            "eval": os.path.basename(eval_path),
        },
    }
    with open(meta_path, "w") as f:
        json.dump(meta, f)
    print(f"[aot] {aid}: wrote train/eval/meta")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--set", dest="set_name", default="all")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--eval-batch", type=int, default=256)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    cfgs = config_set(args.set_name)
    print(f"[aot] lowering {len(cfgs)} configurations -> {args.out_dir}")
    for i, cfg in enumerate(cfgs):
        print(f"[aot] ({i + 1}/{len(cfgs)}) {artifact_id(cfg)}", flush=True)
        emit_config(cfg, args.out_dir, eval_batch=args.eval_batch, force=args.force)
    # Marker for `make` staleness tracking.
    with open(os.path.join(args.out_dir, ".stamp"), "w") as f:
        f.write("ok\n")


if __name__ == "__main__":
    main()
