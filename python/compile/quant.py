"""Quantization-aware-training primitives (straight-through estimators).

The PolyLUT-Add datapath (paper Fig. 1(b)) has three quantization points:

1. **Input features**: unsigned ``beta_in``-bit codes over a min-max
   normalized [0, 1] range.
2. **Sub-neuron pre-activations** (Poly-layer outputs): *signed*
   ``beta + 1``-bit codes with a learnable per-layer scale — the one-bit word
   growth the paper introduces so the Adder-layer cannot overflow.
3. **Neuron activations** (Adder-layer outputs, after BN + ReLU): unsigned
   ``beta``-bit codes with a learnable per-layer scale.

Every quantizer is exactly reproducible in integer/fixed-point form: codes are
what the generated lookup tables index on, values = code * step are what the
polynomial arithmetic consumes.  The Rust hardware-functional model
(``rust/src/nn/quant.rs``) mirrors these formulas bit-for-bit in f32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ste_round(x: jnp.ndarray) -> jnp.ndarray:
    """round-to-nearest-even with identity gradient (straight-through)."""
    return x + jax.lax.stop_gradient(jnp.round(x) - x)


def quant_unsigned(x: jnp.ndarray, bits: int, scale: jnp.ndarray) -> jnp.ndarray:
    """Unsigned uniform quantizer over [0, scale] with 2**bits levels.

    Returns the *dequantized value* (code * step).  Gradients flow to both
    ``x`` and ``scale`` via STE.  code = clip(round(x / step), 0, 2**bits - 1).
    """
    levels = (1 << bits) - 1
    step = scale / levels
    code = jnp.clip(ste_round(x / step), 0.0, float(levels))
    return code * step


def quant_signed(x: jnp.ndarray, bits: int, scale: jnp.ndarray) -> jnp.ndarray:
    """Symmetric signed quantizer: codes in [-(2**(bits-1)), 2**(bits-1) - 1].

    ``scale`` maps to the positive full-scale value.  Returns dequantized
    values; the negative rail has one extra code (two's complement), matching
    the hardware adder word.
    """
    pos = (1 << (bits - 1)) - 1
    neg = -(1 << (bits - 1))
    step = scale / pos
    code = jnp.clip(ste_round(x / step), float(neg), float(pos))
    return code * step


def unsigned_code(x: jnp.ndarray, bits: int, scale: jnp.ndarray) -> jnp.ndarray:
    """Integer code for `quant_unsigned` (no STE; inference/table path)."""
    levels = (1 << bits) - 1
    step = scale / levels
    return jnp.clip(jnp.round(x / step), 0.0, float(levels)).astype(jnp.int32)


def signed_code(x: jnp.ndarray, bits: int, scale: jnp.ndarray) -> jnp.ndarray:
    """Integer code for `quant_signed` (no STE; inference/table path)."""
    pos = (1 << (bits - 1)) - 1
    neg = -(1 << (bits - 1))
    step = scale / pos
    return jnp.clip(jnp.round(x / step), float(neg), float(pos)).astype(jnp.int32)


def quantize_input(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Quantize raw [0, 1] features to `bits`-bit codes' dequantized values.

    Fixed unit scale: the data pipeline min-max normalizes features first.
    """
    return quant_unsigned(x, bits, jnp.float32(1.0))
