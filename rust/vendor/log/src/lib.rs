//! Minimal in-repo equivalent of the `log` facade (offline image; no
//! registry).  The workspace only emits `error!` / `warn!` (plus occasional
//! `info!` / `debug!` / `trace!`); messages go straight to stderr with a
//! level prefix.  `RUST_LOG=off` silences everything; `RUST_LOG=debug` /
//! `RUST_LOG=trace` enable the verbose levels.

/// Log levels, ordered by severity.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

/// Is `level` enabled under the `RUST_LOG` environment variable?
/// Default (unset): Error/Warn/Info on, Debug/Trace off.
pub fn enabled(level: Level) -> bool {
    let max = match std::env::var("RUST_LOG").ok().as_deref() {
        Some("off") | Some("none") => return false,
        Some("error") => Level::Error,
        Some("warn") => Level::Warn,
        Some("debug") => Level::Debug,
        Some("trace") => Level::Trace,
        _ => Level::Info,
    };
    level <= max
}

#[doc(hidden)]
pub fn __log(level: Level, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{tag}] {args}");
    }
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { $crate::__log($crate::Level::Error, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => { $crate::__log($crate::Level::Warn, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::__log($crate::Level::Info, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::__log($crate::Level::Debug, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)*) => { $crate::__log($crate::Level::Trace, format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_levels() {
        // Without RUST_LOG set the severe levels are on, verbose off.
        if std::env::var("RUST_LOG").is_err() {
            assert!(enabled(Level::Error));
            assert!(enabled(Level::Warn));
            assert!(!enabled(Level::Trace));
        }
    }

    #[test]
    fn macros_do_not_panic() {
        error!("e {}", 1);
        warn!("w {}", 2);
        info!("i {}", 3);
        debug!("d {}", 4);
        trace!("t {}", 5);
    }
}
