//! Minimal in-repo equivalent of the `anyhow` crate (the deployment image
//! vendors no crates.io registry).  Provides the subset this workspace uses:
//! [`Error`] with a context chain, [`Result`], the [`Context`] extension
//! trait, and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! Display semantics mirror anyhow: `{}` prints the outermost message,
//! `{:#}` prints the whole chain joined by `": "`, and `{:?}` prints the
//! chain as a "Caused by" list.

use std::fmt;

/// A dynamic error: an outermost message plus the chain of underlying
/// messages (most recent context first).
pub struct Error {
    /// chain[0] is the outermost message; later entries are causes.
    chain: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Wrap with an outer context message (used by [`Context`]).
    pub fn context(mut self, c: impl fmt::Display) -> Error {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The chain of messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The root (innermost) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

// Like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error` — that is what makes the blanket `From` below coherent.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::msg(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::msg(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/path")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn context_chains_and_alternate_display() {
        let e: Error = io_fail().context("reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        let full = format!("{e:#}");
        assert!(full.starts_with("reading config: "));
        assert!(e.chain().count() >= 2);
    }

    #[test]
    fn macros_work() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x > 100 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(f(-1).unwrap_err().to_string(), "negative: -1");
        assert_eq!(f(101).unwrap_err().to_string(), "too big: 101");
        let e = anyhow!("plain {}", 7);
        assert_eq!(e.root_cause(), "plain 7");
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(format!("{e}"), "missing");
    }
}
