//! API-compatible stub of the `xla` (PJRT) bindings.
//!
//! The deployment image does not carry the native `xla_extension` shared
//! library, so the PJRT entry points ([`PjRtClient::cpu`],
//! [`HloModuleProto::from_text_file`]) return a descriptive
//! "runtime unavailable" error.  Everything downstream of client creation is
//! therefore unreachable at runtime but fully typed, which keeps the
//! coordinator's PJRT backend, the training driver and the PJRT benches
//! compiling unchanged; PJRT-dependent tests are artifact-gated and skip.
//!
//! Host-side [`Literal`] handling (build / reshape / read back) is *real*,
//! not stubbed — the runtime marshalling helpers and their tests rely on it.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

/// Stub error type (implements `std::error::Error`, unlike anyhow's).
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    pub fn new(msg: impl fmt::Display) -> Error {
        Error(msg.to_string())
    }

    fn unavailable(what: &str) -> Error {
        Error(format!(
            "{what}: PJRT/XLA native runtime is not vendored in this image \
             (stub xla crate); use the LUT backend or run on an image with \
             xla_extension installed"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

// ---- element types and shapes ----------------------------------------------

/// Element types this workspace marshals (f32 tensors and i32 label vectors).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// Dense array shape: element type + dimensions.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArrayShape {
    ty: ElementType,
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn element_type(&self) -> ElementType {
        self.ty
    }
}

/// XLA shape: an array or a tuple of shapes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Shape {
    Array(ArrayShape),
    Tuple(Vec<Shape>),
}

// ---- literals ---------------------------------------------------------------

#[derive(Debug, Clone, PartialEq)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Data {
    fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }

    fn ty(&self) -> ElementType {
        match self {
            Data::F32(_) => ElementType::F32,
            Data::I32(_) => ElementType::S32,
        }
    }
}

/// Host-side dense literal (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

/// Sealed-ish conversion trait for the element types [`Literal`] stores.
pub trait NativeType: Copy {
    fn wrap(v: Vec<Self>) -> Data;
    fn unwrap(d: &Data) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(v: Vec<Self>) -> Data {
        Data::F32(v)
    }
    fn unwrap(d: &Data) -> Option<Vec<Self>> {
        match d {
            Data::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(v: Vec<Self>) -> Data {
        Data::I32(v)
    }
    fn unwrap(d: &Data) -> Option<Vec<Self>> {
        match d {
            Data::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], data: T::wrap(data.to_vec()) }
    }

    /// Reinterpret under new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want != self.data.len() as i64 {
            return Err(Error::new(format!(
                "reshape: {} elements cannot take shape {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Read the elements back (row-major).
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data)
            .ok_or_else(|| Error::new("to_vec: element type mismatch"))
    }

    pub fn element_count(&self) -> usize {
        self.data.len()
    }

    pub fn shape(&self) -> Result<Shape> {
        Ok(Shape::Array(self.array_shape()?))
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { ty: self.data.ty(), dims: self.dims.clone() })
    }

    /// Host literals in this stub are never tuples.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(Error::new("decompose_tuple: not a tuple literal"))
    }
}

// ---- PJRT surface (stubbed) -------------------------------------------------

/// Stub PJRT client: construction reports the missing native runtime.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        Err(Error::unavailable("buffer_from_host_literal"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("compile"))
    }
}

/// Stub device buffer (unreachable without a client).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("to_literal_sync"))
    }
}

/// Stub loaded executable (unreachable without a client).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T: Borrow<Literal>>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("execute"))
    }

    pub fn execute_b<T: Borrow<PjRtBuffer>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("execute_b"))
    }
}

/// Stub HLO module proto: text parsing needs the native parser.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: &Path) -> Result<HloModuleProto> {
        Err(Error::unavailable(&format!(
            "HloModuleProto::from_text_file({})",
            path.display()
        )))
    }
}

/// Stub computation wrapper.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 2]);
        assert!(l.reshape(&[3, 2]).is_err());
        assert!(r.to_vec::<i32>().is_err(), "type mismatch must error");
    }

    #[test]
    fn literal_roundtrip_i32() {
        let l = Literal::vec1(&[5i32, -6]);
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![5, -6]);
        match l.shape().unwrap() {
            Shape::Array(a) => assert_eq!(a.element_type(), ElementType::S32),
            Shape::Tuple(_) => panic!("rank-1 literal is not a tuple"),
        }
    }

    #[test]
    fn pjrt_reports_unavailable() {
        let e = PjRtClient::cpu().err().expect("stub must not create clients");
        assert!(e.to_string().contains("not vendored"));
        assert!(HloModuleProto::from_text_file(Path::new("/tmp/x.hlo")).is_err());
    }
}
