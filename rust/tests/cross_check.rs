//! Cross-language integration tests: the Rust hardware-functional model must
//! agree with the JAX eval graph (via PJRT) on trained weights.

// Integration tests are a separate crate: clippy's allow-unwrap-in-tests
// doesn't reach them, so the workspace unwrap_used deny is lifted per-file.
#![allow(clippy::unwrap_used)]

use std::path::Path;

use polylut_add::{data, meta, runtime, train};

fn artifacts() -> Option<std::path::PathBuf> {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    p.join("jsc-m-lite-d1-a2.meta.json").exists().then_some(p)
}

#[test]
fn rust_network_matches_jax_eval_graph() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let man = meta::load_id(&dir, "jsc-m-lite-d1-a2").unwrap();
    let engine = runtime::Engine::cpu().unwrap();
    let ds = data::load(&man.dataset, 0).unwrap();
    // Train briefly (or reuse weights) so the comparison uses non-trivial state.
    let opts = train::TrainOptions { steps: 60, ..Default::default() };
    let (state, _) = train::train_or_load(&engine, &man, &ds, &opts).unwrap();
    let net = man.network_from_state(&state).unwrap();

    // PJRT eval on one batch.
    let exe = engine.load_hlo(&man.eval_hlo).unwrap();
    let b = man.eval_batch;
    let mut args = Vec::new();
    // eval graph takes trainables + bn stats (first len(param_specs) tensors).
    let n_params = man.state.iter().filter(|s| matches!(s.role, meta::Role::Train | meta::Role::Stat)).count();
    for (spec, vals) in man.state.iter().zip(&state).take(n_params) {
        let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
        args.push(runtime::f32_literal(vals, &dims).unwrap());
    }
    let mut x = Vec::with_capacity(b * ds.n_features);
    for i in 0..b {
        x.extend_from_slice(ds.test_row(i));
    }
    args.push(runtime::f32_literal(&x, &[b as i64, ds.n_features as i64]).unwrap());
    let outs = exe.run(&args).unwrap();
    assert_eq!(outs.len(), 1, "eval graph returns logits only");
    let logits = runtime::to_f32_vec(&outs[0]).unwrap();
    let n_out = man.config.widths[man.config.n_layers()];
    assert_eq!(logits.len(), b * n_out);

    // Rust fixed-point forward must match to float tolerance, and argmax
    // must agree on effectively every sample (ties at quantization
    // boundaries may flip argmax when two logits are equal).
    let mut mismatch = 0usize;
    for i in 0..b {
        let ours = net.forward(ds.test_row(i));
        let jax = &logits[i * n_out..(i + 1) * n_out];
        for (k, (&a, &b_)) in ours.iter().zip(jax).enumerate() {
            assert!(
                (a - b_).abs() <= 2e-3 * (1.0 + b_.abs()),
                "sample {i} logit {k}: rust {a} vs jax {b_}"
            );
        }
        let am_r = polylut_add::util::argmax_f32(&ours);
        let am_j = polylut_add::util::argmax_f32(jax);
        if am_r != am_j {
            mismatch += 1;
        }
    }
    assert!(mismatch <= b / 100, "argmax mismatch on {mismatch}/{b} samples");
}
