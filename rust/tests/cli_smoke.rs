//! CLI smoke tests — run the `polylut` binary end to end (requires
//! quickstart artifacts; skips otherwise).

// Integration tests are a separate crate: clippy's allow-unwrap-in-tests
// doesn't reach them, so the workspace unwrap_used deny is lifted per-file.
#![allow(clippy::unwrap_used)]

use std::path::Path;
use std::process::Command;

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_polylut")
}

fn have_artifacts() -> bool {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/jsc-m-lite-d1-a1.meta.json")
        .exists()
}

fn run(args: &[&str]) -> (bool, String) {
    run_in(Path::new(env!("CARGO_MANIFEST_DIR")), args)
}

fn run_in(dir: &Path, args: &[&str]) -> (bool, String) {
    let out = Command::new(bin())
        .args(args)
        .current_dir(dir)
        .output()
        .expect("spawn polylut");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

#[test]
fn help_lists_subcommands() {
    let (ok, text) = run(&["--help"]);
    assert!(ok);
    for sub in ["train", "compile", "synth", "rtl", "serve", "list", "verify"] {
        assert!(text.contains(sub), "missing {sub} in help");
    }
}

/// `polylut verify` end to end on a random-weight geometry: needs no
/// artifacts, checks all four artifact kinds, exits zero with every
/// section OK.
#[test]
fn verify_runs_clean_on_random_geometry() {
    let (ok, text) = run(&[
        "verify", "--widths", "8,6,5,3", "--net-seed", "11", "--a", "2", "--shards", "3",
    ]);
    assert!(ok, "{text}");
    for section in ["plan", "bitslice op-streams", "shard op-streams", "hazard schedules", "wire plans"]
    {
        assert!(text.contains(section), "missing section {section:?} in:\n{text}");
    }
    assert!(text.contains("0 violation(s)"), "{text}");
    assert!(!text.to_lowercase().contains("panicked"), "{text}");
}

#[test]
fn verify_without_model_fails_with_usage() {
    let (ok, text) = run(&["verify"]);
    assert!(!ok);
    assert!(text.contains("--id") && text.contains("--widths"), "{text}");
}

#[test]
fn unknown_subcommand_fails() {
    let (ok, text) = run(&["frobnicate"]);
    assert!(!ok);
    assert!(text.contains("unknown subcommand"));
}

#[test]
fn list_shows_artifacts() {
    if !have_artifacts() {
        return;
    }
    let (ok, text) = run(&["list"]);
    assert!(ok, "{text}");
    assert!(text.contains("jsc-m-lite-d1-a1"));
    assert!(text.contains("dataset"));
}

#[test]
fn train_then_synth_roundtrip() {
    if !have_artifacts() {
        return;
    }
    // Scratch artifacts dir so the 30-step checkpoint never clobbers the
    // bench caches in the real artifacts/ directory.
    let scratch = std::env::temp_dir().join("polylut_cli_scratch");
    let _ = std::fs::remove_dir_all(&scratch);
    std::fs::create_dir_all(scratch.join("artifacts")).unwrap();
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    for f in [
        "jsc-m-lite-d1-a1.meta.json",
        "jsc-m-lite-d1-a1.train.hlo.txt",
        "jsc-m-lite-d1-a1.eval.hlo.txt",
    ] {
        std::fs::copy(src.join(f), scratch.join("artifacts").join(f)).unwrap();
    }
    let (ok, text) = run_in(&scratch, &["train", "--id", "jsc-m-lite-d1-a1", "--steps", "30"]);
    assert!(ok, "{text}");
    assert!(text.contains("deployed test acc"));
    let (ok, text) = run_in(&scratch, &["synth", "--id", "jsc-m-lite-d1-a1", "--strategy", "1"]);
    assert!(ok, "{text}");
    assert!(text.contains("F_max"));
    let (ok, text) =
        run_in(&scratch, &["rtl", "--id", "jsc-m-lite-d1-a1", "--out", "/tmp/polylut_cli_rtl"]);
    assert!(ok, "{text}");
    assert!(text.contains("Verilog"));
}
