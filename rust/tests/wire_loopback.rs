//! Integration: multi-node shard handoff over real loopback TCP with real
//! `polylut shard-worker` **processes** (not in-process hosts — those are
//! covered by the `sim::wire` unit tests).  Two workers are spawned from
//! the built binary, each compiles the same random-weight model from the
//! same CLI spec, and a mixed local/remote `ShardedModel` on the test side
//! must be bit-exact against `Network::forward_codes` on both the plan and
//! bitslice routes.

// Integration tests are a separate crate: clippy's allow-unwrap-in-tests
// doesn't reach them, so the workspace unwrap_used deny is lifted per-file.
#![allow(clippy::unwrap_used)]

use std::io::{BufRead, BufReader};
use std::process::{Child, Command, Stdio};

use polylut_add::nn::config;
use polylut_add::nn::network::Network;
use polylut_add::sim::{ShardPlacement, ShardedModel, WireConfig, WORD};
use polylut_add::util::rng::Rng;

/// Model geometry shared between the test and the worker CLI args — any
/// drift fails the fingerprint handshake, which is itself part of what
/// this test exercises.
const WIDTHS: &[usize] = &[8, 6, 3];
const NET_SEED: u64 = 0xB17;

fn test_net(a: usize, degree: u32) -> Network {
    let cfg = config::uniform("wire-proc", WIDTHS, 2, 2, 3, 3, 3, degree, a, 3);
    Network::random(&cfg, &mut Rng::new(NET_SEED))
}

struct Worker {
    child: Child,
    addr: String,
}

impl Worker {
    /// Spawn `polylut shard-worker` on a free loopback port and parse the
    /// bound address from its first stdout line.
    fn spawn(a: usize, degree: u32, shards: usize) -> Worker {
        Self::spawn_at("127.0.0.1:0", a, degree, shards)
    }

    /// Spawn on an explicit address (the kill-and-restart test rebinds the
    /// dead worker's port).
    fn spawn_at(listen: &str, a: usize, degree: u32, shards: usize) -> Worker {
        let widths: Vec<String> = WIDTHS.iter().map(|w| w.to_string()).collect();
        let mut child = Command::new(env!("CARGO_BIN_EXE_polylut"))
            .args([
                "shard-worker",
                "--listen",
                listen,
                "--shards",
                &shards.to_string(),
                "--widths",
                &widths.join(","),
                "--net-seed",
                &NET_SEED.to_string(),
                "--degree",
                &degree.to_string(),
                "--a",
                &a.to_string(),
            ])
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawn shard-worker process");
        let stdout = child.stdout.take().expect("piped stdout");
        let mut line = String::new();
        BufReader::new(stdout).read_line(&mut line).expect("worker banner");
        // "[shard-worker] listening on 127.0.0.1:PORT shards=S …"
        let addr = line
            .split_whitespace()
            .skip_while(|w| *w != "on")
            .nth(1)
            .unwrap_or_else(|| panic!("unparsable worker banner: {line:?}"))
            .to_string();
        Worker { child, addr }
    }

    /// SIGKILL the worker process and reap it (no FIN — the coordinator
    /// sees a dead link, not a clean shutdown).
    fn kill(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

impl Drop for Worker {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

fn assert_wire_bit_exact(a: usize, degree: u32, shards: usize, workers: &[&Worker]) {
    let net = test_net(a, degree);
    let tables = polylut_add::lut::compile_network(&net, 1);
    // Shard 0 local; shards 1.. mapped round-robin onto the worker processes.
    let placement: ShardPlacement = (0..shards)
        .map(|s| (s > 0).then(|| workers[(s - 1) % workers.len()].addr.clone()))
        .collect();
    let model = ShardedModel::compile_placed(&net, &tables, shards, 1, &placement, None)
        .expect("placed compile against worker processes");
    let mut rng = Rng::new(degree as u64 * 31 + a as u64);
    let xs: Vec<Vec<i32>> = (0..WORD + 7)
        .map(|_| {
            let x: Vec<f32> = (0..WIDTHS[0]).map(|_| rng.f32()).collect();
            net.quantize_input(&x)
        })
        .collect();
    let want: Vec<Vec<i32>> = xs.iter().map(|x| net.forward_codes(x)).collect();
    assert_eq!(
        model.plan.forward_batch(&xs).unwrap(),
        want,
        "plan route A={a} D={degree} S={shards}"
    );
    assert_eq!(
        model.bits.forward_batch(&xs).unwrap(),
        want,
        "bitslice route A={a} D={degree} S={shards}"
    );
    let ws = model.wire_stats().expect("remote links present");
    assert!(ws.frames > 0, "frames crossed the wire");
    assert!(ws.bytes > ws.frames, "bytes include headers");
    // Wire v3 link multiplexing: every (engine, shard) session to one
    // worker process shares a single TCP connection, so the link count is
    // the number of distinct worker addresses — not the session count.
    let hosts: std::collections::BTreeSet<&str> =
        placement.iter().flatten().map(String::as_str).collect();
    assert_eq!(
        model.wire_links(),
        hosts.len(),
        "one TCP connection per worker host A={a} D={degree} S={shards}"
    );
    let per_host = model.wire_host_stats();
    assert_eq!(per_host.len(), hosts.len(), "per-host rollup: {per_host:?}");
    for h in &per_host {
        // Both engines (plan + bitslice) open one session per remote shard
        // placed on this host.
        assert!(h.sessions >= 2, "mux carries both engines' sessions: {h:?}");
        assert!(h.frames > 0 && h.bytes > 0, "per-host traffic counted: {h:?}");
    }
}

/// S = 2: one local shard + one shard in a worker process.
#[test]
fn two_shards_one_remote_process() {
    let (a, degree) = (2, 1);
    let w = Worker::spawn(a, degree, 2);
    assert_wire_bit_exact(a, degree, 2, &[&w]);
}

/// S = 3 across two worker processes (the CI loopback job's shape): shard
/// 0 local, shards 1 and 2 each in their own `polylut shard-worker`.
#[test]
fn three_shards_two_remote_processes() {
    let (a, degree) = (1, 2);
    let w1 = Worker::spawn(a, degree, 3);
    let w2 = Worker::spawn(a, degree, 3);
    assert_wire_bit_exact(a, degree, 3, &[&w1, &w2]);
}

/// Kill-and-restart regression for reconnect-and-resume: SIGKILL the
/// worker process mid-batch, restart it on the same port, and the placed
/// model must resume bit-exactly — `wire_resumes` incremented, the retry
/// budget never exhausted, zero degraded batches (no sticky fault, every
/// forward call keeps succeeding on both engine routes).
#[test]
fn kill_and_restart_resumes_bit_exact() {
    let (a, degree) = (2, 1);
    let mut w = Worker::spawn(a, degree, 2);
    let addr = w.addr.clone();
    let net = test_net(a, degree);
    let tables = polylut_add::lut::compile_network(&net, 1);
    let placement: ShardPlacement = vec![None, Some(addr.clone())];
    // Generous retry budget: the restarted process needs a moment to
    // recompile the model before it listens again.
    let wire = WireConfig { window: 4, retries: 12, mux: true };
    let model =
        ShardedModel::compile_placed_wire(&net, &tables, 2, 1, &placement, None, wire)
            .expect("placed compile against worker process");
    let mut rng = Rng::new(0xDEAD);
    let xs: Vec<Vec<i32>> = (0..WORD + 7)
        .map(|_| {
            let x: Vec<f32> = (0..WIDTHS[0]).map(|_| rng.f32()).collect();
            net.quantize_input(&x)
        })
        .collect();
    let want: Vec<Vec<i32>> = xs.iter().map(|x| net.forward_codes(x)).collect();

    // First third of the batch against the original worker.
    let cut = xs.len() / 3;
    for (i, x) in xs[..cut].iter().enumerate() {
        assert_eq!(model.plan.forward_codes(x).unwrap(), want[i], "pre-kill sample {i}");
    }

    // SIGKILL mid-batch, restart on the same port (std listeners set
    // SO_REUSEADDR, so the rebind succeeds immediately).
    w.kill();
    let w2 = Worker::spawn_at(&addr, a, degree, 2);
    assert_eq!(w2.addr, addr, "restart must rebind the same address");

    // Remainder of the batch: the first post-kill call finds the dead
    // link, reconnects with the resume handshake, and keeps serving.
    for (i, x) in xs[cut..].iter().enumerate() {
        assert_eq!(
            model.plan.forward_codes(x).expect("resume keeps serving"),
            want[cut + i],
            "post-restart sample {}",
            cut + i
        );
    }
    // The bitslice route's links resume on their first post-kill use too.
    assert_eq!(model.bits.forward_batch(&xs).unwrap(), want, "bitslice route");

    assert!(!model.faulted(), "zero degraded batches");
    let ws = model.wire_stats().expect("remote links present");
    assert!(ws.resumes >= 1, "kill+restart must count a resume: {ws:?}");
    assert_eq!(ws.retry_exhausted, 0, "retry budget must not exhaust: {ws:?}");
}
