//! Integration: the full toolflow on a tiny config, plus the serving stack
//! (no artifacts required — everything from a random-weight network).

// Integration tests are a separate crate: clippy's allow-unwrap-in-tests
// doesn't reach them, so the workspace unwrap_used deny is lifted per-file.
#![allow(clippy::unwrap_used)]

use std::sync::Arc;
use std::time::Duration;

use polylut_add::coordinator::{BackendSpec, FrozenModel, Server, ServerConfig};
use polylut_add::fpga::{synthesize, Strategy};
use polylut_add::nn::network::Network;
use polylut_add::nn::config;
use polylut_add::sim::{LutSim, PipelineSim};
use polylut_add::util::rng::Rng;
use polylut_add::verilog;

fn tiny_net() -> Network {
    let cfg = config::uniform("e2e-tiny", &[10, 8, 4], 2, 2, 3, 3, 3, 2, 2, 4);
    Network::random(&cfg, &mut Rng::new(0xE2E))
}

#[test]
fn full_backend_flow_composes() {
    let net = tiny_net();
    // tables -> mapping -> synth (both strategies)
    let r2 = synthesize(&net, Strategy::Merged).unwrap();
    let r1 = synthesize(&net, Strategy::SeparateRegisters).unwrap();
    assert!(r2.luts > 0 && r1.luts == r2.luts, "area is strategy-independent");
    assert_eq!(r1.cycles, 2 * r2.cycles);
    assert!(r1.fmax_mhz >= r2.fmax_mhz);
    assert!(r2.latency_ns < r1.latency_ns, "strategy 2 must win total latency");

    // RTL emission.
    let dir = std::env::temp_dir().join("polylut_e2e_rtl");
    let files = verilog::emit_project(&net, &dir).unwrap();
    assert_eq!(files.len(), net.cfg.n_layers() + 2);
    let top = std::fs::read_to_string(&files[net.cfg.n_layers()]).unwrap();
    assert!(top.contains("module e2e_tiny_top"));
    let tb = std::fs::read_to_string(files.last().unwrap()).unwrap();
    assert!(tb.contains("$finish"));

    // Deployed semantics agree across all three evaluators.
    let tables = polylut_add::lut::compile_network(&net, 2);
    let sim = LutSim::new(&net, &tables);
    let mut pipe = PipelineSim::new(&net, &tables, Strategy::Merged);
    let mut rng = Rng::new(7);
    let inputs: Vec<Vec<i32>> =
        (0..16).map(|_| (0..10).map(|_| rng.below(4) as i32).collect()).collect();
    let res = pipe.stream(&inputs);
    for (inp, out) in inputs.iter().zip(&res.outputs) {
        assert_eq!(out, &net.forward_codes(inp));
        assert_eq!(out, &sim.forward_codes(inp));
    }
    assert_eq!(res.latency_cycles, r2.cycles);
}

#[test]
fn serving_stack_under_concurrent_load() {
    let net = tiny_net();
    let model = Arc::new(FrozenModel::from_network(net, 2));
    let server = Server::start(
        BackendSpec::lut(model.clone(), 4),
        4,
        ServerConfig {
            max_batch: 32,
            window: Duration::from_micros(500),
            queue_cap: 512,
            ..Default::default()
        },
    );
    let n_clients = 6;
    let per_client = 50;
    std::thread::scope(|scope| {
        for c in 0..n_clients {
            let client = server.client();
            let model = model.clone();
            scope.spawn(move || {
                let mut rng = Rng::new(c as u64);
                for _ in 0..per_client {
                    let x: Vec<f32> = (0..10).map(|_| rng.f32()).collect();
                    let resp = client.infer(x.clone()).unwrap();
                    assert_eq!(resp.logits, model.sim().forward(&x));
                }
            });
        }
    });
    let m = &server.metrics;
    assert_eq!(
        m.responses.load(std::sync::atomic::Ordering::Relaxed),
        (n_clients * per_client) as u64
    );
    assert!(m.latency_quantile_us(0.5) > 0.0);
    server.shutdown();
}

#[test]
fn backpressure_rejects_when_queue_full() {
    // A 1-slot queue with a slow window: the second burst must see rejects.
    let net = tiny_net();
    let model = Arc::new(FrozenModel::from_network(net, 1));
    let server = Server::start(
        BackendSpec::lut(model, 1),
        4,
        ServerConfig {
            max_batch: 1,
            window: Duration::from_millis(30),
            queue_cap: 1,
            ..Default::default()
        },
    );
    let rejects = std::sync::atomic::AtomicU64::new(0);
    std::thread::scope(|scope| {
        // 8 concurrent clients vs a 1-deep queue drained 1 request / 30 ms:
        // most submissions must bounce.
        for _ in 0..8 {
            let client = server.client();
            let rejects = &rejects;
            scope.spawn(move || {
                for _ in 0..5 {
                    if client.infer(vec![0.5; 10]).is_err() {
                        rejects.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    }
                }
            });
        }
    });
    assert!(
        rejects.load(std::sync::atomic::Ordering::Relaxed) > 0,
        "expected at least one backpressure rejection"
    );
    server.shutdown();
}
