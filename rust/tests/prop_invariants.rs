//! Property-based invariants over randomly generated configurations —
//! the heart of the correctness story (uses the in-repo mini-proptest;
//! reproduce failures with PROP_SEED=<seed>).

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use polylut_add::coordinator::fleet::{Fleet, FleetConfig, FleetError};
use polylut_add::coordinator::FrozenModel;
use polylut_add::fpga::Strategy;
use polylut_add::lut::tables::{
    compile_network, pack_adder_addr, pack_poly_addr, unpack_adder_addr, unpack_poly_addr,
};
use polylut_add::lut::{boolfn::BoolFn, map_network_of};
use polylut_add::nn::network::Network;
use polylut_add::nn::{config, quant};
use polylut_add::prop_assert;
use polylut_add::sim::{BitsliceNet, EngineSelect, EvalPlan, LutSim, PipelineSim, Scratch, WORD};
use polylut_add::simd;
use polylut_add::util::prop::{check, Gen, Outcome};
use polylut_add::util::rng::Rng;

/// A random small-but-nontrivial config.
fn random_config(g: &mut Gen) -> config::ModelConfig {
    let n_in = g.usize_in(4, 12);
    let hidden = g.usize_in(3, 8);
    let n_out = g.usize_in(1, 4);
    let beta_in = g.usize_in(1, 3) as u32;
    let beta = g.usize_in(1, 3) as u32;
    let fan = g.usize_in(1, 3.min(n_in));
    let degree = g.usize_in(1, 3) as u32;
    let a = g.usize_in(1, 3);
    let n_classes = if n_out == 1 { 1 } else { n_out };
    config::uniform(
        "prop", &[n_in, hidden, n_out], beta_in, beta, beta + 1, fan.min(n_in), fan.min(hidden),
        degree, a, n_classes,
    )
}

#[test]
fn lutsim_equals_fixed_point_model() {
    check("tables reproduce the fixed-point model", 25, |g| {
        let cfg = random_config(g);
        if cfg.validate().is_err() {
            return Outcome::Pass; // skip degenerate draws
        }
        let mut rng = g.rng.fork(1);
        let net = Network::random(&cfg, &mut rng);
        let tables = compile_network(&net, 1);
        let sim = LutSim::new(&net, &tables);
        for _ in 0..20 {
            let x: Vec<f32> = (0..cfg.widths[0]).map(|_| rng.f32()).collect();
            let codes = net.quantize_input(&x);
            prop_assert!(
                sim.forward_codes(&codes) == net.forward_codes(&codes),
                "cfg {cfg:?}"
            );
        }
        Outcome::Pass
    });
}

#[test]
fn mapped_netlist_equals_tables_on_random_vectors() {
    check("LUT6 mapping preserves every neuron function", 12, |g| {
        let cfg = random_config(g);
        if cfg.validate().is_err() {
            return Outcome::Pass;
        }
        let mut rng = g.rng.fork(2);
        let net = Network::random(&cfg, &mut rng);
        let tables = compile_network(&net, 1);
        let mapped = map_network_of(&net, &tables, 1);
        // Layer 0: drive random input codes bit-parallel and compare.
        let lt = &tables.layers[0];
        let n_in = cfg.widths[0];
        let mut codes = vec![0u32; n_in * 64];
        for c in codes.iter_mut() {
            *c = rng.below(1usize << lt.in_bits) as u32;
        }
        let wires = |w: u32| -> u64 {
            let (src, bit) = ((w / lt.in_bits) as usize, w % lt.in_bits);
            let mut out = 0u64;
            for s in 0..64 {
                out |= (((codes[src * 64 + s] >> bit) & 1) as u64) << s;
            }
            out
        };
        let vals = mapped.layers[0].netlist.eval64(&wires);
        for (j, bits) in mapped.layers[0].roots.iter().enumerate() {
            for s in 0..64 {
                let gathered: Vec<Vec<i32>> = (0..cfg.a_factor)
                    .map(|a| {
                        net.layers[0].indices[a][j]
                            .iter()
                            .map(|&src| codes[src * 64 + s] as i32)
                            .collect()
                    })
                    .collect();
                let nt = &lt.neurons[j];
                let expect = match &nt.adder {
                    Some(adder) => {
                        let subs: Vec<i32> = nt
                            .poly
                            .iter()
                            .enumerate()
                            .map(|(a, t)| t.code_at(pack_poly_addr(&gathered[a], lt.in_bits)))
                            .collect();
                        adder.code_at(pack_adder_addr(&subs, lt.sub_bits))
                    }
                    None => nt.poly[0].code_at(pack_poly_addr(&gathered[0], lt.in_bits)),
                };
                let want = quant::to_twos_complement(expect, lt.out_bits);
                let mut got = 0u32;
                for (b, &node) in bits.iter().enumerate() {
                    got |= (((vals[node as usize] >> s) & 1) as u32) << b;
                }
                prop_assert!(got == want, "neuron {j} sample {s}: {got} != {want}");
            }
        }
        Outcome::Pass
    });
}

#[test]
fn bitslice_engine_equals_plan_on_random_configs() {
    check("bitsliced 64-lane words == evaluation plan", 10, |g| {
        let cfg = random_config(g);
        if cfg.validate().is_err() {
            return Outcome::Pass;
        }
        let mut rng = g.rng.fork(4);
        let net = Network::random(&cfg, &mut rng);
        let tables = compile_network(&net, 1);
        let plan = EvalPlan::compile(&net, &tables);
        let bits = BitsliceNet::compile(&net, &tables, 1);
        // One full word plus a ragged tail.
        let xs: Vec<Vec<i32>> = (0..WORD + 9)
            .map(|_| {
                (0..cfg.widths[0]).map(|_| rng.below(1usize << cfg.beta[0]) as i32).collect()
            })
            .collect();
        let mut ps = Scratch::for_plan(&plan);
        let mut bs = bits.scratch();
        prop_assert!(
            bits.forward_batch(&xs, &mut bs) == plan.forward_batch(&xs, &mut ps),
            "cfg {cfg:?}"
        );
        Outcome::Pass
    });
}

#[test]
fn wide_lane_engine_equals_forced_scalar_on_random_configs() {
    check("forced-widest lane plan == forced-scalar 64-lane plan", 10, |g| {
        let cfg = random_config(g);
        if cfg.validate().is_err() {
            return Outcome::Pass;
        }
        let mut rng = g.rng.fork(6);
        let net = Network::random(&cfg, &mut rng);
        let tables = compile_network(&net, 1);
        let widest = simd::widest_lanes();
        let scalar = BitsliceNet::compile(&net, &tables, 1).with_lane_plan(simd::plan_for(WORD));
        let wide = BitsliceNet::compile(&net, &tables, 1).with_lane_plan(simd::plan_for(widest));
        // A ragged draw around the wide word boundary: whole batch sizes
        // are part of the random geometry.
        let n = g.usize_in(1, widest + widest / 2);
        let xs: Vec<Vec<i32>> = (0..n)
            .map(|_| {
                (0..cfg.widths[0]).map(|_| rng.below(1usize << cfg.beta[0]) as i32).collect()
            })
            .collect();
        prop_assert!(
            wide.forward_batch_codes(&xs) == scalar.forward_batch_codes(&xs),
            "cfg {cfg:?} batch {n} lanes {widest}"
        );
        Outcome::Pass
    });
}

#[test]
fn pipeline_sim_matches_lutsim_for_both_strategies() {
    check("pipeline simulation == combinational reference", 10, |g| {
        let cfg = random_config(g);
        if cfg.validate().is_err() {
            return Outcome::Pass;
        }
        let mut rng = g.rng.fork(3);
        let net = Network::random(&cfg, &mut rng);
        let tables = compile_network(&net, 1);
        let sim = LutSim::new(&net, &tables);
        let inputs: Vec<Vec<i32>> = (0..8)
            .map(|_| {
                (0..cfg.widths[0]).map(|_| rng.below(1usize << cfg.beta[0]) as i32).collect()
            })
            .collect();
        for strategy in [Strategy::Merged, Strategy::SeparateRegisters] {
            let mut pipe = PipelineSim::new(&net, &tables, strategy);
            let res = pipe.stream(&inputs);
            for (inp, out) in inputs.iter().zip(&res.outputs) {
                prop_assert!(out == &sim.forward_codes(inp), "{strategy:?}");
            }
            let expect_cycles = match strategy {
                Strategy::Merged => cfg.n_layers(),
                Strategy::SeparateRegisters => {
                    cfg.n_layers() * if cfg.a_factor > 1 { 2 } else { 1 }
                }
            } as u32;
            prop_assert!(
                res.latency_cycles == expect_cycles,
                "latency {} != {expect_cycles} for {strategy:?}",
                res.latency_cycles
            );
        }
        Outcome::Pass
    });
}

#[test]
fn addr_packing_is_bijective() {
    check("table address packing round-trips", 100, |g| {
        let beta = g.usize_in(1, 5) as u32;
        let fan = g.usize_in(1, 4);
        let mut out = vec![0i32; fan];
        let size = 1usize << (beta * fan as u32);
        let addr = g.rng.below(size);
        unpack_poly_addr(addr, fan, beta, &mut out);
        prop_assert!(pack_poly_addr(&out, beta) == addr, "poly addr {addr}");
        let sub_bits = g.usize_in(2, 5) as u32;
        let a = g.usize_in(1, 3);
        let mut subs = vec![0i32; a];
        let aaddr = g.rng.below(1usize << (sub_bits * a as u32));
        unpack_adder_addr(aaddr, a, sub_bits, &mut subs);
        prop_assert!(pack_adder_addr(&subs, sub_bits) == aaddr, "adder addr {aaddr}");
        Outcome::Pass
    });
}

#[test]
fn support_reduction_preserves_function() {
    check("BoolFn::support_reduce is semantics-preserving", 60, |g| {
        let n = g.usize_in(2, 10) as u32;
        let words = (1usize << n).div_ceil(64);
        let mut bits = vec![0u64; words];
        // Random function with limited support (makes reduction non-trivial).
        let active: Vec<u32> = (0..n).filter(|_| g.bool()).collect();
        let mut rng = g.rng.fork(9);
        let lut: u64 = rng.next_u64();
        for addr in 0..(1usize << n) {
            let key: usize = active
                .iter()
                .enumerate()
                .map(|(i, &v)| (((addr >> v) & 1) << i))
                .sum();
            if (lut >> (key % 64)) & 1 == 1 {
                bits[addr / 64] |= 1 << (addr % 64);
            }
        }
        let f = BoolFn::from_bits(n, bits);
        let (red, kept) = f.support_reduce();
        prop_assert!(kept.len() <= active.len().max(1), "support grew");
        for _ in 0..50 {
            let addr = rng.below(1usize << n);
            let mut raddr = 0usize;
            for (i, &v) in kept.iter().enumerate() {
                raddr |= ((addr >> v) & 1) << i;
            }
            prop_assert!(
                f.get(addr) == red.get(raddr),
                "n={n} addr={addr} kept={kept:?}"
            );
        }
        Outcome::Pass
    });
}

#[test]
fn quantizer_codes_monotonic_in_input() {
    check("quantizer codes are monotone", 100, |g| {
        let bits = g.usize_in(1, 8) as u32;
        let scale = (g.rng.f32() * 4.0 + 0.01).max(0.05);
        let a = g.f32_signed(8.0);
        let b = a + g.rng.f32() * 4.0;
        prop_assert!(
            quant::unsigned_code(a, bits, scale) <= quant::unsigned_code(b, bits, scale),
            "unsigned a={a} b={b}"
        );
        if bits >= 2 {
            prop_assert!(
                quant::signed_code(a, bits, scale) <= quant::signed_code(b, bits, scale),
                "signed a={a} b={b}"
            );
        }
        Outcome::Pass
    });
}

#[test]
fn fleet_answers_every_admitted_request_exactly_once() {
    // Over random geometries, replica counts, batch-former widths,
    // deadlines, queue depths and arrival patterns: every submitted
    // request gets exactly one outcome (a response or a typed error —
    // infer never hangs and never double-answers), every response is
    // bit-exact against the plan engine, and no formed batch ever exceeds
    // the configured width.
    check("fleet: exactly-once, bit-exact, width-bounded", 6, |g| {
        let cfg = random_config(g);
        if cfg.validate().is_err() {
            return Outcome::Pass;
        }
        let mut rng = g.rng.fork(8);
        let net = Network::random(&cfg, &mut rng);
        let model = Arc::new(FrozenModel::from_network(net, 1));
        let replicas = g.usize_in(1, 3);
        let target = g.usize_in(1, 8);
        let deadline_us = [0u64, 100, 1_000][g.usize_in(0, 2)];
        let depth = g.usize_in(4, 64);
        let fleet = Fleet::start(
            model.clone(),
            1,
            EngineSelect::plan_only(),
            cfg.n_classes,
            FleetConfig {
                replicas,
                target_batch: target,
                batch_deadline: Duration::from_micros(deadline_us),
                queue_depth: depth,
                // Generous: a healthy in-process fleet must never age a
                // request out in this test, so sheds count as failures.
                shed_after: Some(Duration::from_secs(30)),
            },
        );
        let n_clients = g.usize_in(1, 4);
        let per_client = g.usize_in(5, 20);
        let n_in = cfg.widths[0];
        let sim = model.sim();
        // (ok, rejected-at-admission, other-error, bit-mismatch) totals.
        let mut totals = (0usize, 0usize, 0usize, 0usize);
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for c in 0..n_clients {
                let client = fleet.client();
                let sim = &sim;
                let mut crng = g.rng.fork(100 + c as u64);
                let pace = g.bool();
                handles.push(scope.spawn(move || {
                    let (mut ok, mut rejected, mut other, mut mismatch) =
                        (0usize, 0usize, 0usize, 0usize);
                    for _ in 0..per_client {
                        let x: Vec<f32> = (0..n_in).map(|_| crng.f32()).collect();
                        match client.infer(x.clone()) {
                            Ok(resp) => {
                                ok += 1;
                                if resp.logits != sim.forward(&x) {
                                    mismatch += 1;
                                }
                            }
                            Err(FleetError::QueueFull { .. }) => rejected += 1,
                            Err(_) => other += 1,
                        }
                        if pace {
                            std::thread::yield_now();
                        }
                    }
                    (ok, rejected, other, mismatch)
                }));
            }
            for h in handles {
                let (ok, rej, oth, mis) = h.join().expect("fleet prop client");
                totals.0 += ok;
                totals.1 += rej;
                totals.2 += oth;
                totals.3 += mis;
            }
        });
        let issued = n_clients * per_client;
        let m = &fleet.metrics;
        let responses = m.responses.load(Ordering::Relaxed) as usize;
        let rejects = m.queue_rejects.load(Ordering::Relaxed) as usize;
        let max_formed = m.max_formed_batch.load(Ordering::Relaxed) as usize;
        fleet.shutdown();
        prop_assert!(totals.3 == 0, "{} responses not bit-exact vs the plan", totals.3);
        prop_assert!(
            totals.0 + totals.1 + totals.2 == issued,
            "outcomes {totals:?} != issued {issued}"
        );
        prop_assert!(totals.2 == 0, "unexpected shed/replica/stop outcomes: {totals:?}");
        prop_assert!(
            responses == totals.0 && rejects == totals.1,
            "metrics (responses={responses}, rejects={rejects}) disagree with \
             client outcomes {totals:?}"
        );
        prop_assert!(
            max_formed <= target,
            "formed batch of {max_formed} exceeds target width {target}"
        );
        Outcome::Pass
    });
}

#[test]
fn wide_neuron_equals_sum_of_subneurons_eq2() {
    // Paper Eq. (2): a fan-in AF dot product equals the sum of A fan-in-F
    // partial dot products (checked in exact float before quantization).
    check("Eq. (2) decomposition", 80, |g| {
        let f = g.usize_in(1, 5);
        let a = g.usize_in(1, 4);
        let x = g.vec_f32(a * f, 2.0);
        let w = g.vec_f32(a * f, 2.0);
        let b: Vec<f32> = (0..a).map(|_| g.f32_signed(1.0)).collect();
        let wide: f64 = x
            .iter()
            .zip(&w)
            .map(|(xi, wi)| (*xi as f64) * (*wi as f64))
            .sum::<f64>()
            + b.iter().map(|v| *v as f64).sum::<f64>();
        let parts: f64 = (0..a)
            .map(|ai| {
                x[ai * f..(ai + 1) * f]
                    .iter()
                    .zip(&w[ai * f..(ai + 1) * f])
                    .map(|(xi, wi)| (*xi as f64) * (*wi as f64))
                    .sum::<f64>()
                    + b[ai] as f64
            })
            .sum();
        prop_assert!((wide - parts).abs() < 1e-9, "wide {wide} vs parts {parts}");
        Outcome::Pass
    });
}
