//! Lane-count-generic machine words for the bitslice engine.
//!
//! The bitslice engine's premise — one bitwise op retires every lane of a
//! machine word — is only as strong as the word is wide.  This module
//! abstracts the word: [`Word`] is implemented by the scalar `u64` baseline
//! (64 lanes) and by [`Blocks<N>`], an `N`-block `[u64; N]` plane group
//! (128/256/512 lanes) whose lane-wise ops are plain per-block bitwise ops
//! the compiler unrolls and, under the right target features, vectorizes to
//! ymm/zmm registers.
//!
//! # Dispatch ladder
//!
//! Kernel selection is a [`LanePlan`] resolved once at engine-compile time
//! (`CLI --lanes` > `POLYLUT_LANES` env > widest supported) and dispatched
//! per batch in `sim::bitslice::forward_batch_codes`:
//!
//! ```text
//!   lanes  path              codegen
//!   ─────  ────────────────  ──────────────────────────────────────────────
//!     64   Scalar            the original u64 kernel (always correct)
//!    128   Blocks2           portable [u64; 2] unrolled blocks
//!    256   Blocks4 / Avx2    [u64; 4]; Avx2 re-checks CPUID, then enters a
//!                            `#[target_feature(enable = "avx2")]` wrapper so
//!                            LLVM lowers the block ops to 256-bit ymm ops
//!    512   Blocks8 / Avx512  [u64; 8]; the Avx512 path is selected when
//!                            `avx512f` is detected but compiles under the
//!                            avx2 feature set (2× ymm per op) so it builds
//!                            on every stable toolchain — full zmm codegen
//!                            comes from a `-C target-cpu=native` build
//! ```
//!
//! Every `std::arch`-flavoured path re-verifies
//! `is_x86_feature_detected!` at the dispatch site before entering the
//! `unsafe` target-feature wrapper, and falls back to the portable
//! [`Blocks<N>`] kernel otherwise — constructing any [`LanePlan`] from safe
//! code is therefore always sound, and non-x86 hosts get the portable
//! blocks unconditionally.
//!
//! The wire/shard handoff format is *not* widened: remote shards always
//! exchange canonical 64-bit planes (`Blocks<N>` is layout-transparent over
//! `[u64; N]`, block `i` = samples `64·i..64·(i+1)`), so PLW2 frames and
//! the hazard/verify arguments are untouched.  See ARCHITECTURE.md §3.

use std::ops::{BitAnd, BitOr, BitXor, Not};

/// Lane widths the engine can compile for: 64-bit plane blocks only.
pub const SUPPORTED_LANES: [usize; 4] = [64, 128, 256, 512];

/// Environment variable overriding the lane width (`64|128|256|512`, or
/// `widest`/`max`/`0` for the detected maximum).  CLI `--lanes` wins over it.
pub const LANES_ENV: &str = "POLYLUT_LANES";

/// Valid-lane mask for one 64-lane block holding `n_valid` samples: lane
/// `s` is set iff sample `s` exists.  Saturates at a full block
/// (`n_valid >= 64`), so the remainder of any batch size can be passed
/// directly.  This is the single source of truth `sim::bitslice::lane_mask`
/// re-exports.
#[inline]
pub fn lane_mask64(n_valid: usize) -> u64 {
    if n_valid >= 64 {
        !0
    } else {
        (1u64 << n_valid) - 1
    }
}

/// A machine word of `LANES = BLOCKS·64` bit-parallel sample lanes,
/// physically `BLOCKS` consecutive 64-bit plane blocks (block `i` holds
/// samples `64·i..64·(i+1)` — the canonical wire layout).
///
/// Implementors are plain-old-data (`Copy`) and support the four lane-wise
/// bitwise ops the op-stream kernels are written in, so the generic kernels
/// keep exactly the scalar code shape (`l ^ (s & (l ^ h))`, `v & x`,
/// `v & !x`, …) and monomorphize to straight-line block-unrolled code.
pub trait Word:
    Copy
    + Send
    + Sync
    + Sized
    + 'static
    + BitAnd<Output = Self>
    + BitOr<Output = Self>
    + BitXor<Output = Self>
    + Not<Output = Self>
{
    /// Number of 64-bit plane blocks in this word.
    const BLOCKS: usize;
    /// Sample lanes per word (`BLOCKS * 64`).
    const LANES: usize = Self::BLOCKS * 64;

    /// The all-zero word.
    fn zero() -> Self;
    /// The all-ones word.
    fn ones() -> Self;
    /// Valid-lane mask for `n_valid` samples, saturating at `LANES`.
    fn lane_mask(n_valid: usize) -> Self;
    /// Read 64-bit plane block `i` (samples `64·i..64·(i+1)`).
    fn block(&self, i: usize) -> u64;
    /// Overwrite 64-bit plane block `i`.
    fn set_block(&mut self, i: usize, v: u64);

    /// Lane-wise 2:1 mux: lane `s` of the result is `hi[s]` where `sel[s]`
    /// is set, else `lo[s]` — the 3-op word-mux every kernel recombines
    /// cofactors with.
    #[inline(always)]
    fn mux(sel: Self, lo: Self, hi: Self) -> Self {
        lo ^ (sel & (lo ^ hi))
    }
}

impl Word for u64 {
    const BLOCKS: usize = 1;

    #[inline(always)]
    fn zero() -> Self {
        0
    }

    #[inline(always)]
    fn ones() -> Self {
        !0
    }

    #[inline(always)]
    fn lane_mask(n_valid: usize) -> Self {
        lane_mask64(n_valid)
    }

    #[inline(always)]
    fn block(&self, _i: usize) -> u64 {
        *self
    }

    #[inline(always)]
    fn set_block(&mut self, _i: usize, v: u64) {
        *self = v;
    }
}

/// `N` consecutive 64-bit plane blocks treated as one `64·N`-lane word.
///
/// `#[repr(transparent)]` over `[u64; N]`: block `i` of the wide word is
/// bit-for-bit the scalar plane of sample chunk `i`, which is what keeps
/// the 64-bit wire/shard handoff format byte-identical under wide local
/// kernels (asserted by `sim::bitslice` tests).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(transparent)]
pub struct Blocks<const N: usize>(pub [u64; N]);

impl<const N: usize> BitAnd for Blocks<N> {
    type Output = Self;

    #[inline(always)]
    fn bitand(self, rhs: Self) -> Self {
        Blocks(std::array::from_fn(|i| self.0[i] & rhs.0[i]))
    }
}

impl<const N: usize> BitOr for Blocks<N> {
    type Output = Self;

    #[inline(always)]
    fn bitor(self, rhs: Self) -> Self {
        Blocks(std::array::from_fn(|i| self.0[i] | rhs.0[i]))
    }
}

impl<const N: usize> BitXor for Blocks<N> {
    type Output = Self;

    #[inline(always)]
    fn bitxor(self, rhs: Self) -> Self {
        Blocks(std::array::from_fn(|i| self.0[i] ^ rhs.0[i]))
    }
}

impl<const N: usize> Not for Blocks<N> {
    type Output = Self;

    #[inline(always)]
    fn not(self) -> Self {
        Blocks(std::array::from_fn(|i| !self.0[i]))
    }
}

impl<const N: usize> Word for Blocks<N> {
    const BLOCKS: usize = N;

    #[inline(always)]
    fn zero() -> Self {
        Blocks([0; N])
    }

    #[inline(always)]
    fn ones() -> Self {
        Blocks([!0; N])
    }

    #[inline(always)]
    fn lane_mask(n_valid: usize) -> Self {
        Blocks(std::array::from_fn(|i| lane_mask64(n_valid.saturating_sub(i * 64))))
    }

    #[inline(always)]
    fn block(&self, i: usize) -> u64 {
        self.0[i]
    }

    #[inline(always)]
    fn set_block(&mut self, i: usize, v: u64) {
        self.0[i] = v;
    }
}

/// Best SIMD capability detected on the host (ordered: wider is greater).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum SimdLevel {
    /// Forced 64-lane scalar `u64` kernels.
    Scalar = 0,
    /// Portable unrolled `[u64; N]` blocks (any architecture).
    Portable = 1,
    /// 256-bit AVX2 available (`is_x86_feature_detected!("avx2")`).
    Avx2 = 2,
    /// 512-bit AVX-512F available (`is_x86_feature_detected!("avx512f")`).
    Avx512 = 3,
}

impl SimdLevel {
    /// Snapshot / log label.
    pub fn as_str(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Portable => "portable",
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Avx512 => "avx512",
        }
    }

    /// Stable ordinal for atomic metrics storage.
    pub fn ordinal(self) -> u64 {
        self as u64
    }

    /// Inverse of [`SimdLevel::ordinal`].
    pub fn from_ordinal(v: u64) -> Option<SimdLevel> {
        match v {
            0 => Some(SimdLevel::Scalar),
            1 => Some(SimdLevel::Portable),
            2 => Some(SimdLevel::Avx2),
            3 => Some(SimdLevel::Avx512),
            _ => None,
        }
    }
}

/// Which monomorphized kernel executes the op stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelPath {
    /// `u64` — 64 lanes, the always-correct baseline.
    Scalar,
    /// Portable `Blocks<2>` — 128 lanes.
    Blocks2,
    /// Portable `Blocks<4>` — 256 lanes.
    Blocks4,
    /// Portable `Blocks<8>` — 512 lanes.
    Blocks8,
    /// `Blocks<4>` under `#[target_feature(enable = "avx2")]` — 256 lanes
    /// in ymm registers.  Falls back to [`KernelPath::Blocks4`] at dispatch
    /// time if CPUID disagrees.
    Avx2,
    /// `Blocks<8>` under the avx2 feature set (selected when `avx512f` is
    /// detected) — 512 lanes, two ymm ops per block op on a stable
    /// toolchain, full zmm under `-C target-cpu=native`.  Falls back to
    /// [`KernelPath::Blocks8`] at dispatch time if CPUID disagrees.
    Avx512,
}

impl KernelPath {
    /// Snapshot / bench label.
    pub fn as_str(self) -> &'static str {
        match self {
            KernelPath::Scalar => "scalar",
            KernelPath::Blocks2 => "blocks2",
            KernelPath::Blocks4 => "blocks4",
            KernelPath::Blocks8 => "blocks8",
            KernelPath::Avx2 => "avx2",
            KernelPath::Avx512 => "avx512",
        }
    }
}

/// A resolved lane plan: how wide the engine's words are and which kernel
/// path executes them.  Carried by every compiled `BitsliceNet`; validated
/// by `sim::verify` (`lane-width` / `scratch-blocks` invariants).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LanePlan {
    /// Sample lanes per op-stream walk (a supported multiple of 64).
    pub lanes: usize,
    /// Kernel monomorphization dispatched per batch.
    pub path: KernelPath,
    /// SIMD capability the path assumes (for metrics/logs).
    pub level: SimdLevel,
}

impl LanePlan {
    /// The canonical 64-lane scalar plan (wire format, shard handoff, and
    /// the back-compat `BitsliceNet::compile` default).
    pub fn scalar() -> LanePlan {
        LanePlan { lanes: 64, path: KernelPath::Scalar, level: SimdLevel::Scalar }
    }

    /// 64-bit plane blocks per word (`lanes / 64`).
    pub fn blocks(&self) -> usize {
        self.lanes / 64
    }
}

/// Detect the host's best SIMD capability.  Portable blocks are available
/// everywhere; AVX levels only on x86-64 and only when CPUID confirms them.
pub fn detect_level() -> SimdLevel {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx512f") {
            return SimdLevel::Avx512;
        }
        if std::arch::is_x86_feature_detected!("avx2") {
            return SimdLevel::Avx2;
        }
    }
    SimdLevel::Portable
}

/// Widest lane count worth compiling for on this host: 512 with AVX-512F,
/// 256 with AVX2, otherwise 128 (portable 2× blocks still amortize per-op
/// overhead and dual-issue on any 64-bit core).
pub fn widest_lanes() -> usize {
    match detect_level() {
        SimdLevel::Avx512 => 512,
        SimdLevel::Avx2 => 256,
        SimdLevel::Scalar | SimdLevel::Portable => 128,
    }
}

/// Build the lane plan for a supported lane count, picking the best kernel
/// path the host verifiably supports at that width (portable blocks when
/// CPUID comes up short — e.g. a forced `--lanes 512` on an AVX2-only
/// host runs portable `Blocks<8>`).
///
/// `lanes` must be one of [`SUPPORTED_LANES`]; use [`resolve`] for
/// validated user input.
pub fn plan_for(lanes: usize) -> LanePlan {
    assert!(
        SUPPORTED_LANES.contains(&lanes),
        "unsupported lane count {lanes} (supported: {SUPPORTED_LANES:?})"
    );
    let level = detect_level();
    match lanes {
        64 => LanePlan::scalar(),
        128 => LanePlan { lanes, path: KernelPath::Blocks2, level: SimdLevel::Portable },
        256 if level >= SimdLevel::Avx2 => {
            LanePlan { lanes, path: KernelPath::Avx2, level: SimdLevel::Avx2 }
        }
        256 => LanePlan { lanes, path: KernelPath::Blocks4, level: SimdLevel::Portable },
        512 if level >= SimdLevel::Avx512 => {
            LanePlan { lanes, path: KernelPath::Avx512, level: SimdLevel::Avx512 }
        }
        512 if level >= SimdLevel::Avx2 => {
            // Forced past the detected width: still profitable as ymm-backed
            // 8-block words, so keep the avx2-wrapped Blocks<8> kernel.
            LanePlan { lanes, path: KernelPath::Avx512, level: SimdLevel::Avx2 }
        }
        _ => LanePlan { lanes, path: KernelPath::Blocks8, level: SimdLevel::Portable },
    }
}

/// Resolve the active lane plan.  Precedence: explicit caller choice
/// (CLI `--lanes`, strict — unsupported values error) over the
/// [`LANES_ENV`] environment variable (lenient — malformed values log a
/// warning and fall back) over the detected widest width.
pub fn resolve(cli: Option<usize>) -> anyhow::Result<LanePlan> {
    if let Some(lanes) = cli {
        if !SUPPORTED_LANES.contains(&lanes) {
            anyhow::bail!(
                "--lanes {lanes} is not supported (choose one of {SUPPORTED_LANES:?}, \
                 or `widest`)"
            );
        }
        return Ok(plan_for(lanes));
    }
    let lanes = match std::env::var(LANES_ENV) {
        Ok(raw) => {
            let raw = raw.trim();
            if raw.is_empty() || raw.eq_ignore_ascii_case("widest") || raw == "0"
                || raw.eq_ignore_ascii_case("max")
            {
                widest_lanes()
            } else {
                match raw.parse::<usize>() {
                    Ok(n) if SUPPORTED_LANES.contains(&n) => n,
                    _ => {
                        log::warn!(
                            "{LANES_ENV}={raw:?} is not a supported lane count \
                             ({SUPPORTED_LANES:?}); using widest"
                        );
                        widest_lanes()
                    }
                }
            }
        }
        Err(_) => widest_lanes(),
    };
    Ok(plan_for(lanes))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lane_mask64_saturates() {
        assert_eq!(lane_mask64(0), 0);
        assert_eq!(lane_mask64(1), 1);
        assert_eq!(lane_mask64(63), u64::MAX >> 1);
        assert_eq!(lane_mask64(64), u64::MAX);
        assert_eq!(lane_mask64(1000), u64::MAX);
    }

    #[test]
    fn blocks_ops_are_lane_wise() {
        let a = Blocks([0b1100u64, !0]);
        let b = Blocks([0b1010u64, 0]);
        assert_eq!((a & b).0, [0b1000, 0]);
        assert_eq!((a | b).0, [0b1110, !0]);
        assert_eq!((a ^ b).0, [0b0110, !0]);
        assert_eq!((!b).0, [!0b1010u64, !0]);
    }

    #[test]
    fn blocks_lane_mask_spans_block_boundaries() {
        assert_eq!(<Blocks<4>>::lane_mask(0).0, [0, 0, 0, 0]);
        assert_eq!(<Blocks<4>>::lane_mask(64).0, [!0, 0, 0, 0]);
        assert_eq!(<Blocks<4>>::lane_mask(65).0, [!0, 1, 0, 0]);
        assert_eq!(<Blocks<4>>::lane_mask(129).0, [!0, !0, 1, 0]);
        assert_eq!(<Blocks<4>>::lane_mask(256).0, [!0, !0, !0, !0]);
        assert_eq!(<Blocks<4>>::lane_mask(1000).0, [!0, !0, !0, !0]);
    }

    #[test]
    fn word_mux_selects_per_lane() {
        let sel = 0b1010u64;
        let lo = 0b0011u64;
        let hi = 0b0101u64;
        let want = (lo & !sel) | (hi & sel);
        assert_eq!(<u64 as Word>::mux(sel, lo, hi), want);
        let w = <Blocks<2>>::mux(Blocks([sel, 0]), Blocks([lo, 7]), Blocks([hi, 9]));
        assert_eq!(w.0[0], want);
        assert_eq!(w.0[1], 7, "all-clear select keeps lo");
    }

    #[test]
    fn block_accessors_round_trip() {
        let mut w = <Blocks<8>>::zero();
        for i in 0..8 {
            w.set_block(i, i as u64 + 1);
        }
        for i in 0..8 {
            assert_eq!(w.block(i), i as u64 + 1);
        }
        let mut s = 0u64;
        s.set_block(0, 42);
        assert_eq!(s.block(0), 42);
        assert_eq!(<u64 as Word>::BLOCKS, 1);
        assert_eq!(<u64 as Word>::LANES, 64);
        assert_eq!(<Blocks<8> as Word>::LANES, 512);
    }

    #[test]
    fn plan_for_supported_widths_is_consistent() {
        for lanes in SUPPORTED_LANES {
            let plan = plan_for(lanes);
            assert_eq!(plan.lanes, lanes);
            assert_eq!(plan.blocks(), lanes / 64);
        }
        assert_eq!(plan_for(64).path, KernelPath::Scalar);
        assert_eq!(plan_for(128).path, KernelPath::Blocks2);
    }

    #[test]
    fn widest_is_supported_and_at_least_two_blocks() {
        let w = widest_lanes();
        assert!(SUPPORTED_LANES.contains(&w));
        assert!(w >= 128, "portable blocks are always available");
    }

    #[test]
    fn resolve_rejects_bad_cli_widths() {
        assert!(resolve(Some(96)).is_err());
        assert!(resolve(Some(1024)).is_err());
        let plan = resolve(Some(64)).expect("64 is always supported");
        assert_eq!(plan.path, KernelPath::Scalar);
        assert_eq!(plan.level, SimdLevel::Scalar);
    }

    #[test]
    fn simd_level_ordinals_round_trip() {
        for lvl in [SimdLevel::Scalar, SimdLevel::Portable, SimdLevel::Avx2, SimdLevel::Avx512] {
            assert_eq!(SimdLevel::from_ordinal(lvl.ordinal()), Some(lvl));
        }
        assert_eq!(SimdLevel::from_ordinal(17), None);
        assert!(SimdLevel::Avx512 > SimdLevel::Avx2);
        assert!(SimdLevel::Avx2 > SimdLevel::Portable);
    }
}
