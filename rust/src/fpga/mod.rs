//! FPGA synthesis model — the Vivado 2020.1 OOC substitute (DESIGN.md §6).
//!
//! `synthesize` runs the whole back-end: truth tables → LUT6 mapping →
//! area/timing/pipeline report for the xcvu9p part, under either of the
//! paper's two pipeline strategies (Fig. 5).

pub mod baselines;
pub mod device;

use anyhow::Result;

use crate::lut::mapper::{map_network_of, MappedNetwork};
use crate::lut::tables::compile_network;
use crate::nn::network::Network;
use crate::util::pool::default_workers;
use device::{xcvu9p, Device};

/// Paper Fig. 5 pipeline strategies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// (1) Separate registers for Poly-layer and Adder-layer — doubles the
    /// cycle count, maximizes clock frequency.
    SeparateRegisters,
    /// (2) Single register for the combined Poly+Adder stage — lowest
    /// latency, lower F_max.
    Merged,
}

impl TryFrom<usize> for Strategy {
    type Error = anyhow::Error;
    fn try_from(v: usize) -> Result<Strategy> {
        match v {
            1 => Ok(Strategy::SeparateRegisters),
            2 => Ok(Strategy::Merged),
            other => anyhow::bail!("pipeline strategy must be 1 or 2, got {other}"),
        }
    }
}

#[derive(Debug, Clone)]
pub struct LayerSynth {
    pub luts: usize,
    pub regs: usize,
    pub depth: u32,
    pub poly_depth: u32,
    pub free_mux_levels: u32,
    pub period_ns: f64,
}

#[derive(Debug, Clone)]
pub struct SynthReport {
    pub name: String,
    pub device: Device,
    pub strategy: Strategy,
    pub luts: usize,
    pub ffs: usize,
    pub fmax_mhz: f64,
    pub cycles: u32,
    pub latency_ns: f64,
    pub table_words: u128,
    pub gen_seconds: f64,
    pub per_layer: Vec<LayerSynth>,
}

impl SynthReport {
    pub fn lut_pct(&self) -> f64 {
        self.device.lut_pct(self.luts)
    }

    pub fn ff_pct(&self) -> f64 {
        self.device.ff_pct(self.ffs)
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "== {} on {} (pipeline strategy {}) ==\n",
            self.name,
            self.device.name,
            match self.strategy {
                Strategy::SeparateRegisters => "1: separate poly/adder registers",
                Strategy::Merged => "2: merged poly+adder stage",
            }
        ));
        s.push_str(&format!(
            "  LUT      {:>9}  ({:.2}% of {})\n",
            self.luts,
            self.lut_pct(),
            self.device.luts
        ));
        s.push_str(&format!(
            "  FF       {:>9}  ({:.2}% of {})\n",
            self.ffs,
            self.ff_pct(),
            self.device.ffs
        ));
        s.push_str(&format!("  F_max    {:>9.0} MHz\n", self.fmax_mhz));
        s.push_str(&format!(
            "  Latency  {:>9} cycles = {:.1} ns\n",
            self.cycles, self.latency_ns
        ));
        s.push_str(&format!("  Tables   {:>9} words\n", self.table_words));
        s.push_str(&format!("  Gen+map  {:>9.2} s\n", self.gen_seconds));
        for (i, l) in self.per_layer.iter().enumerate() {
            s.push_str(&format!(
                "  layer {i}: {:>7} LUT, {:>6} FF, depth {} (poly {}), {:.2} ns\n",
                l.luts, l.regs, l.depth, l.poly_depth, l.period_ns
            ));
        }
        s
    }
}

/// Free dedicated-mux levels used by a table of `bits` address bits.
fn free_mux_levels_for(bits: u32) -> u32 {
    bits.saturating_sub(6).min(3)
}

/// Area/timing analysis of an already-mapped network.
pub fn analyze(
    net: &Network,
    mapped: &MappedNetwork,
    table_words: u128,
    strategy: Strategy,
    gen_seconds: f64,
) -> SynthReport {
    let dev = xcvu9p();
    let cfg = &net.cfg;
    let a = cfg.a_factor;
    let mut per_layer = Vec::new();
    let mut worst_period = 0f64;
    let mut total_ffs = 0usize;

    for (l, ml) in mapped.layers.iter().enumerate() {
        let n_out = cfg.widths[l + 1];
        let luts = ml.netlist.lut_count();
        let out_regs = n_out * cfg.beta[l + 1] as usize;
        let poly_regs = if a > 1 { a * n_out * cfg.sub_bits(l) as usize } else { 0 };
        let fml = free_mux_levels_for(cfg.table_bits_poly(l));
        let (regs, period) = match strategy {
            Strategy::Merged => {
                // One register stage after the combined poly+adder logic.
                (out_regs, dev.stage_period_ns(ml.depth, fml, luts))
            }
            Strategy::SeparateRegisters => {
                // Two stages; the critical one sets the layer period.
                let adder_depth = ml.depth.saturating_sub(ml.poly_depth);
                let p_poly = dev.stage_period_ns(ml.poly_depth.max(1), fml, luts);
                let p_add = dev.stage_period_ns(
                    adder_depth.max(1),
                    free_mux_levels_for(cfg.table_bits_adder(l)),
                    luts,
                );
                (out_regs + poly_regs, p_poly.max(p_add))
            }
        };
        worst_period = worst_period.max(period);
        total_ffs += regs;
        per_layer.push(LayerSynth {
            luts,
            regs,
            depth: ml.depth,
            poly_depth: ml.poly_depth,
            free_mux_levels: fml,
            period_ns: period,
        });
    }
    // Input capture registers.
    total_ffs += cfg.widths[0] * cfg.beta[0] as usize;

    let stages_per_layer = match strategy {
        Strategy::Merged => 1,
        Strategy::SeparateRegisters => {
            if a > 1 {
                2
            } else {
                1
            }
        }
    };
    let cycles = (cfg.n_layers() * stages_per_layer) as u32;
    let fmax = dev.fmax_mhz(worst_period);
    let latency_ns = cycles as f64 * worst_period;

    SynthReport {
        name: cfg.name.clone(),
        device: dev,
        strategy,
        luts: mapped.total_luts(),
        ffs: total_ffs,
        fmax_mhz: fmax,
        cycles,
        latency_ns,
        table_words,
        gen_seconds,
        per_layer,
    }
}

/// Full back-end: tables → mapping → report.
pub fn synthesize(net: &Network, strategy: Strategy) -> Result<SynthReport> {
    let t0 = std::time::Instant::now();
    let tables = compile_network(net, default_workers());
    let mapped = map_network_of(net, &tables, default_workers());
    Ok(analyze(net, &mapped, tables.total_words, strategy, t0.elapsed().as_secs_f64()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::config;
    use crate::util::rng::Rng;

    #[test]
    fn synthesize_tiny_network() {
        let cfg = config::uniform("t", &[8, 6, 3], 2, 2, 3, 3, 3, 2, 2, 3);
        let net = Network::random(&cfg, &mut Rng::new(7));
        let r2 = synthesize(&net, Strategy::Merged).unwrap();
        let r1 = synthesize(&net, Strategy::SeparateRegisters).unwrap();
        assert!(r2.luts > 0);
        // Paper Table V shape: strategy 2 halves cycles, costs F_max.
        assert_eq!(r1.cycles, 2 * r2.cycles);
        assert!(r1.fmax_mhz >= r2.fmax_mhz);
        assert!(r1.ffs > r2.ffs, "strategy 1 adds poly registers");
    }

    #[test]
    fn a1_has_single_stage_per_layer() {
        let cfg = config::uniform("t", &[8, 6, 3], 2, 2, 3, 3, 3, 2, 1, 3);
        let net = Network::random(&cfg, &mut Rng::new(7));
        let r1 = synthesize(&net, Strategy::SeparateRegisters).unwrap();
        assert_eq!(r1.cycles as usize, cfg.n_layers());
    }

    #[test]
    fn render_contains_key_fields() {
        let cfg = config::uniform("t", &[8, 6, 3], 2, 2, 3, 3, 3, 2, 2, 3);
        let net = Network::random(&cfg, &mut Rng::new(7));
        let r = synthesize(&net, Strategy::Merged).unwrap();
        let text = r.render();
        assert!(text.contains("F_max"));
        assert!(text.contains("xcvu9p"));
    }
}
