//! FPGA device model — AMD/Xilinx xcvu9p-flgb2104-2-i, the part the paper
//! evaluates on (Sec. IV-B), plus the timing constants of the delay model.
//!
//! The delay constants are calibrated so the paper's anchor configurations
//! land in band (DESIGN.md §6): they are *not* vendor datasheet numbers, but
//! the structure (LUT delay + net delay per level, free MUXF7/8/9 levels
//! with a small pass delay, congestion term growing with module size) is the
//! standard post-synthesis estimate shape.

/// A target FPGA part.
#[derive(Debug, Clone)]
pub struct Device {
    pub name: &'static str,
    /// Total LUT6 count (paper reports utilization % of this).
    pub luts: usize,
    /// Total flip-flops.
    pub ffs: usize,
    /// Clock-to-Q + setup overhead (ns).
    pub t_clk_ns: f64,
    /// LUT6 propagation delay (ns).
    pub t_lut_ns: f64,
    /// Average routed-net delay per logic level (ns), before congestion.
    pub t_net_ns: f64,
    /// MUXF7/F8/F9 pass delay (ns) — applied once per free mux level.
    pub t_muxf_ns: f64,
    /// Congestion factor: net delay multiplier grows with
    /// log2(module LUTs / congestion_base).
    pub congestion_k: f64,
    pub congestion_base: f64,
    /// Minimum achievable period (global clocking / FF limits), ns.
    pub min_period_ns: f64,
}

/// The paper's evaluation part.
pub fn xcvu9p() -> Device {
    Device {
        name: "xcvu9p-flgb2104-2-i",
        luts: 1_182_240,
        ffs: 2_364_480,
        t_clk_ns: 0.25,
        t_lut_ns: 0.11,
        t_net_ns: 0.17,
        t_muxf_ns: 0.06,
        congestion_k: 0.22,
        congestion_base: 4096.0,
        // ~850 MHz: the practical global-clock ceiling on UltraScale+ -2
        // fabric (the paper's fastest design runs at 833 MHz).
        min_period_ns: 1.18,
    }
}

impl Device {
    /// Critical-path estimate for a combinational stage of `depth` LUT
    /// levels and `free_mux_levels` dedicated-mux levels inside a module of
    /// `module_luts` LUTs.
    pub fn stage_period_ns(&self, depth: u32, free_mux_levels: u32, module_luts: usize) -> f64 {
        if depth == 0 {
            return (self.t_clk_ns + self.t_net_ns).max(self.min_period_ns);
        }
        let congestion =
            1.0 + self.congestion_k * ((module_luts as f64 / self.congestion_base).max(1.0)).log2();
        (self.t_clk_ns
            + depth as f64 * (self.t_lut_ns + self.t_net_ns * congestion)
            + free_mux_levels as f64 * self.t_muxf_ns)
            .max(self.min_period_ns)
    }

    pub fn fmax_mhz(&self, period_ns: f64) -> f64 {
        1000.0 / period_ns
    }

    pub fn lut_pct(&self, luts: usize) -> f64 {
        100.0 * luts as f64 / self.luts as f64
    }

    pub fn ff_pct(&self, ffs: usize) -> f64 {
        100.0 * ffs as f64 / self.ffs as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn period_monotonic_in_depth_and_size() {
        let d = xcvu9p();
        let p1 = d.stage_period_ns(2, 1, 1000);
        let p2 = d.stage_period_ns(4, 3, 1000);
        let p3 = d.stage_period_ns(4, 3, 100_000);
        assert!(p2 > p1);
        assert!(p3 > p2);
        assert!(d.fmax_mhz(p1) > d.fmax_mhz(p2));
    }

    #[test]
    fn utilization_percentages() {
        let d = xcvu9p();
        assert!((d.lut_pct(40551) - 3.43).abs() < 0.01, "{}", d.lut_pct(40551));
        assert!((d.ff_pct(2837) - 0.12).abs() < 0.01);
    }
}
