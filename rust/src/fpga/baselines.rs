//! Implemented comparator area/latency models + published prior-work rows
//! (paper Table III).
//!
//! Two kinds of baselines appear in Table III:
//! 1. **LogicNets** — a special case of our own framework (A=1, D=1), so it
//!    is *fully implemented* by the main toolflow; nothing to model here.
//! 2. **FINN / hls4ml / Duarte / Fahim / Murovic** — external toolflows on
//!    the authors' testbeds.  We carry their published numbers verbatim
//!    (labelled `published`) and additionally provide first-order analytic
//!    area models of their datapaths (`modelled`) so ablation benches can
//!    vary geometry.  The substitution is documented in DESIGN.md §5.

/// A comparison row: either published by the cited paper or produced by one
/// of our analytic models.
#[derive(Debug, Clone)]
pub struct PriorRow {
    pub system: &'static str,
    pub dataset: &'static str,
    pub accuracy_pct: f64,
    pub luts: usize,
    pub ffs: usize,
    pub dsps: usize,
    pub brams: usize,
    pub fmax_mhz: f64,
    pub latency_ns: f64,
    pub provenance: &'static str, // "published" | "modelled"
}

/// Published rows from the paper's Table III (their cited sources).
pub fn published_rows() -> Vec<PriorRow> {
    vec![
        PriorRow { system: "PolyLUT (HDR, D=4)", dataset: "mnist", accuracy_pct: 96.0, luts: 70673, ffs: 4681, dsps: 0, brams: 0, fmax_mhz: 378.0, latency_ns: 16.0, provenance: "published" },
        PriorRow { system: "FINN", dataset: "mnist", accuracy_pct: 96.0, luts: 91131, ffs: 0, dsps: 0, brams: 5, fmax_mhz: 200.0, latency_ns: 310.0, provenance: "published" },
        PriorRow { system: "hls4ml", dataset: "mnist", accuracy_pct: 95.0, luts: 260092, ffs: 165513, dsps: 0, brams: 0, fmax_mhz: 200.0, latency_ns: 190.0, provenance: "published" },
        PriorRow { system: "PolyLUT (JSC-XL, D=4)", dataset: "jsc", accuracy_pct: 75.0, luts: 236541, ffs: 2775, dsps: 0, brams: 0, fmax_mhz: 235.0, latency_ns: 21.0, provenance: "published" },
        PriorRow { system: "Duarte et al.", dataset: "jsc", accuracy_pct: 75.0, luts: 887, ffs: 97, dsps: 954, brams: 0, fmax_mhz: 200.0, latency_ns: 75.0, provenance: "published" },
        PriorRow { system: "Fahim et al.", dataset: "jsc", accuracy_pct: 76.0, luts: 63251, ffs: 4394, dsps: 38, brams: 0, fmax_mhz: 200.0, latency_ns: 45.0, provenance: "published" },
        PriorRow { system: "PolyLUT (JSC-M Lite, D=6)", dataset: "jsc-lite", accuracy_pct: 72.0, luts: 12436, ffs: 773, dsps: 0, brams: 0, fmax_mhz: 646.0, latency_ns: 5.0, provenance: "published" },
        PriorRow { system: "LogicNets (JSC-M)", dataset: "jsc-lite", accuracy_pct: 72.0, luts: 37931, ffs: 810, dsps: 0, brams: 0, fmax_mhz: 427.0, latency_ns: 13.0, provenance: "published" },
        PriorRow { system: "PolyLUT (NID-Lite, D=4)", dataset: "nid", accuracy_pct: 92.0, luts: 3336, ffs: 686, dsps: 0, brams: 0, fmax_mhz: 529.0, latency_ns: 9.0, provenance: "published" },
        PriorRow { system: "LogicNets (NID)", dataset: "nid", accuracy_pct: 91.0, luts: 15949, ffs: 1274, dsps: 0, brams: 5, fmax_mhz: 471.0, latency_ns: 13.0, provenance: "published" },
        PriorRow { system: "Murovic et al.", dataset: "nid", accuracy_pct: 92.0, luts: 17990, ffs: 0, dsps: 0, brams: 0, fmax_mhz: 55.0, latency_ns: 18.0, provenance: "published" },
    ]
}

/// First-order FINN-style BNN MLP area model: per layer, XNOR gates are
/// absorbed into the popcount compressor tree (~n_in/3 LUT6 per neuron via
/// 6:3 compressors, plus log-depth carry), with a threshold comparator.
pub fn bnn_mlp_model(widths: &[usize], fold: usize, fmax_mhz: f64) -> PriorRow {
    let fold = fold.max(1);
    let mut luts = 0usize;
    let mut ffs = 0usize;
    let mut cycles = 0u32;
    for w in widths.windows(2) {
        let (n_in, n_out) = (w[0], w[1]);
        let popcount = (n_in as f64 / 3.0).ceil() as usize + (n_in as f64).log2().ceil() as usize;
        let threshold = ((n_in as f64).log2().ceil() as usize).max(1);
        // Folding time-multiplexes the PE array: 1/fold the datapath plus
        // accumulator/control overhead per physical neuron lane.
        let lanes = n_out.div_ceil(fold);
        luts += lanes * (popcount + threshold + 16);
        ffs += lanes * 8 + n_out;
        cycles += fold as u32 * ((n_in as f64).log2().ceil() as u32).max(1) / 2;
    }
    let period = 1000.0 / fmax_mhz;
    PriorRow {
        system: "BNN-MLP (modelled)",
        dataset: "-",
        accuracy_pct: f64::NAN,
        luts,
        ffs,
        dsps: 0,
        brams: 0,
        fmax_mhz,
        latency_ns: cycles.max(1) as f64 * period,
        provenance: "modelled",
    }
}

/// First-order hls4ml-style fixed-point MLP model: each MAC is a DSP at
/// reuse factor `reuse` (reuse>1 time-multiplexes), activations/control in
/// LUTs, pipeline registers per stage.
pub fn hls_mlp_model(widths: &[usize], bits: u32, reuse: usize, fmax_mhz: f64) -> PriorRow {
    let mut macs = 0usize;
    let mut ffs = 0usize;
    for w in widths.windows(2) {
        macs += w[0] * w[1];
        ffs += w[1] * bits as usize * 2;
    }
    let dsps = macs.div_ceil(reuse.max(1));
    // Control/activation/routing LUT overhead per DSP lane + per neuron.
    let luts = dsps * 25 + widths.iter().skip(1).sum::<usize>() * 8 * bits as usize / 8;
    let layers = widths.len() - 1;
    let period = 1000.0 / fmax_mhz;
    PriorRow {
        system: "hls4ml-MLP (modelled)",
        dataset: "-",
        accuracy_pct: f64::NAN,
        luts,
        ffs,
        dsps,
        brams: 0,
        fmax_mhz,
        latency_ns: (layers * (3 + reuse)) as f64 * period,
        provenance: "modelled",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn published_rows_cover_all_table3_datasets() {
        let rows = published_rows();
        for ds in ["mnist", "jsc", "jsc-lite", "nid"] {
            assert!(rows.iter().any(|r| r.dataset == ds), "missing {ds}");
        }
    }

    #[test]
    fn bnn_model_scales_with_width() {
        let small = bnn_mlp_model(&[784, 256, 10], 1, 200.0);
        let large = bnn_mlp_model(&[784, 1024, 1024, 10], 1, 200.0);
        assert!(large.luts > small.luts * 2);
        // FINN MNIST-scale network at moderate folding lands within ~3x of
        // the published row (91131 LUTs) — a sanity band, not a claim.
        let finn_like = bnn_mlp_model(&[784, 1024, 1024, 1024, 10], 16, 200.0);
        assert!(finn_like.luts > 30_000 && finn_like.luts < 300_000, "{}", finn_like.luts);
        // Folding trades latency for area.
        let folded = bnn_mlp_model(&[784, 1024, 10], 32, 200.0);
        let unfolded = bnn_mlp_model(&[784, 1024, 10], 1, 200.0);
        assert!(folded.luts < unfolded.luts / 8);
        assert!(folded.latency_ns > unfolded.latency_ns * 4.0);
    }

    #[test]
    fn hls_model_dsp_reuse_tradeoff() {
        let fast = hls_mlp_model(&[16, 64, 32, 5], 16, 1, 200.0);
        let slow = hls_mlp_model(&[16, 64, 32, 5], 16, 8, 200.0);
        assert!(fast.dsps > slow.dsps);
        assert!(fast.latency_ns < slow.latency_ns);
        // Duarte et al. JSC MLP used ~954 DSPs fully parallel on a similar
        // geometry: same order of magnitude.
        assert!(fast.dsps > 2000 && fast.dsps < 6000);
    }
}
