//! Cycle-accurate pipeline simulation (paper Fig. 5).
//!
//! Models the synthesized pipeline register structure clock-by-clock:
//! strategy (1) registers the Poly-layer and Adder-layer separately
//! (2 stages per layer when A > 1), strategy (2) merges them (1 stage per
//! layer).  Initiation interval is 1 everywhere — a new sample enters every
//! cycle — so the simulation validates both the latency-in-cycles numbers
//! of Table II/V and full-throughput streaming behaviour.

use crate::fpga::Strategy;
use crate::lut::tables::{pack_adder_addr, pack_poly_addr, NetworkTables};
use crate::nn::network::Network;

/// One pipeline stage: holds the registered value (codes) per in-flight slot.
enum Stage {
    /// Poly sub-stage of layer l: input = previous layer codes,
    /// output = sub-neuron codes [A * n_out].
    Poly { layer: usize },
    /// Adder sub-stage of layer l: input = sub codes, output = layer codes.
    Adder { layer: usize },
    /// Merged stage (strategy 2 or A == 1).
    Full { layer: usize },
}

/// Clock-by-clock model of the synthesized pipeline register structure
/// (paper Fig. 5); validates latency/II claims, not throughput.
pub struct PipelineSim<'a> {
    net: &'a Network,
    tables: &'a NetworkTables,
    stages: Vec<Stage>,
    /// regs[i] = value standing *after* stage i (None = bubble).
    regs: Vec<Option<Vec<i32>>>,
}

/// Outcome of streaming a batch through the pipeline at II = 1.
pub struct StreamResult {
    /// Latency of the first sample, in cycles (= pipeline depth).
    pub latency_cycles: u32,
    /// Total cycles to drain `n` samples (II=1 ⇒ latency + n - 1).
    pub total_cycles: u64,
    /// Per-sample output codes, in input order.
    pub outputs: Vec<Vec<i32>>,
}

impl<'a> PipelineSim<'a> {
    /// Build the stage structure for `net` under a pipeline `strategy`.
    pub fn new(net: &'a Network, tables: &'a NetworkTables, strategy: Strategy) -> Self {
        let mut stages = Vec::new();
        for l in 0..net.cfg.n_layers() {
            match strategy {
                Strategy::Merged => stages.push(Stage::Full { layer: l }),
                Strategy::SeparateRegisters => {
                    if net.cfg.a_factor > 1 {
                        stages.push(Stage::Poly { layer: l });
                        stages.push(Stage::Adder { layer: l });
                    } else {
                        stages.push(Stage::Full { layer: l });
                    }
                }
            }
        }
        let regs = (0..stages.len()).map(|_| None).collect();
        PipelineSim { net, tables, stages, regs }
    }

    /// Pipeline depth in stages (= first-sample latency in cycles).
    pub fn depth(&self) -> u32 {
        self.stages.len() as u32
    }

    fn eval_stage(&self, stage: &Stage, input: &[i32]) -> Vec<i32> {
        let cfg = &self.net.cfg;
        match *stage {
            Stage::Poly { layer } => {
                let lt = &self.tables.layers[layer];
                let n_out = cfg.widths[layer + 1];
                let mut out = Vec::with_capacity(cfg.a_factor * n_out);
                for j in 0..n_out {
                    for (a, t) in lt.neurons[j].poly.iter().enumerate() {
                        let gathered: Vec<i32> = self.net.layers[layer].indices[a][j]
                            .iter()
                            .map(|&s| input[s])
                            .collect();
                        out.push(t.code_at(pack_poly_addr(&gathered, lt.in_bits)));
                    }
                }
                out
            }
            Stage::Adder { layer } => {
                let lt = &self.tables.layers[layer];
                let n_out = cfg.widths[layer + 1];
                let a = cfg.a_factor;
                (0..n_out)
                    .map(|j| {
                        let subs = &input[j * a..(j + 1) * a];
                        lt.neurons[j]
                            .adder
                            .as_ref()
                            .expect("Adder stages are only scheduled when A > 1")
                            .code_at(pack_adder_addr(
                            subs,
                            lt.sub_bits,
                        ))
                    })
                    .collect()
            }
            Stage::Full { layer } => {
                let lt = &self.tables.layers[layer];
                let n_out = cfg.widths[layer + 1];
                (0..n_out)
                    .map(|j| {
                        let nt = &lt.neurons[j];
                        let subs: Vec<i32> = nt
                            .poly
                            .iter()
                            .enumerate()
                            .map(|(a, t)| {
                                let gathered: Vec<i32> = self.net.layers[layer].indices[a][j]
                                    .iter()
                                    .map(|&s| input[s])
                                    .collect();
                                t.code_at(pack_poly_addr(&gathered, lt.in_bits))
                            })
                            .collect();
                        match &nt.adder {
                            Some(adder) => adder.code_at(pack_adder_addr(&subs, lt.sub_bits)),
                            None => subs[0],
                        }
                    })
                    .collect()
            }
        }
    }

    /// One clock edge: shift every stage (back to front), feed `input`.
    /// Returns the output emerging this cycle, if any.
    pub fn tick(&mut self, input: Option<Vec<i32>>) -> Option<Vec<i32>> {
        let out = self.regs.last().cloned().flatten();
        for i in (1..self.stages.len()).rev() {
            self.regs[i] = self.regs[i - 1]
                .take()
                .map(|v| self.eval_stage(&self.stages[i], &v));
        }
        self.regs[0] = input.map(|v| self.eval_stage(&self.stages[0], &v));
        out
    }

    /// Stream a batch of input-code vectors through at II=1.
    pub fn stream(&mut self, inputs: &[Vec<i32>]) -> StreamResult {
        let mut outputs = Vec::with_capacity(inputs.len());
        let mut first_latency = None;
        let mut cycle = 0u64;
        let mut fed = 0usize;
        while outputs.len() < inputs.len() {
            let input = if fed < inputs.len() {
                fed += 1;
                Some(inputs[fed - 1].clone())
            } else {
                None
            };
            if let Some(out) = self.tick(input) {
                if first_latency.is_none() {
                    first_latency = Some(cycle as u32);
                }
                outputs.push(out);
            }
            cycle += 1;
        }
        StreamResult {
            latency_cycles: first_latency.unwrap_or(0),
            total_cycles: cycle,
            outputs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lut::tables::compile_network;
    use crate::nn::config;
    use crate::sim::lutsim::LutSim;
    use crate::util::rng::Rng;

    fn net(a: usize) -> Network {
        let cfg = config::uniform("t", &[8, 6, 3], 2, 2, 3, 3, 3, 2, a, 3);
        Network::random(&cfg, &mut Rng::new(a as u64))
    }

    #[test]
    fn latency_matches_paper_cycle_counts() {
        // JSC-M Lite case study (Table V): 3 layers, strategy 2 = 3 cycles,
        // strategy 1 with A>1 = 6 cycles.
        let n = net(2);
        let tables = compile_network(&n, 1);
        let inputs: Vec<Vec<i32>> = (0..5).map(|i| vec![(i % 4) as i32; 8]).collect();
        let mut s2 = PipelineSim::new(&n, &tables, Strategy::Merged);
        let r2 = s2.stream(&inputs);
        assert_eq!(r2.latency_cycles, 2); // 2 layers in the tiny net
        let mut s1 = PipelineSim::new(&n, &tables, Strategy::SeparateRegisters);
        let r1 = s1.stream(&inputs);
        assert_eq!(r1.latency_cycles, 4);
        // II = 1: draining n samples takes latency + n cycles.
        assert_eq!(r2.total_cycles, r2.latency_cycles as u64 + inputs.len() as u64);
    }

    #[test]
    fn pipeline_outputs_match_lutsim_both_strategies() {
        for a in [1, 2] {
            let n = net(a);
            let tables = compile_network(&n, 1);
            let sim = LutSim::new(&n, &tables);
            let mut rng = Rng::new(9);
            let inputs: Vec<Vec<i32>> = (0..20)
                .map(|_| (0..8).map(|_| rng.below(4) as i32).collect())
                .collect();
            for strat in [Strategy::Merged, Strategy::SeparateRegisters] {
                let mut p = PipelineSim::new(&n, &tables, strat);
                let r = p.stream(&inputs);
                assert_eq!(r.outputs.len(), inputs.len());
                for (inp, out) in inputs.iter().zip(&r.outputs) {
                    assert_eq!(out, &sim.forward_codes(inp), "A={a} {strat:?}");
                }
            }
        }
    }
}
