//! Deployed-semantics simulators: the LUT-network evaluators (software twin
//! of the FPGA datapath) and the cycle-accurate pipeline model.
//!
//! Four evaluators, one contract (bit-exact with `Network::forward_codes`):
//!
//! - [`plan::EvalPlan`] — the **latency engine**.  A precompiled execution
//!   plan: per layer, one flat `Vec<i32>` of decoded table words (sub-neuron
//!   `(j, a)` at offset `(j·A + a)·2^{β·F}`, adder table of neuron `j` at
//!   `j·2^{A(β+1)}`) plus one flat gather-index array, executed over
//!   reusable double-buffered [`plan::Scratch`] so a forward pass performs
//!   no heap allocation.  Lowest per-sample latency; serves small batches.
//! - [`bitslice::BitsliceNet`] — the **throughput engine**.  The mapped
//!   LUT6 netlists compiled into flat per-layer op streams and evaluated
//!   bit-parallel, 64 samples per `u64` word, with transposition only at
//!   the network edge and ragged tails masked ([`bitslice::lane_mask`]).
//! - [`lutsim::LutSim`] — compatibility shim over the plan, plus the
//!   original naive table walk (`forward_codes_reference`) kept as an
//!   independent cross-check and benchmark baseline.
//! - [`cycle::PipelineSim`] — clock-accurate pipeline-register model
//!   (paper Fig. 5) validating latency/II claims, not throughput.
//!
//! [`EngineSelect`] is the plan-vs-bitslice routing policy the coordinator's
//! `Backend::Lut` applies per batch.

pub mod bitslice;
pub mod cycle;
pub mod lutsim;
pub mod plan;

pub use bitslice::{lane_mask, BitsliceNet, BitsliceScratch, BitsliceStats, WORD};
pub use cycle::PipelineSim;
pub use lutsim::LutSim;
pub use plan::{EvalPlan, Scratch};

/// Which batched LUT engine executes a batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LutEngine {
    /// Gather + decoded-table lookup per sample ([`EvalPlan`]).
    Plan,
    /// 64-sample-per-word bit-parallel netlist evaluation ([`BitsliceNet`]).
    Bitslice,
}

/// Plan-vs-bitslice selection policy: batches of at least `crossover`
/// samples run bitsliced, smaller (latency-sensitive) ones through the
/// plan.  `0` forces bitslice for every batch; `usize::MAX` disables it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EngineSelect {
    pub crossover: usize,
}

impl EngineSelect {
    /// Default crossover: two full 64-sample words — below that the
    /// transposition overhead and partially-filled lanes eat the win.
    pub const DEFAULT_CROSSOVER: usize = 2 * WORD;

    pub fn auto() -> EngineSelect {
        EngineSelect { crossover: Self::DEFAULT_CROSSOVER }
    }

    /// Never route to the bitsliced engine.
    pub fn plan_only() -> EngineSelect {
        EngineSelect { crossover: usize::MAX }
    }

    /// Route every batch to the bitsliced engine.
    pub fn bitslice_only() -> EngineSelect {
        EngineSelect { crossover: 0 }
    }

    pub fn pick(&self, batch_len: usize) -> LutEngine {
        if batch_len >= self.crossover {
            LutEngine::Bitslice
        } else {
            LutEngine::Plan
        }
    }
}

impl Default for EngineSelect {
    fn default() -> Self {
        Self::auto()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_select_routes_on_batch_size() {
        let sel = EngineSelect::auto();
        assert_eq!(sel.pick(1), LutEngine::Plan);
        assert_eq!(sel.pick(EngineSelect::DEFAULT_CROSSOVER - 1), LutEngine::Plan);
        assert_eq!(sel.pick(EngineSelect::DEFAULT_CROSSOVER), LutEngine::Bitslice);
        assert_eq!(EngineSelect::plan_only().pick(1 << 20), LutEngine::Plan);
        assert_eq!(EngineSelect::bitslice_only().pick(0), LutEngine::Bitslice);
    }
}
