//! Deployed-semantics simulators: the LUT-network evaluator (software twin
//! of the FPGA datapath) and the cycle-accurate pipeline model.

pub mod cycle;
pub mod lutsim;

pub use cycle::PipelineSim;
pub use lutsim::LutSim;
