//! Deployed-semantics simulators: the LUT-network evaluators (software twin
//! of the FPGA datapath) and the cycle-accurate pipeline model.
//!
//! Three evaluators, one contract (bit-exact with `Network::forward_codes`):
//!
//! - [`plan::EvalPlan`] — the **hot path**.  A precompiled execution plan:
//!   per layer, one flat `Vec<i32>` of decoded table words (sub-neuron
//!   `(j, a)` at offset `(j·A + a)·2^{β·F}`, adder table of neuron `j` at
//!   `j·2^{A(β+1)}`) plus one flat gather-index array, executed over
//!   reusable double-buffered [`plan::Scratch`] so a forward pass performs
//!   no heap allocation.  Batched entry points walk samples in blocks for
//!   cache locality and fan blocks out over worker threads; the
//!   coordinator's `Backend::Lut` serves from this.
//! - [`lutsim::LutSim`] — compatibility shim over the plan, plus the
//!   original naive table walk (`forward_codes_reference`) kept as an
//!   independent cross-check and benchmark baseline.
//! - [`cycle::PipelineSim`] — clock-accurate pipeline-register model
//!   (paper Fig. 5) validating latency/II claims, not throughput.

pub mod cycle;
pub mod lutsim;
pub mod plan;

pub use cycle::PipelineSim;
pub use lutsim::LutSim;
pub use plan::{EvalPlan, Scratch};
