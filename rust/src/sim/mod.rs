//! Deployed-semantics simulators: the LUT-network evaluators (software twin
//! of the FPGA datapath) and the cycle-accurate pipeline model.
//!
//! Five evaluators, one contract (bit-exact with `Network::forward_codes`):
//!
//! - [`plan::EvalPlan`] — the **latency engine**.  A precompiled execution
//!   plan: per layer, one flat `Vec<i32>` of decoded table words (sub-neuron
//!   `(j, a)` at offset `(j·A + a)·2^{β·F}`, adder table of neuron `j` at
//!   `j·2^{A(β+1)}`) plus one flat gather-index array, executed over
//!   reusable double-buffered [`plan::Scratch`] so a forward pass performs
//!   no heap allocation.  Lowest per-sample latency; serves small batches.
//! - [`bitslice::BitsliceNet`] — the **throughput engine**.  The mapped
//!   LUT6 netlists compiled into flat per-layer op streams and evaluated
//!   bit-parallel, 64 samples per `u64` word, with transposition only at
//!   the network edge and ragged tails masked ([`bitslice::lane_mask`]).
//! - [`shard::ShardedModel`] — the **intra-sample parallel engine**: both
//!   of the above partitioned across S shards (neuron ranges for the plan,
//!   bit-plane ranges for the bitslice op streams) after cache-aware neuron
//!   reordering, with double-buffered handoff buffers and fan-in-aware
//!   early start.  One sample's forward pass itself runs in parallel — the
//!   low-latency route on multi-core hosts and the template for multi-node
//!   sharding.
//! - [`lutsim::LutSim`] — compatibility shim over the plan, plus the
//!   original naive table walk (`forward_codes_reference`) kept as an
//!   independent cross-check and benchmark baseline.
//! - [`cycle::PipelineSim`] — clock-accurate pipeline-register model
//!   (paper Fig. 5) validating latency/II claims, not throughput.
//!
//! [`EngineSelect`] is the per-batch routing policy the coordinator's
//! `Backend::Lut` applies.  The shard handoff is transport-abstracted:
//! [`wire`] frames the boundary bit-planes over TCP so individual shards
//! of [`shard::ShardedModel`] can live on remote `polylut shard-worker`
//! processes (`--shard-hosts` placement).  Since wire handoff v2 each
//! link is a pipelined, windowed stream ([`WireConfig`]: in-flight window
//! + reconnect-and-resume retry budget) instead of a lock-step per-layer
//! conversation.  The data layouts, crossover policy, wire protocol and a
//! request's life through the stack are documented in `ARCHITECTURE.md`
//! at the repository root.

#![warn(missing_docs)]

pub mod bitslice;
pub mod cycle;
pub mod lutsim;
pub mod plan;
pub mod shard;
pub mod verify;
pub mod wire;

pub use bitslice::{lane_mask, BitsliceNet, BitsliceScratch, BitsliceStats, WideScratch, WORD};
pub use cycle::PipelineSim;
pub use lutsim::LutSim;
pub use plan::{EvalPlan, Scratch};
pub use shard::{
    resolve_spin_us, ShardStats, ShardedBitslice, ShardedModel, ShardedPlan, DEFAULT_SPIN_US,
};
pub use verify::{ArtifactKind, Report, Violation};
pub use wire::{
    parse_shard_hosts, ShardPlacement, ShardWorkerHost, WireConfig, WireHostStats,
    WireStats, DEFAULT_WIRE_RETRIES, DEFAULT_WIRE_WINDOW,
};

/// Which batched LUT engine executes a batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LutEngine {
    /// Gather + decoded-table lookup per sample ([`EvalPlan`]).
    Plan,
    /// Bit-parallel netlist evaluation, 64–512 samples per word at the
    /// compiled lane width ([`BitsliceNet`], [`crate::simd::LanePlan`]).
    Bitslice,
    /// Intra-sample sharded execution ([`ShardedModel`]): the batch is
    /// below the bitslice crossover but S > 1 shards can parallelize each
    /// sample (or each ≤64-sample word) internally.
    Sharded,
}

/// Per-batch engine selection policy: batches of at least `crossover`
/// samples run bitsliced (batch-parallel); smaller, latency-sensitive
/// batches run through the sharded engines when `shards > 1`, else through
/// the plan.  `crossover = 0` forces bitslice for every batch;
/// `usize::MAX` disables it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EngineSelect {
    /// Batch size at which the bitsliced engine takes over.
    pub crossover: usize,
    /// Intra-sample shard count (1 = sharding disabled).  When a backend is
    /// built with `shards > 1` its `FrozenModel` must carry a compiled
    /// [`ShardedModel`].
    pub shards: usize,
}

impl EngineSelect {
    /// The historical 64-lane default crossover (two full 64-sample
    /// words — below that the transposition overhead and partially-filled
    /// lanes eat the win).  Kept as the floor of
    /// [`EngineSelect::default_crossover_for`]; the live default scales
    /// with the detected lane width.
    pub const DEFAULT_CROSSOVER: usize = 2 * WORD;

    /// Default crossover for an engine running `lanes` samples per word:
    /// two full words.  Wider words raise the bar — a 256-lane batch walk
    /// wastes 3/4 of its lanes on a 64-sample batch, so the plan (or the
    /// sharded engine) keeps sub-crossover traffic.
    pub fn default_crossover_for(lanes: usize) -> usize {
        2 * lanes.max(WORD)
    }

    /// The default policy: crossover derived from the widest detected lane
    /// width ([`crate::simd::widest_lanes`]), sharding disabled.
    pub fn auto() -> EngineSelect {
        Self::auto_for_lanes(crate::simd::widest_lanes())
    }

    /// The default policy for an engine compiled at `lanes` samples per
    /// word: crossover at two full words, sharding disabled.
    pub fn auto_for_lanes(lanes: usize) -> EngineSelect {
        EngineSelect { crossover: Self::default_crossover_for(lanes), shards: 1 }
    }

    /// Never route to the bitsliced engine.
    pub fn plan_only() -> EngineSelect {
        EngineSelect { crossover: usize::MAX, shards: 1 }
    }

    /// Route every batch to the bitsliced engine.
    pub fn bitslice_only() -> EngineSelect {
        EngineSelect { crossover: 0, shards: 1 }
    }

    /// The width-derived default crossover with intra-sample sharding over
    /// `shards` shards for sub-crossover batches.
    pub fn with_shards(shards: usize) -> EngineSelect {
        EngineSelect {
            crossover: Self::default_crossover_for(crate::simd::widest_lanes()),
            shards: shards.max(1),
        }
    }

    /// Route a batch of `batch_len` samples to an engine.
    pub fn pick(&self, batch_len: usize) -> LutEngine {
        if batch_len >= self.crossover {
            LutEngine::Bitslice
        } else if self.shards > 1 {
            LutEngine::Sharded
        } else {
            LutEngine::Plan
        }
    }
}

impl Default for EngineSelect {
    fn default() -> Self {
        Self::auto()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_select_routes_on_batch_size() {
        let sel = EngineSelect::auto();
        assert_eq!(sel.pick(1), LutEngine::Plan);
        assert_eq!(sel.pick(sel.crossover - 1), LutEngine::Plan);
        assert_eq!(sel.pick(sel.crossover), LutEngine::Bitslice);
        assert_eq!(EngineSelect::plan_only().pick(1 << 20), LutEngine::Plan);
        assert_eq!(EngineSelect::bitslice_only().pick(0), LutEngine::Bitslice);
    }

    #[test]
    fn engine_select_routes_small_batches_to_shards() {
        let sel = EngineSelect::with_shards(4);
        assert_eq!(sel.shards, 4);
        assert_eq!(sel.pick(1), LutEngine::Sharded);
        assert_eq!(sel.pick(sel.crossover - 1), LutEngine::Sharded);
        // At and above the crossover, batch-parallel bitslice still wins.
        assert_eq!(sel.pick(sel.crossover), LutEngine::Bitslice);
        // shards = 1 degrades to the plain policy.
        assert_eq!(EngineSelect::with_shards(1).pick(1), LutEngine::Plan);
    }

    #[test]
    fn default_crossover_scales_with_lane_width() {
        assert_eq!(EngineSelect::default_crossover_for(64), EngineSelect::DEFAULT_CROSSOVER);
        assert_eq!(EngineSelect::default_crossover_for(128), 256);
        assert_eq!(EngineSelect::default_crossover_for(512), 1024);
        // Degenerate widths floor at one 64-lane word.
        assert_eq!(EngineSelect::default_crossover_for(0), 128);
        let auto = EngineSelect::auto();
        assert_eq!(
            auto.crossover,
            EngineSelect::default_crossover_for(crate::simd::widest_lanes())
        );
        assert_eq!(EngineSelect::auto_for_lanes(64).crossover, 128);
    }
}
