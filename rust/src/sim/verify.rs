//! Static verification of compiled artifacts.
//!
//! Every engine in this crate executes a *compiled artifact* — the
//! [`EvalPlan`] gather tables, the [`BitsliceNet`] op streams, the sharded
//! kernels' `(shard, threshold)` hazard schedules, and the `wire_plan`
//! needs/result schedules.  Their correctness was previously pinned only by
//! runtime bit-exactness tests and a randomized interleaving simulation of
//! the handoff protocol.  PolyLUT-Add's core premise is that the LUT
//! network is a *statically known* dataflow graph, so the structural
//! invariants of every artifact can be **proved by static analysis at
//! compile time** instead of sampled at run time.
//!
//! Four checkers, one per artifact kind (full invariant tables in
//! `ARCHITECTURE.md` §8):
//!
//! - **plan** ([`verify_plan`]): every gather index in-bounds for its
//!   source layer width, per-sub-neuron strides consistent with the
//!   decoded table sizes, scratch sizing sufficient for the widest layer.
//! - **op-stream** ([`verify_bitslice`], [`verify_shard_streams`]):
//!   operands defined before use (topological order), operand/plane
//!   indices in-bounds, `Group` membership consistent with its mask store,
//!   no dead writes, and full coverage of each layer's output planes —
//!   both for the whole-layer streams and the sharded `flatten_cone`
//!   re-flattened streams.  Since the SIMD widening the checker also
//!   validates the engine's lane-width metadata: the declared width is a
//!   supported multiple of 64 consistent with the carried
//!   [`crate::simd::LanePlan`] (`lane-width`), and the scratch plane-block
//!   count matches it (`scratch-blocks`).
//! - **hazard schedule** ([`verify_hazards`]): recompute the per-boundary
//!   read/write sets from the kernels' retained specs and check that the
//!   three hazard classes (producer, previous-generation reader,
//!   generation writer) are each dominated by a stored `(shard,
//!   threshold)` dependency, and that the cross-cell dependency graph is
//!   acyclic — a static proof alongside the randomized interleaving test.
//! - **wire-plan** ([`verify_wire_plans`]): per-shard needs runs cover
//!   every cross-shard read exactly once (no gap, no overlap), runs are
//!   sorted and maximally merged, producers and `(deps, counts)` match,
//!   and flightless boundaries ship nothing.
//!
//! Violations are reported as structured [`Violation`] diagnostics
//! (artifact kind, layer/boundary, offending index, invariant name) —
//! never panics.  The compile paths (`FrozenModel::from_network*`,
//! `ShardedModel::compile_placed*`) run the relevant checkers behind
//! [`gate_enabled`]: always on in debug builds, opt-in for release via
//! `POLYLUT_VERIFY=1`.  The `polylut verify` CLI subcommand prints the
//! per-artifact [`Report`] for a model config.

use std::fmt;
use std::ops::Range;

use anyhow::Result;

use crate::lut::tables::NetworkTables;
use crate::nn::network::Network;

use super::bitslice::{BitsliceNet, Op, OpStream};
use super::plan::EvalPlan;
use super::shard::{
    bits_kernel_of, permuted_for_shards, plan_kernel_of, BitsliceKernel, PlanKernel, ShardKernel,
};
use super::wire::{wire_plan, WirePlan};

// ---------------------------------------------------------------------------
// Diagnostics
// ---------------------------------------------------------------------------

/// Which compiled artifact a [`Violation`] was found in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    /// [`EvalPlan`] gather/table layout.
    Plan,
    /// A bitslice op stream (whole-layer or per-shard re-flattened cone).
    OpStream,
    /// A sharded kernel's `(shard, threshold)` hazard schedule.
    Hazard,
    /// A remote shard's `wire_plan` needs/result schedule.
    Wire,
    /// A sharded kernel's per-epoch buffer-slot layout (the Wire-v3 epoch
    /// ring reuses slots across epochs W apart; isolation requires each
    /// epoch's reads to be closed over that epoch's own writes).
    EpochRing,
    /// A folded (`lut::opt`) netlist checked against its unfolded baseline.
    NetlistOpt,
}

impl fmt::Display for ArtifactKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            ArtifactKind::Plan => "plan",
            ArtifactKind::OpStream => "op-stream",
            ArtifactKind::Hazard => "hazard-schedule",
            ArtifactKind::Wire => "wire-plan",
            ArtifactKind::EpochRing => "epoch-ring",
            ArtifactKind::NetlistOpt => "netlist-opt",
        })
    }
}

/// One structural invariant violation, reported as data — the checkers
/// never panic on a corrupt artifact.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    /// Artifact kind the violation was found in.
    pub artifact: ArtifactKind,
    /// Stable machine-readable name of the invariant that failed
    /// (e.g. `"gather-bounds"`, `"undef-operand"`, `"producer-dep"`).
    pub invariant: &'static str,
    /// Layer (or boundary) the violation is anchored at.
    pub layer: usize,
    /// Offending index within the layer: a gather/op/run index, buffer
    /// position, or shard — see `detail` for the interpretation.
    pub index: usize,
    /// Human-readable description of the failure.
    pub detail: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} L{}[{}] {}: {}",
            self.artifact, self.layer, self.index, self.invariant, self.detail
        )
    }
}

fn v(
    artifact: ArtifactKind,
    invariant: &'static str,
    layer: usize,
    index: usize,
    detail: String,
) -> Violation {
    Violation { artifact, invariant, layer, index, detail }
}

/// Aggregated verification outcome over one or more artifacts, grouped
/// into labelled sections for per-artifact reporting.
#[derive(Debug, Default)]
pub struct Report {
    sections: Vec<(String, Vec<Violation>)>,
}

impl Report {
    /// Append a labelled section of checker output.
    pub fn section(&mut self, label: &str, violations: Vec<Violation>) {
        self.sections.push((label.to_string(), violations));
    }

    /// Whether no checker reported a violation.
    pub fn is_clean(&self) -> bool {
        self.sections.iter().all(|(_, vs)| vs.is_empty())
    }

    /// Total violation count across all sections.
    pub fn total(&self) -> usize {
        self.sections.iter().map(|(_, vs)| vs.len()).sum()
    }

    /// All violations, in section order.
    pub fn violations(&self) -> Vec<&Violation> {
        self.sections.iter().flat_map(|(_, vs)| vs).collect()
    }

    /// Number of labelled sections recorded so far.
    pub fn sections_len(&self) -> usize {
        self.sections.len()
    }

    /// Consume the report, yielding its labelled sections — for callers
    /// that relabel or merge sections into another report (the CLI).
    pub fn into_sections(self) -> Vec<(String, Vec<Violation>)> {
        self.sections
    }

    /// Render one line per section (`OK` or the violation list).
    pub fn render(&self) -> String {
        let mut s = String::new();
        for (label, vs) in &self.sections {
            if vs.is_empty() {
                s.push_str(&format!("{label}: OK\n"));
            } else {
                s.push_str(&format!("{label}: {} violation(s)\n", vs.len()));
                for viol in vs {
                    s.push_str(&format!("  {viol}\n"));
                }
            }
        }
        s
    }

    /// Turn the report into a compile error when any violation is present.
    pub fn gate(&self) -> Result<()> {
        anyhow::ensure!(self.is_clean(), "artifact verification failed:\n{}", self.render());
        Ok(())
    }
}

/// Whether the compile-time verification gate is active: always in debug
/// builds; opt-in for release builds via the `POLYLUT_VERIFY` environment
/// variable (any non-empty value other than `0`).
pub fn gate_enabled() -> bool {
    if cfg!(debug_assertions) {
        return true;
    }
    matches!(std::env::var("POLYLUT_VERIFY"), Ok(val) if !val.is_empty() && val != "0")
}

// ---------------------------------------------------------------------------
// Checker 1: EvalPlan gather/table layout
// ---------------------------------------------------------------------------

/// `stride == 2^bits`, without overflowing when `bits` is corrupt.
fn pow2_matches(stride: usize, bits: u64) -> bool {
    bits < usize::BITS as u64 && stride == 1usize << bits
}

/// Check an [`EvalPlan`]: gather indices in-bounds for their source layer
/// width, strides consistent with decoded table sizes, scratch sizing
/// sufficient for the widest layer.
pub fn verify_plan(plan: &EvalPlan) -> Vec<Violation> {
    let art = ArtifactKind::Plan;
    let mut out = Vec::new();
    if plan.widths.len() != plan.layers.len() + 1 {
        out.push(v(
            art,
            "layer-count",
            0,
            plan.widths.len(),
            format!("{} boundary widths for {} layers", plan.widths.len(), plan.layers.len()),
        ));
        return out; // the layout below is uninterpretable
    }
    let widest = plan.widths.iter().copied().max().unwrap_or(0);
    if plan.max_width < widest {
        out.push(v(
            art,
            "scratch-width",
            0,
            plan.max_width,
            format!("scratch sized for width {} but the widest boundary is {widest}", plan.max_width),
        ));
    }
    for (l, lp) in plan.layers.iter().enumerate() {
        let w_in = plan.widths[l];
        if lp.n_out != plan.widths[l + 1] {
            out.push(v(
                art,
                "layer-width",
                l,
                lp.n_out,
                format!("layer emits {} neurons but boundary {} is {} wide", lp.n_out, l + 1, plan.widths[l + 1]),
            ));
        }
        if !pow2_matches(lp.poly_stride, lp.in_bits as u64 * lp.fan as u64) {
            out.push(v(
                art,
                "poly-stride",
                l,
                lp.poly_stride,
                format!("poly stride {} != 2^(β·F) = 2^({}·{})", lp.poly_stride, lp.in_bits, lp.fan),
            ));
        }
        let adder_ok = if lp.a > 1 {
            pow2_matches(lp.adder_stride, lp.a as u64 * lp.sub_bits as u64)
        } else {
            lp.adder_stride == 0
        };
        if !adder_ok {
            out.push(v(
                art,
                "adder-stride",
                l,
                lp.adder_stride,
                format!("adder stride {} inconsistent with A={} sub_bits={}", lp.adder_stride, lp.a, lp.sub_bits),
            ));
        }
        if lp.gather.len() != lp.n_out * lp.a * lp.fan {
            out.push(v(
                art,
                "gather-len",
                l,
                lp.gather.len(),
                format!("{} gather slots for {}·{}·{} sub-neuron inputs", lp.gather.len(), lp.n_out, lp.a, lp.fan),
            ));
        }
        if lp.poly.len() != lp.n_out * lp.a * lp.poly_stride {
            out.push(v(
                art,
                "poly-len",
                l,
                lp.poly.len(),
                format!("{} poly words, expected {}·{}·{}", lp.poly.len(), lp.n_out, lp.a, lp.poly_stride),
            ));
        }
        let want_adder = if lp.a > 1 { lp.n_out * lp.adder_stride } else { 0 };
        if lp.adder.len() != want_adder {
            out.push(v(
                art,
                "adder-len",
                l,
                lp.adder.len(),
                format!("{} adder words, expected {want_adder}", lp.adder.len()),
            ));
        }
        for (i, &g) in lp.gather.iter().enumerate() {
            if g as usize >= w_in {
                out.push(v(
                    art,
                    "gather-bounds",
                    l,
                    i,
                    format!("gather slot {i} reads source {g} but layer {l} is only {w_in} wide"),
                ));
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Checker 2: op streams (whole-layer and per-shard cones)
// ---------------------------------------------------------------------------

fn use_operand(
    layer: usize,
    i: usize,
    slot: u32,
    defined: &[bool],
    used: &mut [bool],
    out: &mut Vec<Violation>,
) {
    let art = ArtifactKind::OpStream;
    let n = defined.len();
    if slot as usize >= n {
        out.push(v(art, "slot-bounds", layer, i, format!("op {i} reads slot {slot} of {n} nodes")));
    } else if !defined[slot as usize] {
        out.push(v(
            art,
            "undef-operand",
            layer,
            i,
            format!("op {i} reads slot {slot} before it is defined"),
        ));
    } else {
        used[slot as usize] = true;
    }
}

fn define_slot(layer: usize, i: usize, slot: u32, defined: &mut [bool], out: &mut Vec<Violation>) {
    let art = ArtifactKind::OpStream;
    let n = defined.len();
    if slot as usize >= n {
        out.push(v(art, "slot-bounds", layer, i, format!("op {i} writes slot {slot} of {n} nodes")));
    } else if defined[slot as usize] {
        out.push(v(art, "multi-def", layer, i, format!("op {i} redefines slot {slot}")));
    } else {
        defined[slot as usize] = true;
    }
}

/// Walk one op stream in emission order, checking define-before-use,
/// index bounds, and `Group` consistency.  Returns the per-slot
/// `(defined, used)` flags so the caller can fold in roots before the
/// dead-write / coverage pass ([`finish_stream`]).
fn check_stream_core(
    layer: usize,
    stream: &OpStream,
    in_planes: usize,
    out: &mut Vec<Violation>,
) -> (Vec<bool>, Vec<bool>) {
    let art = ArtifactKind::OpStream;
    let n = stream.n_nodes;
    let mut defined = vec![false; n];
    let mut used = vec![false; n];
    if stream.lut_masks.len() != stream.lut_nodes.len() {
        out.push(v(
            art,
            "group-store",
            layer,
            stream.lut_nodes.len(),
            format!("{} group member slots but {} masks", stream.lut_nodes.len(), stream.lut_masks.len()),
        ));
    }
    // Bound input planes are defined before any op executes.
    for (i, &(slot, wire)) in stream.bind.iter().enumerate() {
        if wire as usize >= in_planes {
            out.push(v(
                art,
                "bind-wire-bounds",
                layer,
                i,
                format!("bind {i} reads input plane {wire} of {in_planes}"),
            ));
        }
        define_slot(layer, i, slot, &mut defined, out);
    }
    for (i, op) in stream.ops.iter().enumerate() {
        match op {
            Op::Const { out: o, .. } => define_slot(layer, i, *o, &mut defined, out),
            Op::Lut { out: o, n_in, ins, .. } => {
                if *n_in as usize > ins.len() {
                    out.push(v(art, "fanin-bounds", layer, i, format!("LUT op {i} claims {n_in} inputs")));
                }
                for &s in ins.iter().take((*n_in as usize).min(ins.len())) {
                    use_operand(layer, i, s, &defined, &mut used, out);
                }
                define_slot(layer, i, *o, &mut defined, out);
            }
            Op::Mux { out: o, sel, lo, hi } => {
                for &s in &[*sel, *lo, *hi] {
                    use_operand(layer, i, s, &defined, &mut used, out);
                }
                define_slot(layer, i, *o, &mut defined, out);
            }
            Op::Group { n_in, ins, start, len } => {
                if *n_in as usize > ins.len() {
                    out.push(v(art, "fanin-bounds", layer, i, format!("group op {i} claims {n_in} inputs")));
                }
                for &s in ins.iter().take((*n_in as usize).min(ins.len())) {
                    use_operand(layer, i, s, &defined, &mut used, out);
                }
                if *len < 2 {
                    out.push(v(
                        art,
                        "group-size",
                        layer,
                        i,
                        format!("group op {i} has {len} members (singletons must be plain LUT ops)"),
                    ));
                }
                let (g0, g1) = (*start as usize, *start as usize + *len as usize);
                if g1 > stream.lut_nodes.len() {
                    out.push(v(
                        art,
                        "group-range",
                        layer,
                        i,
                        format!("group op {i} spans members {g0}..{g1} of {}", stream.lut_nodes.len()),
                    ));
                } else {
                    for m in g0..g1 {
                        define_slot(layer, i, stream.lut_nodes[m], &mut defined, out);
                    }
                }
            }
        }
    }
    (defined, used)
}

/// Coverage pass after roots are folded into `used`: every local slot must
/// be defined exactly once, and every defined slot must be consumed by an
/// op or exported as a root (no dead writes).
fn finish_stream(
    layer: usize,
    stream: &OpStream,
    defined: &[bool],
    used: &[bool],
    out: &mut Vec<Violation>,
) {
    let art = ArtifactKind::OpStream;
    for slot in 0..stream.n_nodes {
        if !defined[slot] {
            out.push(v(art, "undefined-slot", layer, slot, format!("slot {slot} is never written")));
        } else if !used[slot] {
            out.push(v(
                art,
                "dead-write",
                layer,
                slot,
                format!("slot {slot} is written but never read and is not a root"),
            ));
        }
    }
}

/// Check the whole-layer op streams of a [`BitsliceNet`]: per-layer
/// define-before-use, bounds, group consistency, no dead writes, and full
/// coverage of each layer's `n_out · out_bits` output planes.
pub fn verify_bitslice(net: &BitsliceNet) -> Vec<Violation> {
    let art = ArtifactKind::OpStream;
    let mut out = Vec::new();
    if !crate::simd::SUPPORTED_LANES.contains(&net.lanes) || net.plan.lanes != net.lanes {
        out.push(v(
            art,
            "lane-width",
            0,
            net.lanes,
            format!(
                "declared lane width {} must be one of {:?} and match the lane plan ({})",
                net.lanes,
                crate::simd::SUPPORTED_LANES,
                net.plan.lanes
            ),
        ));
    }
    if net.plane_blocks != net.lanes / 64 {
        out.push(v(
            art,
            "scratch-blocks",
            0,
            net.plane_blocks,
            format!(
                "scratch plane-block count {} does not match lane width {} (want {})",
                net.plane_blocks,
                net.lanes,
                net.lanes / 64
            ),
        ));
    }
    let mut in_planes = net.n_features * net.in_bits as usize;
    for (l, lo) in net.layers.iter().enumerate() {
        let (defined, mut used) = check_stream_core(l, &lo.stream, in_planes, &mut out);
        let want = lo.n_out * lo.out_bits as usize;
        if lo.roots.len() != want {
            out.push(v(
                art,
                "root-coverage",
                l,
                lo.roots.len(),
                format!("{} root planes for {} output planes", lo.roots.len(), want),
            ));
        }
        for (i, &r) in lo.roots.iter().enumerate() {
            if (r as usize) < defined.len() && defined[r as usize] {
                used[r as usize] = true;
            } else {
                out.push(v(art, "root-undef", l, i, format!("root plane {i} maps to undefined slot {r}")));
            }
        }
        finish_stream(l, &lo.stream, &defined, &used, &mut out);
        in_planes = lo.roots.len();
    }
    out
}

/// Check every per-shard re-flattened cone stream of a [`BitsliceKernel`]:
/// the core stream invariants plus exact coverage of the shard's write
/// range — each owned plane produced exactly once, none outside the range.
pub(crate) fn check_kernel_streams(k: &BitsliceKernel) -> Vec<Violation> {
    let art = ArtifactKind::OpStream;
    let mut out = Vec::new();
    let shards = k.n_shards();
    for l in 0..k.n_layers() {
        let in_planes = if l == 0 {
            k.in_len()
        } else {
            (0..shards).map(|q| k.write_range(l - 1, q).end).max().unwrap_or(0)
        };
        for (s, ss) in k.layers[l].iter().enumerate() {
            let (defined, mut used) = check_stream_core(l, &ss.stream, in_planes, &mut out);
            let wr = k.write_range(l, s);
            let mut seen = vec![false; wr.len()];
            for (i, &(plane, node)) in ss.roots.iter().enumerate() {
                let p = plane as usize;
                if !wr.contains(&p) {
                    out.push(v(
                        art,
                        "plane-range",
                        l,
                        s,
                        format!("shard {s} root {i} targets plane {p} outside its write range {wr:?}"),
                    ));
                } else if seen[p - wr.start] {
                    out.push(v(art, "plane-dup", l, s, format!("shard {s} produces plane {p} twice")));
                } else {
                    seen[p - wr.start] = true;
                }
                if (node as usize) < defined.len() && defined[node as usize] {
                    used[node as usize] = true;
                } else {
                    out.push(v(
                        art,
                        "root-undef",
                        l,
                        s,
                        format!("shard {s} root {i} maps to undefined slot {node}"),
                    ));
                }
            }
            let covered = seen.iter().filter(|&&x| x).count();
            if covered != wr.len() {
                out.push(v(
                    art,
                    "plane-coverage",
                    l,
                    s,
                    format!("shard {s} produces {covered}/{} planes of {wr:?}", wr.len()),
                ));
            }
            finish_stream(l, &ss.stream, &defined, &used, &mut out);
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Checker 3: hazard schedules
// ---------------------------------------------------------------------------

/// Check a sharded kernel's `(shard, threshold)` schedule against the
/// read/write sets it retains: write ranges tile every boundary, reads are
/// sorted and in-bounds, the three hazard classes (producer,
/// previous-generation reader, generation writer) are each dominated by a
/// stored dependency, and the cross-cell dependency graph is acyclic.
pub(crate) fn check_hazards<K: ShardKernel>(k: &K) -> Vec<Violation> {
    let art = ArtifactKind::Hazard;
    let mut out = Vec::new();
    let l_count = k.n_layers();
    let shards = k.n_shards();

    // Recompute boundary widths; write ranges must tile each boundary in
    // shard order (no gap, no overlap — position ownership is unambiguous).
    let mut bounds = vec![0usize; l_count + 1];
    bounds[0] = k.in_len();
    for b in 1..=l_count {
        let mut pos = 0usize;
        for s in 0..shards {
            let r = k.write_range(b - 1, s);
            if r.start != pos {
                out.push(v(
                    art,
                    "write-tiling",
                    b - 1,
                    s,
                    format!("shard {s} writes {r:?} at boundary {b}, expected start {pos}"),
                ));
            }
            pos = pos.max(r.end);
        }
        bounds[b] = pos;
    }
    if k.out_len() < bounds[l_count] {
        out.push(v(
            art,
            "out-len",
            l_count,
            k.out_len(),
            format!("output staging holds {} slots, boundary {} needs {}", k.out_len(), l_count, bounds[l_count]),
        ));
    }
    let interior = (1..l_count).map(|b| bounds[b]).max().unwrap_or(0);
    if l_count > 1 && k.buf_len() < interior {
        out.push(v(
            art,
            "buf-len",
            0,
            k.buf_len(),
            format!("shared buffers hold {} slots, widest interior boundary needs {interior}", k.buf_len()),
        ));
    }

    // Previous generation of position x under destination boundary d: the
    // nearest lower same-parity boundary wide enough to cover x (widths
    // are not monotonic, so generations can skip a parity level).
    let prev_gen = |d: usize, x: usize| -> Option<usize> {
        let mut bb = d as isize - 2;
        while bb >= 1 {
            if bounds[bb as usize] > x {
                return Some(bb as usize);
            }
            bb -= 2;
        }
        None
    };
    let owner = |b: usize, x: usize| -> Option<u32> {
        (0..shards).find(|&q| k.write_range(b - 1, q).contains(&x)).map(|q| q as u32)
    };
    let dominated =
        |deps: &[(u32, u32)], q: u32, thr: u32| deps.iter().any(|&(dq, dt)| dq == q && dt >= thr);

    for l in 0..l_count {
        for s in 0..shards {
            let deps = k.deps(l, s);
            for (i, &(q, thr)) in deps.iter().enumerate() {
                if q as usize >= shards {
                    out.push(v(art, "dep-target", l, i, format!("cell ({l},{s}) waits on shard {q} of {shards}")));
                }
                if q as usize == s {
                    out.push(v(art, "dep-self", l, i, format!("cell ({l},{s}) waits on itself")));
                }
                if thr as usize > l {
                    out.push(v(
                        art,
                        "dep-threshold",
                        l,
                        i,
                        format!("cell ({l},{s}) waits for done[{q}] ≥ {thr} > its own layer"),
                    ));
                }
            }
            let reads = k.reads(l, s);
            if reads.windows(2).any(|w| w[0] >= w[1]) {
                out.push(v(art, "reads-sorted", l, s, format!("cell ({l},{s}) read set is not sorted/deduped")));
            }
            for &x in reads {
                if x >= bounds[l] {
                    out.push(v(
                        art,
                        "read-bounds",
                        l,
                        x,
                        format!("cell ({l},{s}) reads position {x} but boundary {l} is {} wide", bounds[l]),
                    ));
                }
            }
            // Dedup per (shard, class) so a single dropped edge does not
            // flood the report with one violation per position.
            let mut reported: Vec<(u32, &'static str)> = Vec::new();
            // Class 1: producers of every cross-shard gather.
            if l >= 1 {
                for &x in reads {
                    if x >= bounds[l] {
                        continue;
                    }
                    if let Some(q) = owner(l, x) {
                        if q as usize != s
                            && !dominated(deps, q, l as u32)
                            && !reported.contains(&(q, "producer-dep"))
                        {
                            reported.push((q, "producer-dep"));
                            out.push(v(
                                art,
                                "producer-dep",
                                l,
                                x,
                                format!("cell ({l},{s}) reads position {x} from shard {q} with no (shard {q}, ≥{l}) wait"),
                            ));
                        }
                    }
                }
            }
            // Classes 2 and 3: before overwriting an interior parity-buffer
            // position, its previous generation's readers and writer must
            // have landed.
            if l + 1 <= l_count.saturating_sub(1) {
                for x in k.write_range(l, s) {
                    let Some(bb) = prev_gen(l + 1, x) else { continue };
                    if let Some(q) = owner(bb, x) {
                        if q as usize != s
                            && !dominated(deps, q, bb as u32)
                            && !reported.contains(&(q, "writer-dep"))
                        {
                            reported.push((q, "writer-dep"));
                            out.push(v(
                                art,
                                "writer-dep",
                                l,
                                x,
                                format!("cell ({l},{s}) overwrites position {x} (gen boundary {bb}) with no (shard {q}, ≥{bb}) writer wait"),
                            ));
                        }
                    }
                    for q in 0..shards {
                        if q == s {
                            continue;
                        }
                        if k.reads(bb, q).binary_search(&x).is_ok()
                            && !dominated(deps, q as u32, bb as u32 + 1)
                            && !reported.contains(&(q as u32, "reader-dep"))
                        {
                            reported.push((q as u32, "reader-dep"));
                            out.push(v(
                                art,
                                "reader-dep",
                                l,
                                x,
                                format!("cell ({l},{s}) overwrites position {x} still readable by shard {q} at layer {bb} with no (shard {q}, ≥{}) wait", bb + 1),
                            ));
                        }
                    }
                }
            }
        }
    }

    // Explicit acyclicity proof over the cross-cell dependency graph: a
    // wait for done[q] ≥ thr is an edge from cell (thr-1, q).
    let idx = |l: usize, s: usize| l * shards + s;
    let n_cells = l_count * shards;
    let mut adj: Vec<Vec<usize>> = vec![Vec::new(); n_cells];
    let mut indeg = vec![0usize; n_cells];
    for l in 0..l_count {
        for s in 0..shards {
            for &(q, thr) in k.deps(l, s) {
                if (q as usize) < shards && thr >= 1 && (thr as usize) <= l_count {
                    adj[idx(thr as usize - 1, q as usize)].push(idx(l, s));
                    indeg[idx(l, s)] += 1;
                }
            }
        }
    }
    let mut queue: Vec<usize> = (0..n_cells).filter(|&c| indeg[c] == 0).collect();
    let mut done = 0usize;
    while let Some(c) = queue.pop() {
        done += 1;
        for &d in &adj[c] {
            indeg[d] -= 1;
            if indeg[d] == 0 {
                queue.push(d);
            }
        }
    }
    if done != n_cells {
        out.push(v(
            art,
            "dep-cycle",
            0,
            n_cells - done,
            format!("{} cells form a dependency cycle", n_cells - done),
        ));
    }
    out
}

// ---------------------------------------------------------------------------
// Checker 4: wire plans
// ---------------------------------------------------------------------------

/// Check one shard's [`WirePlan`] against the kernel it was derived from.
pub(crate) fn check_wire_plan<K: ShardKernel>(k: &K, s: usize, wp: &WirePlan) -> Vec<Violation> {
    let art = ArtifactKind::Wire;
    let mut out = Vec::new();
    let l_count = k.n_layers();
    let coord = k.n_shards() as u32;
    if wp.needs.len() != l_count
        || wp.result.len() != l_count
        || wp.deps.len() != l_count
        || wp.counts.len() != l_count
    {
        out.push(v(art, "wire-len", 0, s, format!("shard {s} plan does not cover all {l_count} layers")));
        return out;
    }
    let owner = |l: usize, x: usize| -> u32 {
        if l == 0 {
            return coord;
        }
        (0..k.n_shards())
            .find(|&q| k.write_range(l - 1, q).contains(&x))
            .map(|q| q as u32)
            .unwrap_or(coord)
    };
    for l in 0..l_count {
        let own: Range<usize> = if l == 0 { 0..0 } else { k.write_range(l - 1, s) };
        let expected: Vec<usize> =
            k.reads(l, s).iter().copied().filter(|x| !own.contains(x)).collect();
        let runs = &wp.needs[l];
        if expected.is_empty() && !runs.is_empty() {
            out.push(v(
                art,
                "wire-flightless",
                l,
                runs.len(),
                format!("shard {s} ships {} run(s) at flightless boundary {l}", runs.len()),
            ));
        }
        // Canonical shape: non-empty runs, sorted, maximally merged.
        let mut prev: Option<(u32, usize)> = None;
        let mut got: Vec<(u32, usize)> = Vec::new();
        for (i, (q, r)) in runs.iter().enumerate() {
            if r.start >= r.end {
                out.push(v(art, "wire-empty-run", l, i, format!("shard {s} run {i} is empty ({r:?})")));
            }
            if let Some((pq, pe)) = prev {
                if r.start < pe {
                    out.push(v(
                        art,
                        "wire-unsorted",
                        l,
                        i,
                        format!("shard {s} run {i} ({r:?}) starts before the previous run ends ({pe})"),
                    ));
                } else if r.start == pe && *q == pq {
                    out.push(v(
                        art,
                        "wire-unmerged",
                        l,
                        i,
                        format!("shard {s} run {i} ({r:?}) is adjacent to the previous run from the same producer"),
                    ));
                }
            }
            prev = Some((*q, r.end));
            for x in r.clone() {
                got.push((*q, x));
            }
        }
        // Exact cover of the cross-shard read set: no gap, no overlap.
        let mut gs: Vec<usize> = got.iter().map(|&(_, x)| x).collect();
        gs.sort_unstable();
        if gs.windows(2).any(|w| w[0] == w[1]) {
            out.push(v(art, "wire-overlap", l, s, format!("shard {s} ships a position more than once")));
        }
        gs.dedup();
        let missing = expected.iter().filter(|x| gs.binary_search(x).is_err()).count();
        if missing > 0 {
            out.push(v(
                art,
                "wire-gap",
                l,
                missing,
                format!("shard {s}: {missing} cross-shard read(s) not covered by any run"),
            ));
        }
        let extra = gs.iter().filter(|x| expected.binary_search(x).is_err()).count();
        if extra > 0 {
            out.push(v(
                art,
                "wire-extra",
                l,
                extra,
                format!("shard {s}: {extra} shipped position(s) it never reads"),
            ));
        }
        for &(q, x) in &got {
            let want = owner(l, x);
            if q != want {
                out.push(v(
                    art,
                    "wire-producer",
                    l,
                    x,
                    format!("shard {s} expects position {x} from {q} but it is produced by {want}"),
                ));
                break; // one per boundary is enough to localize
            }
        }
        // result / deps / counts must match the canonical derivation.
        if wp.result[l] != k.write_range(l, s) {
            out.push(v(
                art,
                "wire-result",
                l,
                s,
                format!("shard {s} result {:?} != its write range {:?}", wp.result[l], k.write_range(l, s)),
            ));
        }
        let mut exp_runs: Vec<(u32, Range<usize>)> = Vec::new();
        for &x in &expected {
            match exp_runs.last_mut() {
                Some((lq, r)) if *lq == owner(l, x) && r.end == x => r.end = x + 1,
                _ => exp_runs.push((owner(l, x), x..x + 1)),
            }
        }
        let mut exp_counts: Vec<(u32, u32)> = Vec::new();
        for (q, _) in &exp_runs {
            match exp_counts.iter_mut().find(|(p, _)| p == q) {
                Some((_, c)) => *c += 1,
                None => exp_counts.push((*q, 1)),
            }
        }
        let exp_deps: Vec<(u32, u32)> = exp_counts
            .iter()
            .map(|&(q, _)| (q, if q == coord { 1 } else { l as u32 }))
            .collect();
        if wp.deps[l] != exp_deps {
            out.push(v(
                art,
                "wire-deps",
                l,
                s,
                format!("shard {s} deps {:?} != expected {exp_deps:?}", wp.deps[l]),
            ));
        }
        if wp.counts[l] != exp_counts {
            out.push(v(
                art,
                "wire-counts",
                l,
                s,
                format!("shard {s} counts {:?} != expected {exp_counts:?}", wp.counts[l]),
            ));
        }
    }
    out
}

/// Derive and check the wire plan of every shard of a kernel.
pub(crate) fn check_wire_plans<K: ShardKernel>(k: &K) -> Vec<Violation> {
    let mut out = Vec::new();
    for s in 0..k.n_shards() {
        let wp = wire_plan(k, s);
        out.extend(check_wire_plan(k, s, &wp));
    }
    out
}

// ---------------------------------------------------------------------------
// Checker 5: epoch-ring slot safety
// ---------------------------------------------------------------------------

/// Check that one epoch's buffer footprint is **self-contained**, so the
/// Wire-v3 epoch ring may hand the kernel a recycled `BufSet` slot
/// without any value leaking between the epochs that share it.
///
/// A ring slot is reused by epochs W apart without being cleared; the
/// recycled buffers still hold the previous tenant's boundary values.
/// Isolation therefore rests on three structural facts, checked here per
/// kernel rather than trusted:
///
/// - `ring-slot-capacity` — every interior boundary's write tiling fits
///   inside the slot buffer (`buf_len`); an oversized tiling would spill
///   a cell's stores past its epoch's slot.
/// - `ring-output-width` — the final layer's write tiling fills the
///   output staging buffer exactly, so a collected epoch never exposes
///   positions last written by an earlier epoch.
/// - `ring-stale-read` — every read at layer `l ≥ 1` lands inside the
///   *same epoch's* boundary-`l` write tiling.  A position that is
///   readable (within `buf_len`) but unwritten this epoch would yield
///   whatever epoch `e − W` left in the slot — the precise cross-epoch
///   leak the ring must exclude, and the reason a checkpointed resume
///   may trim replay flights below the applied boundary (no layer can
///   reach data its own boundary's flights did not carry).
///
/// The within-epoch ordering of these accesses is the hazard checkers'
/// job ([`check_hazards`]); this checker is about which *slot positions*
/// an epoch may legally touch at all.
pub(crate) fn check_epoch_slots<K: ShardKernel>(k: &K) -> Vec<Violation> {
    let art = ArtifactKind::EpochRing;
    let mut out = Vec::new();
    let l_count = k.n_layers();
    let shards = k.n_shards();
    // Tiled width of each boundary ≥ 1 (max write end; tiling gaps and
    // overlaps are check_hazards' "write-tiling" — tolerate them here).
    let mut width = vec![0usize; l_count + 1];
    width[0] = k.in_len();
    for b in 1..=l_count {
        width[b] =
            (0..shards).map(|s| k.write_range(b - 1, s).end).max().unwrap_or(0);
    }
    for b in 1..l_count {
        if width[b] > k.buf_len() {
            out.push(v(
                art,
                "ring-slot-capacity",
                b,
                width[b],
                format!(
                    "boundary {b} tiles {} positions but the slot buffer holds {}",
                    width[b],
                    k.buf_len()
                ),
            ));
        }
    }
    if l_count > 0 && width[l_count] != k.out_len() {
        out.push(v(
            art,
            "ring-output-width",
            l_count,
            width[l_count],
            format!(
                "final boundary tiles {} positions but output staging holds {} — \
                 a short tiling exposes the slot's previous epoch",
                width[l_count],
                k.out_len()
            ),
        ));
    }
    for l in 1..l_count {
        for s in 0..shards {
            for &x in k.reads(l, s) {
                if x >= width[l] {
                    out.push(v(
                        art,
                        "ring-stale-read",
                        l,
                        x,
                        format!(
                            "cell ({l},{s}) reads boundary-{l} position {x}, never \
                             written this epoch (tiled width {}) — the value would \
                             bleed from the slot's previous tenant",
                            width[l]
                        ),
                    ));
                    break; // one per cell localizes the leak
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Aggregate entry points
// ---------------------------------------------------------------------------

/// The sharded kernels of a model at a given shard count, retained for
/// inspection instead of being consumed by runner threads — the handle the
/// CLI and benches use to verify hazard schedules and wire plans.
pub struct ShardedArtifacts {
    pub(crate) plan: PlanKernel,
    pub(crate) bits: BitsliceKernel,
    shards: usize,
}

impl ShardedArtifacts {
    /// Shard count the kernels were compiled for.
    pub fn shards(&self) -> usize {
        self.shards
    }
}

/// Compile the sharded kernels of `net` exactly as
/// `ShardedModel::compile` would (cache-aware permutation included),
/// keeping them inspectable.
pub fn compile_sharded_artifacts(
    net: &Network,
    tables: &NetworkTables,
    shards: usize,
    workers: usize,
) -> ShardedArtifacts {
    let shards = shards.max(1);
    let (pnet, ptables) = permuted_for_shards(net, tables);
    ShardedArtifacts {
        plan: plan_kernel_of(&pnet, &ptables, shards),
        bits: bits_kernel_of(&pnet, &ptables, shards, workers),
        shards,
    }
}

/// Hazard-schedule violations of both sharded kernels.
pub fn verify_hazards(a: &ShardedArtifacts) -> Vec<Violation> {
    let mut out = check_hazards(&a.plan);
    out.extend(check_hazards(&a.bits));
    out
}

/// Wire-plan violations across every shard of both kernels.
pub fn verify_wire_plans(a: &ShardedArtifacts) -> Vec<Violation> {
    let mut out = check_wire_plans(&a.plan);
    out.extend(check_wire_plans(&a.bits));
    out
}

/// Op-stream violations of the per-shard re-flattened cone streams.
pub fn verify_shard_streams(a: &ShardedArtifacts) -> Vec<Violation> {
    check_kernel_streams(&a.bits)
}

/// Epoch-ring slot-safety violations of both sharded kernels (cross-epoch
/// isolation of recycled `BufSet` slots — see [`check_epoch_slots`]).
pub fn verify_epoch_slots(a: &ShardedArtifacts) -> Vec<Violation> {
    let mut out = check_epoch_slots(&a.plan);
    out.extend(check_epoch_slots(&a.bits));
    out
}

// ---------------------------------------------------------------------------
// Checker 6: netlist-opt fold equivalence
// ---------------------------------------------------------------------------

/// Fresh 64-sample random wire words fed per equivalence round.
const OPT_EQUIV_ROUNDS: usize = 4;
/// Random wire-word pool size (wires index it modulo the length, so both
/// netlists see identical values whatever wire universe they read).
const OPT_EQUIV_WIRES: usize = 1024;

/// Random-vector equivalence of each folded (`lut::opt`) layer netlist
/// against its unfolded baseline — a mapping of the same post-rewrite
/// tables, so any disagreement is the fold's fault.  The baseline side
/// runs [`crate::lut::netlist::Netlist::eval64_reference`], the
/// independent per-sample address walk, so a bug in the shared word-level
/// LUT kernel cannot mask a bad fold.  `OPT_EQUIV_ROUNDS` rounds of 64
/// samples per layer.
pub fn verify_opt(
    baseline: &crate::lut::MappedNetwork,
    folded: &crate::lut::MappedNetwork,
    seed: u64,
) -> Vec<Violation> {
    let art = ArtifactKind::NetlistOpt;
    let mut out = Vec::new();
    if baseline.layers.len() != folded.layers.len() {
        out.push(v(
            art,
            "layer-count",
            0,
            0,
            format!("{} baseline layers vs {} folded", baseline.layers.len(), folded.layers.len()),
        ));
        return out;
    }
    let mut rng = crate::util::rng::Rng::new(seed);
    for (l, (bl, fl)) in baseline.layers.iter().zip(&folded.layers).enumerate() {
        if bl.roots.len() != fl.roots.len() {
            out.push(v(
                art,
                "root-shape",
                l,
                0,
                format!("{} baseline neurons vs {} folded", bl.roots.len(), fl.roots.len()),
            ));
            continue;
        }
        let mut shape_ok = true;
        for (j, (rb, rf)) in bl.roots.iter().zip(&fl.roots).enumerate() {
            if rb.len() != rf.len() {
                out.push(v(
                    art,
                    "root-shape",
                    l,
                    j,
                    format!("neuron {j}: {} baseline root bits vs {} folded", rb.len(), rf.len()),
                ));
                shape_ok = false;
            }
        }
        if !shape_ok {
            continue;
        }
        for round in 0..OPT_EQUIV_ROUNDS {
            let words: Vec<u64> = (0..OPT_EQUIV_WIRES).map(|_| rng.next_u64()).collect();
            let wires = |w: u32| words[w as usize % OPT_EQUIV_WIRES];
            let bv = bl.netlist.eval64_reference(&wires);
            let fv = fl.netlist.eval64(&wires);
            for (j, (rb, rf)) in bl.roots.iter().zip(&fl.roots).enumerate() {
                for (bit, (&nb, &nf)) in rb.iter().zip(rf).enumerate() {
                    let (wb, wf) = (bv[nb as usize], fv[nf as usize]);
                    if wb != wf {
                        out.push(v(
                            art,
                            "fold-equivalence",
                            l,
                            j,
                            format!(
                                "neuron {j} bit {bit} round {round}: \
                                 baseline {wb:#018x} vs folded {wf:#018x}"
                            ),
                        ));
                    }
                }
            }
        }
    }
    out
}

/// Verify the two whole-model artifacts every `FrozenModel` carries.
pub fn verify_frozen(plan: &EvalPlan, bits: &BitsliceNet) -> Report {
    let mut r = Report::default();
    r.section("plan", verify_plan(plan));
    r.section("bitslice op-streams", verify_bitslice(bits));
    r
}

/// Verify a compiled pair of sharded kernels: per-shard op streams, both
/// hazard schedules, and every shard's wire plan.
pub(crate) fn report_for_kernels(pk: &PlanKernel, bk: &BitsliceKernel) -> Report {
    let mut r = Report::default();
    r.section("shard op-streams", check_kernel_streams(bk));
    let mut hz = check_hazards(pk);
    hz.extend(check_hazards(bk));
    r.section("hazard schedules", hz);
    let mut wires = check_wire_plans(pk);
    wires.extend(check_wire_plans(bk));
    r.section("wire plans", wires);
    let mut slots = check_epoch_slots(pk);
    slots.extend(check_epoch_slots(bk));
    r.section("epoch-ring slots", slots);
    r
}

/// [`report_for_kernels`] over a retained [`ShardedArtifacts`] pair.
pub fn verify_sharded(a: &ShardedArtifacts) -> Report {
    report_for_kernels(&a.plan, &a.bits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lut::tables::compile_network;
    use crate::nn::config;
    use crate::util::rng::Rng;
    use std::sync::atomic::AtomicU64;

    fn grid_net(a: usize, d: u32) -> (Network, NetworkTables) {
        let cfg = config::uniform("verify-t", &[8, 6, 3], 2, 2, 3, 3, 3, d, a, 3);
        let net = Network::random(&cfg, &mut Rng::new(a as u64 * 100 + d as u64));
        let tables = compile_network(&net, 2);
        (net, tables)
    }

    // ---- positive: every clean compile passes the gate ----

    #[test]
    fn clean_compiles_pass_all_checkers() {
        for (a, d) in [(1usize, 1u32), (2, 1), (1, 2), (2, 2)] {
            let (net, tables) = grid_net(a, d);
            let plan = EvalPlan::compile(&net, &tables);
            let bits = BitsliceNet::compile(&net, &tables, 1);
            let r = verify_frozen(&plan, &bits);
            assert!(r.is_clean(), "frozen a={a} d={d}:\n{}", r.render());
            let art = compile_sharded_artifacts(&net, &tables, 2, 2);
            let r = verify_sharded(&art);
            assert!(r.is_clean(), "sharded a={a} d={d}:\n{}", r.render());
        }
    }

    #[test]
    fn clean_deep_nonmonotonic_passes() {
        let cfg = config::uniform("verify-deep", &[8, 6, 5, 7, 3], 2, 2, 3, 3, 3, 1, 2, 3);
        let net = Network::random(&cfg, &mut Rng::new(11));
        let tables = compile_network(&net, 2);
        let plan = EvalPlan::compile(&net, &tables);
        let bits = BitsliceNet::compile(&net, &tables, 2);
        assert!(verify_frozen(&plan, &bits).is_clean());
        for shards in [2usize, 3] {
            let art = compile_sharded_artifacts(&net, &tables, shards, 2);
            let r = verify_sharded(&art);
            assert!(r.is_clean(), "shards={shards}:\n{}", r.render());
        }
    }

    fn has(vs: &[Violation], invariant: &str) -> bool {
        vs.iter().any(|x| x.invariant == invariant)
    }

    // ---- checker 1: plan mutations ----

    fn plan_of() -> EvalPlan {
        let (net, tables) = grid_net(2, 1);
        EvalPlan::compile(&net, &tables)
    }

    #[test]
    fn plan_rejects_oob_gather() {
        let mut p = plan_of();
        p.layers[1].gather[0] = p.widths[1] as u32;
        let vs = verify_plan(&p);
        assert!(
            vs.iter().any(|x| x.invariant == "gather-bounds"
                && x.artifact == ArtifactKind::Plan
                && x.layer == 1
                && x.index == 0),
            "{vs:?}"
        );
    }

    #[test]
    fn plan_rejects_truncated_table() {
        let mut p = plan_of();
        p.layers[0].poly.pop();
        assert!(has(&verify_plan(&p), "poly-len"));
        let mut p = plan_of();
        p.layers[0].adder.pop();
        assert!(has(&verify_plan(&p), "adder-len"));
    }

    #[test]
    fn plan_rejects_bad_stride() {
        let mut p = plan_of();
        p.layers[0].poly_stride *= 2;
        assert!(has(&verify_plan(&p), "poly-stride"));
        let mut p = plan_of();
        p.layers[0].adder_stride /= 2;
        assert!(has(&verify_plan(&p), "adder-stride"));
    }

    #[test]
    fn plan_rejects_undersized_scratch() {
        let mut p = plan_of();
        p.max_width = 0;
        assert!(has(&verify_plan(&p), "scratch-width"));
    }

    // ---- checker 2: op-stream mutations ----

    fn bits_of() -> BitsliceNet {
        let (net, tables) = grid_net(2, 1);
        BitsliceNet::compile(&net, &tables, 1)
    }

    #[test]
    fn opstream_rejects_dropped_root() {
        let mut b = bits_of();
        b.layers[0].roots.pop();
        assert!(has(&verify_bitslice(&b), "root-coverage"));
    }

    #[test]
    fn opstream_rejects_dead_write() {
        let mut b = bits_of();
        let lo = &mut b.layers[0];
        let slot = lo.stream.n_nodes as u32;
        lo.stream.n_nodes += 1;
        lo.stream.ops.push(Op::Const { out: slot, ones: false });
        assert!(has(&verify_bitslice(&b), "dead-write"));
    }

    #[test]
    fn opstream_rejects_oob_bind_wire() {
        let mut b = bits_of();
        b.layers[0].stream.bind[0].1 = u32::MAX;
        assert!(has(&verify_bitslice(&b), "bind-wire-bounds"));
    }

    #[test]
    fn opstream_accepts_every_supported_lane_plan() {
        for lanes in crate::simd::SUPPORTED_LANES {
            let b = bits_of().with_lane_plan(crate::simd::plan_for(lanes));
            let vs = verify_bitslice(&b);
            assert!(vs.is_empty(), "lanes={lanes}: {vs:?}");
        }
    }

    #[test]
    fn opstream_rejects_unsupported_lane_width() {
        // 96 is not a supported multiple of 64; plane_blocks (96/64 = 1)
        // still matches, so only the lane-width invariant must fire.
        let mut b = bits_of();
        b.lanes = 96;
        let vs = verify_bitslice(&b);
        assert!(has(&vs, "lane-width"), "{vs:?}");
        assert!(!has(&vs, "scratch-blocks"), "{vs:?}");
        // A supported width that disagrees with the carried plan is also a
        // lane-width violation (metadata drifted from the dispatch path).
        let mut b = bits_of();
        b.lanes = 128;
        b.plane_blocks = 2;
        assert!(has(&verify_bitslice(&b), "lane-width"));
    }

    #[test]
    fn opstream_rejects_mis_sized_scratch_blocks() {
        let mut b = bits_of();
        b.plane_blocks = 3;
        let vs = verify_bitslice(&b);
        assert!(has(&vs, "scratch-blocks"), "{vs:?}");
        assert!(!has(&vs, "lane-width"), "{vs:?}");
    }

    #[test]
    fn opstream_rejects_degenerate_group() {
        let mut b = bits_of();
        let mut found = false;
        'outer: for lo in &mut b.layers {
            for op in &mut lo.stream.ops {
                if let Op::Group { len, .. } = op {
                    *len = 1;
                    found = true;
                    break 'outer;
                }
            }
        }
        assert!(found, "A=2 grid net must contain a shared-input group");
        let vs = verify_bitslice(&b);
        assert!(has(&vs, "group-size"), "{vs:?}");
    }

    #[test]
    fn opstream_rejects_reordered_op() {
        // Hand-built stream: op 0 consumes slot 2 before op 1 defines it.
        let stream = OpStream {
            bind: vec![(0, 0), (1, 1)],
            ops: vec![
                Op::Lut { out: 3, mask: 0b0110, n_in: 2, ins: [0, 2, 0, 0, 0, 0] },
                Op::Lut { out: 2, mask: 0b0110, n_in: 2, ins: [0, 1, 0, 0, 0, 0] },
            ],
            lut_nodes: vec![],
            lut_masks: vec![],
            n_nodes: 4,
        };
        let mut vs = Vec::new();
        check_stream_core(0, &stream, 2, &mut vs);
        assert!(has(&vs, "undef-operand"), "{vs:?}");
    }

    #[test]
    fn opstream_rejects_double_definition() {
        let stream = OpStream {
            bind: vec![(0, 0)],
            ops: vec![
                Op::Const { out: 1, ones: true },
                Op::Const { out: 1, ones: false },
            ],
            lut_nodes: vec![],
            lut_masks: vec![],
            n_nodes: 2,
        };
        let mut vs = Vec::new();
        check_stream_core(0, &stream, 1, &mut vs);
        assert!(has(&vs, "multi-def"), "{vs:?}");
    }

    #[test]
    fn opstream_rejects_bad_group_range() {
        let stream = OpStream {
            bind: vec![(0, 0)],
            ops: vec![Op::Group { n_in: 1, ins: [0; 6], start: 0, len: 2 }],
            lut_nodes: vec![1],
            lut_masks: vec![0],
            n_nodes: 2,
        };
        let mut vs = Vec::new();
        check_stream_core(0, &stream, 1, &mut vs);
        assert!(has(&vs, "group-range") && has(&vs, "group-store"), "{vs:?}");
    }

    // ---- checker 3: hazard mutations (real kernels) ----

    fn kernels(shards: usize) -> (PlanKernel, BitsliceKernel) {
        let cfg = config::uniform("verify-k", &[8, 6, 5, 7, 3], 2, 2, 3, 3, 3, 1, 2, 3);
        let net = Network::random(&cfg, &mut Rng::new(23));
        let tables = compile_network(&net, 2);
        let (pnet, ptables) = permuted_for_shards(&net, &tables);
        (plan_kernel_of(&pnet, &ptables, shards), bits_kernel_of(&pnet, &ptables, shards, 2))
    }

    #[test]
    fn hazard_rejects_dropped_dependency_edge() {
        let (mut pk, _) = kernels(2);
        let (mut l0, mut s0) = (usize::MAX, 0);
        'outer: for l in 0..pk.deps.len() {
            for s in 0..pk.deps[l].len() {
                if !pk.deps[l][s].is_empty() {
                    (l0, s0) = (l, s);
                    break 'outer;
                }
            }
        }
        assert_ne!(l0, usize::MAX, "kernel has no dependencies at all");
        pk.deps[l0][s0].clear();
        let vs = check_hazards(&pk);
        assert!(
            has(&vs, "producer-dep") || has(&vs, "reader-dep") || has(&vs, "writer-dep"),
            "{vs:?}"
        );
    }

    #[test]
    fn hazard_rejects_lowered_threshold() {
        // Every stored threshold is the exact max over its hazard classes,
        // so lowering any one of them must break a class.
        let (_, mut bk) = kernels(2);
        let (mut l0, mut s0) = (usize::MAX, 0);
        'outer: for l in 0..bk.deps.len() {
            for s in 0..bk.deps[l].len() {
                if !bk.deps[l][s].is_empty() {
                    (l0, s0) = (l, s);
                    break 'outer;
                }
            }
        }
        assert_ne!(l0, usize::MAX);
        bk.deps[l0][s0][0].1 -= 1;
        let vs = check_hazards(&bk);
        assert!(
            has(&vs, "producer-dep") || has(&vs, "reader-dep") || has(&vs, "writer-dep"),
            "{vs:?}"
        );
    }

    #[test]
    fn hazard_rejects_cycle() {
        let (mut pk, _) = kernels(2);
        pk.deps[1][0] = vec![(1, 2)];
        pk.deps[1][1] = vec![(0, 2)];
        let vs = check_hazards(&pk);
        assert!(has(&vs, "dep-cycle"), "{vs:?}");
        assert!(has(&vs, "dep-threshold"), "{vs:?}");
    }

    // ---- checker 3/4: synthetic kernel for class isolation ----

    struct TestKernel {
        bounds: Vec<usize>,
        write: Vec<Vec<Range<usize>>>,
        reads: Vec<Vec<Vec<usize>>>,
        deps: Vec<Vec<Vec<(u32, u32)>>>,
    }

    impl ShardKernel for TestKernel {
        type Scratch = ();
        fn n_layers(&self) -> usize {
            self.write.len()
        }
        fn n_shards(&self) -> usize {
            self.write[0].len()
        }
        fn in_len(&self) -> usize {
            self.bounds[0]
        }
        fn out_len(&self) -> usize {
            *self.bounds.last().unwrap()
        }
        fn buf_len(&self) -> usize {
            self.bounds[1..self.bounds.len() - 1].iter().copied().max().unwrap_or(0)
        }
        fn deps(&self, l: usize, s: usize) -> &[(u32, u32)] {
            &self.deps[l][s]
        }
        fn reads(&self, l: usize, s: usize) -> &[usize] {
            &self.reads[l][s]
        }
        fn write_range(&self, l: usize, s: usize) -> Range<usize> {
            self.write[l][s].clone()
        }
        fn make_scratch(&self) -> Self::Scratch {}
        fn run_cell(
            &self,
            _l: usize,
            _s: usize,
            _src: &[AtomicU64],
            _dst: &[AtomicU64],
            _scratch: &mut Self::Scratch,
        ) {
        }
    }

    /// 4 layers × 2 shards, every boundary 4 wide in halves, every cell
    /// reading the full previous boundary.  `deps` below is hand-derived
    /// and pinned clean by `hazard_accepts_uniform_kernel`.
    fn uniform_kernel() -> TestKernel {
        TestKernel {
            bounds: vec![4; 5],
            write: vec![vec![0..2, 2..4]; 4],
            reads: vec![vec![vec![0, 1, 2, 3]; 2]; 4],
            deps: vec![
                vec![vec![], vec![]],
                vec![vec![(1, 1)], vec![(0, 1)]],
                vec![vec![(1, 2)], vec![(0, 2)]],
                vec![vec![(1, 3)], vec![(0, 3)]],
            ],
        }
    }

    #[test]
    fn hazard_accepts_uniform_kernel() {
        let vs = check_hazards(&uniform_kernel());
        assert!(vs.is_empty(), "{vs:?}");
    }

    #[test]
    fn hazard_rejects_missing_reader_block() {
        let mut k = uniform_kernel();
        // Cell (2,0) reads only its own half: no producer wait required,
        // but shard 1 still reads [0,2) at layer 1 — (1, ≥2) is mandatory.
        k.reads[2][0] = vec![0, 1];
        k.deps[2][0] = vec![(1, 1)];
        let vs = check_hazards(&k);
        assert!(has(&vs, "reader-dep"), "{vs:?}");
        assert!(!has(&vs, "producer-dep"), "{vs:?}");
    }

    #[test]
    fn hazard_rejects_missing_writer_order() {
        let mut k = uniform_kernel();
        // Boundary-1 ownership differs from boundary-3's: position 1 is
        // written by shard 1 at layer 0 but overwritten by shard 0 at
        // layer 2, so cell (2,0) needs a (1, ≥1) writer-ordering wait —
        // and with these read sets, *only* that wait.
        k.write[0] = vec![0..1, 1..4];
        k.reads[1][0] = vec![0];
        k.reads[1][1] = vec![2, 3];
        k.reads[2][0] = vec![0, 1];
        k.reads[2][1] = vec![2, 3];
        k.reads[3][0] = vec![0, 1];
        k.reads[3][1] = vec![2, 3];
        k.deps = vec![vec![vec![], vec![]]; 4];
        k.deps[2][0] = vec![(1, 1)];
        let baseline = check_hazards(&k);
        assert!(baseline.is_empty(), "{baseline:?}");
        k.deps[2][0].clear();
        let vs = check_hazards(&k);
        assert!(!vs.is_empty() && vs.iter().all(|x| x.invariant == "writer-dep"), "{vs:?}");
    }

    #[test]
    fn hazard_rejects_broken_write_tiling() {
        let mut k = uniform_kernel();
        k.write[1] = vec![0..3, 2..4];
        assert!(has(&check_hazards(&k), "write-tiling"));
    }

    #[test]
    fn hazard_rejects_oob_read() {
        let mut k = uniform_kernel();
        k.reads[1][0] = vec![0, 4];
        assert!(has(&check_hazards(&k), "read-bounds"));
    }

    // ---- checker 4: wire-plan mutations ----

    #[test]
    fn wire_accepts_clean_plan() {
        let k = uniform_kernel();
        for s in 0..2 {
            let wp = wire_plan(&k, s);
            let vs = check_wire_plan(&k, s, &wp);
            assert!(vs.is_empty(), "shard {s}: {vs:?}");
        }
    }

    #[test]
    fn wire_rejects_gap() {
        let k = uniform_kernel();
        let mut wp = wire_plan(&k, 0);
        wp.needs[1].clear();
        assert!(has(&check_wire_plan(&k, 0, &wp), "wire-gap"));
    }

    #[test]
    fn wire_rejects_overlap() {
        let k = uniform_kernel();
        let mut wp = wire_plan(&k, 0);
        let run = wp.needs[1][0].clone();
        wp.needs[1].push(run);
        assert!(has(&check_wire_plan(&k, 0, &wp), "wire-overlap"));
    }

    #[test]
    fn wire_rejects_unmerged_runs() {
        let k = uniform_kernel();
        let mut wp = wire_plan(&k, 0);
        wp.needs[1] = vec![(1, 2..3), (1, 3..4)];
        assert!(has(&check_wire_plan(&k, 0, &wp), "wire-unmerged"));
    }

    #[test]
    fn wire_rejects_unsorted_runs() {
        let k = uniform_kernel();
        let mut wp = wire_plan(&k, 0);
        wp.needs[1] = vec![(1, 3..4), (1, 2..3)];
        assert!(has(&check_wire_plan(&k, 0, &wp), "wire-unsorted"));
    }

    #[test]
    fn wire_rejects_wrong_producer() {
        let k = uniform_kernel();
        let mut wp = wire_plan(&k, 0);
        wp.needs[1] = vec![(0, 2..4)];
        assert!(has(&check_wire_plan(&k, 0, &wp), "wire-producer"));
    }

    #[test]
    fn wire_rejects_wrong_result_range() {
        let k = uniform_kernel();
        let mut wp = wire_plan(&k, 0);
        wp.result[1] = 0..3;
        assert!(has(&check_wire_plan(&k, 0, &wp), "wire-result"));
    }

    #[test]
    fn wire_rejects_stale_deps_and_counts() {
        let k = uniform_kernel();
        let mut wp = wire_plan(&k, 0);
        wp.counts[1] = vec![(1, 2)];
        assert!(has(&check_wire_plan(&k, 0, &wp), "wire-counts"));
        let mut wp = wire_plan(&k, 0);
        wp.deps[1] = vec![(1, 0)];
        assert!(has(&check_wire_plan(&k, 0, &wp), "wire-deps"));
    }

    #[test]
    fn wire_rejects_flightless_shipment() {
        let mut k = uniform_kernel();
        k.reads[1][0] = vec![0, 1]; // own slice only: boundary 1 is flightless
        let mut wp = wire_plan(&k, 0);
        assert!(wp.needs[1].is_empty());
        wp.needs[1].push((1, 2..3));
        assert!(has(&check_wire_plan(&k, 0, &wp), "wire-flightless"));
    }

    // ---- checker 5: epoch-ring slot safety ----

    #[test]
    fn epoch_slots_accept_clean_kernels() {
        let vs = check_epoch_slots(&uniform_kernel());
        assert!(vs.is_empty(), "{vs:?}");
        let (pk, bk) = kernels(2);
        let vs = check_epoch_slots(&pk);
        assert!(vs.is_empty(), "plan kernel: {vs:?}");
        let vs = check_epoch_slots(&bk);
        assert!(vs.is_empty(), "bitslice kernel: {vs:?}");
    }

    #[test]
    fn epoch_slots_reject_oversized_tiling() {
        let mut k = uniform_kernel();
        // Boundary 2's tiling runs past the slot buffer: stores would
        // spill out of the epoch's slot.
        k.write[1] = vec![0..2, 2..6];
        assert!(has(&check_epoch_slots(&k), "ring-slot-capacity"));
    }

    #[test]
    fn epoch_slots_reject_short_output_tiling() {
        let mut k = uniform_kernel();
        // The final layer leaves output positions 2..4 unwritten — a
        // collected epoch would expose the slot's previous tenant there.
        k.write[3] = vec![0..1, 1..2];
        assert!(has(&check_epoch_slots(&k), "ring-output-width"));
    }

    #[test]
    fn epoch_slots_reject_stale_read() {
        let mut k = uniform_kernel();
        // Boundary 2 only tiles positions 0..2 but layer 2 still reads
        // 0..4: positions 2 and 3 are within buffer capacity yet never
        // written this epoch — a cross-epoch leak through the ring slot.
        k.write[1] = vec![0..1, 1..2];
        assert!(has(&check_epoch_slots(&k), "ring-stale-read"));
    }

    // ---- netlist-opt fold equivalence ----

    #[test]
    fn fold_equivalence_passes_on_clean_folds() {
        for (a, d) in [(1usize, 1u32), (2, 1), (1, 2), (2, 2)] {
            let (net, tables) = grid_net(a, d);
            let baseline = crate::lut::map_network_of(&net, &tables, 2);
            let folded = crate::lut::opt::fold_network(&baseline, 2);
            let vs = verify_opt(&baseline, &folded, 42);
            assert!(vs.is_empty(), "a={a} d={d}: {vs:?}");
        }
    }

    #[test]
    fn fold_equivalence_rejects_inverted_root_lut() {
        let (net, tables) = grid_net(1, 2);
        let baseline = crate::lut::map_network_of(&net, &tables, 2);
        let mut folded = crate::lut::opt::fold_network(&baseline, 2);
        // Invert the mask of a LUT sitting directly at a root: the folded
        // output disagrees on every sample.
        let layer = &mut folded.layers[0];
        let root = layer
            .roots
            .iter()
            .flatten()
            .copied()
            .find(|&r| {
                matches!(layer.netlist.nodes[r as usize], crate::lut::netlist::Node::Lut { .. })
            })
            .expect("some root is a LUT");
        if let crate::lut::netlist::Node::Lut { mask, .. } =
            &mut layer.netlist.nodes[root as usize]
        {
            *mask = !*mask;
        }
        let vs = verify_opt(&baseline, &folded, 42);
        assert!(has(&vs, "fold-equivalence"), "{vs:?}");
    }

    #[test]
    fn fold_equivalence_rejects_root_shape_mismatch() {
        let (net, tables) = grid_net(1, 1);
        let baseline = crate::lut::map_network_of(&net, &tables, 2);
        let mut folded = crate::lut::opt::fold_network(&baseline, 2);
        folded.layers[1].roots.pop();
        let vs = verify_opt(&baseline, &folded, 7);
        assert!(has(&vs, "root-shape"), "{vs:?}");
    }

    // ---- diagnostics are data, and the gate renders them ----

    #[test]
    fn report_renders_and_gates() {
        let mut p = plan_of();
        p.layers[0].gather[0] = 10_000;
        let bits = bits_of();
        let r = verify_frozen(&p, &bits);
        assert!(!r.is_clean());
        assert_eq!(r.total(), 1);
        let rendered = r.render();
        assert!(rendered.contains("gather-bounds"), "{rendered}");
        assert!(rendered.contains("bitslice op-streams: OK"), "{rendered}");
        assert!(r.gate().is_err());
        let err = format!("{:#}", r.gate().unwrap_err());
        assert!(err.contains("gather-bounds"), "{err}");
        // Display carries artifact, layer, index, and invariant.
        let one = format!("{}", r.violations()[0]);
        assert!(one.starts_with("plan L0[0] gather-bounds"), "{one}");
    }
}
