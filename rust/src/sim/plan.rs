//! Evaluation-plan compiler + batched LUT execution engine.
//!
//! [`super::lutsim::LutSim`] (the reference twin) walks the frozen network
//! through three levels of `Vec` indirection per lookup
//! (`indices[a][j][slot]`, `layers[l].neurons[j].poly[a]`) and allocates per
//! neuron per sample.  That is fine for a property-test reference but is the
//! wrong shape for a serving hot path.  [`EvalPlan`] flattens everything
//! once, ahead of time:
//!
//! - **Flat decoded tables** — per layer, one contiguous `Vec<i32>` holding
//!   every poly table back to back (sub-neuron `(j, a)` at offset
//!   `(j*A + a) * poly_stride`, `poly_stride = 2^{β·F}`) and one for the
//!   adder tables (neuron `j` at `j * adder_stride`,
//!   `adder_stride = 2^{A·(β+1)}`).  Words are decoded from raw
//!   two's-complement to `i32` codes at compile time, so the hot loop is a
//!   pure gather-shift-index with no sign handling.
//! - **Flat gather indices** — per layer, one `Vec<u32>` with the fan-in
//!   source positions of sub-neuron `(j, a)` at `(j*A + a) * F`; no nested
//!   `Vec` pointer-chasing while gathering.
//! - **Reusable scratch** — [`Scratch`] carries two code buffers (double
//!   buffered across layers) plus the sub-neuron staging slice, so a forward
//!   pass performs **zero** heap allocation.
//!
//! Batched execution ([`EvalPlan::forward_batch`] /
//! [`EvalPlan::forward_batch_f32`]) walks samples in blocks so the decoded
//! tables stay hot in cache, and the f32 entry point fans blocks out over
//! worker threads — this is what `Backend::Lut` in the coordinator serves
//! from.  Bit-exactness against `Network::forward_codes` (and the naive
//! `LutSim` reference) is pinned by tests over the same `(A, degree)` grid
//! the simulator uses.
//!
//! Where this engine sits among the others — and when the router prefers
//! it over the bitsliced or sharded engines — is documented in
//! `ARCHITECTURE.md` §2 and §5 at the repository root.

use crate::lut::tables::NetworkTables;
use crate::nn::network::Network;
use crate::nn::quant::unsigned_code;
use crate::util::pool::parallel_map;

/// Upper bound on samples per block in batched execution: large enough to
/// amortize scratch setup, small enough that a block's working set stays
/// cache-resident.  Small batches are split finer so every worker gets a
/// block (see [`EvalPlan::forward_batch_f32`]).
pub const BATCH_BLOCK: usize = 32;

/// One layer of the compiled plan (all tables decoded, all indices flat).
/// Fields are crate-visible so [`crate::sim::shard`] can execute neuron
/// subranges of a layer without re-deriving the layout.
pub(crate) struct LayerPlan {
    pub(crate) n_out: usize,
    /// Sub-neurons per neuron (the config's A factor).
    pub(crate) a: usize,
    pub(crate) fan: usize,
    /// Input code width β of this layer.
    pub(crate) in_bits: u32,
    /// Sub-neuron output width β+1 (adder address field width).
    pub(crate) sub_bits: u32,
    /// Words per poly table: `2^{β·F}`.
    pub(crate) poly_stride: usize,
    /// Words per adder table: `2^{A·(β+1)}` (0 when A == 1: no adder stage).
    pub(crate) adder_stride: usize,
    /// Fan-in sources, flat: sub-neuron `(j, a)` slot `s` at
    /// `(j*a_factor + a)*fan + s`.
    pub(crate) gather: Vec<u32>,
    /// Decoded poly tables, flat: sub-neuron `(j, a)` at
    /// `(j*a_factor + a)*poly_stride`.
    pub(crate) poly: Vec<i32>,
    /// Decoded adder tables, flat: neuron `j` at `j*adder_stride`
    /// (empty when A == 1).
    pub(crate) adder: Vec<i32>,
}

/// A frozen network compiled into a flat, allocation-free execution plan.
/// Self-contained (owns its tables) — `Send + Sync`, share behind an `Arc`.
///
/// Data layout and crossover policy are described in `ARCHITECTURE.md` §2
/// (see also the [`crate::sim`] module docs).
pub struct EvalPlan {
    pub(crate) layers: Vec<LayerPlan>,
    pub(crate) widths: Vec<usize>,
    pub(crate) max_width: usize,
    pub(crate) a_factor: usize,
    /// Input quantizer width (β of layer 0).
    pub(crate) in_bits: u32,
    /// Dequantization step of the output codes.
    pub(crate) out_step: f32,
    n_classes: usize,
}

/// Reusable per-thread scratch for [`EvalPlan`] execution: two code buffers
/// double-buffered across layers plus the sub-neuron staging slice.
pub struct Scratch {
    cur: Vec<i32>,
    next: Vec<i32>,
    subs: Vec<i32>,
}

impl Scratch {
    /// Allocate scratch sized for `plan` (reusable across forward passes;
    /// one per thread).
    pub fn for_plan(plan: &EvalPlan) -> Scratch {
        Scratch {
            cur: vec![0; plan.max_width],
            next: vec![0; plan.max_width],
            subs: vec![0; plan.a_factor],
        }
    }
}

impl EvalPlan {
    /// Flatten `net`'s connectivity and `tables`' words into a plan.
    pub fn compile(net: &Network, tables: &NetworkTables) -> EvalPlan {
        let cfg = &net.cfg;
        let a = cfg.a_factor;
        let mut layers = Vec::with_capacity(tables.layers.len());
        for (l, lt) in tables.layers.iter().enumerate() {
            let n_out = cfg.widths[l + 1];
            let fan = lt.fan;
            let poly_stride = lt.poly_stride();
            let adder_stride = lt.adder_stride(a);
            let has_adder = adder_stride != 0;

            let mut gather = Vec::with_capacity(n_out * a * fan);
            let mut poly = Vec::with_capacity(n_out * a * poly_stride);
            let mut adder = Vec::with_capacity(n_out * adder_stride);
            for (j, nt) in lt.neurons.iter().enumerate() {
                debug_assert_eq!(nt.poly.len(), a);
                debug_assert_eq!(nt.adder.is_some(), has_adder);
                for (ai, t) in nt.poly.iter().enumerate() {
                    debug_assert_eq!(t.words.len(), poly_stride);
                    gather.extend(net.layers[l].indices[ai][j].iter().map(|&s| s as u32));
                    poly.extend(t.decoded());
                }
                if let Some(at) = &nt.adder {
                    debug_assert_eq!(at.words.len(), adder_stride);
                    adder.extend(at.decoded());
                }
            }
            layers.push(LayerPlan {
                n_out,
                a,
                fan,
                in_bits: lt.in_bits,
                sub_bits: lt.sub_bits,
                poly_stride,
                adder_stride,
                gather,
                poly,
                adder,
            });
        }
        EvalPlan {
            layers,
            widths: cfg.widths.clone(),
            max_width: cfg.widths.iter().copied().max().unwrap_or(0),
            a_factor: a,
            in_bits: cfg.beta[0],
            out_step: net.out_step(cfg.n_layers() - 1),
            n_classes: cfg.n_classes,
        }
    }

    /// Input feature count (width of layer 0).
    pub fn n_features(&self) -> usize {
        self.widths[0]
    }

    /// Output neuron count (width of the last layer boundary).
    pub fn n_outputs(&self) -> usize {
        self.widths[self.widths.len() - 1]
    }

    /// Number of classes (1 = binary task thresholded at 0).
    pub fn n_classes(&self) -> usize {
        self.n_classes
    }

    /// Quantize raw [0,1] features to input codes (mirrors
    /// `Network::quantize_input`).
    pub fn quantize_input(&self, x: &[f32]) -> Vec<i32> {
        x.iter().map(|&v| unsigned_code(v, self.in_bits, 1.0)).collect()
    }

    /// Core loop: consumes input codes from `scratch.cur[..n_features]`,
    /// leaves output codes in `scratch.cur[..n_outputs]`.  Allocation-free.
    fn execute(&self, scratch: &mut Scratch) {
        let Scratch { cur, next, subs } = scratch;
        for lp in &self.layers {
            let in_bits = lp.in_bits;
            let in_mask = (1usize << in_bits) - 1;
            let sub_mask = (1usize << lp.sub_bits) - 1;
            let mut gbase = 0usize; // cursor into lp.gather
            let mut tbase = 0usize; // cursor into lp.poly
            for j in 0..lp.n_out {
                if lp.adder_stride == 0 {
                    // A == 1: one fused table per neuron.
                    let srcs = &lp.gather[gbase..gbase + lp.fan];
                    let mut addr = 0usize;
                    for (s, &src) in srcs.iter().enumerate() {
                        addr |=
                            (cur[src as usize] as usize & in_mask) << (s as u32 * in_bits);
                    }
                    next[j] = lp.poly[tbase + addr];
                    gbase += lp.fan;
                    tbase += lp.poly_stride;
                } else {
                    for sub in subs[..lp.a].iter_mut() {
                        let srcs = &lp.gather[gbase..gbase + lp.fan];
                        let mut addr = 0usize;
                        for (s, &src) in srcs.iter().enumerate() {
                            addr |= (cur[src as usize] as usize & in_mask)
                                << (s as u32 * in_bits);
                        }
                        *sub = lp.poly[tbase + addr];
                        gbase += lp.fan;
                        tbase += lp.poly_stride;
                    }
                    let mut aaddr = 0usize;
                    for (ai, &sc) in subs[..lp.a].iter().enumerate() {
                        aaddr |= (sc as usize & sub_mask) << (ai as u32 * lp.sub_bits);
                    }
                    next[j] = lp.adder[j * lp.adder_stride + aaddr];
                }
            }
            std::mem::swap(cur, next);
        }
    }

    /// Table-only forward pass over input codes, writing into `scratch`.
    /// Returns the output-code slice (valid until the next call).
    pub fn forward_codes_into<'s>(
        &self,
        in_codes: &[i32],
        scratch: &'s mut Scratch,
    ) -> &'s [i32] {
        assert_eq!(in_codes.len(), self.n_features(), "input width mismatch");
        scratch.cur[..in_codes.len()].copy_from_slice(in_codes);
        self.execute(scratch);
        &scratch.cur[..self.n_outputs()]
    }

    /// Convenience: forward pass returning owned output codes.
    pub fn forward_codes(&self, in_codes: &[i32], scratch: &mut Scratch) -> Vec<i32> {
        self.forward_codes_into(in_codes, scratch).to_vec()
    }

    /// Forward from raw [0,1] features; returns dequantized logits.
    pub fn forward(&self, x: &[f32], scratch: &mut Scratch) -> Vec<f32> {
        assert_eq!(x.len(), self.n_features(), "feature width mismatch");
        // A scratch built for a smaller plan would silently truncate the
        // zip below and produce plausible-but-wrong logits — reject it.
        assert!(scratch.cur.len() >= self.max_width, "scratch built for a smaller plan");
        for (slot, &v) in scratch.cur.iter_mut().zip(x) {
            *slot = unsigned_code(v, self.in_bits, 1.0);
        }
        self.execute(scratch);
        scratch.cur[..self.n_outputs()].iter().map(|&c| c as f32 * self.out_step).collect()
    }

    /// Predicted class (argmax; for binary: logit > 0). NaN-safe.
    pub fn predict(&self, x: &[f32], scratch: &mut Scratch) -> usize {
        let logits = self.forward(x, scratch);
        if self.n_classes == 1 {
            (logits[0] > 0.0) as usize
        } else {
            crate::util::argmax_f32(&logits)
        }
    }

    /// Batched code-level forward pass: one scratch, sequential samples.
    pub fn forward_batch(&self, xs: &[Vec<i32>], scratch: &mut Scratch) -> Vec<Vec<i32>> {
        xs.iter().map(|x| self.forward_codes_into(x, scratch).to_vec()).collect()
    }

    /// Batched feature-level forward pass: the serving hot path.  Walks the
    /// batch in blocks (at most [`BATCH_BLOCK`] samples each, split finer so
    /// a small batch still yields one block per worker) and fans the blocks
    /// out over `workers` threads (one scratch per block; ragged final block
    /// and empty batches handled).  Output order matches `xs`.
    pub fn forward_batch_f32(&self, xs: &[Vec<f32>], workers: usize) -> Vec<Vec<f32>> {
        let block = if workers > 1 {
            xs.len().div_ceil(workers).clamp(1, BATCH_BLOCK)
        } else {
            BATCH_BLOCK
        };
        let blocks: Vec<&[Vec<f32>]> = xs.chunks(block).collect();
        let per_block: Vec<Vec<Vec<f32>>> = parallel_map(&blocks, workers, |_, block| {
            let mut scratch = Scratch::for_plan(self);
            block.iter().map(|x| self.forward(x, &mut scratch)).collect()
        });
        per_block.into_iter().flatten().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lut::tables::compile_network;
    use crate::nn::config;
    use crate::sim::lutsim::LutSim;
    use crate::util::rng::Rng;

    /// The same `(A, degree)` grid `lutsim_equals_network_forward` pins.
    const GRID: [(usize, u32); 6] = [(1, 1), (2, 1), (3, 1), (1, 2), (2, 2), (2, 3)];

    fn grid_net(a: usize, d: u32) -> (Network, NetworkTables) {
        let cfg = config::uniform("plan-t", &[8, 6, 3], 2, 2, 3, 3, 3, d, a, 3);
        let net = Network::random(&cfg, &mut Rng::new(a as u64 * 100 + d as u64));
        let tables = compile_network(&net, 1);
        (net, tables)
    }

    /// Bit-exactness: plan == naive LutSim reference == fixed-point model,
    /// across the full (A, degree) grid.
    #[test]
    fn plan_equals_network_and_reference_on_grid() {
        for (a, d) in GRID {
            let (net, tables) = grid_net(a, d);
            let plan = EvalPlan::compile(&net, &tables);
            let sim = LutSim::new(&net, &tables);
            let mut scratch = Scratch::for_plan(&plan);
            let mut rng = Rng::new(5);
            for _ in 0..200 {
                let x: Vec<f32> = (0..8).map(|_| rng.f32()).collect();
                let codes = net.quantize_input(&x);
                let want = net.forward_codes(&codes);
                assert_eq!(plan.forward_codes(&codes, &mut scratch), want, "A={a} D={d}");
                assert_eq!(sim.forward_codes_reference(&codes), want, "A={a} D={d}");
                // Dequantized logits agree with the model too.
                assert_eq!(plan.forward(&x, &mut scratch), net.forward(&x), "A={a} D={d}");
            }
        }
    }

    #[test]
    fn batch_matches_per_sample_with_ragged_final_block() {
        let (net, tables) = grid_net(2, 2);
        let plan = EvalPlan::compile(&net, &tables);
        let mut rng = Rng::new(11);
        // Deliberately not a multiple of BATCH_BLOCK: final block is ragged.
        let n = 2 * BATCH_BLOCK + 7;
        let xs: Vec<Vec<f32>> =
            (0..n).map(|_| (0..8).map(|_| rng.f32()).collect()).collect();
        for workers in [1, 3] {
            let batched = plan.forward_batch_f32(&xs, workers);
            assert_eq!(batched.len(), n);
            let mut scratch = Scratch::for_plan(&plan);
            for (x, got) in xs.iter().zip(&batched) {
                assert_eq!(got, &plan.forward(x, &mut scratch), "workers={workers}");
            }
        }
        // Code-level batch path agrees as well.
        let codes: Vec<Vec<i32>> = xs.iter().map(|x| net.quantize_input(x)).collect();
        let mut scratch = Scratch::for_plan(&plan);
        let batch_codes = plan.forward_batch(&codes, &mut scratch);
        for (c, got) in codes.iter().zip(&batch_codes) {
            assert_eq!(got, &net.forward_codes(c));
        }
    }

    #[test]
    fn empty_batch_is_empty() {
        let (net, tables) = grid_net(2, 1);
        let plan = EvalPlan::compile(&net, &tables);
        assert!(plan.forward_batch_f32(&[], 4).is_empty());
        let mut scratch = Scratch::for_plan(&plan);
        assert!(plan.forward_batch(&[], &mut scratch).is_empty());
    }

    #[test]
    fn scratch_reuse_is_deterministic() {
        let (net, tables) = grid_net(3, 1);
        let plan = EvalPlan::compile(&net, &tables);
        let mut scratch = Scratch::for_plan(&plan);
        let mut rng = Rng::new(3);
        let xs: Vec<Vec<f32>> =
            (0..8).map(|_| (0..8).map(|_| rng.f32()).collect()).collect();
        // Interleave two passes over the same inputs through one scratch:
        // results must not depend on scratch history.
        let first: Vec<Vec<f32>> = xs.iter().map(|x| plan.forward(x, &mut scratch)).collect();
        let second: Vec<Vec<f32>> =
            xs.iter().rev().map(|x| plan.forward(x, &mut scratch)).collect();
        for (a, b) in first.iter().zip(second.iter().rev()) {
            assert_eq!(a, b);
        }
        let _ = net;
    }

    #[test]
    fn predict_handles_binary_and_multiclass() {
        let (net, tables) = grid_net(2, 1);
        let plan = EvalPlan::compile(&net, &tables);
        let mut scratch = Scratch::for_plan(&plan);
        let mut rng = Rng::new(7);
        for _ in 0..20 {
            let x: Vec<f32> = (0..8).map(|_| rng.f32()).collect();
            let p = plan.predict(&x, &mut scratch);
            assert!(p < 3);
            assert_eq!(p, net.predict(&x));
        }
    }
}
