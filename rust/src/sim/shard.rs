//! Sharded intra-sample execution — one forward pass spread across S
//! shards with explicit bit-plane / code-buffer handoff.
//!
//! The batched engines ([`super::plan::EvalPlan`],
//! [`super::bitslice::BitsliceNet`]) parallelize *across* samples; below one
//! word of in-flight requests they leave every core but one idle.  This
//! module is ROADMAP lever (b): it partitions a compiled network so that a
//! *single* sample's forward pass runs in parallel — the software analogue
//! of splitting a wide neuron into A sub-neurons (the paper's core move),
//! applied one level up, and the prerequisite for multi-node serving where
//! the same handoff crosses a network link instead of a cache line.  The
//! full design narrative lives in `ARCHITECTURE.md` §4.
//!
//! # Partitioning
//!
//! - [`ShardedPlan`] splits every layer of an evaluation plan into S
//!   contiguous **neuron ranges**; shard s executes neurons
//!   `parts[l][s]` of layer l (gather → table read → store).
//! - [`ShardedBitslice`] splits every layer's op stream into S **plane
//!   ranges** (the output planes of a contiguous neuron range); each shard
//!   owns the backward cone of its root planes, re-flattened into a private
//!   op stream with compact node numbering (shared interior nodes are
//!   replicated across cones — see [`ShardedBitslice::replication`]).
//!
//! Before either split, the partitioner runs **cache-aware neuron
//! reordering** (ROADMAP lever (c), [`cache_aware_perms`]): within each
//! hidden layer, neurons are greedily chained so consecutive neurons share
//! fan-in sources, then the contiguous shard cuts fall between groups with
//! disjoint fan-in — minimizing cross-shard gathers, which directly shrinks
//! the dependency sets below.  [`permute_network`] applies the permutation
//! to the network and its tables (the last layer keeps its order, so
//! outputs are unchanged — a property test pins `forward_codes` equality).
//!
//! # Handoff and scheduling
//!
//! Layer boundaries are published through two shared buffers of `AtomicU64`
//! words, double-buffered by boundary parity (boundary b lives in
//! `bufs[b % 2]`), with the network edge in dedicated input/output staging
//! buffers.  The bitslice shard handoff format is exactly the bit-plane
//! layout of the boundary (`planes[j·β + b]`) — contiguous `u64` words, no
//! per-sample marshalling, as anticipated by the ROADMAP.
//!
//! The handoff unit is **deliberately pinned to canonical 64-bit plane
//! words** even though the local batch engine now compiles lane-generic
//! kernels up to 512 lanes wide (`crate::simd`): the sharded engines run
//! the scalar `u64` monomorphization of the same generic kernels
//! ([`exec_ops`]`::<u64>`, [`pack_word`]`::<u64>`), and the wide
//! `Blocks<N>` layout stores block i's plane word exactly where the i-th
//! scalar pack of the same 64-sample chunk puts it — so shared buffers,
//! PLW2 wire frames and the PR 3–6 hazard/verify arguments are all
//! untouched by lane width (`ARCHITECTURE.md` §3).
//!
//! Shard s may start layer l as soon as its precomputed dependency set is
//! satisfied — **fan-in-aware early start**, not a global layer barrier.
//! Each cell carries a flat list of `(shard, threshold)` pairs, satisfied
//! when `done[shard] ≥ threshold`, built from three hazard classes (see
//! `compute_deps` for the position-space derivation):
//!
//! - *producers*: the owner of every boundary-l position s gathers must
//!   have published layer l-1 (`done ≥ l`);
//! - *reader blockers*: before s overwrites a parity-buffer position, every
//!   shard still reading that position's previous generation must have
//!   finished that layer (`done ≥ bprev+1`);
//! - *writer ordering*: the previous generation's writer must have landed
//!   first (`done ≥ bprev`), or a lagging shard could clobber data a
//!   leading shard already published.
//!
//! The "previous generation" of a buffer position is the nearest *lower*
//! same-parity boundary **wide enough to cover that position** — boundary
//! widths are not monotonic, so generations can skip a parity level
//! entirely; the adversarial-interleaving simulation of the protocol that
//! pinned this rule down lives in-tree as the
//! `compute_deps_admits_only_safe_interleavings` test (and now drives the
//! protocol through the `Handoff` trait, not a concrete level store).
//! Workers are persistent threads that spin briefly for the next sample
//! (epoch) — budget configurable via `POLYLUT_SHARD_SPIN_US` /
//! [`resolve_spin_us`] — before sleeping on a condvar; within an epoch all
//! synchronization is spin-on-atomic.  Per-shard occupancy (cells
//! executed) and handoff-wait episodes are counted and surfaced through
//! [`ShardStats`] into `coordinator::metrics`.
//!
//! # Handoff abstraction and remote shards
//!
//! The wait-and-publish protocol itself is behind the crate-level
//! `Handoff` trait: `LocalHandoff` is the shared-memory implementation
//! (per-shard atomic levels, spin waits), `sim::wire`'s `RemoteHandoff`
//! satisfies the same waits by frame arrival on a TCP link.  A
//! [`crate::sim::wire::ShardPlacement`] maps each shard to a local worker
//! thread or to a remote `polylut shard-worker` process; each remote
//! shard is driven by a *sender/receiver* thread pair over a windowed
//! link ([`crate::sim::wire::WireConfig`]): the sender replays the exact
//! dependency schedule and ships needs flights up to the window ahead,
//! the receiver demuxes result frames into the shared buffers and
//! publishes `done[s]`.  Runners with any remote shard switch the shared
//! buffers from the parity pair to **per-boundary** buffers, so
//! apply-on-arrival cannot clobber a previous generation (all-local
//! runners keep the parity layout and its hazard argument unchanged).
//!
//! # Failure semantics
//!
//! A panicking kernel no longer poisons a mutex and hangs the engine:
//! worker panics are caught, recorded in the runner's sticky fault cell,
//! and every in-flight and subsequent forward call returns a clean `Err`
//! (the engine stays disabled; the coordinator falls back or surfaces the
//! error).  All control-mutex locks recover from poisoning.  A dead
//! *link*, by contrast, is no longer sticky: the wire layer reconnects
//! and resumes the open epoch from its boundary (`ARCHITECTURE.md` §7.4)
//! and only an exhausted retry budget faults the engine.

use std::collections::BTreeSet;
use std::ops::Range;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::lut::mapper::{map_network_of, MappedNetwork};
use crate::lut::tables::NetworkTables;
use crate::nn::network::Network;
use crate::nn::quant::unsigned_code;
use crate::sim::bitslice::{exec_ops, flatten_cone, mark_cone, pack_word, unpack_word, OpStream, WORD};
use crate::sim::plan::EvalPlan;
use crate::sim::wire::{
    EngineKind, Fnv, Frame, HostRegistry, LinkStats, WireConfig, WireHostStats, WireLink,
    WireStats,
};

/// Cumulative per-shard execution counters (monotonic over the engine's
/// lifetime): `cells` counts (layer, shard) work units executed —
/// the occupancy proxy — and `waits` counts handoff-wait episodes (a
/// dependency that was not yet published when first checked).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ShardStats {
    /// Layer-cells executed by this shard.
    pub cells: u64,
    /// Handoff-wait episodes (unready dependencies encountered).
    pub waits: u64,
}

// ---------------------------------------------------------------------------
// Handoff abstraction (wait-and-publish protocol)
// ---------------------------------------------------------------------------

/// Failure of the handoff protocol (panicked worker, dead link, poisoned
/// control state).  Sticky: once a runner faults, every call errors.
#[derive(Debug, Clone, PartialEq, Eq)]
pub(crate) struct HandoffError(pub String);

impl std::fmt::Display for HandoffError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for HandoffError {}

/// The producer/blocker/writer **wait-and-publish** protocol between shard
/// executors, abstracted from its transport.  `done[shard]` is a per-epoch
/// *level*: the number of layers that shard has completed (equivalently,
/// the highest boundary whose slice it has published).  A cell (l, s) may
/// run once `wait(d, thr)` has returned for every `(d, thr)` in its
/// dependency list, and announces its own boundary with
/// `publish(s, l + 1)`.
///
/// Implementations: `LocalHandoff` (shared `AtomicU32` levels, spin
/// waits — the original in-process path) and `sim::wire::RemoteHandoff`
/// (levels advance on frame arrival, publishes ship frames).  The
/// adversarial-interleaving protocol simulation runs against this trait.
pub(crate) trait Handoff: Send + Sync {
    /// Block until `done[shard] >= threshold`.  Returns whether it had to
    /// wait (the `ShardStats::waits` accounting), or the sticky fault.
    fn wait(&self, shard: usize, threshold: u32) -> Result<bool, HandoffError>;
    /// Announce `done[shard] = level` (shard finished layer `level - 1`).
    fn publish(&self, shard: usize, level: u32) -> Result<(), HandoffError>;
    /// Current published level of `shard` (non-blocking).
    fn level(&self, shard: usize) -> u32;
    /// Zero all levels for a new epoch (faults are *not* cleared).
    fn reset(&self);
    /// Record a fault (first message wins); all waiters unblock with `Err`.
    fn fail(&self, msg: &str);
    /// The sticky fault, if any.
    fn fault(&self) -> Option<String>;
}

/// Sticky fault cell, shareable across every epoch slot of one runner: a
/// fault recorded while any epoch is in flight must poison all of them
/// (and every future one), not just the slot that observed it.
pub(crate) struct FaultCell {
    faulted: AtomicBool,
    msg: Mutex<String>,
}

impl FaultCell {
    pub(crate) fn new() -> Arc<FaultCell> {
        Arc::new(FaultCell { faulted: AtomicBool::new(false), msg: Mutex::new(String::new()) })
    }

    /// Record a fault; the first message wins.
    pub(crate) fn set(&self, msg: &str) {
        let mut m = lock_ignore_poison(&self.msg);
        if !self.faulted.load(Ordering::Relaxed) {
            *m = msg.to_string();
        }
        self.faulted.store(true, Ordering::Release);
    }

    pub(crate) fn get(&self) -> Option<String> {
        if self.faulted.load(Ordering::Acquire) {
            Some(lock_ignore_poison(&self.msg).clone())
        } else {
            None
        }
    }
}

/// Shared-memory handoff: per-shard atomic levels, spin-then-nap waits
/// with fault polling.  This is the PR 3 protocol unchanged, minus the
/// ability to deadlock on a dead peer.  The fault cell may be shared by
/// several handoffs (one per epoch slot of a pipelined runner).
pub(crate) struct LocalHandoff {
    done: Vec<AtomicU32>,
    fault: Arc<FaultCell>,
}

impl LocalHandoff {
    pub(crate) fn new(shards: usize) -> LocalHandoff {
        Self::with_fault(shards, FaultCell::new())
    }

    pub(crate) fn with_fault(shards: usize, fault: Arc<FaultCell>) -> LocalHandoff {
        LocalHandoff { done: (0..shards).map(|_| AtomicU32::new(0)).collect(), fault }
    }
}

impl Handoff for LocalHandoff {
    fn wait(&self, shard: usize, threshold: u32) -> Result<bool, HandoffError> {
        if self.done[shard].load(Ordering::Acquire) >= threshold {
            return Ok(false);
        }
        let mut spins = 0u32;
        loop {
            if self.done[shard].load(Ordering::Acquire) >= threshold {
                return Ok(true);
            }
            if self.fault.faulted.load(Ordering::Relaxed) {
                return Err(HandoffError(self.fault().unwrap_or_default()));
            }
            spins = spins.wrapping_add(1);
            if spins & 0x3FFF == 0 {
                // Long waits (a remote shard's RTT, a stalling peer) must
                // not burn a core: nap, keep polling the fault flag.
                std::thread::sleep(Duration::from_micros(50));
            } else if spins & 0x3FF == 0 {
                std::thread::yield_now();
            } else {
                std::hint::spin_loop();
            }
        }
    }

    fn publish(&self, shard: usize, level: u32) -> Result<(), HandoffError> {
        self.done[shard].store(level, Ordering::Release);
        Ok(())
    }

    fn level(&self, shard: usize) -> u32 {
        self.done[shard].load(Ordering::Acquire)
    }

    fn reset(&self) {
        for d in &self.done {
            d.store(0, Ordering::Relaxed);
        }
    }

    fn fail(&self, msg: &str) {
        self.fault.set(msg);
    }

    fn fault(&self) -> Option<String> {
        self.fault.get()
    }
}

/// Lock a mutex, recovering from poisoning: the guarded state here (epoch
/// counters, fault messages) stays consistent under unwinding, and a
/// poisoned lock must surface as a clean engine error via the fault cell —
/// never as a panic cascade or a deadlocked server (the PR 4 bugfix for
/// the bare `.lock().unwrap()` calls on `ctrl`).
pub(crate) fn lock_ignore_poison<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".to_string()
    }
}

// ---------------------------------------------------------------------------
// Spin budget (configurable; remote links want zero)
// ---------------------------------------------------------------------------

/// Default epoch spin budget in microseconds: long enough that
/// back-to-back samples of one batch never pay a condvar wakeup, short
/// enough that an idle server burns no CPU.
pub const DEFAULT_SPIN_US: u64 = 20;

/// Resolve the worker spin-before-condvar-sleep budget (µs): an explicit
/// config wins, else the `POLYLUT_SHARD_SPIN_US` environment variable,
/// else [`DEFAULT_SPIN_US`] — except that runners driving **remote**
/// shards default to zero spin (the wire RTT dwarfs any wakeup latency, so
/// spinning only burns the coordinator's cores).  The resolved value is
/// recorded in `coordinator::metrics::snapshot()`.
pub fn resolve_spin_us(config: Option<u64>, has_remote: bool) -> u64 {
    config
        .or_else(|| {
            std::env::var("POLYLUT_SHARD_SPIN_US").ok().and_then(|v| v.parse().ok())
        })
        .unwrap_or(if has_remote { 0 } else { DEFAULT_SPIN_US })
}

// ---------------------------------------------------------------------------
// Cache-aware neuron reordering (ROADMAP lever (c))
// ---------------------------------------------------------------------------

/// Count of common elements of two sorted, deduplicated slices.
fn sorted_overlap(a: &[u32], b: &[u32]) -> usize {
    let (mut i, mut j, mut n) = (0usize, 0usize, 0usize);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                n += 1;
                i += 1;
                j += 1;
            }
        }
    }
    n
}

/// Greedy chain ordering: start from the neuron with the smallest first
/// source, then repeatedly append the unplaced neuron sharing the most
/// fan-in sources with the last placed one (ties: smaller first source,
/// then smaller index — fully deterministic).
fn order_by_shared_sources(srcs: &[Vec<u32>]) -> Vec<usize> {
    let n = srcs.len();
    if n == 0 {
        return Vec::new();
    }
    let first_src = |j: usize| srcs[j].first().copied().unwrap_or(u32::MAX);
    let mut placed = vec![false; n];
    let mut cur = (0..n).min_by_key(|&j| (first_src(j), j)).expect("n > 0");
    placed[cur] = true;
    let mut out = Vec::with_capacity(n);
    out.push(cur);
    for _ in 1..n {
        // (candidate, overlap, first source) of the best unplaced neuron.
        let mut best: Option<(usize, usize, u32)> = None;
        for j in 0..n {
            if placed[j] {
                continue;
            }
            let ov = sorted_overlap(&srcs[cur], &srcs[j]);
            let replace = match best {
                None => true,
                Some((bj, bov, bfs)) => {
                    ov > bov || (ov == bov && (first_src(j), j) < (bfs, bj))
                }
            };
            if replace {
                best = Some((j, ov, first_src(j)));
            }
        }
        let (j, _, _) = best.expect("unplaced neuron remains");
        placed[j] = true;
        out.push(j);
        cur = j;
    }
    out
}

/// Compute the cache-aware neuron permutation for every layer:
/// `perms[l][new_j] = old_j` orders layer l's output neurons so that
/// neurons sharing fan-in sources (union over their A sub-neurons, in the
/// *reordered* previous boundary's positions) sit adjacently.  The last
/// layer always gets the identity permutation so network outputs keep
/// their order.  Every returned permutation is a bijection — pinned by a
/// property test together with `forward_codes` preservation.
pub fn cache_aware_perms(net: &Network) -> Vec<Vec<usize>> {
    let cfg = &net.cfg;
    let l_count = cfg.n_layers();
    let mut perms = Vec::with_capacity(l_count);
    // Position of old boundary index `s` after the previous layer's reorder.
    let mut prev_pos: Option<Vec<usize>> = None;
    for l in 0..l_count {
        let n_out = cfg.widths[l + 1];
        if l == l_count - 1 {
            perms.push((0..n_out).collect());
            continue;
        }
        let srcs: Vec<Vec<u32>> = (0..n_out)
            .map(|j| {
                let mut v: Vec<u32> = net.layers[l]
                    .indices
                    .iter()
                    .flat_map(|sub| sub[j].iter())
                    .map(|&s| match &prev_pos {
                        Some(pos) => pos[s] as u32,
                        None => s as u32,
                    })
                    .collect();
                v.sort_unstable();
                v.dedup();
                v
            })
            .collect();
        let perm = order_by_shared_sources(&srcs);
        let mut pos = vec![0usize; n_out];
        for (nj, &oj) in perm.iter().enumerate() {
            pos[oj] = nj;
        }
        prev_pos = Some(pos);
        perms.push(perm);
    }
    perms
}

/// Apply per-layer output-neuron permutations to a network and its
/// compiled tables, remapping every fan-in index through the previous
/// layer's new ordering.  `perms[l][new_j] = old_j`; each must be a
/// bijection over `widths[l+1]`.  If the *last* layer's permutation is the
/// identity (as [`cache_aware_perms`] guarantees), the permuted network's
/// `forward_codes` is bit-identical to the original's for every input.
pub fn permute_network(
    net: &Network,
    tables: &NetworkTables,
    perms: &[Vec<usize>],
) -> (Network, NetworkTables) {
    let l_count = net.cfg.n_layers();
    assert_eq!(perms.len(), l_count, "one permutation per layer");
    let mut pnet = net.clone();
    let mut ptables = tables.clone();
    // Position of old boundary index after the previous layer's permutation.
    let mut prev_pos: Option<Vec<usize>> = None;
    for l in 0..l_count {
        let perm = &perms[l];
        let n_out = net.cfg.widths[l + 1];
        assert_eq!(perm.len(), n_out, "layer {l}: permutation length");
        {
            // Bijection check: every old index appears exactly once.
            let mut seen = vec![false; n_out];
            for &oj in perm {
                assert!(oj < n_out && !seen[oj], "layer {l}: not a permutation");
                seen[oj] = true;
            }
        }
        let src_p = &net.layers[l];
        let dst_p = &mut pnet.layers[l];
        for a in 0..net.cfg.a_factor {
            dst_p.indices[a] = perm
                .iter()
                .map(|&oj| {
                    src_p.indices[a][oj]
                        .iter()
                        .map(|&s| match &prev_pos {
                            Some(pos) => pos[s],
                            None => s,
                        })
                        .collect()
                })
                .collect();
            dst_p.w[a] = perm.iter().map(|&oj| src_p.w[a][oj].clone()).collect();
        }
        dst_p.bn_g = perm.iter().map(|&oj| src_p.bn_g[oj]).collect();
        dst_p.bn_b = perm.iter().map(|&oj| src_p.bn_b[oj]).collect();
        dst_p.bn_m = perm.iter().map(|&oj| src_p.bn_m[oj]).collect();
        dst_p.bn_v = perm.iter().map(|&oj| src_p.bn_v[oj]).collect();
        ptables.layers[l].neurons =
            perm.iter().map(|&oj| tables.layers[l].neurons[oj].clone()).collect();
        let mut pos = vec![0usize; n_out];
        for (nj, &oj) in perm.iter().enumerate() {
            pos[oj] = nj;
        }
        prev_pos = Some(pos);
    }
    (pnet, ptables)
}

// ---------------------------------------------------------------------------
// Partition helpers
// ---------------------------------------------------------------------------

/// Split `0..costs.len()` into `shards` contiguous ranges with approximately
/// balanced cost sums (greedy: each shard takes items until it reaches the
/// ceiling-average of the remaining cost; the last shard takes the rest).
/// Later ranges may be empty when there are fewer items than shards.
fn balanced_ranges(costs: &[u64], shards: usize) -> Vec<Range<usize>> {
    let n = costs.len();
    let total: u64 = costs.iter().sum();
    let mut out = Vec::with_capacity(shards);
    let mut start = 0usize;
    let mut spent = 0u64;
    for s in 0..shards {
        if s + 1 == shards {
            out.push(start..n);
            start = n;
            continue;
        }
        let left = (shards - s) as u64;
        let target = (total - spent).div_ceil(left);
        let mut end = start;
        let mut acc = 0u64;
        while end < n && acc < target {
            acc += costs[end];
            end += 1;
        }
        spent += acc;
        out.push(start..end);
        start = end;
    }
    out
}

// ---------------------------------------------------------------------------
// Dependency computation (shared by both kernels)
// ---------------------------------------------------------------------------

/// Inputs for dependency computation, in boundary *position* space — code
/// slots for the plan kernel, bit-plane indices for the bitslice kernel.
/// Retained inside each kernel after compilation: the wire layer derives a
/// remote shard's needs/result schedule from the same read/write sets.
pub(crate) struct DepSpec {
    /// `bounds[b]` = position-space width of boundary b (0..=L).
    bounds: Vec<usize>,
    /// `write[l][s]` = positions of boundary l+1 that cell (l, s) stores.
    write: Vec<Vec<Range<usize>>>,
    /// `reads[l][s]` = sorted, deduplicated positions of boundary l that
    /// cell (l, s) loads.
    reads: Vec<Vec<Vec<usize>>>,
}

/// Build the per-cell `(shard, threshold)` dependency lists from the three
/// hazard classes on the shared parity buffers (boundary b lives in
/// `bufs[b % 2]`; boundaries 0 and L live in private staging and need no
/// overwrite protection):
///
/// 1. **producers** — cell (l, s) reads boundary-l positions; the shard
///    that writes each such position at layer l-1 must be done with it:
///    threshold `l`.
/// 2. **reader blockers** — cell (l, s) overwrites positions of
///    boundary l+1 in `bufs[(l+1) % 2]` whose current content is the
///    position's *previous generation*: the nearest lower same-parity
///    boundary `bprev` wide enough to cover it (widths are not monotonic,
///    so generations may skip parity levels).  Every shard reading that
///    position at layer `bprev` must have finished: threshold `bprev + 1`.
/// 3. **writer ordering** — the shard that writes the position at
///    boundary `bprev` must have landed first (a lagging shard must not
///    clobber a leading shard's later-generation data): threshold `bprev`.
///
/// All thresholds reference layers strictly below l, so the wait graph is
/// acyclic and the schedule can never deadlock.  The rule set is pinned by
/// an adversarial-interleaving simulation of the protocol — kept in-tree
/// as the `compute_deps_admits_only_safe_interleavings` test — in which
/// every interleaving the dependencies admit must read exactly the
/// boundary generation it expects.
fn compute_deps(spec: &DepSpec, shards: usize) -> Vec<Vec<Vec<(u32, u32)>>> {
    use std::collections::BTreeMap;
    let l_count = spec.write.len();
    // Owner of position x at boundary b (the shard writing it at layer b-1).
    let owner = |b: usize, x: usize| -> u32 {
        for (q, r) in spec.write[b - 1].iter().enumerate() {
            if r.contains(&x) {
                return q as u32;
            }
        }
        unreachable!("boundary {b} position {x} not covered by shard ranges")
    };
    let mut deps = Vec::with_capacity(l_count);
    for l in 0..l_count {
        let mut per_shard = Vec::with_capacity(shards);
        for s in 0..shards {
            let mut set: BTreeMap<u32, u32> = BTreeMap::new();
            let add = |set: &mut BTreeMap<u32, u32>, q: u32, thr: u32| {
                if q as usize != s {
                    let e = set.entry(q).or_insert(0);
                    *e = (*e).max(thr);
                }
            };
            // (1) producers.
            if l >= 1 {
                for &x in &spec.reads[l][s] {
                    add(&mut set, owner(l, x), l as u32);
                }
            }
            // (2)+(3) overwrite protection, for writes into parity buffers.
            if l + 1 <= l_count - 1 {
                let r = &spec.write[l][s];
                let (a, b) = (r.start, r.end);
                let mut covered = 0usize;
                let mut bb = l as isize - 1;
                while bb >= 1 && covered < b {
                    let width = spec.bounds[bb as usize];
                    let lo = a.max(covered);
                    let hi = b.min(width);
                    if lo < hi {
                        for (q, rq) in spec.write[bb as usize - 1].iter().enumerate() {
                            if rq.start.max(lo) < rq.end.min(hi) {
                                add(&mut set, q as u32, bb as u32);
                            }
                        }
                        for (q, reads) in spec.reads[bb as usize].iter().enumerate() {
                            if reads.iter().any(|&x| (lo..hi).contains(&x)) {
                                add(&mut set, q as u32, bb as u32 + 1);
                            }
                        }
                    }
                    covered = covered.max(width);
                    bb -= 2;
                }
            }
            per_shard.push(set.into_iter().collect::<Vec<(u32, u32)>>());
        }
        deps.push(per_shard);
    }
    deps
}

// ---------------------------------------------------------------------------
// Generic shard runner (persistent workers + epoch protocol)
// ---------------------------------------------------------------------------

/// A sharded execution kernel: per-(layer, shard) work cells over shared
/// atomic handoff buffers, plus the precomputed dependency sets the runner
/// schedules by and the position-space read/write sets the wire layer
/// derives a remote shard's frame schedule from.
pub(crate) trait ShardKernel: Send + Sync + 'static {
    /// Per-worker scratch (created inside the worker thread).
    type Scratch: Send;
    fn n_layers(&self) -> usize;
    fn n_shards(&self) -> usize;
    /// Input staging buffer length (u64 slots).
    fn in_len(&self) -> usize;
    /// Output staging buffer length (u64 slots).
    fn out_len(&self) -> usize;
    /// Shared interior-boundary buffer length (u64 slots; max boundary).
    fn buf_len(&self) -> usize;
    /// `(shard, threshold)` pairs: cell (l, s) may run once
    /// `done[shard] >= threshold` for every pair (see `compute_deps`).
    fn deps(&self, l: usize, s: usize) -> &[(u32, u32)];
    /// Sorted, deduplicated boundary-l positions cell (l, s) loads.
    fn reads(&self, l: usize, s: usize) -> &[usize];
    /// Boundary-(l+1) positions cell (l, s) stores.
    fn write_range(&self, l: usize, s: usize) -> Range<usize>;
    fn make_scratch(&self) -> Self::Scratch;
    /// Execute cell (l, s): read boundary l from `src`, publish this
    /// shard's slice of boundary l+1 into `dst`.
    fn run_cell(
        &self,
        l: usize,
        s: usize,
        src: &[AtomicU64],
        dst: &[AtomicU64],
        scratch: &mut Self::Scratch,
    );
}

/// The boundary buffers one epoch flows through: network-edge staging
/// (boundary 0 and L) plus the interior buffers, in one of two modes:
///
/// - **parity** ([`BufSet::for_kernel`]): two shared buffers, boundary b
///   in `bufs[b % 2]` — the memory-lean in-process layout whose overwrite
///   hazards `compute_deps` protects;
/// - **per-boundary** ([`BufSet::per_boundary`]): one buffer per interior
///   boundary — used by the wire worker's private copies, where the
///   windowed stream may apply frames in any arrival order and parity
///   aliasing would otherwise need its own hazard machinery.
pub(crate) struct BufSet {
    pub(crate) input: Vec<AtomicU64>,
    pub(crate) output: Vec<AtomicU64>,
    bufs: Vec<Vec<AtomicU64>>,
    parity: bool,
}

fn mk_buf(n: usize) -> Vec<AtomicU64> {
    (0..n).map(|_| AtomicU64::new(0)).collect()
}

impl BufSet {
    /// Parity-indexed shared buffers (the in-process runner's layout).
    pub(crate) fn for_kernel<K: ShardKernel>(kernel: &K) -> BufSet {
        BufSet {
            input: mk_buf(kernel.in_len()),
            output: mk_buf(kernel.out_len()),
            bufs: vec![mk_buf(kernel.buf_len()), mk_buf(kernel.buf_len())],
            parity: true,
        }
    }

    /// One buffer per interior boundary (the wire worker's layout: frame
    /// application is order-independent because nothing aliases).
    pub(crate) fn per_boundary<K: ShardKernel>(kernel: &K) -> BufSet {
        let interior = kernel.n_layers().saturating_sub(1);
        BufSet {
            input: mk_buf(kernel.in_len()),
            output: mk_buf(kernel.out_len()),
            bufs: (0..interior.max(2)).map(|_| mk_buf(kernel.buf_len())).collect(),
            parity: false,
        }
    }

    fn idx(&self, b: usize) -> usize {
        if self.parity {
            b % 2
        } else {
            b - 1
        }
    }

    /// The buffer cell (l, ·) reads boundary l from.
    pub(crate) fn src(&self, l: usize) -> &[AtomicU64] {
        if l == 0 {
            &self.input
        } else {
            &self.bufs[self.idx(l)]
        }
    }

    /// The buffer cell (l, ·) publishes boundary l+1 into.
    pub(crate) fn dst(&self, l: usize, n_layers: usize) -> &[AtomicU64] {
        if l + 1 == n_layers {
            &self.output
        } else {
            &self.bufs[self.idx(l + 1)]
        }
    }

    /// The buffer holding boundary `b` (0 = input staging, `n_layers` =
    /// output staging, interior = parity or per-boundary buffer).
    pub(crate) fn boundary(&self, b: usize, n_layers: usize) -> &[AtomicU64] {
        if b == 0 {
            &self.input
        } else if b == n_layers {
            &self.output
        } else {
            &self.bufs[self.idx(b)]
        }
    }
}

/// One shard's epoch: the generic cell loop every executor runs — local
/// worker threads, remote proxies' peers (via `sim::wire::serve_shard`) —
/// parameterized only by the [`Handoff`] implementation and the dependency
/// lists (full hazard sets in-process; producer-class sets on the wire).
/// Counters land before the final publish so `stats()` reads taken right
/// after an epoch completes always include it.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_cells<K: ShardKernel, H: Handoff>(
    kernel: &K,
    handoff: &H,
    bufs: &BufSet,
    s: usize,
    deps: &[&[(u32, u32)]],
    cells: &AtomicU64,
    waits: &AtomicU64,
    start: usize,
    scratch: &mut K::Scratch,
) -> Result<(), HandoffError> {
    let n_layers = kernel.n_layers();
    let mut waited = 0u64;
    // `start > 0` is the worker-side checkpointed resume: levels up to
    // `start` were restored from the coordinator's replay, so the run
    // recomputes (and counts) only the layers above them.
    for l in start..n_layers {
        for &(d, thr) in deps[l] {
            if handoff.wait(d as usize, thr)? {
                waited += 1;
            }
        }
        kernel.run_cell(l, s, bufs.src(l), bufs.dst(l, n_layers), scratch);
        if l + 1 == n_layers {
            cells.fetch_add((n_layers - start) as u64, Ordering::Relaxed);
            waits.fetch_add(waited, Ordering::Relaxed);
        }
        handoff.publish(s, l as u32 + 1)?;
    }
    Ok(())
}

/// One slot of the epoch ring: private buffers + per-shard completion
/// levels for a single in-flight epoch.  Epoch `e` runs in slot
/// `(e - 1) % W`; the admission gate in `run_epoch` guarantees the slot's
/// previous occupant (epoch `e - W`) was fully collected before the slot
/// is re-staged.  Cross-epoch isolation therefore needs no extra hazard
/// bookkeeping — the PR 3 dependency classes apply *within* a slot only.
struct EpochSlot {
    bufs: BufSet,
    handoff: LocalHandoff,
}

struct Ctrl {
    /// Highest epoch id handed to a `run_epoch` caller (ticket counter).
    admitted: u64,
    /// Epochs staged but not yet announced (waiting on slower concurrent
    /// stagers of earlier ids).
    staged: BTreeSet<u64>,
    /// Highest epoch the shard loops may run: every id ≤ `announced` has
    /// its input staged and its slot handoff reset.
    announced: u64,
    /// Collected epochs above the contiguous prefix `freed`.
    done: BTreeSet<u64>,
    /// Every epoch ≤ `freed` is collected; slot reuse gates on this.
    freed: u64,
    shutdown: bool,
}

struct RunnerInner<K: ShardKernel> {
    kernel: K,
    /// The W-slot epoch ring (W = [`WireConfig::window`], min 1 — the
    /// lock-step degenerate case is a 1-slot ring).
    slots: Vec<EpochSlot>,
    /// Sticky fault shared by every slot's handoff.
    fault: Arc<FaultCell>,
    /// Fast-path announced-epoch counter (spin target); authoritative
    /// copy in `ctrl`.
    epoch_fast: AtomicU64,
    ctrl: Mutex<Ctrl>,
    /// Shard loops waiting for the next announcement.
    start_cv: Condvar,
    /// Admitters waiting for a ring slot to free up.
    free_cv: Condvar,
    /// High-water mark of concurrently in-flight epochs
    /// (`admitted − freed`; the `wire_inflight_epochs` metric).
    inflight_hwm: AtomicU64,
    /// Per-shard cumulative counters (see [`ShardStats`]).
    cells: Vec<AtomicU64>,
    waits: Vec<AtomicU64>,
    /// Epoch spin budget before the condvar sleep (µs; see
    /// [`resolve_spin_us`]).
    spin_us: u64,
}

impl<K: ShardKernel> RunnerInner<K> {
    fn slot(&self, epoch: u64) -> &EpochSlot {
        &self.slots[((epoch - 1) % self.slots.len() as u64) as usize]
    }
}

struct ShardRunner<K: ShardKernel> {
    inner: Arc<RunnerInner<K>>,
    workers: Vec<JoinHandle<()>>,
    /// The wire links of the remote shards (closed at shutdown to wake
    /// their sender/receiver threads).
    links: Vec<Arc<WireLink>>,
    /// Per-link wire counters (one entry per remote shard).
    link_stats: Vec<Arc<LinkStats>>,
}

/// Wait until epoch `next` has been announced (input staged, slot handoff
/// reset).  Returns the current announce watermark, or `None` on
/// shutdown.  Every shard loop walks epochs in id order — each epoch owns
/// a distinct ring slot, so none may be skipped.
fn wait_for_epoch<K: ShardKernel>(inner: &RunnerInner<K>, next: u64) -> Option<u64> {
    if inner.spin_us > 0 {
        let t0 = Instant::now();
        loop {
            for _ in 0..64 {
                let e = inner.epoch_fast.load(Ordering::Acquire);
                if e >= next {
                    return Some(e);
                }
                std::hint::spin_loop();
            }
            if t0.elapsed().as_micros() as u64 >= inner.spin_us {
                break;
            }
        }
    }
    let mut ctrl = lock_ignore_poison(&inner.ctrl);
    loop {
        if ctrl.shutdown {
            return None;
        }
        if ctrl.announced >= next {
            return Some(ctrl.announced);
        }
        ctrl = match inner.start_cv.wait(ctrl) {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
    }
}

/// Local shard executor: run this shard's cells for every epoch in id
/// order against that epoch's ring slot, catching kernel panics into the
/// sticky fault cell so a crashing shard turns into a clean engine error
/// instead of a poisoned mutex + deadlocked server.
fn worker_loop<K: ShardKernel>(inner: Arc<RunnerInner<K>>, s: usize) {
    let mut scratch = inner.kernel.make_scratch();
    let deps: Vec<&[(u32, u32)]> =
        (0..inner.kernel.n_layers()).map(|l| inner.kernel.deps(l, s)).collect();
    let mut next = 1u64;
    loop {
        if wait_for_epoch(&inner, next).is_none() {
            return;
        }
        if inner.fault.get().is_none() {
            let slot = inner.slot(next);
            let run = catch_unwind(AssertUnwindSafe(|| {
                run_cells(
                    &inner.kernel,
                    &slot.handoff,
                    &slot.bufs,
                    s,
                    &deps,
                    &inner.cells[s],
                    &inner.waits[s],
                    0,
                    &mut scratch,
                )
            }));
            match run {
                // A dependency-wait error means some peer already recorded
                // the fault; nothing to add.
                Ok(Ok(())) | Ok(Err(_)) => {}
                Err(p) => inner
                    .fault
                    .set(&format!("shard {s} worker panicked: {}", panic_message(&*p))),
            }
        }
        next += 1;
    }
}

/// Remote shard **sender** (coordinator side): replay the shard's exact
/// dependency schedule against the shared buffers, shipping each
/// boundary's cross-shard reads as one needs flight the moment the hazard
/// schedule allows — up to `WireConfig::window` flights ahead of the last
/// applied result, instead of the v1 lock-step alternation.  The hazards
/// still hold: a flight for boundary l is read from the shared buffers
/// only after `deps[l]` are satisfied, and every overwrite of those
/// positions waits on `done[s]` levels this link's receiver has not yet
/// published.
fn wire_send_loop<K: ShardKernel>(
    inner: Arc<RunnerInner<K>>,
    s: usize,
    link: Arc<WireLink>,
    needs: Vec<Vec<(u32, Range<usize>)>>,
) {
    let deps: Vec<&[(u32, u32)]> =
        (0..inner.kernel.n_layers()).map(|l| inner.kernel.deps(l, s)).collect();
    let mut next = 1u64;
    loop {
        if wait_for_epoch(&inner, next).is_none() {
            break;
        }
        if inner.fault.get().is_none() {
            if let Err(e) = send_epoch(&inner, s, &link, &needs, &deps, next) {
                if link.is_shutdown() {
                    break;
                }
                inner.fault.set(&format!("remote shard {s} ({}): {e}", link.peer()));
            }
        }
        next += 1;
    }
}

fn send_epoch<K: ShardKernel>(
    inner: &RunnerInner<K>,
    s: usize,
    link: &WireLink,
    needs: &[Vec<(u32, Range<usize>)>],
    deps: &[&[(u32, u32)]],
    epoch: u64,
) -> Result<(), HandoffError> {
    let slot = inner.slot(epoch);
    link.begin_epoch(epoch)?;
    let mut waited = 0u64;
    for (l, layer_needs) in needs.iter().enumerate() {
        // A boundary with no cross-shard needs ships nothing, so its
        // dep-waits would protect no reads — and MUST be skipped: the
        // worker does not block on empty flights, so the epoch can
        // complete (and the next epoch's handoff.reset() zero the levels)
        // while this thread still sits in a tail wait, closing a
        // sender ⇄ local-shard ⇄ worker wait cycle.  Skipping empty
        // boundaries outright means the sender never outlives the epoch:
        // every remaining flight is one the worker must consume before
        // the epoch can finish.
        if layer_needs.is_empty() {
            continue;
        }
        for &(d, thr) in deps[l] {
            if slot.handoff.wait(d as usize, thr)? {
                waited += 1;
            }
        }
        let src = slot.bufs.src(l);
        let mut frames: Vec<Frame> = layer_needs
            .iter()
            .map(|(producer, range)| {
                let words: Vec<u64> =
                    src[range.clone()].iter().map(|w| w.load(Ordering::Relaxed)).collect();
                Frame::data(epoch, l as u32, *producer, range.start as u32, words)
            })
            .collect();
        link.ship_flight(epoch, l as u32, &mut frames)?;
    }
    inner.waits[s].fetch_add(waited, Ordering::Relaxed);
    Ok(())
}

/// Remote shard **receiver** (coordinator side): demultiplex result frames
/// off the link (any arrival order — the link's completion table hands
/// them over as a contiguous boundary prefix, dropping resume-replay
/// duplicates), apply each to the shared buffers, and advance `done[s]` —
/// so every other shard's dependency wait on this shard is satisfied
/// exactly when its slice has landed, as in v1.
fn wire_recv_loop<K: ShardKernel>(
    inner: Arc<RunnerInner<K>>,
    s: usize,
    link: Arc<WireLink>,
    result: Vec<Range<usize>>,
) {
    let n_layers = inner.kernel.n_layers();
    loop {
        match link.recv_applied() {
            Ok(None) => return, // shutdown
            Ok(Some(f)) => {
                let l = f.boundary as usize - 1;
                let rr = &result[l];
                if f.epoch == 0
                    || f.shard as usize != s
                    || f.start as usize != rr.start
                    || f.words.len() != rr.len()
                {
                    let msg = format!(
                        "result frame mismatch: got (epoch {}, boundary {}, shard {}, \
                         {}+{}), want (boundary {}, shard {s}, {}+{})",
                        f.epoch,
                        f.boundary,
                        f.shard,
                        f.start,
                        f.words.len(),
                        f.boundary,
                        rr.start,
                        rr.len(),
                    );
                    link.kill(&msg);
                    inner.fault.set(&format!(
                        "remote shard {s} ({}): {msg}",
                        link.peer()
                    ));
                    return;
                }
                // The frame's epoch is open on the session (its completion
                // table drops stale ones), so its ring slot is its own: the
                // previous occupant was collected before this epoch was
                // admitted, hence before its Start ever shipped.
                let es = inner.slot(f.epoch);
                let dst = es.bufs.dst(l, n_layers);
                for (word_slot, w) in dst[rr.clone()].iter().zip(&f.words) {
                    word_slot.store(*w, Ordering::Relaxed);
                }
                link.mark_applied(&f);
                if f.boundary as usize == n_layers {
                    inner.cells[s].fetch_add(n_layers as u64, Ordering::Relaxed);
                }
                let _ = es.handoff.publish(s, f.boundary);
            }
            Err(e) => {
                if !link.is_shutdown() {
                    inner.fault.set(&format!(
                        "remote shard {s} ({}): {e}",
                        link.peer()
                    ));
                }
                return;
            }
        }
    }
}

impl<K: ShardKernel> ShardRunner<K> {
    /// All-local runner (the PR 3 behavior; cannot fail).
    fn new_local(kernel: K, spin_us: u64) -> ShardRunner<K> {
        let shards = kernel.n_shards();
        let registry = HostRegistry::new(shards, 0, WireConfig::default());
        Self::new(kernel, spin_us, EngineKind::Plan, &vec![None; shards], &registry)
            .expect("all-local shard runner construction cannot fail")
    }

    /// Runner with a placement map: local worker threads for `None`
    /// shards, a windowed sender/receiver thread pair per `Some(addr)`
    /// shard (sessions opened through the model's shared host registry).
    /// Fails cleanly when a link cannot be established or the handshake
    /// (shard count / model fingerprint) is rejected.
    fn new(
        kernel: K,
        spin_us: u64,
        engine: EngineKind,
        placement: &[Option<String>],
        registry: &HostRegistry,
    ) -> Result<ShardRunner<K>> {
        let shards = kernel.n_shards();
        let has_remote = placement.iter().any(|p| p.is_some());
        let depth = registry.cfg().window.max(1);
        // All-local runners keep the memory-lean parity buffers (the PR 3
        // layout compute_deps' hazard classes protect).  Runners with any
        // remote shard use per-boundary buffers: the windowed receiver
        // applies result frames the moment they arrive — possibly before
        // the sender has even reached that boundary in the hazard
        // schedule (a remote cell with zero cross-shard needs runs ahead
        // of its empty flight) — and with nothing aliased there is no
        // previous generation to clobber, so apply-on-arrival is safe and
        // the local shards' parity-hazard waits become harmlessly
        // conservative.  Either layout is replicated per ring slot:
        // concurrent epochs touch disjoint slots by construction.
        let fault = FaultCell::new();
        let slots: Vec<EpochSlot> = (0..depth)
            .map(|_| EpochSlot {
                bufs: if has_remote {
                    BufSet::per_boundary(&kernel)
                } else {
                    BufSet::for_kernel(&kernel)
                },
                handoff: LocalHandoff::with_fault(shards, fault.clone()),
            })
            .collect();
        let inner = Arc::new(RunnerInner {
            slots,
            fault,
            kernel,
            epoch_fast: AtomicU64::new(0),
            ctrl: Mutex::new(Ctrl {
                admitted: 0,
                staged: BTreeSet::new(),
                announced: 0,
                done: BTreeSet::new(),
                freed: 0,
                shutdown: false,
            }),
            start_cv: Condvar::new(),
            free_cv: Condvar::new(),
            inflight_hwm: AtomicU64::new(0),
            cells: (0..shards).map(|_| AtomicU64::new(0)).collect(),
            waits: (0..shards).map(|_| AtomicU64::new(0)).collect(),
            spin_us,
        });
        let mut runner = ShardRunner {
            inner: inner.clone(),
            workers: Vec::with_capacity(shards),
            links: Vec::new(),
            link_stats: Vec::new(),
        };
        let n_layers = inner.kernel.n_layers();
        for s in 0..shards {
            let inner = inner.clone();
            match placement.get(s).and_then(|p| p.as_deref()) {
                None => runner.workers.push(
                    std::thread::Builder::new()
                        .name(format!("polylut-shard-{s}"))
                        .spawn(move || worker_loop(inner, s))
                        .expect("spawn shard worker"),
                ),
                Some(addr) => {
                    let link = WireLink::connect(registry, addr, engine, s, n_layers)
                        .map_err(|e| anyhow::anyhow!("shard {s} -> {addr}: {e}"))?;
                    runner.link_stats.push(link.stats());
                    runner.links.push(link.clone());
                    // One wire-plan compilation per link, split between the
                    // thread pair (sender: needs schedule; receiver: result
                    // ranges).
                    let wp = crate::sim::wire::wire_plan(&inner.kernel, s);
                    let (needs, result) = (wp.needs, wp.result);
                    let send_inner = inner.clone();
                    let send_link = link.clone();
                    runner.workers.push(
                        std::thread::Builder::new()
                            .name(format!("polylut-wire-send-{s}"))
                            .spawn(move || wire_send_loop(send_inner, s, send_link, needs))
                            .expect("spawn wire sender"),
                    );
                    runner.workers.push(
                        std::thread::Builder::new()
                            .name(format!("polylut-wire-recv-{s}"))
                            .spawn(move || wire_recv_loop(inner, s, link, result))
                            .expect("spawn wire receiver"),
                    );
                }
            }
        }
        Ok(runner)
    }

    /// Run one epoch (one sample / one word): admit it into the ring —
    /// blocking while all W slots are occupied — stage the input into its
    /// slot, announce it, wait for this epoch's completion, collect the
    /// output.  Up to W epochs from concurrent callers overlap end to
    /// end; bit-exact isolation comes from the distinct buffer slots.
    /// Errors are sticky: once a shard has panicked or a link's retry
    /// budget is exhausted, this and every subsequent call fail fast.
    fn run_epoch(
        &self,
        stage: impl FnOnce(&[AtomicU64]),
        collect: impl FnOnce(&[AtomicU64]),
    ) -> Result<(), HandoffError> {
        let inner = &*self.inner;
        if let Some(msg) = inner.fault.get() {
            return Err(HandoffError(msg));
        }
        let depth = inner.slots.len() as u64;
        // Admission: claim the next epoch id once its ring slot is free,
        // i.e. the occupant W epochs back has been collected.
        let epoch = {
            let mut ctrl = lock_ignore_poison(&inner.ctrl);
            loop {
                if ctrl.shutdown {
                    return Err(HandoffError("shard runner shut down".into()));
                }
                if let Some(msg) = inner.fault.get() {
                    return Err(HandoffError(msg));
                }
                if ctrl.admitted < ctrl.freed + depth {
                    break;
                }
                ctrl = match inner.free_cv.wait(ctrl) {
                    Ok(g) => g,
                    Err(p) => p.into_inner(),
                };
            }
            ctrl.admitted += 1;
            inner.inflight_hwm.fetch_max(ctrl.admitted - ctrl.freed, Ordering::Relaxed);
            ctrl.admitted
        };
        let slot = inner.slot(epoch);
        stage(&slot.bufs.input);
        slot.handoff.reset();
        {
            // Announce in id order: the watermark advances over the
            // contiguous staged prefix, so a shard loop never runs an
            // epoch whose input a slower concurrent caller is still
            // staging.
            let mut ctrl = lock_ignore_poison(&inner.ctrl);
            ctrl.staged.insert(epoch);
            while ctrl.staged.remove(&(ctrl.announced + 1)) {
                ctrl.announced += 1;
            }
            inner.epoch_fast.store(ctrl.announced, Ordering::Release);
            inner.start_cv.notify_all();
        }
        let n_layers = inner.kernel.n_layers() as u32;
        let mut result = Ok(());
        for s in 0..inner.kernel.n_shards() {
            if let Err(e) = slot.handoff.wait(s, n_layers) {
                result = Err(e);
                break;
            }
        }
        if result.is_ok() {
            collect(&slot.bufs.output);
        }
        // Free the slot even on a fault: peers blocked on admission must
        // wake and observe the sticky fault, not hang on a ring that will
        // never drain.
        {
            let mut ctrl = lock_ignore_poison(&inner.ctrl);
            ctrl.done.insert(epoch);
            while ctrl.done.remove(&(ctrl.freed + 1)) {
                ctrl.freed += 1;
            }
            inner.free_cv.notify_all();
        }
        result
    }

    fn stats(&self) -> Vec<ShardStats> {
        self.inner
            .cells
            .iter()
            .zip(&self.inner.waits)
            .map(|(c, w)| ShardStats {
                cells: c.load(Ordering::Relaxed),
                waits: w.load(Ordering::Relaxed),
            })
            .collect()
    }

    /// Summed wire counters of this runner's remote links (sessions
    /// only — host-level recovery counters are folded once per host by
    /// `ShardedModel::wire_stats`), plus this runner's epoch-ring
    /// concurrency high-water mark.
    fn wire_stats(&self) -> WireStats {
        let mut ws = self
            .link_stats
            .iter()
            .fold(WireStats::default(), |acc, l| acc.merged(l.snapshot()));
        ws.inflight_epochs =
            ws.inflight_epochs.max(self.inner.inflight_hwm.load(Ordering::Relaxed));
        ws
    }

    /// Ring depth W: how many epochs may be in flight at once.
    fn ring_depth(&self) -> usize {
        self.inner.slots.len()
    }

    fn n_remote(&self) -> usize {
        self.link_stats.len()
    }
}

impl<K: ShardKernel> Drop for ShardRunner<K> {
    fn drop(&mut self) {
        {
            let mut ctrl = lock_ignore_poison(&self.inner.ctrl);
            ctrl.shutdown = true;
            self.inner.start_cv.notify_all();
            self.inner.free_cv.notify_all();
        }
        // Close every link: sets the shutdown flag and shuts the socket,
        // so senders blocked on the window gate and receivers parked in a
        // read unblock and join() can't hang.
        for link in &self.links {
            link.close();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Plan kernel: neuron-range sharding of the evaluation plan
// ---------------------------------------------------------------------------

/// Neuron-range sharding of the evaluation plan (see [`ShardedPlan`]).
pub(crate) struct PlanKernel {
    plan: EvalPlan,
    parts: Vec<Vec<Range<usize>>>,
    spec: DepSpec,
    pub(crate) deps: Vec<Vec<Vec<(u32, u32)>>>,
    shards: usize,
}

/// Dependency spec of a neuron-range plan partition: positions are code
/// slots, reads come from the flat gather arrays.
fn plan_dep_spec(plan: &EvalPlan, parts: &[Vec<Range<usize>>]) -> DepSpec {
    let reads = parts
        .iter()
        .zip(&plan.layers)
        .map(|(ranges, lp)| {
            ranges
                .iter()
                .map(|r| {
                    let g0 = r.start * lp.a * lp.fan;
                    let g1 = r.end * lp.a * lp.fan;
                    let mut v: Vec<usize> =
                        lp.gather[g0..g1].iter().map(|&p| p as usize).collect();
                    v.sort_unstable();
                    v.dedup();
                    v
                })
                .collect()
        })
        .collect();
    DepSpec { bounds: plan.widths.clone(), write: parts.to_vec(), reads }
}

impl ShardKernel for PlanKernel {
    type Scratch = Vec<i32>;

    fn n_layers(&self) -> usize {
        self.plan.layers.len()
    }

    fn n_shards(&self) -> usize {
        self.shards
    }

    fn in_len(&self) -> usize {
        self.plan.widths[0]
    }

    fn out_len(&self) -> usize {
        *self.plan.widths.last().expect("at least one boundary")
    }

    fn buf_len(&self) -> usize {
        let w = &self.plan.widths;
        w[1..w.len() - 1].iter().copied().max().unwrap_or(0)
    }

    fn deps(&self, l: usize, s: usize) -> &[(u32, u32)] {
        &self.deps[l][s]
    }

    fn reads(&self, l: usize, s: usize) -> &[usize] {
        &self.spec.reads[l][s]
    }

    fn write_range(&self, l: usize, s: usize) -> Range<usize> {
        self.spec.write[l][s].clone()
    }

    fn make_scratch(&self) -> Vec<i32> {
        vec![0; self.plan.a_factor]
    }

    fn run_cell(
        &self,
        l: usize,
        s: usize,
        src: &[AtomicU64],
        dst: &[AtomicU64],
        subs: &mut Vec<i32>,
    ) {
        let lp = &self.plan.layers[l];
        let r = self.parts[l][s].clone();
        if r.is_empty() {
            return;
        }
        // Mirrors `EvalPlan::execute` exactly (same gather/address/table
        // arithmetic over the same decoded values), restricted to this
        // shard's neuron range — which is what makes shard output
        // bit-exact with the unsharded plan.
        let in_bits = lp.in_bits;
        let in_mask = (1usize << in_bits) - 1;
        let sub_mask = (1usize << lp.sub_bits) - 1;
        let mut gbase = r.start * lp.a * lp.fan;
        let mut tbase = r.start * lp.a * lp.poly_stride;
        for j in r {
            if lp.adder_stride == 0 {
                let srcs = &lp.gather[gbase..gbase + lp.fan];
                let mut addr = 0usize;
                for (slot, &si) in srcs.iter().enumerate() {
                    let c = src[si as usize].load(Ordering::Relaxed) as u32 as i32;
                    addr |= (c as usize & in_mask) << (slot as u32 * in_bits);
                }
                dst[j].store(lp.poly[tbase + addr] as u32 as u64, Ordering::Relaxed);
                gbase += lp.fan;
                tbase += lp.poly_stride;
            } else {
                for sub in subs[..lp.a].iter_mut() {
                    let srcs = &lp.gather[gbase..gbase + lp.fan];
                    let mut addr = 0usize;
                    for (slot, &si) in srcs.iter().enumerate() {
                        let c = src[si as usize].load(Ordering::Relaxed) as u32 as i32;
                        addr |= (c as usize & in_mask) << (slot as u32 * in_bits);
                    }
                    *sub = lp.poly[tbase + addr];
                    gbase += lp.fan;
                    tbase += lp.poly_stride;
                }
                let mut aaddr = 0usize;
                for (ai, &sc) in subs[..lp.a].iter().enumerate() {
                    aaddr |= (sc as usize & sub_mask) << (ai as u32 * lp.sub_bits);
                }
                dst[j].store(
                    lp.adder[j * lp.adder_stride + aaddr] as u32 as u64,
                    Ordering::Relaxed,
                );
            }
        }
    }
}

/// Cache-aware reorder + permute, shared by every shard compilation path
/// (coordinator and remote worker must agree bit-for-bit).
pub(crate) fn permuted_for_shards(
    net: &Network,
    tables: &NetworkTables,
) -> (Network, NetworkTables) {
    let perms = cache_aware_perms(net);
    permute_network(net, tables, &perms)
}

/// Fingerprint of a permuted model + shard count: the wire handshake
/// refuses links whose two ends would partition or evaluate differently.
/// Hashes the numeric geometry, the full fan-in connectivity and every
/// table word (names/seeds excluded — they don't affect evaluation).
pub(crate) fn shard_fingerprint(
    pnet: &Network,
    ptables: &NetworkTables,
    shards: usize,
) -> u64 {
    let cfg = &pnet.cfg;
    let mut h = Fnv::new();
    h.write_u64(shards as u64);
    // Fold level changes the cones the bitslice kernel schedules (the
    // table-word hashing below already catches DC/prune divergence), so a
    // coordinator↔worker mismatch must fail the handshake, not corrupt
    // the needs schedules.
    h.write_u64(crate::lut::OptLevel::resolve(None).folds() as u64);
    h.write_u64(cfg.a_factor as u64);
    h.write_u64(cfg.degree as u64);
    for &w in &cfg.widths {
        h.write_u64(w as u64);
    }
    for &b in &cfg.beta {
        h.write_u64(b as u64);
    }
    for &f in &cfg.fan {
        h.write_u64(f as u64);
    }
    for layer in &pnet.layers {
        for sub in &layer.indices {
            for srcs in sub {
                for &s in srcs {
                    h.write_u64(s as u64);
                }
            }
        }
    }
    for lt in &ptables.layers {
        for nt in &lt.neurons {
            for t in nt.poly.iter().chain(nt.adder.as_ref()) {
                h.write_u64(((t.n_inputs as u64) << 32) | t.out_bits as u64);
                h.write_u64(t.signed_out as u64);
                for &w in &t.words {
                    h.write_u64(w as u64);
                }
            }
        }
    }
    h.finish()
}

/// Compile the neuron-range plan kernel from an already-permuted model.
pub(crate) fn plan_kernel_of(
    pnet: &Network,
    ptables: &NetworkTables,
    shards: usize,
) -> PlanKernel {
    let shards = shards.max(1);
    let plan = EvalPlan::compile(pnet, ptables);
    let parts: Vec<Vec<Range<usize>>> = plan
        .layers
        .iter()
        .map(|lp| {
            let costs = vec![1u64; lp.n_out];
            balanced_ranges(&costs, shards)
        })
        .collect();
    let spec = plan_dep_spec(&plan, &parts);
    let deps = compute_deps(&spec, shards);
    PlanKernel { plan, parts, spec, deps, shards }
}

/// The evaluation plan partitioned into S neuron-range shards with
/// persistent workers — lowest single-sample latency on multi-core hosts
/// once layers are wide enough to amortize the handoff.  Bit-exact with
/// [`EvalPlan`] and `Network::forward_codes`.  Shards may be placed on
/// remote `polylut shard-worker` hosts (see [`ShardedModel::compile_placed`]
/// and `ARCHITECTURE.md` §4/§7).
pub struct ShardedPlan {
    runner: ShardRunner<PlanKernel>,
    n_features: usize,
    n_outputs: usize,
    in_bits: u32,
    out_step: f32,
    shards: usize,
}

impl ShardedPlan {
    /// Reorder (cache-aware), permute, compile and partition `net` into an
    /// all-local S-shard plan engine (spawns S worker threads).
    pub fn compile(net: &Network, tables: &NetworkTables, shards: usize) -> ShardedPlan {
        let (pnet, ptables) = permuted_for_shards(net, tables);
        let kernel = plan_kernel_of(&pnet, &ptables, shards);
        let registry = HostRegistry::new(shards, 0, WireConfig::default());
        Self::from_kernel(kernel, resolve_spin_us(None, false), &[], &registry)
            .expect("all-local plan shards cannot fail")
    }

    /// Build from a compiled kernel, a placement map and the model's host
    /// registry (shared with [`ShardedModel::compile_placed_wire`] so
    /// both engines' sessions multiplex over the same host links).
    pub(crate) fn from_kernel(
        kernel: PlanKernel,
        spin_us: u64,
        placement: &[Option<String>],
        registry: &HostRegistry,
    ) -> Result<ShardedPlan> {
        let n_features = kernel.plan.n_features();
        let n_outputs = kernel.plan.n_outputs();
        let in_bits = kernel.plan.in_bits;
        let out_step = kernel.plan.out_step;
        let shards = kernel.shards;
        Ok(ShardedPlan {
            runner: ShardRunner::new(kernel, spin_us, EngineKind::Plan, placement, registry)?,
            n_features,
            n_outputs,
            in_bits,
            out_step,
            shards,
        })
    }

    /// Shard count S.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Cumulative per-shard occupancy / handoff-wait counters.
    pub fn stats(&self) -> Vec<ShardStats> {
        self.runner.stats()
    }

    /// Summed wire counters of this engine's remote links.
    pub(crate) fn wire_stats(&self) -> WireStats {
        self.runner.wire_stats()
    }

    pub(crate) fn n_remote(&self) -> usize {
        self.runner.n_remote()
    }

    pub(crate) fn faulted(&self) -> bool {
        self.runner.inner.fault.get().is_some()
    }

    /// Sharded table-only forward pass over input codes.  Errors when the
    /// engine has faulted (panicked shard, dead remote link) — sticky.
    pub fn forward_codes(&self, in_codes: &[i32]) -> Result<Vec<i32>> {
        assert_eq!(in_codes.len(), self.n_features, "input width mismatch");
        let mut out = vec![0i32; self.n_outputs];
        self.runner.run_epoch(
            |input| {
                for (slot, &c) in input.iter().zip(in_codes) {
                    slot.store(c as u32 as u64, Ordering::Relaxed);
                }
            },
            |output| {
                for (o, slot) in out.iter_mut().zip(output) {
                    *o = slot.load(Ordering::Relaxed) as u32 as i32;
                }
            },
        )?;
        Ok(out)
    }

    /// Batched code-level forward pass.  All-local (or W = 1) runners go
    /// sample-by-sample; runners with remote shards and a W-deep epoch
    /// ring submit from W lanes so up to W samples overlap end to end —
    /// each sample's network round-trips hide behind its neighbors'
    /// compute.  Sample order is restored on merge and epochs are
    /// isolated by ring slot, so the result is bit-exact with the serial
    /// path.
    pub fn forward_batch(&self, xs: &[Vec<i32>]) -> Result<Vec<Vec<i32>>> {
        let lanes = self.runner.ring_depth().min(xs.len());
        if self.runner.n_remote() == 0 || lanes <= 1 {
            return xs.iter().map(|x| self.forward_codes(x)).collect();
        }
        let mut rows: Vec<Option<Vec<i32>>> = (0..xs.len()).map(|_| None).collect();
        std::thread::scope(|scope| -> Result<()> {
            let mut handles = Vec::with_capacity(lanes);
            for t in 0..lanes {
                handles.push(scope.spawn(move || -> Result<Vec<(usize, Vec<i32>)>> {
                    let mut got = Vec::new();
                    let mut i = t;
                    while i < xs.len() {
                        got.push((i, self.forward_codes(&xs[i])?));
                        i += lanes;
                    }
                    Ok(got)
                }));
            }
            for h in handles {
                let got =
                    h.join().map_err(|_| anyhow::anyhow!("batch submit lane panicked"))??;
                for (i, row) in got {
                    rows[i] = Some(row);
                }
            }
            Ok(())
        })?;
        Ok(rows.into_iter().map(|r| r.expect("every sample produced a row")).collect())
    }

    /// Forward from raw [0,1] features; returns dequantized logits
    /// (bit-exact with `EvalPlan::forward`).
    pub fn forward(&self, x: &[f32]) -> Result<Vec<f32>> {
        assert_eq!(x.len(), self.n_features, "feature width mismatch");
        let codes: Vec<i32> =
            x.iter().map(|&v| unsigned_code(v, self.in_bits, 1.0)).collect();
        Ok(self.forward_codes(&codes)?.iter().map(|&c| c as f32 * self.out_step).collect())
    }
}

// ---------------------------------------------------------------------------
// Bitslice kernel: plane-range sharding of the op streams
// ---------------------------------------------------------------------------

/// One shard's slice of one layer: the op stream over its root cone plus
/// the (global plane, local node) publication list.
pub(crate) struct ShardStream {
    pub(crate) stream: OpStream,
    pub(crate) roots: Vec<(u32, u32)>,
}

/// Plane-range sharding of the bitslice op streams (see
/// [`ShardedBitslice`]).  Carries the network-edge metadata so engines can
/// be built from the kernel alone (both here and in a remote worker).
pub(crate) struct BitsliceKernel {
    pub(crate) layers: Vec<Vec<ShardStream>>,
    spec: DepSpec,
    pub(crate) deps: Vec<Vec<Vec<(u32, u32)>>>,
    shards: usize,
    in_planes: usize,
    out_planes: usize,
    buf_planes: usize,
    max_nodes: usize,
    n_features: usize,
    n_outputs: usize,
    in_bits: u32,
    out_bits: u32,
    signed_out: bool,
    out_step: f32,
    replication: f64,
}

/// Dependency spec of a plane-range bitslice partition: positions are
/// bit-plane indices (neuron range scaled by the layer's output width),
/// reads are the bind wires of each shard's op stream.
fn bitslice_dep_spec(
    pnet: &Network,
    ptables: &NetworkTables,
    layers: &[Vec<ShardStream>],
    parts: &[Vec<Range<usize>>],
) -> DepSpec {
    let cfg = &pnet.cfg;
    let l_count = layers.len();
    let bounds: Vec<usize> =
        (0..=l_count).map(|b| cfg.widths[b] * cfg.beta[b] as usize).collect();
    let write = parts
        .iter()
        .enumerate()
        .map(|(l, ranges)| {
            let ob = ptables.layers[l].out_bits as usize;
            ranges.iter().map(|r| r.start * ob..r.end * ob).collect()
        })
        .collect();
    let reads = layers
        .iter()
        .map(|per_shard| {
            per_shard
                .iter()
                .map(|st| {
                    let mut v: Vec<usize> =
                        st.stream.bind.iter().map(|&(_, w)| w as usize).collect();
                    v.sort_unstable();
                    v.dedup();
                    v
                })
                .collect()
        })
        .collect();
    DepSpec { bounds, write, reads }
}

fn build_bitslice_kernel(
    pnet: &Network,
    ptables: &NetworkTables,
    mapped: &MappedNetwork,
    shards: usize,
) -> BitsliceKernel {
    let cfg = &pnet.cfg;
    let l_count = cfg.n_layers();
    let mut layers = Vec::with_capacity(l_count);
    let mut parts = Vec::with_capacity(l_count);
    for (ml, lt) in mapped.layers.iter().zip(&ptables.layers) {
        let nl = &ml.netlist;
        let n_out = ml.roots.len();
        // Cost = size of each neuron's own cone (shared nodes counted per
        // neuron — the same replication the shard streams pay).
        let costs: Vec<u64> = (0..n_out)
            .map(|j| {
                let mut keep = vec![false; nl.nodes.len()];
                mark_cone(nl, &ml.roots[j], &mut keep);
                keep.iter().filter(|&&k| k).count() as u64
            })
            .collect();
        let ranges = balanced_ranges(&costs, shards);
        let ob = lt.out_bits as usize;
        let per_shard: Vec<ShardStream> = ranges
            .iter()
            .map(|r| {
                let mut keep = vec![false; nl.nodes.len()];
                for j in r.clone() {
                    mark_cone(nl, &ml.roots[j], &mut keep);
                }
                let (stream, map) = flatten_cone(nl, &keep);
                let mut roots = Vec::with_capacity(r.len() * ob);
                for j in r.clone() {
                    for (b, &node) in ml.roots[j].iter().enumerate() {
                        roots.push(((j * ob + b) as u32, map[node as usize]));
                    }
                }
                ShardStream { stream, roots }
            })
            .collect();
        layers.push(per_shard);
        parts.push(ranges);
    }
    let spec = bitslice_dep_spec(pnet, ptables, &layers, &parts);
    let deps = compute_deps(&spec, shards);
    let in_planes = cfg.widths[0] * cfg.beta[0] as usize;
    let out_planes = cfg.widths[l_count] * cfg.beta[l_count] as usize;
    let buf_planes =
        (1..l_count).map(|b| cfg.widths[b] * cfg.beta[b] as usize).max().unwrap_or(0);
    let max_nodes =
        layers.iter().flat_map(|ls| ls.iter()).map(|st| st.stream.n_nodes).max().unwrap_or(0);
    let total_nodes: usize = mapped.layers.iter().map(|l| l.netlist.nodes.len()).sum();
    let shard_nodes: usize =
        layers.iter().flat_map(|ls| ls.iter()).map(|st| st.stream.n_nodes).sum();
    let last = &ptables.layers[l_count - 1];
    BitsliceKernel {
        layers,
        spec,
        deps,
        shards,
        in_planes,
        out_planes,
        buf_planes,
        max_nodes,
        n_features: cfg.widths[0],
        n_outputs: cfg.widths[l_count],
        in_bits: cfg.beta[0],
        out_bits: last.out_bits,
        signed_out: last.signed_out,
        out_step: pnet.out_step(l_count - 1),
        replication: shard_nodes as f64 / total_nodes.max(1) as f64,
    }
}

/// Compile the plane-range bitslice kernel from an already-permuted model
/// (maps the netlists with `workers` threads — deterministic output).
pub(crate) fn bits_kernel_of(
    pnet: &Network,
    ptables: &NetworkTables,
    shards: usize,
    workers: usize,
) -> BitsliceKernel {
    let mut mapped = map_network_of(pnet, ptables, workers);
    // Same resolution as the FrozenModel compile path: the sharded engine
    // executes folded cones at any level above `none` (the tables were
    // already rewritten by the caller at the same resolved level).
    if crate::lut::OptLevel::resolve(None).folds() {
        mapped = crate::lut::opt::fold_network(&mapped, workers);
    }
    build_bitslice_kernel(pnet, ptables, &mapped, shards.max(1))
}

impl ShardKernel for BitsliceKernel {
    type Scratch = Vec<u64>;

    fn n_layers(&self) -> usize {
        self.layers.len()
    }

    fn n_shards(&self) -> usize {
        self.shards
    }

    fn in_len(&self) -> usize {
        self.in_planes
    }

    fn out_len(&self) -> usize {
        self.out_planes
    }

    fn buf_len(&self) -> usize {
        self.buf_planes
    }

    fn deps(&self, l: usize, s: usize) -> &[(u32, u32)] {
        &self.deps[l][s]
    }

    fn reads(&self, l: usize, s: usize) -> &[usize] {
        &self.spec.reads[l][s]
    }

    fn write_range(&self, l: usize, s: usize) -> Range<usize> {
        self.spec.write[l][s].clone()
    }

    fn make_scratch(&self) -> Vec<u64> {
        vec![0; self.max_nodes]
    }

    fn run_cell(
        &self,
        l: usize,
        s: usize,
        src: &[AtomicU64],
        dst: &[AtomicU64],
        vals: &mut Vec<u64>,
    ) {
        let st = &self.layers[l][s];
        for &(node, wire) in &st.stream.bind {
            vals[node as usize] = src[wire as usize].load(Ordering::Relaxed);
        }
        exec_ops(&st.stream, vals);
        for &(plane, node) in &st.roots {
            dst[plane as usize].store(vals[node as usize], Ordering::Relaxed);
        }
    }
}

/// The bitsliced netlist engine partitioned into S plane-range shards: each
/// shard owns the backward cone of a contiguous slice of every layer's
/// output bit-planes and publishes those planes into the shared handoff
/// buffers.  Bit-exact with [`super::bitslice::BitsliceNet`].  See
/// `ARCHITECTURE.md` §4.
pub struct ShardedBitslice {
    runner: ShardRunner<BitsliceKernel>,
    n_features: usize,
    n_outputs: usize,
    in_bits: u32,
    out_bits: u32,
    signed_out: bool,
    out_step: f32,
    shards: usize,
    replication: f64,
}

impl ShardedBitslice {
    /// Reorder, permute, map and partition `net` into an all-local S-shard
    /// bitslice engine (spawns S worker threads; mapping is parallel over
    /// `workers`).
    pub fn compile(
        net: &Network,
        tables: &NetworkTables,
        shards: usize,
        workers: usize,
    ) -> ShardedBitslice {
        let (pnet, ptables) = permuted_for_shards(net, tables);
        let kernel = bits_kernel_of(&pnet, &ptables, shards, workers);
        let registry = HostRegistry::new(shards, 0, WireConfig::default());
        Self::from_kernel(kernel, resolve_spin_us(None, false), &[], &registry)
            .expect("all-local bitslice shards cannot fail")
    }

    /// Build from a compiled kernel, a placement map and the model's host
    /// registry (shared with [`ShardedModel::compile_placed_wire`] so
    /// both engines' sessions multiplex over the same host links).
    pub(crate) fn from_kernel(
        kernel: BitsliceKernel,
        spin_us: u64,
        placement: &[Option<String>],
        registry: &HostRegistry,
    ) -> Result<ShardedBitslice> {
        Ok(ShardedBitslice {
            n_features: kernel.n_features,
            n_outputs: kernel.n_outputs,
            in_bits: kernel.in_bits,
            out_bits: kernel.out_bits,
            signed_out: kernel.signed_out,
            out_step: kernel.out_step,
            shards: kernel.shards,
            replication: kernel.replication,
            runner: ShardRunner::new(
                kernel,
                spin_us,
                EngineKind::Bitslice,
                placement,
                registry,
            )?,
        })
    }

    /// Shard count S.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Input feature count.
    pub fn n_features(&self) -> usize {
        self.n_features
    }

    /// Output neuron count.
    pub fn n_outputs(&self) -> usize {
        self.n_outputs
    }

    /// Node replication factor across shard cones: 1.0 = perfectly disjoint
    /// cones, higher means interior nodes shared between neurons were
    /// duplicated into several shards' streams.
    pub fn replication(&self) -> f64 {
        self.replication
    }

    /// Cumulative per-shard occupancy / handoff-wait counters.
    pub fn stats(&self) -> Vec<ShardStats> {
        self.runner.stats()
    }

    /// Summed wire counters of this engine's remote links.
    pub(crate) fn wire_stats(&self) -> WireStats {
        self.runner.wire_stats()
    }

    pub(crate) fn n_remote(&self) -> usize {
        self.runner.n_remote()
    }

    pub(crate) fn faulted(&self) -> bool {
        self.runner.inner.fault.get().is_some()
    }

    /// One ≤64-sample word: pack to planes, run the sharded streams, unpack.
    /// Pack/unpack go through the same [`pack_word`]/[`unpack_word`] pair as
    /// the unsharded engine — the bit-plane layout lives in one place — with
    /// only the copy to/from the atomic staging buffers added here.
    fn forward_word(&self, word: &[Vec<i32>], out: &mut Vec<Vec<i32>>) -> Result<()> {
        debug_assert!(!word.is_empty() && word.len() <= WORD);
        for row in word {
            assert_eq!(row.len(), self.n_features, "input width mismatch");
        }
        let mut planes = vec![0u64; self.n_features * self.in_bits as usize];
        pack_word(word, self.in_bits, &mut planes);
        self.runner.run_epoch(
            |input| {
                for (slot, &p) in input.iter().zip(&planes) {
                    slot.store(p, Ordering::Relaxed);
                }
            },
            |output| {
                let planes: Vec<u64> =
                    output.iter().map(|p| p.load(Ordering::Relaxed)).collect();
                unpack_word(
                    &planes,
                    self.n_outputs,
                    self.out_bits,
                    self.signed_out,
                    word.len(),
                    out,
                );
            },
        )?;
        Ok(())
    }

    /// Batched code-level forward pass: each ≤64-sample word is one
    /// epoch; ragged tails handled (invalid lanes are packed as zero and
    /// never unpacked).  All-local (or W = 1) runners go word-by-word;
    /// runners with remote shards and a W-deep epoch ring submit words
    /// from W lanes so their network round-trips overlap (order restored
    /// on merge — bit-exact with `BitsliceNet::forward_batch` either
    /// way).  Errors when the engine has faulted.
    pub fn forward_batch(&self, xs: &[Vec<i32>]) -> Result<Vec<Vec<i32>>> {
        let words: Vec<&[Vec<i32>]> = xs.chunks(WORD).collect();
        let lanes = self.runner.ring_depth().min(words.len());
        if self.runner.n_remote() == 0 || lanes <= 1 {
            let mut out = Vec::with_capacity(xs.len());
            for word in words {
                self.forward_word(word, &mut out)?;
            }
            return Ok(out);
        }
        let mut chunks: Vec<Option<Vec<Vec<i32>>>> = (0..words.len()).map(|_| None).collect();
        std::thread::scope(|scope| -> Result<()> {
            let words = &words;
            let mut handles = Vec::with_capacity(lanes);
            for t in 0..lanes {
                handles.push(scope.spawn(move || -> Result<Vec<(usize, Vec<Vec<i32>>)>> {
                    let mut got = Vec::new();
                    let mut i = t;
                    while i < words.len() {
                        let mut rows = Vec::with_capacity(words[i].len());
                        self.forward_word(words[i], &mut rows)?;
                        got.push((i, rows));
                        i += lanes;
                    }
                    Ok(got)
                }));
            }
            for h in handles {
                let got =
                    h.join().map_err(|_| anyhow::anyhow!("batch submit lane panicked"))??;
                for (i, rows) in got {
                    chunks[i] = Some(rows);
                }
            }
            Ok(())
        })?;
        Ok(chunks
            .into_iter()
            .flat_map(|c| c.expect("every word produced its rows"))
            .collect())
    }
}

// ---------------------------------------------------------------------------
// Combined sharded model
// ---------------------------------------------------------------------------

/// Both sharded engines over one shared cache-aware reordering: the plan
/// shards serve sub-word batches sample-by-sample (latency), the bitslice
/// shards serve word-sized batches word-by-word (throughput within a
/// word).  `Backend::Lut` routes here when `EngineSelect::shards > 1` and
/// the batch is below the bitslice crossover.  With a placement map
/// ([`Self::compile_placed`]) individual shards live on remote
/// `polylut shard-worker` hosts, handing bit-planes over TCP.
pub struct ShardedModel {
    /// Neuron-range sharded evaluation plan.
    pub plan: ShardedPlan,
    /// Plane-range sharded bitslice engine.
    pub bits: ShardedBitslice,
    /// Host-link registry both engines' sessions were opened through:
    /// with [`WireConfig::mux`] (the default) all (engine, shard)
    /// sessions to one host share one TCP connection and one recovery
    /// ladder.
    registry: Arc<HostRegistry>,
    shards: usize,
    spin_us: u64,
}

impl ShardedModel {
    /// Reorder once, then build both all-local sharded engines from the
    /// same permuted network (2·S worker threads total).
    pub fn compile(
        net: &Network,
        tables: &NetworkTables,
        shards: usize,
        workers: usize,
    ) -> ShardedModel {
        Self::compile_placed(net, tables, shards, workers, &[], None)
            .expect("all-local sharded compilation cannot fail")
    }

    /// Reorder once, then build both sharded engines under a placement
    /// map: `placement[s] = Some("host:port")` drives shard s on a remote
    /// `polylut shard-worker` (each engine opens its own link), `None` and
    /// unlisted shards run on local threads.  `spin_us` overrides the
    /// epoch spin budget ([`resolve_spin_us`]; remote placements default
    /// to zero spin).  Fails cleanly when a link cannot be established or
    /// a worker's model fingerprint disagrees.
    pub fn compile_placed(
        net: &Network,
        tables: &NetworkTables,
        shards: usize,
        workers: usize,
        placement: &[Option<String>],
        spin_us: Option<u64>,
    ) -> Result<ShardedModel> {
        Self::compile_placed_wire(
            net,
            tables,
            shards,
            workers,
            placement,
            spin_us,
            WireConfig::default(),
        )
    }

    /// [`ShardedModel::compile_placed`] with explicit wire knobs: the
    /// in-flight window (`--wire-window`; 1 = v1 lock-step pacing) and the
    /// reconnect-and-resume retry budget (`--wire-retries`) every remote
    /// link uses.
    pub fn compile_placed_wire(
        net: &Network,
        tables: &NetworkTables,
        shards: usize,
        workers: usize,
        placement: &[Option<String>],
        spin_us: Option<u64>,
        wire: WireConfig,
    ) -> Result<ShardedModel> {
        let shards = shards.max(1);
        anyhow::ensure!(
            placement.len() <= shards,
            "placement lists {} shards, model has {shards}",
            placement.len()
        );
        let has_remote = placement.iter().any(|p| p.is_some());
        let spin_us = resolve_spin_us(spin_us, has_remote);
        let (pnet, ptables) = permuted_for_shards(net, tables);
        let fingerprint = shard_fingerprint(&pnet, &ptables, shards);
        let plan_kernel = plan_kernel_of(&pnet, &ptables, shards);
        let bits_kernel = bits_kernel_of(&pnet, &ptables, shards, workers);
        if crate::sim::verify::gate_enabled() {
            crate::sim::verify::report_for_kernels(&plan_kernel, &bits_kernel).gate()?;
        }
        // One registry for both engines: with mux on, the bitslice
        // engine's sessions ride the host links the plan engine already
        // dialed (one connection, one reader, one recovery ladder per
        // host).
        let registry = Arc::new(HostRegistry::new(shards, fingerprint, wire));
        let plan = ShardedPlan::from_kernel(plan_kernel, spin_us, placement, &registry)?;
        let bits = ShardedBitslice::from_kernel(bits_kernel, spin_us, placement, &registry)?;
        Ok(ShardedModel { plan, bits, registry, shards, spin_us })
    }

    /// Shard count S.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// The resolved epoch spin budget (µs) both runners use.
    pub fn spin_us(&self) -> u64 {
        self.spin_us
    }

    /// Summed wire counters over both engines' remote links (`None` when
    /// every shard is local): session-level transport counters summed per
    /// engine, host-level recovery counters (reconnects, resumes, replay
    /// totals) folded **once per host link** — with mux on both engines
    /// share each host's link, so folding those per engine would
    /// double-count every incident.
    pub fn wire_stats(&self) -> Option<WireStats> {
        if self.plan.n_remote() + self.bits.n_remote() == 0 {
            return None;
        }
        let mut ws = self.plan.wire_stats().merged(self.bits.wire_stats());
        for h in self.registry.hosts() {
            ws = ws.merged(h.recovery_stats());
        }
        Some(ws)
    }

    /// Distinct host links in use — with mux on, exactly one TCP
    /// connection per remote worker host, however many (engine, shard)
    /// sessions it carries.
    pub fn wire_links(&self) -> usize {
        self.registry.hosts().len()
    }

    /// Per-host transport/recovery rollup (the `wire_hosts=[…]` metrics
    /// group).
    pub fn wire_host_stats(&self) -> Vec<WireHostStats> {
        self.registry.hosts().iter().map(|h| h.host_stats()).collect()
    }

    /// Whether either sharded engine carries a sticky fault (panicked
    /// shard, dead wire link).  A faulted model errors on every forward
    /// call; `Backend::route` uses this to fall back to the in-process
    /// plan engine instead of failing every sub-crossover batch forever.
    pub fn faulted(&self) -> bool {
        self.plan.faulted() || self.bits.faulted()
    }

    /// Test hook: inject a sticky fault into both engines (the production
    /// fault paths — kernel panics, wire errors — are exercised at the
    /// runner and wire layers; this lets API-level tests reach the faulted
    /// state without a real failure).
    #[cfg(test)]
    pub(crate) fn inject_fault(&self, msg: &str) {
        self.plan.runner.inner.fault.set(msg);
        self.bits.runner.inner.fault.set(msg);
    }

    /// Batched feature-level forward pass: word-sized batches run through
    /// the sharded bitslice engine, smaller ones sample-by-sample through
    /// the sharded plan.  Bit-exact with both unsharded engines; errors
    /// when an engine has faulted (sticky).
    pub fn forward_batch_f32(&self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        if xs.is_empty() {
            return Ok(Vec::new());
        }
        if xs.len() >= WORD {
            let codes: Vec<Vec<i32>> = xs
                .iter()
                .map(|x| {
                    assert_eq!(x.len(), self.bits.n_features, "feature width mismatch");
                    x.iter().map(|&v| unsigned_code(v, self.bits.in_bits, 1.0)).collect()
                })
                .collect();
            Ok(self
                .bits
                .forward_batch(&codes)?
                .into_iter()
                .map(|row| row.iter().map(|&c| c as f32 * self.bits.out_step).collect())
                .collect())
        } else {
            xs.iter().map(|x| self.plan.forward(x)).collect()
        }
    }

    /// Per-shard counters summed over both engines.
    pub fn stats(&self) -> Vec<ShardStats> {
        self.plan
            .stats()
            .into_iter()
            .zip(self.bits.stats())
            .map(|(p, b)| ShardStats { cells: p.cells + b.cells, waits: p.waits + b.waits })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lut::tables::compile_network;
    use crate::nn::config;
    use crate::prop_assert;
    use crate::sim::plan::Scratch;
    use crate::util::pool::default_workers;
    use crate::util::prop::{self, Outcome};
    use crate::util::rng::Rng;

    /// The same `(A, degree)` grid the plan and bitslice tests pin.
    const GRID: [(usize, u32); 6] = [(1, 1), (2, 1), (3, 1), (1, 2), (2, 2), (2, 3)];

    fn grid_net(a: usize, d: u32) -> (Network, NetworkTables) {
        let cfg = config::uniform("shard-t", &[8, 6, 3], 2, 2, 3, 3, 3, d, a, 3);
        let net = Network::random(&cfg, &mut Rng::new(a as u64 * 100 + d as u64));
        let tables = compile_network(&net, 1);
        (net, tables)
    }

    fn random_codes(net: &Network, n: usize, seed: u64) -> Vec<Vec<i32>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                let x: Vec<f32> =
                    (0..net.cfg.widths[0]).map(|_| rng.f32()).collect();
                net.quantize_input(&x)
            })
            .collect()
    }

    #[test]
    fn balanced_ranges_cover_and_balance() {
        let costs = [3u64, 1, 1, 1, 3, 1, 1, 1];
        for shards in [1usize, 2, 3, 4, 8, 11] {
            let ranges = balanced_ranges(&costs, shards);
            assert_eq!(ranges.len(), shards);
            let mut pos = 0usize;
            for r in &ranges {
                assert_eq!(r.start, pos, "contiguous");
                assert!(r.end >= r.start);
                pos = r.end;
            }
            assert_eq!(pos, costs.len(), "covering");
        }
        assert!(balanced_ranges(&[], 3).iter().all(|r| r.is_empty()));
    }

    /// The adversarial-interleaving simulation the module docs cite,
    /// driven **through the [`Handoff`] trait**: a pure-logic model of the
    /// runner executes cells in randomized orders constrained *only* by
    /// the trait's published levels against `compute_deps`' thresholds,
    /// tagging every parity-buffer position with the boundary generation
    /// it holds.  Any admitted interleaving must read exactly the
    /// generation it expects — this is the harness that pinned the
    /// previous-covering-boundary rule (generations skip a parity level
    /// when widths are non-monotonic) and it doubles as a no-deadlock
    /// check.  Generic over the handoff implementation so the protocol
    /// contract is pinned on the abstraction, not on `LocalHandoff`'s
    /// atomics.
    fn adversarial_interleavings_against<H: Handoff>(mk: impl Fn(usize) -> H) {
        let mut rng = Rng::new(0x0DE9);
        for trial in 0..300 {
            let l_count = 1 + rng.below(6);
            let bounds: Vec<usize> = (0..=l_count).map(|_| 1 + rng.below(12)).collect();
            let shard_choices = [1usize, 2, 3, 5, 8];
            let shards = shard_choices[rng.below(shard_choices.len())];
            let write: Vec<Vec<Range<usize>>> = (0..l_count)
                .map(|l| {
                    let costs = vec![1u64; bounds[l + 1]];
                    balanced_ranges(&costs, shards)
                })
                .collect();
            // Arbitrary read sets (harsher than real gathers, which are
            // derived from connectivity).
            let reads: Vec<Vec<Vec<usize>>> = (0..l_count)
                .map(|l| {
                    (0..shards)
                        .map(|_| {
                            let n = rng.below(6);
                            let mut v: Vec<usize> =
                                (0..n).map(|_| rng.below(bounds[l])).collect();
                            v.sort_unstable();
                            v.dedup();
                            v
                        })
                        .collect()
                })
                .collect();
            let spec = DepSpec {
                bounds: bounds.clone(),
                write: write.clone(),
                reads: reads.clone(),
            };
            let deps = compute_deps(&spec, shards);
            let handoff = mk(shards);
            let maxbuf = bounds[1..l_count].iter().copied().max().unwrap_or(0);
            // tags[p][x] = boundary generation buffer p position x holds
            // (-1 = stale data from a previous epoch).
            let mut tags = [vec![-1isize; maxbuf], vec![-1isize; maxbuf]];
            let mut progress = vec![0usize; shards];
            while progress.iter().any(|&p| p < l_count) {
                let ready: Vec<usize> = (0..shards)
                    .filter(|&s| {
                        progress[s] < l_count
                            && deps[progress[s]][s]
                                .iter()
                                .all(|&(d, thr)| handoff.level(d as usize) >= thr)
                    })
                    .collect();
                assert!(!ready.is_empty(), "deadlock (trial {trial})");
                let s = ready[rng.below(ready.len())];
                let l = progress[s];
                if l >= 1 {
                    for &x in &reads[l][s] {
                        assert_eq!(
                            tags[l % 2][x],
                            l as isize,
                            "trial {trial}: cell ({l}, {s}) read boundary-{l} \
                             position {x} holding a different generation"
                        );
                    }
                }
                if l + 1 <= l_count - 1 {
                    for x in write[l][s].clone() {
                        tags[(l + 1) % 2][x] = l as isize + 1;
                    }
                }
                handoff.publish(s, l as u32 + 1).expect("publish in simulation");
                progress[s] += 1;
            }
        }
    }

    #[test]
    fn compute_deps_admits_only_safe_interleavings() {
        adversarial_interleavings_against(LocalHandoff::new);
    }

    /// Sharded plan and sharded bitslice are bit-exact with the unsharded
    /// plan (itself pinned to `Network::forward_codes`) over the full
    /// (A, degree) grid, a multi-word ragged batch, and several shard
    /// counts including more shards than neurons.
    #[test]
    fn sharded_engines_bit_exact_on_grid() {
        for (a, d) in GRID {
            let (net, tables) = grid_net(a, d);
            let plan = EvalPlan::compile(&net, &tables);
            let mut scratch = Scratch::for_plan(&plan);
            let xs = random_codes(&net, 2 * WORD + 11, 9);
            let want = plan.forward_batch(&xs, &mut scratch);
            for (i, (x, w)) in xs.iter().zip(&want).enumerate() {
                assert_eq!(w, &net.forward_codes(x), "A={a} D={d} sample {i}");
            }
            for shards in [1usize, 2, 3, 8] {
                let model = ShardedModel::compile(&net, &tables, shards, 1);
                assert_eq!(
                    model.plan.forward_batch(&xs).unwrap(),
                    want,
                    "plan A={a} D={d} S={shards}"
                );
                assert_eq!(
                    model.bits.forward_batch(&xs).unwrap(),
                    want,
                    "bits A={a} D={d} S={shards}"
                );
                let st = model.stats();
                assert_eq!(st.len(), shards);
                assert!(st.iter().all(|s| s.cells > 0), "every shard ran");
            }
        }
    }

    /// Ragged and empty batches agree with the plan through one engine
    /// (scratch/epoch reuse across calls must not leak state).
    #[test]
    fn ragged_batches_match_plan() {
        let (net, tables) = grid_net(2, 2);
        let plan = EvalPlan::compile(&net, &tables);
        let mut scratch = Scratch::for_plan(&plan);
        let model = ShardedModel::compile(&net, &tables, 3, 1);
        for n in [0usize, 1, 63, 64, 65, 130] {
            let xs = random_codes(&net, n, 31 + n as u64);
            let want = plan.forward_batch(&xs, &mut scratch);
            assert_eq!(model.plan.forward_batch(&xs).unwrap(), want, "plan batch {n}");
            assert_eq!(model.bits.forward_batch(&xs).unwrap(), want, "bits batch {n}");
        }
    }

    /// The sharded route's canonical 64-bit plane handoff stays bit-exact
    /// when the *local* batch engine is compiled at a wide lane width: the
    /// sharded bitslice (u64 monomorphization of the generic kernels,
    /// planes over the handoff buffers) and a widest-lane
    /// [`crate::sim::BitsliceNet`] must agree sample-for-sample, so a
    /// coordinator mixing the two routes never changes answers with lane
    /// width.  Batch sizes straddle both 64-lane and wide-word boundaries.
    #[test]
    fn sharded_handoff_matches_wide_local_engine() {
        let (net, tables) = grid_net(2, 2);
        let widest = crate::simd::widest_lanes();
        let wide = crate::sim::BitsliceNet::compile(&net, &tables, 1)
            .with_lane_plan(crate::simd::plan_for(widest));
        let model = ShardedModel::compile(&net, &tables, 3, 1);
        for n in [1usize, 63, 64, 65, widest - 1, widest, widest + 1] {
            let xs = random_codes(&net, n, 77 + n as u64);
            let want = wide.forward_batch_codes(&xs);
            assert_eq!(
                model.bits.forward_batch(&xs).unwrap(),
                want,
                "sharded vs wide({widest}) batch {n}"
            );
        }
    }

    /// A deeper geometry (4 layers) exercises the blocker condition
    /// (layers 2..=L-2) and early start across shard counts, including
    /// S = available cores.
    #[test]
    fn deep_geometry_bit_exact_with_blockers() {
        let cfg = config::uniform("shard-deep", &[8, 10, 8, 6, 3], 2, 2, 3, 3, 3, 1, 2, 3);
        let net = Network::random(&cfg, &mut Rng::new(77));
        let tables = compile_network(&net, 1);
        let plan = EvalPlan::compile(&net, &tables);
        let mut scratch = Scratch::for_plan(&plan);
        let xs = random_codes(&net, WORD + 9, 13);
        let want = plan.forward_batch(&xs, &mut scratch);
        for shards in [2usize, 3, default_workers()] {
            let model = ShardedModel::compile(&net, &tables, shards, 1);
            assert_eq!(model.plan.forward_batch(&xs).unwrap(), want, "plan S={shards}");
            assert_eq!(model.bits.forward_batch(&xs).unwrap(), want, "bits S={shards}");
        }
    }

    /// The f32 entry point matches the unsharded engines' dequantized
    /// logits on both routes (sub-word → plan shards, word → bitslice
    /// shards).
    #[test]
    fn forward_batch_f32_matches_unsharded() {
        let (net, tables) = grid_net(2, 1);
        let plan = EvalPlan::compile(&net, &tables);
        let model = ShardedModel::compile(&net, &tables, 2, 1);
        let mut rng = Rng::new(5);
        for n in [5usize, WORD + 3] {
            let xs: Vec<Vec<f32>> =
                (0..n).map(|_| (0..8).map(|_| rng.f32()).collect()).collect();
            assert_eq!(
                model.forward_batch_f32(&xs).unwrap(),
                plan.forward_batch_f32(&xs, 1),
                "n={n}"
            );
        }
        assert!(model.forward_batch_f32(&[]).unwrap().is_empty());
    }

    /// Repeated single-sample calls through one engine are deterministic
    /// (epoch protocol resets cleanly) and waits/cells counters move.
    #[test]
    fn epoch_reuse_is_deterministic_and_counted() {
        let (net, tables) = grid_net(3, 1);
        let model = ShardedModel::compile(&net, &tables, 2, 1);
        let xs = random_codes(&net, 8, 3);
        let first: Vec<Vec<i32>> =
            xs.iter().map(|x| model.plan.forward_codes(x).unwrap()).collect();
        let second: Vec<Vec<i32>> =
            xs.iter().rev().map(|x| model.plan.forward_codes(x).unwrap()).collect();
        for (a, b) in first.iter().zip(second.iter().rev()) {
            assert_eq!(a, b);
        }
        let st = model.plan.stats();
        let total_cells: u64 = st.iter().map(|s| s.cells).sum();
        assert_eq!(total_cells, 16 * 2 * 2, "16 samples x 2 shards x 2 layers");
    }

    /// Property: the cache-aware reorder produces a bijection per layer
    /// (identity on the last) and the permuted network's `forward_codes`
    /// is bit-identical to the original's, over random geometries.
    #[test]
    fn prop_cache_aware_perm_bijection_preserves_forward() {
        prop::check("cache-aware reorder", 25, |g| {
            let a = g.usize_in(1, 3);
            let d = g.usize_in(1, 2) as u32;
            // Hidden widths stay >= the fan-in (3) so connectivity sampling
            // is well-defined at every layer.
            let w1 = g.usize_in(3, 10);
            let w2 = g.usize_in(3, 8);
            let cfg = config::uniform("prop-shard", &[8, w1, w2, 3], 2, 2, 3, 3, 3, d, a, 3);
            let net = Network::random(&cfg, &mut g.rng.fork(1));
            let tables = compile_network(&net, 1);
            let perms = cache_aware_perms(&net);
            prop_assert!(perms.len() == cfg.n_layers(), "one perm per layer");
            for (l, perm) in perms.iter().enumerate() {
                let n_out = cfg.widths[l + 1];
                prop_assert!(perm.len() == n_out, "layer {l} length");
                let mut sorted = perm.clone();
                sorted.sort_unstable();
                prop_assert!(
                    sorted == (0..n_out).collect::<Vec<_>>(),
                    "layer {l} not a bijection: {perm:?}"
                );
            }
            let last = perms.last().expect("at least one layer");
            prop_assert!(
                last == &(0..cfg.widths[cfg.n_layers()]).collect::<Vec<_>>(),
                "last layer must keep output order"
            );
            let (pnet, ptables) = permute_network(&net, &tables, &perms);
            prop_assert!(
                ptables.total_words == tables.total_words,
                "permutation must not change table accounting"
            );
            let mut rng = g.rng.fork(2);
            for _ in 0..20 {
                let x: Vec<f32> = (0..8).map(|_| rng.f32()).collect();
                let codes = net.quantize_input(&x);
                prop_assert!(
                    pnet.forward_codes(&codes) == net.forward_codes(&codes),
                    "forward_codes changed under reorder"
                );
            }
            Outcome::Pass
        });
    }

    /// Reordering groups shared fan-in: interleaved neurons with two
    /// disjoint fan-in sets must come out clustered set-by-set.
    #[test]
    fn reorder_groups_identical_fanin() {
        let a_set = vec![0u32, 1, 2];
        let b_set = vec![9u32, 10, 11];
        let srcs = vec![
            a_set.clone(),
            b_set.clone(),
            a_set.clone(),
            b_set.clone(),
            a_set,
            b_set,
        ];
        let order = order_by_shared_sources(&srcs);
        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..6).collect::<Vec<_>>(), "must be a bijection");
        // All A-fan-in neurons (even indices) first, then all B ones.
        assert_eq!(order, vec![0, 2, 4, 1, 3, 5], "shared fan-in must cluster");
    }

    /// A trivial two-layer kernel whose cell (1, 1) panics — the PR 4
    /// regression harness for the poisoned-`ctrl` bug: a panicking shard
    /// must become a clean, sticky engine error, never a deadlock or a
    /// panic cascade through a poisoned mutex.
    struct PanickingKernel;

    impl ShardKernel for PanickingKernel {
        type Scratch = ();

        fn n_layers(&self) -> usize {
            2
        }

        fn n_shards(&self) -> usize {
            2
        }

        fn in_len(&self) -> usize {
            4
        }

        fn out_len(&self) -> usize {
            4
        }

        fn buf_len(&self) -> usize {
            4
        }

        fn deps(&self, _l: usize, _s: usize) -> &[(u32, u32)] {
            &[]
        }

        fn reads(&self, _l: usize, _s: usize) -> &[usize] {
            &[]
        }

        fn write_range(&self, _l: usize, s: usize) -> Range<usize> {
            2 * s..2 * (s + 1)
        }

        fn make_scratch(&self) -> Self::Scratch {}

        fn run_cell(
            &self,
            l: usize,
            s: usize,
            _src: &[AtomicU64],
            dst: &[AtomicU64],
            _scratch: &mut Self::Scratch,
        ) {
            if (l, s) == (1, 1) {
                panic!("injected kernel failure");
            }
            for slot in &dst[self.write_range(l, s)] {
                slot.store(7, Ordering::Relaxed);
            }
        }
    }

    #[test]
    fn panicking_kernel_is_clean_sticky_error_not_deadlock() {
        let runner = ShardRunner::new_local(PanickingKernel, DEFAULT_SPIN_US);
        let first = runner.run_epoch(|_| {}, |_| {});
        let msg = first.expect_err("panicked shard must error").0;
        assert!(msg.contains("panicked"), "error names the panic: {msg}");
        assert!(msg.contains("injected kernel failure"), "payload survives: {msg}");
        // Sticky: the engine stays disabled with the same clean error.
        let second = runner.run_epoch(|_| {}, |_| {});
        assert!(second.is_err(), "fault must be sticky");
        // Drop must join the dead worker without hanging or panicking.
        drop(runner);
    }

    /// The same failure surfaced through the public engine API: once the
    /// engines carry a sticky fault, every forward call returns `Err`
    /// promptly (no hang, no panic) and `faulted()` reports it — the
    /// signal `Backend::route` degrades on.
    #[test]
    fn engine_fault_surfaces_as_result() {
        let (net, tables) = grid_net(2, 1);
        let model = ShardedModel::compile(&net, &tables, 2, 1);
        let xs = random_codes(&net, 3, 8);
        // Healthy engine: Ok, not faulted.
        assert!(model.plan.forward_batch(&xs).is_ok());
        assert!(model.forward_batch_f32(&[vec![0.5; 8]]).is_ok());
        assert!(!model.faulted());
        // Faulted engine: sticky Err through every public entry point.
        model.inject_fault("injected test fault");
        assert!(model.faulted());
        let err = model.plan.forward_codes(&xs[0]).expect_err("plan must error");
        assert!(format!("{err:#}").contains("injected test fault"), "{err:#}");
        assert!(model.bits.forward_batch(&xs).is_err(), "bits must error");
        assert!(model.forward_batch_f32(&[vec![0.5; 8]]).is_err(), "f32 must error");
        // Repeated calls keep erroring cleanly (no deadlock on dead state).
        assert!(model.plan.forward_batch(&xs).is_err());
    }

    #[test]
    fn spin_budget_resolution() {
        assert_eq!(resolve_spin_us(Some(7), false), 7, "explicit config wins");
        assert_eq!(resolve_spin_us(Some(7), true), 7, "explicit config wins over remote");
        assert_eq!(
            resolve_spin_us(None, true),
            0,
            "remote placements default to zero spin"
        );
        // Without the env var, the local default applies.
        if std::env::var("POLYLUT_SHARD_SPIN_US").is_err() {
            assert_eq!(resolve_spin_us(None, false), DEFAULT_SPIN_US);
        }
    }

    #[test]
    fn local_model_reports_no_wire_stats() {
        let (net, tables) = grid_net(1, 1);
        let model = ShardedModel::compile(&net, &tables, 2, 1);
        assert!(model.wire_stats().is_none(), "no links on an all-local model");
        assert_eq!(model.spin_us(), resolve_spin_us(None, false));
    }

    /// The fingerprint must be sensitive to weights and shard count but
    /// identical across independent compilations (the wire handshake
    /// depends on it).
    #[test]
    fn shard_fingerprint_is_stable_and_discriminating() {
        let (net, tables) = grid_net(2, 1);
        let (pnet, ptables) = permuted_for_shards(&net, &tables);
        let a = shard_fingerprint(&pnet, &ptables, 2);
        let (pnet2, ptables2) = permuted_for_shards(&net, &tables);
        assert_eq!(a, shard_fingerprint(&pnet2, &ptables2, 2), "deterministic");
        assert_ne!(a, shard_fingerprint(&pnet, &ptables, 3), "shard count matters");
        let cfg = config::uniform("shard-t", &[8, 6, 3], 2, 2, 3, 3, 3, 1, 2, 3);
        let other = Network::random(&cfg, &mut Rng::new(999));
        let otables = compile_network(&other, 1);
        let (po, pot) = permuted_for_shards(&other, &otables);
        assert_ne!(a, shard_fingerprint(&po, &pot, 2), "weights matter");
    }
}
