//! Wire transport for the shard handoff — bit-planes over a socket.
//!
//! `sim::shard` publishes layer boundaries as contiguous `u64` words
//! (bit-planes for the bitslice kernel, code slots for the plan kernel).
//! That boundary format is already wire-friendly: the cut between layers is
//! narrow even when the layers are wide (the PolyLUT/NeuraLUT observation
//! that quantized layer boundaries are cheap interfaces), so one sample's
//! forward pass can span hosts.  This module supplies everything the shard
//! runner needs to cross a TCP link instead of a cache line:
//!
//! - a **length-prefixed frame codec** ([`Frame`], [`read_frame`] /
//!   [`write_frame`]): versioned magic, `(epoch, boundary, shard,
//!   plane-range, generation parity)` header, FNV-1a checksum, raw `u64`
//!   payload words.  Corrupted input of any kind decodes to a clean
//!   [`WireError`], never a panic.
//! - the **coordinator side**: `WireLink` (connect + resume handshake +
//!   windowed framed IO with per-link [`WireStats`]) shared by the shard
//!   runner's sender/receiver thread pair, and [`parse_shard_hosts`] for
//!   the `--shard-hosts` placement map (duplicate addresses rejected at
//!   parse time).
//! - the **worker side**: [`ShardWorkerHost`] (the `polylut shard-worker`
//!   process body) and `RemoteHandoff`, the `sim::shard::Handoff`
//!   implementation that maps the per-cell `(shard, threshold)` dependency
//!   waits onto frame arrival — a producer's level advances exactly when
//!   all of its expected frames for a boundary have been applied to the
//!   worker's private buffers.
//!
//! Since wire handoff v2 the per-link conversation is a **pipelined,
//! windowed stream**, not a lock-step request/response alternation: a
//! per-link *sender* ships the needs flight for boundary l as soon as the
//! hazard schedule allows — up to [`WireConfig::window`] flights ahead of
//! the last applied result — while a *receiver* demultiplexes result
//! frames through a per-`(epoch, boundary, shard)` completion table, so
//! completion no longer assumes TCP delivery order and frames of adjacent
//! epochs may share a flight.  Link failures are no longer sticky: the
//! coordinator keeps a per-epoch replay log, re-handshakes on reconnect
//! (fingerprint + resume-epoch header in the Hello frame) and replays the
//! open epochs from their applied boundaries; only an exhausted retry
//! budget ([`WireConfig::retries`]) faults the engine and lets
//! `Backend::route` degrade to the in-process plan.
//!
//! Wire handoff **v3** (`PLW3`) adds two structural changes on top:
//!
//! - **Per-host link multiplexing**: every `(engine, shard)` pair is a
//!   *session* (u16 id in the frame header) and all sessions to one host
//!   share a single TCP connection owned by a [`HostLink`].  A dedicated
//!   per-host reader thread demultiplexes inbound frames to sessions; a
//!   host dying is **one** recovery ladder (redial, re-Hello every
//!   session, replay each open epoch's unapplied suffix), not E×S
//!   independent ones.  `WireConfig::mux = false` falls back to one
//!   connection per session over the identical code path.
//! - **Epoch pipelining + checkpointed suffix resume**: a session keeps up
//!   to W epochs open at once (the runner's epoch ring, `sim::shard`), and
//!   each open epoch checkpoints the worker's last applied result frame as
//!   its applied-boundary high-water mark.  On reconnect the replay ships
//!   `Start(resume = h)` + that checkpoint + only the needs flights at
//!   level ≥ h — the worker re-runs cells from layer h instead of replaying
//!   the whole epoch (`resume_replayed_frames` / `resume_skipped_frames`
//!   count the split).
//!
//! See `ARCHITECTURE.md` §7 for the frame layout, the window diagram, the
//! session demux and the retry → resume → degrade failure ladder.

use std::collections::{BTreeMap, VecDeque};
use std::io::{Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream, ToSocketAddrs};
use std::ops::Range;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::lut::tables::NetworkTables;
use crate::nn::network::Network;
use crate::sim::shard::{
    bits_kernel_of, permuted_for_shards, plan_kernel_of, run_cells, shard_fingerprint,
    BitsliceKernel, BufSet, Handoff, HandoffError, PlanKernel, ShardKernel,
};

// ---------------------------------------------------------------------------
// FNV-1a (checksums + model fingerprints)
// ---------------------------------------------------------------------------

/// Incremental FNV-1a 64-bit hasher (checksums, model fingerprints).
pub(crate) struct Fnv(u64);

impl Fnv {
    pub(crate) fn new() -> Fnv {
        Fnv(0xcbf2_9ce4_8422_2325)
    }

    pub(crate) fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100_0000_01b3);
        }
    }

    pub(crate) fn write_u64(&mut self, v: u64) {
        self.write(&v.to_le_bytes());
    }

    pub(crate) fn finish(&self) -> u64 {
        self.0
    }
}

// ---------------------------------------------------------------------------
// Frame codec
// ---------------------------------------------------------------------------

/// Versioned frame magic: ASCII `PLW3`.  A major protocol change bumps the
/// trailing digit, so mismatched builds fail the handshake with
/// [`WireError::BadMagic`] instead of misparsing frames.  `PLW1` was the
/// lock-step request/response protocol; `PLW2` the pipelined, windowed
/// stream with the resume handshake (Hello carries a resume-epoch and
/// window header); `PLW3` multiplexes all (engine, shard) sessions to one
/// host over a single connection — the previously-reserved u16 at header
/// bytes 6..8 became the session id.
pub const MAGIC: u32 = u32::from_le_bytes(*b"PLW3");

/// Header bytes after the `u32` length prefix.
const HEADER_LEN: usize = 40;

/// Upper bound on payload words per frame (64 MiB) — a corrupt or hostile
/// length field must not trigger an allocation-sized-by-attacker.
pub const MAX_FRAME_WORDS: usize = 1 << 23;

/// Frame type tag (one byte on the wire).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameKind {
    /// Connection opener (coordinator → worker): payload
    /// `[engine, shards, fingerprint, resume_epoch, window]`, `shard`
    /// field = claimed shard (the last two entries are the v2 resume
    /// handshake).
    Hello,
    /// Handshake accept (worker → coordinator): payload `[fingerprint]`.
    HelloAck,
    /// Epoch begin (coordinator → worker).
    Start,
    /// Boundary words: `start..start+words.len()` of boundary `boundary`,
    /// produced by `shard` (`shard == shards` encodes the coordinator's
    /// input staging).
    Data,
    /// Clean shutdown of the link.
    Bye,
    /// Terminal error; payload carries a UTF-8 message (byte length in
    /// `start`).
    Fault,
}

impl FrameKind {
    fn from_u8(v: u8) -> Option<FrameKind> {
        Some(match v {
            0 => FrameKind::Hello,
            1 => FrameKind::HelloAck,
            2 => FrameKind::Start,
            3 => FrameKind::Data,
            4 => FrameKind::Bye,
            5 => FrameKind::Fault,
            _ => return None,
        })
    }
}

/// One decoded wire frame.  On the wire it is a `u32` length prefix
/// followed by `HEADER_LEN` header bytes and `8·words.len()` payload bytes;
/// see `ARCHITECTURE.md` §7 for the byte-level diagram.
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    /// Frame type.
    pub kind: FrameKind,
    /// Generation parity of the boundary (`boundary % 2`) — redundant with
    /// `boundary`, carried so a receiver can cheaply assert which of the
    /// two parity buffers the payload belongs to.
    pub parity: u8,
    /// Multiplexing session id: which (engine, shard) conversation on the
    /// shared per-host connection this frame belongs to.  `0` is the host
    /// control channel (`Bye(0)` closes the whole connection); sessions
    /// count from 1.  Header bytes 6..8 (reserved-zero in PLW2).
    pub session: u16,
    /// Epoch (sample / word sequence number) the frame belongs to.
    pub epoch: u64,
    /// Boundary index (0 = network input, L = network output).
    pub boundary: u32,
    /// Producing shard (`shards` = coordinator input staging).
    pub shard: u32,
    /// First boundary position (word index) of the payload range.
    pub start: u32,
    /// Payload: raw boundary words (bit-planes / code slots).
    pub words: Vec<u64>,
}

impl Frame {
    /// A `Data` frame for `words` at positions `start..` of `boundary`
    /// (session 0 until stamped by the link that ships it).
    pub fn data(epoch: u64, boundary: u32, shard: u32, start: u32, words: Vec<u64>) -> Frame {
        Frame {
            kind: FrameKind::Data,
            parity: (boundary % 2) as u8,
            session: 0,
            epoch,
            boundary,
            shard,
            start,
            words,
        }
    }

    fn control(kind: FrameKind, epoch: u64) -> Frame {
        Frame {
            kind,
            parity: 0,
            session: 0,
            epoch,
            boundary: 0,
            shard: 0,
            start: 0,
            words: Vec::new(),
        }
    }
}

/// Decode/transport failure of the wire protocol.  Every variant is a clean
/// error — corrupted or truncated input can never panic the process.
#[derive(Debug)]
pub enum WireError {
    /// Socket / stream error.
    Io(std::io::Error),
    /// First header word was not [`MAGIC`] (wrong peer or protocol version).
    BadMagic(u32),
    /// Unknown frame-kind byte.
    BadKind(u8),
    /// Fewer bytes than a header on the wire.
    Truncated {
        /// Bytes required.
        need: usize,
        /// Bytes present.
        got: usize,
    },
    /// Length prefix admits more than [`MAX_FRAME_WORDS`] payload words.
    Oversized {
        /// Declared payload length in words.
        words: usize,
    },
    /// Length prefix disagrees with the header's word count.
    BadLength {
        /// Bytes declared by the prefix.
        declared: usize,
        /// Bytes implied by the header.
        expect: usize,
    },
    /// Checksum mismatch (bit corruption on the path).
    BadChecksum {
        /// Checksum computed over the received bytes.
        got: u64,
        /// Checksum carried in the header.
        want: u64,
    },
    /// Structurally valid frame that violates the protocol state machine
    /// (wrong epoch, unknown producer, out-of-range positions, …).
    Protocol(String),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Io(e) => write!(f, "wire i/o: {e}"),
            WireError::BadMagic(m) => {
                write!(f, "bad frame magic {m:#010x} (want {MAGIC:#010x} = \"PLW3\")")
            }
            WireError::BadKind(k) => write!(f, "unknown frame kind {k}"),
            WireError::Truncated { need, got } => {
                write!(f, "truncated frame: need {need} bytes, got {got}")
            }
            WireError::Oversized { words } => {
                write!(f, "oversized frame: {words} words > max {MAX_FRAME_WORDS}")
            }
            WireError::BadLength { declared, expect } => {
                write!(f, "frame length prefix {declared} != header-implied {expect}")
            }
            WireError::BadChecksum { got, want } => {
                write!(f, "frame checksum {got:#018x} != header {want:#018x}")
            }
            WireError::Protocol(m) => write!(f, "wire protocol: {m}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> WireError {
        WireError::Io(e)
    }
}

impl From<WireError> for HandoffError {
    fn from(e: WireError) -> HandoffError {
        HandoffError(e.to_string())
    }
}

fn frame_checksum(header: &[u8], payload: &[u8]) -> u64 {
    let mut h = Fnv::new();
    h.write(header);
    h.write(payload);
    h.finish()
}

/// Encode a frame to its full wire form (length prefix included).
pub fn encode_frame(f: &Frame) -> Result<Vec<u8>, WireError> {
    if f.words.len() > MAX_FRAME_WORDS {
        return Err(WireError::Oversized { words: f.words.len() });
    }
    let payload_len = 8 * f.words.len();
    let mut out = Vec::with_capacity(4 + HEADER_LEN + payload_len);
    out.extend_from_slice(&((HEADER_LEN + payload_len) as u32).to_le_bytes());
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.push(f.kind as u8);
    out.push(f.parity);
    out.extend_from_slice(&f.session.to_le_bytes());
    out.extend_from_slice(&f.epoch.to_le_bytes());
    out.extend_from_slice(&f.boundary.to_le_bytes());
    out.extend_from_slice(&f.shard.to_le_bytes());
    out.extend_from_slice(&f.start.to_le_bytes());
    out.extend_from_slice(&(f.words.len() as u32).to_le_bytes());
    let mut payload = Vec::with_capacity(payload_len);
    for w in &f.words {
        payload.extend_from_slice(&w.to_le_bytes());
    }
    // Checksum covers the header written so far (sans length prefix) plus
    // the payload; it is appended to complete the header.
    let sum = frame_checksum(&out[4..], &payload);
    out.extend_from_slice(&sum.to_le_bytes());
    out.extend_from_slice(&payload);
    Ok(out)
}

fn le_u16(b: &[u8]) -> u16 {
    u16::from_le_bytes([b[0], b[1]])
}

fn le_u32(b: &[u8]) -> u32 {
    u32::from_le_bytes([b[0], b[1], b[2], b[3]])
}

fn le_u64(b: &[u8]) -> u64 {
    u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]])
}

/// Decode one frame body (the bytes *after* the length prefix).
pub fn decode_frame(body: &[u8]) -> Result<Frame, WireError> {
    if body.len() < HEADER_LEN {
        return Err(WireError::Truncated { need: HEADER_LEN, got: body.len() });
    }
    let magic = le_u32(&body[0..4]);
    if magic != MAGIC {
        return Err(WireError::BadMagic(magic));
    }
    let kind = FrameKind::from_u8(body[4]).ok_or(WireError::BadKind(body[4]))?;
    let parity = body[5];
    let session = le_u16(&body[6..8]);
    let epoch = le_u64(&body[8..16]);
    let boundary = le_u32(&body[16..20]);
    let shard = le_u32(&body[20..24]);
    let start = le_u32(&body[24..28]);
    let count = le_u32(&body[28..32]) as usize;
    if count > MAX_FRAME_WORDS {
        return Err(WireError::Oversized { words: count });
    }
    let want = le_u64(&body[32..40]);
    let expect = HEADER_LEN + 8 * count;
    if body.len() != expect {
        return Err(WireError::BadLength { declared: body.len(), expect });
    }
    let got = frame_checksum(&body[..32], &body[HEADER_LEN..]);
    if got != want {
        return Err(WireError::BadChecksum { got, want });
    }
    let words = body[HEADER_LEN..].chunks_exact(8).map(le_u64).collect();
    Ok(Frame { kind, parity, session, epoch, boundary, shard, start, words })
}

/// Write one frame (length prefix + body).
pub fn write_frame(w: &mut impl Write, f: &Frame) -> Result<(), WireError> {
    let bytes = encode_frame(f)?;
    w.write_all(&bytes)?;
    w.flush()?;
    Ok(())
}

/// Read one length-prefixed frame.  The length prefix is validated against
/// [`MAX_FRAME_WORDS`] *before* any allocation.
pub fn read_frame(r: &mut impl Read) -> Result<Frame, WireError> {
    let mut len4 = [0u8; 4];
    r.read_exact(&mut len4)?;
    let len = u32::from_le_bytes(len4) as usize;
    if len < HEADER_LEN {
        return Err(WireError::Truncated { need: HEADER_LEN, got: len });
    }
    if len > HEADER_LEN + 8 * MAX_FRAME_WORDS {
        return Err(WireError::Oversized { words: (len - HEADER_LEN) / 8 });
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    decode_frame(&body)
}

/// On-wire size in bytes of a frame with `words` payload words.
fn frame_bytes(words: usize) -> u64 {
    (4 + HEADER_LEN + 8 * words) as u64
}

// ---------------------------------------------------------------------------
// Patient (progress-aware) frame reads
// ---------------------------------------------------------------------------

/// Consecutive zero-progress read-timeout windows (each [`RECV_TIMEOUT`]
/// long) before a mid-epoch peer is declared dead.  The liveness bound is
/// **progress-aware**: any byte arriving resets the count, so a slow wide
/// frame trickling in under the windowed stream can take arbitrarily long
/// without being misclassified as a half-open peer — only a peer that goes
/// completely silent for `LIVENESS_STRIKES × RECV_TIMEOUT` mid-epoch is
/// dropped.
const LIVENESS_STRIKES: u32 = 2;

/// Read exactly `buf.len()` bytes, tolerating read-timeout wakeups as long
/// as bytes keep arriving (see [`LIVENESS_STRIKES`]).  With `idle_ok`,
/// a timeout *before the first byte* returns `Ok(false)` instead of
/// striking — the between-epochs idle classification, where a silent peer
/// is an idle coordinator, not a dead one.  Returns `Ok(true)` when the
/// buffer is filled.
fn read_full_patient(
    stream: &mut impl Read,
    buf: &mut [u8],
    idle_ok: bool,
) -> Result<bool, WireError> {
    let mut filled = 0usize;
    let mut zero_windows = 0u32;
    while filled < buf.len() {
        match stream.read(&mut buf[filled..]) {
            Ok(0) => {
                return Err(WireError::Io(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "link closed",
                )))
            }
            Ok(n) => {
                filled += n;
                zero_windows = 0;
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if filled == 0 && idle_ok {
                    return Ok(false);
                }
                zero_windows += 1;
                if zero_windows >= LIVENESS_STRIKES {
                    return Err(WireError::Io(e));
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(WireError::Io(e)),
        }
    }
    Ok(true)
}

/// Read one length-prefixed frame with the progress-aware liveness bound.
/// `Ok(None)` = idle timeout before any byte (only when `idle_ok`); once a
/// frame has started, only sustained zero-progress fails the read, so the
/// length prefix and body are never desynchronized by a timeout landing
/// mid-frame.
fn read_frame_patient(
    stream: &mut impl Read,
    idle_ok: bool,
) -> Result<Option<Frame>, WireError> {
    let mut len4 = [0u8; 4];
    if !read_full_patient(stream, &mut len4, idle_ok)? {
        return Ok(None);
    }
    let len = u32::from_le_bytes(len4) as usize;
    if len < HEADER_LEN {
        return Err(WireError::Truncated { need: HEADER_LEN, got: len });
    }
    if len > HEADER_LEN + 8 * MAX_FRAME_WORDS {
        return Err(WireError::Oversized { words: (len - HEADER_LEN) / 8 });
    }
    let mut body = vec![0u8; len];
    read_full_patient(stream, &mut body, false)?;
    decode_frame(&body).map(Some)
}

fn fault_frame(msg: &str) -> Frame {
    let bytes = msg.as_bytes();
    let mut words = Vec::with_capacity(bytes.len().div_ceil(8));
    for chunk in bytes.chunks(8) {
        let mut w = [0u8; 8];
        w[..chunk.len()].copy_from_slice(chunk);
        words.push(u64::from_le_bytes(w));
    }
    Frame {
        kind: FrameKind::Fault,
        parity: 0,
        session: 0,
        epoch: 0,
        boundary: 0,
        shard: 0,
        start: bytes.len() as u32,
        words,
    }
}

fn fault_message(f: &Frame) -> String {
    let mut bytes = Vec::with_capacity(8 * f.words.len());
    for w in &f.words {
        bytes.extend_from_slice(&w.to_le_bytes());
    }
    bytes.truncate((f.start as usize).min(bytes.len()));
    String::from_utf8_lossy(&bytes).into_owned()
}

// ---------------------------------------------------------------------------
// Wire configuration (window + retry knobs)
// ---------------------------------------------------------------------------

/// Default in-flight window: needs flights (one per layer boundary) a link's
/// sender may run ahead of the last applied result.  Four flights hide the
/// round-trip on every geometry the benches track; `1` reproduces the v1
/// lock-step pacing exactly.
pub const DEFAULT_WIRE_WINDOW: usize = 4;

/// Default reconnect budget: dial attempts per link incident (exponential
/// backoff between attempts) before the engine faults and `Backend::route`
/// degrades to the in-process plan.
pub const DEFAULT_WIRE_RETRIES: u32 = 6;

/// Tuning knobs of the wire protocol, plumbed from `ServerConfig` /
/// `polylut serve --wire-window / --wire-retries / --wire-mux` down to
/// every link.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WireConfig {
    /// Maximum needs flights (one per layer boundary) in flight per
    /// session ahead of the last applied result, **and** the depth of the
    /// coordinator's epoch ring (how many epochs may be in flight at
    /// once).  `1` = lock-step parity with the v1 protocol; values ≥ the
    /// layer count stream a whole epoch without ever blocking on a
    /// result.
    pub window: usize,
    /// Reconnect attempts per host-link incident before the sticky engine
    /// fault.  The *initial* connect at compile time keeps a short fixed
    /// budget (a dead address is a config error, not an outage).
    pub retries: u32,
    /// v3 per-host link multiplexing: all (engine, shard) sessions to one
    /// `host:port` share a single TCP connection (and one recovery
    /// ladder).  `false` restores the v2 topology — one connection per
    /// session — over the identical code path.
    pub mux: bool,
}

impl Default for WireConfig {
    fn default() -> WireConfig {
        WireConfig {
            window: DEFAULT_WIRE_WINDOW,
            retries: DEFAULT_WIRE_RETRIES,
            mux: true,
        }
    }
}

impl WireConfig {
    /// The v1 pacing: one flight in flight, ship needs(l) only after the
    /// result of boundary l has been applied.
    pub fn lock_step() -> WireConfig {
        WireConfig { window: 1, ..WireConfig::default() }
    }
}

// ---------------------------------------------------------------------------
// Placement + stats
// ---------------------------------------------------------------------------

/// Shard placement map: `placement[s]` is `Some("host:port")` for a shard
/// hosted by a remote `polylut shard-worker`, `None` for a local worker
/// thread.
pub type ShardPlacement = Vec<Option<String>>;

/// Parse a `--shard-hosts` spec (`addr,addr,…`; `local`, `-` or an empty
/// entry keep that shard on a local thread; unlisted trailing shards are
/// local) into a placement map of length `shards`.
///
/// Duplicate `host:port` entries — two distinct shards pointed at the
/// same worker address — are rejected here, at parse time, with a message
/// naming both shard indices.  A duplicated entry in a hand-written spec
/// is almost always a copy-paste typo that silently halves the fleet (two
/// shards quietly share one host's cores and links), so the CLI refuses
/// it up front.  Hosting several shards from one worker process remains
/// fully supported for *programmatic* placements (the loopback tests and
/// benches do exactly that); operators who genuinely want it can run one
/// worker process per listed port on the same host.
pub fn parse_shard_hosts(spec: &str, shards: usize) -> Result<ShardPlacement> {
    let mut placement: ShardPlacement = Vec::with_capacity(shards);
    if !spec.trim().is_empty() {
        for (i, raw) in spec.split(',').enumerate() {
            let e = raw.trim();
            let entry = if e.is_empty() || e == "local" || e == "-" {
                None
            } else if e.contains(':') {
                if let Some(prev) =
                    placement.iter().position(|p| p.as_deref() == Some(e))
                {
                    anyhow::bail!(
                        "--shard-hosts entry {i} duplicates {e:?} (already used for \
                         shard {prev}): each shard needs its own worker address — \
                         run one `polylut shard-worker` per listed shard, or mark \
                         extra shards `local`"
                    );
                }
                Some(e.to_string())
            } else {
                anyhow::bail!("--shard-hosts entry {e:?} is not host:port / local / -");
            };
            if i >= shards {
                // Trailing local/empty entries (e.g. a trailing comma) are
                // the documented no-op; only a real host past the shard
                // count is an error.
                if entry.is_some() {
                    anyhow::bail!("--shard-hosts lists more than {shards} shards");
                }
                continue;
            }
            placement.push(entry);
        }
    }
    placement.resize(shards, None);
    Ok(placement)
}

/// Cumulative per-link (or summed-over-links) wire counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WireStats {
    /// Frames sent + received.
    pub frames: u64,
    /// Bytes sent + received (frame-level accounting, incl. headers).
    pub bytes: u64,
    /// Nanoseconds spent blocked waiting for a frame to arrive.
    pub wait_ns: u64,
    /// Connection attempts beyond each link's first (retries at connect and
    /// every reconnect-and-resume dial).
    pub reconnects: u64,
    /// Successful reconnect-and-resume handshakes (the open epoch was
    /// replayed from its boundary, or an idle link was re-established).
    pub resumes: u64,
    /// Link incidents whose reconnect budget ([`WireConfig::retries`]) was
    /// exhausted — each one faulted its engine and degraded routing.
    pub retry_exhausted: u64,
    /// High-water mark of in-flight needs flights (the `--wire-window`
    /// unit: one flight per layer boundary) observed on any session.
    pub inflight_hwm: u64,
    /// Cached socket handles installed — exactly one per host-link
    /// generation (initial connect and each successful reconnect).  Every
    /// session's sender and receiver share this per-generation handle; a
    /// regression back to per-flight/per-frame `try_clone` dup syscalls
    /// would show up here as this counter scaling with `frames`.
    pub handle_clones: u64,
    /// High-water mark of concurrently in-flight **epochs** on the runner's
    /// epoch ring (admitted but not yet collected; bounded by
    /// [`WireConfig::window`]; 1 under lock-step pacing).
    pub inflight_epochs: u64,
    /// Frames re-sent by reconnect-and-resume replays — with v3
    /// checkpointed resume, only the unapplied suffix of each open epoch.
    pub resume_replayed_frames: u64,
    /// Frames a full-epoch (v2-style) replay would have re-sent but the
    /// checkpointed resume skipped (trimmed below the applied-boundary
    /// high-water mark of their epoch).
    pub resume_skipped_frames: u64,
}

impl WireStats {
    /// Merge two counter sets: element-wise sums, except the in-flight
    /// high-water marks, which take the max.
    pub fn merged(self, o: WireStats) -> WireStats {
        WireStats {
            frames: self.frames + o.frames,
            bytes: self.bytes + o.bytes,
            wait_ns: self.wait_ns + o.wait_ns,
            reconnects: self.reconnects + o.reconnects,
            resumes: self.resumes + o.resumes,
            retry_exhausted: self.retry_exhausted + o.retry_exhausted,
            inflight_hwm: self.inflight_hwm.max(o.inflight_hwm),
            handle_clones: self.handle_clones + o.handle_clones,
            inflight_epochs: self.inflight_epochs.max(o.inflight_epochs),
            resume_replayed_frames: self.resume_replayed_frames
                + o.resume_replayed_frames,
            resume_skipped_frames: self.resume_skipped_frames
                + o.resume_skipped_frames,
        }
    }
}

/// Per-host rollup of one multiplexed link (rendered by
/// `coordinator::metrics` as the `wire_hosts=[…]` snapshot group), so a
/// saturated or flapping host is visible without log diving.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireHostStats {
    /// Worker address the link dials.
    pub addr: String,
    /// Sessions multiplexed over the link: plan + bitslice engines × their
    /// remote shards on this host (1 with [`WireConfig::mux`] off).
    pub sessions: u64,
    /// Frames sent + received over the host connection, all sessions plus
    /// handshakes.
    pub frames: u64,
    /// Bytes sent + received over the host connection.
    pub bytes: u64,
    /// Connection attempts beyond the link's first.
    pub reconnects: u64,
    /// Successful reconnect-and-resume ladders — one per host incident,
    /// however many sessions the link carries.
    pub resumes: u64,
}

/// Shared atomic wire counters of one live session (or, for the
/// recovery-class counters, of one host link).
#[derive(Default)]
pub(crate) struct LinkStats {
    frames: AtomicU64,
    bytes: AtomicU64,
    wait_ns: AtomicU64,
    reconnects: AtomicU64,
    resumes: AtomicU64,
    retry_exhausted: AtomicU64,
    inflight_hwm: AtomicU64,
    handle_clones: AtomicU64,
    inflight_epochs: AtomicU64,
    resume_replayed_frames: AtomicU64,
    resume_skipped_frames: AtomicU64,
}

impl LinkStats {
    fn count_frame(&self, words: usize) {
        self.frames.fetch_add(1, Ordering::Relaxed);
        self.bytes.fetch_add(frame_bytes(words), Ordering::Relaxed);
    }

    pub(crate) fn snapshot(&self) -> WireStats {
        WireStats {
            frames: self.frames.load(Ordering::Relaxed),
            bytes: self.bytes.load(Ordering::Relaxed),
            wait_ns: self.wait_ns.load(Ordering::Relaxed),
            reconnects: self.reconnects.load(Ordering::Relaxed),
            resumes: self.resumes.load(Ordering::Relaxed),
            retry_exhausted: self.retry_exhausted.load(Ordering::Relaxed),
            inflight_hwm: self.inflight_hwm.load(Ordering::Relaxed),
            handle_clones: self.handle_clones.load(Ordering::Relaxed),
            inflight_epochs: self.inflight_epochs.load(Ordering::Relaxed),
            resume_replayed_frames: self
                .resume_replayed_frames
                .load(Ordering::Relaxed),
            resume_skipped_frames: self.resume_skipped_frames.load(Ordering::Relaxed),
        }
    }
}

// ---------------------------------------------------------------------------
// Wire plan: what crosses the link for one (engine, shard)
// ---------------------------------------------------------------------------

/// Which LUT engine a link serves (one byte in the Hello frame).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum EngineKind {
    Plan = 0,
    Bitslice = 1,
}

impl EngineKind {
    fn from_u64(v: u64) -> Option<EngineKind> {
        match v {
            0 => Some(EngineKind::Plan),
            1 => Some(EngineKind::Bitslice),
            _ => None,
        }
    }
}

/// The per-layer wire schedule of one remote shard, derived identically on
/// both ends from the deterministic kernel compilation:
///
/// - `needs[l]` — `(producer, position range)` runs of boundary l that the
///   coordinator must ship before cell (l, s) can run remotely: the cell's
///   read positions minus the shard's own boundary-l slice, grouped by the
///   producing shard and compressed to maximal contiguous runs (producer
///   `shards` = the coordinator's input staging, boundary 0).
/// - `result[l]` — the boundary l+1 positions the worker ships back.
/// - `deps[l]` — the worker-side `(shard, threshold)` waits; satisfied by
///   frame arrival (see `RemoteHandoff`).  Only *producer*-class waits
///   appear: the worker's buffers are private **and per-boundary**, so
///   frame application in any arrival order aliases nothing and the
///   reader-blocker / writer-ordering hazards of the shared parity
///   buffers cannot arise.
/// - `counts[l]` — `(producer, frames)` expected per boundary, used to
///   advance a producer's level once its last frame lands.
pub(crate) struct WirePlan {
    pub(crate) needs: Vec<Vec<(u32, Range<usize>)>>,
    pub(crate) result: Vec<Range<usize>>,
    pub(crate) deps: Vec<Vec<(u32, u32)>>,
    pub(crate) counts: Vec<Vec<(u32, u32)>>,
}

/// Build the wire schedule of shard `s` from a compiled kernel.
pub(crate) fn wire_plan<K: ShardKernel>(k: &K, s: usize) -> WirePlan {
    let l_count = k.n_layers();
    let coord = k.n_shards() as u32;
    let owner = |l: usize, x: usize| -> u32 {
        for q in 0..k.n_shards() {
            if k.write_range(l - 1, q).contains(&x) {
                return q as u32;
            }
        }
        unreachable!("boundary {l} position {x} has no producing shard")
    };
    let mut needs = Vec::with_capacity(l_count);
    let mut result = Vec::with_capacity(l_count);
    let mut deps = Vec::with_capacity(l_count);
    let mut counts = Vec::with_capacity(l_count);
    for l in 0..l_count {
        let own: Range<usize> = if l >= 1 { k.write_range(l - 1, s) } else { 0..0 };
        let mut runs: Vec<(u32, Range<usize>)> = Vec::new();
        for &x in k.reads(l, s) {
            if l >= 1 && own.contains(&x) {
                continue;
            }
            let q = if l == 0 { coord } else { owner(l, x) };
            match runs.last_mut() {
                Some((lq, r)) if *lq == q && r.end == x => r.end = x + 1,
                _ => runs.push((q, x..x + 1)),
            }
        }
        let mut layer_deps: Vec<(u32, u32)> = Vec::new();
        let mut layer_counts: Vec<(u32, u32)> = Vec::new();
        for (q, _) in &runs {
            let thr = if *q == coord { 1 } else { l as u32 };
            if !layer_deps.iter().any(|&(d, _)| d == *q) {
                layer_deps.push((*q, thr));
            }
            match layer_counts.iter_mut().find(|(d, _)| d == q) {
                Some((_, n)) => *n += 1,
                None => layer_counts.push((*q, 1)),
            }
        }
        needs.push(runs);
        result.push(k.write_range(l, s));
        deps.push(layer_deps);
        counts.push(layer_counts);
    }
    WirePlan { needs, result, deps, counts }
}

/// Frames the coordinator ships per epoch for this plan (needs runs + the
/// Start frame) — sizes the worker's bounded pending buffer under the
/// windowed stream.
fn frames_per_epoch(plan: &WirePlan) -> usize {
    plan.needs.iter().map(|runs| runs.len()).sum::<usize>() + 1
}

// ---------------------------------------------------------------------------
// Coordinator side: HostLink (per-host mux + recovery) + WireLink (session handle)
// ---------------------------------------------------------------------------

/// How long one blocking read waits before waking to re-check liveness (a
/// hung worker must become a clean engine error, not a hung server; see
/// [`LIVENESS_STRIKES`] for the mid-epoch bound).
const RECV_TIMEOUT: Duration = Duration::from_secs(30);
/// Connection attempts for the *initial* compile-time connect (a dead
/// address at compile time is a config error — fail fast; reconnects after
/// an outage use [`WireConfig::retries`]).
const CONNECT_ATTEMPTS: u32 = 3;
/// Per-attempt dial bound: a black-holing host must cost one dial attempt
/// seconds, not the kernel's multi-minute SYN timeout — shutdown (and the
/// retry budget) stays responsive during an outage.
const CONNECT_TIMEOUT: Duration = Duration::from_secs(5);

/// The error every blocked link call returns once the runner shuts down.
fn shutdown_error() -> WireError {
    WireError::Io(std::io::Error::new(std::io::ErrorKind::Interrupted, "link shut down"))
}

/// Per-epoch bookkeeping of one session (coordinator side).  One exists
/// per epoch the session has opened but not yet fully applied — the shard
/// runner's epoch ring admits up to [`WireConfig::window`] of them.
struct EpochState {
    /// The session-stamped `Start` frame.  A resume re-ships it with
    /// `boundary` set to the checkpoint high-water mark, telling the
    /// worker to restart this epoch's cells at that layer instead of
    /// layer 0.
    start: Frame,
    /// Boundaries of the shipped flights, in ship order, not yet acked.
    flight_bounds: VecDeque<u32>,
    /// Result boundaries applied this epoch (contiguous prefix) — the
    /// checkpoint high-water mark a resume replays from.
    applied: u32,
    /// The last applied result frame: re-shipped on resume as the
    /// boundary-`applied` restore, so the worker has its own slice of
    /// that boundary without recomputing layers below it.
    checkpoint: Option<Frame>,
    /// Needs-frame replay ledger as `(boundary, frame)`.  `mark_applied`
    /// trims entries below the checkpoint, so a reconnect replays only
    /// the unapplied suffix of the epoch.
    replay: Vec<(u32, Frame)>,
    /// Frames trimmed off `replay` by checkpoint advancement — what a
    /// full-epoch (v2-style) replay would have re-sent
    /// ([`WireStats::resume_skipped_frames`]).
    trimmed: u64,
    /// Completion table for result frames that arrived ahead of the next
    /// contiguous boundary (keyed by boundary).
    pending: BTreeMap<u32, Frame>,
}

impl EpochState {
    fn new(start: Frame) -> EpochState {
        EpochState {
            start,
            flight_bounds: VecDeque::new(),
            applied: 0,
            checkpoint: None,
            replay: Vec::new(),
            trimmed: 0,
            pending: BTreeMap::new(),
        }
    }
}

/// One (engine, shard) conversation multiplexed over a host link.
struct SessionCore {
    engine: EngineKind,
    shard: usize,
    n_layers: usize,
    /// HelloAck received on the current connection generation.
    open_acked: bool,
    /// Closed by its [`WireLink`] (Bye sent); skipped by re-handshakes.
    closed: bool,
    /// Sticky session death (worker fault / protocol violation).
    dead: Option<String>,
    /// Highest epoch ever opened — epoch ids must ascend per session.
    last_epoch: u64,
    /// Open epochs, ascending (the lowest is the resume epoch).
    epochs: BTreeMap<u64, EpochState>,
    /// Needs flights shipped minus acked, counted across all open epochs —
    /// the per-session window credit, in *flight* units (boundaries
    /// without a flight neither consume nor grant window room, or
    /// `--wire-window` would not bind).
    shipped: u32,
    acked: u32,
    /// Per-session transport counters, shared with the owning
    /// [`WireLink`].
    stats: Arc<LinkStats>,
}

impl SessionCore {
    /// Lowest open epoch — where a resume handshake restarts the stream.
    fn resume_epoch(&self) -> u64 {
        self.epochs.keys().next().copied().unwrap_or(0)
    }
}

/// Everything a (re)connect dial needs to greet one session, snapshotted
/// under the host lock before the lock-free dial + replay.
struct ResumeSpec {
    session: u16,
    engine: EngineKind,
    shard: usize,
    resume_epoch: u64,
    /// Encoded replay suffix: per open epoch ascending, the `Start` (with
    /// `boundary` = checkpoint), the checkpoint restore frame when one
    /// exists, then the needs frames at or above the checkpoint.
    replay: Vec<u8>,
    /// Frames in `replay` (counted into `resume_replayed_frames`).
    replayed: u64,
    /// Frames a full-epoch replay would have added but checkpoints
    /// trimmed (counted into `resume_skipped_frames`).
    skipped: u64,
    stats: Arc<LinkStats>,
}

/// Snapshot one session's resume handshake + checkpointed replay suffix.
fn resume_spec(session: u16, sc: &SessionCore) -> ResumeSpec {
    let mut replay = Vec::new();
    let mut replayed = 0u64;
    let mut skipped = 0u64;
    for es in sc.epochs.values() {
        let mut start = es.start.clone();
        // Re-ship the Start with the checkpoint boundary: the worker
        // restarts this epoch's cells at that layer, not layer 0.
        start.boundary = es.applied;
        let enc = encode_frame(&start)
            .expect("replayed frame was encodable when first shipped");
        replay.extend_from_slice(&enc);
        replayed += 1;
        if let Some(cp) = &es.checkpoint {
            let enc = encode_frame(cp)
                .expect("replayed frame was encodable when first shipped");
            replay.extend_from_slice(&enc);
            replayed += 1;
        }
        for (_, f) in &es.replay {
            let enc = encode_frame(f)
                .expect("replayed frame was encodable when first shipped");
            replay.extend_from_slice(&enc);
            replayed += 1;
        }
        skipped += es.trimmed;
    }
    ResumeSpec {
        session,
        engine: sc.engine,
        shard: sc.shard,
        resume_epoch: sc.resume_epoch(),
        replay,
        replayed,
        skipped,
        stats: sc.stats.clone(),
    }
}

/// Mutable host-link state, guarded by [`HostLink::core`].
struct HostCore {
    /// Live stream (`None` after an idle drop, until a ship requests a
    /// redial).  Shared per-generation handle: the reader thread and
    /// every session's sender take Arc bumps, not `try_clone` dup
    /// syscalls (counted in [`WireStats::handle_clones`]).
    stream: Option<Arc<TcpStream>>,
    /// Bumped on every install *and* teardown; a failed IO call whose
    /// observed generation is stale was already handled.
    generation: u64,
    /// The reader thread is mid-recovery (dial + re-handshake + replay).
    recovering: bool,
    /// A teardown (or an idle ship) wants the reader to redial; carries
    /// the original failure for the resume log and the death message.
    need_reconnect: Option<String>,
    /// The reader thread has been spawned.
    reader: bool,
    /// An initial (inline) connect is in progress.
    connecting: bool,
    /// Sticky host death (retry budget exhausted) — fanned out to every
    /// session.
    dead: Option<String>,
    /// Next session id to hand out (0 is the host control channel).
    next_session: u16,
    sessions: BTreeMap<u16, SessionCore>,
}

/// Coordinator end of one **host link**: a single TCP connection carrying
/// every (engine, shard) session to one `host:port` worker.  A dedicated
/// per-host reader thread owns all socket reads, demultiplexes inbound
/// frames by session id, and runs the one reconnect/resume ladder for
/// the whole host — a host dying is one recovery, not engines × shards
/// independent ones.  Senders (each session's runner thread) serialize
/// whole-frame writes on [`HostLink::wlock`]; bookkeeping stays on
/// [`HostLink::core`] so a wide flight's bytes never block the window
/// credit that unblocks pipelining.
///
/// Lock order: `core` may be held while acquiring `wlock`; never the
/// reverse.
pub(crate) struct HostLink {
    addr: String,
    shards: usize,
    fingerprint: u64,
    cfg: WireConfig,
    core: Mutex<HostCore>,
    cv: Condvar,
    /// Serializes writes to the shared connection (frame granularity).
    wlock: Mutex<()>,
    shutdown: AtomicBool,
    /// Host-level recovery counters (`reconnects` / `resumes` /
    /// `retry_exhausted` / `handle_clones`); transport counters live in
    /// each session's [`LinkStats`].
    stats: Arc<LinkStats>,
    /// Host-rollup transport counters (all sessions + handshakes), for
    /// [`WireHostStats`].
    frames: AtomicU64,
    bytes: AtomicU64,
    /// Deterministic backoff-jitter seed (FNV of the address): links to
    /// different hosts spread over the backoff interval instead of
    /// sharing one synchronized schedule, reproducibly.
    seed: u64,
}

impl HostLink {
    fn new(addr: &str, shards: usize, fingerprint: u64, cfg: WireConfig) -> Arc<HostLink> {
        let mut h = Fnv::new();
        h.write(addr.as_bytes());
        Arc::new(HostLink {
            addr: addr.to_string(),
            shards,
            fingerprint,
            cfg,
            core: Mutex::new(HostCore {
                stream: None,
                generation: 0,
                recovering: false,
                need_reconnect: None,
                reader: false,
                connecting: false,
                dead: None,
                next_session: 1,
                sessions: BTreeMap::new(),
            }),
            cv: Condvar::new(),
            wlock: Mutex::new(()),
            shutdown: AtomicBool::new(false),
            stats: Arc::new(LinkStats::default()),
            frames: AtomicU64::new(0),
            bytes: AtomicU64::new(0),
            seed: h.finish(),
        })
    }

    fn lock(&self) -> MutexGuard<'_, HostCore> {
        self.core.lock().unwrap_or_else(|p| p.into_inner())
    }

    pub(crate) fn addr(&self) -> &str {
        &self.addr
    }

    fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::Relaxed)
    }

    /// Host-level recovery counters (summed into the model's
    /// [`WireStats`] exactly once per host, however many sessions ride
    /// the link).
    pub(crate) fn recovery_stats(&self) -> WireStats {
        self.stats.snapshot()
    }

    /// Per-host rollup for the metrics snapshot.
    pub(crate) fn host_stats(&self) -> WireHostStats {
        let core = self.lock();
        let s = self.stats.snapshot();
        WireHostStats {
            addr: self.addr.clone(),
            sessions: core.sessions.len() as u64,
            frames: self.frames.load(Ordering::Relaxed),
            bytes: self.bytes.load(Ordering::Relaxed),
            reconnects: s.reconnects,
            resumes: s.resumes,
        }
    }

    fn count_host_frame(&self, words: usize) {
        self.frames.fetch_add(1, Ordering::Relaxed);
        self.bytes.fetch_add(frame_bytes(words), Ordering::Relaxed);
    }

    fn hello_frame(
        &self,
        session: u16,
        engine: EngineKind,
        shard: usize,
        resume_epoch: u64,
    ) -> Frame {
        Frame {
            kind: FrameKind::Hello,
            parity: 0,
            session,
            epoch: resume_epoch,
            boundary: 0,
            shard: shard as u32,
            start: 0,
            words: vec![
                engine as u64,
                self.shards as u64,
                self.fingerprint,
                resume_epoch,
                self.cfg.window.max(1) as u64,
            ],
        }
    }

    /// One dial + per-session handshake attempt (bounded by
    /// [`CONNECT_TIMEOUT`]): connect, then greet every session in `specs`
    /// in order — Hello with its resume epoch, HelloAck validated —
    /// before any replay traffic.
    fn try_dial_sessions(&self, specs: &[ResumeSpec]) -> Result<TcpStream, WireError> {
        let sockaddr = self.addr.to_socket_addrs()?.next().ok_or_else(|| {
            WireError::Io(std::io::Error::new(
                std::io::ErrorKind::AddrNotAvailable,
                format!("{} resolves to no address", self.addr),
            ))
        })?;
        let mut stream = TcpStream::connect_timeout(&sockaddr, CONNECT_TIMEOUT)?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(RECV_TIMEOUT))?;
        for spec in specs {
            let hello =
                self.hello_frame(spec.session, spec.engine, spec.shard, spec.resume_epoch);
            write_frame(&mut stream, &hello)?;
            spec.stats.count_frame(hello.words.len());
            self.count_host_frame(hello.words.len());
            let ack = read_frame(&mut stream)?;
            spec.stats.count_frame(ack.words.len());
            self.count_host_frame(ack.words.len());
            match ack.kind {
                FrameKind::HelloAck => {
                    if ack.session != spec.session {
                        return Err(WireError::Protocol(format!(
                            "{}: handshake ack for session {} while greeting \
                             session {}",
                            self.addr, ack.session, spec.session
                        )));
                    }
                    if ack.words.first().copied() != Some(self.fingerprint) {
                        return Err(WireError::Protocol(format!(
                            "{}: model fingerprint mismatch (worker {:#018x}, \
                             coordinator {:#018x}) — same weights, shard count and \
                             build required",
                            self.addr,
                            ack.words.first().copied().unwrap_or(0),
                            self.fingerprint,
                        )));
                    }
                }
                FrameKind::Fault => {
                    return Err(WireError::Protocol(format!(
                        "{} rejected handshake: {}",
                        self.addr,
                        fault_message(&ack)
                    )))
                }
                k => {
                    return Err(WireError::Protocol(format!(
                        "{}: expected HelloAck, got {k:?}",
                        self.addr
                    )))
                }
            }
        }
        Ok(stream)
    }

    /// Dial with a bounded retry budget and jittered exponential backoff.
    /// Handshake rejections (fingerprint / shard count / session demux)
    /// are permanent and end the loop immediately; only transport errors
    /// are retried.  `count_all` counts every attempt into `reconnects`
    /// (resume dials); otherwise only attempts beyond the host's first.
    fn dial_sessions(
        &self,
        specs: &[ResumeSpec],
        attempts: u32,
        count_all: bool,
    ) -> Result<TcpStream, WireError> {
        let mut last: Option<WireError> = None;
        for attempt in 0..attempts.max(1) {
            if self.is_shutdown() {
                return Err(shutdown_error());
            }
            if attempt > 0 {
                self.backoff(attempt);
                if self.is_shutdown() {
                    return Err(shutdown_error());
                }
            }
            if attempt > 0 || count_all {
                self.stats.reconnects.fetch_add(1, Ordering::Relaxed);
            }
            match self.try_dial_sessions(specs) {
                Ok(s) => return Ok(s),
                Err(e @ WireError::Protocol(_)) => return Err(e),
                Err(e) => last = Some(e),
            }
        }
        Err(last.unwrap_or_else(|| WireError::Protocol("no connect attempts".into())))
    }

    /// Shutdown-aware exponential backoff with deterministic
    /// **decorrelation jitter**: attempt `a` sleeps somewhere in
    /// `[base/2, base)` for `base = 50ms << min(a, 5)`, the point drawn
    /// from an FNV hash of `(address, attempt)`.  Links to different
    /// hosts therefore never share a synchronized reconnect schedule (no
    /// thundering-herd redials against a recovering worker), while any
    /// one link's schedule stays fully reproducible for tests.
    fn backoff(&self, attempt: u32) {
        let base = 50u64 << attempt.min(5);
        let mut h = Fnv::new();
        h.write_u64(self.seed);
        h.write_u64(attempt as u64);
        let jitter = h.finish() % (base / 2).max(1);
        // Sleep in short slices so a runner being dropped mid-outage
        // never waits out the whole exponential schedule.
        let mut left = base / 2 + jitter;
        while left > 0 && !self.is_shutdown() {
            let step = left.min(50);
            std::thread::sleep(Duration::from_millis(step));
            left -= step;
        }
    }

    /// Register a new (engine, shard) session and bring it up.  The first
    /// session on a host dials inline with the fail-fast
    /// [`CONNECT_ATTEMPTS`] budget (a dead address at compile time is a
    /// config error, not an outage) and spawns the reader thread; later
    /// sessions piggyback a Hello on the live connection and wait for the
    /// reader to route the HelloAck.
    fn open_session(
        self: &Arc<HostLink>,
        engine: EngineKind,
        shard: usize,
        n_layers: usize,
        stats: Arc<LinkStats>,
    ) -> Result<u16, WireError> {
        let mut core = self.lock();
        if self.is_shutdown() {
            return Err(shutdown_error());
        }
        if let Some(m) = &core.dead {
            return Err(WireError::Protocol(m.clone()));
        }
        let sid = core.next_session;
        core.next_session = core.next_session.checked_add(1).ok_or_else(|| {
            WireError::Protocol(format!("{}: session ids exhausted", self.addr))
        })?;
        core.sessions.insert(
            sid,
            SessionCore {
                engine,
                shard,
                n_layers,
                open_acked: false,
                closed: false,
                dead: None,
                last_epoch: 0,
                epochs: BTreeMap::new(),
                shipped: 0,
                acked: 0,
                stats,
            },
        );
        // Track the generation we last wrote a Hello on, so exactly one
        // Hello per session reaches any one connection (the recovery
        // ladder re-greets every registered session itself on the
        // generations it creates, and flags them acked before waking us).
        let mut hello_gen: Option<u64> = None;
        loop {
            if self.is_shutdown() {
                core.sessions.remove(&sid);
                return Err(shutdown_error());
            }
            if let Some(m) = core.sessions.get(&sid).and_then(|sc| sc.dead.clone()) {
                core.sessions.remove(&sid);
                self.cv.notify_all();
                return Err(WireError::Protocol(m));
            }
            let host_dead = core.dead.clone();
            if let Some(m) = host_dead {
                core.sessions.remove(&sid);
                self.cv.notify_all();
                return Err(WireError::Protocol(m));
            }
            if core.sessions.get(&sid).is_some_and(|sc| sc.open_acked) {
                return Ok(sid);
            }
            if !core.reader && !core.connecting {
                // First connection on this host: dial inline, greeting
                // every registered-but-unacked session in one handshake.
                core.connecting = true;
                let specs: Vec<ResumeSpec> = core
                    .sessions
                    .iter()
                    .filter(|(_, sc)| !sc.closed && sc.dead.is_none() && !sc.open_acked)
                    .map(|(id, sc)| resume_spec(*id, sc))
                    .collect();
                drop(core);
                let dialed = self.dial_sessions(&specs, CONNECT_ATTEMPTS, false);
                core = self.lock();
                core.connecting = false;
                match dialed {
                    Ok(s) => {
                        core.stream = Some(Arc::new(s));
                        core.generation = core.generation.wrapping_add(1);
                        self.stats.handle_clones.fetch_add(1, Ordering::Relaxed);
                        for spec in &specs {
                            if let Some(sc) = core.sessions.get_mut(&spec.session) {
                                sc.open_acked = true;
                            }
                        }
                        if !core.reader {
                            core.reader = true;
                            let host = Arc::clone(self);
                            std::thread::Builder::new()
                                .name("polylut-wire-host".into())
                                .spawn(move || host.reader_loop())
                                .expect("spawn wire host reader");
                        }
                        self.cv.notify_all();
                        continue;
                    }
                    Err(e) => {
                        core.sessions.remove(&sid);
                        self.cv.notify_all();
                        return Err(e);
                    }
                }
            }
            if core.reader
                && !core.recovering
                && !core.connecting
                && hello_gen != Some(core.generation)
            {
                if let Some(s) = core.stream.clone() {
                    hello_gen = Some(core.generation);
                    let hello = self.hello_frame(sid, engine, shard, 0);
                    let bytes = encode_frame(&hello)
                        .expect("hello frame is always encodable");
                    let sent = {
                        let _w = self.wlock.lock().unwrap_or_else(|p| p.into_inner());
                        let mut w: &TcpStream = &s;
                        w.write_all(&bytes).and_then(|_| w.flush()).is_ok()
                    };
                    if sent {
                        if let Some(sc) = core.sessions.get(&sid) {
                            sc.stats.count_frame(hello.words.len());
                        }
                        self.count_host_frame(hello.words.len());
                    } else {
                        self.fail_stream_locked(&mut core, "hello write failed");
                    }
                    // Fall through to the wait: the reader routes the
                    // HelloAck (or runs the recovery ladder, which
                    // re-greets us on its own generation).
                } else if core.need_reconnect.is_none() {
                    core.need_reconnect =
                        Some("opening a session on a dropped link".into());
                    self.cv.notify_all();
                }
            }
            core = self.cv.wait(core).unwrap_or_else(|p| p.into_inner());
        }
    }

    /// Tear down the live stream under the lock: bump the generation and
    /// either arm the reader's recovery (an epoch is open somewhere — the
    /// outage must be resumed now) or defer the redial to the next ship
    /// (idle link).
    fn fail_stream_locked(&self, core: &mut HostCore, why: &str) {
        if let Some(s) = core.stream.take() {
            let _ = s.shutdown(Shutdown::Both);
        }
        core.generation = core.generation.wrapping_add(1);
        let open = core
            .sessions
            .values()
            .any(|sc| !sc.closed && sc.dead.is_none() && !sc.epochs.is_empty());
        if open {
            if core.need_reconnect.is_none() {
                core.need_reconnect = Some(why.to_string());
            }
            log::warn!(
                "[wire] {}: link failed mid-epoch ({why}); reconnect-and-resume \
                 pending",
                self.addr
            );
        } else if core.need_reconnect.is_none() {
            log::info!(
                "[wire] {}: link dropped while idle ({why}); reconnecting at the \
                 next epoch",
                self.addr
            );
        }
        self.cv.notify_all();
    }

    /// Body of the dedicated per-host reader thread: owns every socket
    /// read *and* the whole recovery ladder, so a host dying is exactly
    /// one reconnect-and-resume however many sessions ride the link.
    fn reader_loop(self: Arc<HostLink>) {
        loop {
            // Pin a live stream (or wait for one / run recovery / exit).
            let mut pinned: Option<(Arc<TcpStream>, u64, bool)> = None;
            {
                let mut core = self.lock();
                loop {
                    if self.is_shutdown() || core.dead.is_some() {
                        return;
                    }
                    if core.need_reconnect.is_some() {
                        break; // recover below, outside this guard
                    }
                    if let Some(s) = &core.stream {
                        let idle =
                            core.sessions.values().all(|sc| sc.epochs.is_empty());
                        pinned = Some((Arc::clone(s), core.generation, idle));
                        break;
                    }
                    core = self.cv.wait(core).unwrap_or_else(|p| p.into_inner());
                }
            }
            let Some((stream, generation, idle)) = pinned else {
                self.recover();
                continue;
            };
            let mut r: &TcpStream = &stream;
            match read_frame_patient(&mut r, idle) {
                Ok(None) => continue, // idle timeout between epochs — benign
                Ok(Some(f)) => {
                    let mut core = self.lock();
                    self.route(&mut core, f);
                    drop(core);
                    self.cv.notify_all();
                }
                Err(e) => {
                    if self.is_shutdown() {
                        return;
                    }
                    let mut core = self.lock();
                    // A stale generation means the stream was already torn
                    // down (ship-side write failure or a routed Bye) and
                    // the bookkeeping ran there.
                    if core.generation == generation {
                        self.fail_stream_locked(&mut core, &e.to_string());
                    }
                }
            }
        }
    }

    /// Route one inbound frame under the host lock: count it, then
    /// dispatch by session id.
    fn route(&self, core: &mut HostCore, f: Frame) {
        self.count_host_frame(f.words.len());
        if f.kind == FrameKind::Bye {
            // Worker-initiated teardown (today always connection-wide):
            // one stream failure, recovered by this thread if any epoch
            // is open.
            self.fail_stream_locked(core, "worker sent Bye");
            return;
        }
        let Some(sc) = core.sessions.get_mut(&f.session) else {
            log::warn!(
                "[wire] {}: frame for unknown session {}",
                self.addr,
                f.session
            );
            return;
        };
        sc.stats.count_frame(f.words.len());
        match f.kind {
            FrameKind::HelloAck => {
                if f.words.first().copied() == Some(self.fingerprint) {
                    sc.open_acked = true;
                } else if sc.dead.is_none() {
                    sc.dead = Some(format!(
                        "{}: model fingerprint mismatch (worker {:#018x}, \
                         coordinator {:#018x}) — same weights, shard count and \
                         build required",
                        self.addr,
                        f.words.first().copied().unwrap_or(0),
                        self.fingerprint,
                    ));
                }
            }
            FrameKind::Fault => {
                let msg = fault_message(&f);
                let text = if sc.open_acked {
                    format!("{} faulted: {msg}", self.addr)
                } else {
                    format!("{} rejected handshake: {msg}", self.addr)
                };
                if sc.dead.is_none() {
                    sc.dead = Some(text);
                }
            }
            FrameKind::Data => {
                let n_layers = sc.n_layers as u32;
                let shard = sc.shard as u32;
                match sc.epochs.get_mut(&f.epoch) {
                    None => {
                        // A fully-applied epoch is retired from the map —
                        // late duplicates (resume replays recompute
                        // boundaries we already have) drop silently.  An
                        // epoch we never opened is a protocol violation.
                        if f.epoch > sc.last_epoch && sc.dead.is_none() {
                            sc.dead = Some(format!(
                                "{}: unexpected result frame (epoch {}, boundary \
                                 {}, shard {}) ahead of epoch {}",
                                self.addr, f.epoch, f.boundary, f.shard, sc.last_epoch
                            ));
                        }
                    }
                    Some(es) => {
                        if f.boundary <= es.applied {
                            // Stale duplicate below the checkpoint.
                        } else if f.boundary > n_layers || f.shard != shard {
                            if sc.dead.is_none() {
                                sc.dead = Some(format!(
                                    "{}: unexpected result frame (epoch {}, \
                                     boundary {}, shard {})",
                                    self.addr, f.epoch, f.boundary, f.shard
                                ));
                            }
                        } else {
                            es.pending.insert(f.boundary, f);
                        }
                    }
                }
            }
            k => {
                if sc.dead.is_none() {
                    sc.dead = Some(format!(
                        "{}: unexpected {k:?} frame on the result path",
                        self.addr
                    ));
                }
            }
        }
    }

    /// The one recovery ladder of the host (reader thread only): snapshot
    /// every session's resume handshake + checkpointed replay suffix,
    /// redial with the [`WireConfig::retries`] budget, re-greet each
    /// session and write the replays, then install the stream.  Failure
    /// is the sticky host death, fanned out to every session.
    fn recover(&self) {
        let (why, specs) = {
            let mut core = self.lock();
            if self.is_shutdown() || core.dead.is_some() {
                core.need_reconnect = None;
                return;
            }
            let why = core
                .need_reconnect
                .take()
                .unwrap_or_else(|| "re-establishing link".into());
            core.recovering = true;
            if let Some(s) = core.stream.take() {
                let _ = s.shutdown(Shutdown::Both);
            }
            core.generation = core.generation.wrapping_add(1);
            for sc in core.sessions.values_mut() {
                sc.open_acked = false;
            }
            let specs: Vec<ResumeSpec> = core
                .sessions
                .iter()
                .filter(|(_, sc)| !sc.closed && sc.dead.is_none())
                .map(|(id, sc)| resume_spec(*id, sc))
                .collect();
            (why, specs)
        };
        log::warn!(
            "[wire] {}: reconnect-and-resume across {} session(s): {why}",
            self.addr,
            specs.len()
        );
        let dialed = self
            .dial_sessions(&specs, self.cfg.retries, true)
            .and_then(|mut s| {
                for spec in &specs {
                    if !spec.replay.is_empty() {
                        s.write_all(&spec.replay)?;
                    }
                }
                s.flush()?;
                // Replayed traffic is counted here, once it left — `ship`
                // skips counting on a failed write precisely so an
                // incident accounts its frames exactly once.
                for spec in &specs {
                    spec.stats.frames.fetch_add(spec.replayed, Ordering::Relaxed);
                    spec.stats
                        .bytes
                        .fetch_add(spec.replay.len() as u64, Ordering::Relaxed);
                    spec.stats
                        .resume_replayed_frames
                        .fetch_add(spec.replayed, Ordering::Relaxed);
                    spec.stats
                        .resume_skipped_frames
                        .fetch_add(spec.skipped, Ordering::Relaxed);
                    self.frames.fetch_add(spec.replayed, Ordering::Relaxed);
                    self.bytes
                        .fetch_add(spec.replay.len() as u64, Ordering::Relaxed);
                }
                Ok(s)
            });
        let mut core = self.lock();
        core.recovering = false;
        match dialed {
            Ok(s) => {
                core.stream = Some(Arc::new(s));
                core.generation = core.generation.wrapping_add(1);
                self.stats.handle_clones.fetch_add(1, Ordering::Relaxed);
                self.stats.resumes.fetch_add(1, Ordering::Relaxed);
                for spec in &specs {
                    if let Some(sc) = core.sessions.get_mut(&spec.session) {
                        sc.open_acked = true;
                    }
                }
                let replayed: u64 = specs.iter().map(|s| s.replayed).sum();
                let skipped: u64 = specs.iter().map(|s| s.skipped).sum();
                log::info!(
                    "[wire] {}: resumed {} session(s) ({replayed} frames \
                     replayed, {skipped} skipped below checkpoints)",
                    self.addr,
                    specs.len()
                );
            }
            Err(e) => {
                if self.is_shutdown() {
                    self.cv.notify_all();
                    return;
                }
                self.stats.retry_exhausted.fetch_add(1, Ordering::Relaxed);
                let msg = format!(
                    "{}: reconnect failed after {} attempts: {e} (link originally \
                     failed: {why})",
                    self.addr,
                    self.cfg.retries.max(1)
                );
                core.dead = Some(msg.clone());
                for sc in core.sessions.values_mut() {
                    if sc.dead.is_none() {
                        sc.dead = Some(msg.clone());
                    }
                }
            }
        }
        self.cv.notify_all();
    }

    /// Sender side of one session: record the frames in the replay ledger
    /// under the core lock (opening the epoch first when `open` carries
    /// its Start), then write them on the shared connection under
    /// [`HostLink::wlock`].  Delivery is guaranteed once this returns: a
    /// failed write tears the stream down and the recovery replay carries
    /// everything the ledger recorded.
    fn ship_session(
        &self,
        sid: u16,
        epoch: u64,
        open: Option<Frame>,
        frames: &[Frame],
        flight: Option<u32>,
    ) -> Result<(), WireError> {
        // Encode (copy + checksum) outside the lock: a wide boundary's
        // frames must not serialize the receiver's bookkeeping — the
        // window credit that unblocks pipelining — against the sender.
        let mut bytes = Vec::new();
        if let Some(f) = &open {
            bytes.extend_from_slice(&encode_frame(f)?);
        }
        for f in frames {
            bytes.extend_from_slice(&encode_frame(f)?);
        }
        let mut core = self.lock();
        loop {
            if self.is_shutdown() {
                return Err(shutdown_error());
            }
            if let Some(m) = &core.dead {
                return Err(WireError::Protocol(m.clone()));
            }
            let Some(sc) = core.sessions.get(&sid) else {
                return Err(shutdown_error());
            };
            if sc.closed {
                return Err(shutdown_error());
            }
            if let Some(m) = &sc.dead {
                return Err(WireError::Protocol(m.clone()));
            }
            let window_full = flight.is_some()
                && sc.shipped.saturating_sub(sc.acked) as usize
                    >= self.cfg.window.max(1);
            if core.recovering || core.connecting || window_full {
                core = self.cv.wait(core).unwrap_or_else(|p| p.into_inner());
                continue;
            }
            if core.stream.is_none() {
                if core.need_reconnect.is_none() {
                    core.need_reconnect = Some("re-establishing idle link".into());
                    self.cv.notify_all();
                }
                core = self.cv.wait(core).unwrap_or_else(|p| p.into_inner());
                continue;
            }
            break;
        }
        let stream = Arc::clone(core.stream.as_ref().expect("stream gated above"));
        let generation = core.generation;
        let stats = {
            let sc = core.sessions.get_mut(&sid).expect("session gated above");
            if let Some(start) = &open {
                if epoch <= sc.last_epoch {
                    return Err(WireError::Protocol(format!(
                        "epoch went backwards: {epoch} after {}",
                        sc.last_epoch
                    )));
                }
                sc.last_epoch = epoch;
                sc.epochs.insert(epoch, EpochState::new(start.clone()));
            }
            if !frames.is_empty() || flight.is_some() {
                let Some(es) = sc.epochs.get_mut(&epoch) else {
                    return Err(WireError::Protocol(format!(
                        "flight shipped for unopened epoch {epoch}"
                    )));
                };
                for f in frames {
                    es.replay.push((f.boundary, f.clone()));
                }
                if let Some(boundary) = flight {
                    es.flight_bounds.push_back(boundary);
                    sc.shipped += 1;
                    let inflight = sc.shipped.saturating_sub(sc.acked) as u64;
                    sc.stats.inflight_hwm.fetch_max(inflight, Ordering::Relaxed);
                }
            }
            sc.stats.clone()
        };
        drop(core);
        let written = {
            let _w = self.wlock.lock().unwrap_or_else(|p| p.into_inner());
            let mut w: &TcpStream = &stream;
            w.write_all(&bytes).and_then(|_| w.flush())
        };
        match written {
            Ok(()) => {
                // Count traffic only once it actually left: failed writes
                // are accounted by the recovery replay instead (no double
                // counting per link incident).
                if let Some(f) = &open {
                    stats.count_frame(f.words.len());
                    self.count_host_frame(f.words.len());
                }
                for f in frames {
                    stats.count_frame(f.words.len());
                    self.count_host_frame(f.words.len());
                }
                Ok(())
            }
            Err(e) => {
                let mut core = self.lock();
                if core.generation == generation {
                    self.fail_stream_locked(&mut core, &format!("wire i/o: {e}"));
                }
                // The ledger already holds everything this call shipped —
                // the recovery replay delivers it.
                Ok(())
            }
        }
    }

    /// Receiver side of one session: block until the next in-order,
    /// not-yet-applied result frame of **any** of its open epochs is
    /// available (the reader thread parks demuxed frames in the epochs'
    /// completion tables).  `Ok(None)` = session closed or host shut
    /// down.
    fn recv_session(&self, sid: u16) -> Result<Option<Frame>, WireError> {
        let mut core = self.lock();
        loop {
            if self.is_shutdown() {
                return Ok(None);
            }
            if let Some(m) = &core.dead {
                return Err(WireError::Protocol(m.clone()));
            }
            let Some(sc) = core.sessions.get_mut(&sid) else {
                return Ok(None);
            };
            if sc.closed {
                return Ok(None);
            }
            if let Some(m) = &sc.dead {
                return Err(WireError::Protocol(m.clone()));
            }
            let mut found = None;
            for es in sc.epochs.values_mut() {
                let next = es.applied + 1;
                if let Some(f) = es.pending.remove(&next) {
                    found = Some(f);
                    break;
                }
            }
            if let Some(f) = found {
                return Ok(Some(f));
            }
            let idle = sc.epochs.is_empty();
            let stats = sc.stats.clone();
            let t0 = Instant::now();
            core = self.cv.wait(core).unwrap_or_else(|p| p.into_inner());
            // Idle waits between epochs are not "blocked waiting for a
            // frame" — funding wait_ns from them would swamp the metric
            // on an idle server.
            if !idle {
                stats
                    .wait_ns
                    .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            }
        }
    }

    /// Record that result frame `f` has been applied to the runner's
    /// buffers: window credit, checkpoint advancement (the replay ledger
    /// trims below it) and epoch retirement at the final boundary.
    fn mark_applied(&self, sid: u16, f: &Frame) {
        let mut core = self.lock();
        let Some(sc) = core.sessions.get_mut(&sid) else {
            return;
        };
        let n_layers = sc.n_layers as u32;
        let mut acked = 0u32;
        if let Some(es) = sc.epochs.get_mut(&f.epoch) {
            if f.boundary > es.applied {
                es.applied = f.boundary;
            }
            // Ack every shipped flight whose boundary's result (boundary
            // l + 1 for a flight at boundary l) is now covered —
            // flight-unit credit for the window gate.
            while es.flight_bounds.front().is_some_and(|&l| l + 1 <= f.boundary) {
                es.flight_bounds.pop_front();
                acked += 1;
            }
            if f.boundary < n_layers {
                // Checkpoint: the resume replay restores this frame and
                // re-ships only the needs at or above its boundary.
                es.checkpoint = Some(f.clone());
                let before = es.replay.len();
                es.replay.retain(|(level, _)| *level >= f.boundary);
                es.trimmed += (before - es.replay.len()) as u64;
            }
        }
        if f.boundary == n_layers {
            sc.epochs.remove(&f.epoch);
        }
        sc.acked += acked;
        self.cv.notify_all();
    }

    /// Mark one session dead with a protocol-level message (receiver-side
    /// validation failures — transport errors go through the recovery
    /// ladder instead).
    fn kill_session(&self, sid: u16, msg: &str) {
        let mut core = self.lock();
        if let Some(sc) = core.sessions.get_mut(&sid) {
            if sc.dead.is_none() {
                sc.dead = Some(msg.to_string());
            }
        }
        self.cv.notify_all();
    }

    /// Close one session (best-effort Bye on its id); the last session to
    /// close shuts the whole host link down — Bye on the control channel,
    /// FIN, and the reader thread exits.
    fn close_session(&self, sid: u16) {
        let mut core = self.lock();
        let stream = core.stream.clone();
        if let Some(sc) = core.sessions.get_mut(&sid) {
            if !sc.closed {
                sc.closed = true;
                sc.epochs.clear();
                if let Some(s) = &stream {
                    let mut bye = Frame::control(FrameKind::Bye, 0);
                    bye.session = sid;
                    let _w = self.wlock.lock().unwrap_or_else(|p| p.into_inner());
                    let mut w: &TcpStream = s;
                    let _ = write_frame(&mut w, &bye);
                }
            }
        }
        let all_closed = !core.sessions.is_empty()
            && core.sessions.values().all(|sc| sc.closed);
        if all_closed && !self.shutdown.swap(true, Ordering::Relaxed) {
            if let Some(s) = core.stream.take() {
                let _w = self.wlock.lock().unwrap_or_else(|p| p.into_inner());
                let mut w: &TcpStream = &s;
                let _ = write_frame(&mut w, &Frame::control(FrameKind::Bye, 0));
                let _ = s.shutdown(Shutdown::Both);
            }
        }
        self.cv.notify_all();
    }
}

/// Per-model registry of host links.  With [`WireConfig::mux`] (the
/// default) every remote (engine, shard) session to one `host:port`
/// shares a single [`HostLink`] — and therefore one TCP connection, one
/// reader thread and one recovery ladder.  With mux off each session gets
/// a private host link (the v2 one-connection-per-session topology) over
/// the identical code path.
pub(crate) struct HostRegistry {
    shards: usize,
    fingerprint: u64,
    cfg: WireConfig,
    hosts: Mutex<Vec<Arc<HostLink>>>,
}

impl HostRegistry {
    pub(crate) fn new(shards: usize, fingerprint: u64, cfg: WireConfig) -> HostRegistry {
        HostRegistry { shards, fingerprint, cfg, hosts: Mutex::new(Vec::new()) }
    }

    /// The wire knobs every link from this registry shares (the runner
    /// sizes its epoch ring from `cfg().window`).
    pub(crate) fn cfg(&self) -> WireConfig {
        self.cfg
    }

    fn host(&self, addr: &str) -> Arc<HostLink> {
        let mut hosts = self.hosts.lock().unwrap_or_else(|p| p.into_inner());
        if self.cfg.mux {
            if let Some(h) = hosts.iter().find(|h| h.addr() == addr) {
                return Arc::clone(h);
            }
        }
        let h = HostLink::new(addr, self.shards, self.fingerprint, self.cfg);
        hosts.push(Arc::clone(&h));
        h
    }

    /// Every host link the registry handed out (with mux off: one per
    /// session).
    pub(crate) fn hosts(&self) -> Vec<Arc<HostLink>> {
        self.hosts.lock().unwrap_or_else(|p| p.into_inner()).clone()
    }
}

/// Coordinator end of one (engine, shard) **session**.  The per-link API
/// the shard runner's sender/receiver thread pair drives is unchanged
/// from v2; transport, demux and recovery live in the shared
/// [`HostLink`].
pub(crate) struct WireLink {
    host: Arc<HostLink>,
    session: u16,
    closed: AtomicBool,
    stats: Arc<LinkStats>,
}

impl WireLink {
    /// Open a session to a shard worker through the model's host
    /// registry, running the handshake (fail-fast initial budget on a
    /// fresh host — see [`CONNECT_ATTEMPTS`]).
    pub(crate) fn connect(
        registry: &HostRegistry,
        addr: &str,
        engine: EngineKind,
        shard: usize,
        n_layers: usize,
    ) -> Result<Arc<WireLink>, WireError> {
        let host = registry.host(addr);
        let stats = Arc::new(LinkStats::default());
        let session = host.open_session(engine, shard, n_layers, stats.clone())?;
        Ok(Arc::new(WireLink {
            host,
            session,
            closed: AtomicBool::new(false),
            stats,
        }))
    }

    pub(crate) fn peer(&self) -> &str {
        self.host.addr()
    }

    pub(crate) fn stats(&self) -> Arc<LinkStats> {
        self.stats.clone()
    }

    /// The host link carrying this session (per-host stats + identity for
    /// the `wire_links` rollup).
    pub(crate) fn host(&self) -> &Arc<HostLink> {
        &self.host
    }

    pub(crate) fn is_shutdown(&self) -> bool {
        self.closed.load(Ordering::Relaxed) || self.host.is_shutdown()
    }

    /// Open epoch `epoch` on this session: register it in the replay
    /// ledger and ship its `Start`.  Epochs may overlap — the runner
    /// admits up to [`WireConfig::window`] — but their ids must ascend.
    pub(crate) fn begin_epoch(&self, epoch: u64) -> Result<(), WireError> {
        let mut start = Frame::control(FrameKind::Start, epoch);
        start.session = self.session;
        self.host.ship_session(self.session, epoch, Some(start), &[], None)
    }

    /// Ship the needs flight for `boundary` of `epoch` (window-gated in
    /// flight units across all of the session's open epochs).  Only
    /// boundaries with cross-shard needs ship a flight — see
    /// `send_epoch` — so `window == 1` lock-steps exactly the flights
    /// that exist even when flightless boundaries sit between them.
    pub(crate) fn ship_flight(
        &self,
        epoch: u64,
        boundary: u32,
        frames: &mut [Frame],
    ) -> Result<(), WireError> {
        for f in frames.iter_mut() {
            f.session = self.session;
        }
        self.host.ship_session(self.session, epoch, None, frames, Some(boundary))
    }

    /// Receiver side: block until the next in-order, not-yet-applied
    /// result frame of any open epoch is available.  Duplicates (resume
    /// replays recompute boundaries the coordinator already applied) are
    /// dropped by the completion tables; frames ahead of an epoch's
    /// contiguous prefix are parked in them.  `Ok(None)` = shutdown.
    pub(crate) fn recv_applied(&self) -> Result<Option<Frame>, WireError> {
        self.host.recv_session(self.session)
    }

    /// Record that result frame `f` has been applied to the shared
    /// buffers (window credit + checkpoint + epoch-completion
    /// bookkeeping).
    pub(crate) fn mark_applied(&self, f: &Frame) {
        self.host.mark_applied(self.session, f);
    }

    /// Mark the session dead with a protocol-level message
    /// (receiver-side validation failures — not transport errors, which
    /// go through the host recovery ladder).
    pub(crate) fn kill(&self, msg: &str) {
        self.host.kill_session(self.session, msg);
    }

    /// Best-effort clean shutdown of this session; the host link (and
    /// its reader thread) goes down with the last session.
    pub(crate) fn close(&self) {
        self.closed.store(true, Ordering::Relaxed);
        self.host.close_session(self.session);
    }
}

// ---------------------------------------------------------------------------
// Worker side: connection demux + RemoteHandoff + ShardWorkerHost
// ---------------------------------------------------------------------------

/// Inbound frame queue of one worker-side session.  The per-connection
/// demux thread owns the socket and pushes each session's frames here;
/// the session thread blocks on `recv`.
#[derive(Default)]
struct SessionInbox {
    q: Mutex<VecDeque<Frame>>,
    cv: Condvar,
    closed: AtomicBool,
}

impl SessionInbox {
    fn push(&self, f: Frame) {
        let mut q = self.q.lock().unwrap_or_else(|p| p.into_inner());
        q.push_back(f);
        self.cv.notify_all();
    }

    fn close(&self) {
        self.closed.store(true, Ordering::Relaxed);
        self.cv.notify_all();
    }

    /// Blocking pop with the same liveness discipline the socket reads
    /// have: with `idle_ok` a quiet [`RECV_TIMEOUT`] window returns
    /// `Ok(None)` (idle server between epochs); without it,
    /// [`LIVENESS_STRIKES`] consecutive empty windows declare the peer
    /// (or its session) dead — any delivered frame resets the count.
    fn recv(&self, idle_ok: bool) -> Result<Option<Frame>, WireError> {
        let mut q = self.q.lock().unwrap_or_else(|p| p.into_inner());
        let mut strikes = 0u32;
        loop {
            if let Some(f) = q.pop_front() {
                return Ok(Some(f));
            }
            if self.closed.load(Ordering::Relaxed) {
                return Err(WireError::Io(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "link closed",
                )));
            }
            let (guard, timeout) = self
                .cv
                .wait_timeout(q, RECV_TIMEOUT)
                .unwrap_or_else(|p| p.into_inner());
            q = guard;
            if timeout.timed_out() && q.is_empty() {
                if idle_ok {
                    return Ok(None);
                }
                strikes += 1;
                if strikes >= LIVENESS_STRIKES {
                    return Err(WireError::Io(std::io::Error::new(
                        std::io::ErrorKind::TimedOut,
                        format!(
                            "no frames for {strikes} consecutive liveness windows"
                        ),
                    )));
                }
            } else {
                strikes = 0;
            }
        }
    }
}

/// A session's two endpoints on the shared connection: the write half
/// (serialized with every other session on the link) and its private
/// inbox fed by the demux thread.
#[derive(Clone)]
struct SessionIo {
    session: u16,
    writer: Arc<Mutex<TcpStream>>,
    inbox: Arc<SessionInbox>,
}

/// Worker-side [`Handoff`]: the per-cell `(shard, threshold)` dependency
/// waits of the generic cell loop are satisfied by **frame arrival**.
/// `wait(d, thr)` pulls frames off the session inbox and applies them
/// through a per-`(epoch, boundary, producer)` completion table until
/// producer `d`'s level reaches `thr`; `publish(s, level)` ships the
/// shard's boundary-`level` slice back to the coordinator.  The
/// coordinator's pseudo-shard (`shards`) produces boundary 0 (input
/// staging) at level 1.
///
/// v2 dropped the TCP-order assumption: the worker's buffers are
/// **per-boundary** (no parity aliasing), so a current-epoch frame is
/// applied the moment it arrives regardless of arrival order, levels
/// advance via `fetch_max`, and frames for a *future* epoch (the windowed
/// sender streams up to `window` epochs ahead) park in a bounded pending
/// buffer that `begin_epoch` drains.  v3 adds the checkpointed resume: a
/// `Start` whose `boundary` is `h > 0` means the coordinator already
/// holds everything below boundary `h` — the replay restores this
/// shard's own boundary-`h` slice (`own_restore`) and the cell loop
/// starts at layer `h` instead of layer 0.
struct RemoteHandoff {
    io: SessionIo,
    bufs: Arc<BufSet>,
    plan: WirePlan,
    n_layers: usize,
    shards: usize,
    shard: u32,
    /// levels[q] for q in 0..shards, plus the coordinator at index shards.
    levels: Vec<AtomicU32>,
    /// Frames still expected per boundary, per producer (epoch-local).
    remaining: Mutex<Vec<Vec<(u32, u32)>>>,
    /// Future-epoch frames (incl. `Start`), bounded by `pending_cap`.
    pending: Mutex<Vec<Frame>>,
    pending_cap: usize,
    epoch: AtomicU64,
    /// Highest boundary restored from a resume checkpoint this epoch (the
    /// coordinator re-ships this shard's own applied slice so the cell
    /// loop can restart above it without recomputing).
    own_restore: AtomicU32,
    stats: Arc<LinkStats>,
    fault: Mutex<Option<String>>,
}

impl RemoteHandoff {
    fn new(
        io: SessionIo,
        bufs: Arc<BufSet>,
        plan: WirePlan,
        n_layers: usize,
        shards: usize,
        shard: u32,
        window: usize,
    ) -> RemoteHandoff {
        let remaining = plan.counts.clone();
        // The coordinator keeps up to `window` epochs open at once and a
        // resume can replay all of them back to back — size the pending
        // buffer for every one of them plus slack for restore frames.
        let pending_cap = (window.max(1) + 1) * frames_per_epoch(&plan) + 8;
        RemoteHandoff {
            io,
            bufs,
            plan,
            n_layers,
            shards,
            shard,
            levels: (0..=shards).map(|_| AtomicU32::new(0)).collect(),
            remaining: Mutex::new(remaining),
            pending: Mutex::new(Vec::new()),
            pending_cap,
            epoch: AtomicU64::new(0),
            own_restore: AtomicU32::new(0),
            stats: Arc::new(LinkStats::default()),
            fault: Mutex::new(None),
        }
    }

    /// Blocking read of the next frame (any kind) from the session inbox,
    /// with the liveness bound (see [`SessionInbox::recv`]).
    fn recv_frame(&self) -> Result<Frame, WireError> {
        let t0 = Instant::now();
        let f = self.io.inbox.recv(false);
        self.stats.wait_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        let f = f?.expect("idle_ok=false never yields None");
        self.stats.count_frame(f.words.len());
        Ok(f)
    }

    /// Idle-tolerant read between epochs: `Ok(None)` on a quiet timeout
    /// window (the coordinator simply has no traffic), `Err` once the
    /// connection goes away.
    fn recv_idle(&self) -> Result<Option<Frame>, WireError> {
        let f = self.io.inbox.recv(true)?;
        if let Some(f) = &f {
            self.stats.count_frame(f.words.len());
        }
        Ok(f)
    }

    fn send_frame(&self, f: &Frame) -> Result<(), WireError> {
        let mut w = self.io.writer.lock().unwrap_or_else(|p| p.into_inner());
        write_frame(&mut *w, f)?;
        self.stats.count_frame(f.words.len());
        Ok(())
    }

    /// Reset per-epoch state on a Start frame, then drain any pending
    /// frames the windowed sender shipped ahead for this epoch.
    fn begin_epoch(&self, epoch: u64) -> Result<(), WireError> {
        let last = self.epoch.swap(epoch, Ordering::Relaxed);
        if epoch <= last {
            return Err(WireError::Protocol(format!(
                "epoch went backwards: {epoch} after {last}"
            )));
        }
        for l in &self.levels {
            l.store(0, Ordering::Relaxed);
        }
        self.own_restore.store(0, Ordering::Relaxed);
        *self.remaining.lock().unwrap_or_else(|p| p.into_inner()) = self.plan.counts.clone();
        let ready: Vec<Frame> = {
            let mut pending = self.pending.lock().unwrap_or_else(|p| p.into_inner());
            let mut keep = Vec::new();
            let mut ready = Vec::new();
            for f in pending.drain(..) {
                if f.kind == FrameKind::Data && f.epoch == epoch {
                    ready.push(f);
                } else if f.epoch > epoch {
                    keep.push(f);
                }
                // Older frames are stale leftovers — drop.
            }
            *pending = keep;
            ready
        };
        for f in ready {
            self.apply_now(f)?;
        }
        Ok(())
    }

    /// Park a future-epoch frame in the bounded pending buffer.
    fn pend(&self, f: Frame) -> Result<(), WireError> {
        let mut pending = self.pending.lock().unwrap_or_else(|p| p.into_inner());
        if pending.len() >= self.pending_cap {
            return Err(WireError::Protocol(format!(
                "pending frame buffer overflow ({} frames for future epochs)",
                pending.len()
            )));
        }
        pending.push(f);
        Ok(())
    }

    /// Pop the earliest pending `Start` frame, if any.
    fn take_pending_start(&self) -> Option<Frame> {
        let mut pending = self.pending.lock().unwrap_or_else(|p| p.into_inner());
        let idx = pending
            .iter()
            .enumerate()
            .filter(|(_, f)| f.kind == FrameKind::Start)
            .min_by_key(|(_, f)| f.epoch)
            .map(|(i, _)| i)?;
        Some(pending.remove(idx))
    }

    /// Route one incoming Data frame through the epoch completion table:
    /// current epoch → apply immediately (per-boundary buffers make any
    /// arrival order safe), future epoch → pend, past epoch → drop.
    fn apply(&self, f: Frame) -> Result<(), WireError> {
        let epoch = self.epoch.load(Ordering::Relaxed);
        if f.epoch > epoch {
            return self.pend(f);
        }
        if f.epoch < epoch {
            return Ok(());
        }
        self.apply_now(f)
    }

    /// Apply one current-epoch Data frame to the private buffers and
    /// advance the producer's level when its boundary is complete.
    fn apply_now(&self, f: Frame) -> Result<(), WireError> {
        let b = f.boundary as usize;
        if b >= self.n_layers {
            return Err(WireError::Protocol(format!(
                "incoming boundary {b} out of range (layers {})",
                self.n_layers
            )));
        }
        if f.parity != (f.boundary % 2) as u8 {
            return Err(WireError::Protocol(format!(
                "parity {} does not match boundary {b}",
                f.parity
            )));
        }
        let q = f.shard;
        if q as usize > self.shards {
            return Err(WireError::Protocol(format!("unknown producer shard {q}")));
        }
        let target = self.bufs.boundary(b, self.n_layers);
        let start = f.start as usize;
        let end = start
            .checked_add(f.words.len())
            .ok_or_else(|| WireError::Protocol("position overflow".into()))?;
        if end > target.len() {
            return Err(WireError::Protocol(format!(
                "frame range {start}..{end} exceeds boundary buffer {}",
                target.len()
            )));
        }
        for (slot, w) in target[start..end].iter().zip(&f.words) {
            slot.store(*w, Ordering::Relaxed);
        }
        if q == self.shard {
            // A resume checkpoint restoring our *own* applied slice — it
            // has no entry in the needs completion table (shards never
            // ship themselves their own data mid-epoch); it just unblocks
            // the cell loop's restart layer.
            self.own_restore.fetch_max(f.boundary, Ordering::Release);
            return Ok(());
        }
        let mut remaining = self.remaining.lock().unwrap_or_else(|p| p.into_inner());
        let entry = remaining[b].iter_mut().find(|(d, n)| *d == q && *n > 0);
        match entry {
            Some((_, n)) => {
                *n -= 1;
                if *n == 0 {
                    let level = if q as usize == self.shards { 1 } else { f.boundary };
                    // fetch_max, not store: completion is tracked per
                    // (epoch, boundary, producer), so a level can never
                    // regress whatever order boundaries complete in.
                    self.levels[q as usize].fetch_max(level, Ordering::Release);
                }
            }
            None => {
                return Err(WireError::Protocol(format!(
                    "unexpected frame from producer {q} for boundary {b}"
                )))
            }
        }
        Ok(())
    }

    /// Block until the resume replay has restored this shard's own slice
    /// of boundary `resume` (needs frames and future-epoch Starts keep
    /// routing normally while we wait).
    fn wait_restore(&self, resume: u32) -> Result<(), WireError> {
        while self.own_restore.load(Ordering::Acquire) < resume {
            let f = self.recv_frame()?;
            match f.kind {
                FrameKind::Data => self.apply(f)?,
                FrameKind::Start => self.pend(f)?,
                FrameKind::Fault => {
                    return Err(WireError::Protocol(format!(
                        "coordinator faulted: {}",
                        fault_message(&f)
                    )))
                }
                FrameKind::Bye => {
                    return Err(WireError::Io(std::io::Error::new(
                        std::io::ErrorKind::UnexpectedEof,
                        "link closed mid-epoch",
                    )))
                }
                k => {
                    return Err(WireError::Protocol(format!(
                        "unexpected {k:?} frame while waiting for data"
                    )))
                }
            }
        }
        Ok(())
    }
}

impl Handoff for RemoteHandoff {
    fn wait(&self, shard: usize, threshold: u32) -> Result<bool, HandoffError> {
        if self.levels[shard].load(Ordering::Acquire) >= threshold {
            return Ok(false);
        }
        while self.levels[shard].load(Ordering::Acquire) < threshold {
            let f = self.recv_frame().map_err(HandoffError::from)?;
            match f.kind {
                FrameKind::Data => self.apply(f).map_err(HandoffError::from)?,
                // The windowed sender may open the next epoch while this
                // one finishes — park its Start for the serve loop.
                FrameKind::Start => self.pend(f).map_err(HandoffError::from)?,
                FrameKind::Fault => {
                    return Err(HandoffError(format!(
                        "coordinator faulted: {}",
                        fault_message(&f)
                    )))
                }
                FrameKind::Bye => return Err(HandoffError("link closed mid-epoch".into())),
                k => {
                    return Err(HandoffError(format!(
                        "unexpected {k:?} frame while waiting for data"
                    )))
                }
            }
        }
        Ok(true)
    }

    fn publish(&self, shard: usize, level: u32) -> Result<(), HandoffError> {
        debug_assert_eq!(shard as u32, self.shard);
        let l = level as usize - 1;
        let rr = self.plan.result[l].clone();
        let src = self.bufs.dst(l, self.n_layers);
        let words: Vec<u64> =
            src[rr.clone()].iter().map(|w| w.load(Ordering::Relaxed)).collect();
        let epoch = self.epoch.load(Ordering::Relaxed);
        let mut f = Frame::data(epoch, level, self.shard, rr.start as u32, words);
        f.session = self.io.session;
        self.send_frame(&f).map_err(HandoffError::from)
    }

    fn level(&self, shard: usize) -> u32 {
        self.levels[shard].load(Ordering::Acquire)
    }

    fn reset(&self) {
        // Per-epoch state is reset by `begin_epoch` on the Start frame.
    }

    fn fail(&self, msg: &str) {
        let mut f = self.fault.lock().unwrap_or_else(|p| p.into_inner());
        if f.is_none() {
            *f = Some(msg.to_string());
        }
    }

    fn fault(&self) -> Option<String> {
        self.fault.lock().unwrap_or_else(|p| p.into_inner()).clone()
    }
}

/// Send a session-stamped Fault on the shared write half (best effort
/// error signalling to one coordinator session).
fn send_fault(
    writer: &Arc<Mutex<TcpStream>>,
    session: u16,
    msg: &str,
) -> Result<(), WireError> {
    let mut f = fault_frame(msg);
    f.session = session;
    let mut w = writer.lock().unwrap_or_else(|p| p.into_inner());
    write_frame(&mut *w, &f)
}

/// The `polylut shard-worker` process body: the full sharded kernels
/// (compiled deterministically from the same network, tables and shard
/// count as the coordinator — verified by a fingerprint handshake), served
/// over TCP.  v3: one accepted **connection** carries any number of
/// (engine, shard) **sessions** — a demux thread owns the socket reads,
/// admits sessions as their Hello frames arrive (each gets a session id
/// from the coordinator's header), and routes every subsequent frame to
/// the claiming session's inbox.  Each session gets private boundary
/// buffers plus a thread running the same generic cell loop as a local
/// shard worker, with `RemoteHandoff` mapping its dependency waits onto
/// frame arrival; writes back to the coordinator share the connection
/// under one lock.
pub struct ShardWorkerHost {
    plan: Arc<PlanKernel>,
    bits: Arc<BitsliceKernel>,
    shards: usize,
    fingerprint: u64,
    /// In-flight window this worker sizes its pending buffers for
    /// (`polylut shard-worker --wire-window`; a session uses the larger of
    /// this and the coordinator's Hello-advertised window).
    window: usize,
}

impl ShardWorkerHost {
    /// Compile both shard kernels for `shards` shards (identical to the
    /// coordinator-side compilation: cache-aware reorder, permute, plan +
    /// bitslice partitioning), with the default in-flight window.
    pub fn compile(
        net: &Network,
        tables: &NetworkTables,
        shards: usize,
        workers: usize,
    ) -> ShardWorkerHost {
        Self::compile_windowed(net, tables, shards, workers, DEFAULT_WIRE_WINDOW)
    }

    /// [`ShardWorkerHost::compile`] with an explicit in-flight window
    /// (sizes the per-session bounded pending-frame buffer).
    pub fn compile_windowed(
        net: &Network,
        tables: &NetworkTables,
        shards: usize,
        workers: usize,
        window: usize,
    ) -> ShardWorkerHost {
        let shards = shards.max(1);
        let (pnet, ptables) = permuted_for_shards(net, tables);
        let fingerprint = shard_fingerprint(&pnet, &ptables, shards);
        ShardWorkerHost {
            plan: Arc::new(plan_kernel_of(&pnet, &ptables, shards)),
            bits: Arc::new(bits_kernel_of(&pnet, &ptables, shards, workers)),
            shards,
            fingerprint,
            window: window.max(1),
        }
    }

    /// Shard count the kernels were partitioned for.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Model fingerprint the handshake checks (hash of the permuted
    /// network's connectivity, table words and shard count).
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    /// Accept loop: serves every incoming connection on its own demux
    /// thread until the listener errors (e.g. is closed).  Blocking —
    /// spawn it on a dedicated thread for in-process use.
    pub fn serve(self: Arc<Self>, listener: TcpListener) {
        for stream in listener.incoming() {
            match stream {
                Ok(s) => {
                    let host = self.clone();
                    std::thread::Builder::new()
                        .name("polylut-wire-conn".into())
                        .spawn(move || host.connection(s))
                        .expect("spawn wire connection");
                }
                Err(e) => {
                    log::warn!("shard-worker accept failed: {e}");
                    return;
                }
            }
        }
    }

    /// Validate one session's Hello against the compiled kernels.  A
    /// rejection faults only that session — the connection (and any other
    /// sessions riding it) stays up.
    fn admit(&self, hello: &Frame) -> Result<(EngineKind, usize), String> {
        let engine = hello
            .words
            .first()
            .copied()
            .and_then(EngineKind::from_u64)
            .ok_or_else(|| "Hello names no engine".to_string())?;
        let shards = hello.words.get(1).copied().unwrap_or(0) as usize;
        let fp = hello.words.get(2).copied().unwrap_or(0);
        let shard = hello.shard as usize;
        if shards != self.shards {
            return Err(format!(
                "shard count mismatch: coordinator {shards}, worker {}",
                self.shards
            ));
        }
        if fp != self.fingerprint {
            return Err(format!(
                "model fingerprint mismatch: coordinator {fp:#018x}, worker {:#018x}",
                self.fingerprint
            ));
        }
        if shard >= self.shards {
            return Err(format!("shard {shard} out of range (shards {})", self.shards));
        }
        Ok((engine, shard))
    }

    fn connection(&self, mut stream: TcpStream) {
        let peer = stream
            .peer_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "<unknown>".into());
        if let Err(e) = self.connection_inner(&mut stream, &peer) {
            match &e {
                // EOF without a Bye is how a killed coordinator looks;
                // don't alarm on it.
                WireError::Io(io) if io.kind() == std::io::ErrorKind::UnexpectedEof => {
                    log::info!("[shard-worker] {peer}: link closed");
                }
                _ => {
                    log::warn!("[shard-worker] {peer}: connection failed: {e}");
                    let _ = write_frame(&mut stream, &fault_frame(&e.to_string()));
                }
            }
        } else {
            log::info!("[shard-worker] {peer}: clean shutdown");
        }
        let _ = stream.shutdown(Shutdown::Both);
    }

    /// Per-connection demux loop: owns every read on the socket, admits
    /// sessions on Hello, routes Data/Start frames to session inboxes,
    /// and tears every session down when the connection dies.
    fn connection_inner(&self, stream: &mut TcpStream, peer: &str) -> Result<(), WireError> {
        stream.set_nodelay(true)?;
        // Liveness bound on the worker side too: a half-open link (peer
        // died without FIN) must not pin the demux thread in a blocking
        // read forever.  An idle timeout is benign (idle coordinator) and
        // the loop retries; mid-frame reads use the progress-aware bound
        // of `read_frame_patient`, so a slow wide frame trickling in is
        // never dropped.
        stream.set_read_timeout(Some(RECV_TIMEOUT))?;
        let writer = Arc::new(Mutex::new(stream.try_clone()?));
        let mut sessions: BTreeMap<u16, Arc<SessionInbox>> = BTreeMap::new();
        let mut threads: Vec<std::thread::JoinHandle<()>> = Vec::new();
        let result = loop {
            let f = match read_frame_patient(stream, true) {
                Ok(None) => continue, // quiet window — idle coordinator
                Ok(Some(f)) => f,
                Err(e) => break Err(e),
            };
            match f.kind {
                FrameKind::Hello => {
                    let sid = f.session;
                    if sid == 0 || sessions.contains_key(&sid) {
                        break Err(WireError::Protocol(format!(
                            "reserved or duplicate session id {sid} in Hello"
                        )));
                    }
                    let (engine, shard) = match self.admit(&f) {
                        Ok(ok) => ok,
                        Err(msg) => {
                            log::warn!(
                                "[shard-worker] {peer}: rejected session {sid}: {msg}"
                            );
                            if let Err(e) = send_fault(&writer, sid, &msg) {
                                break Err(e);
                            }
                            continue;
                        }
                    };
                    let resume_epoch = f.words.get(3).copied().unwrap_or(0);
                    let peer_window = f.words.get(4).copied().unwrap_or(1) as usize;
                    let window = self.window.max(peer_window);
                    let inbox = Arc::new(SessionInbox::default());
                    sessions.insert(sid, inbox.clone());
                    let ack = Frame {
                        kind: FrameKind::HelloAck,
                        parity: 0,
                        session: sid,
                        epoch: 0,
                        boundary: 0,
                        shard: shard as u32,
                        start: 0,
                        words: vec![self.fingerprint],
                    };
                    {
                        let mut w = writer.lock().unwrap_or_else(|p| p.into_inner());
                        if let Err(e) = write_frame(&mut *w, &ack) {
                            break Err(e);
                        }
                    }
                    // The effective window is the max of both ends — the
                    // coordinator gates its in-flight epochs on its own
                    // setting, the worker just sizes buffers to match.
                    log::info!(
                        "[shard-worker] {peer}: session {sid} admitted: {engine:?} \
                         shard {shard} window={window} (effective max of worker {}, \
                         coordinator {peer_window})",
                        self.window
                    );
                    if resume_epoch > 0 {
                        log::info!(
                            "[shard-worker] resume handshake: shard {shard} from \
                             epoch {resume_epoch}"
                        );
                    }
                    let io = SessionIo {
                        session: sid,
                        writer: writer.clone(),
                        inbox: inbox.clone(),
                    };
                    let plan = self.plan.clone();
                    let bits = self.bits.clone();
                    let fault_writer = writer.clone();
                    let peer = peer.to_string();
                    let t = std::thread::Builder::new()
                        .name("polylut-wire-session".into())
                        .spawn(move || {
                            let r = match engine {
                                EngineKind::Plan => serve_shard(&*plan, shard, io, window),
                                EngineKind::Bitslice => {
                                    serve_shard(&*bits, shard, io, window)
                                }
                            };
                            match r {
                                Ok(()) => log::info!(
                                    "[shard-worker] {peer}: session {sid} closed"
                                ),
                                Err(WireError::Io(e))
                                    if e.kind() == std::io::ErrorKind::UnexpectedEof =>
                                {
                                    log::info!(
                                        "[shard-worker] {peer}: session {sid} link \
                                         closed"
                                    );
                                }
                                Err(e) => {
                                    log::warn!(
                                        "[shard-worker] {peer}: session {sid} \
                                         failed: {e}"
                                    );
                                    let _ = send_fault(&fault_writer, sid, &e.to_string());
                                }
                            }
                        })
                        .expect("spawn wire session");
                    threads.push(t);
                }
                FrameKind::Data | FrameKind::Start => match sessions.get(&f.session) {
                    Some(inbox) => inbox.push(f),
                    None => {
                        let msg = format!("frame for unknown session {}", f.session);
                        log::warn!("[shard-worker] {peer}: {msg}");
                        if let Err(e) = send_fault(&writer, f.session, &msg) {
                            break Err(e);
                        }
                    }
                },
                FrameKind::Bye => {
                    if f.session == 0 {
                        break Ok(()); // connection-wide clean shutdown
                    }
                    if let Some(inbox) = sessions.remove(&f.session) {
                        inbox.push(f);
                    }
                }
                k => {
                    break Err(WireError::Protocol(format!(
                        "unexpected {k:?} frame on the demux path"
                    )))
                }
            }
        };
        for inbox in sessions.values() {
            inbox.close();
        }
        let _ = stream.shutdown(Shutdown::Both);
        for t in threads {
            let _ = t.join();
        }
        result
    }
}

/// Serve one (engine, shard) session: per Start frame, run the generic
/// cell loop for this shard over private **per-boundary** buffers with the
/// `RemoteHandoff` (per-boundary staging is what lets the windowed stream
/// apply frames in any arrival order — no parity aliasing to protect).  A
/// Start with `boundary = h > 0` is a checkpointed resume: wait for the
/// replay to restore our own boundary-`h` slice, then run from layer `h`.
fn serve_shard<K: ShardKernel>(
    kernel: &K,
    shard: usize,
    io: SessionIo,
    window: usize,
) -> Result<(), WireError> {
    let bufs = Arc::new(BufSet::per_boundary(kernel));
    let plan = wire_plan(kernel, shard);
    let deps_owned = plan.deps.clone();
    let handoff = RemoteHandoff::new(
        io,
        bufs.clone(),
        plan,
        kernel.n_layers(),
        kernel.n_shards(),
        shard as u32,
        window,
    );
    let deps: Vec<&[(u32, u32)]> = deps_owned.iter().map(|v| v.as_slice()).collect();
    let mut scratch = kernel.make_scratch();
    let cells = AtomicU64::new(0);
    let waits = AtomicU64::new(0);
    loop {
        // The windowed sender may have streamed the next epoch's Start
        // while the previous epoch's tail was still being read — serve it
        // from the pending buffer before blocking on the inbox.
        let f = match handoff.take_pending_start() {
            Some(f) => f,
            None => match handoff.recv_idle()? {
                Some(f) => f,
                None => continue, // idle coordinator between epochs
            },
        };
        match f.kind {
            FrameKind::Start => {
                let resume = f.boundary;
                handoff.begin_epoch(f.epoch)?;
                if resume > 0 {
                    handoff.wait_restore(resume)?;
                }
                run_cells(
                    kernel,
                    &handoff,
                    &bufs,
                    shard,
                    &deps,
                    &cells,
                    &waits,
                    resume as usize,
                    &mut scratch,
                )
                .map_err(|e| WireError::Protocol(e.0))?;
            }
            // Stale or early Data frames between epochs route through the
            // epoch completion table (stale → dropped, future → pended).
            FrameKind::Data => handoff.apply(f)?,
            FrameKind::Bye => return Ok(()),
            k => {
                return Err(WireError::Protocol(format!(
                    "expected Start/Data/Bye between epochs, got {k:?}"
                )))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lut::tables::compile_network;
    use crate::nn::config;
    use crate::prop_assert;
    use crate::sim::plan::{EvalPlan, Scratch};
    use crate::sim::shard::ShardedModel;
    use crate::util::prop::{self, Outcome};
    use crate::util::rng::Rng;

    fn random_frame(rng: &mut Rng) -> Frame {
        let kinds = [
            FrameKind::Hello,
            FrameKind::HelloAck,
            FrameKind::Start,
            FrameKind::Data,
            FrameKind::Bye,
            FrameKind::Fault,
        ];
        let boundary = rng.below(9) as u32;
        Frame {
            kind: kinds[rng.below(kinds.len())],
            parity: (boundary % 2) as u8,
            session: rng.below(100) as u16,
            epoch: rng.next_u64(),
            boundary,
            shard: rng.below(17) as u32,
            start: rng.below(1 << 20) as u32,
            // Ragged widths incl. the empty payload.
            words: (0..rng.below(70)).map(|_| rng.next_u64()).collect(),
        }
    }

    /// Round-trip property over random `(epoch, boundary, shard, range)` ×
    /// ragged plane widths: encode → read_frame == original, and the
    /// length prefix always matches the byte count.
    #[test]
    fn prop_frame_roundtrip() {
        prop::check("frame codec roundtrip", 200, |g| {
            let f = random_frame(&mut g.rng);
            let bytes = encode_frame(&f).expect("encode");
            prop_assert!(
                bytes.len() == 4 + HEADER_LEN + 8 * f.words.len(),
                "wire size accounting"
            );
            let declared = u32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]);
            prop_assert!(
                declared as usize == bytes.len() - 4,
                "length prefix covers the body"
            );
            let mut cursor = &bytes[..];
            let back = read_frame(&mut cursor).expect("decode");
            prop_assert!(back == f, "roundtrip mismatch: {back:?} vs {f:?}");
            prop_assert!(cursor.is_empty(), "decode must consume the frame exactly");
            Outcome::Pass
        });
    }

    /// Every corruption class decodes to a clean error, never a panic:
    /// truncated header, truncated payload, bad magic, flipped payload bit
    /// (checksum), flipped header bit, oversized length prefix, length
    /// prefix disagreeing with the word count.
    #[test]
    fn corrupted_frames_are_clean_errors() {
        let f = Frame::data(7, 3, 1, 10, vec![0xDEAD_BEEF, 42, 0]);
        let good = encode_frame(&f).unwrap();

        // Truncated: every proper prefix fails cleanly.
        for cut in 0..good.len() {
            let mut cursor = &good[..cut];
            assert!(read_frame(&mut cursor).is_err(), "prefix of {cut} bytes must fail");
        }

        // Bad magic.
        let mut bad = good.clone();
        bad[4] ^= 0xFF;
        assert!(matches!(
            read_frame(&mut &bad[..]),
            Err(WireError::BadMagic(_))
        ));

        // Unknown kind byte (checksum is checked after structure, so force
        // kind corruption to surface as BadKind by fixing nothing else —
        // decode checks kind before the checksum).
        let mut bad = good.clone();
        bad[8] = 250;
        assert!(matches!(read_frame(&mut &bad[..]), Err(WireError::BadKind(250))));

        // Flipped payload bit -> checksum.
        let mut bad = good.clone();
        let n = bad.len();
        bad[n - 1] ^= 0x01;
        assert!(matches!(
            read_frame(&mut &bad[..]),
            Err(WireError::BadChecksum { .. })
        ));

        // Flipped header field (epoch) -> checksum.
        let mut bad = good.clone();
        bad[12] ^= 0x10;
        assert!(matches!(
            read_frame(&mut &bad[..]),
            Err(WireError::BadChecksum { .. })
        ));

        // Oversized length prefix: rejected before any allocation.
        let mut bad = good.clone();
        bad[0..4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(
            read_frame(&mut &bad[..]),
            Err(WireError::Oversized { .. })
        ));

        // Oversized word count in the header.
        let mut bad = good.clone();
        bad[32..36].copy_from_slice(&((MAX_FRAME_WORDS + 1) as u32).to_le_bytes());
        assert!(matches!(
            read_frame(&mut &bad[..]),
            Err(WireError::Oversized { .. })
        ));

        // Length prefix vs word count disagreement.
        let mut bad = good.clone();
        bad[32..36].copy_from_slice(&2u32.to_le_bytes());
        assert!(matches!(
            read_frame(&mut &bad[..]),
            Err(WireError::BadLength { .. })
        ));

        // Oversized Frame refuses to encode.
        let huge = Frame {
            words: vec![0; MAX_FRAME_WORDS + 1],
            ..Frame::control(FrameKind::Bye, 0)
        };
        assert!(matches!(encode_frame(&huge), Err(WireError::Oversized { .. })));
    }

    #[test]
    fn fault_frame_roundtrips_message() {
        let f = fault_frame("boundary 3 exploded: äöü");
        let bytes = encode_frame(&f).unwrap();
        let back = read_frame(&mut &bytes[..]).unwrap();
        assert_eq!(fault_message(&back), "boundary 3 exploded: äöü");
    }

    #[test]
    fn parse_shard_hosts_cases() {
        assert_eq!(parse_shard_hosts("", 3).unwrap(), vec![None, None, None]);
        assert_eq!(
            parse_shard_hosts("local,127.0.0.1:7001", 3).unwrap(),
            vec![None, Some("127.0.0.1:7001".to_string()), None]
        );
        assert_eq!(
            parse_shard_hosts("-,h:1,", 3).unwrap(),
            vec![None, Some("h:1".to_string()), None]
        );
        assert!(parse_shard_hosts("a:1,b:2,c:3", 2).is_err(), "too many hosts");
        assert!(parse_shard_hosts("no-port", 2).is_err(), "not host:port");
        // Duplicate host:port entries are rejected at parse time with a
        // message naming both shards (previously accepted and failing
        // late, deep in the per-link handshake).
        let dup = parse_shard_hosts("h:1,h:1", 2).expect_err("duplicate host");
        let msg = format!("{dup:#}");
        assert!(msg.contains("duplicates") && msg.contains("shard 0"), "{msg}");
        assert!(parse_shard_hosts("a:1,local,a:1", 3).is_err(), "dup past local");
        // Distinct ports on one host are distinct workers — fine.
        assert!(parse_shard_hosts("h:1,h:2", 2).is_ok());
        // Trailing comma / trailing local entries are the documented no-op.
        assert_eq!(
            parse_shard_hosts("a:1,b:2,", 2).unwrap(),
            vec![Some("a:1".to_string()), Some("b:2".to_string())]
        );
        assert_eq!(
            parse_shard_hosts("a:1,local,local", 1).unwrap(),
            vec![Some("a:1".to_string())]
        );
    }

    const GRID: [(usize, u32); 6] = [(1, 1), (2, 1), (3, 1), (1, 2), (2, 2), (2, 3)];

    fn grid_net(a: usize, d: u32) -> (crate::nn::network::Network, NetworkTables) {
        let cfg = config::uniform("wire-t", &[8, 6, 3], 2, 2, 3, 3, 3, d, a, 3);
        let net =
            crate::nn::network::Network::random(&cfg, &mut Rng::new(a as u64 * 100 + d as u64));
        let tables = compile_network(&net, 1);
        (net, tables)
    }

    fn random_codes(net: &Network, n: usize, seed: u64) -> Vec<Vec<i32>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                let x: Vec<f32> = (0..net.cfg.widths[0]).map(|_| rng.f32()).collect();
                net.quantize_input(&x)
            })
            .collect()
    }

    fn spawn_host(net: &Network, tables: &NetworkTables, shards: usize) -> String {
        let host = Arc::new(ShardWorkerHost::compile(net, tables, shards, 1));
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
        let addr = listener.local_addr().expect("local addr").to_string();
        std::thread::spawn(move || host.serve(listener));
        addr
    }

    /// The PR 4 acceptance grid: mixed local/remote sharded execution over
    /// loopback TCP is bit-exact vs `Network::forward_codes` (via the
    /// pinned unsharded plan) over the (A, degree) grid with S ∈ {2, 3},
    /// on both the plan and bitslice routes, with ragged multi-word
    /// batches.  S = 3 drives two remote shards over two links into one
    /// worker host.
    #[test]
    fn mixed_local_remote_bit_exact_on_grid() {
        for (a, d) in GRID {
            let (net, tables) = grid_net(a, d);
            let plan = EvalPlan::compile(&net, &tables);
            let mut scratch = Scratch::for_plan(&plan);
            let xs = random_codes(&net, crate::sim::WORD + 9, 51);
            let want = plan.forward_batch(&xs, &mut scratch);
            for (i, (x, w)) in xs.iter().zip(&want).enumerate() {
                assert_eq!(w, &net.forward_codes(x), "A={a} D={d} sample {i}");
            }
            for shards in [2usize, 3] {
                let addr = spawn_host(&net, &tables, shards);
                // Shard 0 local; every other shard remote (same host).
                let placement: ShardPlacement = (0..shards)
                    .map(|s| (s > 0).then(|| addr.clone()))
                    .collect();
                let model =
                    ShardedModel::compile_placed(&net, &tables, shards, 1, &placement, None)
                        .expect("loopback placement");
                assert_eq!(model.spin_us(), resolve_spin_us_probe(), "remote => 0 spin");
                assert_eq!(
                    model.plan.forward_batch(&xs).unwrap(),
                    want,
                    "plan A={a} D={d} S={shards}"
                );
                assert_eq!(
                    model.bits.forward_batch(&xs).unwrap(),
                    want,
                    "bits A={a} D={d} S={shards}"
                );
                let ws = model.wire_stats().expect("remote links present");
                assert!(ws.frames > 0 && ws.bytes > 0, "wire counters move: {ws:?}");
                let st = model.stats();
                assert!(st.iter().all(|s| s.cells > 0), "every shard ran");
            }
        }
    }

    fn resolve_spin_us_probe() -> u64 {
        crate::sim::shard::resolve_spin_us(None, true)
    }

    /// Repeated epochs over one wired engine stay deterministic (per-epoch
    /// wire state resets cleanly).
    #[test]
    fn wired_epochs_are_deterministic() {
        let (net, tables) = grid_net(2, 2);
        let addr = spawn_host(&net, &tables, 2);
        let placement: ShardPlacement = vec![None, Some(addr)];
        let model = ShardedModel::compile_placed(&net, &tables, 2, 1, &placement, None)
            .expect("loopback placement");
        let xs = random_codes(&net, 6, 77);
        let first: Vec<Vec<i32>> =
            xs.iter().map(|x| model.plan.forward_codes(x).unwrap()).collect();
        let second: Vec<Vec<i32>> =
            xs.iter().rev().map(|x| model.plan.forward_codes(x).unwrap()).collect();
        for (a, b) in first.iter().zip(second.iter().rev()) {
            assert_eq!(a, b);
        }
    }

    /// A worker hosting different weights (or shard count) must be refused
    /// at handshake time with a clean error naming the fingerprint.
    #[test]
    fn handshake_rejects_mismatched_model() {
        let (net, tables) = grid_net(2, 1);
        let cfg = config::uniform("wire-t", &[8, 6, 3], 2, 2, 3, 3, 3, 1, 2, 3);
        let other = Network::random(&cfg, &mut Rng::new(4242));
        let otables = compile_network(&other, 1);
        let addr = spawn_host(&other, &otables, 2);
        let placement: ShardPlacement = vec![None, Some(addr.clone())];
        let err = ShardedModel::compile_placed(&net, &tables, 2, 1, &placement, None)
            .expect_err("mismatched weights must fail the handshake");
        let msg = format!("{err:#}");
        assert!(msg.contains("fingerprint"), "error names the cause: {msg}");

        // Shard-count mismatch: worker partitioned for 2, coordinator for 3.
        let (net3, tables3) = grid_net(2, 1);
        let addr3 = spawn_host(&net3, &tables3, 2);
        let placement3: ShardPlacement = vec![None, Some(addr3), None];
        let err3 = ShardedModel::compile_placed(&net3, &tables3, 3, 1, &placement3, None)
            .expect_err("shard-count mismatch must fail the handshake");
        let msg3 = format!("{err3:#}");
        assert!(
            msg3.contains("fingerprint") || msg3.contains("shard count"),
            "error names the cause: {msg3}"
        );
    }

    /// Unreachable worker: compile_placed returns a clean error (after its
    /// connect retries), not a hang or panic.
    #[test]
    fn unreachable_worker_is_clean_error() {
        let (net, tables) = grid_net(1, 1);
        // Reserve a port and close it again: nothing listens there.
        let dead = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let placement: ShardPlacement = vec![None, Some(dead)];
        let err = ShardedModel::compile_placed(&net, &tables, 2, 1, &placement, None)
            .expect_err("dead address must fail");
        assert!(format!("{err:#}").contains("shard 1"), "error names the shard");
    }

    /// wire_plan invariants on a real kernel: needs cover exactly the
    /// cross-shard reads, results are the shard's write ranges, worker
    /// deps reference only producers (plus the coordinator for boundary 0).
    #[test]
    fn wire_plan_covers_cross_shard_reads() {
        let (net, tables) = grid_net(2, 1);
        let (pnet, ptables) = crate::sim::shard::permuted_for_shards(&net, &tables);
        let kernel = plan_kernel_of(&pnet, &ptables, 2);
        for s in 0..2 {
            let wp = wire_plan(&kernel, s);
            for l in 0..kernel.n_layers() {
                assert_eq!(wp.result[l], kernel.write_range(l, s));
                let own: Range<usize> =
                    if l >= 1 { kernel.write_range(l - 1, s) } else { 0..0 };
                let mut shipped: Vec<usize> = wp.needs[l]
                    .iter()
                    .flat_map(|(_, r)| r.clone())
                    .collect();
                shipped.sort_unstable();
                let expect: Vec<usize> = kernel
                    .reads(l, s)
                    .iter()
                    .copied()
                    .filter(|x| l == 0 || !own.contains(x))
                    .collect();
                // Runs may cover extra positions only if contiguous merging
                // added nothing: in fact runs are built from the read list
                // alone, so the sets match exactly.
                assert_eq!(shipped, expect, "layer {l} shard {s}");
                for &(q, thr) in &wp.deps[l] {
                    if l == 0 {
                        assert_eq!((q, thr), (2, 1), "boundary 0 waits on the coordinator");
                    } else {
                        assert!(q < 2 && thr == l as u32, "producer wait (q={q}, thr={thr})");
                    }
                }
            }
        }
    }

    // -- patient (progress-aware) reads ------------------------------------

    /// A frame trickling in with per-chunk gaps *longer than the read
    /// timeout* must still decode: each timeout window with zero progress
    /// is one strike, progress resets the count, and the gaps stay under
    /// `LIVENESS_STRIKES` windows — the epoch-aware fix for slow wide
    /// frames being misclassified as half-open peers.
    #[test]
    fn patient_read_survives_slow_wide_frame() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let writer = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let bytes = encode_frame(&Frame::data(3, 2, 1, 0, vec![7; 40])).unwrap();
            let chunk = bytes.len() / 3 + 1;
            for part in bytes.chunks(chunk) {
                s.write_all(part).unwrap();
                s.flush().unwrap();
                // Longer than one read-timeout window, well under two.
                std::thread::sleep(Duration::from_millis(300));
            }
            s
        });
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_millis(250))).unwrap();
        let f = read_frame_patient(&mut stream, false).expect("slow frame decodes");
        let f = f.expect("idle_ok=false never yields None");
        assert_eq!(f.words, vec![7; 40]);
        drop(writer.join().unwrap());
    }

    /// A peer that goes completely silent mid-frame is still declared dead
    /// after the strike budget (half-open links cannot pin a session), and
    /// an idle probe (`idle_ok`) returns cleanly without striking.
    #[test]
    fn patient_read_still_bounds_dead_peers() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let holder = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            // Half a length prefix, then silence (socket held open).
            s.write_all(&[9u8, 0]).unwrap();
            s.flush().unwrap();
            std::thread::sleep(Duration::from_millis(500));
            s
        });
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_millis(30))).unwrap();
        let t0 = Instant::now();
        assert!(
            read_frame_patient(&mut stream, false).is_err(),
            "silent mid-frame peer must fail"
        );
        assert!(t0.elapsed() < Duration::from_millis(400), "bounded, not hung");
        drop(holder.join().unwrap());

        // Idle probe: a quiet (but alive) socket is Ok(None), not an error.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let quiet = std::thread::spawn(move || listener.accept().unwrap().0);
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_millis(20))).unwrap();
        assert!(matches!(read_frame_patient(&mut stream, true), Ok(None)));
        drop(quiet.join().unwrap());
    }

    // -- wire_plan run compression ------------------------------------------

    /// Synthetic kernel with hand-written position-space read/write sets —
    /// lets the run-compression edge cases be stated exactly.
    struct TestKernel {
        bounds: Vec<usize>,
        write: Vec<Vec<Range<usize>>>,
        reads: Vec<Vec<Vec<usize>>>,
    }

    impl crate::sim::shard::ShardKernel for TestKernel {
        type Scratch = ();

        fn n_layers(&self) -> usize {
            self.write.len()
        }

        fn n_shards(&self) -> usize {
            self.write[0].len()
        }

        fn in_len(&self) -> usize {
            self.bounds[0]
        }

        fn out_len(&self) -> usize {
            *self.bounds.last().unwrap()
        }

        fn buf_len(&self) -> usize {
            self.bounds[1..self.bounds.len() - 1].iter().copied().max().unwrap_or(0)
        }

        fn deps(&self, _l: usize, _s: usize) -> &[(u32, u32)] {
            &[]
        }

        fn reads(&self, l: usize, s: usize) -> &[usize] {
            &self.reads[l][s]
        }

        fn write_range(&self, l: usize, s: usize) -> Range<usize> {
            self.write[l][s].clone()
        }

        fn make_scratch(&self) -> Self::Scratch {}

        fn run_cell(
            &self,
            _l: usize,
            _s: usize,
            _src: &[std::sync::atomic::AtomicU64],
            _dst: &[std::sync::atomic::AtomicU64],
            _scratch: &mut Self::Scratch,
        ) {
        }
    }

    /// Run-compression edge cases: adjacent single positions owned by
    /// *different* producers stay separate single-position runs; adjacent
    /// same-producer positions merge into one run; a shard with zero
    /// cross-shard reads ships nothing and waits on nothing.
    #[test]
    fn wire_plan_run_compression_edge_cases() {
        let k = TestKernel {
            bounds: vec![4, 6, 6],
            // Boundary 1 owners: s0 = 0..2, s1 = 2..4, s2 = 4..6.
            write: vec![vec![0..2, 2..4, 4..6], vec![0..2, 2..4, 4..6]],
            reads: vec![
                // Layer 0 (boundary 0 = coordinator): s0 reads nothing at
                // all, s1 reads adjacent 1,2 (one merged run from the
                // coordinator), s2 reads 0 and 2 (two runs, gap between).
                vec![vec![], vec![1, 2], vec![0, 2]],
                // Layer 1 (boundary 1): s0 reads only its own range (zero
                // cross-shard needs); s1 reads 1 and 4 (two producers);
                // s2 reads adjacent 1,2 — position 1 owned by s0 and
                // position 2 by s1, so the adjacency must NOT merge.
                vec![vec![0, 1], vec![1, 4], vec![1, 2]],
            ],
        };
        // Shard 0: no needs at either layer, no deps at all.
        let wp0 = wire_plan(&k, 0);
        assert!(wp0.needs[0].is_empty() && wp0.needs[1].is_empty(), "zero cross-shard reads");
        assert!(wp0.deps[0].is_empty() && wp0.deps[1].is_empty());
        assert!(wp0.counts[0].is_empty() && wp0.counts[1].is_empty());
        assert_eq!(wp0.result, vec![0..2, 0..2]);

        // Shard 1: one merged coordinator run at layer 0; at layer 1 its
        // own position 2..4 read (none listed) — reads 1 (s0) and 4 (s2).
        let wp1 = wire_plan(&k, 1);
        assert_eq!(wp1.needs[0], vec![(3, 1..3)], "adjacent same-producer positions merge");
        assert_eq!(wp1.needs[1], vec![(0, 1..2), (2, 4..5)]);
        assert_eq!(wp1.deps[0], vec![(3, 1)], "coordinator wait");
        assert_eq!(wp1.deps[1], vec![(0, 1), (2, 1)], "producer waits at threshold l");
        assert_eq!(wp1.counts[1], vec![(0, 1), (2, 1)]);

        // Shard 2: two gap-separated runs at layer 0; at layer 1 the
        // adjacent pair 1,2 splits into two single-position runs because
        // the producers differ.
        let wp2 = wire_plan(&k, 2);
        assert_eq!(wp2.needs[0], vec![(3, 0..1), (3, 2..3)], "gap keeps runs apart");
        assert_eq!(wp2.counts[0], vec![(3, 2)], "two frames from the coordinator");
        assert_eq!(
            wp2.needs[1],
            vec![(0, 1..2), (1, 2..3)],
            "adjacent positions with distinct producers must not merge"
        );
    }

    /// The PR 3 widest-boundary-skips-parity shape (non-monotonic bounds
    /// `[8, 8, 11, 2, 9]`): wire_plan's needs must still cover exactly the
    /// cross-shard reads and its results the write ranges, including reads
    /// at positions wider than every later boundary.
    #[test]
    fn wire_plan_on_skips_parity_bounds() {
        let bounds = vec![8usize, 8, 11, 2, 9];
        let shards = 3usize;
        let write: Vec<Vec<Range<usize>>> = (0..4)
            .map(|l| {
                let n = bounds[l + 1];
                let cut1 = n / 3;
                let cut2 = 2 * n / 3;
                vec![0..cut1, cut1..cut2, cut2..n]
            })
            .collect();
        // Every shard reads a spread of the previous boundary, including
        // its widest positions.
        let reads: Vec<Vec<Vec<usize>>> = (0..4)
            .map(|l| {
                (0..shards)
                    .map(|s| {
                        let w = bounds[l];
                        let mut v = vec![0, w / 2, w - 1, (s * 3) % w];
                        v.sort_unstable();
                        v.dedup();
                        v
                    })
                    .collect()
            })
            .collect();
        let k = TestKernel { bounds, write: write.clone(), reads: reads.clone() };
        for s in 0..shards {
            let wp = wire_plan(&k, s);
            for l in 0..4 {
                assert_eq!(wp.result[l], write[l][s], "layer {l} shard {s}");
                let own: Range<usize> = if l >= 1 { write[l - 1][s].clone() } else { 0..0 };
                let mut shipped: Vec<usize> =
                    wp.needs[l].iter().flat_map(|(_, r)| r.clone()).collect();
                shipped.sort_unstable();
                let expect: Vec<usize> = reads[l][s]
                    .iter()
                    .copied()
                    .filter(|x| l == 0 || !own.contains(x))
                    .collect();
                assert_eq!(shipped, expect, "layer {l} shard {s}");
                let runs_per_producer: usize = wp.counts[l].iter().map(|(_, n)| *n as usize).sum();
                assert_eq!(runs_per_producer, wp.needs[l].len(), "counts match runs");
            }
        }
    }

    // -- windowed stream vs lock-step, and reconnect-and-resume -------------

    /// Both pacings are bit-exact over loopback on a deep geometry whose
    /// boundary widths are non-monotonic (the PR 3 skips-parity shape),
    /// S ∈ {2, 3}: W=1 reproduces the v1 lock-step conversation (pinned by
    /// the in-flight high-water mark), W>1 streams ahead.
    #[test]
    fn windowed_and_lockstep_loopback_bit_exact() {
        let cfg = config::uniform("wire-deep", &[8, 10, 8, 6, 3], 2, 2, 3, 3, 3, 1, 2, 3);
        let net = Network::random(&cfg, &mut Rng::new(0x51EE));
        let tables = compile_network(&net, 1);
        let plan = EvalPlan::compile(&net, &tables);
        let mut scratch = Scratch::for_plan(&plan);
        let xs = random_codes(&net, crate::sim::WORD + 5, 23);
        let want = plan.forward_batch(&xs, &mut scratch);
        for shards in [2usize, 3] {
            let addr = spawn_host(&net, &tables, shards);
            for window in [1usize, 4, 16] {
                let placement: ShardPlacement =
                    (0..shards).map(|s| (s > 0).then(|| addr.clone())).collect();
                let wire = WireConfig { window, retries: 3, mux: true };
                let model = ShardedModel::compile_placed_wire(
                    &net, &tables, shards, 1, &placement, None, wire,
                )
                .expect("loopback placement");
                assert_eq!(
                    model.plan.forward_batch(&xs).unwrap(),
                    want,
                    "plan S={shards} W={window}"
                );
                assert_eq!(
                    model.bits.forward_batch(&xs).unwrap(),
                    want,
                    "bits S={shards} W={window}"
                );
                let ws = model.wire_stats().expect("remote links present");
                assert!(
                    ws.inflight_hwm <= window as u64,
                    "window must bound the in-flight flights: {ws:?} (W={window})"
                );
                if window == 1 {
                    assert_eq!(ws.inflight_hwm, 1, "W=1 is lock-step: {ws:?}");
                }
                assert_eq!(ws.retry_exhausted, 0, "{ws:?}");
            }
        }
    }

    /// TCP proxy used to inject deterministic link failures: forwards every
    /// accepted connection to `upstream`; the *first* connection is severed
    /// once `kill_after` client→upstream bytes have passed, and `max_conns`
    /// (when set) bounds how many connections are accepted before the
    /// listener drops (so later dials see connection-refused).
    fn flaky_proxy(upstream: String, kill_after: usize, max_conns: Option<usize>) -> String {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind proxy");
        let addr = listener.local_addr().expect("proxy addr").to_string();
        std::thread::spawn(move || {
            for idx in 0usize.. {
                if let Some(m) = max_conns {
                    if idx >= m {
                        break;
                    }
                }
                let (client, _) = match listener.accept() {
                    Ok(c) => c,
                    Err(_) => break,
                };
                let up = match TcpStream::connect(&upstream) {
                    Ok(u) => u,
                    Err(_) => break,
                };
                let kill = if idx == 0 { Some(kill_after) } else { None };
                let (mut c_in, mut u_out) = (
                    client.try_clone().expect("clone client"),
                    up.try_clone().expect("clone upstream"),
                );
                std::thread::spawn(move || {
                    let mut total = 0usize;
                    let mut buf = [0u8; 1024];
                    loop {
                        let n = match c_in.read(&mut buf) {
                            Ok(0) | Err(_) => break,
                            Ok(n) => n,
                        };
                        if u_out.write_all(&buf[..n]).is_err() {
                            break;
                        }
                        total += n;
                        if kill.is_some_and(|k| total >= k) {
                            break;
                        }
                    }
                    let _ = c_in.shutdown(Shutdown::Both);
                    let _ = u_out.shutdown(Shutdown::Both);
                });
                let (mut u_in, mut c_out) = (up, client);
                std::thread::spawn(move || {
                    let mut buf = [0u8; 1024];
                    loop {
                        let n = match u_in.read(&mut buf) {
                            Ok(0) | Err(_) => break,
                            Ok(n) => n,
                        };
                        if c_out.write_all(&buf[..n]).is_err() {
                            break;
                        }
                    }
                    let _ = u_in.shutdown(Shutdown::Both);
                    let _ = c_out.shutdown(Shutdown::Both);
                });
            }
        });
        addr
    }

    /// Mid-stream link cut → reconnect-and-resume: the proxy severs the
    /// plan engine's link a few hundred bytes in (mid-epoch or at an epoch
    /// boundary, whichever the cut lands on); the link must re-handshake
    /// through the proxy, replay the open epoch, and keep every output
    /// bit-exact — `wire_resumes` counted, no sticky fault, zero degraded
    /// batches.
    #[test]
    fn midstream_cut_reconnects_and_resumes() {
        let (net, tables) = grid_net(2, 1);
        let upstream = spawn_host(&net, &tables, 2);
        let proxy = flaky_proxy(upstream, 300, None);
        let placement: ShardPlacement = vec![None, Some(proxy)];
        let wire = WireConfig { window: 4, retries: 8, mux: true };
        let model =
            ShardedModel::compile_placed_wire(&net, &tables, 2, 1, &placement, None, wire)
                .expect("placement through proxy");
        let xs = random_codes(&net, 24, 99);
        for (i, x) in xs.iter().enumerate() {
            assert_eq!(
                model.plan.forward_codes(x).expect("resume keeps serving"),
                net.forward_codes(x),
                "sample {i} must stay bit-exact across the cut"
            );
        }
        let ws = model.wire_stats().expect("remote link present");
        assert!(ws.resumes >= 1, "the severed link must resume: {ws:?}");
        assert_eq!(ws.retry_exhausted, 0, "{ws:?}");
        assert!(!model.faulted(), "no degraded batches");
        // Pin the cached-handle fix: exactly one socket handle is installed
        // per host-link generation — one initial connect (the multiplexed
        // host link carries both engines' sessions) plus one per resume —
        // never one per flight/frame, and never one per session.
        assert_eq!(
            ws.handle_clones,
            1 + ws.resumes,
            "one cached handle per host-link generation: {ws:?}"
        );
        assert!(
            ws.frames > ws.handle_clones,
            "frame traffic must dwarf handle installs: {ws:?}"
        );
    }

    /// Exhausted retry budget → clean sticky fault (never a hang): the
    /// proxy kills the first link and then refuses further connections, so
    /// the bounded reconnect budget runs dry, the engine faults, and every
    /// later call errors fast (`Backend::route` degrade is pinned by the
    /// coordinator tests).
    #[test]
    fn retry_exhaustion_is_clean_sticky_fault() {
        let (net, tables) = grid_net(1, 1);
        let upstream = spawn_host(&net, &tables, 2);
        // One conn = the multiplexed host link (both engines' sessions
        // share it); nothing after.
        let proxy = flaky_proxy(upstream, 250, Some(1));
        let placement: ShardPlacement = vec![None, Some(proxy)];
        let wire = WireConfig { window: 4, retries: 2, mux: true };
        let model =
            ShardedModel::compile_placed_wire(&net, &tables, 2, 1, &placement, None, wire)
                .expect("placement through proxy");
        let xs = random_codes(&net, 40, 5);
        let mut failed = false;
        for x in &xs {
            if model.plan.forward_codes(x).is_err() {
                failed = true;
                break;
            }
        }
        assert!(failed, "a severed link with no reconnect path must fault");
        assert!(model.faulted());
        assert!(model.plan.forward_codes(&xs[0]).is_err(), "fault is sticky");
        let ws = model.wire_stats().expect("remote link present");
        assert!(ws.retry_exhausted >= 1, "{ws:?}");
    }

    /// Tentpole pin: W-deep epoch pipelining is bit-exact under
    /// concurrently streamed single-sample requests, the epoch-ring
    /// concurrency high-water mark actually exceeds 1 for W > 1 (epochs
    /// overlap end to end) while W = 1 stays strictly lock-step, and one
    /// multiplexed TCP connection per host carries every (engine, shard)
    /// session.
    #[test]
    fn interleaved_epochs_are_bit_exact_and_overlap() {
        for shards in [2usize, 3] {
            let (net, tables) = grid_net(2, 2);
            let addr = spawn_host(&net, &tables, shards);
            for window in [1usize, 2, 8] {
                let placement: ShardPlacement =
                    (0..shards).map(|s| (s > 0).then(|| addr.clone())).collect();
                let wire = WireConfig { window, retries: 3, mux: true };
                let model = ShardedModel::compile_placed_wire(
                    &net, &tables, shards, 1, &placement, None, wire,
                )
                .expect("loopback placement");
                // Several streaming clients, each firing single-sample
                // requests back to back: the admission ring must overlap
                // their epochs rather than drain the pipe between samples.
                let streams = 4usize;
                let xs = random_codes(&net, streams * 16, 0xA11CE ^ window as u64);
                std::thread::scope(|scope| {
                    for t in 0..streams {
                        let (model, xs, net) = (&model, &xs, &net);
                        scope.spawn(move || {
                            let mut i = t;
                            while i < xs.len() {
                                assert_eq!(
                                    model
                                        .plan
                                        .forward_codes(&xs[i])
                                        .expect("pipelined serve"),
                                    net.forward_codes(&xs[i]),
                                    "S={shards} W={window} sample {i}"
                                );
                                i += streams;
                            }
                        });
                    }
                });
                let ws = model.wire_stats().expect("remote links present");
                if window == 1 {
                    assert_eq!(ws.inflight_epochs, 1, "W=1 is lock-step: {ws:?}");
                } else {
                    assert!(
                        ws.inflight_epochs > 1,
                        "W={window} must overlap epochs: {ws:?}"
                    );
                }
                assert!(
                    ws.inflight_epochs <= window as u64,
                    "ring depth bounds the overlap: {ws:?} (W={window})"
                );
                assert_eq!(ws.retry_exhausted, 0, "{ws:?}");
                // Link multiplexing: every session to this host — all
                // remote shards, both engines — rides one connection.
                assert_eq!(model.wire_links(), 1, "one host => one TCP link");
                let hosts = model.wire_host_stats();
                assert_eq!(hosts.len(), 1, "{hosts:?}");
                assert_eq!(
                    hosts[0].sessions as usize,
                    2 * (shards - 1),
                    "plan+bitslice sessions share the link: {hosts:?}"
                );
            }
        }
    }

    /// TCP proxy that severs the *worker → coordinator* direction after
    /// forwarding exactly `cut_after` length-prefixed frames on the first
    /// connection.  The cut is frame-aligned (never mid-frame) and held
    /// for a beat before the sockets die, so the coordinator definitively
    /// applies the last forwarded result — pinning the applied-boundary
    /// high-water mark the resume must honor.  Later connections forward
    /// untouched.
    fn frame_cut_proxy(upstream: String, cut_after: usize) -> String {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind proxy");
        let addr = listener.local_addr().expect("proxy addr").to_string();
        std::thread::spawn(move || {
            for idx in 0usize.. {
                let (client, _) = match listener.accept() {
                    Ok(c) => c,
                    Err(_) => break,
                };
                let up = match TcpStream::connect(&upstream) {
                    Ok(u) => u,
                    Err(_) => break,
                };
                let (mut c_in, mut u_out) = (
                    client.try_clone().expect("clone client"),
                    up.try_clone().expect("clone upstream"),
                );
                std::thread::spawn(move || {
                    let mut buf = [0u8; 1024];
                    loop {
                        let n = match c_in.read(&mut buf) {
                            Ok(0) | Err(_) => break,
                            Ok(n) => n,
                        };
                        if u_out.write_all(&buf[..n]).is_err() {
                            break;
                        }
                    }
                    let _ = c_in.shutdown(Shutdown::Both);
                    let _ = u_out.shutdown(Shutdown::Both);
                });
                let cut = (idx == 0).then_some(cut_after);
                let (mut u_in, mut c_out) = (up, client);
                std::thread::spawn(move || {
                    let mut forwarded = 0usize;
                    loop {
                        let mut len = [0u8; 4];
                        if u_in.read_exact(&mut len).is_err() {
                            break;
                        }
                        let n = u32::from_le_bytes(len) as usize;
                        let mut body = vec![0u8; n];
                        if u_in.read_exact(&mut body).is_err() {
                            break;
                        }
                        if c_out.write_all(&len).is_err()
                            || c_out.write_all(&body).is_err()
                        {
                            break;
                        }
                        forwarded += 1;
                        if cut.is_some_and(|k| forwarded >= k) {
                            // Let the coordinator apply what it got, then die.
                            std::thread::sleep(Duration::from_millis(150));
                            break;
                        }
                    }
                    let _ = u_in.shutdown(Shutdown::Both);
                    let _ = c_out.shutdown(Shutdown::Both);
                });
            }
        });
        addr
    }

    /// Checkpointed suffix resume (v3): sever the worker→coordinator
    /// direction right after an epoch's boundary-1 result.  The
    /// coordinator applies it before the link dies (applied high-water
    /// mark = 1), so recovery must replay only the *unapplied suffix* of
    /// the open epoch — its Start re-aimed at boundary 1, the checkpoint
    /// frame, and any needs flights at or above the mark — while the
    /// already-applied boundary's needs frames are trimmed from the
    /// replay set, pinned here on the frame counters.
    #[test]
    fn resume_replays_only_unapplied_suffix() {
        let (net, tables) = grid_net(2, 1);
        let upstream = spawn_host(&net, &tables, 2);
        // Worker→coordinator frames on the multiplexed link, in order: 2
        // HelloAcks (plan + bitslice sessions greet at compile time),
        // then per plan epoch its boundary-1 and boundary-2 results.
        // Forwarding 7 frames cuts right after epoch 3's boundary-1
        // result, leaving epoch 3 open at applied = 1.
        let proxy = frame_cut_proxy(upstream, 7);
        let placement: ShardPlacement = vec![None, Some(proxy)];
        let wire = WireConfig { window: 4, retries: 8, mux: true };
        let model =
            ShardedModel::compile_placed_wire(&net, &tables, 2, 1, &placement, None, wire)
                .expect("placement through proxy");
        let xs = random_codes(&net, 8, 0xC0DE);
        // Single-threaded stream: epochs run strictly one at a time, so
        // the worker's result-frame sequence (and thus where the cut
        // lands) is fully deterministic.
        for (i, x) in xs.iter().enumerate() {
            assert_eq!(
                model.plan.forward_codes(x).expect("suffix resume keeps serving"),
                net.forward_codes(x),
                "sample {i} must stay bit-exact across the cut"
            );
        }
        let ws = model.wire_stats().expect("remote link present");
        assert_eq!(ws.resumes, 1, "exactly one recovery ladder: {ws:?}");
        assert!(
            ws.resume_replayed_frames >= 2,
            "the re-aimed Start and the checkpoint frame must replay: {ws:?}"
        );
        assert!(
            ws.resume_skipped_frames >= 1,
            "the applied boundary's needs flights must be trimmed, not replayed: {ws:?}"
        );
        assert_eq!(ws.retry_exhausted, 0, "{ws:?}");
        assert!(!model.faulted(), "no sticky fault");
    }
}
