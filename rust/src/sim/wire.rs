//! Wire transport for the shard handoff — bit-planes over a socket.
//!
//! `sim::shard` publishes layer boundaries as contiguous `u64` words
//! (bit-planes for the bitslice kernel, code slots for the plan kernel).
//! That boundary format is already wire-friendly: the cut between layers is
//! narrow even when the layers are wide (the PolyLUT/NeuraLUT observation
//! that quantized layer boundaries are cheap interfaces), so one sample's
//! forward pass can span hosts.  This module supplies everything the shard
//! runner needs to cross a TCP link instead of a cache line:
//!
//! - a **length-prefixed frame codec** ([`Frame`], [`read_frame`] /
//!   [`write_frame`]): versioned magic, `(epoch, boundary, shard,
//!   plane-range, generation parity)` header, FNV-1a checksum, raw `u64`
//!   payload words.  Corrupted input of any kind decodes to a clean
//!   [`WireError`], never a panic.
//! - the **coordinator side**: `RemoteLink` (connect + handshake + framed
//!   send/recv with per-link [`WireStats`]) used by the shard runner's
//!   proxy threads, and [`parse_shard_hosts`] for the
//!   `--shard-hosts` placement map.
//! - the **worker side**: [`ShardWorkerHost`] (the `polylut shard-worker`
//!   process body) and `RemoteHandoff`, the `sim::shard::Handoff`
//!   implementation that maps the per-cell `(shard, threshold)` dependency
//!   waits onto frame arrival — a producer's level advances exactly when
//!   all of its expected frames for a boundary have been applied to the
//!   worker's private buffers.
//!
//! The per-epoch conversation on one link (one engine × one shard) is
//! strictly alternating — `Start`, then per layer: needs frames from the
//! coordinator, one result frame back — so frame application order is
//! total (TCP) and the worker needs no overwrite-hazard machinery of its
//! own; the coordinator proxy replays the full hazard schedule before
//! touching the shared buffers.  See `ARCHITECTURE.md` §7 for the frame
//! layout diagram and the failure-behavior contract.

use std::io::{Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::ops::Range;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::lut::tables::NetworkTables;
use crate::nn::network::Network;
use crate::sim::shard::{
    bits_kernel_of, permuted_for_shards, plan_kernel_of, run_cells, shard_fingerprint,
    BitsliceKernel, BufSet, Handoff, HandoffError, PlanKernel, ShardKernel,
};

// ---------------------------------------------------------------------------
// FNV-1a (checksums + model fingerprints)
// ---------------------------------------------------------------------------

/// Incremental FNV-1a 64-bit hasher (checksums, model fingerprints).
pub(crate) struct Fnv(u64);

impl Fnv {
    pub(crate) fn new() -> Fnv {
        Fnv(0xcbf2_9ce4_8422_2325)
    }

    pub(crate) fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100_0000_01b3);
        }
    }

    pub(crate) fn write_u64(&mut self, v: u64) {
        self.write(&v.to_le_bytes());
    }

    pub(crate) fn finish(&self) -> u64 {
        self.0
    }
}

// ---------------------------------------------------------------------------
// Frame codec
// ---------------------------------------------------------------------------

/// Versioned frame magic: ASCII `PLW1`.  A major protocol change bumps the
/// trailing digit, so mismatched builds fail the handshake with
/// [`WireError::BadMagic`] instead of misparsing frames.
pub const MAGIC: u32 = u32::from_le_bytes(*b"PLW1");

/// Header bytes after the `u32` length prefix.
const HEADER_LEN: usize = 40;

/// Upper bound on payload words per frame (64 MiB) — a corrupt or hostile
/// length field must not trigger an allocation-sized-by-attacker.
pub const MAX_FRAME_WORDS: usize = 1 << 23;

/// Frame type tag (one byte on the wire).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameKind {
    /// Connection opener (coordinator → worker): payload
    /// `[engine, shards, fingerprint]`, `shard` field = claimed shard.
    Hello,
    /// Handshake accept (worker → coordinator): payload `[fingerprint]`.
    HelloAck,
    /// Epoch begin (coordinator → worker).
    Start,
    /// Boundary words: `start..start+words.len()` of boundary `boundary`,
    /// produced by `shard` (`shard == shards` encodes the coordinator's
    /// input staging).
    Data,
    /// Clean shutdown of the link.
    Bye,
    /// Terminal error; payload carries a UTF-8 message (byte length in
    /// `start`).
    Fault,
}

impl FrameKind {
    fn from_u8(v: u8) -> Option<FrameKind> {
        Some(match v {
            0 => FrameKind::Hello,
            1 => FrameKind::HelloAck,
            2 => FrameKind::Start,
            3 => FrameKind::Data,
            4 => FrameKind::Bye,
            5 => FrameKind::Fault,
            _ => return None,
        })
    }
}

/// One decoded wire frame.  On the wire it is a `u32` length prefix
/// followed by `HEADER_LEN` header bytes and `8·words.len()` payload bytes;
/// see `ARCHITECTURE.md` §7 for the byte-level diagram.
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    /// Frame type.
    pub kind: FrameKind,
    /// Generation parity of the boundary (`boundary % 2`) — redundant with
    /// `boundary`, carried so a receiver can cheaply assert which of the
    /// two parity buffers the payload belongs to.
    pub parity: u8,
    /// Epoch (sample / word sequence number) the frame belongs to.
    pub epoch: u64,
    /// Boundary index (0 = network input, L = network output).
    pub boundary: u32,
    /// Producing shard (`shards` = coordinator input staging).
    pub shard: u32,
    /// First boundary position (word index) of the payload range.
    pub start: u32,
    /// Payload: raw boundary words (bit-planes / code slots).
    pub words: Vec<u64>,
}

impl Frame {
    /// A `Data` frame for `words` at positions `start..` of `boundary`.
    pub fn data(epoch: u64, boundary: u32, shard: u32, start: u32, words: Vec<u64>) -> Frame {
        Frame {
            kind: FrameKind::Data,
            parity: (boundary % 2) as u8,
            epoch,
            boundary,
            shard,
            start,
            words,
        }
    }

    fn control(kind: FrameKind, epoch: u64) -> Frame {
        Frame { kind, parity: 0, epoch, boundary: 0, shard: 0, start: 0, words: Vec::new() }
    }
}

/// Decode/transport failure of the wire protocol.  Every variant is a clean
/// error — corrupted or truncated input can never panic the process.
#[derive(Debug)]
pub enum WireError {
    /// Socket / stream error.
    Io(std::io::Error),
    /// First header word was not [`MAGIC`] (wrong peer or protocol version).
    BadMagic(u32),
    /// Unknown frame-kind byte.
    BadKind(u8),
    /// Fewer bytes than a header on the wire.
    Truncated {
        /// Bytes required.
        need: usize,
        /// Bytes present.
        got: usize,
    },
    /// Length prefix admits more than [`MAX_FRAME_WORDS`] payload words.
    Oversized {
        /// Declared payload length in words.
        words: usize,
    },
    /// Length prefix disagrees with the header's word count.
    BadLength {
        /// Bytes declared by the prefix.
        declared: usize,
        /// Bytes implied by the header.
        expect: usize,
    },
    /// Checksum mismatch (bit corruption on the path).
    BadChecksum {
        /// Checksum computed over the received bytes.
        got: u64,
        /// Checksum carried in the header.
        want: u64,
    },
    /// Structurally valid frame that violates the protocol state machine
    /// (wrong epoch, unknown producer, out-of-range positions, …).
    Protocol(String),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Io(e) => write!(f, "wire i/o: {e}"),
            WireError::BadMagic(m) => {
                write!(f, "bad frame magic {m:#010x} (want {MAGIC:#010x} = \"PLW1\")")
            }
            WireError::BadKind(k) => write!(f, "unknown frame kind {k}"),
            WireError::Truncated { need, got } => {
                write!(f, "truncated frame: need {need} bytes, got {got}")
            }
            WireError::Oversized { words } => {
                write!(f, "oversized frame: {words} words > max {MAX_FRAME_WORDS}")
            }
            WireError::BadLength { declared, expect } => {
                write!(f, "frame length prefix {declared} != header-implied {expect}")
            }
            WireError::BadChecksum { got, want } => {
                write!(f, "frame checksum {got:#018x} != header {want:#018x}")
            }
            WireError::Protocol(m) => write!(f, "wire protocol: {m}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> WireError {
        WireError::Io(e)
    }
}

impl From<WireError> for HandoffError {
    fn from(e: WireError) -> HandoffError {
        HandoffError(e.to_string())
    }
}

fn frame_checksum(header: &[u8], payload: &[u8]) -> u64 {
    let mut h = Fnv::new();
    h.write(header);
    h.write(payload);
    h.finish()
}

/// Encode a frame to its full wire form (length prefix included).
pub fn encode_frame(f: &Frame) -> Result<Vec<u8>, WireError> {
    if f.words.len() > MAX_FRAME_WORDS {
        return Err(WireError::Oversized { words: f.words.len() });
    }
    let payload_len = 8 * f.words.len();
    let mut out = Vec::with_capacity(4 + HEADER_LEN + payload_len);
    out.extend_from_slice(&((HEADER_LEN + payload_len) as u32).to_le_bytes());
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.push(f.kind as u8);
    out.push(f.parity);
    out.extend_from_slice(&0u16.to_le_bytes());
    out.extend_from_slice(&f.epoch.to_le_bytes());
    out.extend_from_slice(&f.boundary.to_le_bytes());
    out.extend_from_slice(&f.shard.to_le_bytes());
    out.extend_from_slice(&f.start.to_le_bytes());
    out.extend_from_slice(&(f.words.len() as u32).to_le_bytes());
    let mut payload = Vec::with_capacity(payload_len);
    for w in &f.words {
        payload.extend_from_slice(&w.to_le_bytes());
    }
    // Checksum covers the header written so far (sans length prefix) plus
    // the payload; it is appended to complete the header.
    let sum = frame_checksum(&out[4..], &payload);
    out.extend_from_slice(&sum.to_le_bytes());
    out.extend_from_slice(&payload);
    Ok(out)
}

fn le_u16(b: &[u8]) -> u16 {
    u16::from_le_bytes([b[0], b[1]])
}

fn le_u32(b: &[u8]) -> u32 {
    u32::from_le_bytes([b[0], b[1], b[2], b[3]])
}

fn le_u64(b: &[u8]) -> u64 {
    u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]])
}

/// Decode one frame body (the bytes *after* the length prefix).
pub fn decode_frame(body: &[u8]) -> Result<Frame, WireError> {
    if body.len() < HEADER_LEN {
        return Err(WireError::Truncated { need: HEADER_LEN, got: body.len() });
    }
    let magic = le_u32(&body[0..4]);
    if magic != MAGIC {
        return Err(WireError::BadMagic(magic));
    }
    let kind = FrameKind::from_u8(body[4]).ok_or(WireError::BadKind(body[4]))?;
    let parity = body[5];
    if le_u16(&body[6..8]) != 0 {
        return Err(WireError::Protocol("reserved header bytes not zero".into()));
    }
    let epoch = le_u64(&body[8..16]);
    let boundary = le_u32(&body[16..20]);
    let shard = le_u32(&body[20..24]);
    let start = le_u32(&body[24..28]);
    let count = le_u32(&body[28..32]) as usize;
    if count > MAX_FRAME_WORDS {
        return Err(WireError::Oversized { words: count });
    }
    let want = le_u64(&body[32..40]);
    let expect = HEADER_LEN + 8 * count;
    if body.len() != expect {
        return Err(WireError::BadLength { declared: body.len(), expect });
    }
    let got = frame_checksum(&body[..32], &body[HEADER_LEN..]);
    if got != want {
        return Err(WireError::BadChecksum { got, want });
    }
    let words = body[HEADER_LEN..].chunks_exact(8).map(le_u64).collect();
    Ok(Frame { kind, parity, epoch, boundary, shard, start, words })
}

/// Write one frame (length prefix + body).
pub fn write_frame(w: &mut impl Write, f: &Frame) -> Result<(), WireError> {
    let bytes = encode_frame(f)?;
    w.write_all(&bytes)?;
    w.flush()?;
    Ok(())
}

/// Read one length-prefixed frame.  The length prefix is validated against
/// [`MAX_FRAME_WORDS`] *before* any allocation.
pub fn read_frame(r: &mut impl Read) -> Result<Frame, WireError> {
    let mut len4 = [0u8; 4];
    r.read_exact(&mut len4)?;
    let len = u32::from_le_bytes(len4) as usize;
    if len < HEADER_LEN {
        return Err(WireError::Truncated { need: HEADER_LEN, got: len });
    }
    if len > HEADER_LEN + 8 * MAX_FRAME_WORDS {
        return Err(WireError::Oversized { words: (len - HEADER_LEN) / 8 });
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    decode_frame(&body)
}

/// On-wire size in bytes of a frame with `words` payload words.
fn frame_bytes(words: usize) -> u64 {
    (4 + HEADER_LEN + 8 * words) as u64
}

fn fault_frame(msg: &str) -> Frame {
    let bytes = msg.as_bytes();
    let mut words = Vec::with_capacity(bytes.len().div_ceil(8));
    for chunk in bytes.chunks(8) {
        let mut w = [0u8; 8];
        w[..chunk.len()].copy_from_slice(chunk);
        words.push(u64::from_le_bytes(w));
    }
    Frame {
        kind: FrameKind::Fault,
        parity: 0,
        epoch: 0,
        boundary: 0,
        shard: 0,
        start: bytes.len() as u32,
        words,
    }
}

fn fault_message(f: &Frame) -> String {
    let mut bytes = Vec::with_capacity(8 * f.words.len());
    for w in &f.words {
        bytes.extend_from_slice(&w.to_le_bytes());
    }
    bytes.truncate((f.start as usize).min(bytes.len()));
    String::from_utf8_lossy(&bytes).into_owned()
}

// ---------------------------------------------------------------------------
// Placement + stats
// ---------------------------------------------------------------------------

/// Shard placement map: `placement[s]` is `Some("host:port")` for a shard
/// hosted by a remote `polylut shard-worker`, `None` for a local worker
/// thread.
pub type ShardPlacement = Vec<Option<String>>;

/// Parse a `--shard-hosts` spec (`addr,addr,…`; `local`, `-` or an empty
/// entry keep that shard on a local thread; unlisted trailing shards are
/// local) into a placement map of length `shards`.
pub fn parse_shard_hosts(spec: &str, shards: usize) -> Result<ShardPlacement> {
    let mut placement: ShardPlacement = Vec::with_capacity(shards);
    if !spec.trim().is_empty() {
        for (i, raw) in spec.split(',').enumerate() {
            let e = raw.trim();
            let entry = if e.is_empty() || e == "local" || e == "-" {
                None
            } else if e.contains(':') {
                Some(e.to_string())
            } else {
                anyhow::bail!("--shard-hosts entry {e:?} is not host:port / local / -");
            };
            if i >= shards {
                // Trailing local/empty entries (e.g. a trailing comma) are
                // the documented no-op; only a real host past the shard
                // count is an error.
                if entry.is_some() {
                    anyhow::bail!("--shard-hosts lists more than {shards} shards");
                }
                continue;
            }
            placement.push(entry);
        }
    }
    placement.resize(shards, None);
    Ok(placement)
}

/// Cumulative per-link (or summed-over-links) wire counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WireStats {
    /// Frames sent + received.
    pub frames: u64,
    /// Bytes sent + received (frame-level accounting, incl. headers).
    pub bytes: u64,
    /// Nanoseconds spent blocked waiting for a frame to arrive.
    pub wait_ns: u64,
    /// Connection attempts beyond each link's first (retries at connect).
    pub reconnects: u64,
}

impl WireStats {
    /// Element-wise sum of two counter sets.
    pub fn merged(self, o: WireStats) -> WireStats {
        WireStats {
            frames: self.frames + o.frames,
            bytes: self.bytes + o.bytes,
            wait_ns: self.wait_ns + o.wait_ns,
            reconnects: self.reconnects + o.reconnects,
        }
    }
}

/// Shared atomic wire counters of one live link.
#[derive(Default)]
pub(crate) struct LinkStats {
    frames: AtomicU64,
    bytes: AtomicU64,
    wait_ns: AtomicU64,
    reconnects: AtomicU64,
}

impl LinkStats {
    fn count_frame(&self, words: usize) {
        self.frames.fetch_add(1, Ordering::Relaxed);
        self.bytes.fetch_add(frame_bytes(words), Ordering::Relaxed);
    }

    pub(crate) fn snapshot(&self) -> WireStats {
        WireStats {
            frames: self.frames.load(Ordering::Relaxed),
            bytes: self.bytes.load(Ordering::Relaxed),
            wait_ns: self.wait_ns.load(Ordering::Relaxed),
            reconnects: self.reconnects.load(Ordering::Relaxed),
        }
    }
}

// ---------------------------------------------------------------------------
// Wire plan: what crosses the link for one (engine, shard)
// ---------------------------------------------------------------------------

/// Which LUT engine a link serves (one byte in the Hello frame).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum EngineKind {
    Plan = 0,
    Bitslice = 1,
}

impl EngineKind {
    fn from_u64(v: u64) -> Option<EngineKind> {
        match v {
            0 => Some(EngineKind::Plan),
            1 => Some(EngineKind::Bitslice),
            _ => None,
        }
    }
}

/// The per-layer wire schedule of one remote shard, derived identically on
/// both ends from the deterministic kernel compilation:
///
/// - `needs[l]` — `(producer, position range)` runs of boundary l that the
///   coordinator must ship before cell (l, s) can run remotely: the cell's
///   read positions minus the shard's own boundary-l slice, grouped by the
///   producing shard and compressed to maximal contiguous runs (producer
///   `shards` = the coordinator's input staging, boundary 0).
/// - `result[l]` — the boundary l+1 positions the worker ships back.
/// - `deps[l]` — the worker-side `(shard, threshold)` waits; satisfied by
///   frame arrival (see `RemoteHandoff`).  Only *producer*-class waits
///   appear: the worker's buffers are private, written solely by in-order
///   frame application and its own strictly sequential cells, so the
///   reader-blocker / writer-ordering hazards of the shared-memory path
///   cannot arise.
/// - `counts[l]` — `(producer, frames)` expected per boundary, used to
///   advance a producer's level once its last frame lands.
pub(crate) struct WirePlan {
    pub(crate) needs: Vec<Vec<(u32, Range<usize>)>>,
    pub(crate) result: Vec<Range<usize>>,
    pub(crate) deps: Vec<Vec<(u32, u32)>>,
    pub(crate) counts: Vec<Vec<(u32, u32)>>,
}

/// Build the wire schedule of shard `s` from a compiled kernel.
pub(crate) fn wire_plan<K: ShardKernel>(k: &K, s: usize) -> WirePlan {
    let l_count = k.n_layers();
    let coord = k.n_shards() as u32;
    let owner = |l: usize, x: usize| -> u32 {
        for q in 0..k.n_shards() {
            if k.write_range(l - 1, q).contains(&x) {
                return q as u32;
            }
        }
        unreachable!("boundary {l} position {x} has no producing shard")
    };
    let mut needs = Vec::with_capacity(l_count);
    let mut result = Vec::with_capacity(l_count);
    let mut deps = Vec::with_capacity(l_count);
    let mut counts = Vec::with_capacity(l_count);
    for l in 0..l_count {
        let own: Range<usize> = if l >= 1 { k.write_range(l - 1, s) } else { 0..0 };
        let mut runs: Vec<(u32, Range<usize>)> = Vec::new();
        for &x in k.reads(l, s) {
            if l >= 1 && own.contains(&x) {
                continue;
            }
            let q = if l == 0 { coord } else { owner(l, x) };
            match runs.last_mut() {
                Some((lq, r)) if *lq == q && r.end == x => r.end = x + 1,
                _ => runs.push((q, x..x + 1)),
            }
        }
        let mut layer_deps: Vec<(u32, u32)> = Vec::new();
        let mut layer_counts: Vec<(u32, u32)> = Vec::new();
        for (q, _) in &runs {
            let thr = if *q == coord { 1 } else { l as u32 };
            if !layer_deps.iter().any(|&(d, _)| d == *q) {
                layer_deps.push((*q, thr));
            }
            match layer_counts.iter_mut().find(|(d, _)| d == q) {
                Some((_, n)) => *n += 1,
                None => layer_counts.push((*q, 1)),
            }
        }
        needs.push(runs);
        result.push(k.write_range(l, s));
        deps.push(layer_deps);
        counts.push(layer_counts);
    }
    WirePlan { needs, result, deps, counts }
}

// ---------------------------------------------------------------------------
// Coordinator side: RemoteLink
// ---------------------------------------------------------------------------

/// How long the coordinator waits for one frame from a worker before the
/// link is declared dead (a hung worker must become a clean engine error,
/// not a hung server).
const RECV_TIMEOUT: Duration = Duration::from_secs(30);
/// Connection attempts per link at compile time (retries count into
/// `WireStats::reconnects`).
const CONNECT_ATTEMPTS: u32 = 3;

/// Coordinator end of one (engine, shard) link, used by the shard runner's
/// proxy threads.  All sends/recvs are whole frames; `recv` time funds
/// `wait_ns`.
pub(crate) struct RemoteLink {
    stream: TcpStream,
    peer: String,
    stats: Arc<LinkStats>,
}

impl RemoteLink {
    /// Connect to a shard worker and run the handshake.  Returns the link
    /// plus a second stream handle the runner keeps for shutdown wakeups.
    pub(crate) fn connect(
        addr: &str,
        engine: EngineKind,
        shards: usize,
        shard: usize,
        fingerprint: u64,
    ) -> Result<(RemoteLink, TcpStream), WireError> {
        let stats = Arc::new(LinkStats::default());
        let mut last: Option<std::io::Error> = None;
        let mut stream = None;
        for attempt in 0..CONNECT_ATTEMPTS {
            if attempt > 0 {
                stats.reconnects.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(Duration::from_millis(50 << attempt));
            }
            match TcpStream::connect(addr) {
                Ok(s) => {
                    stream = Some(s);
                    break;
                }
                Err(e) => last = Some(e),
            }
        }
        let stream = match stream {
            Some(s) => s,
            None => {
                return Err(WireError::Io(last.unwrap_or_else(|| {
                    std::io::Error::new(std::io::ErrorKind::Other, "connect failed")
                })))
            }
        };
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(RECV_TIMEOUT))?;
        let wake = stream.try_clone()?;
        let mut link = RemoteLink { stream, peer: addr.to_string(), stats };
        let hello = Frame {
            kind: FrameKind::Hello,
            parity: 0,
            epoch: 0,
            boundary: 0,
            shard: shard as u32,
            start: 0,
            words: vec![engine as u64, shards as u64, fingerprint],
        };
        link.send(&hello)?;
        let ack = link.recv()?;
        match ack.kind {
            FrameKind::HelloAck => {
                if ack.words.first().copied() != Some(fingerprint) {
                    return Err(WireError::Protocol(format!(
                        "{addr}: model fingerprint mismatch (worker {:#018x}, \
                         coordinator {fingerprint:#018x}) — same weights, shard \
                         count and build required",
                        ack.words.first().copied().unwrap_or(0)
                    )));
                }
            }
            FrameKind::Fault => {
                return Err(WireError::Protocol(format!(
                    "{addr} rejected handshake: {}",
                    fault_message(&ack)
                )))
            }
            k => {
                return Err(WireError::Protocol(format!(
                    "{addr}: expected HelloAck, got {k:?}"
                )))
            }
        }
        Ok((link, wake))
    }

    fn send(&mut self, f: &Frame) -> Result<(), WireError> {
        write_frame(&mut self.stream, f)?;
        self.stats.count_frame(f.words.len());
        Ok(())
    }

    fn recv(&mut self) -> Result<Frame, WireError> {
        let t0 = Instant::now();
        let f = read_frame(&mut self.stream);
        self.stats.wait_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        let f = f?;
        self.stats.count_frame(f.words.len());
        if f.kind == FrameKind::Fault {
            return Err(WireError::Protocol(format!(
                "{} faulted: {}",
                self.peer,
                fault_message(&f)
            )));
        }
        Ok(f)
    }

    /// Announce a new epoch to the worker.
    pub(crate) fn start_epoch(&mut self, epoch: u64) -> Result<(), WireError> {
        self.send(&Frame::control(FrameKind::Start, epoch))
    }

    /// Ship one needs run: boundary words the remote cell will read.
    pub(crate) fn send_need(
        &mut self,
        epoch: u64,
        boundary: u32,
        producer: u32,
        start: u32,
        words: Vec<u64>,
    ) -> Result<(), WireError> {
        self.send(&Frame::data(epoch, boundary, producer, start, words))
    }

    /// Receive and validate the result frame for `boundary` covering
    /// exactly `expect` (the remote shard's published slice).
    pub(crate) fn recv_result(
        &mut self,
        epoch: u64,
        boundary: u32,
        shard: u32,
        expect: &Range<usize>,
    ) -> Result<Vec<u64>, WireError> {
        let f = self.recv()?;
        if f.kind != FrameKind::Data {
            return Err(WireError::Protocol(format!("expected Data, got {:?}", f.kind)));
        }
        if f.epoch != epoch
            || f.boundary != boundary
            || f.shard != shard
            || f.start as usize != expect.start
            || f.words.len() != expect.len()
        {
            return Err(WireError::Protocol(format!(
                "result frame mismatch: got (epoch {}, boundary {}, shard {}, \
                 {}+{}), want (epoch {epoch}, boundary {boundary}, shard {shard}, \
                 {}+{})",
                f.epoch,
                f.boundary,
                f.shard,
                f.start,
                f.words.len(),
                expect.start,
                expect.len(),
            )));
        }
        Ok(f.words)
    }

    /// Best-effort clean shutdown (Bye frame + FIN).
    pub(crate) fn close(&mut self) {
        let _ = write_frame(&mut self.stream, &Frame::control(FrameKind::Bye, 0));
        let _ = self.stream.shutdown(Shutdown::Both);
    }

    pub(crate) fn peer(&self) -> &str {
        &self.peer
    }

    pub(crate) fn stats(&self) -> Arc<LinkStats> {
        self.stats.clone()
    }
}

// ---------------------------------------------------------------------------
// Worker side: RemoteHandoff + ShardWorkerHost
// ---------------------------------------------------------------------------

/// Worker-side [`Handoff`]: the per-cell `(shard, threshold)` dependency
/// waits of the generic cell loop are satisfied by **frame arrival**.
/// `wait(d, thr)` pulls frames off the socket (in TCP order) and applies
/// them to the worker's private buffers until producer `d`'s level — the
/// highest boundary for which *all* of `d`'s expected frames have landed —
/// reaches `thr`; `publish(s, level)` ships the shard's boundary-`level`
/// slice back to the coordinator.  The coordinator's pseudo-shard
/// (`shards`) produces boundary 0 (input staging) at level 1.
struct RemoteHandoff {
    stream: Mutex<TcpStream>,
    bufs: Arc<BufSet>,
    plan: WirePlan,
    n_layers: usize,
    shards: usize,
    shard: u32,
    /// levels[q] for q in 0..shards, plus the coordinator at index shards.
    levels: Vec<AtomicU32>,
    /// Frames still expected per boundary, per producer (epoch-local).
    remaining: Mutex<Vec<Vec<(u32, u32)>>>,
    epoch: AtomicU64,
    stats: Arc<LinkStats>,
    fault: Mutex<Option<String>>,
}

impl RemoteHandoff {
    fn new(
        stream: TcpStream,
        bufs: Arc<BufSet>,
        plan: WirePlan,
        n_layers: usize,
        shards: usize,
        shard: u32,
    ) -> RemoteHandoff {
        let remaining = plan.counts.clone();
        RemoteHandoff {
            stream: Mutex::new(stream),
            bufs,
            plan,
            n_layers,
            shards,
            shard,
            levels: (0..=shards).map(|_| AtomicU32::new(0)).collect(),
            remaining: Mutex::new(remaining),
            epoch: AtomicU64::new(0),
            stats: Arc::new(LinkStats::default()),
            fault: Mutex::new(None),
        }
    }

    /// Idle probe between epochs: `Ok(true)` when at least one byte is
    /// pending, `Ok(false)` on a benign read timeout, `Err` on EOF or any
    /// real socket error.
    fn peek_ready(&self) -> Result<bool, WireError> {
        let stream = self.stream.lock().unwrap_or_else(|p| p.into_inner());
        let mut probe = [0u8; 1];
        match stream.peek(&mut probe) {
            Ok(0) => Err(WireError::Io(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "link closed",
            ))),
            Ok(_) => Ok(true),
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                Ok(false)
            }
            Err(e) => Err(WireError::Io(e)),
        }
    }

    /// Blocking read of the next frame (any kind).
    fn recv_frame(&self) -> Result<Frame, WireError> {
        let mut stream = self.stream.lock().unwrap_or_else(|p| p.into_inner());
        let t0 = Instant::now();
        let f = read_frame(&mut *stream);
        self.stats.wait_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        let f = f?;
        self.stats.count_frame(f.words.len());
        Ok(f)
    }

    fn send_frame(&self, f: &Frame) -> Result<(), WireError> {
        let mut stream = self.stream.lock().unwrap_or_else(|p| p.into_inner());
        write_frame(&mut *stream, f)?;
        self.stats.count_frame(f.words.len());
        Ok(())
    }

    /// Reset per-epoch state on a Start frame.
    fn begin_epoch(&self, epoch: u64) -> Result<(), WireError> {
        let last = self.epoch.swap(epoch, Ordering::Relaxed);
        if epoch <= last {
            return Err(WireError::Protocol(format!(
                "epoch went backwards: {epoch} after {last}"
            )));
        }
        for l in &self.levels {
            l.store(0, Ordering::Relaxed);
        }
        *self.remaining.lock().unwrap_or_else(|p| p.into_inner()) = self.plan.counts.clone();
        Ok(())
    }

    /// Apply one incoming Data frame to the private buffers and advance the
    /// producer's level when its boundary is complete.
    fn apply(&self, f: Frame) -> Result<(), WireError> {
        let epoch = self.epoch.load(Ordering::Relaxed);
        if f.epoch != epoch {
            return Err(WireError::Protocol(format!(
                "data frame for epoch {} during epoch {epoch}",
                f.epoch
            )));
        }
        let b = f.boundary as usize;
        if b >= self.n_layers {
            return Err(WireError::Protocol(format!(
                "incoming boundary {b} out of range (layers {})",
                self.n_layers
            )));
        }
        if f.parity != (f.boundary % 2) as u8 {
            return Err(WireError::Protocol(format!(
                "parity {} does not match boundary {b}",
                f.parity
            )));
        }
        let q = f.shard;
        if q as usize > self.shards {
            return Err(WireError::Protocol(format!("unknown producer shard {q}")));
        }
        let target = self.bufs.boundary(b, self.n_layers);
        let start = f.start as usize;
        let end = start
            .checked_add(f.words.len())
            .ok_or_else(|| WireError::Protocol("position overflow".into()))?;
        if end > target.len() {
            return Err(WireError::Protocol(format!(
                "frame range {start}..{end} exceeds boundary buffer {}",
                target.len()
            )));
        }
        for (slot, w) in target[start..end].iter().zip(&f.words) {
            slot.store(*w, Ordering::Relaxed);
        }
        let mut remaining = self.remaining.lock().unwrap_or_else(|p| p.into_inner());
        let entry = remaining[b].iter_mut().find(|(d, n)| *d == q && *n > 0);
        match entry {
            Some((_, n)) => {
                *n -= 1;
                if *n == 0 {
                    let level = if q as usize == self.shards { 1 } else { f.boundary };
                    self.levels[q as usize].store(level, Ordering::Release);
                }
            }
            None => {
                return Err(WireError::Protocol(format!(
                    "unexpected frame from producer {q} for boundary {b}"
                )))
            }
        }
        Ok(())
    }
}

impl Handoff for RemoteHandoff {
    fn wait(&self, shard: usize, threshold: u32) -> Result<bool, HandoffError> {
        if self.levels[shard].load(Ordering::Acquire) >= threshold {
            return Ok(false);
        }
        while self.levels[shard].load(Ordering::Acquire) < threshold {
            let f = self.recv_frame().map_err(HandoffError::from)?;
            match f.kind {
                FrameKind::Data => self.apply(f).map_err(HandoffError::from)?,
                FrameKind::Fault => {
                    return Err(HandoffError(format!(
                        "coordinator faulted: {}",
                        fault_message(&f)
                    )))
                }
                FrameKind::Bye => return Err(HandoffError("link closed mid-epoch".into())),
                k => {
                    return Err(HandoffError(format!(
                        "unexpected {k:?} frame while waiting for data"
                    )))
                }
            }
        }
        Ok(true)
    }

    fn publish(&self, shard: usize, level: u32) -> Result<(), HandoffError> {
        debug_assert_eq!(shard as u32, self.shard);
        let l = level as usize - 1;
        let rr = self.plan.result[l].clone();
        let src = self.bufs.dst(l, self.n_layers);
        let words: Vec<u64> =
            src[rr.clone()].iter().map(|w| w.load(Ordering::Relaxed)).collect();
        let epoch = self.epoch.load(Ordering::Relaxed);
        self.send_frame(&Frame::data(epoch, level, self.shard, rr.start as u32, words))
            .map_err(HandoffError::from)
    }

    fn level(&self, shard: usize) -> u32 {
        self.levels[shard].load(Ordering::Acquire)
    }

    fn reset(&self) {
        // Per-epoch state is reset by `begin_epoch` on the Start frame.
    }

    fn fail(&self, msg: &str) {
        let mut f = self.fault.lock().unwrap_or_else(|p| p.into_inner());
        if f.is_none() {
            *f = Some(msg.to_string());
        }
    }

    fn fault(&self) -> Option<String> {
        self.fault.lock().unwrap_or_else(|p| p.into_inner()).clone()
    }
}

/// The `polylut shard-worker` process body: the full sharded kernels
/// (compiled deterministically from the same network, tables and shard
/// count as the coordinator — verified by a fingerprint handshake), served
/// over TCP.  Each accepted connection claims one `(engine, shard)` pair
/// and gets private boundary buffers plus a thread running the same
/// generic cell loop as a local shard worker, with `RemoteHandoff` mapping
/// its dependency waits onto frame arrival.
pub struct ShardWorkerHost {
    plan: Arc<PlanKernel>,
    bits: Arc<BitsliceKernel>,
    shards: usize,
    fingerprint: u64,
}

impl ShardWorkerHost {
    /// Compile both shard kernels for `shards` shards (identical to the
    /// coordinator-side compilation: cache-aware reorder, permute, plan +
    /// bitslice partitioning).
    pub fn compile(
        net: &Network,
        tables: &NetworkTables,
        shards: usize,
        workers: usize,
    ) -> ShardWorkerHost {
        let shards = shards.max(1);
        let (pnet, ptables) = permuted_for_shards(net, tables);
        let fingerprint = shard_fingerprint(&pnet, &ptables, shards);
        ShardWorkerHost {
            plan: Arc::new(plan_kernel_of(&pnet, &ptables, shards)),
            bits: Arc::new(bits_kernel_of(&pnet, &ptables, shards, workers)),
            shards,
            fingerprint,
        }
    }

    /// Shard count the kernels were partitioned for.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Model fingerprint the handshake checks (hash of the permuted
    /// network's connectivity, table words and shard count).
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    /// Accept loop: serves every incoming connection on its own thread
    /// until the listener errors (e.g. is closed).  Blocking — spawn it on
    /// a dedicated thread for in-process use.
    pub fn serve(self: Arc<Self>, listener: TcpListener) {
        for stream in listener.incoming() {
            match stream {
                Ok(s) => {
                    let host = self.clone();
                    std::thread::Builder::new()
                        .name("polylut-wire-session".into())
                        .spawn(move || host.session(s))
                        .expect("spawn wire session");
                }
                Err(e) => {
                    log::warn!("shard-worker accept failed: {e}");
                    return;
                }
            }
        }
    }

    fn session(&self, mut stream: TcpStream) {
        let peer = stream
            .peer_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "<unknown>".into());
        if let Err(e) = self.session_inner(&mut stream) {
            match &e {
                // EOF without a Bye is how a killed coordinator looks;
                // don't alarm on it.
                WireError::Io(io) if io.kind() == std::io::ErrorKind::UnexpectedEof => {
                    log::info!("[shard-worker] {peer}: link closed");
                }
                _ => {
                    log::warn!("[shard-worker] {peer}: session failed: {e}");
                    let _ = write_frame(&mut stream, &fault_frame(&e.to_string()));
                }
            }
        } else {
            log::info!("[shard-worker] {peer}: clean shutdown");
        }
        let _ = stream.shutdown(Shutdown::Both);
    }

    fn session_inner(&self, stream: &mut TcpStream) -> Result<(), WireError> {
        stream.set_nodelay(true)?;
        // Liveness bound on the worker side too: a half-open link (peer
        // died without FIN) must not pin a session thread in a blocking
        // read forever.  Between epochs a timeout is benign (idle server)
        // and the serve loop retries; mid-epoch it tears the session down.
        stream.set_read_timeout(Some(RECV_TIMEOUT))?;
        let hello = read_frame(stream)?;
        if hello.kind != FrameKind::Hello {
            return Err(WireError::Protocol(format!(
                "expected Hello, got {:?}",
                hello.kind
            )));
        }
        let engine = hello
            .words
            .first()
            .copied()
            .and_then(EngineKind::from_u64)
            .ok_or_else(|| WireError::Protocol("Hello names no engine".into()))?;
        let shards = hello.words.get(1).copied().unwrap_or(0) as usize;
        let fp = hello.words.get(2).copied().unwrap_or(0);
        let shard = hello.shard as usize;
        if shards != self.shards {
            let msg = format!(
                "shard count mismatch: coordinator {shards}, worker {}",
                self.shards
            );
            write_frame(stream, &fault_frame(&msg))?;
            return Err(WireError::Protocol(msg));
        }
        if fp != self.fingerprint {
            let msg = format!(
                "model fingerprint mismatch: coordinator {fp:#018x}, worker {:#018x}",
                self.fingerprint
            );
            write_frame(stream, &fault_frame(&msg))?;
            return Err(WireError::Protocol(msg));
        }
        if shard >= self.shards {
            let msg = format!("shard {shard} out of range (shards {})", self.shards);
            write_frame(stream, &fault_frame(&msg))?;
            return Err(WireError::Protocol(msg));
        }
        write_frame(
            stream,
            &Frame {
                kind: FrameKind::HelloAck,
                parity: 0,
                epoch: 0,
                boundary: 0,
                shard: shard as u32,
                start: 0,
                words: vec![self.fingerprint],
            },
        )?;
        let stream = stream.try_clone()?;
        match engine {
            EngineKind::Plan => serve_shard(&*self.plan, shard, stream),
            EngineKind::Bitslice => serve_shard(&*self.bits, shard, stream),
        }
    }
}

/// Serve one (engine, shard) link: per Start frame, run the generic cell
/// loop for this shard over private buffers with the `RemoteHandoff`.
fn serve_shard<K: ShardKernel>(
    kernel: &K,
    shard: usize,
    stream: TcpStream,
) -> Result<(), WireError> {
    let bufs = Arc::new(BufSet::for_kernel(kernel));
    let plan = wire_plan(kernel, shard);
    let deps_owned = plan.deps.clone();
    let handoff = RemoteHandoff::new(
        stream,
        bufs.clone(),
        plan,
        kernel.n_layers(),
        kernel.n_shards(),
        shard as u32,
    );
    let deps: Vec<&[(u32, u32)]> = deps_owned.iter().map(|v| v.as_slice()).collect();
    let mut scratch = kernel.make_scratch();
    let cells = AtomicU64::new(0);
    let waits = AtomicU64::new(0);
    loop {
        // Between epochs, wait via a 1-byte peek: a read timeout there just
        // means the coordinator is idle — keep waiting (but an EOF/RST is a
        // dead link and ends the session, so half-open peers cannot pin
        // this thread forever once TCP notices).  Only start `read_frame`
        // once a byte is pending, so an idle-probe timeout can never fire
        // mid-frame and desynchronize the stream; mid-epoch timeouts
        // (inside run_cells' waits) still propagate — there a silent peer
        // is a hung epoch, not an idle one.
        if !handoff.peek_ready()? {
            continue;
        }
        let f = handoff.recv_frame()?;
        match f.kind {
            FrameKind::Start => {
                handoff.begin_epoch(f.epoch)?;
                run_cells(kernel, &handoff, &bufs, shard, &deps, &cells, &waits, &mut scratch)
                    .map_err(|e| WireError::Protocol(e.0))?;
            }
            FrameKind::Bye => return Ok(()),
            k => {
                return Err(WireError::Protocol(format!(
                    "expected Start/Bye between epochs, got {k:?}"
                )))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lut::tables::compile_network;
    use crate::nn::config;
    use crate::prop_assert;
    use crate::sim::plan::{EvalPlan, Scratch};
    use crate::sim::shard::ShardedModel;
    use crate::util::prop::{self, Outcome};
    use crate::util::rng::Rng;

    fn random_frame(rng: &mut Rng) -> Frame {
        let kinds = [
            FrameKind::Hello,
            FrameKind::HelloAck,
            FrameKind::Start,
            FrameKind::Data,
            FrameKind::Bye,
            FrameKind::Fault,
        ];
        let boundary = rng.below(9) as u32;
        Frame {
            kind: kinds[rng.below(kinds.len())],
            parity: (boundary % 2) as u8,
            epoch: rng.next_u64(),
            boundary,
            shard: rng.below(17) as u32,
            start: rng.below(1 << 20) as u32,
            // Ragged widths incl. the empty payload.
            words: (0..rng.below(70)).map(|_| rng.next_u64()).collect(),
        }
    }

    /// Round-trip property over random `(epoch, boundary, shard, range)` ×
    /// ragged plane widths: encode → read_frame == original, and the
    /// length prefix always matches the byte count.
    #[test]
    fn prop_frame_roundtrip() {
        prop::check("frame codec roundtrip", 200, |g| {
            let f = random_frame(&mut g.rng);
            let bytes = encode_frame(&f).expect("encode");
            prop_assert!(
                bytes.len() == 4 + HEADER_LEN + 8 * f.words.len(),
                "wire size accounting"
            );
            let declared = u32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]);
            prop_assert!(
                declared as usize == bytes.len() - 4,
                "length prefix covers the body"
            );
            let mut cursor = &bytes[..];
            let back = read_frame(&mut cursor).expect("decode");
            prop_assert!(back == f, "roundtrip mismatch: {back:?} vs {f:?}");
            prop_assert!(cursor.is_empty(), "decode must consume the frame exactly");
            Outcome::Pass
        });
    }

    /// Every corruption class decodes to a clean error, never a panic:
    /// truncated header, truncated payload, bad magic, flipped payload bit
    /// (checksum), flipped header bit, oversized length prefix, length
    /// prefix disagreeing with the word count.
    #[test]
    fn corrupted_frames_are_clean_errors() {
        let f = Frame::data(7, 3, 1, 10, vec![0xDEAD_BEEF, 42, 0]);
        let good = encode_frame(&f).unwrap();

        // Truncated: every proper prefix fails cleanly.
        for cut in 0..good.len() {
            let mut cursor = &good[..cut];
            assert!(read_frame(&mut cursor).is_err(), "prefix of {cut} bytes must fail");
        }

        // Bad magic.
        let mut bad = good.clone();
        bad[4] ^= 0xFF;
        assert!(matches!(
            read_frame(&mut &bad[..]),
            Err(WireError::BadMagic(_))
        ));

        // Unknown kind byte (checksum is checked after structure, so force
        // kind corruption to surface as BadKind by fixing nothing else —
        // decode checks kind before the checksum).
        let mut bad = good.clone();
        bad[8] = 250;
        assert!(matches!(read_frame(&mut &bad[..]), Err(WireError::BadKind(250))));

        // Flipped payload bit -> checksum.
        let mut bad = good.clone();
        let n = bad.len();
        bad[n - 1] ^= 0x01;
        assert!(matches!(
            read_frame(&mut &bad[..]),
            Err(WireError::BadChecksum { .. })
        ));

        // Flipped header field (epoch) -> checksum.
        let mut bad = good.clone();
        bad[12] ^= 0x10;
        assert!(matches!(
            read_frame(&mut &bad[..]),
            Err(WireError::BadChecksum { .. })
        ));

        // Oversized length prefix: rejected before any allocation.
        let mut bad = good.clone();
        bad[0..4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(
            read_frame(&mut &bad[..]),
            Err(WireError::Oversized { .. })
        ));

        // Oversized word count in the header.
        let mut bad = good.clone();
        bad[32..36].copy_from_slice(&((MAX_FRAME_WORDS + 1) as u32).to_le_bytes());
        assert!(matches!(
            read_frame(&mut &bad[..]),
            Err(WireError::Oversized { .. })
        ));

        // Length prefix vs word count disagreement.
        let mut bad = good.clone();
        bad[32..36].copy_from_slice(&2u32.to_le_bytes());
        assert!(matches!(
            read_frame(&mut &bad[..]),
            Err(WireError::BadLength { .. })
        ));

        // Oversized Frame refuses to encode.
        let huge = Frame {
            words: vec![0; MAX_FRAME_WORDS + 1],
            ..Frame::control(FrameKind::Bye, 0)
        };
        assert!(matches!(encode_frame(&huge), Err(WireError::Oversized { .. })));
    }

    #[test]
    fn fault_frame_roundtrips_message() {
        let f = fault_frame("boundary 3 exploded: äöü");
        let bytes = encode_frame(&f).unwrap();
        let back = read_frame(&mut &bytes[..]).unwrap();
        assert_eq!(fault_message(&back), "boundary 3 exploded: äöü");
    }

    #[test]
    fn parse_shard_hosts_cases() {
        assert_eq!(parse_shard_hosts("", 3).unwrap(), vec![None, None, None]);
        assert_eq!(
            parse_shard_hosts("local,127.0.0.1:7001", 3).unwrap(),
            vec![None, Some("127.0.0.1:7001".to_string()), None]
        );
        assert_eq!(
            parse_shard_hosts("-,h:1,", 3).unwrap(),
            vec![None, Some("h:1".to_string()), None]
        );
        assert!(parse_shard_hosts("a:1,b:2,c:3", 2).is_err(), "too many hosts");
        assert!(parse_shard_hosts("no-port", 2).is_err(), "not host:port");
        // Trailing comma / trailing local entries are the documented no-op.
        assert_eq!(
            parse_shard_hosts("a:1,b:2,", 2).unwrap(),
            vec![Some("a:1".to_string()), Some("b:2".to_string())]
        );
        assert_eq!(
            parse_shard_hosts("a:1,local,local", 1).unwrap(),
            vec![Some("a:1".to_string())]
        );
    }

    const GRID: [(usize, u32); 6] = [(1, 1), (2, 1), (3, 1), (1, 2), (2, 2), (2, 3)];

    fn grid_net(a: usize, d: u32) -> (crate::nn::network::Network, NetworkTables) {
        let cfg = config::uniform("wire-t", &[8, 6, 3], 2, 2, 3, 3, 3, d, a, 3);
        let net =
            crate::nn::network::Network::random(&cfg, &mut Rng::new(a as u64 * 100 + d as u64));
        let tables = compile_network(&net, 1);
        (net, tables)
    }

    fn random_codes(net: &Network, n: usize, seed: u64) -> Vec<Vec<i32>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                let x: Vec<f32> = (0..net.cfg.widths[0]).map(|_| rng.f32()).collect();
                net.quantize_input(&x)
            })
            .collect()
    }

    fn spawn_host(net: &Network, tables: &NetworkTables, shards: usize) -> String {
        let host = Arc::new(ShardWorkerHost::compile(net, tables, shards, 1));
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
        let addr = listener.local_addr().expect("local addr").to_string();
        std::thread::spawn(move || host.serve(listener));
        addr
    }

    /// The PR 4 acceptance grid: mixed local/remote sharded execution over
    /// loopback TCP is bit-exact vs `Network::forward_codes` (via the
    /// pinned unsharded plan) over the (A, degree) grid with S ∈ {2, 3},
    /// on both the plan and bitslice routes, with ragged multi-word
    /// batches.  S = 3 drives two remote shards over two links into one
    /// worker host.
    #[test]
    fn mixed_local_remote_bit_exact_on_grid() {
        for (a, d) in GRID {
            let (net, tables) = grid_net(a, d);
            let plan = EvalPlan::compile(&net, &tables);
            let mut scratch = Scratch::for_plan(&plan);
            let xs = random_codes(&net, crate::sim::WORD + 9, 51);
            let want = plan.forward_batch(&xs, &mut scratch);
            for (i, (x, w)) in xs.iter().zip(&want).enumerate() {
                assert_eq!(w, &net.forward_codes(x), "A={a} D={d} sample {i}");
            }
            for shards in [2usize, 3] {
                let addr = spawn_host(&net, &tables, shards);
                // Shard 0 local; every other shard remote (same host).
                let placement: ShardPlacement = (0..shards)
                    .map(|s| (s > 0).then(|| addr.clone()))
                    .collect();
                let model =
                    ShardedModel::compile_placed(&net, &tables, shards, 1, &placement, None)
                        .expect("loopback placement");
                assert_eq!(model.spin_us(), resolve_spin_us_probe(), "remote => 0 spin");
                assert_eq!(
                    model.plan.forward_batch(&xs).unwrap(),
                    want,
                    "plan A={a} D={d} S={shards}"
                );
                assert_eq!(
                    model.bits.forward_batch(&xs).unwrap(),
                    want,
                    "bits A={a} D={d} S={shards}"
                );
                let ws = model.wire_stats().expect("remote links present");
                assert!(ws.frames > 0 && ws.bytes > 0, "wire counters move: {ws:?}");
                let st = model.stats();
                assert!(st.iter().all(|s| s.cells > 0), "every shard ran");
            }
        }
    }

    fn resolve_spin_us_probe() -> u64 {
        crate::sim::shard::resolve_spin_us(None, true)
    }

    /// Repeated epochs over one wired engine stay deterministic (per-epoch
    /// wire state resets cleanly).
    #[test]
    fn wired_epochs_are_deterministic() {
        let (net, tables) = grid_net(2, 2);
        let addr = spawn_host(&net, &tables, 2);
        let placement: ShardPlacement = vec![None, Some(addr)];
        let model = ShardedModel::compile_placed(&net, &tables, 2, 1, &placement, None)
            .expect("loopback placement");
        let xs = random_codes(&net, 6, 77);
        let first: Vec<Vec<i32>> =
            xs.iter().map(|x| model.plan.forward_codes(x).unwrap()).collect();
        let second: Vec<Vec<i32>> =
            xs.iter().rev().map(|x| model.plan.forward_codes(x).unwrap()).collect();
        for (a, b) in first.iter().zip(second.iter().rev()) {
            assert_eq!(a, b);
        }
    }

    /// A worker hosting different weights (or shard count) must be refused
    /// at handshake time with a clean error naming the fingerprint.
    #[test]
    fn handshake_rejects_mismatched_model() {
        let (net, tables) = grid_net(2, 1);
        let cfg = config::uniform("wire-t", &[8, 6, 3], 2, 2, 3, 3, 3, 1, 2, 3);
        let other = Network::random(&cfg, &mut Rng::new(4242));
        let otables = compile_network(&other, 1);
        let addr = spawn_host(&other, &otables, 2);
        let placement: ShardPlacement = vec![None, Some(addr.clone())];
        let err = ShardedModel::compile_placed(&net, &tables, 2, 1, &placement, None)
            .expect_err("mismatched weights must fail the handshake");
        let msg = format!("{err:#}");
        assert!(msg.contains("fingerprint"), "error names the cause: {msg}");

        // Shard-count mismatch: worker partitioned for 2, coordinator for 3.
        let (net3, tables3) = grid_net(2, 1);
        let addr3 = spawn_host(&net3, &tables3, 2);
        let placement3: ShardPlacement = vec![None, Some(addr3), None];
        let err3 = ShardedModel::compile_placed(&net3, &tables3, 3, 1, &placement3, None)
            .expect_err("shard-count mismatch must fail the handshake");
        let msg3 = format!("{err3:#}");
        assert!(
            msg3.contains("fingerprint") || msg3.contains("shard count"),
            "error names the cause: {msg3}"
        );
    }

    /// Unreachable worker: compile_placed returns a clean error (after its
    /// connect retries), not a hang or panic.
    #[test]
    fn unreachable_worker_is_clean_error() {
        let (net, tables) = grid_net(1, 1);
        // Reserve a port and close it again: nothing listens there.
        let dead = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let placement: ShardPlacement = vec![None, Some(dead)];
        let err = ShardedModel::compile_placed(&net, &tables, 2, 1, &placement, None)
            .expect_err("dead address must fail");
        assert!(format!("{err:#}").contains("shard 1"), "error names the shard");
    }

    /// wire_plan invariants on a real kernel: needs cover exactly the
    /// cross-shard reads, results are the shard's write ranges, worker
    /// deps reference only producers (plus the coordinator for boundary 0).
    #[test]
    fn wire_plan_covers_cross_shard_reads() {
        let (net, tables) = grid_net(2, 1);
        let (pnet, ptables) = crate::sim::shard::permuted_for_shards(&net, &tables);
        let kernel = plan_kernel_of(&pnet, &ptables, 2);
        for s in 0..2 {
            let wp = wire_plan(&kernel, s);
            for l in 0..kernel.n_layers() {
                assert_eq!(wp.result[l], kernel.write_range(l, s));
                let own: Range<usize> =
                    if l >= 1 { kernel.write_range(l - 1, s) } else { 0..0 };
                let mut shipped: Vec<usize> = wp.needs[l]
                    .iter()
                    .flat_map(|(_, r)| r.clone())
                    .collect();
                shipped.sort_unstable();
                let expect: Vec<usize> = kernel
                    .reads(l, s)
                    .iter()
                    .copied()
                    .filter(|x| l == 0 || !own.contains(x))
                    .collect();
                // Runs may cover extra positions only if contiguous merging
                // added nothing: in fact runs are built from the read list
                // alone, so the sets match exactly.
                assert_eq!(shipped, expect, "layer {l} shard {s}");
                for &(q, thr) in &wp.deps[l] {
                    if l == 0 {
                        assert_eq!((q, thr), (2, 1), "boundary 0 waits on the coordinator");
                    } else {
                        assert!(q < 2 && thr == l as u32, "producer wait (q={q}, thr={thr})");
                    }
                }
            }
        }
    }
}
