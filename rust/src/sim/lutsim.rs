//! LUT-network simulator — evaluates the *frozen tables* (deployed
//! semantics), independent of the float model.  This is the software twin of
//! the FPGA datapath and the reference for the Verilog testbench; a property
//! test pins it bit-exactly to `Network::forward_codes`.
//!
//! Since the evaluation-plan engine landed, `LutSim` is a thin compatibility
//! shim: construction compiles an [`EvalPlan`] and every forward goes
//! through it.  The original pointer-chasing walk survives as
//! [`LutSim::forward_codes_reference`] — an independent implementation the
//! tests (and the `micro_hotpaths` bench baseline) cross-check the plan
//! against.

use crate::lut::tables::{pack_adder_addr, pack_poly_addr, NetworkTables};
use crate::nn::network::Network;
use crate::sim::bitslice::BitsliceNet;
use crate::sim::plan::{EvalPlan, Scratch};

/// Owned-or-borrowed plan storage: `LutSim::new` compiles its own plan;
/// callers that already hold one (e.g. `FrozenModel`) share it instead of
/// recompiling on every construction.
enum PlanStore<'a> {
    Owned(Box<EvalPlan>),
    Shared(&'a EvalPlan),
}

/// Simulator over a frozen network (borrows the trained network only for
/// its connectivity and input quantizer).  See `ARCHITECTURE.md` §1 for
/// where this shim sits among the engines.
pub struct LutSim<'a> {
    /// The frozen network (connectivity + input quantizer).
    pub net: &'a Network,
    /// Its compiled truth tables.
    pub tables: &'a NetworkTables,
    plan: PlanStore<'a>,
}

impl<'a> LutSim<'a> {
    /// Build a simulator, compiling a private [`EvalPlan`] for `net`.
    pub fn new(net: &'a Network, tables: &'a NetworkTables) -> Self {
        let plan = PlanStore::Owned(Box::new(EvalPlan::compile(net, tables)));
        LutSim { net, tables, plan }
    }

    /// Build a shim over an already-compiled plan (no recompilation).
    pub fn with_plan(
        net: &'a Network,
        tables: &'a NetworkTables,
        plan: &'a EvalPlan,
    ) -> Self {
        LutSim { net, tables, plan: PlanStore::Shared(plan) }
    }

    /// The compiled evaluation plan (the batched hot path).
    pub fn plan(&self) -> &EvalPlan {
        match &self.plan {
            PlanStore::Owned(p) => p,
            PlanStore::Shared(p) => p,
        }
    }

    /// Compile the bit-parallel 64-sample-per-word engine for this frozen
    /// network (the plan's throughput-oriented twin — see
    /// [`crate::sim::EngineSelect`] for when to prefer which).  Compilation
    /// maps the network to LUT6 netlists, so callers should do this once
    /// and reuse the engine, not per request.
    pub fn compile_bitslice(&self, workers: usize) -> BitsliceNet {
        BitsliceNet::compile(self.net, self.tables, workers)
    }

    /// Table-only forward pass over input codes (plan-backed).
    pub fn forward_codes(&self, in_codes: &[i32]) -> Vec<i32> {
        let plan = self.plan();
        let mut scratch = Scratch::for_plan(plan);
        plan.forward_codes(in_codes, &mut scratch)
    }

    /// The original naive walk: re-gathers fan-in indices through the nested
    /// `indices[a][j]` vectors and allocates per neuron.  Kept as an
    /// independent reference implementation — the plan is tested bit-exact
    /// against it, and `micro_hotpaths` uses it as the pre-plan baseline.
    pub fn forward_codes_reference(&self, in_codes: &[i32]) -> Vec<i32> {
        let cfg = &self.net.cfg;
        let mut codes = in_codes.to_vec();
        let mut gathered: Vec<i32> = Vec::new();
        for (l, lt) in self.tables.layers.iter().enumerate() {
            let n_out = cfg.widths[l + 1];
            let mut next = vec![0i32; n_out];
            for (j, nt) in lt.neurons.iter().enumerate() {
                let subs: Vec<i32> = nt
                    .poly
                    .iter()
                    .enumerate()
                    .map(|(a, t)| {
                        gathered.clear();
                        gathered.extend(
                            self.net.layers[l].indices[a][j].iter().map(|&s| codes[s]),
                        );
                        t.code_at(pack_poly_addr(&gathered, lt.in_bits))
                    })
                    .collect();
                next[j] = match &nt.adder {
                    Some(adder) => adder.code_at(pack_adder_addr(&subs, lt.sub_bits)),
                    None => subs[0],
                };
            }
            codes = next;
        }
        codes
    }

    /// Forward from raw [0,1] features; returns dequantized logits.
    pub fn forward(&self, x: &[f32]) -> Vec<f32> {
        let plan = self.plan();
        let mut scratch = Scratch::for_plan(plan);
        plan.forward(x, &mut scratch)
    }

    /// Predicted class (argmax over logits, NaN-safe; binary: logit > 0).
    pub fn predict(&self, x: &[f32]) -> usize {
        let plan = self.plan();
        let mut scratch = Scratch::for_plan(plan);
        plan.predict(x, &mut scratch)
    }

    /// Deployed-semantics test accuracy over the first `limit` test rows
    /// (0 = all).
    pub fn accuracy(&self, ds: &crate::data::Dataset, limit: usize) -> f64 {
        let n = if limit == 0 { ds.n_test() } else { ds.n_test().min(limit) };
        let plan = self.plan();
        let mut scratch = Scratch::for_plan(plan);
        let correct = (0..n)
            .filter(|&i| plan.predict(ds.test_row(i), &mut scratch) == ds.y_test[i])
            .count();
        correct as f64 / n.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lut::tables::compile_network;
    use crate::nn::config;
    use crate::util::rng::Rng;

    /// Bit-exact equivalence: tables == float fixed-point model, for every
    /// A and degree combination we ship — through both the plan-backed path
    /// and the naive reference walk.
    #[test]
    fn lutsim_equals_network_forward() {
        for (a, d) in [(1, 1), (2, 1), (3, 1), (1, 2), (2, 2), (2, 3)] {
            let cfg = config::uniform("t", &[8, 6, 3], 2, 2, 3, 3, 3, d, a, 3);
            let net = Network::random(&cfg, &mut Rng::new(a as u64 * 10 + d as u64));
            let tables = compile_network(&net, 1);
            let sim = LutSim::new(&net, &tables);
            let mut rng = Rng::new(5);
            for _ in 0..200 {
                let x: Vec<f32> = (0..8).map(|_| rng.f32()).collect();
                let codes = net.quantize_input(&x);
                let want = net.forward_codes(&codes);
                assert_eq!(sim.forward_codes(&codes), want, "A={a} D={d}");
                assert_eq!(sim.forward_codes_reference(&codes), want, "A={a} D={d}");
            }
        }
    }

    /// The throughput engine compiled off a shim agrees with the shim.
    #[test]
    fn compiled_bitslice_matches_shim() {
        let cfg = config::uniform("t", &[8, 6, 3], 2, 2, 3, 3, 3, 2, 2, 3);
        let net = Network::random(&cfg, &mut Rng::new(21));
        let tables = compile_network(&net, 1);
        let sim = LutSim::new(&net, &tables);
        let bits = sim.compile_bitslice(1);
        let mut rng = Rng::new(8);
        let xs: Vec<Vec<i32>> = (0..70)
            .map(|_| {
                let x: Vec<f32> = (0..8).map(|_| rng.f32()).collect();
                net.quantize_input(&x)
            })
            .collect();
        let mut scratch = bits.scratch();
        for (x, got) in xs.iter().zip(bits.forward_batch(&xs, &mut scratch)) {
            assert_eq!(got, sim.forward_codes(x));
        }
    }
}
