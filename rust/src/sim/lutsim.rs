//! LUT-network simulator — evaluates the *frozen tables* (deployed
//! semantics), independent of the float model.  This is the software twin of
//! the FPGA datapath and the reference for the Verilog testbench; a property
//! test pins it bit-exactly to `Network::forward_codes`.

use crate::lut::tables::{pack_adder_addr, pack_poly_addr, NetworkTables};
use crate::nn::network::Network;

/// Simulator over a frozen network (borrows the trained network only for
/// its connectivity and input quantizer).
pub struct LutSim<'a> {
    pub net: &'a Network,
    pub tables: &'a NetworkTables,
}

impl<'a> LutSim<'a> {
    pub fn new(net: &'a Network, tables: &'a NetworkTables) -> Self {
        LutSim { net, tables }
    }

    /// Table-only forward pass over input codes.
    pub fn forward_codes(&self, in_codes: &[i32]) -> Vec<i32> {
        let cfg = &self.net.cfg;
        let mut codes = in_codes.to_vec();
        let mut gathered: Vec<i32> = Vec::new();
        for (l, lt) in self.tables.layers.iter().enumerate() {
            let n_out = cfg.widths[l + 1];
            let mut next = vec![0i32; n_out];
            for (j, nt) in lt.neurons.iter().enumerate() {
                let subs: Vec<i32> = nt
                    .poly
                    .iter()
                    .enumerate()
                    .map(|(a, t)| {
                        gathered.clear();
                        gathered.extend(
                            self.net.layers[l].indices[a][j].iter().map(|&s| codes[s]),
                        );
                        t.code_at(pack_poly_addr(&gathered, lt.in_bits))
                    })
                    .collect();
                next[j] = match &nt.adder {
                    Some(adder) => adder.code_at(pack_adder_addr(&subs, lt.sub_bits)),
                    None => subs[0],
                };
            }
            codes = next;
        }
        codes
    }

    /// Forward from raw [0,1] features; returns dequantized logits.
    pub fn forward(&self, x: &[f32]) -> Vec<f32> {
        let codes = self.forward_codes(&self.net.quantize_input(x));
        let l = self.net.cfg.n_layers() - 1;
        let step = self.net.out_step(l);
        codes.iter().map(|&c| c as f32 * step).collect()
    }

    pub fn predict(&self, x: &[f32]) -> usize {
        let logits = self.forward(x);
        if self.net.cfg.n_classes == 1 {
            (logits[0] > 0.0) as usize
        } else {
            logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap()
        }
    }

    pub fn accuracy(&self, ds: &crate::data::Dataset, limit: usize) -> f64 {
        let n = if limit == 0 { ds.n_test() } else { ds.n_test().min(limit) };
        let correct =
            (0..n).filter(|&i| self.predict(ds.test_row(i)) == ds.y_test[i]).count();
        correct as f64 / n.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lut::tables::compile_network;
    use crate::nn::config;
    use crate::util::rng::Rng;

    /// Bit-exact equivalence: tables == float fixed-point model, for every
    /// A and degree combination we ship.
    #[test]
    fn lutsim_equals_network_forward() {
        for (a, d) in [(1, 1), (2, 1), (3, 1), (1, 2), (2, 2), (2, 3)] {
            let cfg = config::uniform("t", &[8, 6, 3], 2, 2, 3, 3, 3, d, a, 3);
            let net = Network::random(&cfg, &mut Rng::new(a as u64 * 10 + d as u64));
            let tables = compile_network(&net, 1);
            let sim = LutSim::new(&net, &tables);
            let mut rng = Rng::new(5);
            for _ in 0..200 {
                let x: Vec<f32> = (0..8).map(|_| rng.f32()).collect();
                let codes = net.quantize_input(&x);
                assert_eq!(
                    sim.forward_codes(&codes),
                    net.forward_codes(&codes),
                    "A={a} D={d}"
                );
            }
        }
    }
}
