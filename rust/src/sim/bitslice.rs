//! Bitsliced word-level execution engine over the mapped netlist,
//! lane-count generic (64–512 samples per op-stream walk).
//!
//! The paper's premise is that a neuron *is* a LUT network, and a LUT
//! network evaluated in software is fastest word-level: one machine word
//! holds the same wire for **every lane at once** (lane `s` = sample `s`),
//! so every gate costs a handful of bitwise ops *for the whole word*.  This
//! engine is the batched-serving counterpart of [`super::plan::EvalPlan`]:
//! the plan gathers codes and reads decoded tables one sample at a time
//! (lowest latency, cache-resident tables), the bitslice engine transposes
//! a word of samples into bit-planes once and then streams a flat op list
//! per layer (highest throughput when the mapped tables are shallow).
//!
//! Since the SIMD widening, the word is a [`crate::simd::Word`]: the
//! canonical `u64` (64 lanes), or a [`crate::simd::Blocks`] group of 2/4/8
//! consecutive 64-bit plane blocks (128/256/512 lanes) that the compiler
//! unrolls and — through the AVX2 `target_feature` wrappers selected by the
//! engine's [`LanePlan`] — vectorizes.  All kernels (`exec_ops`, the
//! Shannon [`lut_word`] kernel, `pack_word`/`unpack_word`) are generic over
//! `W`; the op stream itself is width-agnostic and compiled once.
//!
//! # Bit-plane layout
//!
//! A layer boundary carrying β-bit codes for `W` neurons is `W·β` planes;
//! each plane is `lanes/64` 64-bit blocks, block `i` holding samples
//! `64·i..64·(i+1)`:
//!
//! ```text
//!                      lane 63        …        lane 1   lane 0
//!                   ┌───────────┬───────────┬─────────┬─────────┐
//!   planes[j·β + b] │ sample 63 │     …     │ sample 1│ sample 0│  block 0
//!                   ├───────────┼───────────┼─────────┼─────────┤
//!                   │ sample 127│     …     │ s. 65   │ s. 64   │  block 1
//!                   └───────────┴───────────┴─────────┴─────────┘  …
//!                      bit b of neuron j's code, all samples
//!
//!   planes[0]      = neuron 0, code bit 0
//!   planes[j·β+b]  = neuron j, code bit b      (raw two's-complement bits)
//! ```
//!
//! This is exactly the wire numbering the LUT6 mapper uses
//! (`wire = src·in_bits + bit`), so a layer's **output planes are the next
//! layer's input planes verbatim** — transposition happens only at the
//! network edge.  Because block `i` of a wide plane is bit-for-bit the
//! scalar `u64` plane of sample chunk `i`, the shard/wire handoff keeps
//! shipping canonical 64-bit planes regardless of the local kernel width
//! (PLW2 frames and the hazard arguments are untouched).
//!
//! # Transposition cost model
//!
//! - **Pack** (codes → planes, network input): `width·β` planes built from
//!   ≤lanes samples — `O(width·β·lanes)` bit ops per word, ~`width·β` ops
//!   per sample.  **Unpack** (planes → codes, network output) is symmetric.
//! - **Evaluate**: one LUT6 op costs at most 63 word-muxes (3 bit ops each)
//!   for all lanes — ~3·(64/lanes) ops *per sample* versus the plan's
//!   per-sample gather + address assembly + table read; shared-input LUT
//!   groups (the bits of one table) drop further to one minterm expansion
//!   (`2^{k+1}` ANDs) plus ~`2^{k-1}` ORs per mask.  Widening the word
//!   divides the per-sample cost of *every* op — and amortizes the per-op
//!   dispatch/recursion overhead — by `lanes/64`.
//! - The engine therefore wins when the mapped netlist is shallow (βF ≤ ~8:
//!   the paper's Table IV Add2 design point, where every table bit is a
//!   single LUT6) and batches span full words; the plan stays ahead for
//!   deep-table geometries (βF ≈ 12+) and tiny batches, which is why the
//!   coordinator routes on batch size ([`super::EngineSelect`], crossover
//!   derived from the active lane width).
//!
//! Ragged tails (batches not divisible by the lane count) are handled with
//! [`lane_mask`]/[`Word::lane_mask`]: invalid lanes are packed as zero,
//! evaluated like any other lane, and never unpacked.
//!
//! The 64-bit bit-plane layout doubles as the shard handoff format of the
//! intra-sample sharded engine ([`crate::sim::shard`]); the full engine map
//! and the SIMD dispatch ladder live in `ARCHITECTURE.md` §3–§5 at the
//! repository root.

use std::collections::HashMap;

use crate::lut::mapper::{map_network_of, MappedNetwork};
use crate::lut::netlist::{lut_word, Netlist, Node};
use crate::lut::tables::{LayerTables, NetworkTables};
use crate::nn::network::Network;
use crate::nn::quant::{from_twos_complement, unsigned_code};
use crate::simd::{self, Blocks, KernelPath, LanePlan, Word};
use crate::util::pool::parallel_map;

/// Samples per canonical 64-bit plane block (lanes of one `u64`), the unit
/// of the shard/wire handoff format.  Wide kernels run multiples of this.
pub const WORD: usize = 64;

/// Valid-lane mask for one 64-bit plane block holding `n_valid` samples:
/// lane `s` is set iff sample `s` exists.  Saturates at a full block
/// (`n_valid >= 64`), so the remainder of any batch size can be passed
/// directly.  Wide words use [`Word::lane_mask`], which applies this per
/// block.
#[inline]
pub fn lane_mask(n_valid: usize) -> u64 {
    simd::lane_mask64(n_valid)
}

/// One step of the flat, topologically-ordered per-layer op stream.  All
/// operands are node slots; no op owns heap memory, so executing a layer is
/// a single linear walk.  Crate-visible so [`crate::sim::shard`] can build
/// per-shard sub-streams over the same executor.
#[derive(Debug, Clone, Copy)]
pub(crate) enum Op {
    Const { out: u32, ones: bool },
    /// A physical LUT evaluated through the shared word-level
    /// mask-decomposition kernel ([`lut_word`]).
    Lut { out: u32, mask: u64, n_in: u8, ins: [u32; 6] },
    Mux { out: u32, sel: u32, lo: u32, hi: u32 },
    /// ≥2 LUTs over the *identical* input tuple (typically the output bits
    /// of one truth table): one shared minterm expansion, then one OR-reduce
    /// per mask.  `(node, mask)` pairs live in `OpStream::lut_nodes` /
    /// `lut_masks` at `start..start+len`.
    Group { n_in: u8, ins: [u32; 6], start: u32, len: u32 },
}

/// A compiled, self-contained op stream over compact local node slots:
/// input bindings, the ops, and the backing store for [`Op::Group`]
/// members.  Built by [`flatten_cone`]; executed by [`exec_ops`] (at any
/// lane width — the stream itself is width-agnostic) after the caller has
/// bound the input planes.
pub(crate) struct OpStream {
    /// `(node slot, input wire)` — wire = `src·in_bits + bit`.
    pub(crate) bind: Vec<(u32, u32)>,
    pub(crate) ops: Vec<Op>,
    /// Backing store for [`Op::Group`] members (local node slots).
    pub(crate) lut_nodes: Vec<u32>,
    pub(crate) lut_masks: Vec<u64>,
    /// Local node-slot count (size of the `vals` scratch this stream needs).
    pub(crate) n_nodes: usize,
}

/// One compiled layer: the op stream plus the output roots.
pub(crate) struct LayerOps {
    pub(crate) stream: OpStream,
    /// Output node (local slot) of bit `b` of neuron `j` at `j·out_bits + b`.
    pub(crate) roots: Vec<u32>,
    pub(crate) n_out: usize,
    pub(crate) out_bits: u32,
    pub(crate) signed_out: bool,
}

/// Engine shape statistics (for benches and logs).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct BitsliceStats {
    /// Compiled layer count.
    pub layers: usize,
    /// Total netlist nodes across all layers.
    pub nodes: usize,
    /// LUTs evaluated individually through the Shannon kernel.
    pub lut_ops: usize,
    /// LUTs folded into shared-input minterm groups.
    pub grouped_luts: usize,
    /// Shared-input minterm groups.
    pub groups: usize,
    /// Word-level 2:1 mux ops.
    pub mux_ops: usize,
}

/// A frozen network compiled for bit-parallel word-level execution.
/// Self-contained (owns its op streams) — `Send + Sync`, share behind `Arc`.
///
/// The op streams are lane-width agnostic; the carried [`LanePlan`] (see
/// [`BitsliceNet::with_lane_plan`]) only selects which monomorphized kernel
/// [`BitsliceNet::forward_batch_codes`] dispatches to.  [`compile`]
/// defaults to the canonical 64-lane scalar plan.
///
/// [`compile`]: BitsliceNet::compile
pub struct BitsliceNet {
    pub(crate) layers: Vec<LayerOps>,
    pub(crate) n_features: usize,
    n_outputs: usize,
    /// Input quantizer width (β of layer 0).
    pub(crate) in_bits: u32,
    /// Dequantization step of the output codes.
    out_step: f32,
    /// Bit-planes needed at the widest layer boundary.
    max_wires: usize,
    max_nodes: usize,
    stats: BitsliceStats,
    /// Active lane width (`plan.lanes`, a supported multiple of 64).
    /// Redundant with `plan` on purpose: `sim::verify` cross-checks it.
    pub(crate) lanes: usize,
    /// 64-bit plane blocks per scratch word (`lanes / 64`) — the size
    /// contract `sim::verify`'s `scratch-blocks` invariant pins.
    pub(crate) plane_blocks: usize,
    pub(crate) plan: LanePlan,
}

/// Reusable per-word scratch at lane width `W::LANES`: double-buffered
/// boundary planes plus the per-node value array.  A forward word performs
/// zero heap allocation.
pub struct WideScratch<W: Word> {
    planes: Vec<W>,
    next: Vec<W>,
    vals: Vec<W>,
}

/// The canonical 64-lane scratch ([`BitsliceNet::forward_batch`], shard
/// handoff staging).
pub type BitsliceScratch = WideScratch<u64>;

impl BitsliceNet {
    /// Map `net` to LUT6 netlists and compile them into op streams, at the
    /// canonical 64-lane scalar plan.
    pub fn compile(net: &Network, tables: &NetworkTables, workers: usize) -> BitsliceNet {
        let mapped = map_network_of(net, tables, workers);
        Self::from_mapped(net, tables, &mapped)
    }

    /// [`BitsliceNet::compile`] with an explicit lane plan (see
    /// [`crate::simd::resolve`]) — the op streams are identical, only the
    /// kernel dispatch changes.
    pub fn compile_wide(
        net: &Network,
        tables: &NetworkTables,
        workers: usize,
        plan: LanePlan,
    ) -> BitsliceNet {
        Self::compile(net, tables, workers).with_lane_plan(plan)
    }

    /// Compile from an already-mapped network (no re-mapping), at the
    /// canonical 64-lane scalar plan.
    pub fn from_mapped(
        net: &Network,
        tables: &NetworkTables,
        mapped: &MappedNetwork,
    ) -> BitsliceNet {
        let cfg = &net.cfg;
        let mut stats = BitsliceStats::default();
        let layers: Vec<LayerOps> = mapped
            .layers
            .iter()
            .zip(&tables.layers)
            .map(|(ml, lt)| flatten_layer(ml, lt, &mut stats))
            .collect();
        stats.layers = layers.len();
        let max_wires = (0..=cfg.n_layers())
            .map(|b| cfg.widths[b] * cfg.beta[b] as usize)
            .max()
            .unwrap_or(0);
        let last = cfg.n_layers() - 1;
        let plan = LanePlan::scalar();
        BitsliceNet {
            max_nodes: layers.iter().map(|l| l.stream.n_nodes).max().unwrap_or(0),
            layers,
            n_features: cfg.widths[0],
            n_outputs: cfg.widths[cfg.n_layers()],
            in_bits: cfg.beta[0],
            out_step: net.out_step(last),
            max_wires,
            stats,
            lanes: plan.lanes,
            plane_blocks: plan.blocks(),
            plan,
        }
    }

    /// Re-plan the engine's lane width without recompiling the op streams
    /// (they are width-agnostic).  Cheap — metadata only.
    pub fn with_lane_plan(mut self, plan: LanePlan) -> BitsliceNet {
        self.lanes = plan.lanes;
        self.plane_blocks = plan.blocks();
        self.plan = plan;
        self
    }

    /// The active lane plan (width + kernel path).
    pub fn lane_plan(&self) -> LanePlan {
        self.plan
    }

    /// Active sample lanes per op-stream walk.
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Input feature count (width of layer 0).
    pub fn n_features(&self) -> usize {
        self.n_features
    }

    /// Output neuron count (width of the last layer boundary).
    pub fn n_outputs(&self) -> usize {
        self.n_outputs
    }

    /// Engine shape statistics (op and group counts, for benches and logs).
    pub fn stats(&self) -> BitsliceStats {
        self.stats
    }

    /// Allocate canonical 64-lane scratch (reusable across words; one per
    /// thread).  Wide kernels size their own scratch internally.
    pub fn scratch(&self) -> BitsliceScratch {
        self.wide_scratch::<u64>()
    }

    /// Allocate scratch sized for this engine at lane width `W::LANES`.
    fn wide_scratch<W: Word>(&self) -> WideScratch<W> {
        WideScratch {
            planes: vec![W::zero(); self.max_wires],
            next: vec![W::zero(); self.max_wires],
            vals: vec![W::zero(); self.max_nodes],
        }
    }

    /// Batched code-level forward pass over the canonical 64-lane path,
    /// ragged tail masked.  Bit-exact with `EvalPlan::forward_batch` and
    /// `Network::forward_codes` — and, by the width-grid tests, with
    /// [`BitsliceNet::forward_batch_codes`] at every lane plan.  The shard
    /// engine and handoff staging build on this path, so it stays 64-lane
    /// regardless of the compiled plan.
    pub fn forward_batch(
        &self,
        xs: &[Vec<i32>],
        scratch: &mut BitsliceScratch,
    ) -> Vec<Vec<i32>> {
        let mut out = Vec::with_capacity(xs.len());
        for word in xs.chunks(WORD) {
            self.forward_chunk(word, scratch, &mut out);
        }
        out
    }

    /// Batched code-level forward pass at the engine's compiled lane width:
    /// one op-stream walk retires `lanes` samples.  Scratch is allocated
    /// once per call and reused across chunks.  Bit-exact with
    /// [`BitsliceNet::forward_batch`].
    pub fn forward_batch_codes(&self, xs: &[Vec<i32>]) -> Vec<Vec<i32>> {
        match self.plan.path {
            KernelPath::Scalar => self.run_codes::<u64>(xs),
            KernelPath::Blocks2 => self.run_codes::<Blocks<2>>(xs),
            KernelPath::Blocks4 => self.run_codes::<Blocks<4>>(xs),
            KernelPath::Blocks8 => self.run_codes::<Blocks<8>>(xs),
            KernelPath::Avx2 => {
                #[cfg(target_arch = "x86_64")]
                {
                    if std::arch::is_x86_feature_detected!("avx2") {
                        // SAFETY: AVX2 support re-verified on this CPU on
                        // the line above; the wrapper only enables avx2.
                        return unsafe { self.run_codes_avx2(xs) };
                    }
                }
                self.run_codes::<Blocks<4>>(xs)
            }
            KernelPath::Avx512 => {
                #[cfg(target_arch = "x86_64")]
                {
                    if std::arch::is_x86_feature_detected!("avx2") {
                        // SAFETY: AVX2 support re-verified on this CPU on
                        // the line above; the wrapper only enables avx2
                        // (512-lane blocks run as 2× ymm per op — see
                        // `crate::simd` module docs).
                        return unsafe { self.run_codes_avx512(xs) };
                    }
                }
                self.run_codes::<Blocks<8>>(xs)
            }
        }
    }

    /// Monomorphized batch loop: chunk by `W::LANES`, one reused scratch.
    fn run_codes<W: Word>(&self, xs: &[Vec<i32>]) -> Vec<Vec<i32>> {
        let mut out = Vec::with_capacity(xs.len());
        let mut scratch = self.wide_scratch::<W>();
        for chunk in xs.chunks(W::LANES) {
            self.forward_chunk(chunk, &mut scratch, &mut out);
        }
        out
    }

    /// [`run_codes`](Self::run_codes) at `Blocks<4>` compiled with the avx2
    /// feature set, so LLVM lowers the 4-block ops to ymm instructions.
    ///
    /// # Safety
    /// The CPU must support AVX2 (`is_x86_feature_detected!("avx2")`).
    #[cfg(target_arch = "x86_64")]
    #[target_feature(enable = "avx2")]
    unsafe fn run_codes_avx2(&self, xs: &[Vec<i32>]) -> Vec<Vec<i32>> {
        self.run_codes::<Blocks<4>>(xs)
    }

    /// [`run_codes`](Self::run_codes) at `Blocks<8>` compiled with the avx2
    /// feature set (2× ymm per block op on stable; full zmm under
    /// `-C target-cpu=native`).
    ///
    /// # Safety
    /// The CPU must support AVX2 (`is_x86_feature_detected!("avx2")`).
    #[cfg(target_arch = "x86_64")]
    #[target_feature(enable = "avx2")]
    unsafe fn run_codes_avx512(&self, xs: &[Vec<i32>]) -> Vec<Vec<i32>> {
        self.run_codes::<Blocks<8>>(xs)
    }

    /// Batched feature-level forward pass: quantize, run lane-width chunks
    /// in parallel (one scratch per chunk), dequantize.  Output order
    /// matches `xs`.  Runs at the compiled lane plan.
    pub fn forward_batch_f32(&self, xs: &[Vec<f32>], workers: usize) -> Vec<Vec<f32>> {
        if xs.is_empty() {
            return Vec::new();
        }
        let chunks: Vec<&[Vec<f32>]> = xs.chunks(self.lanes).collect();
        let per_chunk: Vec<Vec<Vec<f32>>> = parallel_map(&chunks, workers, |_, chunk| {
            let codes: Vec<Vec<i32>> = chunk
                .iter()
                .map(|x| {
                    assert_eq!(x.len(), self.n_features, "feature width mismatch");
                    x.iter().map(|&v| unsigned_code(v, self.in_bits, 1.0)).collect()
                })
                .collect();
            self.forward_batch_codes(&codes)
                .into_iter()
                .map(|row| row.iter().map(|&c| c as f32 * self.out_step).collect())
                .collect()
        });
        per_chunk.into_iter().flatten().collect()
    }

    /// One ≤`W::LANES`-sample word: pack → per-layer op streams → unpack.
    #[inline]
    fn forward_chunk<W: Word>(
        &self,
        word: &[Vec<i32>],
        scratch: &mut WideScratch<W>,
        out: &mut Vec<Vec<i32>>,
    ) {
        if word.is_empty() {
            return;
        }
        debug_assert!(word.len() <= W::LANES);
        for row in word {
            assert_eq!(row.len(), self.n_features, "input width mismatch");
        }
        pack_word(word, self.in_bits, &mut scratch.planes);
        for lp in &self.layers {
            lp.run(&scratch.planes, &mut scratch.vals);
            for (plane, &root) in scratch.next.iter_mut().zip(&lp.roots) {
                *plane = scratch.vals[root as usize];
            }
            std::mem::swap(&mut scratch.planes, &mut scratch.next);
        }
        let last = self.layers.last().expect("at least one layer");
        unpack_word(
            &scratch.planes,
            last.n_out,
            last.out_bits,
            last.signed_out,
            word.len(),
            out,
        );
    }
}

/// Transpose ≤`W::LANES` samples of unsigned input codes into bit-planes
/// (`planes[f·bits + b]`, lane `s` = sample `s`, block `s/64` holding
/// sample chunk `s/64`); invalid lanes of a ragged word are left zero (see
/// [`lane_mask`]).  Block `i` of a wide plane is bit-for-bit the scalar
/// 64-lane pack of sample chunk `i` — the identity that keeps the
/// shard/wire handoff format canonical.  Shared with the sharded engine
/// ([`crate::sim::shard`]), whose staging differs only in buffer type.
pub(crate) fn pack_word<W: Word>(word: &[Vec<i32>], bits: u32, planes: &mut [W]) {
    let bits = bits as usize;
    let n_planes = word[0].len() * bits;
    planes[..n_planes].fill(W::zero());
    for (blk, chunk) in word.chunks(WORD).enumerate() {
        for (s, row) in chunk.iter().enumerate() {
            for (f, &c) in row.iter().enumerate() {
                let c = c as u32 as u64;
                for (b, p) in planes[f * bits..(f + 1) * bits].iter_mut().enumerate() {
                    let cur = p.block(blk);
                    p.set_block(blk, cur | (((c >> b) & 1) << s));
                }
            }
        }
    }
    // Ragged-tail invariant: lanes beyond the word hold zero (the clear
    // above plus the bounded OR loop guarantee it; unpack never reads them).
    debug_assert!({
        let m = W::lane_mask(word.len());
        planes[..n_planes]
            .iter()
            .all(|p| (0..W::BLOCKS).all(|i| p.block(i) & !m.block(i) == 0))
    });
}

/// Inverse of [`pack_word`] at the network edge: decode the first
/// `n_valid` lanes of `n_out·out_bits` output planes back into per-sample
/// code rows (two's-complement when `signed_out`), appending to `out`.
/// Shared between [`BitsliceNet::forward_batch`] and the sharded engine so
/// the bit-plane layout lives in exactly one pack/unpack pair.
pub(crate) fn unpack_word<W: Word>(
    planes: &[W],
    n_out: usize,
    out_bits: u32,
    signed_out: bool,
    n_valid: usize,
    out: &mut Vec<Vec<i32>>,
) {
    let ob = out_bits as usize;
    for s in 0..n_valid {
        let (blk, lane) = (s / WORD, s % WORD);
        let mut row = Vec::with_capacity(n_out);
        for j in 0..n_out {
            let mut raw = 0u32;
            for (b, plane) in planes[j * ob..(j + 1) * ob].iter().enumerate() {
                raw |= (((plane.block(blk) >> lane) & 1) as u32) << b;
            }
            row.push(if signed_out {
                from_twos_complement(raw, out_bits)
            } else {
                raw as i32
            });
        }
        out.push(row);
    }
}

impl LayerOps {
    /// Execute the op stream for one word.  `planes` are this layer's input
    /// bit-planes; node values land in `vals`.
    #[inline]
    fn run<W: Word>(&self, planes: &[W], vals: &mut [W]) {
        for &(node, wire) in &self.stream.bind {
            vals[node as usize] = planes[wire as usize];
        }
        exec_ops(&self.stream, vals);
    }
}

/// Execute an [`OpStream`]'s ops over one word of lane width `W::LANES`.
/// The caller must have bound the stream's input slots (`stream.bind`) into
/// `vals` first — the binding source differs between the whole-layer engine
/// (plain plane slices) and the sharded engine (atomic handoff buffers),
/// which is why binding is not part of this function.
#[inline]
pub(crate) fn exec_ops<W: Word>(stream: &OpStream, vals: &mut [W]) {
    for op in &stream.ops {
        match *op {
            Op::Const { out, ones } => {
                vals[out as usize] = if ones { W::ones() } else { W::zero() }
            }
            Op::Lut { out, mask, n_in, ins } => {
                let mut a = [W::zero(); 6];
                for (slot, &i) in a.iter_mut().zip(&ins[..n_in as usize]) {
                    *slot = vals[i as usize];
                }
                vals[out as usize] = lut_word(mask, &a[..n_in as usize]);
            }
            Op::Mux { out, sel, lo, hi } => {
                let (s, l, h) = (vals[sel as usize], vals[lo as usize], vals[hi as usize]);
                vals[out as usize] = l ^ (s & (l ^ h));
            }
            Op::Group { n_in, ins, start, len } => {
                // Shared minterm expansion: buf[a] = word where lane s is
                // set iff the k inputs of sample s spell address a.
                let k = n_in as usize;
                let mut buf = [W::zero(); 64];
                buf[0] = W::ones();
                let mut cur = 1usize;
                for &i in &ins[..k] {
                    let x = vals[i as usize];
                    for j in 0..cur {
                        let v = buf[j];
                        buf[j + cur] = v & x;
                        buf[j] = v & !x;
                    }
                    cur <<= 1;
                }
                let full = if cur == 64 { !0u64 } else { (1u64 << cur) - 1 };
                let lo = start as usize;
                let hi = lo + len as usize;
                for (&node, &raw_mask) in
                    stream.lut_nodes[lo..hi].iter().zip(&stream.lut_masks[lo..hi])
                {
                    let mask = raw_mask & full;
                    // The 2^k minterms partition all lanes, so
                    // OR(set minterms) == !OR(clear minterms): reduce
                    // whichever polarity has fewer terms.  (`mask` indexes
                    // minterms, not lanes — it stays a scalar u64.)
                    let (mut rem, invert) = if (mask.count_ones() as usize) * 2 <= cur {
                        (mask, false)
                    } else {
                        (!mask & full, true)
                    };
                    let mut acc = W::zero();
                    while rem != 0 {
                        acc = acc | buf[rem.trailing_zeros() as usize];
                        rem &= rem - 1;
                    }
                    vals[node as usize] = if invert { !acc } else { acc };
                }
            }
        }
    }
}

/// Flatten the `keep`-marked cone of a netlist into an [`OpStream`] with
/// compact local node numbering.  Nodes are already in topological order
/// (the netlist arena appends inputs before users), so the kept
/// subsequence stays topological; LUTs sharing an identical input tuple
/// (within the kept set) are folded into one [`Op::Group`], emitted at the
/// position of the group's *first* member — safe because every member has
/// the same (already-ready) inputs and every consumer sits after its
/// producer.  Returns the stream plus the old-id → local-slot map
/// (`u32::MAX` for dropped nodes), which callers use to translate root
/// node ids.  `keep` must be closed under node inputs.
pub(crate) fn flatten_cone(nl: &Netlist, keep: &[bool]) -> (OpStream, Vec<u32>) {
    debug_assert_eq!(keep.len(), nl.nodes.len());
    // Local numbering: kept nodes in id order.
    let mut map = vec![u32::MAX; nl.nodes.len()];
    let mut n_local = 0u32;
    for (id, &k) in keep.iter().enumerate() {
        if k {
            map[id] = n_local;
            n_local += 1;
        }
    }
    // Pass 1: collect kept LUT nodes by identical input tuple.
    let mut group_of: HashMap<&[u32], usize> = HashMap::new();
    let mut members: Vec<Vec<(u32, u64)>> = Vec::new();
    for (id, node) in nl.nodes.iter().enumerate() {
        if !keep[id] {
            continue;
        }
        if let Node::Lut { inputs, mask } = node {
            let g = *group_of.entry(inputs.as_slice()).or_insert_with(|| {
                members.push(Vec::new());
                members.len() - 1
            });
            members[g].push((id as u32, *mask));
        }
    }
    // Pass 2: emit ops in node order.
    let mut bind = Vec::new();
    let mut ops = Vec::new();
    let mut lut_nodes = Vec::new();
    let mut lut_masks = Vec::new();
    for (id, node) in nl.nodes.iter().enumerate() {
        if !keep[id] {
            continue;
        }
        let out = map[id];
        match node {
            Node::Input { wire } => bind.push((out, *wire)),
            Node::Const(v) => ops.push(Op::Const { out, ones: *v }),
            Node::Mux { sel, lo, hi, .. } => {
                ops.push(Op::Mux {
                    out,
                    sel: map[*sel as usize],
                    lo: map[*lo as usize],
                    hi: map[*hi as usize],
                });
            }
            Node::Lut { inputs, mask } => {
                let group = &members[group_of[inputs.as_slice()]];
                if group[0].0 != id as u32 {
                    continue; // evaluated with the group's first member
                }
                let mut ins = [0u32; 6];
                for (slot, &i) in ins.iter_mut().zip(inputs) {
                    *slot = map[i as usize];
                }
                let n_in = inputs.len() as u8;
                if group.len() == 1 {
                    ops.push(Op::Lut { out, mask: *mask, n_in, ins });
                } else {
                    let start = lut_nodes.len() as u32;
                    for &(node_id, m) in group {
                        lut_nodes.push(map[node_id as usize]);
                        lut_masks.push(m);
                    }
                    ops.push(Op::Group { n_in, ins, start, len: group.len() as u32 });
                }
            }
        }
    }
    let stream = OpStream { bind, ops, lut_nodes, lut_masks, n_nodes: n_local as usize };
    (stream, map)
}

/// Mark the backward cone of `roots` in `keep` (closed under node inputs).
pub(crate) fn mark_cone(nl: &Netlist, roots: &[u32], keep: &mut [bool]) {
    let mut stack: Vec<u32> = roots.iter().copied().filter(|&r| !keep[r as usize]).collect();
    while let Some(id) = stack.pop() {
        if keep[id as usize] {
            continue;
        }
        keep[id as usize] = true;
        match &nl.nodes[id as usize] {
            Node::Input { .. } | Node::Const(_) => {}
            Node::Lut { inputs, .. } => {
                stack.extend(inputs.iter().copied().filter(|&i| !keep[i as usize]));
            }
            Node::Mux { sel, lo, hi, .. } => {
                for c in [*sel, *lo, *hi] {
                    if !keep[c as usize] {
                        stack.push(c);
                    }
                }
            }
        }
    }
}

/// Flatten one whole mapped layer into an op stream.  Only the backward
/// cone of the layer's output roots is kept: the mapper's adder-stage
/// support reduction can orphan poly sub-bit nodes the adder ignores
/// (A > 1), and keeping them would execute dead word-ops every pass.
fn flatten_layer(
    ml: &crate::lut::mapper::MappedLayer,
    lt: &LayerTables,
    stats: &mut BitsliceStats,
) -> LayerOps {
    let nl = &ml.netlist;
    let mut keep = vec![false; nl.nodes.len()];
    for bits in &ml.roots {
        mark_cone(nl, bits, &mut keep);
    }
    let (stream, map) = flatten_cone(nl, &keep);
    stats.nodes += stream.n_nodes;
    stats.grouped_luts += stream.lut_nodes.len();
    for op in &stream.ops {
        match op {
            Op::Lut { .. } => stats.lut_ops += 1,
            Op::Group { .. } => stats.groups += 1,
            Op::Mux { .. } => stats.mux_ops += 1,
            Op::Const { .. } => {}
        }
    }
    let out_bits = lt.out_bits;
    let mut roots = Vec::with_capacity(ml.roots.len() * out_bits as usize);
    for bits in &ml.roots {
        debug_assert_eq!(bits.len(), out_bits as usize);
        roots.extend(bits.iter().map(|&n| map[n as usize]));
    }
    LayerOps { stream, roots, n_out: ml.roots.len(), out_bits, signed_out: lt.signed_out }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lut::tables::compile_network;
    use crate::nn::config;
    use crate::sim::plan::{EvalPlan, Scratch};
    use crate::simd::SimdLevel;
    use crate::util::rng::Rng;

    #[test]
    fn lane_mask_covers_ragged_tails() {
        assert_eq!(lane_mask(0), 0);
        assert_eq!(lane_mask(1), 0b1);
        assert_eq!(lane_mask(63), u64::MAX >> 1);
        assert_eq!(lane_mask(64), u64::MAX);
        assert_eq!(lane_mask(65), u64::MAX, "saturates past a full word");
    }

    /// The same `(A, degree)` grid the plan tests pin.
    const GRID: [(usize, u32); 6] = [(1, 1), (2, 1), (3, 1), (1, 2), (2, 2), (2, 3)];

    fn grid_net(a: usize, d: u32) -> (Network, NetworkTables) {
        let cfg = config::uniform("bits-t", &[8, 6, 3], 2, 2, 3, 3, 3, d, a, 3);
        let net = Network::random(&cfg, &mut Rng::new(a as u64 * 100 + d as u64));
        let tables = compile_network(&net, 1);
        (net, tables)
    }

    /// Bit-exactness across the grid: bitslice == plan == fixed-point model,
    /// on a batch spanning two full words plus a ragged tail.
    #[test]
    fn bitslice_equals_plan_and_network_on_grid() {
        for (a, d) in GRID {
            let (net, tables) = grid_net(a, d);
            let plan = EvalPlan::compile(&net, &tables);
            let bits = BitsliceNet::compile(&net, &tables, 1);
            let mut rng = Rng::new(9);
            let xs: Vec<Vec<i32>> = (0..(2 * WORD + 11))
                .map(|_| {
                    let x: Vec<f32> = (0..8).map(|_| rng.f32()).collect();
                    net.quantize_input(&x)
                })
                .collect();
            let mut bscratch = bits.scratch();
            let got = bits.forward_batch(&xs, &mut bscratch);
            let mut pscratch = Scratch::for_plan(&plan);
            assert_eq!(got, plan.forward_batch(&xs, &mut pscratch), "A={a} D={d}");
            for (x, row) in xs.iter().zip(&got) {
                assert_eq!(row, &net.forward_codes(x), "A={a} D={d}");
            }
        }
    }

    /// Ragged-tail coverage: 0, 1, 63, 64 and 65-sample batches all agree
    /// with the plan, through one reused scratch.
    #[test]
    fn ragged_batches_match_plan() {
        let (net, tables) = grid_net(2, 2);
        let plan = EvalPlan::compile(&net, &tables);
        let bits = BitsliceNet::compile(&net, &tables, 1);
        let mut bscratch = bits.scratch();
        let mut pscratch = Scratch::for_plan(&plan);
        let mut rng = Rng::new(31);
        for n in [0usize, 1, 63, 64, 65] {
            let xs: Vec<Vec<i32>> = (0..n)
                .map(|_| {
                    let x: Vec<f32> = (0..8).map(|_| rng.f32()).collect();
                    net.quantize_input(&x)
                })
                .collect();
            let got = bits.forward_batch(&xs, &mut bscratch);
            assert_eq!(got.len(), n);
            assert_eq!(got, plan.forward_batch(&xs, &mut pscratch), "batch {n}");
        }
    }

    /// The f32 entry point matches the plan's (same quantizer, same
    /// dequantization step), sequentially and fanned out over workers.
    #[test]
    fn forward_batch_f32_matches_plan() {
        let (net, tables) = grid_net(2, 1);
        let plan = EvalPlan::compile(&net, &tables);
        let bits = BitsliceNet::compile(&net, &tables, 1);
        let mut rng = Rng::new(5);
        let xs: Vec<Vec<f32>> =
            (0..(WORD + 9)).map(|_| (0..8).map(|_| rng.f32()).collect()).collect();
        for workers in [1usize, 3] {
            assert_eq!(
                bits.forward_batch_f32(&xs, workers),
                plan.forward_batch_f32(&xs, 1),
                "workers={workers}"
            );
        }
        assert!(bits.forward_batch_f32(&[], 4).is_empty());
        let _ = net;
    }

    /// Grouping must fold the multi-bit tables (shared input tuples) without
    /// changing results — sanity check that groups actually form.
    #[test]
    fn shared_input_tables_form_groups() {
        let (net, tables) = grid_net(2, 1);
        let bits = BitsliceNet::compile(&net, &tables, 1);
        let st = bits.stats();
        assert!(st.groups > 0, "expected shared-input LUT groups, got {st:?}");
        assert!(st.grouped_luts >= 2 * st.groups);
        assert_eq!(st.layers, 2);
        assert!(st.nodes > 0);
    }

    /// The default compile is the canonical 64-lane scalar plan, and its
    /// wide dispatcher is the same path as `forward_batch`.
    #[test]
    fn default_compile_is_canonical_64_lane() {
        let (net, tables) = grid_net(1, 1);
        let bits = BitsliceNet::compile(&net, &tables, 1);
        assert_eq!(bits.lane_plan(), LanePlan::scalar());
        assert_eq!(bits.lanes(), 64);
        assert_eq!(bits.plane_blocks, 1);
        let mut rng = Rng::new(3);
        let xs: Vec<Vec<i32>> = (0..70)
            .map(|_| {
                let x: Vec<f32> = (0..8).map(|_| rng.f32()).collect();
                net.quantize_input(&x)
            })
            .collect();
        let mut scratch = bits.scratch();
        assert_eq!(bits.forward_batch_codes(&xs), bits.forward_batch(&xs, &mut scratch));
    }

    /// Tentpole gate: every wide kernel path (portable blocks and the
    /// CPUID-detected std::arch paths) is bit-exact with the 64-lane
    /// reference over the full (A, degree) grid, at every block-boundary
    /// batch size.
    #[test]
    fn wide_paths_match_64_lane_reference_on_grid() {
        const SIZES: [usize; 14] =
            [0, 1, 63, 64, 65, 127, 128, 129, 255, 256, 257, 511, 512, 513];
        for (a, d) in GRID {
            let (net, tables) = grid_net(a, d);
            let mut bits = BitsliceNet::compile(&net, &tables, 1);
            let mut rng = Rng::new(a as u64 * 7 + d as u64);
            let xs: Vec<Vec<i32>> = (0..513)
                .map(|_| {
                    let x: Vec<f32> = (0..8).map(|_| rng.f32()).collect();
                    net.quantize_input(&x)
                })
                .collect();
            let mut scratch = bits.scratch();
            let reference = bits.forward_batch(&xs, &mut scratch);
            let portable = |lanes, path| LanePlan { lanes, path, level: SimdLevel::Portable };
            let plans = [
                portable(128, KernelPath::Blocks2),
                portable(256, KernelPath::Blocks4),
                portable(512, KernelPath::Blocks8),
                simd::plan_for(128),
                simd::plan_for(256),
                simd::plan_for(512),
            ];
            for plan in plans {
                bits = bits.with_lane_plan(plan);
                assert_eq!(bits.lanes(), plan.lanes);
                assert_eq!(bits.plane_blocks, plan.lanes / 64);
                for n in SIZES {
                    let got = bits.forward_batch_codes(&xs[..n]);
                    assert_eq!(got, &reference[..n], "A={a} D={d} plan={plan:?} n={n}");
                }
            }
        }
    }

    /// The shard/wire handoff argument: block `i` of a wide pack is
    /// bit-for-bit the scalar 64-lane pack of sample chunk `i`, so wide
    /// local kernels never change the canonical 64-bit plane format.
    #[test]
    fn wide_pack_blocks_are_byte_identical_to_scalar_planes() {
        let mut rng = Rng::new(77);
        let bits = 3u32;
        let word: Vec<Vec<i32>> =
            (0..130).map(|_| (0..8).map(|_| rng.below(8) as i32).collect()).collect();
        let n_planes = 8 * bits as usize;
        let mut wide = vec![<Blocks<4>>::zero(); n_planes];
        pack_word(&word, bits, &mut wide);
        for (i, chunk) in word.chunks(64).enumerate() {
            let mut scalar = vec![0u64; n_planes];
            pack_word(chunk, bits, &mut scalar);
            for (w, s) in wide.iter().zip(&scalar) {
                assert_eq!(w.block(i), *s, "chunk {i}");
            }
        }
        for w in &wide {
            assert_eq!(w.block(3), 0, "blocks past the batch stay zero");
        }
    }

    /// The f32 entry point at the widest detected plan matches the plan
    /// engine, sequentially and fanned out over workers.
    #[test]
    fn wide_f32_entry_matches_plan() {
        let (net, tables) = grid_net(2, 2);
        let plan = EvalPlan::compile(&net, &tables);
        let widest = simd::plan_for(simd::widest_lanes());
        let bits = BitsliceNet::compile_wide(&net, &tables, 1, widest);
        assert_eq!(bits.lanes(), widest.lanes);
        let mut rng = Rng::new(15);
        let xs: Vec<Vec<f32>> =
            (0..300).map(|_| (0..8).map(|_| rng.f32()).collect()).collect();
        for workers in [1usize, 3] {
            assert_eq!(
                bits.forward_batch_f32(&xs, workers),
                plan.forward_batch_f32(&xs, 1),
                "workers={workers} plan={widest:?}"
            );
        }
        let _ = net;
    }
}
