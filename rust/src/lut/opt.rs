//! Netlist optimization pipeline — fewer ops for every engine.
//!
//! Runs between table generation / LUT6 mapping and op-stream (or plan)
//! compilation, in three passes:
//!
//! 1. **Structured pruning** (`all` only): sub-neurons whose contribution
//!    to the adder stage (reachable-code span) falls below a fraction of
//!    the neuron's strongest sub-neuron are overwritten with their most
//!    frequent code.  Layout-preserving — strides and table counts do not
//!    change — so every downstream consumer is oblivious.  The output
//!    agreement delta vs the unpruned tables is measured and reported.
//! 2. **Don't-care propagation** (`fold+dc` and up): the set of β-bit
//!    codes each neuron can actually emit is derived layer by layer
//!    (layer-0 inputs span the full quantizer range; deeper boundaries
//!    are the image of the care addresses through each table).  Addresses
//!    containing an unreachable input code are never presented at
//!    runtime, so their words are don't-cares: small tables are
//!    re-materialized through [`espresso::minimize_dc`], larger ones get
//!    a projection rewrite (`words[addr] = words[π(addr)]`, π clamping
//!    each unreachable field to its nearest reachable code).  Care
//!    addresses are untouched, so the rewrite is bit-exact by
//!    construction for every engine.
//! 3. **Cross-LUT folding** (`fold` and up): each mapped layer netlist is
//!    rebuilt to fixpoint — constant-input cofactoring, duplicate-input
//!    merging, support reduction, identity/constant collapsing, mux
//!    simplification, and NeuraLUT-style composition of fanout-1 LUTs
//!    into their consumer when the merged support still fits one LUT6.
//!    Structural hashing (the arena's hash-consing) dedups as a side
//!    effect of the rebuild.  Pure logic rewriting: equivalence vs the
//!    unfolded netlist is checked by `sim::verify`'s netlist-opt section.
//!
//! The pipeline is selected by `--netlist-opt <none|fold|fold+dc|all>`
//! (env `POLYLUT_NETLIST_OPT`), default `fold+dc`.

use std::fmt;

use super::boolfn::BoolFn;
use super::espresso::minimize_dc;
use super::mapper::{map_network_of, MappedLayer, MappedNetwork};
use super::netlist::{Netlist, Node, NodeId};
use super::tables::{NetworkTables, TruthTable};
use crate::nn::network::Network;
use crate::nn::quant::to_twos_complement;
use crate::util::pool::parallel_map;
use crate::util::rng::Rng;

/// Env var consulted by [`OptLevel::resolve`] when no explicit level is
/// given (same design as `POLYLUT_LANES`).
pub const OPT_ENV: &str = "POLYLUT_NETLIST_OPT";

/// Tables at or below this arity are re-materialized through
/// `espresso::minimize_dc`; larger ones get the cheap projection rewrite.
const ESPRESSO_DC_MAX_BITS: u32 = 10;

/// Tables wider than this are never enumerated (reachable set assumed
/// full — a sound superset).  Far above any geometry in this repo.
const ENUM_CAP_BITS: u32 = 20;

/// Bounded fold fixpoint (each iteration only shrinks; 8 is generous).
const MAX_FOLD_ITERS: usize = 8;

/// Default pruning threshold: drop a sub-neuron whose reachable-code span
/// is below this fraction of the neuron's widest sub-neuron span.
const PRUNE_FRAC_DEFAULT: f64 = 0.25;
/// Env override for the pruning fraction (`all` level only).
pub const PRUNE_FRAC_ENV: &str = "POLYLUT_PRUNE_FRAC";

/// Random input vectors used to measure the pruning agreement delta.
const AGREEMENT_SAMPLES: usize = 512;

/// Netlist optimization level (`--netlist-opt`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum OptLevel {
    /// Compile the mapped netlists untouched.
    None,
    /// Structural folding only (bit-exact).
    Fold,
    /// Folding + don't-care propagation (bit-exact by construction).
    #[default]
    FoldDc,
    /// Everything, including structured pruning (accuracy-affecting;
    /// explicit opt-in — never a default).
    All,
}

impl OptLevel {
    /// Parse a CLI/env spelling. `None` on unknown input.
    pub fn parse(s: &str) -> Option<OptLevel> {
        match s.trim().to_ascii_lowercase().as_str() {
            "none" | "off" => Some(OptLevel::None),
            "fold" => Some(OptLevel::Fold),
            "fold+dc" | "fold-dc" | "folddc" | "dc" => Some(OptLevel::FoldDc),
            "all" => Some(OptLevel::All),
            _ => None,
        }
    }

    /// Resolution ladder: explicit value > `POLYLUT_NETLIST_OPT` env >
    /// default (`fold+dc`).  Both sides of the sharded fingerprint
    /// handshake resolve through here, so a coordinator and its remote
    /// workers agree on the table-level rewrites.
    pub fn resolve(explicit: Option<OptLevel>) -> OptLevel {
        if let Some(l) = explicit {
            return l;
        }
        match std::env::var(OPT_ENV) {
            Ok(s) if !s.trim().is_empty() => OptLevel::parse(&s).unwrap_or_else(|| {
                log::warn!("{OPT_ENV}={s:?} not recognized; using default {}", OptLevel::default());
                OptLevel::default()
            }),
            _ => OptLevel::default(),
        }
    }

    /// Does this level rebuild the mapped netlists (fold pass)?
    pub fn folds(&self) -> bool {
        !matches!(self, OptLevel::None)
    }

    /// Does this level rewrite table don't-cares?
    pub fn dc(&self) -> bool {
        matches!(self, OptLevel::FoldDc | OptLevel::All)
    }

    /// Does this level prune sub-neurons (accuracy-affecting)?
    pub fn prunes(&self) -> bool {
        matches!(self, OptLevel::All)
    }

    /// Stable ordinal for the metrics snapshot (inverse of
    /// [`OptLevel::from_ordinal`]).
    pub fn ordinal(&self) -> u64 {
        match self {
            OptLevel::None => 0,
            OptLevel::Fold => 1,
            OptLevel::FoldDc => 2,
            OptLevel::All => 3,
        }
    }

    pub fn from_ordinal(ord: u64) -> Option<OptLevel> {
        match ord {
            0 => Some(OptLevel::None),
            1 => Some(OptLevel::Fold),
            2 => Some(OptLevel::FoldDc),
            3 => Some(OptLevel::All),
            _ => None,
        }
    }
}

/// Parse `--netlist-opt` and publish the choice through
/// [`OPT_ENV`], so every in-process consumer that resolves lazily
/// (sharded kernels, fingerprints, RTL emit) sees the same level.
pub fn level_from_args(args: &crate::util::cli::Args) -> anyhow::Result<Option<OptLevel>> {
    let Some(raw) = args.get("netlist-opt") else {
        return Ok(None);
    };
    let level = OptLevel::parse(raw).ok_or_else(|| {
        anyhow::anyhow!("--netlist-opt expects none|fold|fold+dc|all, got {raw:?}")
    })?;
    std::env::set_var(OPT_ENV, level.to_string());
    Ok(Some(level))
}

impl fmt::Display for OptLevel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            OptLevel::None => "none",
            OptLevel::Fold => "fold",
            OptLevel::FoldDc => "fold+dc",
            OptLevel::All => "all",
        })
    }
}

/// Per-layer word-op delta (cone-restricted: what the engines execute).
#[derive(Debug, Clone, Copy, Default)]
pub struct LayerDelta {
    pub luts_before: usize,
    pub muxes_before: usize,
    pub luts_after: usize,
    pub muxes_after: usize,
}

impl LayerDelta {
    pub fn ops_before(&self) -> usize {
        self.luts_before + self.muxes_before
    }
    pub fn ops_after(&self) -> usize {
        self.luts_after + self.muxes_after
    }
}

/// What the pipeline did — per-layer op counts plus pruning outcome.
/// Carried on `FrozenModel`, surfaced by `polylut verify`/`compile` and
/// `coordinator::metrics`.
#[derive(Debug, Clone, Default)]
pub struct OptReport {
    pub level: OptLevel,
    pub layers: Vec<LayerDelta>,
    /// Sub-neuron tables overwritten by the pruning pass.
    pub pruned_subs: usize,
    /// Fraction of random inputs whose output codes match the unpruned
    /// tables exactly (measured only when pruning ran).
    pub exact_agreement: Option<f64>,
    /// Fraction whose predicted class matches (argmax / sign).
    pub class_agreement: Option<f64>,
}

impl OptReport {
    pub fn ops_before(&self) -> usize {
        self.layers.iter().map(|l| l.ops_before()).sum()
    }

    pub fn ops_after(&self) -> usize {
        self.layers.iter().map(|l| l.ops_after()).sum()
    }

    /// Percent of word-ops removed by the pipeline.
    pub fn reduction_pct(&self) -> f64 {
        let before = self.ops_before();
        if before == 0 {
            return 0.0;
        }
        100.0 * (before - self.ops_after()) as f64 / before as f64
    }

    /// The per-layer ops-before/after table (`polylut verify` / `compile`).
    pub fn render_table(&self) -> String {
        let rows: Vec<Vec<String>> = self
            .layers
            .iter()
            .enumerate()
            .map(|(l, d)| {
                let (b, a) = (d.ops_before(), d.ops_after());
                let pct = if b == 0 { 0.0 } else { 100.0 * (b - a) as f64 / b as f64 };
                vec![
                    format!("L{l}"),
                    d.luts_before.to_string(),
                    d.muxes_before.to_string(),
                    b.to_string(),
                    a.to_string(),
                    format!("{pct:.1}%"),
                ]
            })
            .chain(std::iter::once({
                let (b, a) = (self.ops_before(), self.ops_after());
                vec![
                    "total".into(),
                    self.layers.iter().map(|l| l.luts_before).sum::<usize>().to_string(),
                    self.layers.iter().map(|l| l.muxes_before).sum::<usize>().to_string(),
                    b.to_string(),
                    a.to_string(),
                    format!("{:.1}%", self.reduction_pct()),
                ]
            }))
            .collect();
        let mut out = crate::util::bench::table_string(
            &format!("netlist-opt [{}]", self.level),
            &["layer", "luts", "muxes", "ops before", "ops after", "saved"],
            &rows,
        );
        if let Some(exact) = self.exact_agreement {
            out.push_str(&format!(
                "pruned sub-neurons: {} | exact agreement {:.4} | class agreement {:.4}\n",
                self.pruned_subs,
                exact,
                self.class_agreement.unwrap_or(1.0),
            ));
        }
        out
    }
}

/// The pipeline's output: rewritten tables, the folded mapping the
/// engines compile, the unfolded mapping of the same tables (equivalence
/// baseline for `sim::verify`; `None` at level `none`), and the report.
pub struct Optimized {
    pub tables: NetworkTables,
    pub mapped: MappedNetwork,
    pub baseline: Option<MappedNetwork>,
    pub report: OptReport,
}

/// Run the full pipeline at `level`.  The ops-before figures always come
/// from a mapping of the *original* tables — the stream an unoptimized
/// compile would execute.
pub fn optimize(net: &Network, tables: NetworkTables, level: OptLevel, workers: usize) -> Optimized {
    let before = map_network_of(net, &tables, workers);
    let before_counts: Vec<(usize, usize)> = before.layers.iter().map(cone_ops).collect();
    if !level.folds() {
        let layers = before_counts
            .iter()
            .map(|&(l, m)| LayerDelta {
                luts_before: l,
                muxes_before: m,
                luts_after: l,
                muxes_after: m,
            })
            .collect();
        let report = OptReport { level, layers, ..OptReport::default() };
        return Optimized { tables, mapped: before, baseline: None, report };
    }

    let mut tables = tables;
    let original = if level.prunes() { Some(tables.clone()) } else { None };
    let outcome = optimize_tables(net, &mut tables, level);
    let mut exact_agreement = None;
    let mut class_agreement = None;
    if outcome.pruned_subs > 0 {
        if let Some(original) = &original {
            let (exact, class) =
                measure_agreement(net, original, &tables, AGREEMENT_SAMPLES);
            exact_agreement = Some(exact);
            class_agreement = Some(class);
        }
    }

    // The equivalence baseline must map the *final* tables (fold is a pure
    // logic rewrite of this netlist); when no table changed, the original
    // mapping doubles as the baseline.
    let baseline =
        if outcome.changed { map_network_of(net, &tables, workers) } else { before };
    let mapped = fold_network(&baseline, workers);
    let layers = before_counts
        .iter()
        .zip(mapped.layers.iter().map(cone_ops))
        .map(|(&(lb, mb), (la, ma))| LayerDelta {
            luts_before: lb,
            muxes_before: mb,
            luts_after: la,
            muxes_after: ma,
        })
        .collect();
    let report = OptReport {
        level,
        layers,
        pruned_subs: outcome.pruned_subs,
        exact_agreement,
        class_agreement,
    };
    Optimized { tables, mapped, baseline: Some(baseline), report }
}

/// What [`optimize_tables`] did to the table words.
#[derive(Debug, Clone, Copy, Default)]
pub struct TableOutcome {
    /// Sub-neuron tables overwritten by the pruning pass.
    pub pruned_subs: usize,
    /// Whether any table word changed (prune or don't-care rewrite).
    pub changed: bool,
}

/// The table-level passes alone (prune, then don't-care rewrite), in the
/// exact order [`optimize`] applies them.  The sharded worker runs this
/// on its slice of the tables so the coordinator↔worker table-word
/// fingerprints agree; everything netlist-shaped (folding) stays on the
/// mapping side.
pub fn optimize_tables(
    net: &Network,
    tables: &mut NetworkTables,
    level: OptLevel,
) -> TableOutcome {
    let mut outcome = TableOutcome::default();
    if level.prunes() {
        let reach = derive_reachable(net, tables);
        outcome.pruned_subs = prune_low_contribution(net, tables, &reach, prune_frac());
        outcome.changed = outcome.pruned_subs > 0;
    }
    if level.dc() {
        let reach = derive_reachable(net, tables);
        outcome.changed |= rewrite_dont_cares(net, tables, &reach) > 0;
    }
    outcome
}

fn prune_frac() -> f64 {
    match std::env::var(PRUNE_FRAC_ENV) {
        Ok(s) => s.trim().parse::<f64>().ok().filter(|f| (0.0..=1.0).contains(f)).unwrap_or_else(
            || {
                log::warn!("{PRUNE_FRAC_ENV}={s:?} invalid; using {PRUNE_FRAC_DEFAULT}");
                PRUNE_FRAC_DEFAULT
            },
        ),
        Err(_) => PRUNE_FRAC_DEFAULT,
    }
}

// ---------------------------------------------------------------------------
// Reachable-code derivation (don't-care soundness rests on this set).
// ---------------------------------------------------------------------------

/// Reachable raw-code sets, derived bottom-up.  `boundaries[b][j][code]`
/// is true iff neuron `j` of layer boundary `b` can emit raw code `code`
/// (boundary 0 = quantized network inputs, always the full range —
/// `nn::quant::unsigned_code` clamps into `[0, 2^β)` and every code in
/// range is hit).  `subs[l][j][a]` are the reachable sub-neuron codes
/// feeding layer `l`'s adder stage (empty when A == 1).
pub struct Reachable {
    pub boundaries: Vec<Vec<Vec<bool>>>,
    pub subs: Vec<Vec<Vec<Vec<bool>>>>,
}

/// Image of `table` over its care addresses: fields of `field_w` bits,
/// field `i` restricted to `field_reach[i]`.  Returns the reachable raw
/// output words.  Falls back to the full range (sound superset) past
/// [`ENUM_CAP_BITS`].
fn table_image(table: &TruthTable, field_w: u32, field_reach: &[&Vec<bool>]) -> Vec<bool> {
    let out_size = 1usize << table.out_bits;
    if table.n_inputs > ENUM_CAP_BITS {
        return vec![true; out_size];
    }
    let mut out = vec![false; out_size];
    let mask = (1usize << field_w) - 1;
    'addr: for (addr, &w) in table.words.iter().enumerate() {
        for (i, reach) in field_reach.iter().enumerate() {
            if !reach[(addr >> (i as u32 * field_w)) & mask] {
                continue 'addr;
            }
        }
        out[w as usize & (out_size - 1)] = true;
    }
    out
}

/// Derive the reachable sets for every boundary and sub-neuron.
pub fn derive_reachable(net: &Network, tables: &NetworkTables) -> Reachable {
    let cfg = &net.cfg;
    let a_factor = tables.a_factor;
    let mut boundaries: Vec<Vec<Vec<bool>>> = Vec::with_capacity(cfg.n_layers() + 1);
    boundaries.push(vec![vec![true; 1usize << cfg.beta[0]]; cfg.widths[0]]);
    let mut subs: Vec<Vec<Vec<Vec<bool>>>> = Vec::with_capacity(cfg.n_layers());
    for (l, lt) in tables.layers.iter().enumerate() {
        let prev = &boundaries[l];
        let mut layer_out = Vec::with_capacity(lt.neurons.len());
        let mut layer_subs = Vec::with_capacity(lt.neurons.len());
        for (j, neuron) in lt.neurons.iter().enumerate() {
            match &neuron.adder {
                None => {
                    let fields: Vec<&Vec<bool>> = net.layers[l].indices[0][j]
                        .iter()
                        .map(|&src| &prev[src])
                        .collect();
                    layer_out.push(table_image(&neuron.poly[0], lt.in_bits, &fields));
                    layer_subs.push(Vec::new());
                }
                Some(adder) => {
                    let sub_reach: Vec<Vec<bool>> = (0..a_factor)
                        .map(|a| {
                            let fields: Vec<&Vec<bool>> = net.layers[l].indices[a][j]
                                .iter()
                                .map(|&src| &prev[src])
                                .collect();
                            table_image(&neuron.poly[a], lt.in_bits, &fields)
                        })
                        .collect();
                    let fields: Vec<&Vec<bool>> = sub_reach.iter().collect();
                    layer_out.push(table_image(adder, lt.sub_bits, &fields));
                    layer_subs.push(sub_reach);
                }
            }
        }
        boundaries.push(layer_out);
        subs.push(layer_subs);
    }
    Reachable { boundaries, subs }
}

// ---------------------------------------------------------------------------
// Don't-care rewrite.
// ---------------------------------------------------------------------------

/// Rewrite one table under per-field reachability.  Care addresses keep
/// their exact words; don't-care addresses are repainted to whatever
/// makes the logic simplest.  Returns whether anything changed.
fn rewrite_table(table: &mut TruthTable, field_w: u32, field_reach: &[&Vec<bool>]) -> bool {
    if table.n_inputs > ENUM_CAP_BITS {
        return false;
    }
    if field_reach.iter().all(|r| r.iter().all(|&b| b)) {
        return false;
    }
    let mask = (1usize << field_w) - 1;
    let is_care = |addr: usize| {
        field_reach
            .iter()
            .enumerate()
            .all(|(i, reach)| reach[(addr >> (i as u32 * field_w)) & mask])
    };
    if table.n_inputs <= ESPRESSO_DC_MAX_BITS {
        // Exact re-materialization: minimize each output bit under the
        // care set and rebuild the words from the covers.
        let n = table.n_inputs;
        let mut care_bits = vec![0u64; super::boolfn::words_for(n)];
        for addr in 0..table.size() {
            if is_care(addr) {
                care_bits[addr / 64] |= 1 << (addr % 64);
            }
        }
        let care = BoolFn::from_bits(n, care_bits);
        let mut words = vec![0u32; table.size()];
        for b in 0..table.out_bits {
            let f = BoolFn::from_bits(n, table.bit_plane(b));
            let cover = minimize_dc(&f, &care);
            for (addr, w) in words.iter_mut().enumerate() {
                if cover.eval(addr) {
                    *w |= 1 << b;
                }
            }
        }
        let changed = words != table.words;
        table.words = words;
        changed
    } else {
        // Projection rewrite: clamp each unreachable field code to its
        // nearest reachable one (Hamming distance, then value), making
        // the table constant along unreachable directions so the mapper's
        // support reduction and cofactor checks can fire.
        let canon: Vec<Vec<usize>> = field_reach
            .iter()
            .map(|reach| {
                (0..reach.len())
                    .map(|c| {
                        if reach[c] {
                            return c;
                        }
                        (0..reach.len())
                            .filter(|&r| reach[r])
                            .min_by_key(|&r| ((r ^ c).count_ones(), r))
                            .unwrap_or(c)
                    })
                    .collect()
            })
            .collect();
        let mut changed = false;
        let old = table.words.clone();
        for (addr, w) in table.words.iter_mut().enumerate() {
            let mut src = 0usize;
            for (i, c) in canon.iter().enumerate() {
                src |= c[(addr >> (i as u32 * field_w)) & mask] << (i as u32 * field_w);
            }
            if src != addr {
                *w = old[src];
                changed |= *w != old[addr];
            }
        }
        changed
    }
}

/// Apply the don't-care rewrite across the network.  Returns the number
/// of tables whose words changed.
fn rewrite_dont_cares(net: &Network, tables: &mut NetworkTables, reach: &Reachable) -> usize {
    let a_factor = tables.a_factor;
    let mut touched = 0usize;
    for (l, lt) in tables.layers.iter_mut().enumerate() {
        let in_bits = lt.in_bits;
        let sub_bits = lt.sub_bits;
        for (j, neuron) in lt.neurons.iter_mut().enumerate() {
            for (a, poly) in neuron.poly.iter_mut().enumerate() {
                let fields: Vec<&Vec<bool>> = net.layers[l].indices[a.min(a_factor - 1)][j]
                    .iter()
                    .map(|&src| &reach.boundaries[l][src])
                    .collect();
                touched += rewrite_table(poly, in_bits, &fields) as usize;
            }
            if let Some(adder) = &mut neuron.adder {
                let fields: Vec<&Vec<bool>> = reach.subs[l][j].iter().collect();
                touched += rewrite_table(adder, sub_bits, &fields) as usize;
            }
        }
    }
    touched
}

// ---------------------------------------------------------------------------
// Structured pruning (`all` only — accuracy-affecting, explicit opt-in).
// ---------------------------------------------------------------------------

/// Overwrite low-contribution sub-neuron tables with their most frequent
/// code.  Contribution = reachable-code span (max − min over care
/// addresses); a sub-neuron is pruned when its span falls strictly below
/// `frac` × the widest span among its neuron's sub-neurons (so the
/// strongest sub-neuron is never pruned).  Layout-preserving: the table
/// stays, every word becomes the same constant, and the mapper turns it
/// into `Const` nodes.  Returns the number of pruned tables.
fn prune_low_contribution(
    net: &Network,
    tables: &mut NetworkTables,
    reach: &Reachable,
    frac: f64,
) -> usize {
    let mut pruned = 0usize;
    for (l, lt) in tables.layers.iter_mut().enumerate() {
        let in_bits = lt.in_bits;
        let sub_bits = lt.sub_bits;
        for (j, neuron) in lt.neurons.iter_mut().enumerate() {
            if neuron.adder.is_none() || neuron.poly.len() < 2 {
                continue; // A == 1: no adder stage to contribute to.
            }
            let mask = (1usize << in_bits) - 1;
            // (span, mode code) per sub-neuron, over care addresses only.
            let stats: Vec<(i64, i32)> = neuron
                .poly
                .iter()
                .enumerate()
                .map(|(a, t)| {
                    let fields: Vec<&Vec<bool>> = net.layers[l].indices[a][j]
                        .iter()
                        .map(|&src| &reach.boundaries[l][src])
                        .collect();
                    let mut lo = i64::MAX;
                    let mut hi = i64::MIN;
                    let mut freq = vec![0usize; 1usize << sub_bits];
                    'addr: for addr in 0..t.size() {
                        for (i, r) in fields.iter().enumerate() {
                            if !r[(addr >> (i as u32 * in_bits)) & mask] {
                                continue 'addr;
                            }
                        }
                        let c = t.code_at(addr) as i64;
                        lo = lo.min(c);
                        hi = hi.max(c);
                        freq[t.words[addr] as usize & (freq.len() - 1)] += 1;
                    }
                    let mode_raw = freq
                        .iter()
                        .enumerate()
                        .max_by_key(|&(raw, &n)| (n, usize::MAX - raw))
                        .map(|(raw, _)| raw as u32)
                        .unwrap_or(0);
                    let mode = crate::nn::quant::from_twos_complement(mode_raw, sub_bits);
                    (if hi >= lo { hi - lo } else { 0 }, mode)
                })
                .collect();
            let widest = stats.iter().map(|&(s, _)| s).max().unwrap_or(0);
            for (a, &(span, mode)) in stats.iter().enumerate() {
                if widest > 0 && (span as f64) < frac * widest as f64 {
                    let raw = to_twos_complement(mode, sub_bits);
                    neuron.poly[a].words.iter_mut().for_each(|w| *w = raw);
                    pruned += 1;
                }
            }
        }
    }
    pruned
}

/// Fixed-point forward pass *through the tables* (not the polynomial
/// transfer functions) — the oracle for the pruning agreement delta and
/// for test cross-checks.  Mirrors `Network::forward_codes` addressing.
pub fn forward_codes_tables(
    net: &Network,
    tables: &NetworkTables,
    in_codes: &[i32],
) -> Vec<i32> {
    let cfg = &net.cfg;
    assert_eq!(in_codes.len(), cfg.widths[0]);
    let mut codes = in_codes.to_vec();
    for (l, lt) in tables.layers.iter().enumerate() {
        let mut next = Vec::with_capacity(cfg.widths[l + 1]);
        for (j, neuron) in lt.neurons.iter().enumerate() {
            let gather = |a: usize| -> Vec<i32> {
                net.layers[l].indices[a][j].iter().map(|&src| codes[src]).collect()
            };
            let out = match &neuron.adder {
                None => neuron.poly[0]
                    .code_at(super::tables::pack_poly_addr(&gather(0), lt.in_bits)),
                Some(adder) => {
                    let subs: Vec<i32> = neuron
                        .poly
                        .iter()
                        .enumerate()
                        .map(|(a, t)| {
                            t.code_at(super::tables::pack_poly_addr(&gather(a), lt.in_bits))
                        })
                        .collect();
                    adder.code_at(super::tables::pack_adder_addr(&subs, lt.sub_bits))
                }
            };
            next.push(out);
        }
        codes = next;
    }
    codes
}

/// Output agreement between two table sets over random input codes:
/// (exact output-code agreement, predicted-class agreement).
fn measure_agreement(
    net: &Network,
    original: &NetworkTables,
    pruned: &NetworkTables,
    samples: usize,
) -> (f64, f64) {
    let cfg = &net.cfg;
    let mut rng = Rng::new(cfg.seed ^ 0x9E3779B97F4A7C15);
    let range = 1usize << cfg.beta[0];
    let mut exact = 0usize;
    let mut class = 0usize;
    for _ in 0..samples {
        let x: Vec<i32> = (0..cfg.widths[0]).map(|_| rng.below(range) as i32).collect();
        let a = forward_codes_tables(net, original, &x);
        let b = forward_codes_tables(net, pruned, &x);
        exact += (a == b) as usize;
        class += (predicted_class(cfg.n_classes, &a) == predicted_class(cfg.n_classes, &b))
            as usize;
    }
    (exact as f64 / samples as f64, class as f64 / samples as f64)
}

/// Argmax over output codes (step > 0, so code order = logit order);
/// binary heads compare the logit sign.
fn predicted_class(n_classes: usize, codes: &[i32]) -> usize {
    if n_classes == 1 {
        (codes[0] > 0) as usize
    } else {
        codes
            .iter()
            .enumerate()
            .max_by_key(|&(i, &c)| (c, usize::MAX - i))
            .map(|(i, _)| i)
            .unwrap_or(0)
    }
}

// ---------------------------------------------------------------------------
// Cross-LUT folding (pure logic rewrite of the mapped netlists).
// ---------------------------------------------------------------------------

/// Fold every layer of a mapped network to fixpoint (non-destructive —
/// the input stays intact as the equivalence baseline).
pub fn fold_network(mapped: &MappedNetwork, workers: usize) -> MappedNetwork {
    let jobs: Vec<usize> = (0..mapped.layers.len()).collect();
    let layers = parallel_map(&jobs, workers, |_, &l| fold_layer(&mapped.layers[l]));
    MappedNetwork { layers }
}

/// Fold one layer: bounded rewrite-to-fixpoint.
fn fold_layer(ml: &MappedLayer) -> MappedLayer {
    let mut cur = rewrite_once(ml);
    for _ in 1..MAX_FOLD_ITERS {
        if !cur.1 {
            break;
        }
        cur = rewrite_once(&cur.0);
    }
    cur.0
}

/// Dead-node marker: live = backward cone of roots ∪ poly_roots.
fn live_nodes(ml: &MappedLayer) -> Vec<bool> {
    let nl = &ml.netlist;
    let mut live = vec![false; nl.nodes.len()];
    let mut stack: Vec<NodeId> = ml
        .roots
        .iter()
        .chain(ml.poly_roots.iter())
        .flatten()
        .copied()
        .collect();
    while let Some(id) = stack.pop() {
        if std::mem::replace(&mut live[id as usize], true) {
            continue;
        }
        match &nl.nodes[id as usize] {
            Node::Input { .. } | Node::Const(_) => {}
            Node::Lut { inputs, .. } => stack.extend(inputs.iter().copied()),
            Node::Mux { sel, lo, hi, .. } => stack.extend([*sel, *lo, *hi]),
        }
    }
    live
}

/// One rewrite pass: rebuild the layer netlist through a fresh arena with
/// constant cofactoring, duplicate-input merging, support reduction,
/// identity/mux collapsing, structural hashing (the arena's dedup), and
/// single-level composition of fanout-1 LUTs into their consumer.
/// Returns the rewritten layer and whether anything changed.
fn rewrite_once(ml: &MappedLayer) -> (MappedLayer, bool) {
    let old = &ml.netlist;
    let live = live_nodes(ml);
    let n_old = old.nodes.len();

    // Fanout over live nodes; roots are protected uses.
    let mut fanout = vec![0u32; n_old];
    let mut only_user: Vec<Option<NodeId>> = vec![None; n_old];
    let mut is_root = vec![false; n_old];
    for &r in ml.roots.iter().chain(ml.poly_roots.iter()).flatten() {
        is_root[r as usize] = true;
    }
    for (id, node) in old.nodes.iter().enumerate() {
        if !live[id] {
            continue;
        }
        let mut user = |i: NodeId| {
            fanout[i as usize] += 1;
            only_user[i as usize] = Some(id as NodeId);
        };
        match node {
            Node::Input { .. } | Node::Const(_) => {}
            Node::Lut { inputs, .. } => inputs.iter().copied().for_each(&mut user),
            Node::Mux { sel, lo, hi, .. } => [*sel, *lo, *hi].into_iter().for_each(&mut user),
        }
    }

    // Compose candidates: a live, non-root LUT with exactly one user,
    // itself a LUT, where the merged distinct support fits one LUT6.
    let mut inline_into: Vec<Option<NodeId>> = vec![None; n_old];
    for (id, node) in old.nodes.iter().enumerate() {
        let inputs_p = match node {
            Node::Lut { inputs, .. } if live[id] && !is_root[id] && fanout[id] == 1 => inputs,
            _ => continue,
        };
        let user = match only_user[id] {
            Some(u) => u,
            None => continue,
        };
        let inputs_c = match &old.nodes[user as usize] {
            Node::Lut { inputs, .. } => inputs,
            _ => continue,
        };
        let mut support: Vec<NodeId> = inputs_c
            .iter()
            .copied()
            .filter(|&i| i != id as NodeId)
            .chain(inputs_p.iter().copied())
            .collect();
        support.sort_unstable();
        support.dedup();
        if support.len() <= 6 {
            inline_into[id] = Some(user);
        }
    }
    // No chains in one pass: a candidate survives only if its consumer is
    // not itself being inlined and none of its inputs are candidates (the
    // fixpoint loop composes chains across iterations).  One inline per
    // consumer.
    let mut taken = vec![false; n_old];
    for id in 0..n_old {
        let Some(user) = inline_into[id] else { continue };
        let bad = inline_into[user as usize].is_some()
            || taken[user as usize]
            || match &old.nodes[id] {
                Node::Lut { inputs, .. } => {
                    inputs.iter().any(|&i| inline_into[i as usize].is_some())
                }
                _ => true,
            };
        if bad {
            inline_into[id] = None;
        } else {
            taken[user as usize] = true;
        }
    }
    let mut inlined_input: Vec<Option<NodeId>> = vec![None; n_old];
    for id in 0..n_old {
        if let Some(user) = inline_into[id] {
            inlined_input[user as usize] = Some(id as NodeId);
        }
    }

    // Rebuild.
    let mut new = Netlist::new();
    let mut map: Vec<NodeId> = vec![u32::MAX; n_old];
    let mut changed = n_old != live.iter().filter(|&&l| l).count();
    for (id, node) in old.nodes.iter().enumerate() {
        if !live[id] || inline_into[id].is_some() {
            continue;
        }
        map[id] = match node {
            Node::Input { wire } => new.input(*wire),
            Node::Const(v) => new.constant(*v),
            Node::Lut { inputs, mask } => {
                let (nid, simplified) = match inlined_input[id] {
                    None => {
                        let ins: Vec<NodeId> =
                            inputs.iter().map(|&i| map[i as usize]).collect();
                        add_simplified_lut(&mut new, &ins, &|addr| mask >> addr & 1 == 1)
                    }
                    Some(p) => {
                        let (p_inputs, p_mask) = match &old.nodes[p as usize] {
                            Node::Lut { inputs, mask } => (inputs, *mask),
                            _ => unreachable!("compose candidates are LUTs"),
                        };
                        // Slots: consumer inputs with the p slot removed,
                        // then p's inputs.  `eval` folds p's value back
                        // into the consumer's address.
                        let p_slot =
                            inputs.iter().position(|&i| i == p).expect("p feeds its user");
                        let ins: Vec<NodeId> = inputs
                            .iter()
                            .enumerate()
                            .filter(|&(s, _)| s != p_slot)
                            .map(|(_, &i)| map[i as usize])
                            .chain(p_inputs.iter().map(|&i| map[i as usize]))
                            .collect();
                        let k_c = inputs.len();
                        let eval = move |addr: usize| {
                            let mut p_addr = 0usize;
                            for b in 0..p_inputs.len() {
                                p_addr |= (addr >> (k_c - 1 + b) & 1) << b;
                            }
                            let p_val = p_mask >> p_addr & 1;
                            let mut c_addr = 0usize;
                            for (s, _) in inputs.iter().enumerate() {
                                let bit = match s.cmp(&p_slot) {
                                    std::cmp::Ordering::Less => addr >> s & 1,
                                    std::cmp::Ordering::Equal => p_val as usize,
                                    std::cmp::Ordering::Greater => addr >> (s - 1) & 1,
                                };
                                c_addr |= bit << s;
                            }
                            mask >> c_addr & 1 == 1
                        };
                        let r = add_simplified_lut(&mut new, &ins, &eval);
                        (r.0, true)
                    }
                };
                changed |= simplified;
                nid
            }
            Node::Mux { sel, lo, hi, free } => {
                let (s, l, h) =
                    (map[*sel as usize], map[*lo as usize], map[*hi as usize]);
                let collapse = if l == h {
                    Some(l)
                } else {
                    match (&new.nodes[s as usize], &new.nodes[l as usize], &new.nodes[h as usize])
                    {
                        (Node::Const(v), ..) => Some(if *v { h } else { l }),
                        (_, Node::Const(false), Node::Const(true)) => Some(s),
                        _ => None,
                    }
                };
                match collapse {
                    Some(n) => {
                        changed = true;
                        n
                    }
                    None => {
                        let inverts = matches!(new.nodes[l as usize], Node::Const(true))
                            && matches!(new.nodes[h as usize], Node::Const(false));
                        if inverts {
                            changed = true;
                            add_simplified_lut(&mut new, &[s], &|addr| addr == 0).0
                        } else {
                            new.add(Node::Mux { sel: s, lo: l, hi: h, free: *free })
                        }
                    }
                }
            }
        };
    }
    changed |= new.nodes.len() < live.iter().filter(|&&l| l).count();

    let remap = |roots: &Vec<Vec<NodeId>>| -> Vec<Vec<NodeId>> {
        roots
            .iter()
            .map(|bits| bits.iter().map(|&r| map[r as usize]).collect())
            .collect()
    };
    let roots = remap(&ml.roots);
    let poly_roots = remap(&ml.poly_roots);
    let poly_depth = poly_roots
        .iter()
        .flatten()
        .map(|&r| new.depth_of(r))
        .max()
        .unwrap_or(0);
    let depth = roots.iter().flatten().map(|&r| new.depth_of(r)).max().unwrap_or(0);
    (MappedLayer { netlist: new, roots, poly_roots, poly_depth, depth }, changed)
}

/// Add a LUT over `ins` (new-arena ids; constants and duplicates
/// allowed) computing `eval` over the slot address space.  Constant
/// slots are cofactored away, duplicate slots merged, the remainder
/// support-reduced; constants and identities collapse to existing
/// nodes.  Returns the node and whether anything beyond a plain re-add
/// happened.
fn add_simplified_lut(
    nl: &mut Netlist,
    ins: &[NodeId],
    eval: &dyn Fn(usize) -> bool,
) -> (NodeId, bool) {
    // Classify slots: constant value or index into the distinct var list.
    enum Slot {
        Fixed(bool),
        Var(usize),
    }
    let mut distinct: Vec<NodeId> = Vec::with_capacity(ins.len());
    let slots: Vec<Slot> = ins
        .iter()
        .map(|&i| match &nl.nodes[i as usize] {
            Node::Const(v) => Slot::Fixed(*v),
            _ => Slot::Var(match distinct.iter().position(|&d| d == i) {
                Some(p) => p,
                None => {
                    distinct.push(i);
                    distinct.len() - 1
                }
            }),
        })
        .collect();
    let m = distinct.len();
    assert!(m <= 6, "simplified LUT support must fit one LUT6");
    let mut bits = 0u64;
    for a in 0..(1usize << m) {
        let mut addr = 0usize;
        for (s, slot) in slots.iter().enumerate() {
            let bit = match slot {
                Slot::Fixed(v) => *v as usize,
                Slot::Var(d) => a >> d & 1,
            };
            addr |= bit << s;
        }
        if eval(addr) {
            bits |= 1 << a;
        }
    }
    let f = BoolFn::from_bits(m as u32, vec![bits]);
    let (red, kept) = f.support_reduce();
    if let Some(v) = red.is_const() {
        return (nl.constant(v), true);
    }
    let wires: Vec<NodeId> = kept.iter().map(|&k| distinct[k as usize]).collect();
    if red.n == 1 && red.get(1) && !red.get(0) {
        return (wires[0], true); // identity: alias the input wire
    }
    let simplified = wires.len() < ins.len();
    (nl.add(Node::Lut { inputs: wires, mask: red.lut_mask() }), simplified)
}

/// Cone-restricted word-op counts (LUTs, muxes) — what the engines
/// actually execute: the backward cone of the output roots (orphaned
/// poly sub-bits are dead there, matching `sim::bitslice`'s flatten).
pub fn cone_ops(ml: &MappedLayer) -> (usize, usize) {
    let nl = &ml.netlist;
    let mut seen = vec![false; nl.nodes.len()];
    let mut stack: Vec<NodeId> = ml.roots.iter().flatten().copied().collect();
    let (mut luts, mut muxes) = (0usize, 0usize);
    while let Some(id) = stack.pop() {
        if std::mem::replace(&mut seen[id as usize], true) {
            continue;
        }
        match &nl.nodes[id as usize] {
            Node::Input { .. } | Node::Const(_) => {}
            Node::Lut { inputs, .. } => {
                luts += 1;
                stack.extend(inputs.iter().copied());
            }
            Node::Mux { sel, lo, hi, .. } => {
                muxes += 1;
                stack.extend([*sel, *lo, *hi]);
            }
        }
    }
    (luts, muxes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lut::tables::{compile_network, LayerTables, NeuronTables};
    use crate::nn::config;
    use crate::util::rng::Rng;

    /// The (A, degree) grid shared with the engine bit-exactness suites.
    const GRID: [(usize, u32); 6] = [(1, 1), (2, 1), (3, 1), (1, 2), (2, 2), (2, 3)];

    fn grid_net(a: usize, d: u32) -> Network {
        let cfg = config::uniform("t", &[8, 6, 3], 2, 2, 3, 3, 3, d, a, 3);
        Network::random(&cfg, &mut Rng::new(7 + a as u64 * 31 + d as u64))
    }

    #[test]
    fn level_parse_display_roundtrip() {
        for l in [OptLevel::None, OptLevel::Fold, OptLevel::FoldDc, OptLevel::All] {
            assert_eq!(OptLevel::parse(&l.to_string()), Some(l));
        }
        assert_eq!(OptLevel::parse("garbage"), None);
        assert_eq!(OptLevel::resolve(Some(OptLevel::All)), OptLevel::All);
        assert_eq!(OptLevel::default(), OptLevel::FoldDc);
        assert!(!OptLevel::None.folds() && !OptLevel::None.dc());
        assert!(OptLevel::Fold.folds() && !OptLevel::Fold.dc());
        assert!(OptLevel::FoldDc.dc() && !OptLevel::FoldDc.prunes());
        assert!(OptLevel::All.prunes());
    }

    /// Satellite: layer-0 inputs span the full quantizer range — the
    /// unsigned quantizer clamps into [0, 2^β) and hits every code.
    #[test]
    fn reachable_layer0_is_full_range() {
        let net = grid_net(2, 2);
        let beta = net.cfg.beta[0];
        let mut seen = vec![false; 1usize << beta];
        for i in 0..=1000 {
            let x = i as f32 / 1000.0;
            let c = crate::nn::quant::unsigned_code(x, beta, 1.0);
            assert!((0..(1 << beta)).contains(&c), "clamped into range");
            seen[c as usize] = true;
        }
        // Out-of-range values clamp, never escape the code range.
        assert_eq!(crate::nn::quant::unsigned_code(-5.0, beta, 1.0), 0);
        assert_eq!(crate::nn::quant::unsigned_code(7.5, beta, 1.0), (1 << beta) - 1);
        assert!(seen.iter().all(|&s| s), "every code is reachable at the input");
        let tables = compile_network(&net, 1);
        let reach = derive_reachable(&net, &tables);
        for neuron in &reach.boundaries[0] {
            assert!(neuron.iter().all(|&b| b));
        }
    }

    /// Satellite: the derived set is exactly the table image — full
    /// range, clamped range, and degenerate single-value geometries.
    #[test]
    fn reachable_sets_pin_table_images() {
        // Hand-built 1-layer network shell: 2 inputs (β=2), 1 neuron,
        // fan 2, A=1 → one fused 4-bit table, out_bits 2.
        let cfg = config::uniform("r", &[2, 1], 2, 2, 2, 2, 2, 1, 1, 2);
        let net = Network::random(&cfg, &mut Rng::new(3));
        let mk = |words: Vec<u32>| NetworkTables {
            layers: vec![LayerTables {
                neurons: vec![NeuronTables {
                    poly: vec![TruthTable {
                        n_inputs: 4,
                        out_bits: 2,
                        signed_out: true,
                        words,
                    }],
                    adder: None,
                }],
                in_bits: 2,
                fan: 2,
                sub_bits: 3,
                out_bits: 2,
                signed_out: true,
            }],
            a_factor: 1,
            total_words: 16,
        };
        // Full range: identity-ish table emitting all 4 codes.
        let full = mk((0..16).map(|a| (a % 4) as u32).collect());
        let r = derive_reachable(&net, &full);
        assert_eq!(r.boundaries[1][0], vec![true; 4]);
        // Clamped range: only codes {1, 2} ever appear.
        let clamped = mk((0..16).map(|a| 1 + (a % 2) as u32).collect());
        let r = derive_reachable(&net, &clamped);
        assert_eq!(r.boundaries[1][0], vec![false, true, true, false]);
        // Degenerate: constant table → a single reachable code.
        let constant = mk(vec![3; 16]);
        let r = derive_reachable(&net, &constant);
        assert_eq!(r.boundaries[1][0], vec![false, false, false, true]);
    }

    /// Reachability is sound (a superset of the brute-force table image at
    /// every boundary) and exact where fields are jointly independent —
    /// boundary 0 (inputs) and boundary 1 (layer 0 reads the raw inputs,
    /// which take every combination).  Deeper boundaries may be strict
    /// supersets: the per-field product ignores correlations between
    /// neurons of the same layer.
    #[test]
    fn reachable_matches_brute_force_enumeration() {
        let cfg = config::uniform("b", &[3, 2, 2], 2, 2, 2, 3, 2, 2, 1, 3);
        let net = Network::random(&cfg, &mut Rng::new(11));
        let tables = compile_network(&net, 1);
        let reach = derive_reachable(&net, &tables);
        // Enumerate every input-code vector (2^(2*3) = 64) through the
        // tables — the same semantics the derivation abstracts.
        let mut seen: Vec<Vec<Vec<bool>>> = reach
            .boundaries
            .iter()
            .map(|b| b.iter().map(|s| vec![false; s.len()]).collect())
            .collect();
        let range = 1usize << cfg.beta[0];
        for combo in 0..range.pow(3) {
            let x: Vec<i32> =
                (0..3u32).map(|i| ((combo / range.pow(i)) % range) as i32).collect();
            for (src, &c) in x.iter().enumerate() {
                seen[0][src][c as usize] = true;
            }
            let mut codes = x;
            for (l, lt) in tables.layers.iter().enumerate() {
                let mut next = Vec::with_capacity(lt.neurons.len());
                for (j, neuron) in lt.neurons.iter().enumerate() {
                    let g: Vec<i32> = net.layers[l].indices[0][j]
                        .iter()
                        .map(|&s| codes[s])
                        .collect();
                    let addr = crate::lut::tables::pack_poly_addr(&g, lt.in_bits);
                    let raw = neuron.poly[0].words[addr] as usize
                        & ((1usize << lt.out_bits) - 1);
                    seen[l + 1][j][raw] = true;
                    next.push(neuron.poly[0].code_at(addr));
                }
                codes = next;
            }
        }
        for (b, layer) in seen.iter().enumerate() {
            for (j, s) in layer.iter().enumerate() {
                for (c, &hit) in s.iter().enumerate() {
                    assert!(
                        !hit || reach.boundaries[b][j][c],
                        "unsound: boundary {b} neuron {j} code {c} observed but not derived"
                    );
                }
            }
        }
        assert_eq!(seen[0], reach.boundaries[0], "inputs span the full range");
        assert_eq!(seen[1], reach.boundaries[1], "layer 0 image is exact");
    }

    /// fold+dc is bit-exact: the optimized tables agree with the
    /// original ones on every runtime-reachable path, for the whole
    /// (A, degree) grid.
    #[test]
    fn fold_dc_tables_bit_exact_on_grid() {
        for &(a, d) in &GRID {
            let net = grid_net(a, d);
            let tables = compile_network(&net, 1);
            let opt = optimize(&net, tables.clone(), OptLevel::FoldDc, 1);
            let mut rng = Rng::new(0xB17 + a as u64);
            let range = 1usize << net.cfg.beta[0];
            for _ in 0..200 {
                let x: Vec<i32> =
                    (0..net.cfg.widths[0]).map(|_| rng.below(range) as i32).collect();
                assert_eq!(
                    forward_codes_tables(&net, &opt.tables, &x),
                    net.forward_codes(&x),
                    "A={a} degree={d}"
                );
            }
            assert!(opt.report.ops_after() <= opt.report.ops_before(), "A={a} d={d}");
            assert!(opt.baseline.is_some());
        }
    }

    /// The folded netlist computes the same function as its unfolded
    /// baseline on random 64-sample words (the verify-section check, run
    /// here over the grid).
    #[test]
    fn folded_netlist_equivalent_to_baseline() {
        for &(a, d) in &GRID {
            let net = grid_net(a, d);
            let tables = compile_network(&net, 1);
            let opt = optimize(&net, tables, OptLevel::FoldDc, 1);
            let base = opt.baseline.as_ref().unwrap();
            let mut rng = Rng::new(0xF01D);
            for (l, (fl, bl)) in opt.mapped.layers.iter().zip(&base.layers).enumerate() {
                let seeds: Vec<u64> = (0..256).map(|_| rng.next_u64()).collect();
                let wires = |w: u32| seeds[w as usize % seeds.len()];
                let fv = fl.netlist.eval64(&wires);
                let bv = bl.netlist.eval64_reference(&wires);
                for (j, (fbits, bbits)) in fl.roots.iter().zip(&bl.roots).enumerate() {
                    for (b, (&fr, &br)) in fbits.iter().zip(bbits).enumerate() {
                        assert_eq!(
                            fv[fr as usize], bv[br as usize],
                            "A={a} d={d} layer {l} neuron {j} bit {b}"
                        );
                    }
                }
            }
        }
    }

    /// Folding strictly reduces (or preserves) executed ops and never
    /// changes root widths or wire numbering semantics.
    #[test]
    fn fold_reduces_ops_and_preserves_shape() {
        let net = grid_net(2, 2);
        let tables = compile_network(&net, 1);
        let opt = optimize(&net, tables, OptLevel::FoldDc, 1);
        for (l, d) in opt.report.layers.iter().enumerate() {
            assert!(d.ops_after() <= d.ops_before(), "layer {l} grew");
        }
        let base = opt.baseline.as_ref().unwrap();
        for (fl, bl) in opt.mapped.layers.iter().zip(&base.layers) {
            assert_eq!(fl.roots.len(), bl.roots.len());
            for (f, b) in fl.roots.iter().zip(&bl.roots) {
                assert_eq!(f.len(), b.len());
            }
            assert!(fl.depth <= bl.depth, "fold must not deepen the layer");
        }
    }

    /// Pruning stays behind the explicit opt-in and reports its
    /// agreement delta; fold+dc never reports one.
    #[test]
    fn pruning_is_opt_in_and_reports_agreement() {
        let net = grid_net(3, 1);
        let tables = compile_network(&net, 1);
        let dc = optimize(&net, tables.clone(), OptLevel::FoldDc, 1);
        assert_eq!(dc.report.pruned_subs, 0);
        assert!(dc.report.exact_agreement.is_none());
        let all = optimize(&net, tables, OptLevel::All, 1);
        assert_eq!(all.report.level, OptLevel::All);
        if all.report.pruned_subs > 0 {
            let exact = all.report.exact_agreement.unwrap();
            let class = all.report.class_agreement.unwrap();
            assert!((0.0..=1.0).contains(&exact));
            assert!(class >= exact, "class agreement can only be looser");
        } else {
            assert!(all.report.exact_agreement.is_none());
        }
    }

    /// Pruning with an aggressive threshold rewrites sub-neuron tables
    /// to constants and the pipeline still produces runnable mappings.
    #[test]
    fn aggressive_pruning_rewrites_tables() {
        let net = grid_net(3, 1);
        let tables = compile_network(&net, 1);
        let original = tables.clone();
        let mut pruned_tables = tables;
        let reach = derive_reachable(&net, &pruned_tables);
        let pruned = prune_low_contribution(&net, &mut pruned_tables, &reach, 1.0);
        assert!(pruned > 0, "frac=1.0 prunes every non-widest sub-neuron");
        let (exact, class) = measure_agreement(&net, &original, &pruned_tables, 64);
        assert!((0.0..=1.0).contains(&exact));
        assert!((0.0..=1.0).contains(&class));
        // Layout preserved: same table counts and sizes.
        for (lo, ln) in original.layers.iter().zip(&pruned_tables.layers) {
            for (no, nn) in lo.neurons.iter().zip(&ln.neurons) {
                assert_eq!(no.poly.len(), nn.poly.len());
                for (to, tn) in no.poly.iter().zip(&nn.poly) {
                    assert_eq!(to.words.len(), tn.words.len());
                }
            }
        }
    }

    /// `none` is a true no-op: tables untouched, before == after.
    #[test]
    fn level_none_is_identity() {
        let net = grid_net(2, 1);
        let tables = compile_network(&net, 1);
        let words: Vec<Vec<u32>> = tables.layers[0]
            .neurons
            .iter()
            .flat_map(|n| n.poly.iter().map(|t| t.words.clone()))
            .collect();
        let opt = optimize(&net, tables, OptLevel::None, 1);
        assert_eq!(opt.report.ops_before(), opt.report.ops_after());
        assert!(opt.baseline.is_none());
        let after: Vec<Vec<u32>> = opt.tables.layers[0]
            .neurons
            .iter()
            .flat_map(|n| n.poly.iter().map(|t| t.words.clone()))
            .collect();
        assert_eq!(words, after);
    }

    /// The DC rewrite is deterministic (fingerprint handshake safety):
    /// two runs over the same tables produce identical words.
    #[test]
    fn dc_rewrite_is_deterministic() {
        let net = grid_net(2, 2);
        let tables = compile_network(&net, 1);
        let a = optimize(&net, tables.clone(), OptLevel::FoldDc, 1);
        let b = optimize(&net, tables, OptLevel::FoldDc, 2);
        for (la, lb) in a.tables.layers.iter().zip(&b.tables.layers) {
            for (na, nb) in la.neurons.iter().zip(&lb.neurons) {
                for (ta, tb) in na.poly.iter().zip(&nb.poly) {
                    assert_eq!(ta.words, tb.words);
                }
                assert_eq!(
                    na.adder.as_ref().map(|t| &t.words),
                    nb.adder.as_ref().map(|t| &t.words)
                );
            }
        }
    }

    /// render_table shows every layer plus a total row.
    #[test]
    fn report_table_renders() {
        let net = grid_net(2, 1);
        let tables = compile_network(&net, 1);
        let opt = optimize(&net, tables, OptLevel::FoldDc, 1);
        let s = opt.report.render_table();
        assert!(s.contains("netlist-opt [fold+dc]"));
        assert!(s.contains("total"));
        assert!(s.contains("L0") && s.contains("L1"));
    }

    /// add_simplified_lut: constants cofactor away, duplicates merge,
    /// identities alias.
    #[test]
    fn simplified_lut_collapses() {
        let mut nl = Netlist::new();
        let a = nl.input(0);
        let t = nl.constant(true);
        // f(a, 1) where f = AND → identity on a.
        let (id, simplified) =
            add_simplified_lut(&mut nl, &[a, t], &|addr| addr & 0b11 == 0b11);
        assert_eq!(id, a);
        assert!(simplified);
        // f(a, a) where f = XOR → constant false.
        let (id, _) = add_simplified_lut(&mut nl, &[a, a], &|addr| {
            (addr & 1) ^ (addr >> 1 & 1) == 1
        });
        assert!(matches!(nl.nodes[id as usize], Node::Const(false)));
        // A real 2-input function stays a LUT.
        let b = nl.input(1);
        let (id, simplified) =
            add_simplified_lut(&mut nl, &[a, b], &|addr| addr & 0b11 == 0b11);
        assert!(matches!(nl.nodes[id as usize], Node::Lut { .. }));
        assert!(!simplified);
    }
}
