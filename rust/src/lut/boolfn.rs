//! Dense Boolean functions (truth-table bitvectors) — the mapper's working
//! representation.  A `BoolFn` over `n` variables stores `2^n` bits packed
//! into u64 words; variable `i` is address bit `i`.  All operations are the
//! classic cube ones: cofactoring, vacuous-variable detection, support
//! reduction.  Sizes here are small (n ≤ 26 by config validation), so dense
//! tables beat BDDs on simplicity and, for these sizes, on speed.

use std::hash::{Hash, Hasher};

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BoolFn {
    pub n: u32,
    /// 2^n bits, LSB-first within each u64; length = max(1, 2^n / 64).
    pub bits: Vec<u64>,
}

impl Hash for BoolFn {
    fn hash<H: Hasher>(&self, state: &mut H) {
        self.n.hash(state);
        self.bits.hash(state);
    }
}

impl BoolFn {
    pub fn from_bits(n: u32, bits: Vec<u64>) -> BoolFn {
        let want = words_for(n);
        assert_eq!(bits.len(), want, "bad bitvector length for n={n}");
        let mut f = BoolFn { n, bits };
        f.mask_tail();
        f
    }

    pub fn constant(n: u32, val: bool) -> BoolFn {
        let mut f =
            BoolFn { n, bits: vec![if val { u64::MAX } else { 0 }; words_for(n)] };
        f.mask_tail();
        f
    }

    /// The projection function f = x_var.
    pub fn var(n: u32, var: u32) -> BoolFn {
        let size = 1usize << n;
        let mut bits = vec![0u64; words_for(n)];
        for addr in 0..size {
            if (addr >> var) & 1 == 1 {
                bits[addr / 64] |= 1 << (addr % 64);
            }
        }
        BoolFn::from_bits(n, bits)
    }

    fn mask_tail(&mut self) {
        let size = 1usize << self.n;
        if size < 64 {
            self.bits[0] &= (1u64 << size) - 1;
        }
    }

    #[inline]
    pub fn get(&self, addr: usize) -> bool {
        (self.bits[addr / 64] >> (addr % 64)) & 1 == 1
    }

    pub fn size(&self) -> usize {
        1usize << self.n
    }

    pub fn is_const(&self) -> Option<bool> {
        let size = 1usize << self.n;
        if size < 64 {
            let mask = (1u64 << size) - 1;
            let v = self.bits[0] & mask;
            if v == 0 {
                return Some(false);
            }
            if v == mask {
                return Some(true);
            }
            return None;
        }
        if self.bits.iter().all(|&w| w == 0) {
            Some(false)
        } else if self.bits.iter().all(|&w| w == u64::MAX) {
            Some(true)
        } else {
            None
        }
    }

    /// Positive/negative cofactor with respect to `var` (result has n-1 vars;
    /// variables above `var` shift down by one).
    pub fn cofactor(&self, var: u32, val: bool) -> BoolFn {
        debug_assert!(var < self.n);
        let n2 = self.n - 1;
        let size2 = 1usize << n2;
        let mut bits = vec![0u64; words_for(n2)];
        // Fast path: var >= 6 means whole u64 words are selected.
        if var >= 6 {
            let stride = 1usize << (var - 6); // words per half-block
            let mut dst = 0usize;
            let mut src = if val { stride } else { 0 };
            while dst < words_for(n2).max(1) && src < self.bits.len() {
                for k in 0..stride.min(words_for(n2) - dst) {
                    bits[dst + k] = self.bits[src + k];
                }
                dst += stride;
                src += 2 * stride;
            }
        } else {
            for addr2 in 0..size2 {
                let lo_mask = (1usize << var) - 1;
                let addr = (addr2 & lo_mask)
                    | ((val as usize) << var)
                    | ((addr2 & !lo_mask) << 1);
                if self.get(addr) {
                    bits[addr2 / 64] |= 1 << (addr2 % 64);
                }
            }
        }
        BoolFn::from_bits(n2, bits)
    }

    /// True if f does not depend on `var` — checked in place (no cofactor
    /// materialization; this is the mapper's innermost loop).
    pub fn is_vacuous(&self, var: u32) -> bool {
        if var < 6 {
            // Within-word comparison: mask of positions whose address bit
            // `var` is 0, compared against the same word shifted by 2^var.
            const MASKS: [u64; 6] = [
                0x5555_5555_5555_5555,
                0x3333_3333_3333_3333,
                0x0F0F_0F0F_0F0F_0F0F,
                0x00FF_00FF_00FF_00FF,
                0x0000_FFFF_0000_FFFF,
                0x0000_0000_FFFF_FFFF,
            ];
            let sh = 1u32 << var;
            let m = if self.n <= var {
                return true;
            } else {
                MASKS[var as usize]
            };
            // For n < 6 the tail is masked to zero already (mask_tail), and
            // zero-vs-zero compares equal, so no special casing is needed.
            self.bits.iter().all(|&w| ((w >> sh) ^ w) & m == 0)
        } else {
            // Whole-word stride comparison.
            let stride = 1usize << (var - 6);
            if stride >= self.bits.len() {
                return true;
            }
            let mut base = 0usize;
            while base + stride < self.bits.len() {
                for k in 0..stride {
                    if self.bits[base + k] != self.bits[base + stride + k] {
                        return false;
                    }
                }
                base += 2 * stride;
            }
            true
        }
    }

    /// Drop all vacuous variables in a single extraction pass.
    /// Returns (reduced fn, kept-variable list: reduced var i corresponds to
    /// original var kept[i]).
    pub fn support_reduce(&self) -> (BoolFn, Vec<u32>) {
        let kept: Vec<u32> = (0..self.n).filter(|&v| !self.is_vacuous(v)).collect();
        if kept.len() == self.n as usize {
            return (self.clone(), kept);
        }
        let n2 = kept.len() as u32;
        let mut bits = vec![0u64; words_for(n2)];
        for addr2 in 0..(1usize << n2) {
            // Expand the reduced address into the original space with all
            // vacuous variables at 0.
            let mut addr = 0usize;
            for (i, &v) in kept.iter().enumerate() {
                addr |= ((addr2 >> i) & 1) << v;
            }
            if self.get(addr) {
                bits[addr2 / 64] |= 1 << (addr2 % 64);
            }
        }
        (BoolFn::from_bits(n2, bits), kept)
    }

    /// Evaluate on a full assignment of the original variables.
    pub fn eval(&self, assignment: &[bool]) -> bool {
        debug_assert_eq!(assignment.len(), self.n as usize);
        let mut addr = 0usize;
        for (i, &b) in assignment.iter().enumerate() {
            addr |= (b as usize) << i;
        }
        self.get(addr)
    }

    /// For n <= 6: the 64-bit LUT mask (truth table of a physical LUT6).
    pub fn lut_mask(&self) -> u64 {
        assert!(self.n <= 6, "lut_mask needs n<=6, got {}", self.n);
        self.bits[0]
    }
}

#[inline]
pub fn words_for(n: u32) -> usize {
    (1usize << n).div_ceil(64)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn from_fn(n: u32, f: impl Fn(usize) -> bool) -> BoolFn {
        let mut bits = vec![0u64; words_for(n)];
        for addr in 0..(1usize << n) {
            if f(addr) {
                bits[addr / 64] |= 1 << (addr % 64);
            }
        }
        BoolFn::from_bits(n, bits)
    }

    #[test]
    fn cofactor_small_var() {
        // f = x0 XOR x1 over 3 vars (x2 vacuous).
        let f = from_fn(3, |a| ((a & 1) ^ ((a >> 1) & 1)) == 1);
        let f0 = f.cofactor(0, false); // = x1 (over remaining vars x1->0, x2->1)
        let f1 = f.cofactor(0, true); // = !x1
        assert_eq!(f0, from_fn(2, |a| a & 1 == 1));
        assert_eq!(f1, from_fn(2, |a| a & 1 == 0));
        assert!(f.is_vacuous(2));
        assert!(!f.is_vacuous(0));
    }

    #[test]
    fn cofactor_large_var_word_path() {
        // 8 vars; f depends only on x7: checks the word-stride fast path.
        let f = from_fn(8, |a| (a >> 7) & 1 == 1);
        assert_eq!(f.cofactor(7, false), BoolFn::constant(7, false));
        assert_eq!(f.cofactor(7, true), BoolFn::constant(7, true));
        // and a mixed function
        let g = from_fn(8, |a| ((a >> 7) & 1 == 1) ^ (a & 1 == 1));
        let g0 = g.cofactor(7, false);
        assert_eq!(g0, from_fn(7, |a| a & 1 == 1));
        let g1 = g.cofactor(7, true);
        assert_eq!(g1, from_fn(7, |a| a & 1 == 0));
    }

    #[test]
    fn support_reduction() {
        // 10 vars, only x3 and x8 matter: f = x3 AND x8.
        let f = from_fn(10, |a| ((a >> 3) & 1 == 1) && ((a >> 8) & 1 == 1));
        let (r, kept) = f.support_reduce();
        assert_eq!(kept, vec![3, 8]);
        assert_eq!(r, from_fn(2, |a| a == 0b11));
    }

    #[test]
    fn consts_and_var() {
        assert_eq!(BoolFn::constant(4, true).is_const(), Some(true));
        assert_eq!(BoolFn::constant(7, false).is_const(), Some(false));
        assert_eq!(BoolFn::var(3, 1), from_fn(3, |a| (a >> 1) & 1 == 1));
        assert_eq!(BoolFn::var(3, 1).is_const(), None);
    }

    #[test]
    fn cofactor_consistency_random() {
        let mut rng = crate::util::rng::Rng::new(5);
        for n in 3..=9u32 {
            let f = {
                let mut bits = vec![0u64; words_for(n)];
                for w in bits.iter_mut() {
                    *w = rng.next_u64();
                }
                BoolFn::from_bits(n, bits)
            };
            for var in 0..n {
                let f0 = f.cofactor(var, false);
                let f1 = f.cofactor(var, true);
                for addr in 0..(1usize << n) {
                    let bit = (addr >> var) & 1 == 1;
                    let lo_mask = (1usize << var) - 1;
                    let addr2 = (addr & lo_mask) | ((addr >> 1) & !lo_mask);
                    let c = if bit { &f1 } else { &f0 };
                    assert_eq!(f.get(addr), c.get(addr2), "n={n} var={var} addr={addr}");
                }
            }
        }
    }
}
