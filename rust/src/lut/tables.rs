//! Truth-table generation — freezing the trained network into lookup tables.
//!
//! This is the paper's "LUT generation" toolflow stage (Fig. 4): for every
//! Poly-layer sub-neuron enumerate all `2^{βF}` input-code combinations
//! through the bit-exact fixed-point transfer function; for every
//! Adder-layer neuron (A > 1) enumerate all `2^{A(β+1)}` sub-neuron code
//! combinations through sum → BN → activation → quant.  For A == 1 the whole
//! neuron collapses into a single `2^{βF}` table (plain PolyLUT).
//!
//! Table words store the output code in raw two's complement (masked to the
//! output width), which is exactly what the RTL ROMs hold.

use crate::nn::network::Network;
use crate::nn::quant::{from_twos_complement, to_twos_complement};
use crate::util::pool::parallel_map;

/// A single lookup table: `words[addr]` = raw output code (`out_bits` wide).
#[derive(Debug, Clone, PartialEq)]
pub struct TruthTable {
    pub n_inputs: u32,
    pub out_bits: u32,
    /// Whether the stored code is two's-complement signed.
    pub signed_out: bool,
    pub words: Vec<u32>,
}

impl TruthTable {
    pub fn size(&self) -> usize {
        1usize << self.n_inputs
    }

    /// Decode a word back to an integer code.
    pub fn code_at(&self, addr: usize) -> i32 {
        let raw = self.words[addr];
        if self.signed_out {
            from_twos_complement(raw, self.out_bits)
        } else {
            raw as i32
        }
    }

    /// Decode every word to an `i32` code in address order — the flat-table
    /// layout `sim::plan` compiles into (decoding happens once here, keeping
    /// sign handling off the evaluation hot path).
    pub fn decoded(&self) -> impl Iterator<Item = i32> + '_ {
        (0..self.size()).map(|addr| self.code_at(addr))
    }

    /// Extract single output bit `b` as a bitvector truth table
    /// (one u64 per 64 addresses) — the mapper's input.
    pub fn bit_plane(&self, b: u32) -> Vec<u64> {
        let n = self.size();
        let mut out = vec![0u64; n.div_ceil(64)];
        for (addr, &w) in self.words.iter().enumerate() {
            if (w >> b) & 1 == 1 {
                out[addr / 64] |= 1u64 << (addr % 64);
            }
        }
        out
    }
}

/// Tables for one neuron.
#[derive(Debug, Clone)]
pub struct NeuronTables {
    /// A tables of `2^{βF}` words each (for A == 1 this single table already
    /// includes BN + activation and `adder` is None).
    pub poly: Vec<TruthTable>,
    /// The Adder-layer table (`2^{A(β+1)}` words), present iff A > 1.
    pub adder: Option<TruthTable>,
}

impl NeuronTables {
    pub fn words(&self) -> u128 {
        self.poly.iter().map(|t| t.size() as u128).sum::<u128>()
            + self.adder.as_ref().map(|t| t.size() as u128).unwrap_or(0)
    }
}

/// Tables for one layer.
#[derive(Debug, Clone)]
pub struct LayerTables {
    pub neurons: Vec<NeuronTables>,
    /// Input code width (β of this layer).
    pub in_bits: u32,
    pub fan: usize,
    /// Sub-neuron output width (β+1) — adder-table field width.
    pub sub_bits: u32,
    /// Layer output code width.
    pub out_bits: u32,
    pub signed_out: bool,
}

impl LayerTables {
    /// Words per poly table in this layer: `2^{β·F}`.  In a flat per-layer
    /// table vector, sub-neuron `(j, a)` starts at
    /// `(j*A + a) * poly_stride()`.
    pub fn poly_stride(&self) -> usize {
        1usize << (self.in_bits * self.fan as u32)
    }

    /// Words per adder table: `2^{A·(β+1)}`, or 0 when `a_factor == 1`
    /// (plain PolyLUT has no adder stage).  In a flat per-layer adder
    /// vector, neuron `j` starts at `j * adder_stride(a)`.
    pub fn adder_stride(&self, a_factor: usize) -> usize {
        if a_factor > 1 {
            1usize << (a_factor as u32 * self.sub_bits)
        } else {
            0
        }
    }
}

/// The full frozen network.
#[derive(Debug, Clone)]
pub struct NetworkTables {
    pub layers: Vec<LayerTables>,
    pub a_factor: usize,
    /// Paper Table II "lookup table size" accounting.
    pub total_words: u128,
}

impl NetworkTables {
    pub fn n_tables(&self) -> usize {
        self.layers
            .iter()
            .flat_map(|l| &l.neurons)
            .map(|n| n.poly.len() + n.adder.is_some() as usize)
            .sum()
    }
}

/// Pack F input codes into a poly-table address (slot i at bits [i*β, (i+1)*β)).
#[inline]
pub fn pack_poly_addr(codes: &[i32], beta: u32) -> usize {
    let mut addr = 0usize;
    for (i, &c) in codes.iter().enumerate() {
        addr |= (c as usize & ((1 << beta) - 1)) << (i as u32 * beta);
    }
    addr
}

/// Unpack a poly-table address into F unsigned codes.
#[inline]
pub fn unpack_poly_addr(addr: usize, fan: usize, beta: u32, out: &mut [i32]) {
    let mask = (1usize << beta) - 1;
    for (i, o) in out.iter_mut().enumerate().take(fan) {
        *o = ((addr >> (i as u32 * beta)) & mask) as i32;
    }
}

/// Pack A signed sub-neuron codes into an adder-table address.
#[inline]
pub fn pack_adder_addr(codes: &[i32], sub_bits: u32) -> usize {
    let mut addr = 0usize;
    for (i, &c) in codes.iter().enumerate() {
        addr |= (to_twos_complement(c, sub_bits) as usize) << (i as u32 * sub_bits);
    }
    addr
}

/// Unpack an adder-table address into A signed codes.
#[inline]
pub fn unpack_adder_addr(addr: usize, a: usize, sub_bits: u32, out: &mut [i32]) {
    let mask = (1usize << sub_bits) - 1;
    for (i, o) in out.iter_mut().enumerate().take(a) {
        *o = from_twos_complement(((addr >> (i as u32 * sub_bits)) & mask) as u32, sub_bits);
    }
}

/// Generate all tables for one neuron of layer `l`.
pub fn compile_neuron(net: &Network, l: usize, j: usize) -> NeuronTables {
    let cfg = &net.cfg;
    let (beta, fan, a) = (cfg.beta[l], cfg.fan[l], cfg.a_factor);
    let sub_bits = cfg.sub_bits(l);
    let out_bits = cfg.beta[l + 1];
    let last = l == cfg.n_layers() - 1;
    let poly_size = 1usize << (beta * fan as u32);
    let mut in_codes = vec![0i32; fan];

    if a == 1 {
        // Plain PolyLUT: one fused table (poly → quant → BN → act → quant).
        let mut words = vec![0u32; poly_size];
        for (addr, w) in words.iter_mut().enumerate() {
            unpack_poly_addr(addr, fan, beta, &mut in_codes);
            let sub = net.sub_neuron_code(l, 0, j, &in_codes);
            let out = net.adder_code(l, j, &[sub]);
            *w = to_twos_complement(out, out_bits);
        }
        return NeuronTables {
            poly: vec![TruthTable { n_inputs: beta * fan as u32, out_bits, signed_out: last, words }],
            adder: None,
        };
    }

    // Poly tables: sub-neuron transfer functions.
    let poly = (0..a)
        .map(|ai| {
            let mut words = vec![0u32; poly_size];
            for (addr, w) in words.iter_mut().enumerate() {
                unpack_poly_addr(addr, fan, beta, &mut in_codes);
                let sub = net.sub_neuron_code(l, ai, j, &in_codes);
                *w = to_twos_complement(sub, sub_bits);
            }
            TruthTable { n_inputs: beta * fan as u32, out_bits: sub_bits, signed_out: true, words }
        })
        .collect();

    // Adder table: A signed fields → output code.
    let adder_size = 1usize << (a as u32 * sub_bits);
    let mut sub_codes = vec![0i32; a];
    let mut words = vec![0u32; adder_size];
    for (addr, w) in words.iter_mut().enumerate() {
        unpack_adder_addr(addr, a, sub_bits, &mut sub_codes);
        let out = net.adder_code(l, j, &sub_codes);
        *w = to_twos_complement(out, out_bits);
    }
    let adder = TruthTable {
        n_inputs: a as u32 * sub_bits,
        out_bits,
        signed_out: last,
        words,
    };
    NeuronTables { poly, adder: Some(adder) }
}

/// Generate all tables for a network (parallel over neurons).
pub fn compile_network(net: &Network, workers: usize) -> NetworkTables {
    let cfg = &net.cfg;
    let mut layers = Vec::new();
    for (l, (_, n_out)) in cfg.layer_dims().into_iter().enumerate() {
        let jobs: Vec<usize> = (0..n_out).collect();
        let neurons = parallel_map(&jobs, workers, |_, &j| compile_neuron(net, l, j));
        layers.push(LayerTables {
            neurons,
            in_bits: cfg.beta[l],
            fan: cfg.fan[l],
            sub_bits: cfg.sub_bits(l),
            out_bits: cfg.beta[l + 1],
            signed_out: l == cfg.n_layers() - 1,
        });
    }
    let total_words = layers.iter().flat_map(|l| &l.neurons).map(|n| n.words()).sum();
    NetworkTables { layers, a_factor: cfg.a_factor, total_words }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::config;
    use crate::util::rng::Rng;

    fn tiny(a: usize) -> Network {
        let cfg = config::uniform("t", &[8, 6, 3], 2, 2, 3, 3, 3, 2, a, 3);
        Network::random(&cfg, &mut Rng::new(7))
    }

    #[test]
    fn addr_packing_roundtrip() {
        let mut out = [0i32; 4];
        for addr in 0..(1usize << 8) {
            unpack_poly_addr(addr, 4, 2, &mut out);
            assert_eq!(pack_poly_addr(&out, 2), addr);
        }
        let mut s = [0i32; 2];
        for addr in 0..(1usize << 6) {
            unpack_adder_addr(addr, 2, 3, &mut s);
            assert_eq!(pack_adder_addr(&s, 3), addr);
        }
    }

    #[test]
    fn table_matches_neuron_function() {
        let net = tiny(2);
        let nt = compile_neuron(&net, 0, 0);
        assert_eq!(nt.poly.len(), 2);
        let adder = nt.adder.as_ref().unwrap();
        let mut rng = Rng::new(3);
        for _ in 0..200 {
            let codes: Vec<i32> = (0..3).map(|_| rng.below(4) as i32).collect();
            let addr = pack_poly_addr(&codes, 2);
            for a in 0..2 {
                assert_eq!(nt.poly[a].code_at(addr), net.sub_neuron_code(0, a, 0, &codes));
            }
            let subs = [nt.poly[0].code_at(addr), nt.poly[1].code_at(addr)];
            let aaddr = pack_adder_addr(&subs, net.cfg.sub_bits(0));
            assert_eq!(adder.code_at(aaddr), net.adder_code(0, 0, &subs));
        }
    }

    #[test]
    fn a1_is_single_fused_table() {
        let net = tiny(1);
        let nt = compile_neuron(&net, 0, 0);
        assert_eq!(nt.poly.len(), 1);
        assert!(nt.adder.is_none());
        let t = &nt.poly[0];
        let mut codes = [0i32; 3];
        for addr in 0..t.size() {
            unpack_poly_addr(addr, 3, 2, &mut codes);
            let sub = net.sub_neuron_code(0, 0, 0, &codes);
            assert_eq!(t.code_at(addr), net.adder_code(0, 0, &[sub]));
        }
    }

    #[test]
    fn paper_table_accounting() {
        // HDR-style neuron: beta=2 F=6 A=2 -> 2 * 2^12 + 2^6 words.
        let cfg = config::hdr(1, 2);
        let net = Network::random(&cfg, &mut Rng::new(1));
        let nt = compile_neuron(&net, 1, 0);
        assert_eq!(nt.words(), 2 * (1 << 12) + (1 << 6));
    }

    #[test]
    fn bit_plane_roundtrip() {
        let net = tiny(2);
        let t = &compile_neuron(&net, 0, 0).poly[0];
        let planes: Vec<Vec<u64>> = (0..t.out_bits).map(|b| t.bit_plane(b)).collect();
        for addr in 0..t.size() {
            let mut raw = 0u32;
            for (b, p) in planes.iter().enumerate() {
                raw |= (((p[addr / 64] >> (addr % 64)) & 1) as u32) << b;
            }
            assert_eq!(raw, t.words[addr]);
        }
    }

    #[test]
    fn network_tables_totals() {
        let net = tiny(2);
        let all = compile_network(&net, 2);
        assert_eq!(all.layers.len(), 2);
        let manual: u128 = all.layers.iter().flat_map(|l| &l.neurons).map(|n| n.words()).sum();
        assert_eq!(all.total_words, manual);
        assert_eq!(all.total_words, net.cfg.table_words_total());
    }
}
