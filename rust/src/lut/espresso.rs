//! Two-level Boolean minimization (Espresso-lite).
//!
//! LogicNets' released toolflow runs Espresso on each neuron's truth table
//! before RTL emission; this module provides the same capability as an
//! optional pre-pass for reporting and for the `polylut report` cube
//! statistics.  It implements the classic Espresso loop on cube lists —
//! EXPAND (greedy literal removal against the OFF-set), IRREDUNDANT (drop
//! covered cubes) — over the dense `BoolFn` representation, which is exact
//! at the sizes this repo deals with (≤ ~16 inputs).
//!
//! The result is a sum-of-products cover: useful both as an area proxy
//! (cube/literal counts correlate with pre-mapping logic complexity) and to
//! emit human-auditable Boolean expressions for small neurons.

use super::boolfn::BoolFn;

/// A product term over n variables: for each variable, `care` bit set means
/// the literal participates, `value` bit gives its polarity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Cube {
    pub care: u32,
    pub value: u32,
}

impl Cube {
    /// The minterm cube for an assignment.
    pub fn minterm(addr: usize, n: u32) -> Cube {
        Cube { care: (1u32 << n) - 1, value: addr as u32 }
    }

    /// Does this cube contain the given assignment?
    #[inline]
    pub fn covers(&self, addr: usize) -> bool {
        (addr as u32 ^ self.value) & self.care == 0
    }

    /// Number of literals in the product term.
    pub fn literals(&self) -> u32 {
        self.care.count_ones()
    }

    /// Is `other` entirely contained in this cube?
    pub fn contains(&self, other: &Cube) -> bool {
        // Every literal of self must be a literal of other with the same
        // polarity.
        self.care & other.care == self.care
            && (self.value ^ other.value) & self.care == 0
    }
}

/// A sum-of-products cover.
#[derive(Debug, Clone, Default)]
pub struct Cover {
    pub n: u32,
    pub cubes: Vec<Cube>,
}

impl Cover {
    pub fn eval(&self, addr: usize) -> bool {
        self.cubes.iter().any(|c| c.covers(addr))
    }

    pub fn literal_count(&self) -> u32 {
        self.cubes.iter().map(|c| c.literals()).sum()
    }

    /// Verify the cover implements `f` exactly.
    pub fn equals(&self, f: &BoolFn) -> bool {
        (0..f.size()).all(|addr| self.eval(addr) == f.get(addr))
    }

    /// Render as a human-readable SOP expression (x3' = NOT x3).
    pub fn to_expression(&self) -> String {
        if self.cubes.is_empty() {
            return "0".into();
        }
        let terms: Vec<String> = self
            .cubes
            .iter()
            .map(|c| {
                if c.care == 0 {
                    return "1".into();
                }
                (0..self.n)
                    .filter(|&v| c.care >> v & 1 == 1)
                    .map(|v| {
                        if c.value >> v & 1 == 1 {
                            format!("x{v}")
                        } else {
                            format!("x{v}'")
                        }
                    })
                    .collect::<Vec<_>>()
                    .join("·")
            })
            .collect();
        terms.join(" + ")
    }
}

/// Minimize `f` into an irredundant prime-ish cover (Espresso EXPAND +
/// IRREDUNDANT loop; exact containment checks against ON/OFF sets).
///
/// Deterministic: the whole loop runs over position-stable `Vec`s (no hash
/// iteration anywhere) and the result is put into the canonical cube order,
/// so identical inputs always produce identical covers.
pub fn minimize(f: &BoolFn) -> Cover {
    minimize_dc(f, &BoolFn::constant(f.n, true))
}

/// [`minimize`] with an explicit care set: `care.get(a) == false` marks
/// address `a` as a don't-care the expansion may freely absorb.  The
/// returned cover agrees with `f` on every care point; its value on
/// don't-care points is whatever makes the cover smallest.
///
/// This is the hook the netlist optimizer ([`crate::lut::opt`]) uses to
/// re-materialize truth tables under unreachable-code don't-cares.
pub fn minimize_dc(f: &BoolFn, care: &BoolFn) -> Cover {
    let n = f.n;
    assert!(n <= 16, "espresso-lite is for table-sized functions");
    assert_eq!(care.n, n, "care set arity mismatch");
    let size = 1usize << n;

    // Start from the care ON-set minterms.
    let mut cubes: Vec<Cube> = (0..size)
        .filter(|&a| care.get(a) && f.get(a))
        .map(|a| Cube::minterm(a, n))
        .collect();
    if cubes.is_empty() {
        return Cover { n, cubes };
    }
    if (0..size).all(|a| !care.get(a) || f.get(a)) {
        return Cover { n, cubes: vec![Cube { care: 0, value: 0 }] };
    }

    // EXPAND: greedily drop literals while the cube avoids the care OFF-set
    // (don't-care points are absorbable by construction).
    for cube in cubes.iter_mut() {
        for v in 0..n {
            if cube.care >> v & 1 == 0 {
                continue;
            }
            let candidate = Cube { care: cube.care & !(1 << v), value: cube.value };
            // Valid iff no care OFF-set point is covered. Enumerate the
            // cube's free variables only (2^(n - literals) points).
            if cube_avoids_off_set(&candidate, f, care) {
                *cube = candidate;
            }
        }
    }

    // Normalize (value bits outside the care mask are noise) so dedup and
    // the canonical ordering see one representative per cube.
    for cube in cubes.iter_mut() {
        cube.value &= cube.care;
    }

    // Dedup + IRREDUNDANT: remove cubes covered by the union of the others.
    cubes.sort_by_key(|c| (c.care, c.value));
    cubes.dedup();
    // Sort by size (largest cube first) so redundant minterms get dropped.
    cubes.sort_by_key(|c| c.literals());
    let mut keep: Vec<Cube> = Vec::with_capacity(cubes.len());
    // Pairwise containment first (cheap).
    for c in &cubes {
        if !keep.iter().any(|k| k.contains(c)) {
            keep.push(*c);
        }
    }
    // Full irredundancy: drop any cube all of whose *care* points are
    // covered by the rest (don't-care points need no cover).
    let mut i = 0;
    while i < keep.len() {
        let cube = keep[i];
        let others_cover_all = enumerate_cube(&cube, n)
            .filter(|&addr| care.get(addr))
            .all(|addr| keep.iter().enumerate().any(|(j, k)| j != i && k.covers(addr)));
        if others_cover_all {
            keep.remove(i);
        } else {
            i += 1;
        }
    }
    // Canonical result order: fewest literals first, then (care, value) —
    // a total order on cubes, so the cover is a function of the inputs
    // alone (pinned by `minimize_is_deterministic`).
    keep.sort_by_key(|c| (c.literals(), c.care, c.value));
    Cover { n, cubes: keep }
}

/// Iterate all assignments inside a cube.
fn enumerate_cube(cube: &Cube, n: u32) -> impl Iterator<Item = usize> + '_ {
    let free: Vec<u32> = (0..n).filter(|&v| cube.care >> v & 1 == 0).collect();
    let base = (cube.value & cube.care) as usize;
    (0..(1usize << free.len())).map(move |k| {
        let mut addr = base;
        for (i, &v) in free.iter().enumerate() {
            addr |= ((k >> i) & 1) << v;
        }
        addr
    })
}

/// Does the cube cover no care OFF-set point (care ∧ ¬f)?
fn cube_avoids_off_set(cube: &Cube, f: &BoolFn, care: &BoolFn) -> bool {
    enumerate_cube(cube, f.n).all(|addr| f.get(addr) || !care.get(addr))
}

/// Cube-count statistics for a truth table's output bits (reporting aid).
pub fn table_cube_stats(table: &super::tables::TruthTable) -> (usize, u32) {
    let mut cubes = 0usize;
    let mut literals = 0u32;
    for b in 0..table.out_bits {
        let f = BoolFn::from_bits(table.n_inputs, table.bit_plane(b));
        let cover = minimize(&f);
        cubes += cover.cubes.len();
        literals += cover.literal_count();
    }
    (cubes, literals)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn from_fn(n: u32, f: impl Fn(usize) -> bool) -> BoolFn {
        let mut bits = vec![0u64; super::super::boolfn::words_for(n)];
        for addr in 0..(1usize << n) {
            if f(addr) {
                bits[addr / 64] |= 1 << (addr % 64);
            }
        }
        BoolFn::from_bits(n, bits)
    }

    #[test]
    fn minimizes_and_function() {
        // f = x0 AND x1 over 3 vars: one cube, two literals.
        let f = from_fn(3, |a| a & 0b11 == 0b11);
        let cover = minimize(&f);
        assert!(cover.equals(&f));
        assert_eq!(cover.cubes.len(), 1);
        assert_eq!(cover.literal_count(), 2);
        assert_eq!(cover.to_expression(), "x0·x1");
    }

    #[test]
    fn minimizes_xor_needs_two_cubes() {
        let f = from_fn(2, |a| (a & 1) ^ ((a >> 1) & 1) == 1);
        let cover = minimize(&f);
        assert!(cover.equals(&f));
        assert_eq!(cover.cubes.len(), 2);
        assert_eq!(cover.literal_count(), 4, "XOR is not single-cube compressible");
    }

    #[test]
    fn constants() {
        let f0 = BoolFn::constant(4, false);
        assert_eq!(minimize(&f0).cubes.len(), 0);
        let f1 = BoolFn::constant(4, true);
        let c = minimize(&f1);
        assert_eq!(c.cubes.len(), 1);
        assert_eq!(c.literal_count(), 0);
        assert_eq!(c.to_expression(), "1");
    }

    #[test]
    fn random_functions_roundtrip_exactly() {
        let mut rng = Rng::new(42);
        for n in 2..=8u32 {
            for _ in 0..8 {
                let pattern: Vec<bool> =
                    (0..(1usize << n)).map(|_| rng.chance(0.4)).collect();
                let f = from_fn(n, |a| pattern[a]);
                let cover = minimize(&f);
                assert!(cover.equals(&f), "n={n}");
                // Never worse than the minterm cover.
                let minterms = (0..(1usize << n)).filter(|&a| f.get(a)).count();
                assert!(cover.cubes.len() <= minterms.max(1));
            }
        }
    }

    #[test]
    fn sparse_function_compresses_well() {
        // f depends only on x2 (of 6 vars): must compress to 1 cube, 1 literal.
        let f = from_fn(6, |a| (a >> 2) & 1 == 1);
        let cover = minimize(&f);
        assert!(cover.equals(&f));
        assert_eq!(cover.cubes.len(), 1);
        assert_eq!(cover.literal_count(), 1);
        assert_eq!(cover.to_expression(), "x2");
    }

    /// Satellite: exhaustive equivalence on random functions up to 8
    /// inputs, at several densities (not just the hand-picked AND/XOR).
    #[test]
    fn random_functions_equal_truth_table_exhaustively() {
        let mut rng = Rng::new(0xE59);
        for n in 2..=8u32 {
            for density in [0.05, 0.25, 0.5, 0.75, 0.95] {
                let pattern: Vec<bool> =
                    (0..(1usize << n)).map(|_| rng.chance(density)).collect();
                let f = from_fn(n, |a| pattern[a]);
                let cover = minimize(&f);
                for addr in 0..(1usize << n) {
                    assert_eq!(
                        cover.eval(addr),
                        f.get(addr),
                        "n={n} density={density} addr={addr}"
                    );
                }
            }
        }
    }

    /// Satellite: identical inputs must yield identical covers (canonical
    /// ordering — no dependence on any iteration order).
    #[test]
    fn minimize_is_deterministic() {
        let mut rng = Rng::new(0xD373);
        for n in 2..=8u32 {
            let pattern: Vec<bool> = (0..(1usize << n)).map(|_| rng.chance(0.4)).collect();
            let f = from_fn(n, |a| pattern[a]);
            let first = minimize(&f);
            for _ in 0..3 {
                let again = minimize(&f);
                assert_eq!(first.cubes, again.cubes, "n={n}");
            }
            // Canonical order is (literals, care, value), non-decreasing.
            let keys: Vec<_> =
                first.cubes.iter().map(|c| (c.literals(), c.care, c.value)).collect();
            let mut sorted = keys.clone();
            sorted.sort();
            assert_eq!(keys, sorted, "cover not in canonical order, n={n}");
        }
    }

    /// `minimize_dc` must agree with f on every care point and never
    /// exceed the care ON-minterm cover.
    #[test]
    fn dc_minimization_agrees_on_care_points() {
        let mut rng = Rng::new(0xDCDC);
        for n in 2..=8u32 {
            for _ in 0..6 {
                let fpat: Vec<bool> = (0..(1usize << n)).map(|_| rng.chance(0.4)).collect();
                let cpat: Vec<bool> = (0..(1usize << n)).map(|_| rng.chance(0.7)).collect();
                let f = from_fn(n, |a| fpat[a]);
                let care = from_fn(n, |a| cpat[a]);
                let cover = minimize_dc(&f, &care);
                for addr in 0..(1usize << n) {
                    if care.get(addr) {
                        assert_eq!(cover.eval(addr), f.get(addr), "n={n} addr={addr}");
                    }
                }
                // Never worse than one cube per care ON minterm.
                let on = (0..(1usize << n)).filter(|&a| care.get(a) && f.get(a)).count();
                assert!(cover.cubes.len() <= on.max(1), "n={n}");
            }
        }
    }

    /// Don't-cares let a function that is only *reachably* constant
    /// collapse to the constant cube.
    #[test]
    fn dc_collapses_reachably_constant_function() {
        // f = 1 on all even addresses, 0 on odd; care = even only.
        let f = from_fn(4, |a| a % 2 == 0);
        let care = from_fn(4, |a| a % 2 == 0);
        let cover = minimize_dc(&f, &care);
        assert_eq!(cover.cubes.len(), 1);
        assert_eq!(cover.literal_count(), 0, "tautology over the care set");
        // Empty care ON-set → empty cover.
        let none = minimize_dc(&f, &BoolFn::constant(4, false));
        assert!(none.cubes.is_empty());
    }

    #[test]
    fn cube_containment_and_enumeration() {
        let big = Cube { care: 0b001, value: 0b001 }; // x0
        let small = Cube { care: 0b011, value: 0b011 }; // x0 x1
        assert!(big.contains(&small));
        assert!(!small.contains(&big));
        let pts: Vec<usize> = enumerate_cube(&small, 3).collect();
        assert_eq!(pts.len(), 2); // free var: x2
        assert!(pts.contains(&0b011) && pts.contains(&0b111));
    }
}
