//! The LUT compiler: truth-table generation from the trained network,
//! Boolean-function algebra, and LUT6 technology mapping (the Vivado
//! substitute — DESIGN.md §6).

pub mod boolfn;
pub mod espresso;
pub mod mapper;
pub mod netlist;
pub mod opt;
pub mod tables;

pub use mapper::{map_network_of, MappedNetwork};
pub use opt::{optimize, OptLevel, OptReport, Optimized};
pub use tables::{compile_network, NetworkTables};
