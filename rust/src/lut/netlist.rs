//! Mapped netlist — the output of LUT6 technology mapping.
//!
//! One arena per layer: input nodes are the layer's input wires
//! ((source neuron, bit) pairs), internal nodes are LUT6s and the dedicated
//! CLB muxes (MUXF7/F8/F9 are free on UltraScale+; deeper mux levels burn a
//! LUT6 each).  Identical functions of identical wires hash-cons to the same
//! node, which is exactly the sharing Vivado finds within an out-of-context
//! module.  The netlist is executable (bit-parallel over 64 samples) so the
//! mapping can be property-tested against the truth tables it came from.

use std::collections::HashMap;

pub type NodeId = u32;

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum Node {
    /// External wire: (source index, bit) — opaque to the netlist.
    Input { wire: u32 },
    Const(bool),
    /// A physical LUT with up to 6 inputs; `mask` bit i = output for input
    /// pattern i (inputs[0] is address bit 0).
    Lut { inputs: Vec<NodeId>, mask: u64 },
    /// 2:1 mux. `free` muxes are the CLB's MUXF7/F8/F9; others cost a LUT6.
    Mux { sel: NodeId, lo: NodeId, hi: NodeId, free: bool },
}

/// Word-level LUT evaluation — the shared mask-decomposition kernel,
/// generic over the lane width ([`crate::simd::Word`]).
///
/// `inputs[k]` holds `W::LANES` samples of address bit `k` (lane `s` =
/// sample `s`); the result holds `W::LANES` samples of `mask[addr]`.  The
/// truth-table `mask` itself stays a scalar `u64` at every width — only the
/// data lanes widen.  Instead of assembling a per-sample address
/// (lanes × fan shift/or operations), the mask is Shannon-decomposed
/// top-down: splitting on the highest address bit halves the mask, and the
/// two cofactor words are recombined with one word-wide mux
/// (`lo ^ (x & (lo ^ hi))`, 3 ops for all lanes).  Equal or constant
/// cofactors prune whole subtrees, so structured (trained) masks cost well
/// under the 2^n−1 worst-case mux count.
///
/// Both [`Netlist::eval64`] (at `W = u64`) and the `sim::bitslice` op
/// stream (at the engine's compiled lane width) evaluate their LUT6 ops
/// through this kernel.  Mask bits above `2^inputs.len()` are ignored.
#[inline]
pub fn lut_word<W: crate::simd::Word>(mask: u64, inputs: &[W]) -> W {
    debug_assert!(inputs.len() <= 6, "physical LUTs have at most 6 inputs");
    let n = inputs.len();
    let m = if n == 6 { mask } else { mask & ((1u64 << (1u32 << n)) - 1) };
    lut_word_rec(m, inputs)
}

/// Invariant: only the low `2^inputs.len()` bits of `mask` may be set.
fn lut_word_rec<W: crate::simd::Word>(mask: u64, inputs: &[W]) -> W {
    let (&x, rest) = match inputs.split_last() {
        None => return if mask & 1 != 0 { W::ones() } else { W::zero() },
        Some(p) => p,
    };
    if mask == 0 {
        return W::zero();
    }
    // Cofactor width is 2^(n-1) <= 32 bits, so the splits below cannot shift
    // by 64.
    let half = 1u32 << rest.len();
    let full = (1u64 << half) - 1;
    if mask == full | (full << half) {
        return W::ones();
    }
    let lo = mask & full;
    let hi = mask >> half;
    if lo == hi {
        return lut_word_rec(lo, rest);
    }
    let l = lut_word_rec(lo, rest);
    let h = lut_word_rec(hi, rest);
    l ^ (x & (l ^ h))
}

#[derive(Debug, Default)]
pub struct Netlist {
    pub nodes: Vec<Node>,
    dedup: HashMap<Node, NodeId>,
    /// Cached logic depth per node (LUT levels; free muxes add 0).
    depth: Vec<u32>,
}

impl Netlist {
    pub fn new() -> Netlist {
        Netlist::default()
    }

    pub fn add(&mut self, node: Node) -> NodeId {
        if let Some(&id) = self.dedup.get(&node) {
            return id;
        }
        let d = match &node {
            Node::Input { .. } | Node::Const(_) => 0,
            Node::Lut { inputs, .. } => {
                1 + inputs.iter().map(|&i| self.depth[i as usize]).max().unwrap_or(0)
            }
            Node::Mux { sel, lo, hi, free } => {
                let base = [*sel, *lo, *hi]
                    .iter()
                    .map(|&i| self.depth[i as usize])
                    .max()
                    .expect("three operands, never empty");
                base + if *free { 0 } else { 1 }
            }
        };
        let id = self.nodes.len() as NodeId;
        self.nodes.push(node.clone());
        self.dedup.insert(node, id);
        self.depth.push(d);
        id
    }

    pub fn input(&mut self, wire: u32) -> NodeId {
        self.add(Node::Input { wire })
    }

    pub fn constant(&mut self, v: bool) -> NodeId {
        self.add(Node::Const(v))
    }

    pub fn depth_of(&self, id: NodeId) -> u32 {
        self.depth[id as usize]
    }

    /// Physical LUT6 count (LUTs + non-free muxes).
    pub fn lut_count(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| matches!(n, Node::Lut { .. } | Node::Mux { free: false, .. }))
            .count()
    }

    pub fn free_mux_count(&self) -> usize {
        self.nodes.iter().filter(|n| matches!(n, Node::Mux { free: true, .. })).count()
    }

    /// Evaluate the netlist bit-parallel: `wires[w]` holds 64 samples of
    /// input wire w (bit k = sample k).  Returns one u64 per node.  LUT
    /// nodes go through the shared word-level [`lut_word`] kernel.
    pub fn eval64(&self, wires: &dyn Fn(u32) -> u64) -> Vec<u64> {
        let mut vals = vec![0u64; self.nodes.len()];
        for (i, node) in self.nodes.iter().enumerate() {
            vals[i] = match node {
                Node::Input { wire } => wires(*wire),
                Node::Const(v) => {
                    if *v {
                        u64::MAX
                    } else {
                        0
                    }
                }
                Node::Lut { inputs, mask } => {
                    let mut ins = [0u64; 6];
                    for (k, &inp) in inputs.iter().enumerate() {
                        ins[k] = vals[inp as usize];
                    }
                    lut_word(*mask, &ins[..inputs.len()])
                }
                Node::Mux { sel, lo, hi, .. } => {
                    let s = vals[*sel as usize];
                    (s & vals[*hi as usize]) | (!s & vals[*lo as usize])
                }
            };
        }
        vals
    }

    /// The original per-sample address-assembly walk (O(64·fan) per LUT
    /// node), kept as the only independent implementation of netlist
    /// semantics: the word-level kernel is property-tested against it, and
    /// `sim::verify`'s netlist-opt equivalence check uses it as the oracle
    /// side so a shared-kernel bug cannot mask itself.
    pub fn eval64_reference(&self, wires: &dyn Fn(u32) -> u64) -> Vec<u64> {
        let mut vals = vec![0u64; self.nodes.len()];
        for (i, node) in self.nodes.iter().enumerate() {
            vals[i] = match node {
                Node::Input { wire } => wires(*wire),
                Node::Const(v) => {
                    if *v {
                        u64::MAX
                    } else {
                        0
                    }
                }
                Node::Lut { inputs, mask } => {
                    let mut out = 0u64;
                    for s in 0..64 {
                        let mut addr = 0usize;
                        for (k, &inp) in inputs.iter().enumerate() {
                            addr |= (((vals[inp as usize] >> s) & 1) as usize) << k;
                        }
                        out |= ((mask >> addr) & 1) << s;
                    }
                    out
                }
                Node::Mux { sel, lo, hi, .. } => {
                    let s = vals[*sel as usize];
                    (s & vals[*hi as usize]) | (!s & vals[*lo as usize])
                }
            };
        }
        vals
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dedup_shares_nodes() {
        let mut nl = Netlist::new();
        let a = nl.input(0);
        let b = nl.input(1);
        let l1 = nl.add(Node::Lut { inputs: vec![a, b], mask: 0b0110 });
        let l2 = nl.add(Node::Lut { inputs: vec![a, b], mask: 0b0110 });
        assert_eq!(l1, l2);
        assert_eq!(nl.lut_count(), 1);
    }

    #[test]
    fn depth_tracking() {
        let mut nl = Netlist::new();
        let a = nl.input(0);
        let b = nl.input(1);
        let l1 = nl.add(Node::Lut { inputs: vec![a, b], mask: 0b1000 });
        let l2 = nl.add(Node::Lut { inputs: vec![l1, a], mask: 0b0110 });
        assert_eq!(nl.depth_of(l1), 1);
        assert_eq!(nl.depth_of(l2), 2);
        let m = nl.add(Node::Mux { sel: a, lo: l2, hi: l1, free: true });
        assert_eq!(nl.depth_of(m), 2, "free mux adds no level");
        let m2 = nl.add(Node::Mux { sel: a, lo: m, hi: l1, free: false });
        assert_eq!(nl.depth_of(m2), 3);
        assert_eq!(nl.lut_count(), 3);
        assert_eq!(nl.free_mux_count(), 1);
    }

    /// The word-level kernel must agree with a per-sample mask read for
    /// every arity, including structured (constant / equal-cofactor) masks.
    #[test]
    fn lut_word_matches_per_sample_lookup() {
        let mut rng = crate::util::rng::Rng::new(0x10C4);
        for n in 0..=6usize {
            let width = 1u32 << n;
            let full = if width == 64 { !0u64 } else { (1u64 << width) - 1 };
            let mut masks = vec![0u64, full, rng.next_u64(), rng.next_u64() & rng.next_u64()];
            if n >= 1 {
                // Equal cofactors on the top variable (prunes to n-1 vars).
                let lo = rng.next_u64() & (full >> (width / 2).max(1));
                masks.push(lo | (lo << (width / 2)));
            }
            for mask in masks {
                let inputs: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
                let got = lut_word(mask, &inputs);
                for s in 0..64 {
                    let mut addr = 0usize;
                    for (k, &w) in inputs.iter().enumerate() {
                        addr |= (((w >> s) & 1) as usize) << k;
                    }
                    let want = (mask >> addr) & 1;
                    assert_eq!(
                        (got >> s) & 1,
                        want,
                        "n={n} mask={mask:#x} sample {s} addr {addr}"
                    );
                }
            }
        }
    }

    /// Whole-netlist property: the kernel-backed eval64 is bit-identical to
    /// the original per-sample reference walk on random netlists.
    #[test]
    fn eval64_matches_reference_on_random_netlists() {
        let mut rng = crate::util::rng::Rng::new(0xE64);
        for trial in 0..20 {
            let mut nl = Netlist::new();
            let mut pool: Vec<NodeId> = (0..6).map(|w| nl.input(w)).collect();
            pool.push(nl.constant(false));
            pool.push(nl.constant(true));
            for _ in 0..40 {
                let id = if rng.below(4) == 0 {
                    let sel = pool[rng.below(pool.len())];
                    let lo = pool[rng.below(pool.len())];
                    let hi = pool[rng.below(pool.len())];
                    nl.add(Node::Mux { sel, lo, hi, free: rng.below(2) == 0 })
                } else {
                    let fan = 1 + rng.below(6);
                    let inputs: Vec<NodeId> =
                        (0..fan).map(|_| pool[rng.below(pool.len())]).collect();
                    nl.add(Node::Lut { inputs, mask: rng.next_u64() })
                };
                pool.push(id);
            }
            let seeds: Vec<u64> = (0..6).map(|_| rng.next_u64()).collect();
            let wires = |w: u32| seeds[w as usize];
            assert_eq!(nl.eval64(&wires), nl.eval64_reference(&wires), "trial {trial}");
        }
    }

    #[test]
    fn eval64_lut_and_mux() {
        let mut nl = Netlist::new();
        let a = nl.input(0);
        let b = nl.input(1);
        let xor = nl.add(Node::Lut { inputs: vec![a, b], mask: 0b0110 });
        let mux = nl.add(Node::Mux { sel: a, lo: b, hi: xor, free: true });
        // sample 0: a=0 b=0; 1: a=1 b=0; 2: a=0 b=1; 3: a=1 b=1
        let wires = |w: u32| -> u64 {
            match w {
                0 => 0b1010,
                1 => 0b1100,
                _ => 0,
            }
        };
        let vals = nl.eval64(&wires);
        assert_eq!(vals[xor as usize] & 0xF, 0b0110);
        // mux: a ? xor : b -> samples: a0->b=0, a1->xor=1, a0->b=1, a1->xor=0
        assert_eq!(vals[mux as usize] & 0xF, 0b0110);
    }
}
