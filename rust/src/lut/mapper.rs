//! LUT6 technology mapping — the Vivado-synthesis substitute (DESIGN.md §6).
//!
//! Every table output bit is a Boolean function of the table's address bits.
//! Mapping is recursive Shannon decomposition over the *support-reduced*
//! function: functions of ≤ 6 variables are one physical LUT; wider
//! functions split on the variable that maximizes cofactor simplification,
//! and the two halves are recombined by a mux — MUXF7/F8/F9 levels are free
//! on UltraScale+, deeper levels burn a LUT6 each.  Hash-consing happens at
//! two levels: the function cache here (identical sub-functions of the same
//! wires map once) and the netlist node dedup (identical LUTs share).
//!
//! This is deliberately the same cost structure Vivado's `casez`-ROM
//! synthesis exploits, so LUT counts track the paper's Table II shape: a
//! 2^{βF}-word table costs ~2^{βF-6} LUTs *before* simplification, and the
//! trained-function structure (vacuous inputs, equal cofactors, shared
//! sub-functions) is what pulls counts below worst case.

use std::collections::HashMap;

use super::boolfn::BoolFn;
use super::netlist::{Netlist, Node, NodeId};
use super::tables::{LayerTables, NetworkTables, TruthTable};
use crate::util::pool::parallel_map;

/// How many Shannon/mux levels above the LUT leaves are free (MUXF7/F8/F9).
const FREE_MUX_LEVELS: u32 = 3;

/// A mapped layer: one netlist arena (sharing scope = the layer module, as
/// in the paper's per-layer OOC synthesis), with per-neuron output roots.
pub struct MappedLayer {
    pub netlist: Netlist,
    /// roots[j][bit] — output bit nodes of neuron j (the layer's output code).
    pub roots: Vec<Vec<NodeId>>,
    /// Poly-stage roots (A > 1 only): the sub-neuron code bits that feed the
    /// adder table; registered in pipeline strategy (1).
    pub poly_roots: Vec<Vec<NodeId>>,
    /// Logic depth of the poly stage alone and of the whole layer.
    pub poly_depth: u32,
    pub depth: u32,
}

pub struct MappedNetwork {
    pub layers: Vec<MappedLayer>,
}

impl MappedNetwork {
    pub fn total_luts(&self) -> usize {
        self.layers.iter().map(|l| l.netlist.lut_count()).sum()
    }

    pub fn max_depth(&self) -> u32 {
        self.layers.iter().map(|l| l.depth).max().unwrap_or(0)
    }

    /// Pipeline registers, strategy (2): one register per layer output bit.
    pub fn total_regs_strategy2(&self) -> usize {
        self.layers.iter().map(|l| l.roots.iter().map(|r| r.len()).sum::<usize>()).sum()
    }

    /// Pipeline registers, strategy (1): poly-stage outputs also registered.
    pub fn total_regs_strategy1(&self) -> usize {
        self.total_regs_strategy2()
            + self
                .layers
                .iter()
                .map(|l| l.poly_roots.iter().map(|r| r.len()).sum::<usize>())
                .sum::<usize>()
    }
}

/// Mapper state for one layer (function cache shared across all neurons
/// and output bits of that layer).
struct Mapper<'a> {
    nl: &'a mut Netlist,
    /// (reduced function, support wires) -> mapped node.
    cache: HashMap<(BoolFn, Vec<NodeId>), NodeId>,
}

impl<'a> Mapper<'a> {
    fn new(nl: &'a mut Netlist) -> Self {
        Mapper { nl, cache: HashMap::new() }
    }

    /// Map `f` over the given input wires; returns the output node.
    fn map(&mut self, f: &BoolFn, wires: &[NodeId]) -> NodeId {
        debug_assert_eq!(f.n as usize, wires.len());
        let (red, kept) = f.support_reduce();
        let red_wires: Vec<NodeId> = kept.iter().map(|&k| wires[k as usize]).collect();
        if let Some(v) = red.is_const() {
            return self.nl.constant(v);
        }
        let key = (red.clone(), red_wires.clone());
        if let Some(&id) = self.cache.get(&key) {
            return id;
        }
        let id = self.map_reduced(&red, &red_wires, 0);
        self.cache.insert(key, id);
        id
    }

    /// Map an already support-reduced, non-constant function.
    /// `mux_level` counts how many Shannon levels are above us (for the
    /// free-mux budget).
    fn map_reduced(&mut self, f: &BoolFn, wires: &[NodeId], mux_level: u32) -> NodeId {
        if f.n <= 6 {
            return self.nl.add(Node::Lut { inputs: wires.to_vec(), mask: f.lut_mask() });
        }
        // Cache intermediate functions too (they can recur across bits).
        let key = (f.clone(), wires.to_vec());
        if let Some(&id) = self.cache.get(&key) {
            return id;
        }
        let var = self.pick_split_var(f);
        let f0 = f.cofactor(var, false);
        let f1 = f.cofactor(var, true);
        let mut sub_wires: Vec<NodeId> = wires.to_vec();
        let sel = sub_wires.remove(var as usize);
        let lo = self.map_sub(&f0, &sub_wires, mux_level + 1);
        let hi = self.map_sub(&f1, &sub_wires, mux_level + 1);
        let id = if lo == hi {
            lo
        } else {
            // Mux levels count from the LUT leaves upward; a split at
            // mux_level L sits (total_levels - L) above the leaves. Using the
            // conservative equivalent: the first FREE_MUX_LEVELS splits
            // *closest to the leaves* are free. Levels here are counted from
            // the root, so free-ness depends on remaining depth:
            let remaining = f.n - 6; // Shannon levels below this node (worst case)
            let free = remaining <= FREE_MUX_LEVELS;
            self.nl.add(Node::Mux { sel, lo, hi, free })
        };
        self.cache.insert(key, id);
        id
    }

    /// Support-reduce a cofactor then map it (re-entering the shared cache).
    fn map_sub(&mut self, f: &BoolFn, wires: &[NodeId], mux_level: u32) -> NodeId {
        let (red, kept) = f.support_reduce();
        if let Some(v) = red.is_const() {
            return self.nl.constant(v);
        }
        let red_wires: Vec<NodeId> = kept.iter().map(|&k| wires[k as usize]).collect();
        let key = (red.clone(), red_wires.clone());
        if let Some(&id) = self.cache.get(&key) {
            return id;
        }
        let id = self.map_reduced(&red, &red_wires, mux_level);
        self.cache.insert(key, id);
        id
    }

    /// Pick the Shannon variable: prefer splits whose cofactors lose the
    /// most support (cheap lookahead over a bounded candidate set).
    fn pick_split_var(&self, f: &BoolFn) -> u32 {
        let n = f.n;
        // Candidate set: all vars for small n, top-of-address ones otherwise
        // (address bits are grouped per input word, so high bits split
        // between different source inputs — the natural decomposition).
        let candidates: Vec<u32> =
            if n <= 10 { (0..n).collect() } else { (n - 8..n).collect() };
        let mut best = (n - 1, -1i64);
        for &v in &candidates {
            let f0 = f.cofactor(v, false);
            let f1 = f.cofactor(v, true);
            if f0 == f1 {
                // Vacuous split would be removed by support_reduce upstream,
                // but guard anyway: skip.
                continue;
            }
            let mut score = 0i64;
            for g in [&f0, &f1] {
                if g.is_const().is_some() {
                    score += 64;
                    continue;
                }
                for u in 0..g.n {
                    if g.is_vacuous(u) {
                        score += 1;
                    }
                }
            }
            if f0 == f1 {
                score += 32;
            }
            if score > best.1 {
                best = (v, score);
            }
        }
        best.0
    }
}

/// Map one layer's tables into a LUT6 netlist.
///
/// Wire numbering: input wire id = `src_neuron * in_bits + bit` (the
/// previous layer's output code bits).  Poly tables read their fan-in
/// sources' code bits; the adder table reads the freshly mapped sub-neuron
/// output bits (as internal nodes, not wires).
pub fn map_layer(
    layer: &LayerTables,
    indices: &[Vec<Vec<usize>>],
    a_factor: usize,
) -> MappedLayer {
    let mut nl = Netlist::new();
    let mut mapper = Mapper::new(&mut nl);
    let mut roots = Vec::with_capacity(layer.neurons.len());
    let mut poly_roots_all = Vec::with_capacity(layer.neurons.len());
    let mut poly_depth = 0u32;

    for (j, neuron) in layer.neurons.iter().enumerate() {
        // Map each poly table bit over the source wires.
        let mut sub_bits_nodes: Vec<Vec<NodeId>> = Vec::with_capacity(neuron.poly.len());
        for (a, table) in neuron.poly.iter().enumerate() {
            let srcs = &indices[a.min(indices.len() - 1)][j];
            let mut wires = Vec::with_capacity(table.n_inputs as usize);
            for (slot, &src) in srcs.iter().enumerate() {
                for b in 0..layer.in_bits {
                    let _ = slot;
                    let w = (src as u32) * layer.in_bits + b;
                    wires.push(mapper.nl.input(w));
                }
            }
            let bits = map_table_bits(&mut mapper, table, &wires);
            for &n in &bits {
                poly_depth = poly_depth.max(mapper.nl.depth_of(n));
            }
            sub_bits_nodes.push(bits);
        }

        match &neuron.adder {
            None => {
                // A == 1: poly table output bits are the neuron outputs.
                roots.push(sub_bits_nodes.pop().expect("A >= 1: one poly table per neuron"));
                poly_roots_all.push(Vec::new());
            }
            Some(adder) => {
                // Adder table inputs: A * sub_bits nodes (field i*sub_bits+b).
                let mut adder_wires = Vec::with_capacity(adder.n_inputs as usize);
                for sub in &sub_bits_nodes {
                    adder_wires.extend_from_slice(sub);
                }
                debug_assert_eq!(adder_wires.len(), adder.n_inputs as usize);
                let bits = map_table_bits(&mut mapper, adder, &adder_wires);
                roots.push(bits);
                poly_roots_all.push(sub_bits_nodes.concat());
            }
        }
        let _ = a_factor;
    }

    let depth = roots
        .iter()
        .flat_map(|bits| bits.iter())
        .map(|&n| nl.depth_of(n))
        .max()
        .unwrap_or(0);
    MappedLayer { netlist: nl, roots, poly_roots: poly_roots_all, poly_depth, depth }
}

/// Map every output bit of one table.
fn map_table_bits(mapper: &mut Mapper, table: &TruthTable, wires: &[NodeId]) -> Vec<NodeId> {
    (0..table.out_bits)
        .map(|b| {
            let f = BoolFn::from_bits(table.n_inputs, table.bit_plane(b));
            mapper.map(&f, wires)
        })
        .collect()
}

/// Map a whole network (parallel over layers).
pub fn map_network_with_indices(
    tables: &NetworkTables,
    indices: &[Vec<Vec<Vec<usize>>>],
    workers: usize,
) -> MappedNetwork {
    let jobs: Vec<usize> = (0..tables.layers.len()).collect();
    let layers = parallel_map(&jobs, workers, |_, &l| {
        map_layer(&tables.layers[l], &indices[l], tables.a_factor)
    });
    MappedNetwork { layers }
}

/// Convenience: map using the indices stored in a `Network`.
pub fn map_network_of(
    net: &crate::nn::network::Network,
    tables: &NetworkTables,
    workers: usize,
) -> MappedNetwork {
    let indices: Vec<_> = net.layers.iter().map(|p| p.indices.clone()).collect();
    map_network_with_indices(tables, &indices, workers)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lut::tables::compile_network;
    use crate::nn::config;
    use crate::nn::network::Network;
    use crate::util::rng::Rng;

    fn tiny(a: usize) -> Network {
        let cfg = config::uniform("t", &[8, 6, 3], 2, 2, 3, 3, 3, 2, a, 3);
        Network::random(&cfg, &mut Rng::new(7))
    }

    #[test]
    fn mapping_produces_luts_and_depth() {
        let net = tiny(2);
        let tables = compile_network(&net, 1);
        let mapped = map_network_of(&net, &tables, 1);
        assert_eq!(mapped.layers.len(), 2);
        assert!(mapped.total_luts() > 0);
        assert!(mapped.max_depth() >= 1);
        assert!(mapped.total_regs_strategy1() > mapped.total_regs_strategy2());
    }

    /// The heart of the Vivado substitute: the mapped netlist must compute
    /// exactly the same function as the truth tables it came from.
    #[test]
    fn mapped_netlist_matches_tables() {
        for a in [1, 2, 3] {
            let cfg = config::uniform("t", &[8, 6, 3], 2, 2, 3, 3, 3, 2, a, 3);
            let net = Network::random(&cfg, &mut Rng::new(a as u64 + 10));
            let tables = compile_network(&net, 1);
            let mapped = map_network_of(&net, &tables, 1);
            let mut rng = Rng::new(99);
            // 64 random input-code vectors, checked bit-parallel per layer.
            for l in 0..tables.layers.len() {
                let n_in = cfg.widths[l];
                let in_bits = tables.layers[l].in_bits;
                // wire values: wire = src * in_bits + bit
                let mut codes = vec![0u32; n_in * 64];
                for c in codes.iter_mut() {
                    *c = rng.below(1 << in_bits) as u32;
                }
                let wires = |w: u32| -> u64 {
                    let (src, bit) = ((w / in_bits) as usize, w % in_bits);
                    let mut out = 0u64;
                    for s in 0..64 {
                        out |= (((codes[src * 64 + s] >> bit) & 1) as u64) << s;
                    }
                    out
                };
                let vals = mapped.layers[l].netlist.eval64(&wires);
                for (j, bits) in mapped.layers[l].roots.iter().enumerate() {
                    for s in 0..64 {
                        // Reference through the truth tables.
                        let gathered: Vec<Vec<i32>> = (0..cfg.a_factor)
                            .map(|ai| {
                                net.layers[l].indices[ai][j]
                                    .iter()
                                    .map(|&src| codes[src * 64 + s] as i32)
                                    .collect()
                            })
                            .collect();
                        let nt = &tables.layers[l].neurons[j];
                        let expect = if let Some(adder) = &nt.adder {
                            let subs: Vec<i32> = nt
                                .poly
                                .iter()
                                .enumerate()
                                .map(|(ai, t)| {
                                    t.code_at(crate::lut::tables::pack_poly_addr(
                                        &gathered[ai],
                                        in_bits,
                                    ))
                                })
                                .collect();
                            adder.code_at(crate::lut::tables::pack_adder_addr(
                                &subs,
                                tables.layers[l].sub_bits,
                            ))
                        } else {
                            nt.poly[0].code_at(crate::lut::tables::pack_poly_addr(
                                &gathered[0],
                                in_bits,
                            ))
                        };
                        let expect_raw =
                            crate::nn::quant::to_twos_complement(expect, tables.layers[l].out_bits);
                        let mut got = 0u32;
                        for (b, &node) in bits.iter().enumerate() {
                            got |= (((vals[node as usize] >> s) & 1) as u32) << b;
                        }
                        assert_eq!(
                            got, expect_raw,
                            "A={a} layer {l} neuron {j} sample {s}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn single_lut_for_small_tables() {
        // beta=1, F=3 -> 3-input tables: every output bit must be 1 LUT max.
        let cfg = config::uniform("s", &[6, 4, 2], 1, 1, 2, 3, 3, 1, 1, 2);
        let net = Network::random(&cfg, &mut Rng::new(2));
        let tables = compile_network(&net, 1);
        let mapped = map_network_of(&net, &tables, 1);
        for l in &mapped.layers {
            assert!(l.depth <= 1, "3-input functions must map to single LUTs");
        }
    }
}
