//! `polylut` CLI — the L3 leader entrypoint.
use anyhow::Result;

fn main() -> Result<()> {
    polylut_add::cli_main()
}
