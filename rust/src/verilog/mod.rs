//! Verilog RTL emitter — the paper's "RTL Generation" toolflow stage.
//!
//! Emits one module per layer (matching the paper's per-layer OOC synthesis
//! unit): each Poly/Adder lookup table becomes a `case`-ROM function that
//! Vivado maps onto LUT6s exactly as our internal mapper models, plus
//! pipeline registers per the selected strategy (Fig. 5).  A self-checking
//! testbench drives dataset vectors and compares against the LutSim-computed
//! golden outputs.

pub mod emit;
pub mod testbench;

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::fpga::Strategy;
use crate::lut::tables::compile_network;
use crate::nn::network::Network;
use crate::util::pool::default_workers;

/// Emit the complete RTL project for a trained network (strategy 2 top).
/// Returns the written file paths.
pub fn emit_project(net: &Network, out_dir: &Path) -> Result<Vec<PathBuf>> {
    emit_project_with(net, out_dir, Strategy::Merged, 64)
}

pub fn emit_project_with(
    net: &Network,
    out_dir: &Path,
    strategy: Strategy,
    tb_vectors: usize,
) -> Result<Vec<PathBuf>> {
    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("creating {}", out_dir.display()))?;
    let mut tables = compile_network(net, default_workers());
    // Same table-level rewrites the serving engines execute (resolved via
    // `POLYLUT_NETLIST_OPT`, published by `rtl --netlist-opt`): don't-care
    // propagation is bit-exact on every reachable address, so the
    // testbench golden vectors stay valid either way.
    let level = crate::lut::OptLevel::resolve(None);
    crate::lut::opt::optimize_tables(net, &mut tables, level);
    let mut files = Vec::new();
    for l in 0..tables.layers.len() {
        let path = out_dir.join(format!("{}_layer{l}.v", module_name(net)));
        std::fs::write(&path, emit::layer_module(net, &tables, l, strategy))?;
        files.push(path);
    }
    let top = out_dir.join(format!("{}_top.v", module_name(net)));
    std::fs::write(&top, emit::top_module(net, &tables, strategy))?;
    files.push(top);
    let tb = out_dir.join(format!("{}_tb.v", module_name(net)));
    std::fs::write(&tb, testbench::testbench(net, &tables, tb_vectors))?;
    files.push(tb);
    Ok(files)
}

/// Sanitize the config name into a Verilog identifier.
pub fn module_name(net: &Network) -> String {
    net.cfg
        .name
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::config;
    use crate::util::rng::Rng;

    #[test]
    fn emits_parseable_files() {
        let cfg = config::uniform("tiny-a2", &[8, 6, 3], 2, 2, 3, 3, 3, 1, 2, 3);
        let net = Network::random(&cfg, &mut Rng::new(3));
        let dir = std::env::temp_dir().join("polylut_rtl_test");
        let files = emit_project(&net, &dir).unwrap();
        assert_eq!(files.len(), 2 + 2); // 2 layers + top + tb
        for f in &files {
            let text = std::fs::read_to_string(f).unwrap();
            assert!(text.contains("module "), "{}", f.display());
            assert!(text.contains("endmodule"), "{}", f.display());
            // Balanced begin/end as a cheap structural check.
            let begins = text.matches("begin").count();
            let ends = text.matches(" end").count() + text.matches("\nend").count();
            assert!(ends >= begins, "unbalanced begin/end in {}", f.display());
        }
    }
}
