//! Self-checking testbench emission.
//!
//! The testbench embeds `n` stimulus vectors (random input codes) together
//! with golden outputs computed by the LUT simulator — the same
//! deployed-semantics reference the property tests pin to the float model.
//! It clocks the pipeline at II=1 and fails loudly on any mismatch, so any
//! Verilog simulator (iverilog/verilator/xsim) can verify the generated RTL
//! without our toolchain.

use std::fmt::Write;

use crate::lut::tables::NetworkTables;
use crate::nn::network::Network;
use crate::sim::lutsim::LutSim;
use crate::util::rng::Rng;

use super::module_name;

pub fn testbench(net: &Network, tables: &NetworkTables, n_vectors: usize) -> String {
    let cfg = &net.cfg;
    let name = module_name(net);
    let n_layers = cfg.n_layers();
    let in_w = cfg.widths[0] as u32 * cfg.beta[0];
    let out_bits = tables.layers[n_layers - 1].out_bits;
    let out_w = cfg.widths[n_layers] as u32 * out_bits;
    let latency = n_layers; // top is emitted with strategy-2 register structure

    // Build stimulus + golden outputs via the LUT simulator.
    let sim = LutSim::new(net, tables);
    let mut rng = Rng::new(cfg.seed ^ 0x7B);
    let mut stim = Vec::with_capacity(n_vectors);
    let mut gold = Vec::with_capacity(n_vectors);
    let levels = 1usize << cfg.beta[0];
    for _ in 0..n_vectors {
        let codes: Vec<i32> = (0..cfg.widths[0]).map(|_| rng.below(levels) as i32).collect();
        let outs = sim.forward_codes(&codes);
        stim.push(pack_hex(&codes, cfg.beta[0], in_w));
        let raw: Vec<i32> = outs
            .iter()
            .map(|&c| crate::nn::quant::to_twos_complement(c, out_bits) as i32)
            .collect();
        gold.push(pack_hex(&raw, out_bits, out_w));
    }

    let mut v = String::new();
    let _ = writeln!(v, "// Auto-generated self-checking testbench for {}.", cfg.name);
    let _ = writeln!(v, "`timescale 1ns/1ps");
    let _ = writeln!(v, "module {name}_tb;");
    let _ = writeln!(v, "  reg clk = 0;");
    let _ = writeln!(v, "  always #2 clk = ~clk;");
    let _ = writeln!(v, "  reg  [{}:0] in_bus;", in_w - 1);
    let _ = writeln!(v, "  wire [{}:0] out_bus;", out_w - 1);
    let _ = writeln!(v, "  {name}_top dut (.clk(clk), .in_bus(in_bus), .out_bus(out_bus));");
    let _ = writeln!(v, "  reg [{}:0] stim [0:{}];", in_w - 1, n_vectors - 1);
    let _ = writeln!(v, "  reg [{}:0] gold [0:{}];", out_w - 1, n_vectors - 1);
    let _ = writeln!(v, "  integer i, errors;");
    let _ = writeln!(v, "  initial begin");
    for (i, s) in stim.iter().enumerate() {
        let _ = writeln!(v, "    stim[{i}] = {in_w}'h{s};");
    }
    for (i, g) in gold.iter().enumerate() {
        let _ = writeln!(v, "    gold[{i}] = {out_w}'h{g};");
    }
    let _ = writeln!(v, "    errors = 0;");
    let _ = writeln!(v, "    // II=1 streaming with {latency}-cycle latency.");
    let _ = writeln!(v, "    for (i = 0; i < {}; i = i + 1) begin", n_vectors + latency);
    let _ = writeln!(v, "      if (i < {n_vectors}) in_bus = stim[i];");
    let _ = writeln!(v, "      @(posedge clk); #1;");
    let _ = writeln!(v, "      if (i >= {latency}) begin");
    let _ = writeln!(v, "        if (out_bus !== gold[i-{latency}]) begin");
    let _ = writeln!(
        v,
        "          $display(\"FAIL vector %0d: got %h want %h\", i-{latency}, out_bus, gold[i-{latency}]);"
    );
    let _ = writeln!(v, "          errors = errors + 1;");
    let _ = writeln!(v, "        end");
    let _ = writeln!(v, "      end");
    let _ = writeln!(v, "    end");
    let _ = writeln!(v, "    if (errors == 0) $display(\"PASS: %0d vectors\", {n_vectors});");
    let _ = writeln!(v, "    else $display(\"FAIL: %0d mismatches\", errors);");
    let _ = writeln!(v, "    $finish;");
    let _ = writeln!(v, "  end");
    let _ = writeln!(v, "endmodule");
    v
}

/// Pack per-neuron codes (LSB-first fields of `bits` each) into a hex string
/// of total width `total_bits`.
fn pack_hex(codes: &[i32], bits: u32, total_bits: u32) -> String {
    let mut words = vec![0u64; (total_bits as usize).div_ceil(64)];
    for (i, &c) in codes.iter().enumerate() {
        let raw = (c as u64) & ((1u64 << bits) - 1);
        let pos = i as u32 * bits;
        let (w, off) = ((pos / 64) as usize, pos % 64);
        words[w] |= raw << off;
        if off + bits > 64 && w + 1 < words.len() {
            words[w + 1] |= raw >> (64 - off);
        }
    }
    // Hex, MSB first, trimmed to total_bits.
    let nibbles = (total_bits as usize).div_ceil(4);
    let mut s = String::with_capacity(nibbles);
    for i in (0..nibbles).rev() {
        let bitpos = i * 4;
        let (w, off) = (bitpos / 64, bitpos % 64);
        let nib = (words[w] >> off) & 0xF;
        s.push(char::from_digit(nib as u32, 16).expect("nib masked to 0..=15"));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lut::tables::compile_network;
    use crate::nn::config;

    #[test]
    fn pack_hex_basic() {
        // codes [3, 1, 2] at 2 bits each = 0b10_01_11 = 0x27 over 6 bits.
        assert_eq!(pack_hex(&[3, 1, 2], 2, 6), "27");
        // one 4-bit signed -1 -> 0xF.
        assert_eq!(pack_hex(&[-1], 4, 4), "f");
    }

    #[test]
    fn testbench_structure() {
        let cfg = config::uniform("t", &[8, 6, 3], 2, 2, 3, 3, 3, 1, 2, 3);
        let net = Network::random(&cfg, &mut crate::util::rng::Rng::new(1));
        let tables = compile_network(&net, 1);
        let tb = testbench(&net, &tables, 8);
        assert!(tb.contains("stim[7]"));
        assert!(tb.contains("gold[7]"));
        assert!(tb.contains("PASS"));
        assert_eq!(tb.matches("stim[").count(), 8 + 1); // 8 inits + 1 read (decl has a space)
    }
}
