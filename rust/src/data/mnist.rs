//! Synthetic handwritten-digit generator (MNIST substitute; DESIGN.md §5).
//!
//! Each digit 0-9 is a stroke skeleton (polyline segments in unit
//! coordinates, hand-tuned to the usual glyph shapes).  A sample applies a
//! random affine jitter (translate / scale / rotate / shear), rasterizes the
//! strokes with a soft pen profile, and adds pixel noise — giving
//! image-like statistics (spatial correlation, stroke topology, per-class
//! multimodality from jitter) at 28×28 or 14×14.

use super::Dataset;
use crate::util::rng::Rng;

/// Stroke skeletons per digit, in [0,1]² glyph coordinates (y down).
fn skeleton(digit: usize) -> Vec<[f64; 4]> {
    // Segments [x0, y0, x1, y1]; compact but recognisable glyphs.
    match digit {
        0 => vec![
            [0.30, 0.15, 0.70, 0.15],
            [0.70, 0.15, 0.80, 0.50],
            [0.80, 0.50, 0.70, 0.85],
            [0.70, 0.85, 0.30, 0.85],
            [0.30, 0.85, 0.20, 0.50],
            [0.20, 0.50, 0.30, 0.15],
        ],
        1 => vec![[0.35, 0.25, 0.55, 0.12], [0.55, 0.12, 0.55, 0.88], [0.35, 0.88, 0.75, 0.88]],
        2 => vec![
            [0.25, 0.25, 0.45, 0.12],
            [0.45, 0.12, 0.70, 0.20],
            [0.70, 0.20, 0.72, 0.40],
            [0.72, 0.40, 0.25, 0.85],
            [0.25, 0.85, 0.78, 0.85],
        ],
        3 => vec![
            [0.25, 0.15, 0.70, 0.15],
            [0.70, 0.15, 0.50, 0.45],
            [0.50, 0.45, 0.75, 0.65],
            [0.75, 0.65, 0.65, 0.85],
            [0.65, 0.85, 0.25, 0.85],
        ],
        4 => vec![[0.60, 0.12, 0.22, 0.60], [0.22, 0.60, 0.80, 0.60], [0.62, 0.35, 0.62, 0.88]],
        5 => vec![
            [0.72, 0.15, 0.30, 0.15],
            [0.30, 0.15, 0.28, 0.48],
            [0.28, 0.48, 0.65, 0.45],
            [0.65, 0.45, 0.75, 0.65],
            [0.75, 0.65, 0.60, 0.85],
            [0.60, 0.85, 0.25, 0.82],
        ],
        6 => vec![
            [0.65, 0.12, 0.35, 0.35],
            [0.35, 0.35, 0.25, 0.65],
            [0.25, 0.65, 0.40, 0.88],
            [0.40, 0.88, 0.68, 0.82],
            [0.68, 0.82, 0.70, 0.58],
            [0.70, 0.58, 0.30, 0.55],
        ],
        7 => vec![[0.22, 0.15, 0.78, 0.15], [0.78, 0.15, 0.45, 0.88], [0.35, 0.50, 0.68, 0.50]],
        8 => vec![
            [0.50, 0.12, 0.28, 0.30],
            [0.28, 0.30, 0.50, 0.48],
            [0.50, 0.48, 0.72, 0.30],
            [0.72, 0.30, 0.50, 0.12],
            [0.50, 0.48, 0.25, 0.70],
            [0.25, 0.70, 0.50, 0.88],
            [0.50, 0.88, 0.75, 0.70],
            [0.75, 0.70, 0.50, 0.48],
        ],
        9 => vec![
            [0.70, 0.42, 0.35, 0.45],
            [0.35, 0.45, 0.28, 0.25],
            [0.28, 0.25, 0.50, 0.12],
            [0.50, 0.12, 0.70, 0.22],
            [0.70, 0.22, 0.70, 0.42],
            [0.70, 0.42, 0.60, 0.88],
        ],
        _ => unreachable!(),
    }
}

struct Affine {
    a: f64,
    b: f64,
    c: f64,
    d: f64,
    tx: f64,
    ty: f64,
}

impl Affine {
    fn jitter(rng: &mut Rng) -> Affine {
        let angle = rng.range_f64(-0.22, 0.22); // ~±13°
        let scale = rng.range_f64(0.82, 1.12);
        let shear = rng.range_f64(-0.18, 0.18);
        let (sin, cos) = angle.sin_cos();
        let tx = rng.range_f64(-0.07, 0.07);
        let ty = rng.range_f64(-0.07, 0.07);
        Affine {
            a: scale * cos,
            b: scale * (shear * cos - sin),
            c: scale * sin,
            d: scale * (shear * sin + cos),
            tx,
            ty,
        }
    }

    fn apply(&self, x: f64, y: f64) -> (f64, f64) {
        // Centre, transform, un-centre.
        let (cx, cy) = (x - 0.5, y - 0.5);
        (0.5 + self.a * cx + self.b * cy + self.tx, 0.5 + self.c * cx + self.d * cy + self.ty)
    }
}

fn dist_to_segment(px: f64, py: f64, seg: &[f64; 4]) -> f64 {
    let (x0, y0, x1, y1) = (seg[0], seg[1], seg[2], seg[3]);
    let (dx, dy) = (x1 - x0, y1 - y0);
    let len2 = dx * dx + dy * dy;
    let t = if len2 > 0.0 { (((px - x0) * dx + (py - y0) * dy) / len2).clamp(0.0, 1.0) } else { 0.0 };
    let (qx, qy) = (x0 + t * dx, y0 + t * dy);
    ((px - qx).powi(2) + (py - qy).powi(2)).sqrt()
}

/// Rasterize one digit sample into `side`×`side` pixels in [0,1].
pub fn draw_digit(digit: usize, side: usize, rng: &mut Rng) -> Vec<f32> {
    let aff = Affine::jitter(rng);
    let pen = rng.range_f64(0.035, 0.065); // stroke half-width
    let segs: Vec<[f64; 4]> = skeleton(digit)
        .iter()
        .map(|s| {
            let (x0, y0) = aff.apply(s[0], s[1]);
            let (x1, y1) = aff.apply(s[2], s[3]);
            [x0, y0, x1, y1]
        })
        .collect();
    let mut img = vec![0f32; side * side];
    for r in 0..side {
        for c in 0..side {
            let px = (c as f64 + 0.5) / side as f64;
            let py = (r as f64 + 0.5) / side as f64;
            let d = segs.iter().map(|s| dist_to_segment(px, py, s)).fold(f64::MAX, f64::min);
            // Soft pen: full ink inside the core, linear falloff outside.
            let v = if d < pen {
                1.0
            } else if d < pen * 2.2 {
                1.0 - (d - pen) / (pen * 1.2)
            } else {
                0.0
            };
            // Ink level + additive sensor noise.
            let noise = rng.normal_ms(0.0, 0.04);
            img[r * side + c] = ((v * rng.range_f64(0.85, 1.0)) + noise).clamp(0.0, 1.0) as f32;
        }
    }
    img
}

pub fn generate(side: usize, n_train: usize, n_test: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0x3141_5926);
    let n_features = side * side;
    let mut gen_split = |n: usize| {
        let mut xs = Vec::with_capacity(n * n_features);
        let mut ys = Vec::with_capacity(n);
        for i in 0..n {
            let digit = i % 10;
            xs.extend(draw_digit(digit, side, &mut rng));
            ys.push(digit);
        }
        // Shuffle rows so minibatches are class-mixed even without sampler.
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        let mut xs2 = vec![0f32; xs.len()];
        let mut ys2 = vec![0usize; n];
        for (dst, &src) in order.iter().enumerate() {
            xs2[dst * n_features..(dst + 1) * n_features]
                .copy_from_slice(&xs[src * n_features..(src + 1) * n_features]);
            ys2[dst] = ys[src];
        }
        (xs2, ys2)
    };
    let (x_train, y_train) = gen_split(n_train);
    let (x_test, y_test) = gen_split(n_test);
    Dataset {
        name: format!("mnist{side}"),
        n_features,
        n_classes: 10,
        x_train,
        y_train,
        x_test,
        y_test,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn digits_have_ink_and_differ() {
        let mut rng = Rng::new(1);
        let imgs: Vec<Vec<f32>> = (0..10).map(|d| draw_digit(d, 28, &mut rng)).collect();
        for (d, img) in imgs.iter().enumerate() {
            let ink: f32 = img.iter().sum();
            assert!(ink > 20.0, "digit {d} has almost no ink ({ink})");
            assert!(ink < 500.0, "digit {d} is a blob ({ink})");
        }
        // Any two digits should differ substantially (L1 distance).
        for a in 0..10 {
            for b in a + 1..10 {
                let l1: f32 =
                    imgs[a].iter().zip(&imgs[b]).map(|(x, y)| (x - y).abs()).sum();
                assert!(l1 > 10.0, "digits {a} and {b} look identical");
            }
        }
    }

    #[test]
    fn same_digit_varies_between_samples() {
        let mut rng = Rng::new(2);
        let a = draw_digit(3, 28, &mut rng);
        let b = draw_digit(3, 28, &mut rng);
        let l1: f32 = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum();
        assert!(l1 > 5.0, "jitter should vary samples");
    }

    #[test]
    fn nearest_centroid_separability() {
        // A trivial classifier must beat chance comfortably: the generator
        // is supposed to be learnable.
        let ds = generate(14, 2000, 500, 3);
        let f = ds.n_features;
        let mut centroids = vec![vec![0f32; f]; 10];
        let mut counts = [0usize; 10];
        for i in 0..ds.n_train() {
            let y = ds.y_train[i];
            counts[y] += 1;
            for (c, v) in centroids[y].iter_mut().zip(ds.train_row(i)) {
                *c += v;
            }
        }
        for (cent, n) in centroids.iter_mut().zip(counts) {
            for c in cent.iter_mut() {
                *c /= n as f32;
            }
        }
        let mut correct = 0;
        for i in 0..ds.n_test() {
            let row = ds.test_row(i);
            let best = (0..10)
                .min_by(|&a, &b| {
                    let da: f32 = row.iter().zip(&centroids[a]).map(|(x, c)| (x - c).powi(2)).sum();
                    let db: f32 = row.iter().zip(&centroids[b]).map(|(x, c)| (x - c).powi(2)).sum();
                    da.total_cmp(&db)
                })
                .unwrap();
            if best == ds.y_test[i] {
                correct += 1;
            }
        }
        let acc = correct as f64 / ds.n_test() as f64;
        assert!(acc > 0.7, "nearest-centroid accuracy only {acc}");
    }
}
