//! Synthetic network-flow generator (UNSW-NB15 substitute; DESIGN.md §5).
//!
//! The real dataset has 49 flow features (durations, byte/packet counts,
//! TTLs, TCP window stats, connection-rate aggregates, protocol/service
//! categoricals) with a binary label (normal vs attack, ~12% attacks across
//! 9 attack families).  The substitute emulates that structure: heavy-tailed
//! volume features (lognormal), bounded protocol-ish features, per-family
//! signature shifts on small feature subsets, plus label-independent nuisance
//! features and a little label noise — so achievable accuracy saturates in
//! the low-90s, like the paper's NID rows, and convergence is seed-sensitive
//! (multiple restarts are genuinely needed, as the paper notes).

use super::Dataset;
use crate::util::rng::Rng;

pub const N_FEATURES: usize = 49;
const ATTACK_RATE: f64 = 0.35; // balanced-ish training mix (the paper trains on the provided split)
const N_FAMILIES: usize = 6; // attack families with distinct signatures
const LABEL_NOISE: f64 = 0.02;

/// Per-family signature: which features shift and by how much.
fn family_signature(family: usize) -> Vec<(usize, f64)> {
    // Deterministic signatures (feature index, shift in normalized units).
    match family {
        // DoS-like: packet/byte rates explode, duration short.
        0 => vec![(0, -0.30), (3, 0.45), (4, 0.45), (7, 0.40), (21, 0.35), (30, 0.30), (18, 0.30)],
        // Exploit-like: odd TCP state features.
        1 => vec![(10, 0.40), (11, -0.30), (12, 0.35), (26, 0.25), (40, 0.30), (22, 0.30)],
        // Fuzzer-like: high variance in sizes.
        2 => vec![(5, 0.35), (6, 0.35), (13, 0.30), (33, -0.25), (44, 0.25)],
        // Recon-like: many small flows, high connection-rate aggregates.
        3 => vec![(35, 0.45), (36, 0.45), (37, 0.40), (2, -0.25), (19, 0.25)],
        // Backdoor-like: unusual service/port patterns.
        4 => vec![(15, 0.40), (16, 0.35), (27, -0.30), (42, 0.30), (31, 0.30)],
        // Generic/crypto-like: uniform high-entropy payloads.
        5 => vec![(8, 0.35), (9, 0.35), (24, 0.30), (46, -0.30), (47, 0.30)],
        _ => unreachable!(),
    }
}

fn base_flow(rng: &mut Rng) -> [f64; N_FEATURES] {
    let mut x = [0f64; N_FEATURES];
    for (f, v) in x.iter_mut().enumerate() {
        *v = match f % 5 {
            // Heavy-tailed volume features: lognormal squashed by log1p.
            0 | 3 => {
                let raw = (rng.normal_ms(0.0, 1.1)).exp() * 40.0;
                (raw.ln_1p() / 9.0).clamp(0.0, 1.0)
            }
            // Bounded counters (TTL-ish): a few discrete modes + noise.
            1 => {
                let mode = [0.25, 0.5, 0.95][rng.below(3)];
                (mode + rng.normal_ms(0.0, 0.05)).clamp(0.0, 1.0)
            }
            // Rate-like features.
            2 => rng.f64().powf(1.6),
            // Pseudo-categorical: near-binary indicator.
            _ => {
                if rng.chance(0.3) {
                    rng.range_f64(0.85, 1.0)
                } else {
                    rng.range_f64(0.0, 0.12)
                }
            }
        };
    }
    x
}

pub fn generate(n_train: usize, n_test: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0x0B5E_55E0);
    let mut gen_split = |n: usize| {
        let mut xs = Vec::with_capacity(n * N_FEATURES);
        let mut ys = Vec::with_capacity(n);
        for _ in 0..n {
            let is_attack = rng.chance(ATTACK_RATE);
            let mut x = base_flow(&mut rng);
            if is_attack {
                let fam = rng.below(N_FAMILIES);
                // Attack intensity varies per flow; weak attacks overlap
                // the normal manifold (this is what caps accuracy ~92%).
                let intensity = rng.range_f64(0.55, 1.45);
                for (feat, shift) in family_signature(fam) {
                    x[feat] = (x[feat] + shift * intensity + rng.normal_ms(0.0, 0.05))
                        .clamp(0.0, 1.0);
                }
            }
            let mut label = is_attack as usize;
            if rng.chance(LABEL_NOISE) {
                label = 1 - label;
            }
            xs.extend(x.iter().map(|&v| v as f32));
            ys.push(label);
        }
        (xs, ys)
    };
    let (x_train, y_train) = gen_split(n_train);
    let (x_test, y_test) = gen_split(n_test);
    Dataset {
        name: "nid".into(),
        n_features: N_FEATURES,
        n_classes: 1, // binary, single output neuron
        x_train,
        y_train,
        x_test,
        y_test,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn attack_rate_in_band() {
        let ds = generate(20000, 100, 4);
        let rate = ds.y_train.iter().sum::<usize>() as f64 / ds.n_train() as f64;
        assert!((0.30..0.42).contains(&rate), "attack rate {rate}");
    }

    #[test]
    fn linear_probe_beats_chance_but_not_perfect() {
        // A one-pass perceptron should land well above chance and below
        // ~98%: the task must be learnable but not trivially separable.
        let ds = generate(12000, 3000, 9);
        let f = N_FEATURES;
        let mut w = vec![0f64; f + 1];
        for epoch in 0..4 {
            let lr = 0.05 / (1.0 + epoch as f64);
            for i in 0..ds.n_train() {
                let row = ds.train_row(i);
                let t = if ds.y_train[i] == 1 { 1.0 } else { -1.0 };
                let s: f64 =
                    w[f] + row.iter().enumerate().map(|(j, &v)| w[j] * v as f64).sum::<f64>();
                if s * t <= 0.0 {
                    for (j, &v) in row.iter().enumerate() {
                        w[j] += lr * t * v as f64;
                    }
                    w[f] += lr * t;
                }
            }
        }
        let mut correct = 0;
        for i in 0..ds.n_test() {
            let row = ds.test_row(i);
            let s: f64 =
                w[f] + row.iter().enumerate().map(|(j, &v)| w[j] * v as f64).sum::<f64>();
            let pred = (s > 0.0) as usize;
            if pred == ds.y_test[i] {
                correct += 1;
            }
        }
        let acc = correct as f64 / ds.n_test() as f64;
        assert!(acc > 0.70, "perceptron acc only {acc}");
        assert!(acc < 0.985, "dataset too separable: {acc}");
    }
}
