//! Synthetic jet-substructure generator (OpenML JSC substitute; DESIGN.md §5).
//!
//! The real dataset has 16 high-level jet-substructure observables
//! (masses, N-subjettiness ratios, energy-correlation functions, multiplicity)
//! for 5 jet classes {q, g, W, Z, t}.  The substitute draws a latent
//! "jet" per class — mass peak, prongness, radiation level — and derives 16
//! correlated observables with class-appropriate structure: W/Z are close
//! mass peaks (hard pair), q/g differ mainly in radiation (moderate pair),
//! t is heavy and 3-pronged (easy).  Overlap is tuned so small quantized
//! MLPs land in the paper's ~70-77% band with clear headroom ordering.

use super::Dataset;
use crate::util::rng::Rng;

pub const N_FEATURES: usize = 16;
pub const N_CLASSES: usize = 5; // q, g, W, Z, t

struct Latent {
    mass: f64,    // jet mass, GeV-ish scale
    prong: f64,   // effective prong count (1, 2, 3 + smearing)
    radiation: f64, // soft-radiation level
}

fn latent(class: usize, rng: &mut Rng) -> Latent {
    match class {
        // q: light, 1-prong, low radiation
        0 => Latent {
            mass: rng.normal_ms(18.0, 9.0),
            prong: rng.normal_ms(1.0, 0.25),
            radiation: rng.normal_ms(0.35, 0.14),
        },
        // g: light, 1-prong, high radiation (the q/g overlap is physical)
        1 => Latent {
            mass: rng.normal_ms(26.0, 11.0),
            prong: rng.normal_ms(1.15, 0.3),
            radiation: rng.normal_ms(0.62, 0.16),
        },
        // W: 80 GeV 2-prong
        2 => Latent {
            mass: rng.normal_ms(80.0, 9.0),
            prong: rng.normal_ms(2.0, 0.22),
            radiation: rng.normal_ms(0.42, 0.13),
        },
        // Z: 91 GeV 2-prong — deliberately close to W
        3 => Latent {
            mass: rng.normal_ms(91.0, 9.5),
            prong: rng.normal_ms(2.0, 0.22),
            radiation: rng.normal_ms(0.44, 0.13),
        },
        // t: 173 GeV 3-prong
        4 => Latent {
            mass: rng.normal_ms(173.0, 16.0),
            prong: rng.normal_ms(3.0, 0.3),
            radiation: rng.normal_ms(0.5, 0.15),
        },
        _ => unreachable!(),
    }
}

/// Derive the 16 observables from a latent jet. Nonlinear mixes + noise give
/// realistic cross-correlations; every feature gets instrument smearing.
fn observables(l: &Latent, rng: &mut Rng) -> [f64; N_FEATURES] {
    let m = l.mass.max(1.0);
    let p = l.prong.max(0.3);
    let r = l.radiation.clamp(0.02, 1.2);
    let n = |rng: &mut Rng, s: f64| rng.normal_ms(0.0, s);
    [
        m + n(rng, 3.0),                               // 0 m_SD   (soft-drop mass)
        m * rng.range_f64(0.85, 1.05) + n(rng, 4.0),   // 1 m_inv  (groomed mass variant)
        (1.0 / p + 0.25 * r) + n(rng, 0.05),           // 2 tau21-like
        (1.0 / (p * p) + 0.18 * r) + n(rng, 0.04),     // 3 tau32-like
        p + 0.8 * r + n(rng, 0.2),                     // 4 n-subjet estimate
        (30.0 + 22.0 * p + 60.0 * r) + n(rng, 7.0),    // 5 multiplicity
        (0.12 + 0.5 * r) / p + n(rng, 0.03),           // 6 girth / width
        (m / 100.0) * (0.3 + 0.6 * r) + n(rng, 0.05),  // 7 ECF C2-like
        (m / 100.0).powi(2) / p + n(rng, 0.08),        // 8 ECF D2-like
        0.5 * r + 0.1 * p + n(rng, 0.04),              // 9 p_T^D-like
        (1.0 - (-m / 60.0_f64).exp()) + n(rng, 0.05),  // 10 mass-fraction z_g proxy
        r * r + n(rng, 0.03),                          // 11 soft-activity sq
        (p - 1.0).max(0.0) * 0.4 + 0.2 * r + n(rng, 0.05), // 12 splitting scale
        m / (40.0 + 120.0 * r) + n(rng, 0.08),         // 13 mass/radiation ratio
        (0.6 * p + 0.4) * (1.0 - 0.3 * r) + n(rng, 0.07), // 14 prong asymmetry proxy
        ((m - 75.0) / 50.0).tanh() + n(rng, 0.06),     // 15 EW-peak discriminator
    ]
}

/// Fixed normalization bounds (population 1st/99th percentile analogues),
/// so train/test use identical scaling like real min-max preprocessing.
const LO: [f64; N_FEATURES] =
    [0.0, 0.0, 0.0, 0.0, 0.5, 20.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.2, -1.1];
const HI: [f64; N_FEATURES] =
    [210.0, 215.0, 1.4, 1.3, 4.8, 220.0, 0.9, 1.9, 3.6, 0.95, 1.5, 1.6, 1.3, 2.2, 2.6, 1.1];

pub fn generate(n_train: usize, n_test: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0x4A53_4331);
    let mut gen_split = |n: usize| {
        let mut xs = Vec::with_capacity(n * N_FEATURES);
        let mut ys = Vec::with_capacity(n);
        for i in 0..n {
            let class = i % N_CLASSES;
            let l = latent(class, &mut rng);
            let obs = observables(&l, &mut rng);
            for (f, &v) in obs.iter().enumerate() {
                let norm = (v - LO[f]) / (HI[f] - LO[f]);
                xs.push(norm.clamp(0.0, 1.0) as f32);
            }
            ys.push(class);
        }
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        let mut xs2 = vec![0f32; xs.len()];
        let mut ys2 = vec![0usize; n];
        for (dst, &src) in order.iter().enumerate() {
            xs2[dst * N_FEATURES..(dst + 1) * N_FEATURES]
                .copy_from_slice(&xs[src * N_FEATURES..(src + 1) * N_FEATURES]);
            ys2[dst] = ys[src];
        }
        (xs2, ys2)
    };
    let (x_train, y_train) = gen_split(n_train);
    let (x_test, y_test) = gen_split(n_test);
    Dataset {
        name: "jsc".into(),
        n_features: N_FEATURES,
        n_classes: N_CLASSES,
        x_train,
        y_train,
        x_test,
        y_test,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn feature_spread_not_saturated() {
        let ds = generate(4000, 100, 5);
        // Each feature should use a reasonable part of [0,1] and not be
        // pinned at the clamp rails.
        for f in 0..N_FEATURES {
            let vals: Vec<f32> = (0..ds.n_train()).map(|i| ds.train_row(i)[f]).collect();
            let mean: f32 = vals.iter().sum::<f32>() / vals.len() as f32;
            let clamped =
                vals.iter().filter(|&&v| v == 0.0 || v == 1.0).count() as f64 / vals.len() as f64;
            assert!(clamped < 0.2, "feature {f}: {clamped:.2} of values clamped");
            assert!((0.02..0.98).contains(&mean), "feature {f} mean {mean}");
        }
    }

    #[test]
    fn class_structure_w_z_harder_than_t() {
        // Centroid distances should reflect physics: W-Z close, t far.
        let ds = generate(10000, 100, 6);
        let mut cent = vec![vec![0f64; N_FEATURES]; N_CLASSES];
        let mut counts = vec![0usize; N_CLASSES];
        for i in 0..ds.n_train() {
            counts[ds.y_train[i]] += 1;
            for (c, &v) in cent[ds.y_train[i]].iter_mut().zip(ds.train_row(i)) {
                *c += v as f64;
            }
        }
        for (c, n) in cent.iter_mut().zip(&counts) {
            for v in c.iter_mut() {
                *v /= *n as f64;
            }
        }
        let dist = |a: &[f64], b: &[f64]| -> f64 {
            a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt()
        };
        let wz = dist(&cent[2], &cent[3]);
        let qt = dist(&cent[0], &cent[4]);
        let qg = dist(&cent[0], &cent[1]);
        assert!(wz < qg * 1.2, "W-Z should be among the hardest pairs: wz={wz} qg={qg}");
        assert!(qt > 2.5 * wz, "t should be well separated: qt={qt} wz={wz}");
    }
}
