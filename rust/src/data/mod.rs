//! Dataset substrates.
//!
//! The paper evaluates on MNIST, OpenML Jet-Substructure-Classification and
//! UNSW-NB15 — none of which are downloadable in this offline image.  Per
//! DESIGN.md §5 we substitute deterministic synthetic generators that keep
//! the properties the experiments depend on: identical input/output
//! dimensionality, image-like / physics-like / flow-like feature statistics,
//! and class overlap tuned so the *relative* accuracy ordering between
//! configurations (the Fig. 6 claim) is meaningful.  All features are
//! min-max normalized to [0, 1] (the model quantizes them to beta_in bits).

pub mod jsc;
pub mod mnist;
pub mod nid;

use anyhow::{bail, Result};

use crate::util::rng::Rng;

/// An in-memory dataset split into train/test.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub name: String,
    pub n_features: usize,
    pub n_classes: usize,
    /// Row-major [n, n_features], values in [0, 1].
    pub x_train: Vec<f32>,
    pub y_train: Vec<usize>,
    pub x_test: Vec<f32>,
    pub y_test: Vec<usize>,
}

impl Dataset {
    pub fn n_train(&self) -> usize {
        self.y_train.len()
    }

    pub fn n_test(&self) -> usize {
        self.y_test.len()
    }

    pub fn train_row(&self, i: usize) -> &[f32] {
        &self.x_train[i * self.n_features..(i + 1) * self.n_features]
    }

    pub fn test_row(&self, i: usize) -> &[f32] {
        &self.x_test[i * self.n_features..(i + 1) * self.n_features]
    }

    /// Sanity checks every generator must satisfy.
    pub fn validate(&self) -> Result<()> {
        if self.x_train.len() != self.n_train() * self.n_features
            || self.x_test.len() != self.n_test() * self.n_features
        {
            bail!("{}: feature matrix shape mismatch", self.name);
        }
        let classes = self.n_classes.max(2);
        if self.y_train.iter().chain(&self.y_test).any(|&y| y >= classes) {
            bail!("{}: label out of range", self.name);
        }
        if self.x_train.iter().chain(&self.x_test).any(|v| !(0.0..=1.0).contains(v)) {
            bail!("{}: feature outside [0,1]", self.name);
        }
        Ok(())
    }
}

/// Load a dataset by name. Sizes are the defaults used by the benches;
/// generation is O(n) and deterministic in `seed`.
pub fn load(name: &str, seed: u64) -> Result<Dataset> {
    load_sized(name, seed, default_sizes(name)?)
}

/// (n_train, n_test) defaults per dataset.
pub fn default_sizes(name: &str) -> Result<(usize, usize)> {
    Ok(match name {
        "mnist" | "mnist14" => (20_000, 4_000),
        "jsc" => (30_000, 6_000),
        "nid" => (30_000, 6_000),
        other => bail!("unknown dataset {other:?}"),
    })
}

pub fn load_sized(name: &str, seed: u64, sizes: (usize, usize)) -> Result<Dataset> {
    let (n_train, n_test) = sizes;
    let ds = match name {
        "mnist" => mnist::generate(28, n_train, n_test, seed),
        "mnist14" => mnist::generate(14, n_train, n_test, seed),
        "jsc" => jsc::generate(n_train, n_test, seed),
        "nid" => nid::generate(n_train, n_test, seed),
        other => bail!("unknown dataset {other:?}"),
    };
    ds.validate()?;
    Ok(ds)
}

/// A minibatch sampler: epoch-shuffled without replacement, reshuffling at
/// each epoch boundary (matches the PyTorch DataLoader the paper trains with).
pub struct BatchSampler {
    order: Vec<usize>,
    cursor: usize,
    rng: Rng,
}

impl BatchSampler {
    pub fn new(n: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0xBA7C4);
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        Self { order, cursor: 0, rng }
    }

    /// Next `batch` sample indices.
    pub fn next_batch(&mut self, batch: usize) -> Vec<usize> {
        let mut out = Vec::with_capacity(batch);
        while out.len() < batch {
            if self.cursor >= self.order.len() {
                self.rng.shuffle(&mut self.order);
                self.cursor = 0;
            }
            out.push(self.order[self.cursor]);
            self.cursor += 1;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_datasets_generate_and_validate() {
        for name in ["mnist14", "jsc", "nid"] {
            let ds = load_sized(name, 1, (500, 100)).unwrap();
            assert_eq!(ds.n_train(), 500);
            assert_eq!(ds.n_test(), 100);
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let a = load_sized("jsc", 7, (200, 50)).unwrap();
        let b = load_sized("jsc", 7, (200, 50)).unwrap();
        assert_eq!(a.x_train, b.x_train);
        assert_eq!(a.y_train, b.y_train);
        let c = load_sized("jsc", 8, (200, 50)).unwrap();
        assert_ne!(a.x_train, c.x_train);
    }

    #[test]
    fn sampler_covers_epoch() {
        let mut s = BatchSampler::new(10, 0);
        let mut seen = vec![false; 10];
        for _ in 0..2 {
            for &i in &s.next_batch(5) {
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&x| x), "first epoch must cover all samples");
    }

    #[test]
    fn class_balance_reasonable() {
        let ds = load_sized("jsc", 3, (5000, 500)).unwrap();
        let mut counts = vec![0usize; 5];
        for &y in &ds.y_train {
            counts[y] += 1;
        }
        for (c, &n) in counts.iter().enumerate() {
            assert!(n > 500, "class {c} underrepresented: {n}");
        }
    }
}
