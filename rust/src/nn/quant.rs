//! Fixed-point quantizers — bit-exact mirror of `python/compile/quant.py`.
//!
//! The deployed LUT network indexes on integer *codes*; the polynomial
//! arithmetic consumes *values* = code × step.  jnp.round is
//! round-half-to-even, so [`round_half_even`] reproduces it exactly — the
//! one place where f32 semantics could silently diverge between the trained
//! model and the generated tables.

/// Scale parameters pass through |p| + floor (model.py `scale_of`).
pub const SCALE_FLOOR: f32 = 1e-3;
pub const BN_EPS: f32 = 1e-5;

#[inline]
pub fn scale_of(p: f32) -> f32 {
    p.abs() + SCALE_FLOOR
}

/// Round half to even, matching `jnp.round` / IEEE roundTiesToEven.
#[inline]
pub fn round_half_even(x: f32) -> f32 {
    let r = x.round(); // half away from zero
    if (x - x.trunc()).abs() == 0.5 {
        // Tie: pick the even neighbour.
        let f = x.floor();
        if (f as i64) % 2 == 0 {
            f
        } else {
            f + 1.0
        }
    } else {
        r
    }
}

/// Unsigned quantizer over [0, scale] with 2^bits levels.
/// Returns the integer code in [0, 2^bits - 1].
#[inline]
pub fn unsigned_code(x: f32, bits: u32, scale: f32) -> i32 {
    let levels = ((1u64 << bits) - 1) as f32;
    let step = scale / levels;
    round_half_even(x / step).clamp(0.0, levels) as i32
}

/// Signed symmetric quantizer; codes in [-(2^(bits-1)), 2^(bits-1) - 1].
#[inline]
pub fn signed_code(x: f32, bits: u32, scale: f32) -> i32 {
    let pos = ((1u64 << (bits - 1)) - 1) as f32;
    let neg = -((1u64 << (bits - 1)) as f32);
    let step = scale / pos;
    round_half_even(x / step).clamp(neg, pos) as i32
}

/// Dequantization step of the unsigned quantizer.
#[inline]
pub fn unsigned_step(bits: u32, scale: f32) -> f32 {
    scale / ((1u64 << bits) - 1) as f32
}

/// Dequantization step of the signed quantizer.
#[inline]
pub fn signed_step(bits: u32, scale: f32) -> f32 {
    scale / ((1u64 << (bits - 1)) - 1) as f32
}

/// Two's-complement encoding of a signed code into `bits` bits (table
/// addressing / RTL constant emission).
#[inline]
pub fn to_twos_complement(code: i32, bits: u32) -> u32 {
    (code as u32) & ((1u32 << bits) - 1)
}

/// Inverse of [`to_twos_complement`].
#[inline]
pub fn from_twos_complement(raw: u32, bits: u32) -> i32 {
    let sign = 1u32 << (bits - 1);
    if raw & sign != 0 {
        (raw | !((1u32 << bits) - 1)) as i32
    } else {
        raw as i32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn half_even_ties() {
        assert_eq!(round_half_even(0.5), 0.0);
        assert_eq!(round_half_even(1.5), 2.0);
        assert_eq!(round_half_even(2.5), 2.0);
        assert_eq!(round_half_even(-0.5), 0.0);
        assert_eq!(round_half_even(-1.5), -2.0);
        assert_eq!(round_half_even(0.4999), 0.0);
        assert_eq!(round_half_even(0.5001), 1.0);
    }

    #[test]
    fn unsigned_codes() {
        // 2 bits over [0, 1]: levels 0,1,2,3 at step 1/3.
        assert_eq!(unsigned_code(0.0, 2, 1.0), 0);
        assert_eq!(unsigned_code(1.0, 2, 1.0), 3);
        assert_eq!(unsigned_code(0.34, 2, 1.0), 1);
        assert_eq!(unsigned_code(2.0, 2, 1.0), 3, "clamps above");
        assert_eq!(unsigned_code(-1.0, 2, 1.0), 0, "clamps below");
    }

    #[test]
    fn signed_codes() {
        // 3 bits, scale 3 => pos 3, step 1; codes -4..3.
        assert_eq!(signed_code(0.0, 3, 3.0), 0);
        assert_eq!(signed_code(3.0, 3, 3.0), 3);
        assert_eq!(signed_code(100.0, 3, 3.0), 3);
        assert_eq!(signed_code(-100.0, 3, 3.0), -4);
        assert_eq!(signed_code(-1.2, 3, 3.0), -1);
    }

    #[test]
    fn twos_complement_roundtrip() {
        for bits in 2..=8u32 {
            let lo = -(1i32 << (bits - 1));
            let hi = (1i32 << (bits - 1)) - 1;
            for code in lo..=hi {
                let raw = to_twos_complement(code, bits);
                assert!(raw < (1 << bits));
                assert_eq!(from_twos_complement(raw, bits), code, "bits={bits}");
            }
        }
    }

    #[test]
    fn steps_match_formulas() {
        assert!((unsigned_step(2, 1.0) - 1.0 / 3.0).abs() < 1e-7);
        assert!((signed_step(4, 7.0) - 1.0).abs() < 1e-7);
    }
}
