//! Model configuration — mirrors `python/compile/configs.py` (paper Table I
//! and Table IV presets).  The Python side is authoritative for trained
//! artifacts (configs arrive through `meta.json`); the presets here let
//! Rust-only paths (area/timing experiments, tests, benches) build the same
//! geometries without Python.

use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    /// widths[0] = input features; widths[last] = output neurons.
    pub widths: Vec<usize>,
    /// beta[l] = bit width of layer l's *input* codes; beta[n_layers] = output width.
    pub beta: Vec<u32>,
    /// fan[l] = fan-in F of layer l's sub-neurons.
    pub fan: Vec<usize>,
    pub degree: u32,
    /// A — PolyLUT sub-neurons per neuron (A=1 is plain PolyLUT).
    pub a_factor: usize,
    /// 1 => binary task (single output neuron, threshold at 0).
    pub n_classes: usize,
    pub seed: u64,
}

impl ModelConfig {
    pub fn n_layers(&self) -> usize {
        self.widths.len() - 1
    }

    pub fn layer_dims(&self) -> Vec<(usize, usize)> {
        (0..self.n_layers()).map(|i| (self.widths[i], self.widths[i + 1])).collect()
    }

    /// Signed word width of a sub-neuron output feeding the Adder-layer
    /// (paper Sec. III-A: one bit wider than the activation to avoid
    /// adder overflow).
    pub fn sub_bits(&self, layer: usize) -> u32 {
        self.beta[layer + 1] + 1
    }

    /// Address bits of one Poly-layer sub-neuron lookup table: beta * F.
    pub fn table_bits_poly(&self, layer: usize) -> u32 {
        self.beta[layer] * self.fan[layer] as u32
    }

    /// Address bits of the Adder-layer lookup table: A * (beta + 1).
    /// Zero when A == 1 (no adder stage — plain PolyLUT).
    pub fn table_bits_adder(&self, layer: usize) -> u32 {
        if self.a_factor == 1 {
            0
        } else {
            self.a_factor as u32 * self.sub_bits(layer)
        }
    }

    /// Output code width of layer `layer` (input width of the next).
    pub fn out_bits(&self, layer: usize) -> u32 {
        let last = layer == self.n_layers() - 1;
        if last {
            self.beta[layer + 1] // signed output codes
        } else {
            self.beta[layer + 1] // unsigned activation codes
        }
    }

    /// Total "lookup table size" in the paper's Table II accounting:
    /// per neuron, A * 2^{beta*F} + (A>1 ? 2^{A*(beta+1)} : 0) table words —
    /// summed over a single *representative* neuron (the paper reports the
    /// per-neuron table size) or over the network via [`Self::table_words_total`].
    pub fn table_words_neuron(&self, layer: usize) -> u128 {
        let poly = (self.a_factor as u128) << self.table_bits_poly(layer);
        let adder = if self.a_factor > 1 { 1u128 << self.table_bits_adder(layer) } else { 0 };
        poly + adder
    }

    pub fn table_words_total(&self) -> u128 {
        self.layer_dims()
            .iter()
            .enumerate()
            .map(|(l, &(_, n_out))| n_out as u128 * self.table_words_neuron(l))
            .sum()
    }

    pub fn validate(&self) -> Result<()> {
        if self.widths.len() < 2 {
            bail!("need at least one layer");
        }
        if self.beta.len() != self.widths.len() {
            bail!("beta length {} != widths length {}", self.beta.len(), self.widths.len());
        }
        if self.fan.len() != self.n_layers() {
            bail!("fan length {} != n_layers {}", self.fan.len(), self.n_layers());
        }
        for (l, &(n_in, _)) in self.layer_dims().iter().enumerate() {
            if self.fan[l] > n_in {
                bail!("layer {l}: fan-in {} exceeds input width {n_in}", self.fan[l]);
            }
            if self.table_bits_poly(l) > 26 {
                bail!(
                    "layer {l}: poly table of 2^{} words is not practical",
                    self.table_bits_poly(l)
                );
            }
        }
        if self.a_factor == 0 || self.degree == 0 {
            bail!("a_factor and degree must be >= 1");
        }
        Ok(())
    }
}

/// Builder for the uniform-geometry presets.
#[allow(clippy::too_many_arguments)]
pub fn uniform(
    name: &str,
    widths: &[usize],
    beta_in: u32,
    beta: u32,
    beta_out: u32,
    fan_in: usize,
    fan: usize,
    degree: u32,
    a: usize,
    n_classes: usize,
) -> ModelConfig {
    let n_layers = widths.len() - 1;
    let mut betas = vec![beta_in];
    betas.extend(std::iter::repeat(beta).take(n_layers - 1));
    betas.push(beta_out);
    let mut fans = vec![fan_in];
    fans.extend(std::iter::repeat(fan).take(n_layers - 1));
    ModelConfig {
        name: name.to_string(),
        widths: widths.to_vec(),
        beta: betas,
        fan: fans,
        degree,
        a_factor: a,
        n_classes,
        seed: 0,
    }
}

// ---- paper Table I presets -------------------------------------------------

pub fn hdr(degree: u32, a: usize) -> ModelConfig {
    uniform("hdr", &[784, 256, 100, 100, 100, 100, 10], 2, 2, 4, 6, 6, degree, a, 10)
}

pub fn jsc_xl(degree: u32, a: usize) -> ModelConfig {
    uniform("jsc-xl", &[16, 128, 64, 64, 64, 5], 7, 5, 5, 2, 3, degree, a, 5)
}

pub fn jsc_m_lite(degree: u32, a: usize) -> ModelConfig {
    uniform("jsc-m-lite", &[16, 64, 32, 5], 3, 3, 4, 4, 4, degree, a, 5)
}

pub fn nid_lite(degree: u32, a: usize) -> ModelConfig {
    uniform("nid-lite", &[49, 686, 147, 98, 49, 1], 1, 3, 2, 7, 5, degree, a, 1)
}

// ---- paper Table IV presets (smaller F; A=2) --------------------------------

pub fn hdr_add2() -> ModelConfig {
    uniform("hdr-t4", &[784, 256, 100, 100, 100, 100, 10], 2, 2, 4, 4, 4, 3, 2, 10)
}

pub fn jsc_xl_add2() -> ModelConfig {
    uniform("jsc-xl-t4", &[16, 128, 64, 64, 64, 5], 7, 5, 5, 1, 2, 3, 2, 5)
}

pub fn jsc_m_lite_add2() -> ModelConfig {
    uniform("jsc-m-lite-t4", &[16, 64, 32, 5], 3, 3, 4, 2, 2, 3, 2, 5)
}

pub fn nid_add2() -> ModelConfig {
    uniform("nid-t4", &[49, 100, 100, 50, 50, 1], 1, 2, 2, 6, 3, 1, 2, 1)
}

/// PolyLUT-Deeper: replicate hidden layers (paper Sec. IV-C).
pub fn deeper(cfg: &ModelConfig, factor: usize) -> ModelConfig {
    let hidden: Vec<usize> =
        cfg.widths[1..cfg.widths.len() - 1].iter().flat_map(|&w| vec![w; factor]).collect();
    let mut widths = vec![cfg.widths[0]];
    widths.extend(hidden);
    widths.push(*cfg.widths.last().expect("validated config has >= 2 widths"));
    let n_layers = widths.len() - 1;
    let mut beta = vec![cfg.beta[0]];
    beta.extend(std::iter::repeat(cfg.beta[1]).take(n_layers - 1));
    beta.push(*cfg.beta.last().expect("validated config has per-boundary beta"));
    let mut fan = vec![cfg.fan[0]];
    let hidden_fan = if cfg.n_layers() > 1 { cfg.fan[1] } else { cfg.fan[0] };
    fan.extend(std::iter::repeat(hidden_fan).take(n_layers - 1));
    ModelConfig {
        name: format!("{}-deep{factor}", cfg.name),
        widths,
        beta,
        fan,
        ..cfg.clone()
    }
}

/// PolyLUT-Wider: multiply hidden widths (paper Sec. IV-C).
pub fn wider(cfg: &ModelConfig, factor: usize) -> ModelConfig {
    let mut widths = cfg.widths.clone();
    for w in widths.iter_mut().skip(1).take(cfg.n_layers() - 1) {
        *w *= factor;
    }
    ModelConfig { name: format!("{}-wide{factor}", cfg.name), widths, ..cfg.clone() }
}

pub fn preset(name: &str, degree: u32, a: usize) -> Result<ModelConfig> {
    Ok(match name {
        "hdr" => hdr(degree, a),
        "jsc-xl" => jsc_xl(degree, a),
        "jsc-m-lite" => jsc_m_lite(degree, a),
        "nid-lite" => nid_lite(degree, a),
        "hdr-t4" => hdr_add2(),
        "jsc-xl-t4" => jsc_xl_add2(),
        "jsc-m-lite-t4" => jsc_m_lite_add2(),
        "nid-t4" => nid_add2(),
        other => bail!("unknown preset {other:?}"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        for cfg in [
            hdr(1, 1),
            hdr(2, 3),
            jsc_xl(2, 2),
            jsc_m_lite(1, 2),
            nid_lite(1, 2),
            hdr_add2(),
            jsc_xl_add2(),
            jsc_m_lite_add2(),
            nid_add2(),
        ] {
            cfg.validate().unwrap_or_else(|e| panic!("{}: {e}", cfg.name));
        }
    }

    #[test]
    fn table_accounting_matches_paper() {
        // HDR beta=2, F=6: PolyLUT table 2^12; Add2: 2^12*2 + 2^6.
        let p = hdr(1, 1);
        assert_eq!(p.table_words_neuron(0), 1 << 12);
        let a2 = hdr(1, 2);
        assert_eq!(a2.table_words_neuron(0), (1 << 12) * 2 + (1 << 6));
        let a3 = hdr(1, 3);
        assert_eq!(a3.table_words_neuron(0), (1 << 12) * 3 + (1 << 9));
        // JSC-XL beta=5, F=3: 2^15; Add2 hidden: 2^15*2 + 2^12.
        let x = jsc_xl(1, 2);
        assert_eq!(x.table_words_neuron(1), (1 << 15) * 2 + (1 << 12));
        // JSC-M Lite beta=3 F=4: Add2 2^12*2+2^8, Add3 2^12*3+2^12.
        let m2 = jsc_m_lite(1, 2);
        assert_eq!(m2.table_words_neuron(1), (1 << 12) * 2 + (1 << 8));
        let m3 = jsc_m_lite(1, 3);
        assert_eq!(m3.table_words_neuron(1), (1 << 12) * 3 + (1 << 12));
        // NID Lite beta=3 F=5: Add2 2^15*2 + 2^8.
        let n2 = nid_lite(1, 2);
        assert_eq!(n2.table_words_neuron(1), (1 << 15) * 2 + (1 << 8));
    }

    #[test]
    fn deeper_wider_shapes() {
        let base = jsc_m_lite(1, 1);
        let d2 = deeper(&base, 2);
        assert_eq!(d2.widths, vec![16, 64, 64, 32, 32, 5]);
        let w2 = wider(&base, 2);
        assert_eq!(w2.widths, vec![16, 128, 64, 5]);
        d2.validate().unwrap();
        w2.validate().unwrap();
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut cfg = jsc_m_lite(1, 1);
        cfg.fan[0] = 100; // > 16 inputs
        assert!(cfg.validate().is_err());
        let mut cfg = jsc_m_lite(1, 1);
        cfg.beta[0] = 9; // 9*4 = 36 address bits
        assert!(cfg.validate().is_err());
    }
}
