//! The hardware-functional model: a trained PolyLUT-Add network evaluated in
//! the exact fixed-point semantics the generated hardware implements.
//!
//! This is the single source of truth the LUT compiler enumerates
//! (`lut::tables`), the netlist simulator must match bit-for-bit
//! (`sim::lutsim`), and the Verilog testbench checks against.  The float
//! arithmetic mirrors the JAX graph op-for-op in f32 (see quant.rs for the
//! rounding contract).

use anyhow::{bail, Result};

use super::config::ModelConfig;
use super::poly::{monomial_count, monomial_index_lists, poly_eval};
use super::quant::{
    scale_of, signed_code, signed_step, unsigned_code, unsigned_step, BN_EPS,
};
use crate::util::rng::Rng;

/// Per-layer trained parameters (layout mirrors python/compile/model.py).
#[derive(Debug, Clone)]
pub struct LayerParams {
    /// Sparse connectivity: indices[a][j] = the F input positions feeding
    /// sub-neuron a of neuron j.
    pub indices: Vec<Vec<Vec<usize>>>,
    /// Weights, [A][n_out][M] (canonical monomial order).
    pub w: Vec<Vec<Vec<f32>>>,
    /// Raw scale params (pass through `scale_of`).
    pub s_pre: f32,
    pub s_act: f32,
    /// Batch-norm affine + running stats, per output neuron.
    pub bn_g: Vec<f32>,
    pub bn_b: Vec<f32>,
    pub bn_m: Vec<f32>,
    pub bn_v: Vec<f32>,
}

/// A complete network: config + parameters, ready for evaluation, table
/// generation, or RTL emission.
#[derive(Debug, Clone)]
pub struct Network {
    pub cfg: ModelConfig,
    pub layers: Vec<LayerParams>,
    /// monomials[l] — the index multisets for layer l's (F, D).
    pub monomials: Vec<Vec<Vec<usize>>>,
}

impl Network {
    /// Validate structural consistency (shapes, index bounds).
    pub fn validate(&self) -> Result<()> {
        self.cfg.validate()?;
        if self.layers.len() != self.cfg.n_layers() {
            bail!("{} layers vs {} in config", self.layers.len(), self.cfg.n_layers());
        }
        for (l, (p, &(n_in, n_out))) in
            self.layers.iter().zip(self.cfg.layer_dims().iter()).enumerate()
        {
            let (a, f) = (self.cfg.a_factor, self.cfg.fan[l]);
            let m = monomial_count(f, self.cfg.degree);
            if p.indices.len() != a || p.w.len() != a {
                bail!("layer {l}: A mismatch");
            }
            for sub in 0..a {
                if p.indices[sub].len() != n_out || p.w[sub].len() != n_out {
                    bail!("layer {l} sub {sub}: n_out mismatch");
                }
                for j in 0..n_out {
                    if p.indices[sub][j].len() != f {
                        bail!("layer {l} sub {sub} neuron {j}: fan-in mismatch");
                    }
                    if p.w[sub][j].len() != m {
                        bail!("layer {l} sub {sub} neuron {j}: weight count != {m}");
                    }
                    if let Some(&bad) = p.indices[sub][j].iter().find(|&&i| i >= n_in) {
                        bail!("layer {l}: index {bad} out of range {n_in}");
                    }
                }
            }
            for v in [&p.bn_g, &p.bn_b, &p.bn_m, &p.bn_v] {
                if v.len() != n_out {
                    bail!("layer {l}: BN length mismatch");
                }
            }
            if self.monomials[l].len() != m {
                bail!("layer {l}: monomial list mismatch");
            }
        }
        Ok(())
    }

    /// Random-weight network for a config (area/timing experiments and tests
    /// that don't need trained accuracy; weight realism documented in
    /// DESIGN.md §6).
    pub fn random(cfg: &ModelConfig, rng: &mut Rng) -> Network {
        let mut layers = Vec::new();
        let mut monomials = Vec::new();
        for (l, (n_in, n_out)) in cfg.layer_dims().into_iter().enumerate() {
            let f = cfg.fan[l];
            let m = monomial_count(f, cfg.degree);
            let std = 1.0 / (m as f32).sqrt();
            let indices = (0..cfg.a_factor)
                .map(|_| (0..n_out).map(|_| rng.choose_distinct(n_in, f)).collect())
                .collect();
            let w = (0..cfg.a_factor)
                .map(|_| {
                    (0..n_out)
                        .map(|_| (0..m).map(|_| rng.normal_ms(0.0, std as f64) as f32).collect())
                        .collect()
                })
                .collect();
            layers.push(LayerParams {
                indices,
                w,
                s_pre: 2.0,
                s_act: 2.0,
                bn_g: vec![1.0; n_out],
                bn_b: vec![0.0; n_out],
                bn_m: (0..n_out).map(|_| rng.normal_ms(0.0, 0.3) as f32).collect(),
                bn_v: (0..n_out).map(|_| (0.5 + rng.f64()) as f32).collect(),
            });
            monomials.push(monomial_index_lists(f, cfg.degree));
        }
        Network { cfg: cfg.clone(), layers, monomials }
    }

    /// Step (value per code unit) of layer `l`'s *input* codes.
    pub fn in_step(&self, l: usize) -> f32 {
        if l == 0 {
            unsigned_step(self.cfg.beta[0], 1.0)
        } else {
            unsigned_step(self.cfg.beta[l], scale_of(self.layers[l - 1].s_act))
        }
    }

    /// Step of layer `l`'s sub-neuron (Poly-layer) output codes.
    pub fn pre_step(&self, l: usize) -> f32 {
        signed_step(self.cfg.sub_bits(l), scale_of(self.layers[l].s_pre))
    }

    /// Step of layer `l`'s output codes.
    pub fn out_step(&self, l: usize) -> f32 {
        let last = l == self.cfg.n_layers() - 1;
        let bits = self.cfg.beta[l + 1];
        let scale = scale_of(self.layers[l].s_act);
        if last {
            signed_step(bits, scale)
        } else {
            unsigned_step(bits, scale)
        }
    }

    /// Poly-layer sub-neuron: input codes -> signed (beta+1)-bit output code.
    /// This is the exact function each Poly lookup table stores.
    pub fn sub_neuron_code(&self, l: usize, a: usize, j: usize, in_codes: &[i32]) -> i32 {
        let step_in = self.in_step(l);
        let p = &self.layers[l];
        let f = self.cfg.fan[l];
        debug_assert_eq!(in_codes.len(), f);
        debug_assert!(f <= 32, "fan-in beyond table practicality cap");
        let mut x = [0f32; 32];
        for i in 0..f {
            x[i] = in_codes[i] as f32 * step_in;
        }
        let pre = poly_eval(&x[..f], &p.w[a][j], &self.monomials[l]);
        signed_code(pre, self.cfg.sub_bits(l), scale_of(p.s_pre))
    }

    /// Adder-layer: A signed sub-neuron codes -> layer output code
    /// (sum -> BN -> activation -> quant).  The exact Adder table function.
    pub fn adder_code(&self, l: usize, j: usize, sub_codes: &[i32]) -> i32 {
        let p = &self.layers[l];
        let step_pre = self.pre_step(l);
        let sum: i32 = sub_codes.iter().sum();
        let z = sum as f32 * step_pre;
        let zn = (z - p.bn_m[j]) / (p.bn_v[j] + BN_EPS).sqrt() * p.bn_g[j] + p.bn_b[j];
        let last = l == self.cfg.n_layers() - 1;
        let bits = self.cfg.beta[l + 1];
        if last {
            signed_code(zn, bits, scale_of(p.s_act))
        } else {
            unsigned_code(zn.max(0.0), bits, scale_of(p.s_act))
        }
    }

    /// Full fixed-point forward pass over input *codes* (beta[0]-bit).
    /// Returns the output codes (signed beta_out-bit).
    pub fn forward_codes(&self, in_codes: &[i32]) -> Vec<i32> {
        assert_eq!(in_codes.len(), self.cfg.widths[0]);
        let mut codes = in_codes.to_vec();
        for l in 0..self.cfg.n_layers() {
            let n_out = self.cfg.widths[l + 1];
            let mut next = vec![0i32; n_out];
            let mut gathered = vec![0i32; self.cfg.fan[l]];
            let mut subs = vec![0i32; self.cfg.a_factor];
            for j in 0..n_out {
                for a in 0..self.cfg.a_factor {
                    for (slot, &src) in self.layers[l].indices[a][j].iter().enumerate() {
                        gathered[slot] = codes[src];
                    }
                    subs[a] = self.sub_neuron_code(l, a, j, &gathered);
                }
                next[j] = self.adder_code(l, j, &subs);
            }
            codes = next;
        }
        codes
    }

    /// Quantize raw [0,1] features to input codes.
    pub fn quantize_input(&self, x: &[f32]) -> Vec<i32> {
        x.iter().map(|&v| unsigned_code(v, self.cfg.beta[0], 1.0)).collect()
    }

    /// Forward from raw features; returns dequantized logits.
    pub fn forward(&self, x: &[f32]) -> Vec<f32> {
        let codes = self.forward_codes(&self.quantize_input(x));
        let l = self.cfg.n_layers() - 1;
        let step = self.out_step(l);
        codes.iter().map(|&c| c as f32 * step).collect()
    }

    /// Predicted class (argmax, NaN-safe; for binary: logit > 0).
    pub fn predict(&self, x: &[f32]) -> usize {
        let logits = self.forward(x);
        if self.cfg.n_classes == 1 {
            (logits[0] > 0.0) as usize
        } else {
            crate::util::argmax_f32(&logits)
        }
    }

    /// Classification accuracy over a dataset.
    pub fn accuracy(&self, xs: &[Vec<f32>], ys: &[usize]) -> f64 {
        let correct: usize =
            xs.iter().zip(ys).filter(|(x, &y)| self.predict(x) == y).count();
        correct as f64 / xs.len().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::config;

    fn tiny() -> Network {
        let cfg = config::uniform("tiny", &[8, 6, 3], 2, 2, 3, 3, 3, 2, 2, 3);
        let mut rng = Rng::new(11);
        Network::random(&cfg, &mut rng)
    }

    #[test]
    fn random_network_validates() {
        tiny().validate().unwrap();
    }

    #[test]
    fn forward_shapes_and_determinism() {
        let net = tiny();
        let x: Vec<f32> = (0..8).map(|i| i as f32 / 8.0).collect();
        let a = net.forward(&x);
        let b = net.forward(&x);
        assert_eq!(a.len(), 3);
        assert_eq!(a, b);
    }

    #[test]
    fn output_codes_within_width() {
        let net = tiny();
        let mut rng = Rng::new(5);
        for _ in 0..50 {
            let x: Vec<f32> = (0..8).map(|_| rng.f32()).collect();
            let codes = net.forward_codes(&net.quantize_input(&x));
            let bits = net.cfg.beta[net.cfg.n_layers()];
            let lo = -(1i32 << (bits - 1));
            let hi = (1i32 << (bits - 1)) - 1;
            assert!(codes.iter().all(|&c| (lo..=hi).contains(&c)), "{codes:?}");
        }
    }

    #[test]
    fn sub_neuron_codes_within_width() {
        let net = tiny();
        let bits = net.cfg.sub_bits(0);
        let lo = -(1i32 << (bits - 1));
        let hi = (1i32 << (bits - 1)) - 1;
        let levels = (1i32 << net.cfg.beta[0]) - 1;
        let mut rng = Rng::new(17);
        for _ in 0..200 {
            let codes: Vec<i32> =
                (0..net.cfg.fan[0]).map(|_| rng.below(levels as usize + 1) as i32).collect();
            let c = net.sub_neuron_code(0, 0, 0, &codes);
            assert!((lo..=hi).contains(&c));
        }
    }

    #[test]
    fn a1_has_no_adder_table_but_still_evaluates() {
        let cfg = config::uniform("a1", &[8, 6, 3], 2, 2, 3, 3, 3, 1, 1, 3);
        let mut rng = Rng::new(3);
        let net = Network::random(&cfg, &mut rng);
        net.validate().unwrap();
        let x: Vec<f32> = (0..8).map(|_| rng.f32()).collect();
        assert_eq!(net.forward(&x).len(), 3);
        assert_eq!(cfg.table_bits_adder(0), 0);
    }
}
