//! Monomial enumeration and polynomial evaluation — the Rust mirror of
//! `python/compile/monomials.py`.  The canonical order (degree-major,
//! lexicographic combinations-with-replacement within a degree) defines the
//! weight-tensor layout; a cross-language test checks it against the
//! `monomials` section of every artifact manifest.

/// Number of monomials of degree <= `degree` in `fan_in` variables:
/// C(fan_in + degree, degree).
pub fn monomial_count(fan_in: usize, degree: u32) -> usize {
    let (n, k) = (fan_in + degree as usize, degree as usize);
    // C(n, k) with small arguments; compute in u128 to stay exact.
    let mut num: u128 = 1;
    let mut den: u128 = 1;
    for i in 0..k {
        num *= (n - i) as u128;
        den *= (i + 1) as u128;
    }
    (num / den) as usize
}

/// All monomials in canonical order, each as the multiset of input indices
/// it multiplies (empty list = the constant 1).
pub fn monomial_index_lists(fan_in: usize, degree: u32) -> Vec<Vec<usize>> {
    fn rec(fan_in: usize, d: usize, start: usize, cur: &mut Vec<usize>, out: &mut Vec<Vec<usize>>) {
        if cur.len() == d {
            out.push(cur.clone());
            return;
        }
        for i in start..fan_in {
            cur.push(i);
            rec(fan_in, d, i, cur, out);
            cur.pop();
        }
    }
    let mut out = Vec::new();
    for d in 0..=degree as usize {
        rec(fan_in, d, 0, &mut Vec::new(), &mut out);
    }
    out
}

/// Evaluate the polynomial `sum_m w[m] * monomial_m(x)` for one sub-neuron.
/// `monomials` must be in the same order as `w`.
#[inline]
pub fn poly_eval(x: &[f32], w: &[f32], monomials: &[Vec<usize>]) -> f32 {
    debug_assert_eq!(w.len(), monomials.len());
    let mut acc = 0.0f32;
    for (wm, combo) in w.iter().zip(monomials) {
        let mut term = 1.0f32;
        for &i in combo {
            term *= x[i];
        }
        acc += term * wm;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts() {
        assert_eq!(monomial_count(6, 1), 7);
        assert_eq!(monomial_count(6, 2), 28);
        assert_eq!(monomial_count(4, 2), 15);
        assert_eq!(monomial_count(2, 3), 10);
        assert_eq!(monomial_count(6, 3), 84);
        assert_eq!(monomial_count(3, 1), 4);
    }

    #[test]
    fn order_matches_python_f2_d2() {
        // combinations_with_replacement(range(2), d) for d=0,1,2:
        // [], [0], [1], [0,0], [0,1], [1,1]
        let m = monomial_index_lists(2, 2);
        assert_eq!(
            m,
            vec![vec![], vec![0], vec![1], vec![0, 0], vec![0, 1], vec![1, 1]]
        );
    }

    #[test]
    fn order_matches_python_f3_d2() {
        let m = monomial_index_lists(3, 2);
        assert_eq!(
            m,
            vec![
                vec![],
                vec![0],
                vec![1],
                vec![2],
                vec![0, 0],
                vec![0, 1],
                vec![0, 2],
                vec![1, 1],
                vec![1, 2],
                vec![2, 2],
            ]
        );
    }

    #[test]
    fn enumeration_count_matches_formula() {
        for f in 1..=7usize {
            for d in 1..=3u32 {
                assert_eq!(
                    monomial_index_lists(f, d).len(),
                    monomial_count(f, d),
                    "F={f} D={d}"
                );
            }
        }
    }

    #[test]
    fn eval_quadratic() {
        // f(x) = 1 + 2*x0 + 3*x1 + 4*x0^2 + 5*x0*x1 + 6*x1^2 at (2, -1)
        let monomials = monomial_index_lists(2, 2);
        let w = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let v = poly_eval(&[2.0, -1.0], &w, &monomials);
        assert_eq!(v, 1.0 + 4.0 - 3.0 + 16.0 - 10.0 + 6.0);
    }
}
