//! The hardware-functional network model: configs (paper Table I/IV),
//! bit-exact quantizers, monomial algebra, and the fixed-point forward pass
//! that the LUT compiler enumerates and the netlist simulator must match.

pub mod config;
pub mod network;
pub mod poly;
pub mod quant;

pub use config::ModelConfig;
pub use network::{LayerParams, Network};
