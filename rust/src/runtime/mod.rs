//! PJRT runtime — loads AOT-lowered HLO text and executes it on the CPU
//! PJRT client via the `xla` crate.  This is the only bridge between the
//! Rust coordinator and the JAX/Pallas-authored compute graphs; Python never
//! runs at this point.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`): jax >= 0.5
//! serialized protos carry 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};
use xla::{Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

/// Shared PJRT CPU client. Clone freely; the underlying client is
/// reference-counted by the xla crate.
#[derive(Clone)]
pub struct Engine {
    client: Arc<PjRtClient>,
}

impl Engine {
    pub fn cpu() -> Result<Engine> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client: Arc::new(client) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Upload a host literal to a device buffer that Rust owns (and frees).
    pub fn to_buffer(&self, lit: &Literal) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_literal(None, lit)
            .map_err(|e| anyhow!("host->device transfer: {e}"))
    }

    /// Load + compile an HLO text file into an executable.  The executable
    /// keeps a clone of this engine so its host-literal entry points can use
    /// the leak-free upload-and-borrow path.
    pub fn load_hlo(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { exe, name: path.display().to_string(), engine: self.clone() })
    }
}

/// A compiled computation. The lowered graphs in this repo return a single
/// tuple (aot.py lowers with return_tuple=True); `run` flattens it back to
/// per-output literals.
pub struct Executable {
    exe: PjRtLoadedExecutable,
    name: String,
    engine: Engine,
}

impl Executable {
    /// Upload host literals to Rust-owned device buffers (freed on drop).
    /// Every host-literal entry point goes through this + `execute_b`: the
    /// vendored C wrapper behind the raw `execute()` entry point *leaks
    /// every input device buffer* (`buffer.release()` without a matching
    /// delete in xla_rs.cc), so nothing here ever calls it.
    fn upload(&self, args: &[Literal]) -> Result<Vec<PjRtBuffer>> {
        args.iter().map(|l| self.engine.to_buffer(l)).collect()
    }

    /// Execute with host literals; returns the flattened output literals.
    /// Leak-free: inputs go through [`Executable::upload`] and the borrowing
    /// `execute_b` path, so no caller can hit the leaking wrapper.
    pub fn run(&self, args: &[Literal]) -> Result<Vec<Literal>> {
        let bufs = self.upload(args)?;
        let refs: Vec<&PjRtBuffer> = bufs.iter().collect();
        self.run_b(&refs)
    }

    /// Execute with device buffers (inputs stay on device).
    pub fn run_b(&self, args: &[&PjRtBuffer]) -> Result<Vec<Literal>> {
        let outs = self
            .exe
            .execute_b(args)
            .with_context(|| format!("executing {}", self.name))?;
        self.flatten(outs)
    }

    /// Execute with host literals and keep outputs as raw device buffers.
    /// Same leak-free upload-and-borrow path as [`Executable::run`].
    pub fn run_buffers(&self, args: &[Literal]) -> Result<Vec<PjRtBuffer>> {
        let bufs = self.upload(args)?;
        let refs: Vec<&PjRtBuffer> = bufs.iter().collect();
        let mut outs = self
            .exe
            .execute_b(&refs)
            .with_context(|| format!("executing {}", self.name))?;
        if outs.is_empty() {
            bail!("{}: no replica outputs", self.name);
        }
        Ok(outs.swap_remove(0))
    }

    fn flatten(&self, mut outs: Vec<Vec<xla::PjRtBuffer>>) -> Result<Vec<Literal>> {
        if outs.is_empty() {
            bail!("{}: no replica outputs", self.name);
        }
        let replica = outs.swap_remove(0);
        let mut literals = Vec::new();
        for buf in &replica {
            let lit = buf.to_literal_sync()?;
            // return_tuple=True lowers to a tuple root; decompose transparently.
            match lit.shape()? {
                xla::Shape::Tuple(_) => {
                    let mut l = lit;
                    literals.extend(l.decompose_tuple()?);
                }
                _ => literals.push(lit),
            }
        }
        Ok(literals)
    }
}

// ---- literal marshalling ----------------------------------------------------

/// Build an f32 literal of the given shape from a flat slice.
pub fn f32_literal(data: &[f32], dims: &[i64]) -> Result<Literal> {
    let want: i64 = dims.iter().product();
    if want != data.len() as i64 {
        bail!("f32_literal: {} values for shape {dims:?}", data.len());
    }
    Literal::vec1(data).reshape(dims).map_err(|e| anyhow!("{e}"))
}

/// Build an i32 literal of the given shape from a flat slice.
pub fn i32_literal(data: &[i32], dims: &[i64]) -> Result<Literal> {
    let want: i64 = dims.iter().product();
    if want != data.len() as i64 {
        bail!("i32_literal: {} values for shape {dims:?}", data.len());
    }
    Literal::vec1(data).reshape(dims).map_err(|e| anyhow!("{e}"))
}

/// Extract an f32 vector from a literal (any shape, row-major).
pub fn to_f32_vec(lit: &Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("{e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = f32_literal(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]).unwrap();
        assert_eq!(to_f32_vec(&l).unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(f32_literal(&[1.0], &[2]).is_err());
    }

    #[test]
    fn engine_compiles_reference_hlo() {
        // PJRT smoke: only when quickstart artifacts exist (`make artifacts`).
        let eval = Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/jsc-m-lite-d1-a1.eval.hlo.txt");
        if !eval.exists() {
            return;
        }
        let engine = Engine::cpu().unwrap();
        assert_eq!(engine.platform(), "cpu");
        let _exe = engine.load_hlo(&eval).unwrap();
    }
}
