//! # PolyLUT-Add — FPGA-based LUT inference with wide inputs
//!
//! Full-toolflow reproduction of *PolyLUT-Add* (Lou et al., 2024): LUT-based
//! DNN inference where each neuron is `A` PolyLUT sub-neurons combined by an
//! adder lookup table, cutting table cost from `2^{βFA}` to
//! `A·2^{βF} + 2^{A(β+1)}`.
//!
//! The stack has three layers (see DESIGN.md):
//! - **L1/L2 (build time)**: Pallas kernels + JAX QAT model, AOT-lowered to
//!   HLO text artifacts by `python/compile/aot.py`.
//! - **L3 (this crate)**: training driver, LUT compiler (truth tables →
//!   ROBDD → LUT6 mapping), Verilog emitter, FPGA area/timing model,
//!   bit-exact netlist simulator, and a batching inference server — all in
//!   Rust over the PJRT C API; Python never runs on the request path.

pub mod coordinator;
pub mod data;
pub mod fpga;
pub mod lut;
pub mod meta;
pub mod nn;
pub mod runtime;
pub mod sim;
pub mod simd;
pub mod train;
pub mod util;
pub mod verilog;
pub mod cli_app;
pub use cli_app::cli_main;
pub mod harness;
