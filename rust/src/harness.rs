//! Experiment harness — shared orchestration for examples and benches.
//!
//! Centralizes the train-or-load / synth / evaluate flow so every
//! table/figure bench reproduces the paper rows through the same code path.
//! Training is cached as `<id>.weights.json` next to the artifacts: the
//! first bench run trains (PJRT), later runs load.

use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::data::{self, Dataset};
use crate::fpga::{self, Strategy, SynthReport};
use crate::meta::{self, Manifest};
use crate::nn::network::Network;
use crate::runtime::Engine;
use crate::train::{self, TrainOptions};

/// Default artifact directory (env `POLYLUT_ARTIFACTS` overrides).
pub fn artifacts_dir() -> PathBuf {
    std::env::var("POLYLUT_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

/// Training steps for experiment runs (env `POLYLUT_STEPS`; scale-down
/// documented in DESIGN.md §4).
pub fn train_steps() -> usize {
    std::env::var("POLYLUT_STEPS").ok().and_then(|s| s.parse().ok()).unwrap_or(1200)
}

/// A fully prepared experiment model.
pub struct Prepared {
    pub man: Manifest,
    pub ds: Dataset,
    pub net: Network,
    /// Deployed-semantics test accuracy (fraction).
    pub accuracy: f64,
    pub state: Vec<Vec<f32>>,
}

/// Train-or-load an artifact id and evaluate deployed accuracy.
pub fn prepare(engine: &Engine, id: &str) -> Result<Prepared> {
    prepare_with(engine, id, train_steps(), restarts_for(id))
}

/// UNSW convergence is seed-sensitive (paper Sec. IV-B): use restarts.
pub fn restarts_for(id: &str) -> usize {
    if id.starts_with("nid") {
        3
    } else {
        1
    }
}

pub fn prepare_with(
    engine: &Engine,
    id: &str,
    steps: usize,
    restarts: usize,
) -> Result<Prepared> {
    let dir = artifacts_dir();
    let man = meta::load_id(&dir, id)
        .with_context(|| format!("artifact {id} — run `make artifacts` first"))?;
    let ds = data::load(&man.dataset, 0)?;
    let opts = TrainOptions {
        steps,
        restarts,
        verbose: std::env::var("POLYLUT_VERBOSE").is_ok(),
        ..Default::default()
    };
    let (state, _) = train::train_or_load(engine, &man, &ds, &opts)?;
    let net = man.network_from_state(&state)?;
    // Full-test-set deployed accuracy.
    let (_, accuracy) = train::deployed_accuracy(&man, &state, &ds, 0)?;
    Ok(Prepared { man, ds, net, accuracy, state })
}

/// Synthesize under a strategy (the Vivado-substitute back-end).
pub fn synth(p: &Prepared, strategy: Strategy) -> Result<SynthReport> {
    fpga::synthesize(&p.net, strategy)
}

/// Format a fraction as the paper's percentage style.
pub fn pct(acc: f64) -> String {
    format!("{:.1}", acc * 100.0)
}

/// "2^12 x 2 + 2^6"-style table-size strings (paper Table II).
pub fn table_size_string(cfg: &crate::nn::ModelConfig) -> String {
    let bits = cfg.table_bits_poly(cfg.n_layers() - 1).max(cfg.table_bits_poly(0));
    if cfg.a_factor == 1 {
        format!("2^{bits}")
    } else {
        format!("2^{bits} x {} + 2^{}", cfg.a_factor, cfg.table_bits_adder(1.min(cfg.n_layers() - 1)))
    }
}
