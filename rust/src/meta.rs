//! Artifact manifest loading — the contract with `python/compile/aot.py`.
//!
//! A manifest (`artifacts/<id>.meta.json`) carries the model config, the
//! frozen sparse connectivity, the canonical monomial order, the training
//! state layout (name/shape/role per tensor) with initial values, optimizer
//! hyperparameters, and the file names of the lowered HLO graphs.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::nn::network::{LayerParams, Network};
use crate::nn::poly::monomial_count;
use crate::nn::ModelConfig;
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct StateSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub role: Role,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    Train,
    Stat,
    OptM,
    OptV,
    Step,
}

impl Role {
    fn parse(s: &str) -> Result<Role> {
        Ok(match s {
            "train" => Role::Train,
            "stat" => Role::Stat,
            "opt_m" => Role::OptM,
            "opt_v" => Role::OptV,
            "step" => Role::Step,
            other => bail!("unknown state role {other:?}"),
        })
    }
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub id: String,
    pub dataset: String,
    pub batch: usize,
    pub eval_batch: usize,
    pub config: ModelConfig,
    /// indices[l][a][j] = fan-in positions.
    pub indices: Vec<Vec<Vec<Vec<usize>>>>,
    /// monomials[l][m] = index multiset.
    pub monomials: Vec<Vec<Vec<usize>>>,
    pub state: Vec<StateSpec>,
    /// Initial state tensors (flattened), in `state` order.
    pub init: Vec<Vec<f32>>,
    pub train_hlo: PathBuf,
    pub eval_hlo: PathBuf,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let j = Json::parse_file(path)?;
        let dir = path.parent().unwrap_or(Path::new(".")).to_path_buf();
        let cfg_j = j.field("config")?;
        let config = ModelConfig {
            name: cfg_j.field("name")?.as_str()?.to_string(),
            widths: cfg_j.field("widths")?.usize_vec()?,
            beta: cfg_j
                .field("beta")?
                .usize_vec()?
                .into_iter()
                .map(|b| b as u32)
                .collect(),
            fan: cfg_j.field("fan")?.usize_vec()?,
            degree: cfg_j.field("degree")?.as_usize()? as u32,
            a_factor: cfg_j.field("a_factor")?.as_usize()?,
            n_classes: cfg_j.field("n_classes")?.as_usize()?,
            seed: cfg_j.field("seed")?.as_i64()? as u64,
        };
        config.validate().context("manifest config invalid")?;

        let indices = j
            .field("indices")?
            .as_arr()?
            .iter()
            .map(|layer| {
                layer
                    .as_arr()?
                    .iter()
                    .map(|sub| sub.as_arr()?.iter().map(|n| n.usize_vec()).collect())
                    .collect()
            })
            .collect::<Result<Vec<Vec<Vec<Vec<usize>>>>>>()?;

        let monomials = j
            .field("monomials")?
            .as_arr()?
            .iter()
            .map(|layer| layer.as_arr()?.iter().map(|m| m.usize_vec()).collect())
            .collect::<Result<Vec<Vec<Vec<usize>>>>>()?;

        let state = j
            .field("state")?
            .as_arr()?
            .iter()
            .map(|s| {
                Ok(StateSpec {
                    name: s.field("name")?.as_str()?.to_string(),
                    shape: s.field("shape")?.usize_vec()?,
                    role: Role::parse(s.field("role")?.as_str()?)?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let init = j
            .field("init")?
            .as_arr()?
            .iter()
            .map(|v| v.f32_vec())
            .collect::<Result<Vec<_>>>()?;
        if init.len() != state.len() {
            bail!("init count {} != state count {}", init.len(), state.len());
        }
        for (spec, vals) in state.iter().zip(&init) {
            let want: usize = spec.shape.iter().product();
            if want != vals.len() {
                bail!("{}: init length {} != shape product {want}", spec.name, vals.len());
            }
        }

        let arts = j.field("artifacts")?;
        Ok(Manifest {
            id: j.field("id")?.as_str()?.to_string(),
            dataset: j.field("dataset")?.as_str()?.to_string(),
            batch: j.field("batch")?.as_usize()?,
            eval_batch: j.field("eval_batch")?.as_usize()?,
            config,
            indices,
            monomials,
            state,
            init,
            train_hlo: dir.join(arts.field("train")?.as_str()?),
            eval_hlo: dir.join(arts.field("eval")?.as_str()?),
            dir,
        })
    }

    /// Look up a state tensor index by name.
    pub fn state_index(&self, name: &str) -> Result<usize> {
        self.state
            .iter()
            .position(|s| s.name == name)
            .ok_or_else(|| anyhow!("state tensor {name:?} not in manifest"))
    }

    /// Assemble the hardware-functional `Network` from a flat state vector
    /// (either `self.init` or trained values pulled back from PJRT buffers).
    pub fn network_from_state(&self, state: &[Vec<f32>]) -> Result<Network> {
        if state.len() != self.state.len() {
            bail!("state length {} != manifest {}", state.len(), self.state.len());
        }
        let cfg = &self.config;
        let mut layers = Vec::new();
        for (l, (_, n_out)) in cfg.layer_dims().into_iter().enumerate() {
            let m = monomial_count(cfg.fan[l], cfg.degree);
            let a = cfg.a_factor;
            let wflat = &state[self.state_index(&format!("l{l}.w"))?];
            if wflat.len() != a * n_out * m {
                bail!("l{l}.w: {} != {}", wflat.len(), a * n_out * m);
            }
            let w: Vec<Vec<Vec<f32>>> = (0..a)
                .map(|ai| {
                    (0..n_out)
                        .map(|j| {
                            let base = (ai * n_out + j) * m;
                            wflat[base..base + m].to_vec()
                        })
                        .collect()
                })
                .collect();
            let scalar = |name: &str| -> Result<f32> {
                let v = &state[self.state_index(name)?];
                Ok(v[0])
            };
            let vector = |name: &str| -> Result<Vec<f32>> {
                Ok(state[self.state_index(name)?].clone())
            };
            layers.push(LayerParams {
                indices: self.indices[l].clone(),
                w,
                s_pre: scalar(&format!("l{l}.s_pre"))?,
                s_act: scalar(&format!("l{l}.s_act"))?,
                bn_g: vector(&format!("l{l}.bn_g"))?,
                bn_b: vector(&format!("l{l}.bn_b"))?,
                bn_m: vector(&format!("l{l}.bn_m"))?,
                bn_v: vector(&format!("l{l}.bn_v"))?,
            });
        }
        let net = Network { cfg: cfg.clone(), layers, monomials: self.monomials.clone() };
        net.validate()?;
        Ok(net)
    }
}

/// Find every manifest under a directory (sorted by id).
pub fn discover(dir: &Path) -> Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    for entry in std::fs::read_dir(dir).with_context(|| format!("reading {}", dir.display()))? {
        let p = entry?.path();
        if p.file_name().and_then(|n| n.to_str()).is_some_and(|n| n.ends_with(".meta.json")) {
            out.push(p);
        }
    }
    out.sort();
    Ok(out)
}

/// Load the manifest for an artifact id, e.g. "jsc-m-lite-d1-a2".
pub fn load_id(dir: &Path, id: &str) -> Result<Manifest> {
    Manifest::load(&dir.join(format!("{id}.meta.json")))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The quickstart artifacts are produced by `make artifacts`; skip when
    /// absent so `cargo test` works on a fresh checkout.
    fn quickstart() -> Option<Manifest> {
        let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/jsc-m-lite-d1-a2.meta.json");
        p.exists().then(|| Manifest::load(&p).unwrap())
    }

    #[test]
    fn manifest_roundtrip() {
        let Some(m) = quickstart() else { return };
        assert_eq!(m.config.widths, vec![16, 64, 32, 5]);
        assert_eq!(m.config.a_factor, 2);
        let net = m.network_from_state(&m.init).unwrap();
        let x: Vec<f32> = (0..16).map(|i| i as f32 / 16.0).collect();
        assert_eq!(net.forward(&x).len(), 5);
    }

    #[test]
    fn monomials_match_rust_enumeration() {
        let Some(m) = quickstart() else { return };
        for l in 0..m.config.n_layers() {
            let ours = crate::nn::poly::monomial_index_lists(m.config.fan[l], m.config.degree);
            assert_eq!(ours, m.monomials[l], "layer {l}: python/rust monomial order differs");
        }
    }
}
