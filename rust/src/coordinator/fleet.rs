//! Replica fleet: the data-parallel serving front-end (ARCHITECTURE.md §9).
//!
//! Everything below the coordinator parallelizes *one* batch (bitslice
//! lanes) or *one* sample (sharding); this module adds the third axis —
//! data-parallel over **independent requests**.  A compiled
//! [`FrozenModel`](crate::coordinator::FrozenModel) is shared by N
//! in-process worker *replicas* (the plan and bitslice engines are
//! immutable and lock-free, so replicas run truly concurrently; a sharded
//! engine serializes on its internal call lock and is shared, not
//! duplicated), fronted by an **admission queue with deadline-aware
//! adaptive batch forming**:
//!
//! - arrivals are packed toward the active bitslice lane width (the word a
//!   single op-stream walk retires, 64–512 lanes), and a batch dispatches
//!   the moment the word fills;
//! - a partially filled word dispatches when the *oldest* queued request's
//!   deadline budget ([`FleetConfig::batch_deadline`]) expires — latency is
//!   bounded by the deadline, not by traffic;
//! - formed batches go to the **least-loaded live replica** (fewest
//!   in-flight batches, capped at [`REPLICA_PIPELINE`] so one slow replica
//!   cannot hoard work);
//! - the queue is bounded ([`FleetConfig::queue_depth`]): admission beyond
//!   the bound fails fast ([`FleetError::QueueFull`] — backpressure), and
//!   requests that age past [`FleetConfig::shed_after`] while queued are
//!   **shed** with [`FleetError::Shed`] instead of stalling the line;
//! - a replica that panics mid-batch is marked dead and its batch is
//!   re-dispatched through the queue to the survivors (or shed if it has
//!   aged out); the fleet keeps serving on the remaining replicas.
//!
//! The batch former itself ([`BatchFormer`]) is a pure state machine driven
//! by explicit microsecond timestamps, so its dispatch/shed decisions are
//! unit-tested with a mock clock — no real sleeps, no timing-flaky
//! assertions.  Every admitted request is answered **exactly once**: with a
//! [`Response`], or with a clean [`FleetError`].

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use super::metrics::Metrics;
use super::{Backend, FrozenModel, Response};
use crate::sim::shard::lock_ignore_poison;
use crate::sim::EngineSelect;

/// Formed batches a replica may have queued + running before the former
/// stops feeding it (2 = one running, one on deck — enough to hide the
/// dispatch hop without letting a slow replica hoard the queue).
pub const REPLICA_PIPELINE: usize = 2;

/// Default shed budget as a multiple of the batch deadline when
/// [`FleetConfig::shed_after`] is `None`.
pub const DEFAULT_SHED_MULTIPLE: u32 = 16;

// ---------------------------------------------------------------------------
// Batch former: a pure, mock-clock-friendly state machine
// ---------------------------------------------------------------------------

/// Why a batch left the former.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchReason {
    /// The batch reached the target width (a full bitslice word).
    Fill,
    /// The oldest queued request's deadline budget expired.
    Deadline,
}

/// Static policy of a [`BatchFormer`].
#[derive(Debug, Clone, Copy)]
pub struct FormerPolicy {
    /// Pack target: batches never exceed this many requests (the active
    /// bitslice lane width in the fleet).
    pub target: usize,
    /// Oldest-request age at which a partial batch dispatches, µs.
    pub deadline_us: u64,
    /// Queued age at which a request is shed instead of served, µs.
    pub shed_after_us: u64,
    /// Admission bound: `admit` fails once this many requests are queued.
    pub depth: usize,
}

/// Deadline-aware adaptive batch former.  Generic over the queued payload
/// and driven by explicit `now_us` timestamps: the fleet feeds it real
/// (monotonic) time, tests feed it a mock clock.  All methods are O(1) or
/// O(batch); the former never blocks and never reads a clock itself.
pub struct BatchFormer<T> {
    policy: FormerPolicy,
    queue: VecDeque<(u64, T)>,
}

impl<T> BatchFormer<T> {
    /// New former; `target` and `depth` are clamped to ≥ 1.
    pub fn new(mut policy: FormerPolicy) -> BatchFormer<T> {
        policy.target = policy.target.max(1);
        policy.depth = policy.depth.max(1);
        BatchFormer { policy, queue: VecDeque::new() }
    }

    /// The active policy.
    pub fn policy(&self) -> &FormerPolicy {
        &self.policy
    }

    /// Queued request count.
    pub fn len(&self) -> usize {
        self.queue.len()
    }

    /// Whether the queue is empty.
    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Admit one request at `now_us`.  `Err(item)` when the queue is at
    /// [`FormerPolicy::depth`] — the caller turns that into a backpressure
    /// error, the former never buffers beyond its bound.
    pub fn admit(&mut self, item: T, now_us: u64) -> Result<(), T> {
        if self.queue.len() >= self.policy.depth {
            return Err(item);
        }
        self.queue.push_back((now_us, item));
        Ok(())
    }

    /// Re-queue items at the *front* (replica-death re-dispatch): admit
    /// stamps are preserved so age keeps accruing toward the shed bound,
    /// and the depth bound is deliberately not enforced — these requests
    /// were already admitted once and must not be silently dropped.
    pub fn requeue_front(&mut self, items: Vec<(u64, T)>) {
        for it in items.into_iter().rev() {
            self.queue.push_front(it);
        }
    }

    /// Remove and return every request whose queued age reached
    /// [`FormerPolicy::shed_after_us`] at `now_us` (paired with its admit
    /// stamp).  Called before forming, so a shed request can never ride
    /// along in a dispatched batch.
    pub fn shed_expired(&mut self, now_us: u64) -> Vec<(u64, T)> {
        let shed_after = self.policy.shed_after_us;
        let mut out = Vec::new();
        // Admit stamps are not monotonic after `requeue_front`, so scan —
        // the queue is bounded by `depth` + one in-flight batch.
        let mut keep = VecDeque::with_capacity(self.queue.len());
        for (adm, item) in self.queue.drain(..) {
            if now_us.saturating_sub(adm) >= shed_after {
                out.push((adm, item));
            } else {
                keep.push_back((adm, item));
            }
        }
        self.queue = keep;
        out
    }

    /// Form the next batch at `now_us`, if the dispatch condition holds:
    /// the word is full ([`DispatchReason::Fill`], takes precedence in the
    /// fill-vs-deadline race — a full word is never split), or the oldest
    /// queued request's deadline expired ([`DispatchReason::Deadline`] —
    /// ships the partial word).  `None` = keep packing.
    pub fn form(&mut self, now_us: u64) -> Option<(Vec<(u64, T)>, DispatchReason)> {
        if self.queue.len() >= self.policy.target {
            let batch = self.queue.drain(..self.policy.target).collect();
            return Some((batch, DispatchReason::Fill));
        }
        let oldest = self.oldest_admit_us()?;
        if now_us.saturating_sub(oldest) >= self.policy.deadline_us {
            let batch = self.queue.drain(..).collect();
            return Some((batch, DispatchReason::Deadline));
        }
        None
    }

    /// Drain everything unconditionally (shutdown / no-live-replica shed).
    pub fn drain_all(&mut self) -> Vec<(u64, T)> {
        self.queue.drain(..).collect()
    }

    /// Earliest admit stamp in the queue (`None` when empty).  Not simply
    /// the front element: `requeue_front` can break FIFO age order.
    fn oldest_admit_us(&self) -> Option<u64> {
        self.queue.iter().map(|(adm, _)| *adm).min()
    }

    /// Timestamp at which [`BatchFormer::form`] next fires on its own
    /// (oldest admit + deadline; `None` when empty or already full — a full
    /// word dispatches immediately, there is nothing to wait for).
    pub fn next_deadline_us(&self) -> Option<u64> {
        if self.queue.len() >= self.policy.target {
            return Some(0);
        }
        self.oldest_admit_us().map(|adm| adm + self.policy.deadline_us)
    }

    /// Timestamp at which [`BatchFormer::shed_expired`] next sheds
    /// (`None` when empty).  The former loop sleeps toward this when no
    /// replica can accept a dispatch, so aging out never needs a poll spin.
    pub fn next_shed_us(&self) -> Option<u64> {
        self.oldest_admit_us().map(|adm| adm + self.policy.shed_after_us)
    }
}

// ---------------------------------------------------------------------------
// Fleet
// ---------------------------------------------------------------------------

/// How the serving fleet is laid out and how it forms batches.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// In-process worker replicas sharing the compiled model
    /// (`serve --replicas`).
    pub replicas: usize,
    /// Pack target per formed batch; `0` = the model's active bitslice
    /// lane width (the word one op-stream walk retires).
    pub target_batch: usize,
    /// Oldest-request deadline budget before a partial batch dispatches
    /// (`serve --batch-deadline-us`).
    pub batch_deadline: Duration,
    /// Bounded admission queue depth (`serve --queue-depth`); admission
    /// beyond it fails fast with [`FleetError::QueueFull`].
    pub queue_depth: usize,
    /// Queued age at which a request is shed ([`FleetError::Shed`]);
    /// `None` = [`DEFAULT_SHED_MULTIPLE`] × the batch deadline, floored at
    /// 1 ms.
    pub shed_after: Option<Duration>,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            replicas: 2,
            target_batch: 0,
            batch_deadline: Duration::from_micros(200),
            queue_depth: 4096,
            shed_after: None,
        }
    }
}

impl FleetConfig {
    /// The resolved shed budget (see [`FleetConfig::shed_after`]).
    pub fn shed_budget(&self) -> Duration {
        self.shed_after.unwrap_or_else(|| {
            (self.batch_deadline * DEFAULT_SHED_MULTIPLE).max(Duration::from_millis(1))
        })
    }
}

/// Why a fleet request was not answered with a [`Response`].  Every
/// admitted request gets exactly one outcome: `Ok(Response)` or one of
/// these, never silence.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FleetError {
    /// The admission queue was at `--queue-depth` (backpressure): the
    /// request was **not** admitted; retry later.
    QueueFull {
        /// The configured bound that was hit.
        depth: usize,
    },
    /// The request aged past the shed budget while queued (overload) and
    /// was dropped cleanly instead of stalling younger traffic.
    Shed {
        /// How long it had been queued when shed, µs.
        waited_us: u64,
    },
    /// A replica failed the batch (backend error, or no live replica
    /// remains to re-dispatch to).
    Replica(String),
    /// The fleet was shut down while the request was queued.
    Stopped,
}

impl std::fmt::Display for FleetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FleetError::QueueFull { depth } => {
                write!(f, "fleet queue full (depth {depth}, backpressure)")
            }
            FleetError::Shed { waited_us } => {
                write!(f, "request shed after {waited_us}µs queued (overload)")
            }
            FleetError::Replica(msg) => write!(f, "replica failure: {msg}"),
            FleetError::Stopped => write!(f, "fleet stopped"),
        }
    }
}

impl std::error::Error for FleetError {}

/// One queued request: feature row + wall-clock admit instant (for the
/// client-visible latency) + the response slot.
struct FleetRequest {
    features: Vec<f32>,
    enqueued: Instant,
    resp: SyncSender<Result<Response, FleetError>>,
}

/// A formed batch on its way to a replica: `(admit_us, request)` pairs.
type Formed = Vec<(u64, FleetRequest)>;

/// State under the fleet's one lock: the batch former plus the stop flag.
struct FormerState {
    former: BatchFormer<FleetRequest>,
    stopping: bool,
}

/// Shared fleet state: the locked former, per-replica liveness/in-flight
/// tracking, fault injection hooks, and the metrics sink.
struct FleetShared {
    state: Mutex<FormerState>,
    /// Signaled on admit, replica completion, replica death and stop.
    cv: Condvar,
    start: Instant,
    live: Vec<AtomicBool>,
    /// Formed batches queued + running per replica (the least-loaded key).
    inflight: Vec<AtomicU64>,
    /// Test hook: make replica i panic on its next batch (exercises the
    /// real catch_unwind → re-dispatch path).
    panic_next: Vec<AtomicBool>,
    metrics: Arc<Metrics>,
}

impl FleetShared {
    /// Monotonic µs since fleet start — the former's clock.
    fn now_us(&self) -> u64 {
        self.start.elapsed().as_micros() as u64
    }

    fn lock(&self) -> MutexGuard<'_, FormerState> {
        lock_ignore_poison(&self.state)
    }

    /// Least-loaded live replica with pipeline room, `None` when every
    /// live replica is saturated (or none is live).
    fn pick_replica(&self) -> Option<usize> {
        let mut best: Option<(usize, u64)> = None;
        for (i, (live, inflight)) in self.live.iter().zip(&self.inflight).enumerate() {
            if !live.load(Ordering::Relaxed) {
                continue;
            }
            let load = inflight.load(Ordering::Relaxed);
            if load >= REPLICA_PIPELINE as u64 {
                continue;
            }
            if best.map_or(true, |(_, b)| load < b) {
                best = Some((i, load));
            }
        }
        best.map(|(i, _)| i)
    }

    fn live_replicas(&self) -> usize {
        self.live.iter().filter(|l| l.load(Ordering::Relaxed)).count()
    }
}

/// Handle for submitting requests to the fleet (clonable across client
/// threads).
#[derive(Clone)]
pub struct FleetClient {
    shared: Arc<FleetShared>,
    n_classes: usize,
}

impl FleetClient {
    /// Submit one request and block for its outcome.  The typed error
    /// distinguishes backpressure ([`FleetError::QueueFull`] — the request
    /// was never admitted) from shed/replica/stop outcomes of admitted
    /// requests.
    pub fn infer(&self, features: Vec<f32>) -> Result<Response, FleetError> {
        let (tx, rx) = sync_channel(1);
        let m = &self.shared.metrics;
        m.requests.fetch_add(1, Ordering::Relaxed);
        let req = FleetRequest { features, enqueued: Instant::now(), resp: tx };
        {
            let mut st = self.shared.lock();
            if st.stopping {
                return Err(FleetError::Stopped);
            }
            let now = self.shared.now_us();
            let depth = st.former.policy().depth;
            if st.former.admit(req, now).is_err() {
                m.queue_rejects.fetch_add(1, Ordering::Relaxed);
                return Err(FleetError::QueueFull { depth });
            }
            m.note_queue_depth(st.former.len() as u64);
        }
        self.shared.cv.notify_all();
        match rx.recv() {
            Ok(outcome) => outcome,
            // The fleet never drops a responder without answering; a closed
            // channel can only mean teardown raced the request.
            Err(_) => Err(FleetError::Stopped),
        }
    }

    /// Output classes of the served model (1 = binary threshold on the
    /// single logit).
    pub fn n_classes(&self) -> usize {
        self.n_classes
    }
}

/// The running replica fleet: a batch-former thread and N replica worker
/// threads around one shared [`FrozenModel`].
pub struct Fleet {
    /// Serving metrics (same sink the single-server path uses, plus the
    /// `fleet_*` group — see `metrics::snapshot()`).
    pub metrics: Arc<Metrics>,
    shared: Arc<FleetShared>,
    former: Option<std::thread::JoinHandle<()>>,
    replicas: Vec<std::thread::JoinHandle<()>>,
    client: FleetClient,
}

impl Fleet {
    /// Start `cfg.replicas` worker replicas over `model` plus the batch
    /// former.  Replicas share the compiled engines (plan/bitslice are
    /// immutable; a sharded engine serializes on its internal call lock),
    /// so memory cost is per-scratch, not per-model-copy.
    pub fn start(
        model: Arc<FrozenModel>,
        workers: usize,
        select: EngineSelect,
        n_classes: usize,
        cfg: FleetConfig,
    ) -> Fleet {
        let n = cfg.replicas.max(1);
        let target = if cfg.target_batch == 0 {
            model.bitslice.lanes()
        } else {
            cfg.target_batch
        };
        let policy = FormerPolicy {
            target,
            deadline_us: cfg.batch_deadline.as_micros() as u64,
            shed_after_us: cfg.shed_budget().as_micros() as u64,
            depth: cfg.queue_depth,
        };
        let metrics = Arc::new(Metrics::new());
        metrics.set_fleet(n as u64, target as u64, policy.deadline_us);
        let shared = Arc::new(FleetShared {
            state: Mutex::new(FormerState { former: BatchFormer::new(policy), stopping: false }),
            cv: Condvar::new(),
            start: Instant::now(),
            live: (0..n).map(|_| AtomicBool::new(true)).collect(),
            inflight: (0..n).map(|_| AtomicU64::new(0)).collect(),
            panic_next: (0..n).map(|_| AtomicBool::new(false)).collect(),
            metrics: metrics.clone(),
        });
        let mut txs = Vec::with_capacity(n);
        let mut replicas = Vec::with_capacity(n);
        for i in 0..n {
            let (tx, rx) = sync_channel::<Formed>(REPLICA_PIPELINE);
            txs.push(tx);
            let sh = shared.clone();
            let m = model.clone();
            let handle = std::thread::Builder::new()
                .name(format!("polylut-replica-{i}"))
                .spawn(move || replica_loop(i, sh, rx, m, workers, select, n_classes))
                .expect("spawn replica");
            replicas.push(handle);
        }
        let sh = shared.clone();
        let former = std::thread::Builder::new()
            .name("polylut-former".into())
            .spawn(move || former_loop(sh, txs))
            .expect("spawn batch former");
        let client = FleetClient { shared: shared.clone(), n_classes };
        Fleet { metrics, shared, former: Some(former), replicas, client }
    }

    /// A clonable request handle.
    pub fn client(&self) -> FleetClient {
        self.client.clone()
    }

    /// Replicas still alive (a panicked replica is dead until restart).
    pub fn live_replicas(&self) -> usize {
        self.shared.live_replicas()
    }

    /// Test hook: make replica `i` panic on its next batch, exercising the
    /// mark-dead + re-dispatch path end to end (mirrors the sharded
    /// engines' `inject_fault`).
    pub fn inject_replica_panic(&self, i: usize) {
        self.shared.panic_next[i].store(true, Ordering::SeqCst);
    }

    /// Stop the fleet: queued requests get [`FleetError::Stopped`],
    /// in-flight batches finish normally, every thread is joined.
    pub fn shutdown(mut self) {
        {
            let mut st = self.shared.lock();
            st.stopping = true;
        }
        self.shared.cv.notify_all();
        if let Some(h) = self.former.take() {
            let _ = h.join();
        }
        for h in self.replicas.drain(..) {
            let _ = h.join();
        }
    }
}

/// The former thread: shed → dispatch → sleep-until-next-event loop.  All
/// decisions go through the pure [`BatchFormer`]; this loop only supplies
/// real time, replica placement and the condvar plumbing.
fn former_loop(shared: Arc<FleetShared>, replica_tx: Vec<SyncSender<Formed>>) {
    let metrics = shared.metrics.clone();
    let mut st = shared.lock();
    loop {
        if st.stopping {
            for (_, req) in st.former.drain_all() {
                let _ = req.resp.send(Err(FleetError::Stopped));
            }
            // Dropping `replica_tx` (this frame) closes every replica's
            // receive loop once its in-flight batches are done.
            return;
        }
        let now = shared.now_us();
        // Shed ladder rung 1: age-out.  Runs before forming so a shed
        // request can never ride along in a dispatched batch.
        for (adm, req) in st.former.shed_expired(now) {
            metrics.fleet_shed.fetch_add(1, Ordering::Relaxed);
            let _ = req
                .resp
                .send(Err(FleetError::Shed { waited_us: now.saturating_sub(adm) }));
        }
        // Shed ladder rung 2: no live replica can ever serve the queue.
        if shared.live_replicas() == 0 && !st.former.is_empty() {
            for (_, req) in st.former.drain_all() {
                metrics.fleet_shed.fetch_add(1, Ordering::Relaxed);
                let _ = req.resp.send(Err(FleetError::Replica(
                    "no live replicas (all workers failed)".into(),
                )));
            }
        }
        // Dispatch while a replica has pipeline room and a batch is due.
        let mut progressed = false;
        while let Some(i) = shared.pick_replica() {
            let Some((batch, reason)) = st.former.form(shared.now_us()) else {
                break;
            };
            metrics.record_formed_batch(batch.len() as u64, reason);
            shared.inflight[i].fetch_add(1, Ordering::Relaxed);
            match replica_tx[i].try_send(batch) {
                Ok(()) => progressed = true,
                Err(TrySendError::Full(batch)) => {
                    // Can't happen while inflight < REPLICA_PIPELINE gates
                    // dispatch, but stay safe: put the batch back and stop
                    // dispatching this pass.
                    shared.inflight[i].fetch_sub(1, Ordering::Relaxed);
                    st.former.requeue_front(batch);
                    break;
                }
                Err(TrySendError::Disconnected(batch)) => {
                    // Replica thread is gone (panicked out): mark dead and
                    // re-dispatch through the queue.
                    shared.live[i].store(false, Ordering::Relaxed);
                    shared.inflight[i].fetch_sub(1, Ordering::Relaxed);
                    metrics
                        .fleet_redispatched
                        .fetch_add(batch.len() as u64, Ordering::Relaxed);
                    st.former.requeue_front(batch);
                }
            }
        }
        if progressed {
            continue;
        }
        // Nothing dispatchable: sleep until the next former event — the
        // oldest request's dispatch deadline when a replica could take a
        // batch, its shed deadline when all replicas are saturated — or a
        // notify (admit / replica completion / stop).  The 20 ms cap is a
        // liveness backstop, not a poll loop: every state change notifies.
        let now = shared.now_us();
        let wake = if shared.pick_replica().is_some() {
            st.former.next_deadline_us()
        } else {
            st.former.next_shed_us()
        };
        if wake.is_some_and(|t| t <= now) {
            continue;
        }
        let timeout = match wake {
            Some(t) => Duration::from_micros(t - now).min(Duration::from_millis(20)),
            None => Duration::from_millis(20),
        };
        let (guard, _) = shared
            .cv
            .wait_timeout(st, timeout)
            .unwrap_or_else(|p| p.into_inner());
        st = guard;
    }
}

/// One replica worker: builds its backend view over the shared model and
/// serves formed batches until its channel closes (fleet shutdown) or it
/// dies (panic → batch re-dispatched, replica marked dead).
fn replica_loop(
    i: usize,
    shared: Arc<FleetShared>,
    rx: Receiver<Formed>,
    model: Arc<FrozenModel>,
    workers: usize,
    select: EngineSelect,
    n_classes: usize,
) {
    let metrics = shared.metrics.clone();
    let backend = Backend::Lut { model, workers, select };
    // After a panic the thread stays parked on `rx` as a dead husk instead
    // of dropping the receiver: a dispatch that raced the death (the former
    // read `live` just before the store) lands here and is re-queued
    // instead of vanishing with a closed channel — the exactly-once
    // guarantee must not depend on the former winning that race.  The husk
    // exits when the former drops the senders at shutdown.
    let mut dead = false;
    while let Ok(batch) = rx.recv() {
        if dead {
            requeue(&shared, &metrics, i, batch);
            continue;
        }
        let xs: Vec<Vec<f32>> = batch.iter().map(|(_, r)| r.features.clone()).collect();
        let inject = shared.panic_next[i].swap(false, Ordering::SeqCst);
        let result = catch_unwind(AssertUnwindSafe(|| {
            assert!(!inject, "injected replica fault (test)");
            backend.infer(&xs)
        }));
        match result {
            Ok(Ok(all_logits)) => {
                metrics.batches.fetch_add(1, Ordering::Relaxed);
                metrics.batch_samples.fetch_add(xs.len() as u64, Ordering::Relaxed);
                if let Some(engine) = backend.route(xs.len()) {
                    metrics.record_engine(engine);
                }
                for ((_, req), logits) in batch.into_iter().zip(all_logits) {
                    let pred = super::predict(n_classes, &logits);
                    let latency = req.enqueued.elapsed();
                    metrics.record_latency(latency.as_secs_f64() * 1e6);
                    metrics.responses.fetch_add(1, Ordering::Relaxed);
                    let _ = req.resp.send(Ok(Response { logits, pred, latency }));
                }
            }
            Ok(Err(e)) => {
                // Backend-level error (e.g. a faulted sharded engine before
                // its internal degrade kicks in): the batch fails cleanly,
                // the replica lives on.
                metrics.fleet_batch_errors.fetch_add(1, Ordering::Relaxed);
                let msg = format!("replica {i}: {e:#}");
                for (_, req) in batch {
                    let _ = req.resp.send(Err(FleetError::Replica(msg.clone())));
                }
            }
            Err(_) => {
                // Replica death: mark dead and push the batch back through
                // the former (admit stamps preserved — survivors serve it,
                // or the shed ladder ages it out).
                dead = true;
                shared.live[i].store(false, Ordering::Relaxed);
                metrics.fleet_replica_faults.fetch_add(1, Ordering::Relaxed);
                log::error!("[fleet] replica {i} died mid-batch; re-dispatching");
                requeue(&shared, &metrics, i, batch);
                continue;
            }
        }
        shared.inflight[i].fetch_sub(1, Ordering::Relaxed);
        shared.cv.notify_all();
    }
}

/// Push a batch a dead replica cannot serve back through the former and
/// release the replica's in-flight slot.
fn requeue(shared: &FleetShared, metrics: &Metrics, i: usize, batch: Formed) {
    metrics.fleet_redispatched.fetch_add(batch.len() as u64, Ordering::Relaxed);
    {
        let mut st = shared.lock();
        st.former.requeue_front(batch);
    }
    shared.inflight[i].fetch_sub(1, Ordering::Relaxed);
    shared.cv.notify_all();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::config;
    use crate::nn::network::Network;
    use crate::util::rng::Rng;

    // -- BatchFormer: deterministic mock-clock unit tests (no sleeps) -----

    fn former(target: usize, deadline: u64, shed: u64, depth: usize) -> BatchFormer<usize> {
        BatchFormer::new(FormerPolicy {
            target,
            deadline_us: deadline,
            shed_after_us: shed,
            depth,
        })
    }

    #[test]
    fn former_dispatches_on_word_fill() {
        let mut f = former(4, 1_000, 10_000, 64);
        for i in 0..3 {
            f.admit(i, 100 + i as u64).unwrap();
            assert!(f.form(100 + i as u64).is_none(), "below target and deadline");
        }
        f.admit(3, 103).unwrap();
        let (batch, reason) = f.form(103).expect("word filled");
        assert_eq!(reason, DispatchReason::Fill);
        assert_eq!(batch.iter().map(|(_, v)| *v).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        assert!(f.is_empty());
    }

    #[test]
    fn former_dispatches_partial_word_on_deadline() {
        let mut f = former(64, 1_000, 10_000, 64);
        f.admit(7, 500).unwrap();
        f.admit(8, 900).unwrap();
        assert!(f.form(1_499).is_none(), "oldest is 999µs old — under deadline");
        let (batch, reason) = f.form(1_500).expect("oldest hit its deadline");
        assert_eq!(reason, DispatchReason::Deadline);
        assert_eq!(batch.len(), 2, "partial word ships whole");
        assert!(f.next_deadline_us().is_none(), "queue drained");
    }

    #[test]
    fn former_fill_wins_the_fill_vs_deadline_race() {
        // At the same tick the oldest request's deadline expires AND the
        // word fills: the full word dispatches as Fill (never split, never
        // double-dispatched).
        let mut f = former(3, 1_000, 10_000, 64);
        f.admit(0, 0).unwrap();
        f.admit(1, 400).unwrap();
        f.admit(2, 1_000).unwrap();
        let (batch, reason) = f.form(1_000).expect("both conditions hold");
        assert_eq!(reason, DispatchReason::Fill, "fill takes precedence");
        assert_eq!(batch.len(), 3);
        assert!(f.form(1_000).is_none(), "exactly one dispatch");
    }

    #[test]
    fn former_never_exceeds_target_width() {
        let mut f = former(4, 0, 10_000, 64);
        for i in 0..11 {
            f.admit(i, 50).unwrap();
        }
        // deadline_us = 0: everything is instantly dispatchable, but each
        // formed batch still caps at the target word width.
        let mut sizes = Vec::new();
        while let Some((batch, _)) = f.form(50) {
            sizes.push(batch.len());
        }
        assert_eq!(sizes, vec![4, 4, 3]);
    }

    #[test]
    fn former_sheds_only_aged_requests() {
        let mut f = former(64, 1_000, 5_000, 64);
        f.admit(1, 0).unwrap();
        f.admit(2, 4_000).unwrap();
        assert!(f.shed_expired(4_999).is_empty(), "oldest is 4999µs — under bound");
        let shed = f.shed_expired(5_000);
        assert_eq!(shed.len(), 1, "only the aged request sheds");
        assert_eq!(shed[0].1, 1);
        assert_eq!(f.len(), 1, "young request stays queued");
        assert_eq!(f.next_shed_us(), Some(9_000));
    }

    #[test]
    fn former_backpressure_at_depth() {
        let mut f = former(64, 1_000, 5_000, 2);
        f.admit(1, 0).unwrap();
        f.admit(2, 0).unwrap();
        assert_eq!(f.admit(3, 0), Err(3), "depth bound rejects, payload returned");
        // requeue_front bypasses the depth bound (re-dispatch must not drop)
        f.requeue_front(vec![(0, 9)]);
        assert_eq!(f.len(), 3);
        let (batch, _) = f.form(1_000).expect("deadline dispatch");
        assert_eq!(batch[0].1, 9, "requeued item is at the front");
    }

    #[test]
    fn former_next_deadline_tracks_oldest() {
        let mut f = former(8, 1_000, 5_000, 64);
        assert!(f.next_deadline_us().is_none());
        f.admit(1, 300).unwrap();
        f.admit(2, 200).unwrap(); // requeue scenarios make stamps non-monotonic
        assert_eq!(f.next_deadline_us(), Some(1_300), "oldest unqueued stamp + deadline");
        for i in 0..6 {
            f.admit(10 + i, 400).unwrap();
        }
        assert_eq!(f.next_deadline_us(), Some(0), "full word: dispatch now");
    }

    // -- Fleet integration (real threads, timing-robust assertions) -------

    fn fleet_model() -> Arc<FrozenModel> {
        let cfg = config::uniform("fleet", &[8, 6, 3], 2, 2, 3, 3, 3, 1, 2, 3);
        let net = Network::random(&cfg, &mut Rng::new(11));
        Arc::new(FrozenModel::from_network(net, 1))
    }

    fn start(model: &Arc<FrozenModel>, cfg: FleetConfig) -> Fleet {
        Fleet::start(model.clone(), 1, EngineSelect::plan_only(), 3, cfg)
    }

    #[test]
    fn fleet_roundtrip_bit_exact_across_replicas() {
        let model = fleet_model();
        let fleet = start(
            &model,
            FleetConfig {
                replicas: 3,
                target_batch: 4,
                batch_deadline: Duration::from_micros(500),
                queue_depth: 256,
                shed_after: Some(Duration::from_secs(10)),
            },
        );
        let sim = model.sim();
        std::thread::scope(|scope| {
            for c in 0..4 {
                let client = fleet.client();
                let sim = &sim;
                scope.spawn(move || {
                    let mut rng = Rng::new(40 + c);
                    for _ in 0..25 {
                        let x: Vec<f32> = (0..8).map(|_| rng.f32()).collect();
                        let resp = client.infer(x.clone()).expect("fleet serves");
                        assert_eq!(resp.logits, sim.forward(&x), "bit-exact via fleet");
                        assert!(resp.pred < 3);
                    }
                });
            }
        });
        assert_eq!(fleet.metrics.responses.load(Ordering::Relaxed), 100);
        assert!(fleet.metrics.fleet_formed.load(Ordering::Relaxed) > 0);
        assert!(fleet.metrics.max_formed_batch.load(Ordering::Relaxed) <= 4);
        assert!(fleet.metrics.queue_depth_hwm.load(Ordering::Relaxed) >= 1);
        let snap = fleet.metrics.snapshot();
        assert!(snap.contains("fleet_replicas=3"), "{snap}");
        assert!(snap.contains("queue_hwm="), "{snap}");
        assert!(snap.contains("shed=0"), "{snap}");
        fleet.shutdown();
    }

    #[test]
    fn fleet_sheds_aged_requests_cleanly() {
        // shed_after = 0: every admitted request ages out at the former's
        // first pass — deterministic shed path, no timing assertions.
        let model = fleet_model();
        let fleet = start(
            &model,
            FleetConfig {
                replicas: 2,
                target_batch: 64,
                batch_deadline: Duration::from_secs(5),
                queue_depth: 64,
                shed_after: Some(Duration::ZERO),
            },
        );
        let client = fleet.client();
        for _ in 0..10 {
            match client.infer(vec![0.1; 8]) {
                Err(FleetError::Shed { .. }) => {}
                other => panic!("expected shed, got {other:?}"),
            }
        }
        assert_eq!(fleet.metrics.fleet_shed.load(Ordering::Relaxed), 10);
        assert_eq!(fleet.metrics.responses.load(Ordering::Relaxed), 0);
        assert!(fleet.metrics.snapshot().contains("shed=10"));
        fleet.shutdown();
    }

    #[test]
    fn fleet_backpressure_rejects_at_queue_depth() {
        let model = fleet_model();
        let fleet = start(
            &model,
            FleetConfig {
                replicas: 1,
                target_batch: 64,
                // Generous deadline: the probe request below must land
                // while the first is still queued.
                batch_deadline: Duration::from_millis(500),
                queue_depth: 1,
                shed_after: Some(Duration::from_secs(10)),
            },
        );
        let client = fleet.client();
        let model2 = model.clone();
        let parked = std::thread::spawn({
            let client = fleet.client();
            move || {
                let x = vec![0.5; 8];
                let resp = client.infer(x.clone()).expect("eventually served");
                assert_eq!(resp.logits, model2.sim().forward(&x));
            }
        });
        // Wait until the parked request occupies the queue slot (the HWM
        // only moves on admission, and no other client has run yet).
        while fleet.metrics.queue_depth_hwm.load(Ordering::Relaxed) == 0 {
            std::thread::yield_now();
        }
        // One probe: with depth 1 held by the parked request, admission
        // must fail fast.  (The parked request leaving the queue first
        // requires its 500 ms deadline to have fired — in that unlikely
        // case the probe is served; the deterministic queue-full unit
        // coverage lives in former_backpressure_at_depth.)
        match client.infer(vec![0.25; 8]) {
            Err(FleetError::QueueFull { depth }) => {
                assert_eq!(depth, 1);
                assert!(fleet.metrics.queue_rejects.load(Ordering::Relaxed) >= 1);
            }
            Ok(_) => {}
            other => panic!("unexpected outcome {other:?}"),
        }
        parked.join().expect("parked client");
        fleet.shutdown();
    }

    #[test]
    fn replica_death_degrades_to_survivors() {
        let model = fleet_model();
        let fleet = start(
            &model,
            FleetConfig {
                replicas: 2,
                target_batch: 2,
                batch_deadline: Duration::from_micros(200),
                queue_depth: 256,
                shed_after: Some(Duration::from_secs(10)),
            },
        );
        // Kill replica 0 on its next batch: the batch re-dispatches to the
        // survivor, the client still gets a bit-exact answer.
        fleet.inject_replica_panic(0);
        let sim = model.sim();
        let client = fleet.client();
        let mut rng = Rng::new(5);
        for _ in 0..40 {
            let x: Vec<f32> = (0..8).map(|_| rng.f32()).collect();
            let resp = client.infer(x.clone()).expect("fleet survives a replica death");
            assert_eq!(resp.logits, sim.forward(&x), "bit-exact after fault");
        }
        assert_eq!(fleet.metrics.fleet_replica_faults.load(Ordering::Relaxed), 1);
        assert!(fleet.metrics.fleet_redispatched.load(Ordering::Relaxed) >= 1);
        assert_eq!(fleet.live_replicas(), 1, "one replica dead, one serving");
        assert_eq!(fleet.metrics.responses.load(Ordering::Relaxed), 40);
        let snap = fleet.metrics.snapshot();
        assert!(snap.contains("replica_faults=1"), "{snap}");
        fleet.shutdown();
    }

    #[test]
    fn all_replicas_dead_sheds_with_clean_error() {
        let model = fleet_model();
        let fleet = start(
            &model,
            FleetConfig {
                replicas: 1,
                target_batch: 1,
                batch_deadline: Duration::ZERO,
                queue_depth: 64,
                shed_after: Some(Duration::from_secs(10)),
            },
        );
        fleet.inject_replica_panic(0);
        let client = fleet.client();
        // First request kills the lone replica; it is re-dispatched, finds
        // no live replica, and must come back as a clean error — then every
        // later request fails fast the same way.  Nothing hangs.
        for i in 0..5 {
            match client.infer(vec![0.3; 8]) {
                Err(FleetError::Replica(msg)) => {
                    assert!(msg.contains("no live replicas"), "request {i}: {msg}")
                }
                other => panic!("request {i}: expected replica error, got {other:?}"),
            }
        }
        assert_eq!(fleet.live_replicas(), 0);
        assert_eq!(fleet.metrics.fleet_replica_faults.load(Ordering::Relaxed), 1);
        fleet.shutdown();
    }

    #[test]
    fn shutdown_answers_queued_requests() {
        let model = fleet_model();
        let fleet = start(
            &model,
            FleetConfig {
                replicas: 1,
                target_batch: 64,
                batch_deadline: Duration::from_secs(30),
                queue_depth: 8,
                shed_after: Some(Duration::from_secs(60)),
            },
        );
        let client = fleet.client();
        let waiter = std::thread::spawn(move || client.infer(vec![0.7; 8]));
        // Give the request time to be admitted, then stop the fleet: the
        // queued request must get a Stopped outcome, not silence.
        while fleet.metrics.requests.load(Ordering::Relaxed) == 0 {
            std::thread::yield_now();
        }
        std::thread::sleep(Duration::from_millis(5));
        fleet.shutdown();
        match waiter.join().expect("client thread") {
            Err(FleetError::Stopped) => {}
            Ok(_) => {} // raced the deadline dispatch — also a valid answer
            other => panic!("expected Stopped or served, got {other:?}"),
        }
    }
}
