//! Server metrics: lock-free counters + a fixed-bucket latency histogram
//! (µs resolution, exponential buckets) good enough for p50/p95/p99 without
//! allocation on the hot path.  Engine-routing counters record which LUT
//! engine served each batch; when intra-sample sharding is active the
//! sharded engines' cumulative per-shard occupancy/handoff-wait counters
//! are mirrored here after every sharded batch (see `snapshot()` and the
//! README's metrics glossary).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::fleet::DispatchReason;
use crate::sim::{LutEngine, ShardStats, WireHostStats, WireStats};

const BUCKETS: usize = 40;

/// Formed-batch-size histogram buckets: bucket i counts batches of size
/// `[2^i, 2^(i+1))`, with the last bucket open-ended (≥ 1024 — wider than
/// any bitslice word, so the fleet's lane-width targets always resolve).
const BATCH_BUCKETS: usize = 11;

#[derive(Debug)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    pub batches: AtomicU64,
    pub batch_samples: AtomicU64,
    pub queue_rejects: AtomicU64,
    /// Batches the LUT backend served through the evaluation plan vs the
    /// bitsliced wide-lane engine (64–512 samples per op-stream walk; see
    /// `simd=`/`lanes=` below) vs the intra-sample sharded engines (all
    /// zero under the PJRT backend).
    pub plan_batches: AtomicU64,
    pub bitslice_batches: AtomicU64,
    pub sharded_batches: AtomicU64,
    /// Latest cumulative per-shard counters from the sharded engines
    /// (empty when sharding is off): `cells` = layer-cells executed
    /// (occupancy proxy), `waits` = handoff-wait episodes.
    shard: Mutex<Vec<ShardStats>>,
    /// Latest cumulative wire-link counters (frames/bytes/wait-ns and
    /// connect retries, summed over links) — mirrored after every sharded
    /// batch when any shard is remote; all zero otherwise.
    pub wire_frames: AtomicU64,
    pub wire_bytes: AtomicU64,
    pub wire_wait_ns: AtomicU64,
    pub wire_reconnects: AtomicU64,
    /// High-water mark of concurrently in-flight *epochs* through the
    /// Wire-v3 ring (bounded by `--wire-window`; > 1 proves end-to-end
    /// epoch pipelining is actually overlapping samples).
    pub wire_inflight_epochs: AtomicU64,
    /// High-water mark of in-flight needs *flights* on any session (one
    /// flight per layer boundary with cross-shard reads, so an epoch of
    /// an L-layer model is up to L flights).
    pub wire_inflight_flights: AtomicU64,
    /// Successful reconnect-and-resume handshakes over all links.
    pub wire_resumes: AtomicU64,
    /// Frames re-shipped by checkpointed resumes vs frames the
    /// applied-boundary high-water marks let them skip.
    pub wire_resume_replayed: AtomicU64,
    pub wire_resume_skipped: AtomicU64,
    /// Link incidents whose reconnect budget was exhausted (each one
    /// faulted its engine and degraded routing to the in-process plan).
    pub wire_retry_exhausted: AtomicU64,
    /// Latest per-host link rollup (one entry per multiplexed TCP
    /// connection): sessions riding the link, frames/bytes carried,
    /// reconnect and resume counts — so a saturated or flapping host is
    /// visible without log diving.  Empty with no wire placement.
    wire_hosts: Mutex<Vec<WireHostStats>>,
    /// Whether a wire placement is active (controls snapshot rendering).
    wire_active: AtomicU64,
    /// Resolved shard-worker spin budget in µs (`u64::MAX` = not recorded:
    /// sharding off).
    shard_spin_us: AtomicU64,
    /// Violations found by the `sim::verify` pass over the served
    /// artifacts (`u64::MAX` = no verify pass recorded).
    verify_violations: AtomicU64,
    /// Ordinal of the detected [`crate::simd::SimdLevel`] the bitslice
    /// engine compiled against (`u64::MAX` = not recorded: no LUT backend).
    simd_level: AtomicU64,
    /// Active bitslice lane width — samples retired per op-stream walk
    /// (`u64::MAX` = not recorded).
    simd_lanes: AtomicU64,
    /// Ordinal of the served model's [`crate::lut::OptLevel`]
    /// (`u64::MAX` = not recorded — hides the netlist-opt group).
    netlist_opt_level: AtomicU64,
    /// Total word-ops of the mapped netlists before/after the
    /// `lut::opt` pipeline (what the engines execute per sample word).
    netlist_ops_before: AtomicU64,
    netlist_ops_after: AtomicU64,
    /// Replica-fleet group (`coordinator::fleet`): worker replica count
    /// (`u64::MAX` = no fleet — hides the whole group in `snapshot()`).
    fleet_replicas: AtomicU64,
    /// Batch former's pack target (the active bitslice lane width unless
    /// `--max-batch` overrides it) and deadline budget, for the snapshot.
    fleet_target: AtomicU64,
    fleet_deadline_us: AtomicU64,
    /// Batches formed, split by dispatch reason: word filled to the target
    /// vs the oldest request's deadline budget expiring on a partial word.
    pub fleet_formed: AtomicU64,
    pub fleet_fill: AtomicU64,
    pub fleet_deadline: AtomicU64,
    /// Requests shed (aged past the shed budget, or orphaned when no live
    /// replica remains) — each got a clean error, never a stall.
    pub fleet_shed: AtomicU64,
    /// Replica worker threads that died (panicked) mid-stream.
    pub fleet_replica_faults: AtomicU64,
    /// Requests re-queued through the former after their replica died.
    pub fleet_redispatched: AtomicU64,
    /// Batches that failed with a backend error on a live replica.
    pub fleet_batch_errors: AtomicU64,
    /// High-water mark of the admission queue depth (`--queue-depth` unit).
    pub queue_depth_hwm: AtomicU64,
    /// Largest batch the former ever dispatched (≤ the pack target — the
    /// fleet property test pins this bound).
    pub max_formed_batch: AtomicU64,
    /// Formed-batch-size histogram, power-of-two buckets (see
    /// [`BATCH_BUCKETS`]).
    batch_hist: [AtomicU64; BATCH_BUCKETS],
    hist: [AtomicU64; BUCKETS],
}

impl Default for Metrics {
    fn default() -> Metrics {
        Metrics {
            requests: AtomicU64::new(0),
            responses: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batch_samples: AtomicU64::new(0),
            queue_rejects: AtomicU64::new(0),
            plan_batches: AtomicU64::new(0),
            bitslice_batches: AtomicU64::new(0),
            sharded_batches: AtomicU64::new(0),
            shard: Mutex::new(Vec::new()),
            wire_frames: AtomicU64::new(0),
            wire_bytes: AtomicU64::new(0),
            wire_wait_ns: AtomicU64::new(0),
            wire_reconnects: AtomicU64::new(0),
            wire_inflight_epochs: AtomicU64::new(0),
            wire_inflight_flights: AtomicU64::new(0),
            wire_resumes: AtomicU64::new(0),
            wire_resume_replayed: AtomicU64::new(0),
            wire_resume_skipped: AtomicU64::new(0),
            wire_retry_exhausted: AtomicU64::new(0),
            wire_hosts: Mutex::new(Vec::new()),
            wire_active: AtomicU64::new(0),
            shard_spin_us: AtomicU64::new(u64::MAX),
            verify_violations: AtomicU64::new(u64::MAX),
            simd_level: AtomicU64::new(u64::MAX),
            simd_lanes: AtomicU64::new(u64::MAX),
            netlist_opt_level: AtomicU64::new(u64::MAX),
            netlist_ops_before: AtomicU64::new(0),
            netlist_ops_after: AtomicU64::new(0),
            fleet_replicas: AtomicU64::new(u64::MAX),
            fleet_target: AtomicU64::new(0),
            fleet_deadline_us: AtomicU64::new(0),
            fleet_formed: AtomicU64::new(0),
            fleet_fill: AtomicU64::new(0),
            fleet_deadline: AtomicU64::new(0),
            fleet_shed: AtomicU64::new(0),
            fleet_replica_faults: AtomicU64::new(0),
            fleet_redispatched: AtomicU64::new(0),
            fleet_batch_errors: AtomicU64::new(0),
            queue_depth_hwm: AtomicU64::new(0),
            max_formed_batch: AtomicU64::new(0),
            batch_hist: std::array::from_fn(|_| AtomicU64::new(0)),
            hist: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Bucket i covers [2^(i/2), 2^((i+1)/2)) µs approximately — two buckets
    /// per octave from 1 µs to ~1 s.
    fn bucket(us: f64) -> usize {
        if us <= 1.0 {
            return 0;
        }
        ((2.0 * us.log2()).floor() as usize).min(BUCKETS - 1)
    }

    fn bucket_upper_us(i: usize) -> f64 {
        2f64.powf((i + 1) as f64 / 2.0)
    }

    pub fn record_latency(&self, us: f64) {
        self.hist[Self::bucket(us)].fetch_add(1, Ordering::Relaxed);
    }

    /// Count one batch against the LUT engine that executed it.
    pub fn record_engine(&self, engine: LutEngine) {
        match engine {
            LutEngine::Plan => self.plan_batches.fetch_add(1, Ordering::Relaxed),
            LutEngine::Bitslice => self.bitslice_batches.fetch_add(1, Ordering::Relaxed),
            LutEngine::Sharded => self.sharded_batches.fetch_add(1, Ordering::Relaxed),
        };
    }

    /// Mirror the sharded engines' cumulative per-shard counters (called by
    /// the batcher after a sharded batch; values are monotonic, so the last
    /// write always reflects the engine's lifetime totals).
    pub fn record_shard_stats(&self, stats: &[ShardStats]) {
        let mut guard = crate::sim::shard::lock_ignore_poison(&self.shard);
        guard.clear();
        guard.extend_from_slice(stats);
    }

    /// Latest per-shard counters (empty when sharding is off).
    pub fn shard_stats(&self) -> Vec<ShardStats> {
        crate::sim::shard::lock_ignore_poison(&self.shard).clone()
    }

    /// Mirror the sharded engines' cumulative wire-link counters (called
    /// by the batcher after a sharded batch on a remote placement; values
    /// are monotonic, so the last write reflects lifetime totals).
    pub fn record_wire(&self, ws: &WireStats) {
        self.wire_frames.store(ws.frames, Ordering::Relaxed);
        self.wire_bytes.store(ws.bytes, Ordering::Relaxed);
        self.wire_wait_ns.store(ws.wait_ns, Ordering::Relaxed);
        self.wire_reconnects.store(ws.reconnects, Ordering::Relaxed);
        self.wire_inflight_epochs.store(ws.inflight_epochs, Ordering::Relaxed);
        self.wire_inflight_flights.store(ws.inflight_hwm, Ordering::Relaxed);
        self.wire_resumes.store(ws.resumes, Ordering::Relaxed);
        self.wire_resume_replayed.store(ws.resume_replayed_frames, Ordering::Relaxed);
        self.wire_resume_skipped.store(ws.resume_skipped_frames, Ordering::Relaxed);
        self.wire_retry_exhausted.store(ws.retry_exhausted, Ordering::Relaxed);
        self.wire_active.store(1, Ordering::Relaxed);
    }

    /// Mirror the per-host link rollup (one entry per multiplexed TCP
    /// connection; called alongside [`Metrics::record_wire`]).
    pub fn record_wire_hosts(&self, hosts: &[WireHostStats]) {
        let mut guard = crate::sim::shard::lock_ignore_poison(&self.wire_hosts);
        guard.clear();
        guard.extend_from_slice(hosts);
    }

    /// Latest per-host link rollup (empty with no wire placement).
    pub fn wire_hosts(&self) -> Vec<WireHostStats> {
        crate::sim::shard::lock_ignore_poison(&self.wire_hosts).clone()
    }

    /// Record the resolved shard-worker epoch spin budget (µs) so the
    /// snapshot shows which value `POLYLUT_SHARD_SPIN_US` / config chose.
    pub fn set_shard_spin_us(&self, spin_us: u64) {
        self.shard_spin_us.store(spin_us, Ordering::Relaxed);
    }

    /// Record the outcome of a `sim::verify` pass over the served
    /// artifacts (total violation count; 0 = verified clean).
    pub fn record_verify(&self, violations: u64) {
        self.verify_violations.store(violations, Ordering::Relaxed);
    }

    /// Record the SIMD dispatch level and lane width the served bitslice
    /// engine compiled against, so the snapshot shows which kernel path
    /// (`--lanes` / `POLYLUT_LANES` / auto-detect) is live.
    pub fn set_simd(&self, level: crate::simd::SimdLevel, lanes: u64) {
        self.simd_level.store(level.ordinal(), Ordering::Relaxed);
        self.simd_lanes.store(lanes, Ordering::Relaxed);
    }

    /// Record the served model's netlist-optimization outcome: resolved
    /// level plus total word-ops before/after the `lut::opt` pipeline.
    pub fn set_netlist_opt(&self, level: crate::lut::OptLevel, before: u64, after: u64) {
        self.netlist_opt_level.store(level.ordinal(), Ordering::Relaxed);
        self.netlist_ops_before.store(before, Ordering::Relaxed);
        self.netlist_ops_after.store(after, Ordering::Relaxed);
    }

    /// Activate the fleet metrics group (replica count, pack target and
    /// deadline budget make the snapshot self-describing).
    pub fn set_fleet(&self, replicas: u64, target: u64, deadline_us: u64) {
        self.fleet_replicas.store(replicas, Ordering::Relaxed);
        self.fleet_target.store(target, Ordering::Relaxed);
        self.fleet_deadline_us.store(deadline_us, Ordering::Relaxed);
    }

    /// Count one formed batch: total + dispatch-reason split, the
    /// power-of-two size histogram, and the max-size watermark.
    pub fn record_formed_batch(&self, size: u64, reason: DispatchReason) {
        self.fleet_formed.fetch_add(1, Ordering::Relaxed);
        match reason {
            DispatchReason::Fill => self.fleet_fill.fetch_add(1, Ordering::Relaxed),
            DispatchReason::Deadline => self.fleet_deadline.fetch_add(1, Ordering::Relaxed),
        };
        let bucket = (63 - size.max(1).leading_zeros() as usize).min(BATCH_BUCKETS - 1);
        self.batch_hist[bucket].fetch_add(1, Ordering::Relaxed);
        self.max_formed_batch.fetch_max(size, Ordering::Relaxed);
    }

    /// Raise the admission-queue depth high-water mark.
    pub fn note_queue_depth(&self, depth: u64) {
        self.queue_depth_hwm.fetch_max(depth, Ordering::Relaxed);
    }

    /// Formed-batch-size histogram counts: entry i counts batches of size
    /// `[2^i, 2^(i+1))` (last entry open-ended).
    pub fn formed_batch_hist(&self) -> Vec<u64> {
        self.batch_hist.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    /// Approximate quantile from the histogram (upper bucket bound).
    pub fn latency_quantile_us(&self, q: f64) -> f64 {
        let counts: Vec<u64> =
            self.hist.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let target = (q * total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return Self::bucket_upper_us(i);
            }
        }
        Self::bucket_upper_us(BUCKETS - 1)
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batch_samples.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    pub fn snapshot(&self) -> String {
        let mut s = format!(
            "requests={} responses={} batches={} (plan={} bitslice={} sharded={}) mean_batch={:.1} rejects={} p50={:.0}µs p95={:.0}µs p99={:.0}µs",
            self.requests.load(Ordering::Relaxed),
            self.responses.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.plan_batches.load(Ordering::Relaxed),
            self.bitslice_batches.load(Ordering::Relaxed),
            self.sharded_batches.load(Ordering::Relaxed),
            self.mean_batch_size(),
            self.queue_rejects.load(Ordering::Relaxed),
            self.latency_quantile_us(0.5),
            self.latency_quantile_us(0.95),
            self.latency_quantile_us(0.99),
        );
        let shard = crate::sim::shard::lock_ignore_poison(&self.shard);
        if !shard.is_empty() {
            let cells: Vec<String> = shard.iter().map(|st| st.cells.to_string()).collect();
            let waits: Vec<String> = shard.iter().map(|st| st.waits.to_string()).collect();
            s.push_str(&format!(
                " shard_cells=[{}] shard_waits=[{}]",
                cells.join(","),
                waits.join(",")
            ));
        }
        let spin = self.shard_spin_us.load(Ordering::Relaxed);
        if spin != u64::MAX {
            s.push_str(&format!(" shard_spin_us={spin}"));
        }
        let verify = self.verify_violations.load(Ordering::Relaxed);
        if verify != u64::MAX {
            s.push_str(&format!(" verify_violations={verify}"));
        }
        let level = self.simd_level.load(Ordering::Relaxed);
        if level != u64::MAX {
            let name = crate::simd::SimdLevel::from_ordinal(level)
                .map(|l| l.as_str())
                .unwrap_or("unknown");
            s.push_str(&format!(
                " simd={name} lanes={}",
                self.simd_lanes.load(Ordering::Relaxed)
            ));
        }
        let opt = self.netlist_opt_level.load(Ordering::Relaxed);
        if opt != u64::MAX {
            let name = crate::lut::OptLevel::from_ordinal(opt)
                .map(|l| l.to_string())
                .unwrap_or_else(|| "unknown".into());
            s.push_str(&format!(
                " netlist_opt={name} netlist_ops_before={} netlist_ops_after={}",
                self.netlist_ops_before.load(Ordering::Relaxed),
                self.netlist_ops_after.load(Ordering::Relaxed),
            ));
        }
        let replicas = self.fleet_replicas.load(Ordering::Relaxed);
        if replicas != u64::MAX {
            let hist = self.formed_batch_hist();
            let top = hist.iter().rposition(|&c| c != 0).map_or(0, |i| i + 1);
            let hist_s: Vec<String> =
                hist[..top].iter().map(|c| c.to_string()).collect();
            s.push_str(&format!(
                " fleet_replicas={replicas} target_batch={} batch_deadline_us={} \
                 formed={} (fill={} deadline={}) max_formed={} batch_hist=[{}] \
                 queue_hwm={} shed={} replica_faults={} redispatched={} batch_errors={}",
                self.fleet_target.load(Ordering::Relaxed),
                self.fleet_deadline_us.load(Ordering::Relaxed),
                self.fleet_formed.load(Ordering::Relaxed),
                self.fleet_fill.load(Ordering::Relaxed),
                self.fleet_deadline.load(Ordering::Relaxed),
                self.max_formed_batch.load(Ordering::Relaxed),
                hist_s.join(","),
                self.queue_depth_hwm.load(Ordering::Relaxed),
                self.fleet_shed.load(Ordering::Relaxed),
                self.fleet_replica_faults.load(Ordering::Relaxed),
                self.fleet_redispatched.load(Ordering::Relaxed),
                self.fleet_batch_errors.load(Ordering::Relaxed),
            ));
        }
        if self.wire_active.load(Ordering::Relaxed) != 0 {
            s.push_str(&format!(
                " wire_frames={} wire_bytes={} wire_wait_ns={} wire_reconnects={} \
                 wire_inflight_epochs={} wire_inflight_flights={} wire_resumes={} \
                 wire_resume_replayed={} wire_resume_skipped={} wire_retry_exhausted={}",
                self.wire_frames.load(Ordering::Relaxed),
                self.wire_bytes.load(Ordering::Relaxed),
                self.wire_wait_ns.load(Ordering::Relaxed),
                self.wire_reconnects.load(Ordering::Relaxed),
                self.wire_inflight_epochs.load(Ordering::Relaxed),
                self.wire_inflight_flights.load(Ordering::Relaxed),
                self.wire_resumes.load(Ordering::Relaxed),
                self.wire_resume_replayed.load(Ordering::Relaxed),
                self.wire_resume_skipped.load(Ordering::Relaxed),
                self.wire_retry_exhausted.load(Ordering::Relaxed),
            ));
            let hosts = crate::sim::shard::lock_ignore_poison(&self.wire_hosts);
            if !hosts.is_empty() {
                let sessions: Vec<String> =
                    hosts.iter().map(|h| h.sessions.to_string()).collect();
                let rollup: Vec<String> = hosts
                    .iter()
                    .map(|h| {
                        format!(
                            "{}(sessions={},frames={},bytes={},reconnects={},resumes={})",
                            h.addr, h.sessions, h.frames, h.bytes, h.reconnects, h.resumes
                        )
                    })
                    .collect();
                s.push_str(&format!(
                    " wire_links={} wire_sessions_per_link=[{}] wire_hosts=[{}]",
                    hosts.len(),
                    sessions.join(","),
                    rollup.join(";"),
                ));
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_ordered() {
        let m = Metrics::new();
        for us in [10.0, 20.0, 30.0, 1000.0, 50.0, 40.0, 45.0, 55.0] {
            m.record_latency(us);
        }
        let p50 = m.latency_quantile_us(0.5);
        let p95 = m.latency_quantile_us(0.95);
        assert!(p50 <= p95);
        assert!(p95 >= 1000.0 * 0.7, "p95 {p95} should see the 1ms outlier bucket");
    }

    #[test]
    fn engine_routing_counters() {
        let m = Metrics::new();
        m.record_engine(LutEngine::Plan);
        m.record_engine(LutEngine::Bitslice);
        m.record_engine(LutEngine::Bitslice);
        m.record_engine(LutEngine::Sharded);
        assert_eq!(m.plan_batches.load(Ordering::Relaxed), 1);
        assert_eq!(m.bitslice_batches.load(Ordering::Relaxed), 2);
        assert_eq!(m.sharded_batches.load(Ordering::Relaxed), 1);
        assert!(m.snapshot().contains("plan=1 bitslice=2 sharded=1"));
    }

    #[test]
    fn shard_stats_surface_in_snapshot() {
        let m = Metrics::new();
        assert!(!m.snapshot().contains("shard_cells"), "hidden when sharding is off");
        m.record_shard_stats(&[
            ShardStats { cells: 10, waits: 2 },
            ShardStats { cells: 9, waits: 0 },
        ]);
        let snap = m.snapshot();
        assert!(snap.contains("shard_cells=[10,9]"), "{snap}");
        assert!(snap.contains("shard_waits=[2,0]"), "{snap}");
        assert_eq!(m.shard_stats().len(), 2);
    }

    #[test]
    fn wire_and_spin_counters_surface_in_snapshot() {
        let m = Metrics::new();
        let snap = m.snapshot();
        assert!(!snap.contains("wire_frames"), "hidden without a wire placement");
        assert!(!snap.contains("shard_spin_us"), "hidden until recorded");
        m.set_shard_spin_us(0);
        m.record_wire(&WireStats {
            frames: 12,
            bytes: 3400,
            wait_ns: 560,
            reconnects: 1,
            resumes: 2,
            retry_exhausted: 0,
            inflight_hwm: 4,
            handle_clones: 1,
            inflight_epochs: 3,
            resume_replayed_frames: 5,
            resume_skipped_frames: 7,
        });
        let snap = m.snapshot();
        assert!(snap.contains("shard_spin_us=0"), "{snap}");
        assert!(
            snap.contains("wire_frames=12 wire_bytes=3400 wire_wait_ns=560 wire_reconnects=1"),
            "{snap}"
        );
        assert!(
            snap.contains(
                "wire_inflight_epochs=3 wire_inflight_flights=4 wire_resumes=2 \
                 wire_resume_replayed=5 wire_resume_skipped=7 wire_retry_exhausted=0"
            ),
            "{snap}"
        );
        assert!(!snap.contains("wire_links"), "hidden until hosts recorded: {snap}");
        m.record_wire_hosts(&[
            WireHostStats {
                addr: "10.0.0.1:4000".into(),
                sessions: 4,
                frames: 8,
                bytes: 2200,
                reconnects: 1,
                resumes: 2,
            },
            WireHostStats {
                addr: "10.0.0.2:4000".into(),
                sessions: 2,
                frames: 4,
                bytes: 1200,
                reconnects: 0,
                resumes: 0,
            },
        ]);
        let snap = m.snapshot();
        assert!(snap.contains("wire_links=2 wire_sessions_per_link=[4,2]"), "{snap}");
        assert!(
            snap.contains(
                "wire_hosts=[10.0.0.1:4000(sessions=4,frames=8,bytes=2200,reconnects=1,\
                 resumes=2);10.0.0.2:4000(sessions=2,frames=4,bytes=1200,reconnects=0,\
                 resumes=0)]"
            ),
            "{snap}"
        );
        assert_eq!(m.wire_hosts().len(), 2);
    }

    #[test]
    fn verify_counter_surfaces_in_snapshot() {
        let m = Metrics::new();
        assert!(!m.snapshot().contains("verify_violations"), "hidden until recorded");
        m.record_verify(0);
        assert!(m.snapshot().contains("verify_violations=0"));
        m.record_verify(3);
        assert!(m.snapshot().contains("verify_violations=3"));
    }

    #[test]
    fn simd_fields_surface_in_snapshot() {
        let m = Metrics::new();
        let snap = m.snapshot();
        assert!(!snap.contains("simd="), "hidden until a LUT backend records");
        assert!(!snap.contains("lanes="), "{snap}");
        m.set_simd(crate::simd::SimdLevel::Avx2, 256);
        let snap = m.snapshot();
        assert!(snap.contains("simd=avx2 lanes=256"), "{snap}");
        m.set_simd(crate::simd::SimdLevel::Scalar, 64);
        assert!(m.snapshot().contains("simd=scalar lanes=64"));
    }

    #[test]
    fn netlist_opt_group_surfaces_in_snapshot() {
        let m = Metrics::new();
        assert!(!m.snapshot().contains("netlist_opt"), "hidden until recorded");
        m.set_netlist_opt(crate::lut::OptLevel::FoldDc, 120, 90);
        let snap = m.snapshot();
        assert!(
            snap.contains("netlist_opt=fold+dc netlist_ops_before=120 netlist_ops_after=90"),
            "{snap}"
        );
        m.set_netlist_opt(crate::lut::OptLevel::None, 120, 120);
        assert!(m.snapshot().contains("netlist_opt=none"));
    }

    #[test]
    fn fleet_group_hidden_until_activated() {
        let m = Metrics::new();
        // Recording alone must not leak the group into the snapshot — only
        // `set_fleet` (called by `Fleet::start`) activates it.
        m.record_formed_batch(4, DispatchReason::Fill);
        m.note_queue_depth(7);
        let snap = m.snapshot();
        assert!(!snap.contains("fleet_replicas"), "{snap}");
        m.set_fleet(2, 64, 200);
        let snap = m.snapshot();
        assert!(snap.contains("fleet_replicas=2 target_batch=64 batch_deadline_us=200"), "{snap}");
        assert!(snap.contains("queue_hwm=7"), "{snap}");
    }

    #[test]
    fn formed_batch_histogram_buckets_by_power_of_two() {
        let m = Metrics::new();
        m.set_fleet(1, 64, 100);
        for size in [1, 1, 2, 3, 4, 7, 8, 64, 5000] {
            m.record_formed_batch(size, DispatchReason::Fill);
        }
        m.record_formed_batch(5, DispatchReason::Deadline);
        let hist = m.formed_batch_hist();
        assert_eq!(hist[0], 2, "size 1");
        assert_eq!(hist[1], 2, "sizes 2..4");
        assert_eq!(hist[2], 3, "sizes 4..8 (incl. the deadline batch)");
        assert_eq!(hist[3], 1, "size 8");
        assert_eq!(hist[6], 1, "size 64");
        assert_eq!(hist[10], 1, "open-ended top bucket");
        assert_eq!(m.fleet_formed.load(Ordering::Relaxed), 10);
        assert_eq!(m.fleet_fill.load(Ordering::Relaxed), 9);
        assert_eq!(m.fleet_deadline.load(Ordering::Relaxed), 1);
        assert_eq!(m.max_formed_batch.load(Ordering::Relaxed), 5000);
        let snap = m.snapshot();
        assert!(snap.contains("formed=10 (fill=9 deadline=1) max_formed=5000"), "{snap}");
        assert!(snap.contains("batch_hist=[2,2,3,1,0,0,1,0,0,0,1]"), "{snap}");
    }

    #[test]
    fn queue_depth_hwm_is_monotonic() {
        let m = Metrics::new();
        m.note_queue_depth(3);
        m.note_queue_depth(9);
        m.note_queue_depth(5);
        assert_eq!(m.queue_depth_hwm.load(Ordering::Relaxed), 9);
    }

    #[test]
    fn bucket_monotonic() {
        let mut last = 0;
        for us in [0.5, 1.5, 3.0, 10.0, 100.0, 1e4, 1e6, 1e9] {
            let b = Metrics::bucket(us);
            assert!(b >= last);
            last = b;
        }
        assert_eq!(Metrics::bucket(1e9), 39, "clamps to last bucket");
    }
}
