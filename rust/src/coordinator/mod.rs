//! L3 inference coordinator — request router, dynamic batcher and worker
//! (vLLM-router-style, scaled to the paper's edge-inference setting).
//!
//! The paper's deployment story is ultra-low-latency edge classification
//! (NIDS at line rate, LHC triggers); this module provides the serving
//! runtime around the frozen model: clients submit feature vectors, a
//! dynamic batcher groups them under a time window, and a backend executes
//! either
//! - the **LUT netlist simulator** (deployed semantics, per-sample, scales
//!   across cores — the software stand-in for the FPGA), or
//! - the **PJRT executable** (the Pallas-lowered JAX eval graph, batched —
//!   Python is *not* involved; the HLO was lowered at build time).
//!
//! Everything is std-thread based (tokio is not vendored).
//!
//! For sustained concurrent traffic the single batcher thread is the
//! bottleneck; [`fleet`] adds the data-parallel axis — N worker replicas
//! over one shared [`FrozenModel`] behind a deadline-aware batch former
//! (`serve --replicas`).

pub mod fleet;
pub mod metrics;

use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};


use crate::lut::tables::NetworkTables;
use crate::lut::{OptLevel, OptReport};
use crate::meta::{Manifest, Role};
use crate::nn::network::Network;
use crate::runtime::{f32_literal, to_f32_vec, Engine, Executable};
use crate::sim::bitslice::BitsliceNet;
use crate::sim::lutsim::LutSim;
use crate::sim::plan::EvalPlan;
use crate::sim::shard::ShardedModel;
use crate::sim::wire::{
    parse_shard_hosts, ShardPlacement, WireConfig, WireHostStats, WireStats,
};
use crate::sim::{
    EngineSelect, LutEngine, ShardStats, DEFAULT_WIRE_RETRIES, DEFAULT_WIRE_WINDOW,
};
use crate::util::cli::Args;
use fleet::{Fleet, FleetConfig, FleetError};
use metrics::Metrics;

/// A frozen deployable model: trained network + its compiled tables + the
/// precompiled LUT execution engines — the per-sample evaluation plan
/// (latency), the bitsliced netlist engine compiled at the widest
/// supported lane width (throughput; override with `serve --lanes` /
/// `POLYLUT_LANES`), and optionally the intra-sample sharded engines
/// (`shards > 1`, always canonical 64-bit planes on the handoff).
/// `Backend::Lut` picks between them per batch.
pub struct FrozenModel {
    pub net: Network,
    /// Compiled truth tables *after* the netlist-optimization table passes
    /// (don't-care rewrite / pruning at the resolved [`OptLevel`]) — what
    /// every engine executes.
    pub tables: NetworkTables,
    pub plan: EvalPlan,
    pub bitslice: BitsliceNet,
    /// Compiled when the model was built with `shards > 1`; required for
    /// backends whose `EngineSelect::shards > 1`.
    pub sharded: Option<ShardedModel>,
    /// What the netlist-optimization pipeline did (per-layer op deltas,
    /// pruning agreement) — surfaced by `polylut serve`/`verify` metrics.
    pub opt_report: OptReport,
}

impl FrozenModel {
    pub fn from_network(net: Network, workers: usize) -> FrozenModel {
        Self::from_network_sharded(net, workers, 1)
    }

    /// Freeze a network with intra-sample sharding compiled in: `shards > 1`
    /// additionally builds the cache-aware-reordered [`ShardedModel`]
    /// (spawning `2·shards` persistent worker threads).
    pub fn from_network_sharded(net: Network, workers: usize, shards: usize) -> FrozenModel {
        Self::from_network_placed(net, workers, shards, &[], None)
            .expect("all-local freeze cannot fail")
    }

    /// Freeze with a shard **placement map**: shards whose entry is
    /// `Some("host:port")` are driven on remote `polylut shard-worker`
    /// processes over the wire handoff (the `serve --shard-hosts` path);
    /// `None`/unlisted shards stay on local threads.  `spin_us` overrides
    /// the worker epoch spin budget (see `sim::resolve_spin_us`).  Fails
    /// cleanly when a remote link cannot be established or a worker's
    /// model fingerprint disagrees.
    pub fn from_network_placed(
        net: Network,
        workers: usize,
        shards: usize,
        placement: &ShardPlacement,
        spin_us: Option<u64>,
    ) -> Result<FrozenModel> {
        Self::from_network_placed_wire(
            net,
            workers,
            shards,
            placement,
            spin_us,
            WireConfig::default(),
            None,
            None,
        )
    }

    /// [`FrozenModel::from_network_placed`] with explicit wire knobs (the
    /// `serve --wire-window` / `--wire-retries` path): in-flight window per
    /// link and the reconnect-and-resume retry budget.  `lanes` forces the
    /// bitslice engine's lane width (the `serve --lanes` path, strict);
    /// `None` resolves `POLYLUT_LANES` and falls back to the widest
    /// detected width ([`crate::simd::resolve`]).  `opt` forces the
    /// netlist-optimization level (the `--netlist-opt` path); `None`
    /// resolves `POLYLUT_NETLIST_OPT` and falls back to `fold+dc`.
    #[allow(clippy::too_many_arguments)]
    pub fn from_network_placed_wire(
        net: Network,
        workers: usize,
        shards: usize,
        placement: &ShardPlacement,
        spin_us: Option<u64>,
        wire: WireConfig,
        lanes: Option<usize>,
        opt: Option<OptLevel>,
    ) -> Result<FrozenModel> {
        let lane_plan = crate::simd::resolve(lanes)?;
        let level = OptLevel::resolve(opt);
        if opt.is_some() {
            // Publish the explicit choice so lazily-resolving consumers
            // (the sharded kernels' fold gate, the wire fingerprints)
            // agree with this compile.
            std::env::set_var(crate::lut::opt::OPT_ENV, level.to_string());
        }
        let tables = crate::lut::tables::compile_network(&net, workers);
        // The netlist-optimization pipeline sits between table generation
        // and engine compilation: every engine below compiles the rewritten
        // tables, and the two netlist consumers (bitslice here, the sharded
        // kernels inside `ShardedModel`) execute folded netlists.
        let opt = crate::lut::optimize(&net, tables, level, workers);
        let plan = EvalPlan::compile(&net, &opt.tables);
        let bitslice =
            BitsliceNet::from_mapped(&net, &opt.tables, &opt.mapped).with_lane_plan(lane_plan);
        if crate::sim::verify::gate_enabled() {
            let mut report = crate::sim::verify::verify_frozen(&plan, &bitslice);
            if let Some(base) = &opt.baseline {
                report.section(
                    "netlist-opt equivalence",
                    crate::sim::verify::verify_opt(base, &opt.mapped, 0x0707_F01D),
                );
            }
            report.gate()?;
        }
        let sharded = if shards > 1 {
            Some(ShardedModel::compile_placed_wire(
                &net, &opt.tables, shards, workers, placement, spin_us, wire,
            )?)
        } else {
            None
        };
        Ok(FrozenModel {
            net,
            tables: opt.tables,
            plan,
            bitslice,
            sharded,
            opt_report: opt.report,
        })
    }

    pub fn sim(&self) -> LutSim<'_> {
        // Share the already-compiled plan — sim() is called in per-request
        // assertion loops and must not recompile the tables each time.
        LutSim::with_plan(&self.net, &self.tables, &self.plan)
    }
}

/// Backend specification — `Send`able across threads.  PJRT handles (Rc
/// internals in the xla crate) are NOT Send, so the actual `Backend` is
/// constructed *inside* the batcher thread from this spec.
pub enum BackendSpec {
    Lut { model: Arc<FrozenModel>, workers: usize, select: EngineSelect },
    Pjrt { man: Manifest, state: Vec<Vec<f32>> },
}

impl BackendSpec {
    pub fn lut(model: Arc<FrozenModel>, workers: usize) -> BackendSpec {
        // Crossover derives from the lane width the model actually compiled
        // (widest detected unless forced), not the host-widest default.
        let select = EngineSelect::auto_for_lanes(model.bitslice.lanes());
        BackendSpec::Lut { model, workers, select }
    }

    /// LUT backend with an explicit plan-vs-bitslice crossover policy.
    pub fn lut_with_select(
        model: Arc<FrozenModel>,
        workers: usize,
        select: EngineSelect,
    ) -> BackendSpec {
        BackendSpec::Lut { model, workers, select }
    }

    pub fn pjrt(man: Manifest, state: Vec<Vec<f32>>) -> BackendSpec {
        BackendSpec::Pjrt { man, state }
    }

    /// Build the runnable backend (call from the thread that will use it).
    pub fn build(self) -> Result<Backend> {
        match self {
            BackendSpec::Lut { model, workers, select } => {
                Ok(Backend::Lut { model, workers, select })
            }
            BackendSpec::Pjrt { man, state } => {
                let engine = Engine::cpu()?;
                Backend::pjrt(&engine, &man, &state)
            }
        }
    }
}

/// Inference backends.
pub enum Backend {
    /// Deployed-semantics LUT evaluation, parallel across the batch.
    /// `select` routes each batch to the evaluation plan (small /
    /// latency-sensitive) or the bitsliced engine at its compiled lane
    /// width (large; crossover scales with that width).
    Lut { model: Arc<FrozenModel>, workers: usize, select: EngineSelect },
    /// AOT-lowered JAX eval graph via PJRT (fixed batch, padded). Params
    /// stay resident as device buffers.
    Pjrt {
        engine: Engine,
        exe: Executable,
        params: Vec<xla::PjRtBuffer>,
        batch: usize,
        n_features: usize,
        n_out: usize,
    },
}

impl Backend {
    pub fn lut(model: Arc<FrozenModel>, workers: usize) -> Backend {
        let select = EngineSelect::auto_for_lanes(model.bitslice.lanes());
        Backend::Lut { model, workers, select }
    }

    /// Which LUT engine a batch of `batch_len` samples would run on
    /// (`None` for the PJRT backend).  `Sharded` is only returned when the
    /// model actually carries compiled sharded engines **and** they are
    /// healthy — a sticky engine fault (panicked shard, dead wire link)
    /// degrades routing to the in-process plan engine instead of failing
    /// every sub-crossover batch until restart.  (The batch that observed
    /// the fault still errors; every later batch is served.)
    pub fn route(&self, batch_len: usize) -> Option<LutEngine> {
        match self {
            Backend::Lut { model, select, .. } => Some(match select.pick(batch_len) {
                LutEngine::Sharded
                    if model.sharded.as_ref().map_or(true, |s| s.faulted()) =>
                {
                    LutEngine::Plan
                }
                engine => engine,
            }),
            Backend::Pjrt { .. } => None,
        }
    }

    /// Cumulative per-shard counters of the sharded engines (`None` when
    /// sharding is off or the backend is PJRT).
    pub fn shard_stats(&self) -> Option<Vec<ShardStats>> {
        match self {
            Backend::Lut { model, .. } => model.sharded.as_ref().map(|s| s.stats()),
            Backend::Pjrt { .. } => None,
        }
    }

    /// Cumulative wire-link counters of the sharded engines (`None` when
    /// sharding is off, every shard is local, or the backend is PJRT).
    pub fn wire_stats(&self) -> Option<WireStats> {
        match self {
            Backend::Lut { model, .. } => {
                model.sharded.as_ref().and_then(|s| s.wire_stats())
            }
            Backend::Pjrt { .. } => None,
        }
    }

    /// Per-host link rollup of the sharded engines (empty when sharding is
    /// off, every shard is local, or the backend is PJRT) — one entry per
    /// multiplexed TCP connection.
    pub fn wire_host_stats(&self) -> Vec<WireHostStats> {
        match self {
            Backend::Lut { model, .. } => model
                .sharded
                .as_ref()
                .map(|s| s.wire_host_stats())
                .unwrap_or_default(),
            Backend::Pjrt { .. } => Vec::new(),
        }
    }

    /// Build the PJRT backend from a manifest + trained state.
    pub fn pjrt(engine: &Engine, man: &Manifest, state: &[Vec<f32>]) -> Result<Backend> {
        let exe = engine.load_hlo(&man.eval_hlo)?;
        let n_params = man
            .state
            .iter()
            .filter(|s| matches!(s.role, Role::Train | Role::Stat))
            .count();
        let params: Result<Vec<xla::PjRtBuffer>> = man
            .state
            .iter()
            .zip(state)
            .take(n_params)
            .map(|(spec, vals)| {
                let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
                engine.to_buffer(&f32_literal(vals, &dims)?)
            })
            .collect();
        Ok(Backend::Pjrt {
            engine: engine.clone(),
            exe,
            params: params?,
            batch: man.eval_batch,
            n_features: man.config.widths[0],
            n_out: man.config.widths[man.config.n_layers()],
        })
    }

    /// Run a batch of feature vectors; returns per-sample logits.
    pub fn infer(&self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        match self {
            Backend::Lut { model, workers, .. } => {
                let plan = &model.plan;
                for x in xs {
                    if x.len() != plan.n_features() {
                        bail!("feature length {} != {}", x.len(), plan.n_features());
                    }
                }
                // Both engines are bit-exact with `Network::forward_codes`;
                // the crossover only trades latency for throughput.  `route`
                // is the single decision point (the batcher's metrics read
                // the same function, so they cannot drift from execution).
                Ok(match self.route(xs.len()).expect("Lut backend routes") {
                    // Blocked, allocation-free batched execution over the
                    // precompiled plan (parallel across blocks).
                    LutEngine::Plan => plan.forward_batch_f32(xs, *workers),
                    // Bit-parallel netlist evaluation at the compiled lane
                    // width, 64–512 samples per word (parallel across
                    // words).
                    LutEngine::Bitslice => model.bitslice.forward_batch_f32(xs, *workers),
                    // Intra-sample sharded execution (route guarantees the
                    // engines exist when this arm is reached).  A faulted
                    // engine — panicked shard, dead wire link — surfaces
                    // here as a clean error instead of a hung batcher.
                    LutEngine::Sharded => model
                        .sharded
                        .as_ref()
                        .expect("route only picks Sharded when compiled")
                        .forward_batch_f32(xs)
                        .context("sharded engine failed")?,
                })
            }
            Backend::Pjrt { engine, exe, params, batch, n_features, n_out } => {
                let mut out = Vec::with_capacity(xs.len());
                for chunk in xs.chunks(*batch) {
                    // Pad the final chunk to the compiled batch size.
                    let mut flat = Vec::with_capacity(batch * n_features);
                    for x in chunk {
                        if x.len() != *n_features {
                            bail!("feature length {} != {}", x.len(), n_features);
                        }
                        flat.extend_from_slice(x);
                    }
                    flat.resize(batch * n_features, 0.0);
                    let xbuf = engine
                        .to_buffer(&f32_literal(&flat, &[*batch as i64, *n_features as i64])?)?;
                    let mut refs: Vec<&xla::PjRtBuffer> = params.iter().collect();
                    refs.push(&xbuf);
                    let outs = exe.run_b(&refs)?;
                    let logits = to_f32_vec(&outs[0])?;
                    for i in 0..chunk.len() {
                        out.push(logits[i * n_out..(i + 1) * n_out].to_vec());
                    }
                }
                Ok(out)
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------------

pub struct ServerConfig {
    /// Maximum requests per batch.
    pub max_batch: usize,
    /// How long the batcher waits to fill a batch.
    pub window: Duration,
    /// Bounded ingress queue (backpressure: submit fails when full).
    pub queue_cap: usize,
    /// Shard-worker epoch spin budget in µs before the condvar sleep
    /// (`None` = `POLYLUT_SHARD_SPIN_US` env, else the engine default;
    /// remote placements default to zero).  Applied when the serve CLI
    /// freezes the model; recorded in `metrics::snapshot()`.
    pub shard_spin_us: Option<u64>,
    /// Wire in-flight window per remote shard link: needs flights (one per
    /// layer boundary) shipped ahead of the last applied result
    /// (`--wire-window`; 1 = the v1 lock-step pacing).
    pub wire_window: usize,
    /// Reconnect-and-resume attempts per link incident before the engine
    /// faults and routing degrades to the in-process plan
    /// (`--wire-retries`).
    pub wire_retries: u32,
    /// Multiplex every (engine, shard) session to one host over a single
    /// TCP connection (`--wire-mux`; default on — `off` restores the v2
    /// one-connection-per-session topology).
    pub wire_mux: bool,
}

impl ServerConfig {
    /// The wire knobs as a [`WireConfig`] for the freeze path.
    pub fn wire(&self) -> WireConfig {
        WireConfig {
            window: self.wire_window.max(1),
            retries: self.wire_retries,
            mux: self.wire_mux,
        }
    }
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            max_batch: 256,
            window: Duration::from_micros(200),
            queue_cap: 4096,
            shard_spin_us: None,
            wire_window: DEFAULT_WIRE_WINDOW,
            wire_retries: DEFAULT_WIRE_RETRIES,
            wire_mux: true,
        }
    }
}

/// Logits → predicted class, shared by the single-server batcher and the
/// fleet replicas.  NaN-safe: a poisoned logit must not panic a serving
/// thread and drop every in-flight request.
pub(crate) fn predict(n_classes: usize, logits: &[f32]) -> usize {
    if n_classes == 1 {
        (logits[0] > 0.0) as usize
    } else {
        crate::util::argmax_f32(logits)
    }
}

struct Request {
    features: Vec<f32>,
    enqueued: Instant,
    resp: SyncSender<Response>,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub logits: Vec<f32>,
    pub pred: usize,
    pub latency: Duration,
}

/// Handle for submitting requests (clonable across client threads).
#[derive(Clone)]
pub struct ClientHandle {
    tx: SyncSender<Request>,
    metrics: Arc<Metrics>,
    n_classes: usize,
}

impl ClientHandle {
    /// Submit one request; blocks until the response arrives.
    pub fn infer(&self, features: Vec<f32>) -> Result<Response> {
        let (tx, rx) = sync_channel(1);
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        let req = Request { features, enqueued: Instant::now(), resp: tx };
        match self.tx.try_send(req) {
            Ok(()) => {}
            Err(TrySendError::Full(_)) => {
                self.metrics.queue_rejects.fetch_add(1, Ordering::Relaxed);
                bail!("server queue full (backpressure)");
            }
            Err(TrySendError::Disconnected(_)) => bail!("server stopped"),
        }
        rx.recv().context("server dropped the request")
    }

    pub fn n_classes(&self) -> usize {
        self.n_classes
    }
}

/// The running server: a batcher thread draining the ingress queue and an
/// inference thread executing batches on the backend.
pub struct Server {
    pub metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
    batcher: Option<std::thread::JoinHandle<()>>,
    handle: ClientHandle,
    pub inflight_hwm: Arc<AtomicU64>,
}

impl Server {
    pub fn start(backend: BackendSpec, n_classes: usize, cfg: ServerConfig) -> Server {
        let metrics = Arc::new(Metrics::new());
        let stop = Arc::new(AtomicBool::new(false));
        let (tx, rx) = sync_channel::<Request>(cfg.queue_cap);
        let handle = ClientHandle { tx, metrics: metrics.clone(), n_classes };
        let m = metrics.clone();
        let s = stop.clone();
        let hwm = Arc::new(AtomicU64::new(0));
        let hwm2 = hwm.clone();
        let batcher = std::thread::Builder::new()
            .name("polylut-batcher".into())
            .spawn(move || batcher_loop(rx, backend, n_classes, cfg, m, s, hwm2))
            .expect("spawn batcher");
        Server { metrics, stop, batcher: Some(batcher), handle, inflight_hwm: hwm }
    }

    pub fn client(&self) -> ClientHandle {
        self.handle.clone()
    }

    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.batcher.take() {
            let _ = h.join();
        }
    }
}

fn batcher_loop(
    rx: Receiver<Request>,
    backend: BackendSpec,
    n_classes: usize,
    cfg: ServerConfig,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
    hwm: Arc<AtomicU64>,
) {
    let backend = match backend.build() {
        Ok(b) => b,
        Err(e) => {
            log::error!("backend construction failed: {e:#}");
            return;
        }
    };
    let rx = Mutex::new(rx);
    while !stop.load(Ordering::Relaxed) {
        // Collect a batch under the window.
        let mut batch: Vec<Request> = Vec::with_capacity(cfg.max_batch);
        {
            let rx = crate::sim::shard::lock_ignore_poison(&rx);
            match rx.recv_timeout(Duration::from_millis(20)) {
                Ok(first) => batch.push(first),
                Err(_) => continue,
            }
            let deadline = Instant::now() + cfg.window;
            while batch.len() < cfg.max_batch {
                let left = deadline.saturating_duration_since(Instant::now());
                if left.is_zero() {
                    break;
                }
                match rx.recv_timeout(left) {
                    Ok(r) => batch.push(r),
                    Err(_) => break,
                }
            }
        }
        hwm.fetch_max(batch.len() as u64, Ordering::Relaxed);
        metrics.batches.fetch_add(1, Ordering::Relaxed);
        metrics.batch_samples.fetch_add(batch.len() as u64, Ordering::Relaxed);

        let xs: Vec<Vec<f32>> = batch.iter().map(|r| r.features.clone()).collect();
        match backend.infer(&xs) {
            Ok(all_logits) => {
                // Count the engine only for batches it actually served
                // (same decision function infer() just used).
                if let Some(engine) = backend.route(batch.len()) {
                    metrics.record_engine(engine);
                    if engine == LutEngine::Sharded {
                        if let Some(stats) = backend.shard_stats() {
                            metrics.record_shard_stats(&stats);
                        }
                        if let Some(ws) = backend.wire_stats() {
                            metrics.record_wire(&ws);
                            metrics.record_wire_hosts(&backend.wire_host_stats());
                        }
                    }
                }
                for (req, logits) in batch.into_iter().zip(all_logits) {
                    let pred = predict(n_classes, &logits);
                    let latency = req.enqueued.elapsed();
                    metrics.record_latency(latency.as_secs_f64() * 1e6);
                    metrics.responses.fetch_add(1, Ordering::Relaxed);
                    let _ = req.resp.send(Response { logits, pred, latency });
                }
            }
            Err(e) => {
                log::error!("batch inference failed: {e:#}");
                // Drop the batch; clients see a disconnected channel.
            }
        }
    }
}

// ---------------------------------------------------------------------------
// CLI entry (polylut serve)
// ---------------------------------------------------------------------------

/// `polylut serve --id <artifact> [--backend lut|pjrt] [--requests N]
///  [--clients N] [--batch-window-us N] [--lanes N|widest]
///  [--bitslice-threshold N] [--shards N] [--shard-hosts a:p,b:p,…]
///  [--shard-spin-us N] [--wire-window N] [--wire-retries N]
///  [--replicas N] [--batch-deadline-us N] [--queue-depth N]` — runs a
/// self-driving load test against the server with dataset samples and
/// prints metrics.  `--lanes` forces the bitslice engine's lane width
/// (64/128/256/512, or `widest` for the detected maximum — the default;
/// also settable via `POLYLUT_LANES`).  `--bitslice-threshold` sets the
/// batch crossover of the LUT backend above which the bitsliced engine
/// takes over (0 = always bitsliced; default two full words of the active
/// lane width, [`EngineSelect::default_crossover_for`]); `--shards N`
/// (default 1) compiles the intra-sample sharded engines and routes every
/// sub-crossover batch through them, so a single request's forward pass
/// runs on N cores (the shard handoff always carries canonical 64-bit
/// planes, whatever the local lane width).  `--shard-hosts` places individual shards on remote
/// `polylut shard-worker` processes (entry i = shard i; `local`/`-`/empty
/// and unlisted shards stay local; duplicate addresses are rejected at
/// parse time), `--shard-spin-us` overrides the worker epoch spin budget
/// (remote placements default to 0), `--wire-window` sets each link's
/// in-flight needs-flight window (1 = v1 lock-step pacing) and
/// `--wire-retries` bounds reconnect-and-resume attempts before routing
/// degrades to the in-process plan.
///
/// `--replicas N` switches the serving front-end from the single batcher
/// thread to the [`fleet`] — N in-process worker replicas over the shared
/// frozen model behind a deadline-aware batch former that packs arrivals
/// toward the active bitslice lane width (`--max-batch` overrides the pack
/// target), dispatching when the word fills or the oldest request's
/// `--batch-deadline-us` budget expires, with bounded `--queue-depth`
/// admission and clean shed errors under overload (LUT backend only).
pub fn serve_cli(dir: &Path, id: &str, args: &Args) -> Result<()> {
    let man = crate::meta::load_id(dir, id)?;
    let ds = crate::data::load(&man.dataset, 0)?;
    let state = crate::train::load_state(&man, &man.dir)
        .context("no trained weights — run `polylut train` first")?;
    let backend_name = args.get_choice("backend", "lut", &["lut", "pjrt"])?.to_string();
    let lanes = match args.get("lanes") {
        Some(raw)
            if raw.trim().eq_ignore_ascii_case("widest")
                || raw.trim().eq_ignore_ascii_case("max")
                || raw.trim() == "0" =>
        {
            Some(crate::simd::widest_lanes())
        }
        Some(raw) => Some(raw.trim().parse::<usize>().map_err(|_| {
            anyhow::anyhow!("--lanes expects a lane count or `widest`, got {raw:?}")
        })?),
        None => None,
    };
    // Resolve the lane plan up front: the crossover default scales with the
    // active lane width (two full words), and `--lanes` errors early on
    // unsupported widths instead of inside the freeze.
    let lane_plan = crate::simd::resolve(lanes)?;
    let netlist_opt = crate::lut::opt::level_from_args(args)?;
    let crossover = args.get_usize(
        "bitslice-threshold",
        EngineSelect::default_crossover_for(lane_plan.lanes),
    )?;
    let shards = args.get_usize("shards", 1)?.max(1);
    let placement = parse_shard_hosts(args.get_or("shard-hosts", ""), shards)?;
    let n_remote = placement.iter().filter(|p| p.is_some()).count();
    let shard_spin_us = match args.get("shard-spin-us") {
        Some(_) => Some(args.get_usize("shard-spin-us", 0)? as u64),
        None => None,
    };
    let wire_window = args.get_usize("wire-window", DEFAULT_WIRE_WINDOW)?;
    if wire_window == 0 {
        bail!(
            "--wire-window 0 is invalid: the window is counted in in-flight epochs \
             and must be ≥ 1 (1 = lock-step pacing, {DEFAULT_WIRE_WINDOW} = default; \
             each session runs at the max of both ends' windows)"
        );
    }
    let cfg = ServerConfig {
        max_batch: args.get_usize("max-batch", 256)?,
        window: Duration::from_micros(args.get_usize("batch-window-us", 200)? as u64),
        shard_spin_us,
        wire_window,
        wire_retries: args.get_usize("wire-retries", DEFAULT_WIRE_RETRIES as usize)? as u32,
        wire_mux: args.get_choice("wire-mux", "on", &["on", "off"])? == "on",
        ..Default::default()
    };
    let net = man.network_from_state(&state)?;
    let mut frozen: Option<Arc<FrozenModel>> = None;
    let backend = match backend_name.as_str() {
        "lut" => {
            let model = Arc::new(FrozenModel::from_network_placed_wire(
                net,
                crate::util::pool::default_workers(),
                shards,
                &placement,
                cfg.shard_spin_us,
                cfg.wire(),
                Some(lane_plan.lanes),
                netlist_opt,
            )?);
            frozen = Some(model.clone());
            BackendSpec::lut_with_select(
                model,
                crate::util::pool::default_workers(),
                EngineSelect { crossover, shards },
            )
        }
        "pjrt" => BackendSpec::pjrt(man.clone(), state.clone()),
        other => unreachable!("get_choice admitted unknown backend {other:?}"),
    };
    let n_requests = args.get_usize("requests", 10_000)?;
    let n_clients = args.get_usize("clients", 4)?;
    if args.get("replicas").is_some() {
        if backend_name != "lut" {
            bail!("--replicas (replica fleet) requires --backend lut");
        }
        let model = frozen.clone().expect("lut backend froze a model");
        let fcfg = FleetConfig {
            replicas: args.get_usize("replicas", 2)?.max(1),
            // 0 = pack toward the model's active bitslice lane width;
            // --max-batch overrides the target explicitly.
            target_batch: args.get_usize("max-batch", 0)?,
            batch_deadline: Duration::from_micros(
                args.get_usize("batch-deadline-us", 200)? as u64,
            ),
            queue_depth: args.get_usize("queue-depth", 4096)?.max(1),
            shed_after: None,
        };
        return serve_fleet(
            id,
            &ds,
            model,
            EngineSelect { crossover, shards },
            man.config.n_classes,
            fcfg,
            n_requests,
            n_clients,
        );
    }
    let (wire_window, wire_retries, wire_mux) =
        (cfg.wire_window, cfg.wire_retries, cfg.wire_mux);
    let server = Server::start(backend, man.config.n_classes, cfg);
    if let Some(sharded) = frozen.as_ref().and_then(|m| m.sharded.as_ref()) {
        server.metrics.set_shard_spin_us(sharded.spin_us());
    }
    if let Some(model) = frozen.as_ref() {
        // Mirror the static-verification outcome of the served artifacts
        // (the compile gate already rejected hard violations when enabled;
        // this records the count even on release builds with the gate off).
        let report = crate::sim::verify::verify_frozen(&model.plan, &model.bitslice);
        server.metrics.record_verify(report.total() as u64);
        // Surface the active SIMD level / lane width in `snapshot()`.
        let lp = model.bitslice.lane_plan();
        server.metrics.set_simd(lp.level, lp.lanes as u64);
        // And the netlist-optimization outcome (level + word-op delta).
        let r = &model.opt_report;
        server.metrics.set_netlist_opt(
            r.level,
            r.ops_before() as u64,
            r.ops_after() as u64,
        );
    }

    if backend_name == "lut" {
        let wire_note = if n_remote > 0 {
            format!(
                " wire-window={wire_window} wire-retries={wire_retries} wire-mux={}",
                if wire_mux { "on" } else { "off" }
            )
        } else {
            String::new()
        };
        println!(
            "[serve] {id} backend=lut (lanes={} simd={} bitslice-threshold={crossover} shards={shards} remote={n_remote}{wire_note}): {n_requests} requests from {n_clients} clients…",
            lane_plan.lanes,
            lane_plan.level.as_str(),
        );
    } else {
        println!("[serve] {id} backend={backend_name}: {n_requests} requests from {n_clients} clients…");
    }
    let t0 = Instant::now();
    let correct = Arc::new(AtomicU64::new(0));
    std::thread::scope(|scope| {
        for c in 0..n_clients {
            let client = server.client();
            let ds = &ds;
            let correct = correct.clone();
            scope.spawn(move || {
                let per = n_requests / n_clients;
                for i in 0..per {
                    let idx = (c * per + i) % ds.n_test();
                    match client.infer(ds.test_row(idx).to_vec()) {
                        Ok(resp) => {
                            if resp.pred == ds.y_test[idx] {
                                correct.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                        Err(e) => log::warn!("request failed: {e}"),
                    }
                }
            });
        }
    });
    let wall = t0.elapsed();
    let served = server.metrics.responses.load(Ordering::Relaxed);
    println!("[serve] {}", server.metrics.snapshot());
    println!(
        "[serve] throughput {:.0} req/s, accuracy {:.4}, wall {:.2}s",
        served as f64 / wall.as_secs_f64(),
        correct.load(Ordering::Relaxed) as f64 / served.max(1) as f64,
        wall.as_secs_f64()
    );
    server.shutdown();
    Ok(())
}

/// The `serve --replicas` path: drive the dataset load test through the
/// replica fleet instead of the single-batcher [`Server`], counting shed /
/// backpressure outcomes separately from hard failures.
#[allow(clippy::too_many_arguments)]
fn serve_fleet(
    id: &str,
    ds: &crate::data::Dataset,
    model: Arc<FrozenModel>,
    select: EngineSelect,
    n_classes: usize,
    fcfg: FleetConfig,
    n_requests: usize,
    n_clients: usize,
) -> Result<()> {
    let workers = crate::util::pool::default_workers();
    let replicas = fcfg.replicas.max(1);
    let deadline_us = fcfg.batch_deadline.as_micros();
    let queue_depth = fcfg.queue_depth;
    let target = if fcfg.target_batch == 0 {
        model.bitslice.lanes()
    } else {
        fcfg.target_batch
    };
    let fleet = Fleet::start(model.clone(), workers, select, n_classes, fcfg);
    if let Some(sharded) = model.sharded.as_ref() {
        fleet.metrics.set_shard_spin_us(sharded.spin_us());
    }
    // Same observability as the single-server path: verification outcome
    // and the live SIMD kernel path of the served artifacts.
    let report = crate::sim::verify::verify_frozen(&model.plan, &model.bitslice);
    fleet.metrics.record_verify(report.total() as u64);
    let lp = model.bitslice.lane_plan();
    fleet.metrics.set_simd(lp.level, lp.lanes as u64);
    let r = &model.opt_report;
    fleet.metrics.set_netlist_opt(r.level, r.ops_before() as u64, r.ops_after() as u64);
    println!(
        "[serve] {id} fleet: replicas={replicas} target-batch={target} \
         batch-deadline-us={deadline_us} queue-depth={queue_depth}: \
         {n_requests} requests from {n_clients} clients…"
    );
    let t0 = Instant::now();
    let correct = Arc::new(AtomicU64::new(0));
    let shed = Arc::new(AtomicU64::new(0));
    std::thread::scope(|scope| {
        for c in 0..n_clients {
            let client = fleet.client();
            let correct = correct.clone();
            let shed = shed.clone();
            scope.spawn(move || {
                let per = n_requests / n_clients;
                for i in 0..per {
                    let idx = (c * per + i) % ds.n_test();
                    match client.infer(ds.test_row(idx).to_vec()) {
                        Ok(resp) => {
                            if resp.pred == ds.y_test[idx] {
                                correct.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                        Err(FleetError::Shed { .. } | FleetError::QueueFull { .. }) => {
                            shed.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(e) => log::warn!("request failed: {e}"),
                    }
                }
            });
        }
    });
    let wall = t0.elapsed();
    let served = fleet.metrics.responses.load(Ordering::Relaxed);
    println!("[serve] {}", fleet.metrics.snapshot());
    println!(
        "[serve] throughput {:.0} req/s, accuracy {:.4}, shed+rejected {}, wall {:.2}s",
        served as f64 / wall.as_secs_f64(),
        correct.load(Ordering::Relaxed) as f64 / served.max(1) as f64,
        shed.load(Ordering::Relaxed),
        wall.as_secs_f64()
    );
    fleet.shutdown();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::config;
    use crate::util::rng::Rng;

    fn model() -> Arc<FrozenModel> {
        let cfg = config::uniform("srv", &[8, 6, 3], 2, 2, 3, 3, 3, 1, 2, 3);
        let net = Network::random(&cfg, &mut Rng::new(4));
        Arc::new(FrozenModel::from_network(net, 2))
    }

    /// The default `fold+dc` pipeline is bit-exact vs the unoptimized
    /// compile on both whole-model engine routes (decoded-table plan and
    /// widest-lane bitslice), across the (A, degree) grid — the
    /// engine-route face of the opt-equivalence contract (the sharded and
    /// wire routes inherit it through `bits_kernel_of`'s env-resolved
    /// fold, exercised by the existing sharded/loopback suites).
    #[test]
    fn netlist_opt_engines_bit_exact_across_grid() {
        for (a, d) in [(1usize, 1u32), (2, 1), (1, 2), (2, 2), (2, 3)] {
            let cfg = config::uniform("opt-grid", &[8, 6, 3], 2, 2, 3, 3, 3, d, a, 3);
            let net = Network::random(&cfg, &mut Rng::new(40 + a as u64 * 7 + d as u64));
            let workers = 2;
            let tables = crate::lut::compile_network(&net, workers);
            let plain_plan = EvalPlan::compile(&net, &tables);
            let opt = crate::lut::optimize(&net, tables, OptLevel::FoldDc, workers);
            let opt_plan = EvalPlan::compile(&net, &opt.tables);
            let bits = BitsliceNet::from_mapped(&net, &opt.tables, &opt.mapped)
                .with_lane_plan(crate::simd::plan_for(crate::simd::widest_lanes()));
            let mut rng = Rng::new(9);
            let rows: Vec<Vec<i32>> = (0..150)
                .map(|_| {
                    let x: Vec<f32> = (0..cfg.widths[0]).map(|_| rng.f32()).collect();
                    net.quantize_input(&x)
                })
                .collect();
            let mut s0 = crate::sim::Scratch::for_plan(&plain_plan);
            let mut s1 = crate::sim::Scratch::for_plan(&opt_plan);
            let expected = plain_plan.forward_batch(&rows, &mut s0);
            assert_eq!(opt_plan.forward_batch(&rows, &mut s1), expected, "plan a={a} d={d}");
            let mut bs = bits.scratch();
            assert_eq!(bits.forward_batch(&rows, &mut bs), expected, "bitslice a={a} d={d}");
        }
    }

    #[test]
    fn server_roundtrip_lut_backend() {
        let m = model();
        let backend = BackendSpec::lut(m.clone(), 2);
        let server = Server::start(
            backend,
            3,
            ServerConfig {
                max_batch: 8,
                window: Duration::from_micros(100),
                queue_cap: 64,
                ..Default::default()
            },
        );
        let client = server.client();
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let x: Vec<f32> = (0..8).map(|_| rng.f32()).collect();
            let resp = client.infer(x.clone()).unwrap();
            // Response must equal direct LUT-sim evaluation.
            assert_eq!(resp.logits, m.sim().forward(&x));
            assert!(resp.pred < 3);
        }
        assert_eq!(server.metrics.responses.load(Ordering::Relaxed), 50);
        server.shutdown();
    }

    /// Forcing every batch through the bitsliced engine must be invisible
    /// to clients (bit-exact logits) and visible in the routing metrics.
    #[test]
    fn bitslice_route_is_bit_exact_and_recorded() {
        let m = model();
        let backend = BackendSpec::lut_with_select(m.clone(), 2, EngineSelect::bitslice_only());
        let server = Server::start(
            backend,
            3,
            ServerConfig {
                max_batch: 8,
                window: Duration::from_micros(100),
                queue_cap: 64,
                ..Default::default()
            },
        );
        let client = server.client();
        let mut rng = Rng::new(2);
        for _ in 0..30 {
            let x: Vec<f32> = (0..8).map(|_| rng.f32()).collect();
            let resp = client.infer(x.clone()).unwrap();
            assert_eq!(resp.logits, m.sim().forward(&x));
        }
        assert_eq!(server.metrics.responses.load(Ordering::Relaxed), 30);
        assert!(server.metrics.bitslice_batches.load(Ordering::Relaxed) > 0);
        assert_eq!(server.metrics.plan_batches.load(Ordering::Relaxed), 0);
        server.shutdown();
    }

    /// With `--shards`-style selection, sub-crossover batches route to the
    /// intra-sample sharded engines — invisible to clients (bit-exact
    /// logits), visible in the routing metrics and the mirrored per-shard
    /// counters.
    #[test]
    fn sharded_route_is_bit_exact_and_recorded() {
        let cfg = config::uniform("srv-sh", &[8, 6, 3], 2, 2, 3, 3, 3, 1, 2, 3);
        let net = Network::random(&cfg, &mut Rng::new(4));
        let m = Arc::new(FrozenModel::from_network_sharded(net, 2, 3));
        assert!(m.sharded.is_some(), "shards > 1 must compile the sharded engines");
        let select = EngineSelect { crossover: usize::MAX, shards: 3 };
        let backend = BackendSpec::lut_with_select(m.clone(), 2, select);
        let server = Server::start(
            backend,
            3,
            ServerConfig {
                max_batch: 8,
                window: Duration::from_micros(100),
                queue_cap: 64,
                ..Default::default()
            },
        );
        let client = server.client();
        let mut rng = Rng::new(2);
        for _ in 0..30 {
            let x: Vec<f32> = (0..8).map(|_| rng.f32()).collect();
            let resp = client.infer(x.clone()).unwrap();
            assert_eq!(resp.logits, m.sim().forward(&x));
        }
        assert_eq!(server.metrics.responses.load(Ordering::Relaxed), 30);
        assert!(server.metrics.sharded_batches.load(Ordering::Relaxed) > 0);
        assert_eq!(server.metrics.plan_batches.load(Ordering::Relaxed), 0);
        assert_eq!(server.metrics.bitslice_batches.load(Ordering::Relaxed), 0);
        let shard_stats = server.metrics.shard_stats();
        assert_eq!(shard_stats.len(), 3, "one counter row per shard");
        assert!(shard_stats.iter().all(|s| s.cells > 0));
        assert!(server.metrics.snapshot().contains("shard_cells="));
        server.shutdown();
    }

    /// A placed model (one shard behind a loopback shard-worker host)
    /// serves through the full batching stack bit-exactly, and the wire
    /// counters reach the metrics snapshot.
    #[test]
    fn wire_placed_route_is_bit_exact_and_recorded() {
        let cfg = config::uniform("srv-wire", &[8, 6, 3], 2, 2, 3, 3, 3, 1, 2, 3);
        let net = Network::random(&cfg, &mut Rng::new(4));
        let tables = crate::lut::tables::compile_network(&net, 2);
        let host =
            Arc::new(crate::sim::ShardWorkerHost::compile(&net, &tables, 2, 2));
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        std::thread::spawn(move || host.serve(listener));
        let placement = vec![None, Some(addr)];
        let m = Arc::new(
            FrozenModel::from_network_placed(net, 2, 2, &placement, None)
                .expect("loopback placement"),
        );
        let sharded = m.sharded.as_ref().expect("sharded engines compiled");
        assert_eq!(sharded.spin_us(), 0, "remote placement defaults to zero spin");
        let select = EngineSelect { crossover: usize::MAX, shards: 2 };
        let backend = BackendSpec::lut_with_select(m.clone(), 2, select);
        let server = Server::start(
            backend,
            3,
            ServerConfig {
                max_batch: 8,
                window: Duration::from_micros(100),
                queue_cap: 64,
                ..Default::default()
            },
        );
        server.metrics.set_shard_spin_us(sharded.spin_us());
        let client = server.client();
        let mut rng = Rng::new(2);
        for _ in 0..20 {
            let x: Vec<f32> = (0..8).map(|_| rng.f32()).collect();
            let resp = client.infer(x.clone()).unwrap();
            assert_eq!(resp.logits, m.sim().forward(&x));
        }
        assert_eq!(server.metrics.responses.load(Ordering::Relaxed), 20);
        assert!(server.metrics.sharded_batches.load(Ordering::Relaxed) > 0);
        assert!(server.metrics.wire_frames.load(Ordering::Relaxed) > 0);
        let snap = server.metrics.snapshot();
        assert!(snap.contains("wire_frames="), "{snap}");
        assert!(snap.contains("shard_spin_us=0"), "{snap}");
        server.shutdown();
    }

    /// A backend whose selection asks for shards but whose model was frozen
    /// without them falls back to the plan engine instead of panicking.
    #[test]
    fn shardless_model_falls_back_to_plan() {
        let m = model();
        let select = EngineSelect { crossover: usize::MAX, shards: 4 };
        let backend = Backend::Lut { model: m, workers: 2, select };
        assert_eq!(backend.route(1), Some(LutEngine::Plan));
        assert!(backend.shard_stats().is_none());
    }

    /// A sticky engine fault must degrade routing to the in-process plan
    /// engine — later batches keep being served bit-exactly instead of
    /// erroring until the server is restarted.
    #[test]
    fn faulted_sharded_engine_degrades_to_plan() {
        let cfg = config::uniform("srv-flt", &[8, 6, 3], 2, 2, 3, 3, 3, 1, 2, 3);
        let net = Network::random(&cfg, &mut Rng::new(4));
        let m = Arc::new(FrozenModel::from_network_sharded(net, 2, 2));
        let select = EngineSelect { crossover: usize::MAX, shards: 2 };
        let backend = Backend::Lut { model: m.clone(), workers: 2, select };
        assert_eq!(backend.route(1), Some(LutEngine::Sharded), "healthy: sharded");
        m.sharded.as_ref().unwrap().inject_fault("test wire death");
        assert_eq!(backend.route(1), Some(LutEngine::Plan), "faulted: degrade");
        // infer() keeps serving through the plan engine, bit-exactly.
        let mut rng = Rng::new(9);
        let xs: Vec<Vec<f32>> =
            (0..3).map(|_| (0..8).map(|_| rng.f32()).collect()).collect();
        let out = backend.infer(&xs).expect("degraded backend still serves");
        let sim = m.sim();
        for (x, got) in xs.iter().zip(&out) {
            assert_eq!(got, &sim.forward(x));
        }
    }

    /// The default policy keeps single-request batches on the plan engine,
    /// with the crossover derived from the model's compiled lane width.
    #[test]
    fn small_batches_route_to_plan() {
        let m = model();
        let backend = Backend::lut(m.clone(), 2);
        let crossover = match &backend {
            Backend::Lut { select, .. } => select.crossover,
            Backend::Pjrt { .. } => unreachable!("lut backend"),
        };
        assert_eq!(
            crossover,
            EngineSelect::default_crossover_for(m.bitslice.lanes()),
            "crossover derives from the model's compiled lane width"
        );
        assert_eq!(backend.route(1), Some(LutEngine::Plan));
        assert_eq!(backend.route(crossover - 1), Some(LutEngine::Plan));
        assert_eq!(backend.route(crossover), Some(LutEngine::Bitslice));
        // Route choice is bit-exact either way on a whole batch.
        let mut rng = Rng::new(6);
        let xs: Vec<Vec<f32>> =
            (0..150).map(|_| (0..8).map(|_| rng.f32()).collect()).collect();
        let small = backend.infer(&xs[..4]).unwrap();
        let sim = m.sim();
        for (x, got) in xs[..4].iter().zip(&small) {
            assert_eq!(got, &sim.forward(x));
        }
        let large = backend.infer(&xs).unwrap();
        for (x, got) in xs.iter().zip(&large) {
            assert_eq!(got, &sim.forward(x));
        }
    }

    /// A model frozen at the widest detected lane width (the `--lanes`
    /// path) serves bit-exactly through the bitslice route.
    #[test]
    fn wide_frozen_model_serves_bit_exact() {
        let cfg = config::uniform("srv-w", &[8, 6, 3], 2, 2, 3, 3, 3, 1, 2, 3);
        let net = Network::random(&cfg, &mut Rng::new(4));
        let widest = crate::simd::widest_lanes();
        let m = Arc::new(
            FrozenModel::from_network_placed_wire(
                net,
                2,
                1,
                &[],
                None,
                WireConfig::default(),
                Some(widest),
                None,
            )
            .expect("wide all-local freeze"),
        );
        assert_eq!(m.bitslice.lanes(), widest);
        let backend =
            Backend::Lut { model: m.clone(), workers: 2, select: EngineSelect::bitslice_only() };
        let mut rng = Rng::new(12);
        let xs: Vec<Vec<f32>> =
            (0..(widest + 9)).map(|_| (0..8).map(|_| rng.f32()).collect()).collect();
        let out = backend.infer(&xs).expect("wide bitslice route serves");
        let sim = m.sim();
        for (x, got) in xs.iter().zip(&out) {
            assert_eq!(got, &sim.forward(x));
        }
    }

    #[test]
    fn batcher_groups_concurrent_clients() {
        let m = model();
        let server = Server::start(
            BackendSpec::lut(m, 2),
            3,
            ServerConfig {
                max_batch: 64,
                window: Duration::from_millis(5),
                queue_cap: 1024,
                ..Default::default()
            },
        );
        std::thread::scope(|scope| {
            for _ in 0..8 {
                let client = server.client();
                scope.spawn(move || {
                    let mut rng = Rng::new(7);
                    for _ in 0..25 {
                        let x: Vec<f32> = (0..8).map(|_| rng.f32()).collect();
                        client.infer(x).unwrap();
                    }
                });
            }
        });
        assert_eq!(server.metrics.responses.load(Ordering::Relaxed), 200);
        // With 8 concurrent clients and a 5 ms window, batches must form.
        assert!(
            server.metrics.mean_batch_size() > 1.5,
            "mean batch {}",
            server.metrics.mean_batch_size()
        );
        server.shutdown();
    }
}
