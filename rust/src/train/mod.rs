//! Rust-driven training: the L3 loop around the AOT-lowered `train_step`.
//!
//! Python lowered one optimizer step to HLO at build time; this driver owns
//! everything else — data order, minibatch assembly, restarts (the paper
//! notes UNSW-NB15 convergence is seed-sensitive and needs multiple trials),
//! model selection, and checkpointing trained weights to JSON for the LUT
//! compiler.  No Python runs here.

use std::path::Path;

use anyhow::{bail, Context, Result};
use xla::Literal;

use crate::data::{BatchSampler, Dataset};
use crate::meta::{Manifest, Role};
use crate::nn::network::Network;
use crate::nn::poly::monomial_count;
use crate::runtime::{f32_literal, i32_literal, to_f32_vec, Engine, Executable};
use crate::util::json::{Json, JsonObj};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct TrainOptions {
    pub steps: usize,
    /// Batch-order / restart seed (independent of the model init seed).
    pub seed: u64,
    pub log_every: usize,
    /// Train `restarts` times and keep the best by deployed test accuracy.
    pub restarts: usize,
    /// Samples of the test split used for model selection (0 = all).
    pub select_limit: usize,
    pub verbose: bool,
}

impl Default for TrainOptions {
    fn default() -> Self {
        Self {
            steps: 400,
            seed: 0,
            log_every: 100,
            restarts: 1,
            select_limit: 2000,
            verbose: false,
        }
    }
}

#[derive(Debug, Clone)]
pub struct TrainOutcome {
    /// Final training state (manifest order).
    pub state: Vec<Vec<f32>>,
    pub final_loss: f32,
    /// Deployed-semantics test accuracy (hardware-functional model).
    pub test_acc: f64,
    /// (step, loss, batch_acc) trace of the winning restart.
    pub history: Vec<(usize, f32, f32)>,
    pub restarts_run: usize,
}

fn shape_dims(shape: &[usize]) -> Vec<i64> {
    shape.iter().map(|&s| s as i64).collect()
}

/// Build the state literals from flat f32 vectors.
fn state_literals(man: &Manifest, state: &[Vec<f32>]) -> Result<Vec<Literal>> {
    man.state
        .iter()
        .zip(state)
        .map(|(spec, vals)| f32_literal(vals, &shape_dims(&spec.shape)))
        .collect()
}

/// Fresh random init for restart r > 0 (same distributions as model.py).
fn reinit_state(man: &Manifest, rng: &mut Rng) -> Vec<Vec<f32>> {
    let cfg = &man.config;
    man.state
        .iter()
        .zip(&man.init)
        .map(|(spec, init)| {
            let kind = spec.name.rsplit('.').next().unwrap_or("");
            match (spec.role, kind) {
                (Role::Train, "w") => {
                    // l{i}.w — shape [A, n_out, M]; He-style on M.
                    let dot = spec.name.find('.').unwrap_or(spec.name.len());
                    let layer: usize = spec.name[1..dot].parse().unwrap_or(0);
                    let m = monomial_count(cfg.fan[layer], cfg.degree);
                    let std = 1.0 / (m as f64).sqrt();
                    init.iter().map(|_| rng.normal_ms(0.0, std) as f32).collect()
                }
                // Scales / BN / stats / opt moments: restart from the same
                // deterministic values the manifest carries.
                _ => init.clone(),
            }
        })
        .collect()
}

/// Assemble one minibatch into (x, y) literals.
fn batch_literals(
    ds: &Dataset,
    idx: &[usize],
    n_features: usize,
) -> Result<(Literal, Literal)> {
    let mut x = Vec::with_capacity(idx.len() * n_features);
    let mut y = Vec::with_capacity(idx.len());
    for &i in idx {
        x.extend_from_slice(ds.train_row(i));
        y.push(ds.y_train[i] as i32);
    }
    Ok((
        f32_literal(&x, &[idx.len() as i64, n_features as i64])?,
        i32_literal(&y, &[idx.len() as i64])?,
    ))
}

/// Run one training (single restart); returns (state, history, final_loss).
fn run_once(
    engine: &Engine,
    exe: &Executable,
    man: &Manifest,
    ds: &Dataset,
    init: &[Vec<f32>],
    opts: &TrainOptions,
    restart: usize,
) -> Result<(Vec<Vec<f32>>, Vec<(usize, f32, f32)>, f32)> {
    let n_state = man.state.len();
    let mut state = state_literals(man, init)?;
    let mut sampler = BatchSampler::new(ds.n_train(), opts.seed ^ (restart as u64) << 17);
    let mut history = Vec::new();
    let mut last_loss = f32::NAN;
    for step in 0..opts.steps {
        let idx = sampler.next_batch(man.batch);
        let (x, y) = batch_literals(ds, &idx, ds.n_features)?;
        let mut args: Vec<&Literal> = Vec::with_capacity(n_state + 2);
        args.extend(state.iter());
        args.push(&x);
        args.push(&y);
        // Leak-free execute_b path (see runtime::Executable::run docs).
        let bufs: Vec<xla::PjRtBuffer> = args
            .iter()
            .map(|l| engine.to_buffer(l))
            .collect::<Result<_>>()?;
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        let outs = exe.run_b(&refs).with_context(|| format!("train step {step}"))?;
        if outs.len() != n_state + 2 {
            bail!("train_step returned {} outputs, expected {}", outs.len(), n_state + 2);
        }
        let mut outs = outs;
        let acc_l = outs.pop().expect("length checked above: n_state + 2 outputs");
        let loss_l = outs.pop().expect("length checked above: n_state + 2 outputs");
        state = outs;
        let loss = to_f32_vec(&loss_l)?[0];
        let acc = to_f32_vec(&acc_l)?[0];
        last_loss = loss;
        if !loss.is_finite() {
            bail!("loss diverged (NaN/inf) at step {step}");
        }
        if step % opts.log_every == 0 || step + 1 == opts.steps {
            history.push((step, loss, acc));
            if opts.verbose {
                eprintln!("[train {}] r{restart} step {step}: loss {loss:.4} acc {acc:.3}", man.id);
            }
        }
    }
    let final_state: Result<Vec<Vec<f32>>> = state.iter().map(to_f32_vec).collect();
    Ok((final_state?, history, last_loss))
}

/// Deployed-semantics evaluation: build the hardware-functional network and
/// measure accuracy on the test split (the number the paper reports).
pub fn deployed_accuracy(
    man: &Manifest,
    state: &[Vec<f32>],
    ds: &Dataset,
    limit: usize,
) -> Result<(Network, f64)> {
    let net = man.network_from_state(state)?;
    let n = if limit == 0 { ds.n_test() } else { ds.n_test().min(limit) };
    let correct: usize = (0..n)
        .filter(|&i| net.predict(ds.test_row(i)) == ds.y_test[i])
        .count();
    Ok((net, correct as f64 / n.max(1) as f64))
}

/// Train with restarts; keep the best state by deployed test accuracy.
pub fn train(
    engine: &Engine,
    man: &Manifest,
    ds: &Dataset,
    opts: &TrainOptions,
) -> Result<TrainOutcome> {
    if ds.n_features != man.config.widths[0] {
        bail!(
            "dataset {} has {} features but model {} expects {}",
            ds.name,
            ds.n_features,
            man.id,
            man.config.widths[0]
        );
    }
    let exe = engine.load_hlo(&man.train_hlo)?;
    let mut rng = Rng::new(opts.seed ^ 0x7314_AB1E);
    let mut best: Option<TrainOutcome> = None;
    for r in 0..opts.restarts.max(1) {
        let init: Vec<Vec<f32>> =
            if r == 0 { man.init.clone() } else { reinit_state(man, &mut rng) };
        let (state, history, final_loss) = run_once(engine, &exe, man, ds, &init, opts, r)?;
        let (_, acc) = deployed_accuracy(man, &state, ds, opts.select_limit)?;
        if opts.verbose {
            eprintln!("[train {}] restart {r}: deployed acc {acc:.4}", man.id);
        }
        let outcome = TrainOutcome {
            state,
            final_loss,
            test_acc: acc,
            history,
            restarts_run: r + 1,
        };
        if best.as_ref().map(|b| acc > b.test_acc).unwrap_or(true) {
            best = Some(outcome);
        }
    }
    let mut best = best.expect("at least one restart");
    best.restarts_run = opts.restarts.max(1);
    Ok(best)
}

// ---- checkpointing ----------------------------------------------------------

/// Save a trained state vector next to the artifacts
/// (`<dir>/<id>.weights.json`).
pub fn save_state(man: &Manifest, state: &[Vec<f32>], dir: &Path) -> Result<std::path::PathBuf> {
    save_state_tagged(man, state, dir, 0)
}

/// Save with a training-recipe tag (steps) so `train_or_load` can refuse
/// checkpoints trained under a different budget.
pub fn save_state_tagged(
    man: &Manifest,
    state: &[Vec<f32>],
    dir: &Path,
    steps: usize,
) -> Result<std::path::PathBuf> {
    let mut obj = JsonObj::new();
    obj.insert("id", man.id.as_str());
    obj.insert("steps", steps);
    obj.insert(
        "state",
        Json::Arr(
            state
                .iter()
                .map(|v| Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect()))
                .collect(),
        ),
    );
    let path = dir.join(format!("{}.weights.json", man.id));
    std::fs::write(&path, Json::Obj(obj).to_string())?;
    Ok(path)
}

/// Load a previously saved state (shape-checked against the manifest).
pub fn load_state(man: &Manifest, dir: &Path) -> Result<Vec<Vec<f32>>> {
    load_state_tagged(man, dir, None)
}

/// Load a checkpoint; when `want_steps` is given, reject checkpoints trained
/// under a different step budget (keeps bench comparisons fair).
pub fn load_state_tagged(
    man: &Manifest,
    dir: &Path,
    want_steps: Option<usize>,
) -> Result<Vec<Vec<f32>>> {
    let path = dir.join(format!("{}.weights.json", man.id));
    let j = Json::parse_file(&path)?;
    if j.field("id")?.as_str()? != man.id {
        bail!("weights file {} is for a different artifact", path.display());
    }
    if let Some(want) = want_steps {
        let got = j.field("steps").and_then(|v| v.as_usize()).unwrap_or(0);
        if got != want {
            bail!("checkpoint trained for {got} steps, want {want}");
        }
    }
    let state: Vec<Vec<f32>> = j
        .field("state")?
        .as_arr()?
        .iter()
        .map(|v| v.f32_vec())
        .collect::<Result<_>>()?;
    if state.len() != man.state.len() {
        bail!("weights tensor count mismatch");
    }
    for (spec, vals) in man.state.iter().zip(&state) {
        if vals.len() != spec.shape.iter().product::<usize>() {
            bail!("{}: weight length mismatch", spec.name);
        }
    }
    Ok(state)
}

/// Load trained weights if present, else train and save.
pub fn train_or_load(
    engine: &Engine,
    man: &Manifest,
    ds: &Dataset,
    opts: &TrainOptions,
) -> Result<(Vec<Vec<f32>>, f64)> {
    if let Ok(state) = load_state_tagged(man, &man.dir, Some(opts.steps)) {
        let (_, acc) = deployed_accuracy(man, &state, ds, opts.select_limit)?;
        return Ok((state, acc));
    }
    let outcome = train(engine, man, ds, opts)?;
    save_state_tagged(man, &outcome.state, &man.dir, opts.steps)?;
    Ok((outcome.state, outcome.test_acc))
}
